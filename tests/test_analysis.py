"""Unit tests for the ``repro.analysis`` layer (DESIGN.md §13).

Each lint rule gets a violation/clean fixture pair; the jaxpr audits are
exercised on hand-built traces, including a deliberate re-introduction of
the PR 4 threefry-into-SpMM fusion (A1 must fire) next to its shipped
QR-orthonormalized fix (A1 must stay silent). The VMEM estimator is
checked against hand-computed byte counts for the shipped ``spmm_tiled``
tile config, and A3 is asserted against the two real drivers the issue
names: ``lamc_cocluster`` and ``streaming.assign_rows``.
"""

import json
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

from repro.analysis import entry_points, findings as fmod, vmem
from repro.analysis.ast_lint import lint_source
from repro.analysis.cli import main as cli_main
from repro.analysis.jaxpr_audit import (
    audit_dtypes,
    audit_rng_gather,
    count_recompiles,
)
from repro.kernels import ops as kops


def lint(src: str, path: str = "src/repro/_fixture.py"):
    return lint_source(path, textwrap.dedent(src))


def rules_of(findings):
    return sorted({f.rule for f in findings})


# --------------------------------------------------------------------------
# R1 — PRNG key reuse
# --------------------------------------------------------------------------


class TestR1:
    def test_double_sample_fires(self):
        fs = lint("""
            import jax

            def f(seed):
                k = jax.random.key(seed)
                a = jax.random.normal(k, (4,))
                b = jax.random.normal(k, (4,))
                return a + b
        """)
        assert rules_of(fs) == ["R1"]

    def test_sample_after_split_fires(self):
        fs = lint("""
            import jax

            def f(seed):
                k = jax.random.key(seed)
                k1, k2 = jax.random.split(k)
                return jax.random.normal(k, (4,))
        """)
        assert rules_of(fs) == ["R1"]

    def test_fold_in_after_sample_is_clean(self):
        # deriving a child from a consumed key is safe: the child stream
        # is distinct from the sample already drawn (the "sample then
        # fold_in the same parent" idiom in models/transformer.py)
        fs = lint("""
            import jax

            def f(seed):
                k = jax.random.key(seed)
                a = jax.random.normal(k, (4,))
                k2 = jax.random.fold_in(k, 1)
                return a + jax.random.normal(k2, (4,))
        """)
        assert fs == []

    def test_split_fanout_is_clean(self):
        # fn(keys[i]) hands over one element of a key batch, not the batch
        fs = lint("""
            import jax

            def g(k):
                return jax.random.normal(k, (4,))

            def f(seed):
                keys = jax.random.split(jax.random.key(seed), 4)
                return g(keys[0]) + g(keys[1])
        """)
        assert fs == []

    def test_whole_key_escapes_twice_fires(self):
        fs = lint("""
            import jax

            def g(k):
                return jax.random.normal(k, (4,))

            def f(seed):
                k = jax.random.key(seed)
                return g(k) + g(k)
        """)
        assert "R1" in rules_of(fs)

    def test_loop_reconsume_fires_and_rebind_is_clean(self):
        bad = lint("""
            import jax

            def f(k, xs):
                out = 0.0
                for x in xs:
                    out = out + x * jax.random.normal(k, ())
                return out
        """)
        assert "R1" in rules_of(bad)
        good = lint("""
            import jax

            def f(seed, n):
                out = 0.0
                for k in jax.random.split(jax.random.key(seed), n):
                    out = out + jax.random.normal(k, ())
                return out
        """)
        assert good == []


# --------------------------------------------------------------------------
# R2 — host sync in jitted scope
# --------------------------------------------------------------------------


class TestR2:
    def test_float_on_traced_value_fires(self):
        fs = lint("""
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return float(jnp.sum(x))
        """)
        assert rules_of(fs) == ["R2"]

    def test_item_in_jit_reachable_callee_fires(self):
        fs = lint("""
            import jax
            import jax.numpy as jnp

            def helper(x):
                return x.item()

            @jax.jit
            def f(x):
                return helper(jnp.sum(x))
        """)
        assert rules_of(fs) == ["R2"]

    def test_host_sync_outside_jit_is_clean(self):
        fs = lint("""
            import jax.numpy as jnp

            def report(x):
                return float(jnp.sum(x))
        """)
        assert fs == []

    def test_jnp_only_jit_body_is_clean(self):
        fs = lint("""
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return jnp.sum(x) * 2.0
        """)
        assert fs == []


# --------------------------------------------------------------------------
# R3 — non-static Python state
# --------------------------------------------------------------------------


class TestR3:
    def test_mutable_default_fires(self):
        fs = lint("""
            def f(x, acc=[]):
                acc.append(x)
                return acc
        """)
        assert rules_of(fs) == ["R3"]

    def test_global_mutation_in_jit_fires(self):
        fs = lint("""
            import jax

            _COUNT = 0

            @jax.jit
            def f(x):
                global _COUNT
                _COUNT += 1
                return x
        """)
        assert "R3" in rules_of(fs)

    def test_none_default_is_clean(self):
        fs = lint("""
            def f(x, acc=None):
                acc = [] if acc is None else acc
                acc.append(x)
                return acc
        """)
        assert fs == []


# --------------------------------------------------------------------------
# R4 — wall clock / legacy numpy RNG (src/repro only)
# --------------------------------------------------------------------------


class TestR4:
    def test_legacy_sampler_fires(self):
        fs = lint("""
            import numpy as np

            def f():
                return np.random.rand(3)
        """)
        assert rules_of(fs) == ["R4"]

    def test_unseeded_default_rng_fires(self):
        fs = lint("""
            import numpy as np

            def f():
                return np.random.default_rng().normal(size=3)
        """)
        assert rules_of(fs) == ["R4"]

    def test_seeded_default_rng_is_clean(self):
        fs = lint("""
            import numpy as np

            def f(seed, step):
                return np.random.default_rng([seed, step]).normal(size=3)
        """)
        assert fs == []

    def test_clock_into_seed_fires(self):
        fs = lint("""
            import time

            import jax

            def f():
                seed = int(time.time())
                return jax.random.key(seed)
        """)
        assert "R4" in rules_of(fs)

    def test_rule_scoped_to_src_repro(self):
        # tests/benchmarks may use ad-hoc numpy RNG freely
        fs = lint("""
            import numpy as np

            def f():
                return np.random.rand(3)
        """, path="tests/helpers.py")
        assert fs == []


# --------------------------------------------------------------------------
# pragmas
# --------------------------------------------------------------------------


class TestPragmas:
    SRC = textwrap.dedent("""
        import numpy as np

        def f():
            return np.random.rand(3)  # repro: allow[R4] fixture noise only
    """)

    def test_same_line_pragma_suppresses(self):
        path = "src/repro/_fixture.py"
        raw = lint_source(path, self.SRC)
        active, suppressed = fmod.filter_suppressed(
            raw, {path: fmod.parse_pragmas(self.SRC)})
        assert active == []
        assert [f.rule for f in suppressed] == ["R4"]

    def test_comment_line_above_covers_next_line(self):
        src = textwrap.dedent("""
            import numpy as np

            def f():
                # repro: allow[R4] exercised below
                return np.random.rand(3)
        """)
        path = "src/repro/_fixture.py"
        active, suppressed = fmod.filter_suppressed(
            lint_source(path, src), {path: fmod.parse_pragmas(src)})
        assert active == [] and len(suppressed) == 1

    def test_pragma_for_other_rule_does_not_suppress(self):
        src = self.SRC.replace("allow[R4]", "allow[R1]")
        path = "src/repro/_fixture.py"
        active, suppressed = fmod.filter_suppressed(
            lint_source(path, src), {path: fmod.parse_pragmas(src)})
        assert [f.rule for f in active] == ["R4"] and suppressed == []

    def test_star_allows_all(self):
        src = self.SRC.replace("allow[R4]", "allow[*]")
        path = "src/repro/_fixture.py"
        active, suppressed = fmod.filter_suppressed(
            lint_source(path, src), {path: fmod.parse_pragmas(src)})
        assert active == [] and len(suppressed) == 1


# --------------------------------------------------------------------------
# A4 — VMEM estimator
# --------------------------------------------------------------------------


class TestVmem:
    def test_spmm_tiled_oracle(self):
        # shipped config: g=64 payload tiles of (1, 128, 128), rhs block
        # (128, 128), out block (128, 128) — all already granule-aligned,
        # so each block is exactly 128*128*4 B = 64 KiB; three blocks.
        est = vmem.KERNEL_SPECS["spmm_tiled"]()
        per_block = 128 * 128 * 4
        assert [b.nbytes() for b in est.blocks] == [per_block] * 3
        assert est.total_bytes == 3 * per_block == 196_608
        assert est.budget_bytes == int(16 * 2**20 * 0.75) == 12_582_912
        assert est.fits

    def test_granule_padding(self):
        # (4, 100) f32 pads to the (8, 128) tiling granule
        b = vmem.BlockUse("x", (4, 100))
        assert b.padded_block() == (8, 128)
        assert b.nbytes() == 8 * 128 * 4

    def test_divisibility_violation_detected(self):
        b = vmem.BlockUse("x", (96, 128), array_shape=(256, 128))
        assert b.divisibility_issues()  # 256 % 96 != 0
        est = vmem.estimate_kernel("bad", [b])
        assert not est.fits

    def test_over_budget_not_fits(self):
        huge = vmem.BlockUse("x", (4096, 4096))  # 64 MiB > 12 MiB budget
        est = vmem.estimate_kernel("huge", [huge])
        assert est.total_bytes > est.budget_bytes and not est.fits

    def test_ata_bytes_match_ops_fallback_threshold(self):
        # the runtime fallback in kernels.ops.spmm_ata prices stripes with
        # this exact function; spot-check the closed form
        assert vmem.ata_resident_bytes(16, 16, 128, 128, 128) == (
            (16 * 128 + 16 * 128) * 128 * 4)

    def test_registry_all_fit(self):
        assert vmem.audit_vmem("tpu") == []

    def test_non_tpu_budget_is_unbounded(self):
        assert vmem.vmem_budget_bytes("cpu") > 2**60


# --------------------------------------------------------------------------
# A1 — RNG-into-gather fusion (the PR 4 regression gate)
# --------------------------------------------------------------------------


def _fixture_bcoo(m: int = 32, n: int = 32, seed: int = 0):
    rng = np.random.default_rng(seed)
    mask = rng.random((m, n)) < 0.2
    mask[0, 0] = True
    dense = np.where(mask, rng.standard_normal((m, n)), 0.0).astype(np.float32)
    return jsparse.BCOO.fromdense(jnp.asarray(dense))


class TestA1:
    def test_pr4_pattern_fires(self):
        # the original bug: a raw gaussian sketch fed straight into the
        # SpMM gather — XLA fuses threefry into the gather loop
        a = _fixture_bcoo()

        def bad(key):
            sketch = jax.random.normal(key, (32, 8))
            return kops.spmm(a, sketch, transpose=True)

        closed = jax.make_jaxpr(bad)(jax.random.key(0))
        fs = audit_rng_gather("fixture_bad", closed)
        assert fs and all(f.rule == "A1" for f in fs)

    def test_orthonormalized_sketch_is_clean(self):
        # the shipped fix: QR materializes the sketch before the product
        a = _fixture_bcoo()

        def good(key):
            sketch = jax.random.normal(key, (32, 8))
            q, _ = jnp.linalg.qr(sketch)
            return kops.spmm(a, q, transpose=True)

        closed = jax.make_jaxpr(good)(jax.random.key(0))
        assert audit_rng_gather("fixture_good", closed) == []

    def test_rng_in_while_body_fires(self):
        def bad(key):
            def cond(c):
                return c[0] < 3

            def body(c):
                i, k, x = c
                k = jax.random.fold_in(k, i)
                return i + 1, k, x + jax.random.normal(k, x.shape)

            return jax.lax.while_loop(
                cond, body, (jnp.int32(0), key, jnp.zeros((4,))))

        closed = jax.make_jaxpr(bad)(jax.random.key(0))
        fs = audit_rng_gather("fixture_while", closed)
        assert fs and all(f.rule == "A1" for f in fs)

    def test_scan_body_counter_keys_are_clean(self):
        # per-step fold_in inside scan is the repo's reproducibility
        # contract and must not be flagged
        def good(key, x):
            def body(carry, i):
                return carry + jax.random.normal(
                    jax.random.fold_in(key, i), x.shape), None

            out, _ = jax.lax.scan(body, x, jnp.arange(3))
            return out

        closed = jax.make_jaxpr(good)(jax.random.key(0), jnp.zeros((4,)))
        assert audit_rng_gather("fixture_scan", closed) == []


# --------------------------------------------------------------------------
# A2 — dtype promotion
# --------------------------------------------------------------------------


def _trace_x64(fn, *args):
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        return jax.make_jaxpr(fn)(*args)
    finally:
        jax.config.update("jax_enable_x64", prev)


class TestA2:
    def test_f64_promotion_fires(self):
        x = jnp.ones((4,), jnp.float32)
        closed = _trace_x64(lambda v: v * np.float64(2.0), x)
        fs = audit_dtypes("fixture_promo", closed)
        assert fs and all(f.rule == "A2" for f in fs)

    def test_explicit_f32_is_clean(self):
        x = jnp.ones((4,), jnp.float32)
        closed = _trace_x64(lambda v: v * jnp.float32(2.0), x)
        assert audit_dtypes("fixture_f32", closed) == []


# --------------------------------------------------------------------------
# A3 — recompile guard
# --------------------------------------------------------------------------


class TestA3:
    def test_detector_catches_per_call_jit(self):
        # a fresh jit wrapper per call can never hit the cache
        def leaky(x):
            return jax.jit(lambda y: y * 2.0)(x)

        counter = {"n": 0}

        def make_args():
            counter["n"] += 1
            return (jnp.full((8,), float(counter["n"])),)

        misses, fs = count_recompiles("fixture_leaky", leaky, make_args)
        assert misses > 0 and [f.rule for f in fs] == ["A3"]

    def test_stable_jit_is_clean(self):
        fn = jax.jit(lambda x: x * 2.0)

        counter = {"n": 0}

        def make_args():
            counter["n"] += 1
            return (jnp.full((8,), float(counter["n"])),)

        misses, fs = count_recompiles("fixture_stable", fn, make_args)
        assert misses == 0 and fs == []

    def test_real_drivers_do_not_recompile(self):
        # the two drivers the issue pins: lamc_cocluster and the
        # streaming serving path assign_rows
        targets = entry_points.recompile_targets()
        assert set(targets) == {"lamc_cocluster", "assign_rows"}
        for name, (fn, make_args) in sorted(targets.items()):
            misses, fs = count_recompiles(name, fn, make_args)
            assert misses == 0, f"{name}: {[f.message for f in fs]}"


# --------------------------------------------------------------------------
# entry-point registry + CLI
# --------------------------------------------------------------------------


class TestEntryPoints:
    def test_registry_covers_required_surfaces(self):
        assert {"lamc_dense", "lamc_sparse", "distributed_step",
                "streaming_chunk", "cosine_assign", "cosine_topk",
                "spmm", "spmm_tiled", "spmm_ata"} <= set(
                    entry_points.ENTRY_POINTS)

    def test_kernel_entries_audit_clean(self):
        # cheap smoke of the registry plumbing; the CI lane audits all
        fs = entry_points.audit_entry_points(
            ["cosine_assign", "cosine_topk", "spmm"], x64=True)
        assert fs == []


class TestCli:
    def _violating_file(self, tmp_path):
        p = tmp_path / "bad.py"
        p.write_text(textwrap.dedent("""
            def f(x, acc=[]):
                acc.append(x)
                return acc
        """))
        return p

    def test_non_strict_reports_but_exits_zero(self, tmp_path, capsys):
        p = self._violating_file(tmp_path)
        assert cli_main([str(p), "--ast-only"]) == 0
        out = capsys.readouterr().out
        assert "[R3]" in out and "1 finding" in out

    def test_strict_exits_nonzero_on_findings(self, tmp_path, capsys):
        p = self._violating_file(tmp_path)
        assert cli_main([str(p), "--ast-only", "--strict"]) == 1

    def test_json_output_parses(self, tmp_path, capsys):
        p = self._violating_file(tmp_path)
        cli_main([str(p), "--ast-only", "--json"])
        doc = json.loads(capsys.readouterr().out)
        assert [f["rule"] for f in doc["findings"]] == ["R3"]
        assert doc["suppressed"] == []
        assert set(doc["rules"]) == set(fmod.RULES)

    def test_clean_file_strict_exits_zero(self, tmp_path, capsys):
        p = tmp_path / "ok.py"
        p.write_text("def f(x):\n    return x + 1\n")
        assert cli_main([str(p), "--ast-only", "--strict"]) == 0
