"""Device-resident conversion parity + pattern-cache semantics (DESIGN.md §9).

Two contracts from the sparse-prep rework:

* **Conversion parity** — the fast host plan/apply path and the jitted
  device path (``block_sparse_pattern_device`` / ``_build_device``,
  exercised off-TPU via ``REPRO_FORCE_INTERPRET``) must reproduce the
  original union1d/lexsort conversion (``bcoo_to_block_sparse_host``,
  kept as the oracle) **bit-exactly**, field for field — including the
  seeded zero payloads for empty tile-rows/-cols that both product
  orientations rely on.

* **Cache semantics** — ``core.opcache.PatternCache`` may only ever
  return (a) the identical cached operator on an identity hit, (b) a
  values-refreshed operator sharing the cached plan arrays on a
  same-pattern/new-data lookup, or (c) a fresh conversion. No reuse
  across pattern, tile-config, or dtype changes; ``REPRO_TILED_CACHE=0``
  degrades every lookup to an uncached conversion.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import sparse as jsparse

from repro.core import opcache
from repro.core import sparse as core_sparse
from repro.data import to_bcoo
from repro.kernels import spmm as kspmm


def _rand_sparse(rng, m, n, density):
    return np.where(rng.random((m, n)) < density,
                    rng.normal(size=(m, n)), 0.0).astype(np.float32)


def _with_empty_bands(rng, m, n, density, bm, bk):
    """Sparse matrix with a forced-empty tile-row and tile-col band."""
    mat = _rand_sparse(rng, m, n, density)
    if m > 2 * bm:
        mat[bm:2 * bm, :] = 0.0
    if n > 2 * bk:
        mat[:, bk:2 * bk] = 0.0
    return mat


def _assert_same_operator(got, want):
    """All four conversion fields bit-exact (values and pattern)."""
    np.testing.assert_array_equal(np.asarray(got.blocks),
                                  np.asarray(want.blocks))
    np.testing.assert_array_equal(np.asarray(got.block_rows),
                                  np.asarray(want.block_rows))
    np.testing.assert_array_equal(np.asarray(got.block_cols),
                                  np.asarray(want.block_cols))
    np.testing.assert_array_equal(np.asarray(got.t_order),
                                  np.asarray(want.t_order))


class TestConversionParity:
    @pytest.mark.parametrize("shape,tile", [((300, 240), 64),
                                            ((256, 192), 128),
                                            ((64, 64), 64)])
    def test_host_fast_path_matches_oracle(self, shape, tile):
        rng = np.random.default_rng(shape[0] + tile)
        mat = _with_empty_bands(rng, *shape, 0.1, tile, tile)
        a = to_bcoo(mat)
        oracle = kspmm.bcoo_to_block_sparse_host(a, bm=tile, bk=tile)
        got = kspmm.bcoo_to_block_sparse(a, bm=tile, bk=tile)
        _assert_same_operator(got, oracle)

    @pytest.mark.parametrize("shape,tile", [((300, 240), 64),
                                            ((256, 192), 128),
                                            ((64, 64), 64)])
    def test_device_path_matches_oracle(self, shape, tile, monkeypatch):
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        rng = np.random.default_rng(shape[0] * 2 + tile)
        mat = _with_empty_bands(rng, *shape, 0.1, tile, tile)
        a = to_bcoo(mat)
        plan = kspmm.block_sparse_plan(a, bm=tile, bk=tile)
        assert plan.on_device
        got = kspmm.block_sparse_apply(plan, a.data)
        _assert_same_operator(got, kspmm.bcoo_to_block_sparse_host(
            a, bm=tile, bk=tile))

    def test_device_and_host_plans_agree(self, monkeypatch):
        """Same pattern fields and scatter semantics from both planners."""
        rng = np.random.default_rng(7)
        a = to_bcoo(_rand_sparse(rng, 200, 136, 0.08))
        host = kspmm.block_sparse_plan(a, bm=64, bk=64)
        assert not host.on_device
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
        dev = kspmm.block_sparse_plan(a, bm=64, bk=64)
        assert dev.on_device
        assert host.g == dev.g
        np.testing.assert_array_equal(np.asarray(host.block_rows),
                                      np.asarray(dev.block_rows))
        np.testing.assert_array_equal(np.asarray(host.block_cols),
                                      np.asarray(dev.block_cols))
        np.testing.assert_array_equal(np.asarray(host.t_order),
                                      np.asarray(dev.t_order))
        np.testing.assert_array_equal(np.asarray(host.flat_idx),
                                      np.asarray(dev.flat_idx))
        _assert_same_operator(kspmm.block_sparse_apply(dev, a.data),
                              kspmm.block_sparse_apply(host, a.data))

    def test_single_nnz_matrix(self):
        """Degenerate pattern: one nonzero, everything else seeded zeros."""
        mat = np.zeros((96, 96), np.float32)
        mat[70, 70] = 3.5
        a = to_bcoo(mat)
        got = kspmm.bcoo_to_block_sparse(a, bm=32, bk=32)
        _assert_same_operator(got, kspmm.bcoo_to_block_sparse_host(
            a, bm=32, bk=32))
        # every tile-row and tile-col is represented despite one nnz
        assert set(np.asarray(got.block_rows)) == {0, 1, 2}
        assert set(np.asarray(got.block_cols)) == {0, 1, 2}

    def test_values_refresh_equals_fresh_conversion(self):
        rng = np.random.default_rng(11)
        mat = _rand_sparse(rng, 150, 150, 0.1)
        a = to_bcoo(mat)
        plan = kspmm.block_sparse_plan(a, bm=64, bk=64)
        b = jsparse.BCOO((a.data * 2.0, a.indices), shape=a.shape)
        _assert_same_operator(kspmm.block_sparse_apply(plan, b.data),
                              kspmm.bcoo_to_block_sparse(b, bm=64, bk=64))


class TestPatternCache:
    def _bcoo(self, seed=0, m=128, n=128, density=0.1):
        rng = np.random.default_rng(seed)
        return to_bcoo(_rand_sparse(rng, m, n, density))

    def test_identity_hit_returns_same_object(self):
        cache = opcache.PatternCache()
        a = self._bcoo()
        t1 = core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        t2 = core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        assert t2 is t1
        assert (cache.hits, cache.misses, cache.refreshes) == (1, 1, 0)

    def test_values_refresh_shares_plan_arrays(self):
        cache = opcache.PatternCache()
        a = self._bcoo(seed=1)
        t1 = core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        b = jsparse.BCOO((a.data * 2.0, a.indices), shape=a.shape)
        t2 = core_sparse.to_tiled(b, bm=64, bk=64, cache=cache)
        assert cache.refreshes == 1
        # pattern arrays are the cached plan's, values are fresh
        assert t2.block_rows is t1.block_rows
        assert t2.t_order is t1.t_order
        np.testing.assert_array_equal(np.asarray(t2.blocks),
                                      2.0 * np.asarray(t1.blocks))
        # refreshed entry now hits on identity
        assert core_sparse.to_tiled(b, bm=64, bk=64, cache=cache) is t2

    def test_concurrent_convert_is_safe(self):
        # two threads hammer one cache with an interleaved mix of hits,
        # refreshes, and capacity-evicting misses. Unsynchronized, the
        # OrderedDict mutates under iteration / loses LRU moves; the
        # locked cache must never raise, never return a wrong operator,
        # and never grow past capacity.
        import threading

        cache = opcache.PatternCache(capacity=4)
        mats = [self._bcoo(seed=s, m=64, n=64) for s in range(8)]
        oracle = [core_sparse.to_tiled(a, bm=32, bk=32) for a in mats]
        errors: list = []

        def hammer(offset: int) -> None:
            try:
                for i in range(200):
                    j = (i + offset) % len(mats)
                    a = mats[j]
                    if i % 3 == 0:  # values refresh on the cached pattern
                        a = jsparse.BCOO((a.data * 2.0, a.indices),
                                         shape=a.shape)
                    got = core_sparse.to_tiled(a, bm=32, bk=32, cache=cache)
                    want = oracle[j]
                    np.testing.assert_array_equal(
                        np.asarray(got.block_rows),
                        np.asarray(want.block_rows))
                    np.testing.assert_allclose(
                        np.abs(np.asarray(got.blocks)),
                        np.abs(np.asarray(want.blocks))
                        * (2.0 if i % 3 == 0 else 1.0), rtol=1e-6)
            except Exception as e:  # noqa: BLE001 — surfaced by the assert
                errors.append(e)

        threads = [threading.Thread(target=hammer, args=(k,))
                   for k in range(2)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=120.0)
        assert not errors, errors[0]
        assert len(cache) <= 4
        assert cache.hits + cache.misses + cache.refreshes == 400

    def test_pattern_change_misses(self):
        cache = opcache.PatternCache()
        core_sparse.to_tiled(self._bcoo(seed=2), bm=64, bk=64, cache=cache)
        core_sparse.to_tiled(self._bcoo(seed=3), bm=64, bk=64, cache=cache)
        assert cache.misses == 2 and cache.hits == 0 and cache.refreshes == 0

    def test_tile_config_change_misses(self):
        cache = opcache.PatternCache()
        a = self._bcoo(seed=4)
        core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        core_sparse.to_tiled(a, bm=128, bk=128, cache=cache)
        assert cache.misses == 2 and len(cache) == 2

    def test_dtype_change_misses(self):
        cache = opcache.PatternCache()
        a = self._bcoo(seed=5)
        core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        b = jsparse.BCOO((a.data.astype(jnp.bfloat16), a.indices),
                         shape=a.shape)
        core_sparse.to_tiled(b, bm=64, bk=64, cache=cache)
        assert cache.misses == 2 and cache.refreshes == 0

    def test_lru_eviction_is_bounded(self):
        cache = opcache.PatternCache(capacity=2)
        for seed in range(4):
            core_sparse.to_tiled(self._bcoo(seed=10 + seed),
                                 bm=64, bk=64, cache=cache)
        assert len(cache) == 2 and cache.misses == 4

    def test_ell_and_tiled_do_not_collide(self):
        a = self._bcoo(seed=6)
        cache = opcache.PatternCache()
        ell = core_sparse.to_ell(a, cache=cache)
        tiled = core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        assert cache.misses == 2 and len(cache) == 2
        assert core_sparse.to_ell(a, cache=cache) is ell
        assert core_sparse.to_tiled(a, bm=64, bk=64, cache=cache) is tiled

    def test_ell_refresh_matches_fresh_conversion(self):
        cache = opcache.PatternCache()
        a = self._bcoo(seed=7)
        core_sparse.to_ell(a, cache=cache)
        b = jsparse.BCOO((a.data * 3.0, a.indices), shape=a.shape)
        got = core_sparse.to_ell(b, cache=cache)
        want = core_sparse.to_ell(b)
        assert cache.refreshes == 1
        np.testing.assert_array_equal(np.asarray(got.row_vals),
                                      np.asarray(want.row_vals))
        np.testing.assert_array_equal(np.asarray(got.col_vals),
                                      np.asarray(want.col_vals))

    def test_env_kill_switch_bypasses_storage(self, monkeypatch):
        monkeypatch.setenv("REPRO_TILED_CACHE", "0")
        cache = opcache.PatternCache()
        a = self._bcoo(seed=8)
        t1 = core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        t2 = core_sparse.to_tiled(a, bm=64, bk=64, cache=cache)
        assert t2 is not t1 and len(cache) == 0
        assert (cache.hits, cache.misses, cache.refreshes) == (0, 0, 0)
        _assert_same_operator(t1, t2)

    def test_prepare_operator_routes_through_default_cache(self):
        a = self._bcoo(seed=9)
        default = opcache.default_cache()
        default.clear()
        t1 = core_sparse.prepare_operator(a, "tiled")
        t2 = core_sparse.prepare_operator(a, "tiled")
        assert t2 is t1 and default.hits >= 1
        default.clear()
