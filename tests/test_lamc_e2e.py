"""End-to-end LAMC pipeline behaviour (replaces the placeholder system test)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LAMCConfig, lamc_cocluster
from repro.core.baselines import nmtf_full, scc_full
from repro.core.metrics import cocluster_scores
from repro.core.partition import PartitionPlan
from repro.data import planted_cocluster_matrix


@pytest.fixture(scope="module")
def planted():
    rng = np.random.default_rng(0)
    return planted_cocluster_matrix(rng, 600, 500, k=5, d=5, signal=4.0, noise=0.6)


class TestLAMCEndToEnd:
    def test_scc_atom_quality_close_to_full(self, planted):
        a = jnp.asarray(planted.matrix)
        cfg = LAMCConfig(n_row_clusters=5, n_col_clusters=5,
                         min_cocluster_rows=120, min_cocluster_cols=100)
        plan = PartitionPlan(600, 500, m=2, n=2, phi=300, psi=250, t_p=3, seed=0)
        out = lamc_cocluster(a, cfg, plan=plan)
        s_lamc = cocluster_scores(np.array(out.row_labels), np.array(out.col_labels),
                                  planted.row_labels, planted.col_labels)
        base = scc_full(jax.random.key(0), a, 5)
        s_full = cocluster_scores(np.array(base.row_labels), np.array(base.col_labels),
                                  planted.row_labels, planted.col_labels)
        # Table III behaviour: partitioned quality within a modest gap of full
        assert s_lamc["nmi"] > s_full["nmi"] - 0.2, (s_lamc, s_full)
        assert s_lamc["nmi"] > 0.5

    def test_nmtf_atom_runs(self, planted):
        a = jnp.asarray(planted.matrix)
        cfg = LAMCConfig(n_row_clusters=5, n_col_clusters=5, atom="nmtf",
                         min_cocluster_rows=120, min_cocluster_cols=100)
        plan = PartitionPlan(600, 500, m=2, n=2, phi=300, psi=250, t_p=2, seed=0)
        out = lamc_cocluster(a, cfg, plan=plan)
        s = cocluster_scores(np.array(out.row_labels), np.array(out.col_labels),
                             planted.row_labels, planted.col_labels)
        assert s["nmi"] > 0.4, s

    def test_auto_plan_respects_threshold(self, planted):
        a = jnp.asarray(planted.matrix)
        cfg = LAMCConfig(n_row_clusters=5, n_col_clusters=5,
                         min_cocluster_rows=120, min_cocluster_cols=100,
                         p_thresh=0.9, workers=4)
        out = lamc_cocluster(a, cfg)
        assert out.plan.detection_p >= 0.9

    def test_deterministic_given_seed(self, planted):
        a = jnp.asarray(planted.matrix)
        cfg = LAMCConfig(n_row_clusters=5, n_col_clusters=5,
                         min_cocluster_rows=120, min_cocluster_cols=100)
        plan = PartitionPlan(600, 500, m=2, n=2, phi=300, psi=250, t_p=2, seed=7)
        out1 = lamc_cocluster(a, cfg, plan=plan)
        out2 = lamc_cocluster(a, cfg, plan=plan)
        np.testing.assert_array_equal(np.array(out1.row_labels), np.array(out2.row_labels))
        np.testing.assert_array_equal(np.array(out1.col_labels), np.array(out2.col_labels))

    def test_fused_pallas_path_matches_jnp(self, planted):
        """assign_impl='pallas' (fused Lloyd kernel) must reproduce the jnp
        path's end-to-end labels — identical up to cluster permutation."""
        from repro.core.metrics import nmi

        a = jnp.asarray(planted.matrix)
        plan = PartitionPlan(600, 500, m=2, n=2, phi=300, psi=250, t_p=2, seed=0)
        base = dict(n_row_clusters=5, n_col_clusters=5,
                    min_cocluster_rows=120, min_cocluster_cols=100)
        out_j = lamc_cocluster(a, LAMCConfig(**base, assign_impl="jnp"), plan=plan)
        out_p = lamc_cocluster(a, LAMCConfig(**base, assign_impl="pallas"), plan=plan)
        assert nmi(np.array(out_j.row_labels), np.array(out_p.row_labels)) > 0.999
        assert nmi(np.array(out_j.col_labels), np.array(out_p.col_labels)) > 0.999

    def test_cholesky_qr_path_quality(self, planted):
        """qr_method='cholesky' (Gram-based batched subspace iteration) must
        keep consensus quality on par with the LAPACK-QR path."""
        a = jnp.asarray(planted.matrix)
        plan = PartitionPlan(600, 500, m=2, n=2, phi=300, psi=250, t_p=3, seed=0)
        base = dict(n_row_clusters=5, n_col_clusters=5,
                    min_cocluster_rows=120, min_cocluster_cols=100)
        out_q = lamc_cocluster(a, LAMCConfig(**base, qr_method="qr"), plan=plan)
        out_c = lamc_cocluster(a, LAMCConfig(**base, qr_method="cholesky"), plan=plan)
        s_q = cocluster_scores(np.array(out_q.row_labels), np.array(out_q.col_labels),
                               planted.row_labels, planted.col_labels)
        s_c = cocluster_scores(np.array(out_c.row_labels), np.array(out_c.col_labels),
                               planted.row_labels, planted.col_labels)
        assert s_c["nmi"] > s_q["nmi"] - 0.1, (s_c, s_q)

    def test_labels_in_range_no_nans(self, planted):
        a = jnp.asarray(planted.matrix)
        cfg = LAMCConfig(n_row_clusters=5, n_col_clusters=5,
                         min_cocluster_rows=120, min_cocluster_cols=100)
        plan = PartitionPlan(600, 500, m=2, n=2, phi=300, psi=250, t_p=2, seed=0)
        out = lamc_cocluster(a, cfg, plan=plan)
        rl = np.array(out.row_labels)
        cl = np.array(out.col_labels)
        assert rl.min() >= 0 and rl.max() < 5
        assert cl.min() >= 0 and cl.max() < 5
        assert np.all(np.isfinite(np.array(out.row_votes)))


class TestBaselines:
    def test_nmtf_full_quality(self, planted):
        a = jnp.asarray(planted.matrix)
        res = nmtf_full(jax.random.key(0), a, 5, n_iter=64)
        s = cocluster_scores(np.array(res.row_labels), np.array(res.col_labels),
                             planted.row_labels, planted.col_labels)
        assert s["nmi"] > 0.5, s

    def test_scc_full_quality(self, planted):
        a = jnp.asarray(planted.matrix)
        res = scc_full(jax.random.key(0), a, 5)
        s = cocluster_scores(np.array(res.row_labels), np.array(res.col_labels),
                             planted.row_labels, planted.col_labels)
        assert s["nmi"] > 0.6, s
