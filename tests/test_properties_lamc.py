"""Property-based invariants of the partition plan and the plan cost model.

Runs through ``hypothesis_compat``: with hypothesis installed the ``@given``
tests sweep randomized cases; without it they skip individually while the
seeded parametrized twins below keep every invariant exercised (the shim
contract — the suite always collects).

Invariants locked down:
  * resample permutations are bijections (each axis index appears at most
    once, all within range) and blocks tile the used submatrix exactly once;
  * ``coverage_probability`` is monotone in ``t_p``, bounded in [0, 1], and
    the axis-free form is the min of the per-axis forms;
  * ``probability._atom_cost`` is monotone in density on the gather
    (dual-ELL) route — more nonzeros, more gathered work;
  * ``probability.spmm_route`` returns the argmin of ``spmm_costs``
    whenever the sparse formats are admissible at all.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import partition, probability
from repro.core.partition import PartitionPlan

plan_dims = st.integers(2, 4)
axis_sizes = st.integers(32, 200)
densities = st.floats(1e-4, 1.0, allow_nan=False)


def _check_bijection(plan, resample):
    row_idx, col_idx = partition.resample_indices(plan, resample)
    rows = np.asarray(row_idx).reshape(-1)
    cols = np.asarray(col_idx).reshape(-1)
    assert row_idx.shape == (plan.m, plan.phi)
    assert col_idx.shape == (plan.n, plan.psi)
    assert len(set(rows.tolist())) == rows.size          # no duplicates
    assert rows.min() >= 0 and rows.max() < plan.n_rows  # in range
    assert len(set(cols.tolist())) == cols.size
    assert cols.min() >= 0 and cols.max() < plan.n_cols


def _check_tiles_once(plan, resample):
    """Every used (row, col) cell lands in exactly one block, with its
    original value."""
    a = np.arange(plan.n_rows * plan.n_cols, dtype=np.float32).reshape(
        plan.n_rows, plan.n_cols)
    blocks, row_idx, col_idx = partition.extract_blocks(
        jnp.asarray(a), plan, resample)
    blocks = np.asarray(blocks)
    row_idx, col_idx = np.asarray(row_idx), np.asarray(col_idx)
    seen = np.zeros_like(a, dtype=np.int32)
    for i in range(plan.m):
        for j in range(plan.n):
            blk = blocks[i * plan.n + j]
            expect = a[row_idx[i]][:, col_idx[j]]
            assert np.array_equal(blk, expect)
            np.add.at(seen, (row_idx[i][:, None], col_idx[j][None, :]), 1)
    used = seen.sum()
    assert used == plan.m * plan.phi * plan.n * plan.psi
    assert seen.max() <= 1                               # never twice


CASES = [
    PartitionPlan(64, 48, m=2, n=2, phi=30, psi=20, t_p=3, seed=0),
    PartitionPlan(97, 53, m=3, n=2, phi=32, psi=26, t_p=2, seed=5),
    PartitionPlan(40, 120, m=2, n=4, phi=20, psi=30, t_p=1, seed=11),
]


class TestPartitionInvariants:
    @pytest.mark.parametrize("plan", CASES)
    @pytest.mark.parametrize("resample", [0, 1])
    def test_permutations_are_bijections(self, plan, resample):
        _check_bijection(plan, resample)

    @pytest.mark.parametrize("plan", CASES)
    def test_blocks_tile_exactly_once(self, plan):
        _check_tiles_once(plan, 0)

    @given(m=plan_dims, n=plan_dims, rows=axis_sizes, cols=axis_sizes,
           seed=st.integers(0, 2**16), resample=st.integers(0, 4))
    @settings(max_examples=20, deadline=None)
    def test_bijection_property(self, m, n, rows, cols, seed, resample):
        plan = PartitionPlan(rows, cols, m=m, n=n, phi=max(1, rows // m),
                             psi=max(1, cols // n), t_p=2, seed=seed)
        _check_bijection(plan, resample)

    @given(m=plan_dims, n=plan_dims, rows=st.integers(16, 64),
           cols=st.integers(16, 64), seed=st.integers(0, 2**16))
    @settings(max_examples=10, deadline=None)
    def test_tiling_property(self, m, n, rows, cols, seed):
        plan = PartitionPlan(rows, cols, m=m, n=n, phi=max(1, rows // m),
                             psi=max(1, cols // n), t_p=1, seed=seed)
        _check_tiles_once(plan, 0)


class TestCoverageMonotonicity:
    def test_monotone_in_t_p(self):
        covs = [
            partition.coverage_probability(
                PartitionPlan(100, 80, m=3, n=3, phi=33, psi=26, t_p=t))
            for t in range(1, 12)
        ]
        assert all(0.0 <= c <= 1.0 for c in covs)
        assert all(b >= a - 1e-12 for a, b in zip(covs, covs[1:]))

    def test_axis_min_law(self):
        plan = PartitionPlan(100, 80, m=3, n=3, phi=33, psi=26, t_p=4)
        assert partition.coverage_probability(plan) == min(
            partition.coverage_probability(plan, "row"),
            partition.coverage_probability(plan, "col"))

    def test_full_grid_covers(self):
        plan = PartitionPlan(96, 64, m=2, n=2, phi=48, psi=32, t_p=1)
        assert partition.coverage_probability(plan) == 1.0

    @given(t1=st.integers(1, 50), dt=st.integers(1, 50),
           m=plan_dims, rows=axis_sizes, cols=axis_sizes)
    @settings(max_examples=25, deadline=None)
    def test_monotone_property(self, t1, dt, m, rows, cols):
        mk = lambda t: partition.coverage_probability(
            PartitionPlan(rows, cols, m=m, n=m, phi=rows // (m + 1),
                          psi=cols // (m + 1), t_p=t))
        assert mk(t1 + dt) >= mk(t1) - 1e-12


class TestCostModelInvariants:
    def test_atom_cost_monotone_in_density_gather_route(self):
        ds = np.linspace(0.01, 1.0, 25)
        costs = [probability._atom_cost(512, 256, 8, 4, 16, 8,
                                        density=d, spmm_impl="dual_ell")
                 for d in ds]
        assert all(b >= a - 1e-9 for a, b in zip(costs, costs[1:]))
        # and strictly grows somewhere: density is actually priced
        assert costs[-1] > costs[0]

    def test_atom_cost_auto_never_exceeds_pinned(self):
        for d in (0.01, 0.05, 0.2, 0.5):
            auto = probability._atom_cost(512, 256, 8, 4, 16, 8,
                                          density=d, spmm_impl="auto")
            for impl in ("dual_ell", "tiled", "dense"):
                pinned = probability._atom_cost(512, 256, 8, 4, 16, 8,
                                                density=d, spmm_impl=impl)
                assert auto <= pinned + 1e-9, (d, impl)

    def test_spmm_route_is_cost_argmin(self):
        for d in (0.001, 0.01, 0.05, 0.072, 0.1, 0.3, 0.8):
            cells = 4096.0 * 2048.0
            route = probability.spmm_route(d, cells)
            costs = probability.spmm_costs(d, cells)
            assert route == min(costs, key=costs.get), (d, route, costs)

    def test_spmm_route_guards(self):
        # sub-64x64 blocks and near-dense inputs route dense outright
        assert probability.spmm_route(0.01, 32.0 * 32.0) == "dense"
        assert probability.spmm_route(0.95, 4096.0 * 2048.0) == "dense"

    def test_crossover_inside_measured_bracket(self):
        assert 0.05 < probability.SPMM_ELL_CROSSOVER < 0.2

    @given(d=densities, logc=st.floats(12.5, 24.0))
    @settings(max_examples=50, deadline=None)
    def test_route_argmin_property(self, d, logc):
        cells = float(2.0 ** logc)
        route = probability.spmm_route(d, cells)
        if cells < probability._SPMM_MIN_SPARSE_CELLS or d >= 0.9:
            assert route == "dense"
        else:
            costs = probability.spmm_costs(d, cells)
            assert route == min(costs, key=costs.get)

    @given(d1=densities, d2=densities)
    @settings(max_examples=40, deadline=None)
    def test_gather_cost_monotone_property(self, d1, d2):
        lo, hi = sorted((d1, d2))
        cost = lambda d: probability._atom_cost(
            256, 256, 8, 4, 16, 8, density=d, spmm_impl="dual_ell")
        assert cost(hi) >= cost(lo) - 1e-9
