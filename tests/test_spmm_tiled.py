"""Tiled block-sparse SpMM kernel suite (DESIGN.md §9).

Parity of ``ops.spmm_tiled`` (forward, transposed, multi-RHS) and the
fused normal-equations ``ops.spmm_ata`` against the element-level
``ref.spmm_ref`` oracle, across densities, tile sizes and ragged edges —
on both the batched-einsum jnp tier (default off-TPU) and the Pallas
kernels in interpret mode (``REPRO_FORCE_INTERPRET``).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import to_bcoo
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _rand_sparse(rng, m, n, density):
    return np.where(rng.random((m, n)) < density,
                    rng.normal(size=(m, n)), 0.0).astype(np.float32)


@pytest.fixture(params=["jnp", "interpret"])
def tier(request, monkeypatch):
    """Run each test on the fast jnp tier and the Pallas interpret tier."""
    if request.param == "interpret":
        monkeypatch.setenv("REPRO_FORCE_INTERPRET", "1")
    else:
        monkeypatch.delenv("REPRO_FORCE_INTERPRET", raising=False)
    return request.param


class TestSpmmTiled:
    @pytest.mark.parametrize("density", [0.01, 0.05, 0.2])
    @pytest.mark.parametrize("tile", [128, 256, 512])
    def test_forward_and_transpose_match_ref(self, tier, density, tile):
        """Ragged edges: M, K deliberately not tile multiples."""
        rng = np.random.default_rng(int(density * 100) + tile)
        m, k = 300, 389
        mat = _rand_sparse(rng, m, k, density)
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=tile, bk=tile)
        b = jnp.asarray(rng.normal(size=(k, 33)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(m, 17)).astype(np.float32))
        got = np.asarray(kops.spmm_tiled(a, b))
        np.testing.assert_allclose(got, mat @ np.asarray(b), atol=2e-3)
        got_t = np.asarray(kops.spmm_tiled(a, c, transpose=True))
        np.testing.assert_allclose(got_t, mat.T @ np.asarray(c), atol=2e-3)

    @pytest.mark.parametrize("n_rhs", [1, 9, 200])
    def test_multi_rhs_widths(self, tier, n_rhs):
        """RHS narrower / wider than one column stripe."""
        rng = np.random.default_rng(n_rhs)
        mat = _rand_sparse(rng, 150, 260, 0.1)
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=128, bk=128)
        b = jnp.asarray(rng.normal(size=(260, n_rhs)).astype(np.float32))
        got = np.asarray(kops.spmm_tiled(a, b))
        assert got.shape == (150, n_rhs)
        np.testing.assert_allclose(got, mat @ np.asarray(b), atol=2e-3)

    def test_all_zero_tile_row_and_col(self, tier):
        """Empty tile-rows/-cols must yield exact zeros in either product."""
        mat = np.zeros((256, 192), np.float32)
        mat[5, 3] = 2.0    # only tile (0, 0) occupied
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64)
        b = np.ones((192, 32), np.float32)
        c = np.ones((256, 8), np.float32)
        out = np.asarray(kops.spmm_tiled(a, jnp.asarray(b)))
        np.testing.assert_array_equal(out, mat @ b)
        out_t = np.asarray(kops.spmm_tiled(a, jnp.asarray(c), transpose=True))
        np.testing.assert_array_equal(out_t, mat.T @ c)

    def test_matches_element_level_oracle(self, tier):
        """Same answer as ref.spmm_ref on the raw COO triplets."""
        rng = np.random.default_rng(7)
        mat = _rand_sparse(rng, 200, 130, 0.07)
        sp = to_bcoo(mat)
        a = kops.bcoo_to_block_sparse(sp, bm=64, bk=64)
        b = jnp.asarray(rng.normal(size=(130, 12)).astype(np.float32))
        want = np.asarray(kref.spmm_ref(sp.data, sp.indices[:, 0],
                                        sp.indices[:, 1], 200, b))
        np.testing.assert_allclose(np.asarray(kops.spmm_tiled(a, b)), want,
                                   atol=2e-3)


class TestSpmmAtaFused:
    @pytest.mark.parametrize("density", [0.01, 0.2])
    def test_fused_matches_two_product_oracle(self, tier, density):
        """One-sweep Aᵀ(A·X) == spmm_ref applied twice."""
        rng = np.random.default_rng(int(density * 1000))
        mat = _rand_sparse(rng, 300, 200, density)
        sp = to_bcoo(mat)
        a = kops.bcoo_to_block_sparse(sp, bm=128, bk=128)
        x = jnp.asarray(rng.normal(size=(200, 9)).astype(np.float32))
        y = kref.spmm_ref(sp.data, sp.indices[:, 0], sp.indices[:, 1], 300, x)
        want = np.asarray(kref.spmm_ref(sp.data, sp.indices[:, 1],
                                        sp.indices[:, 0], 200, y))
        got = np.asarray(kops.spmm_ata(a, x))
        np.testing.assert_allclose(got, want, atol=5e-3)

    def test_fused_vmem_fallback(self, tier, monkeypatch):
        """Operands past the VMEM budget decompose into two products."""
        monkeypatch.setattr(kops.vmem, "vmem_budget_bytes", lambda p="tpu": 1)
        rng = np.random.default_rng(3)
        mat = _rand_sparse(rng, 128, 128, 0.1)
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64)
        x = jnp.asarray(rng.normal(size=(128, 5)).astype(np.float32))
        got = np.asarray(kops.spmm_ata(a, x))
        np.testing.assert_allclose(got, mat.T @ (mat @ np.asarray(x)),
                                   atol=2e-3)


class TestBlockSparseFormat:
    def test_converter_seeds_both_orientations(self):
        """Every tile-row AND tile-col owns >= 1 payload (init guarantee)."""
        mat = np.zeros((256, 256), np.float32)
        mat[130, 200] = 1.0   # single nonzero in tile (2, 3) at bm=bk=64
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64)
        assert set(np.asarray(a.block_rows)) == set(range(4))
        assert set(np.asarray(a.block_cols)) == set(range(4))
        order = np.asarray(a.t_order)
        cols_sorted = np.asarray(a.block_cols)[order]
        assert (np.diff(cols_sorted) >= 0).all()

    def test_pytree_shape_is_static(self):
        """shape must survive jit as a static attribute (aux data)."""
        import jax

        rng = np.random.default_rng(0)
        mat = _rand_sparse(rng, 100, 80, 0.1)
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64)

        @jax.jit
        def shape_of(op):
            assert op.shape == (100, 80)      # python ints inside trace
            return kops.spmm_tiled(op, jnp.ones((80, 3), jnp.float32))

        assert shape_of(a).shape == (100, 3)


def _scaled(a, seed=0):
    """Attach positive row/col scale grids to a tiled operand."""
    import repro.kernels.spmm as kspmm

    rng = np.random.default_rng(seed)
    n_tr, n_tc = a.n_tiles
    bm, bk = a.tile_shape
    rs = jnp.asarray(rng.uniform(0.5, 2.0, (n_tr, bm)).astype(np.float32))
    cs = jnp.asarray(rng.uniform(0.5, 2.0, (n_tc, bk)).astype(np.float32))
    return kspmm.BlockSparseMatrix(
        blocks=a.blocks, block_rows=a.block_rows, block_cols=a.block_cols,
        t_order=a.t_order, shape=a.shape, row_scale=rs, col_scale=cs)


class TestScaleFusion:
    """Lazy diagonal scaling (DESIGN.md §9): the scaled operator must be
    bit-identical to eagerly materializing D_r^{1/2}-style scales into the
    payloads — the in-VMEM multiply order is pinned to the materialized
    order, so fusion can never move a label."""

    @pytest.mark.parametrize("tile", [64, 128])
    def test_forward_lazy_equals_materialized(self, tier, tile):
        rng = np.random.default_rng(tile)
        mat = _rand_sparse(rng, 300, 240, 0.1)
        a = _scaled(kops.bcoo_to_block_sparse(to_bcoo(mat), bm=tile, bk=tile))
        b = jnp.asarray(rng.normal(size=(240, 17)).astype(np.float32))
        got = np.asarray(kops.spmm_tiled(a, b))
        want = np.asarray(kops.spmm_tiled(a.materialize_scales(), b))
        np.testing.assert_array_equal(got, want)

    def test_transpose_lazy_equals_materialized(self, tier):
        rng = np.random.default_rng(1)
        mat = _rand_sparse(rng, 200, 260, 0.08)
        a = _scaled(kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64))
        c = jnp.asarray(rng.normal(size=(200, 9)).astype(np.float32))
        got = np.asarray(kops.spmm_tiled(a, c, transpose=True))
        want = np.asarray(kops.spmm_tiled(a.materialize_scales(), c,
                                          transpose=True))
        np.testing.assert_array_equal(got, want)

    def test_ata_lazy_equals_materialized(self, tier):
        rng = np.random.default_rng(2)
        mat = _rand_sparse(rng, 256, 192, 0.1)
        a = _scaled(kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64))
        x = jnp.asarray(rng.normal(size=(192, 7)).astype(np.float32))
        got = np.asarray(kops.spmm_ata(a, x))
        want = np.asarray(kops.spmm_ata(a.materialize_scales(), x))
        np.testing.assert_array_equal(got, want)

    def test_scaled_matches_dense_reference(self, tier):
        """Against the dense scaled product, not just self-consistency."""
        rng = np.random.default_rng(3)
        mat = _rand_sparse(rng, 150, 140, 0.1)
        a = _scaled(kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64))
        rs = np.asarray(a.row_scale).reshape(-1)[:150]
        cs = np.asarray(a.col_scale).reshape(-1)[:140]
        b = rng.normal(size=(140, 11)).astype(np.float32)
        want = (rs[:, None] * mat * cs[None, :]) @ b
        got = np.asarray(kops.spmm_tiled(a, jnp.asarray(b)))
        np.testing.assert_allclose(got, want, atol=2e-3)

    def test_materialize_is_idempotent_and_drops_scales(self, tier):
        rng = np.random.default_rng(4)
        a = _scaled(kops.bcoo_to_block_sparse(
            to_bcoo(_rand_sparse(rng, 128, 128, 0.1)), bm=64, bk=64))
        assert a.has_scales
        m1 = a.materialize_scales()
        assert not m1.has_scales
        np.testing.assert_array_equal(np.asarray(m1.materialize_scales().blocks),
                                      np.asarray(m1.blocks))


class TestFusedGram:
    def test_gram_matches_outer_product(self, tier):
        """with_gram=True returns (AᵀAX, (AᵀAX)ᵀ(AᵀAX)) for narrow X."""
        rng = np.random.default_rng(5)
        mat = _rand_sparse(rng, 256, 192, 0.1)
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64)
        x = jnp.asarray(rng.normal(size=(192, 8)).astype(np.float32))
        z, gram = kops.spmm_ata(a, x, with_gram=True)
        np.testing.assert_allclose(np.asarray(gram),
                                   np.asarray(z).T @ np.asarray(z),
                                   atol=5e-4, rtol=1e-5)

    def test_gram_scaled_operand(self, tier):
        rng = np.random.default_rng(6)
        mat = _rand_sparse(rng, 200, 150, 0.1)
        a = _scaled(kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64))
        x = jnp.asarray(rng.normal(size=(150, 6)).astype(np.float32))
        z, gram = kops.spmm_ata(a, x, with_gram=True)
        zm, gram_m = kops.spmm_ata(a.materialize_scales(), x, with_gram=True)
        np.testing.assert_array_equal(np.asarray(z), np.asarray(zm))
        np.testing.assert_array_equal(np.asarray(gram), np.asarray(gram_m))

    def test_gram_vmem_fallback(self, tier, monkeypatch):
        monkeypatch.setattr(kops.vmem, "vmem_budget_bytes", lambda p="tpu": 1)
        rng = np.random.default_rng(7)
        mat = _rand_sparse(rng, 128, 128, 0.1)
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64)
        x = jnp.asarray(rng.normal(size=(128, 5)).astype(np.float32))
        z, gram = kops.spmm_ata(a, x, with_gram=True)
        np.testing.assert_allclose(np.asarray(gram),
                                   np.asarray(z).T @ np.asarray(z),
                                   atol=5e-4, rtol=1e-5)

    def test_fused_cholesky_step_matches_manual(self, tier):
        """One fused subspace-iteration step == orth(AᵀAX) done by hand."""
        from repro.core import spectral

        rng = np.random.default_rng(8)
        mat = _rand_sparse(rng, 256, 192, 0.1)
        a = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=64, bk=64)
        x = jnp.asarray(rng.normal(size=(192, 8)).astype(np.float32))
        z, gram = kops.spmm_ata(a, x, with_gram=True)
        got = np.asarray(spectral._orth_from_gram(z, gram))
        want = np.asarray(spectral._cholesky_orth(z))
        np.testing.assert_allclose(got, want, atol=1e-5)
        # orthonormal columns
        np.testing.assert_allclose(got.T @ got, np.eye(8), atol=1e-4)
