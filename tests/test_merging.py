"""Hierarchical merging: signature separation, consensus quality, host merge."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import merging
from repro.core.metrics import nmi
from repro.core.partition import PartitionPlan, extract_blocks
from repro.data import planted_cocluster_matrix


class TestSignatures:
    def test_anchor_signatures_separate_clusters(self):
        """Same-cluster signatures across ALL block pairs must be closer
        than different-cluster signatures — including blocks with disjoint
        column sets, which is exactly what shared anchors buy (per-block
        random projections fail this: disjoint supports -> zero expected
        cosine; see merging module docstring)."""
        rng = np.random.default_rng(0)
        data = planted_cocluster_matrix(rng, 400, 400, k=4, d=4, signal=4.0, noise=0.5)
        a = jnp.asarray(data.matrix)
        plan = PartitionPlan(400, 400, m=2, n=2, phi=200, psi=200, t_p=1, seed=0)
        blocks, row_idx, col_idx = extract_blocks(a, plan, 0)
        q = 64
        anchor_cols = merging.anchor_indices(jax.random.key(7), 400, q)
        # use ground-truth labels per block: isolates signature quality
        sigs = []
        for b in range(4):
            i, j = b // 2, b % 2
            rt = jnp.asarray(data.row_labels[np.array(row_idx[i])])
            feats = a[row_idx[i]][:, anchor_cols]      # (phi, q)
            s, _ = merging.atom_signatures(feats[None], rt[None], 4)
            sigs.append(np.array(s[0]))  # (4, q)
        sigs = np.stack(sigs)  # (blocks, 4, q)
        same, diff = [], []
        for b1 in range(4):
            for b2 in range(b1 + 1, 4):
                cos = sigs[b1] @ sigs[b2].T
                same.extend(np.diag(cos))
                diff.extend(cos[~np.eye(4, dtype=bool)])
        assert np.mean(same) > 0.6, f"same-cluster cos too low: {np.mean(same)}"
        assert np.mean(same) > np.mean(diff) + 0.4

    def test_empty_cluster_zero_count(self):
        feats = jnp.ones((1, 10, 4))
        labels = jnp.zeros((1, 10), jnp.int32)  # everything in cluster 0
        sigs, counts = merging.atom_signatures(feats, labels, 3)
        assert float(counts[0, 0]) == 10.0
        assert float(counts[0, 1]) == 0.0
        assert sigs.shape == (1, 3, 4)


class TestSignatureMerge:
    def _run(self, t_p, m, n, M=360, N=300, k=4, noise=0.5, seed=0):
        from repro.core import LAMCConfig, lamc_cocluster

        rng = np.random.default_rng(seed)
        data = planted_cocluster_matrix(rng, M, N, k=k, d=k, signal=4.0, noise=noise)
        plan = PartitionPlan(M, N, m=m, n=n, phi=M // m, psi=N // n, t_p=t_p, seed=seed)
        cfg = LAMCConfig(n_row_clusters=k, n_col_clusters=k)
        out = lamc_cocluster(jnp.asarray(data.matrix), cfg, plan=plan)
        return (
            nmi(np.array(out.row_labels), data.row_labels),
            nmi(np.array(out.col_labels), data.col_labels),
            out,
        )

    def test_consensus_quality(self):
        r, c, _ = self._run(t_p=3, m=2, n=2)
        # small-matrix seed variance: gate on the mean, floor on each side
        assert (r + c) / 2 > 0.6 and min(r, c) > 0.5, (r, c)

    def test_votes_shapes_and_support(self):
        _, _, out = self._run(t_p=3, m=2, n=2)
        assert out.row_votes.shape == (360, 4)
        # every row voted on: t_p resamples x n col-blocks votes each
        votes_per_row = np.array(out.row_votes).sum(axis=1)
        assert votes_per_row.min() >= 1

    def test_more_resamples_not_worse(self):
        r1, c1, _ = self._run(t_p=1, m=2, n=2, noise=0.8, seed=3)
        r3, c3, _ = self._run(t_p=4, m=2, n=2, noise=0.8, seed=3)
        assert r3 + c3 >= r1 + c1 - 0.15  # consensus should help or hold


class TestJaccardMergeHost:
    def test_merges_split_cocluster(self):
        # one true co-cluster split across two column blocks
        atoms = [
            {"rows": set(range(0, 10)), "cols": set(range(0, 5)),
             "resample": 0, "block": (0, 0)},
            {"rows": set(range(0, 10)), "cols": set(range(5, 10)),
             "resample": 0, "block": (0, 1)},
            {"rows": set(range(20, 30)), "cols": set(range(20, 25)),
             "resample": 0, "block": (1, 0)},
        ]
        rl, cl = merging.jaccard_merge_host(atoms, 40, 30, tau=0.5)
        # first two atoms merged -> same label for their rows
        assert rl[0] == rl[5]
        assert cl[0] == cl[7]
        # third atom distinct
        assert rl[25] != rl[0]
        # untouched indices unassigned
        assert rl[35] == -1

    def test_cross_resample_consensus(self):
        atoms = [
            {"rows": set(range(0, 10)), "cols": set(range(0, 10)),
             "resample": 0, "block": (0, 0)},
            {"rows": set(range(0, 10)), "cols": set(range(0, 10)),
             "resample": 1, "block": (0, 0)},
        ]
        rl, cl = merging.jaccard_merge_host(atoms, 20, 20, tau=0.5)
        assert len({rl[i] for i in range(10)}) == 1
        assert rl[0] >= 0
