"""Overlapping & non-exhaustive assignment mode, end to end (DESIGN.md §11).

Locks down the three contracts of the overlap tentpole:

  1. Hard mode is bit-identical to the pre-overlap pipeline (golden
     label hashes captured before the mode existed, dense and BCOO).
  2. Overlap with a forcing threshold (``overlap_threshold > 0.5``,
     ``min_membership=1``) reduces *exactly* to hard mode — labels and
     memberships — on the dense, BCOO, and distributed paths.
  3. At default knobs, overlap mode recovers planted overlapping
     ground truth: omega index >= 0.8 on the planted generator.

Plus the serving side: top-k scoring kernel vs its oracle, streaming
``assign_*_topk`` consistency with the k=1 path, and membership views of
a fitted model.
"""

import dataclasses
import hashlib
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LAMCConfig, lamc_cocluster, memberships_from_votes, omega_index, overlap_f1
from repro.core.merging import finalize_assignment
from repro.core.partition import PartitionPlan
from repro.data import planted_cocluster_matrix, to_bcoo
from repro.data.synthetic import planted_overlapping_cocluster_matrix
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def _sha(x) -> str:
    return hashlib.sha256(np.asarray(x).tobytes()).hexdigest()


@pytest.fixture(scope="module")
def dense_case():
    rng = np.random.default_rng(0)
    data = planted_cocluster_matrix(rng, 300, 240, k=4, d=4, signal=4.0,
                                    noise=0.6)
    plan = PartitionPlan(300, 240, m=2, n=2, phi=150, psi=120, t_p=3, seed=0)
    cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4)
    return data, plan, cfg


class TestHardModeGolden:
    """Hard-mode labels must stay bit-identical to the pre-overlap PR."""

    def test_dense_golden_hashes(self, dense_case):
        data, plan, cfg = dense_case
        out = lamc_cocluster(jnp.asarray(data.matrix), cfg, plan=plan)
        assert _sha(out.row_labels) == (
            "140bfb2d037ae0be9e3137976f6c18a8445089a3d7121097cbc5a49cee478256")
        assert _sha(out.col_labels) == (
            "21b85dc1a680597a1e668bc6f6a6d002135644a8614c2feac9c6fb9305a8b6fb")

    def test_bcoo_golden_hashes(self):
        rng = np.random.default_rng(0)
        # same generator sequence as the capture script: a dense draw first
        planted_cocluster_matrix(rng, 300, 240, k=4, d=4, signal=4.0, noise=0.6)
        data = planted_cocluster_matrix(rng, 256, 192, k=3, d=3, signal=5.0,
                                        noise=0.4, density=0.3)
        cfg = LAMCConfig(n_row_clusters=3, n_col_clusters=3,
                         input_format="bcoo")
        plan = PartitionPlan(256, 192, m=2, n=2, phi=128, psi=96, t_p=2, seed=1)
        out = lamc_cocluster(to_bcoo(data.matrix), cfg, plan=plan)
        assert _sha(out.row_labels) == (
            "6e64ddbf87f6b0ca148dfb9936d042f45417e8816697ff97f3340a2b8f1feda0")
        assert _sha(out.col_labels) == (
            "aad1a94f634ce317fa9e428db7a32da3bcdafd94501a1793e8734b7033797e3a")

    def test_hard_membership_is_one_hot(self, dense_case):
        data, plan, cfg = dense_case
        out = lamc_cocluster(jnp.asarray(data.matrix), cfg, plan=plan)
        mem = np.asarray(out.row_membership)
        labels = np.asarray(out.row_labels)
        assert mem.dtype == bool and mem.shape == (300, 4)
        assert (mem.sum(1) == 1).all()
        assert (mem.argmax(1) == labels).all()


class TestForcingReduction:
    """overlap_threshold > 0.5 with min_membership=1 == hard, exactly."""

    def test_dense_reduction(self, dense_case):
        data, plan, cfg = dense_case
        a = jnp.asarray(data.matrix)
        hard = lamc_cocluster(a, cfg, plan=plan)
        forced = lamc_cocluster(
            a, dataclasses.replace(cfg, assignment="overlap",
                                   overlap_threshold=1.0, min_membership=1),
            plan=plan)
        assert np.array_equal(np.asarray(hard.row_labels),
                              np.asarray(forced.row_labels))
        assert np.array_equal(np.asarray(hard.col_labels),
                              np.asarray(forced.col_labels))
        assert np.array_equal(np.asarray(hard.row_membership),
                              np.asarray(forced.row_membership))
        assert np.array_equal(np.asarray(hard.col_membership),
                              np.asarray(forced.col_membership))

    def test_bcoo_reduction(self):
        rng = np.random.default_rng(3)
        data = planted_cocluster_matrix(rng, 200, 160, k=3, d=3, signal=5.0,
                                        noise=0.4, density=0.25)
        plan = PartitionPlan(200, 160, m=2, n=2, phi=100, psi=80, t_p=2, seed=2)
        cfg = LAMCConfig(n_row_clusters=3, n_col_clusters=3,
                         input_format="bcoo")
        b = to_bcoo(data.matrix)
        hard = lamc_cocluster(b, cfg, plan=plan)
        forced = lamc_cocluster(
            b, dataclasses.replace(cfg, assignment="overlap",
                                   overlap_threshold=0.51, min_membership=1),
            plan=plan)
        assert np.array_equal(np.asarray(hard.row_labels),
                              np.asarray(forced.row_labels))
        assert np.array_equal(np.asarray(hard.row_membership),
                              np.asarray(forced.row_membership))
        assert np.array_equal(np.asarray(hard.col_membership),
                              np.asarray(forced.col_membership))


class TestVoteMembership:
    """Unit semantics of the vote-share membership rule."""

    def test_threshold_and_outlier(self):
        votes = jnp.asarray([[8.0, 0.0, 0.0],    # pure: one membership
                             [4.0, 4.0, 0.0],    # split: two memberships
                             [3.0, 3.0, 2.0],    # scattered, thr catches 2
                             [1.0, 1.0, 1.0]])   # uniform below thr: outlier
        mem = np.asarray(memberships_from_votes(votes, 0.37))
        assert mem.tolist() == [[True, False, False],
                                [True, True, False],
                                [True, True, False],
                                [False, False, False]]

    def test_min_membership_guarantee(self):
        votes = jnp.asarray([[1.0, 1.0, 1.0], [0.0, 0.0, 0.0]])
        mem = np.asarray(memberships_from_votes(votes, 0.9, min_membership=1))
        # argmax (ties -> lowest id) is guaranteed even below threshold;
        # a zero-vote row falls back to cluster 0 exactly like argmax
        assert mem.tolist() == [[True, False, False], [True, False, False]]

    def test_tie_breaks_match_argmax(self):
        votes = jnp.asarray([[2.0, 3.0, 3.0, 1.0],
                             [5.0, 0.0, 5.0, 5.0]])
        mem = np.asarray(memberships_from_votes(votes, 1.0, min_membership=1))
        assert (mem.argmax(1) == np.asarray(jnp.argmax(votes, 1))).all()

    def test_finalize_hard_is_argmax_one_hot(self):
        votes = jnp.asarray(np.random.default_rng(0).random((17, 5)),
                            dtype=jnp.float32)
        labels, mem = finalize_assignment(votes, "hard")
        assert np.array_equal(np.asarray(labels),
                              np.asarray(jnp.argmax(votes, 1)))
        assert (np.asarray(mem).argmax(1) == np.asarray(labels)).all()
        assert (np.asarray(mem).sum(1) == 1).all()

    def test_finalize_overlap_outlier_label(self):
        votes = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
        labels, mem = finalize_assignment(votes, "overlap", 0.5, 0)
        assert int(labels[0]) == -1 and not np.asarray(mem).any()

    def test_validation(self):
        a = jnp.zeros((16, 16))
        with pytest.raises(ValueError, match="assignment"):
            lamc_cocluster(a, LAMCConfig(2, 2, assignment="soft"))
        with pytest.raises(ValueError, match="overlap_threshold"):
            lamc_cocluster(a, LAMCConfig(2, 2, assignment="overlap",
                                         overlap_threshold=0.0))
        with pytest.raises(ValueError, match="min_membership"):
            lamc_cocluster(a, LAMCConfig(2, 2, assignment="overlap",
                                         min_membership=5))


class TestOverlapQuality:
    """Acceptance: omega >= 0.8 on the planted overlapping generator at
    default knobs (generator defaults + LAMCConfig overlap defaults)."""

    def test_omega_on_planted_overlap(self):
        rng = np.random.default_rng(0)
        data = planted_overlapping_cocluster_matrix(rng, 480, 400, k=4)
        plan = PartitionPlan(480, 400, m=2, n=8, phi=240, psi=50, t_p=8,
                             seed=0)
        cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                         assignment="overlap",
                         atom_row_clusters=8, atom_col_clusters=8)
        out = lamc_cocluster(jnp.asarray(data.matrix), cfg, plan=plan)
        mem = np.asarray(out.row_membership)
        om = omega_index(mem, data.row_membership)
        f1 = overlap_f1(mem, data.row_membership)
        assert om >= 0.8, (om, f1)
        assert f1 >= 0.85, (om, f1)
        # non-exhaustive: overlap rows detected, and the true multi-
        # membership rows carry most of them
        two = mem.sum(1) >= 2
        assert two.sum() >= 20
        true_two = data.row_membership.sum(1) >= 2
        assert (two & true_two).sum() / max(two.sum(), 1) >= 0.7

    def test_generator_membership_shapes(self):
        rng = np.random.default_rng(1)
        data = planted_overlapping_cocluster_matrix(
            rng, 120, 90, k=3, row_overlap=0.3, row_outliers=0.1,
            col_overlap=0.2, col_outliers=0.1)
        assert data.row_membership.shape == (120, 3)
        assert data.col_membership.shape == (90, 3)
        # fractions approximately honored
        assert (data.row_membership.sum(1) == 0).sum() == 12
        assert (data.row_membership.sum(1) == 2).sum() > 0
        assert (data.col_membership.sum(1) == 0).sum() == 9
        # hard projections: -1 exactly on the outliers
        assert ((data.row_labels == -1)
                == (data.row_membership.sum(1) == 0)).all()


class TestTopKKernel:
    """cosine_topk ops wrapper vs the lax.top_k oracle."""

    @pytest.mark.parametrize("p,d,k_sigs,k", [
        (37, 50, 7, 3), (512, 128, 16, 1), (100, 33, 5, 5), (9, 200, 12, 4),
    ])
    def test_matches_oracle(self, p, d, k_sigs, k):
        rng = np.random.default_rng(p + d + k)
        x = jnp.asarray(rng.normal(size=(p, d)).astype(np.float32))
        s = jnp.asarray(rng.normal(size=(k_sigs, d)).astype(np.float32))
        s = s / jnp.linalg.norm(s, axis=1, keepdims=True)
        labels, scores = kops.cosine_topk(x, s, k)
        ref_l, ref_s = kref.cosine_topk_ref(x, s, k)
        assert np.array_equal(np.asarray(labels), np.asarray(ref_l))
        np.testing.assert_allclose(np.asarray(scores), np.asarray(ref_s),
                                   rtol=1e-5)
        # descending scores, distinct labels per row
        s_np = np.asarray(scores)
        assert (np.diff(s_np, axis=1) <= 1e-6).all()
        l_np = np.asarray(labels)
        assert all(len(set(row)) == k for row in l_np)

    def test_k1_equals_cosine_assign(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32))
        s = jnp.asarray(rng.normal(size=(6, 48)).astype(np.float32))
        l1, s1 = kops.cosine_assign(x, s)
        lk, sk = kops.cosine_topk(x, s, 1)
        assert np.array_equal(np.asarray(l1), np.asarray(lk[:, 0]))
        np.testing.assert_allclose(np.asarray(s1), np.asarray(sk[:, 0]),
                                   rtol=1e-6)

    def test_k_bounds_validated(self):
        x = jnp.zeros((4, 8))
        s = jnp.zeros((3, 8))
        with pytest.raises(ValueError, match="top-k width"):
            kops.cosine_topk(x, s, 4)
        with pytest.raises(ValueError, match="top-k width"):
            kops.cosine_topk(x, s, 0)


class TestServingTopK:
    """Streaming model serves top-k multi-assignments."""

    @pytest.fixture(scope="class")
    def fitted(self):
        from repro import streaming

        rng = np.random.default_rng(0)
        data = planted_cocluster_matrix(rng, 256, 200, k=4, d=4, signal=4.0,
                                        noise=0.5)
        plan = PartitionPlan(256, 200, m=2, n=2, phi=128, psi=100, t_p=3,
                             seed=0)
        cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4)
        out = lamc_cocluster(jnp.asarray(data.matrix), cfg, plan=plan)
        return streaming.model_from_result(out), data

    def test_topk_consistent_with_k1(self, fitted):
        from repro import streaming

        model, data = fitted
        reqs = jnp.asarray(data.matrix[:64])
        r1 = streaming.assign_rows(model, reqs)
        rk = streaming.assign_rows_topk(model, reqs, k=3)
        assert rk.labels.shape == (64, 3)
        assert np.array_equal(np.asarray(r1.labels),
                              np.asarray(rk.labels[:, 0]))
        np.testing.assert_allclose(np.asarray(r1.score),
                                   np.asarray(rk.scores[:, 0]), rtol=1e-6)

    def test_topk_cols_and_validation(self, fitted):
        from repro import streaming

        model, data = fitted
        creqs = jnp.asarray(data.matrix.T[:32])
        rk = streaming.assign_cols_topk(model, creqs, k=2)
        assert rk.labels.shape == (32, 2)
        with pytest.raises(ValueError, match="expects"):
            streaming.assign_rows_topk(model, creqs, k=2)

    def test_stream_fit_consumes_assignment_knobs(self):
        """StreamConfig's overlap knobs apply at finalize: forcing knobs
        reproduce the hard fit exactly, and the validator is the shared
        one (bad knobs raise)."""
        from repro import streaming

        rng = np.random.default_rng(2)
        data = planted_cocluster_matrix(rng, 192, 128, k=3, d=3, signal=4.0,
                                        noise=0.5)
        base = dict(n_row_clusters=3, n_col_clusters=3, seed=0)
        hard, _ = streaming.fit(
            streaming.iter_row_chunks(data.matrix, 64),
            streaming.StreamConfig(**base))
        forced, _ = streaming.fit(
            streaming.iter_row_chunks(data.matrix, 64),
            streaming.StreamConfig(**base, assignment="overlap",
                                   overlap_threshold=1.0, min_membership=1))
        assert np.array_equal(np.asarray(hard.row_labels),
                              np.asarray(forced.row_labels))
        assert np.array_equal(np.asarray(hard.row_votes),
                              np.asarray(forced.row_votes))
        with pytest.raises(ValueError, match="min_membership"):
            streaming.StreamingCocluster(
                streaming.StreamConfig(**base, assignment="overlap",
                                       min_membership=7))

    def test_model_memberships(self, fitted):
        from repro import streaming

        model, _ = fitted
        row_mem, col_mem = streaming.model_memberships(model, 0.25)
        assert np.asarray(row_mem).shape == (model.n_rows,
                                             model.n_row_clusters)
        # forcing knobs reduce to the one-hot of the hard labels
        row_f, col_f = streaming.model_memberships(model, 1.0,
                                                   min_membership=1)
        assert (np.asarray(row_f).argmax(1)
                == np.asarray(model.row_labels)).all()
        assert (np.asarray(row_f).sum(1) == 1).all()
        assert (np.asarray(col_f).sum(1) == 1).all()


_DISTRIBUTED_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import LAMCConfig, lamc_cocluster
    from repro.core.distributed import distributed_lamc
    from repro.core.partition import PartitionPlan
    from repro.data import planted_cocluster_matrix

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    rng = np.random.default_rng(0)
    data = planted_cocluster_matrix(rng, 320, 240, k=4, d=4, signal=4.0,
                                    noise=0.6)
    a = jnp.asarray(data.matrix)
    plan = PartitionPlan(320, 240, m=4, n=2, phi=80, psi=120, t_p=2, seed=0)
    cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                     assignment="overlap", overlap_threshold=0.3)

    # distributed memberships bit-identical to single-host at equal seeds
    dist = distributed_lamc(mesh, a, cfg, plan)
    host = lamc_cocluster(a, cfg, plan=plan)
    assert np.array_equal(np.asarray(dist.row_membership),
                          np.asarray(host.row_membership))
    assert np.array_equal(np.asarray(dist.col_membership),
                          np.asarray(host.col_membership))
    assert np.array_equal(np.asarray(dist.row_labels),
                          np.asarray(host.row_labels))

    # forcing threshold reduces the distributed path to hard mode exactly
    cfg_hard = dataclasses.replace(cfg, assignment="hard")
    cfg_forced = dataclasses.replace(cfg, overlap_threshold=1.0,
                                     min_membership=1)
    hard = distributed_lamc(mesh, a, cfg_hard, plan)
    forced = distributed_lamc(mesh, a, cfg_forced, plan)
    assert np.array_equal(np.asarray(hard.row_labels),
                          np.asarray(forced.row_labels))
    assert np.array_equal(np.asarray(hard.row_membership),
                          np.asarray(forced.row_membership))
    assert np.array_equal(np.asarray(hard.col_membership),
                          np.asarray(forced.col_membership))
    print("OVERLAP_DISTRIBUTED_OK")
    """
)


def test_distributed_overlap_parity_8dev():
    env = dict(os.environ, PYTHONPATH="src", JAX_PLATFORMS="cpu")
    res = subprocess.run([sys.executable, "-c", _DISTRIBUTED_SCRIPT],
                         capture_output=True, text=True, env=env,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert res.returncode == 0, res.stderr[-2000:]
    assert "OVERLAP_DISTRIBUTED_OK" in res.stdout
