"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles.

All kernels run in interpret mode on CPU (the kernel body executes in
Python) — this validates BlockSpec indexing, padding/masking, and the
numerics of the in-kernel math against ``ref.py``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rng(seed=0):
    return np.random.default_rng(seed)


class TestKMeansAssign:
    @pytest.mark.parametrize("p", [8, 100, 512, 777])
    @pytest.mark.parametrize("d", [4, 37, 128])
    @pytest.mark.parametrize("k", [2, 7, 16])
    def test_shape_sweep_f32(self, p, d, k):
        rng = _rng(p * 1000 + d * 10 + k)
        x = jnp.asarray(rng.normal(size=(p, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        l_k, d_k = ops.kmeans_assign(x, c)
        l_r, d_r = ref.kmeans_assign_ref(x, c)
        np.testing.assert_array_equal(np.array(l_k), np.array(l_r))
        np.testing.assert_allclose(np.array(d_k), np.array(d_r), rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep(self, dtype):
        rng = _rng(1)
        x = jnp.asarray(rng.normal(size=(130, 64))).astype(dtype)
        c = jnp.asarray(rng.normal(size=(5, 64))).astype(dtype)
        l_k, _ = ops.kmeans_assign(x, c)
        l_r, _ = ref.kmeans_assign_ref(x, c)
        agree = float(jnp.mean((l_k == l_r).astype(jnp.float32)))
        # bf16 rounding can flip genuinely ambiguous points; require near-total agreement
        assert agree > 0.98, agree

    def test_sentinel_centroids_never_selected(self):
        """Padding adds sentinel centroids; labels must stay < true K."""
        rng = _rng(2)
        x = jnp.asarray(rng.normal(size=(50, 16)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
        labels, _ = ops.kmeans_assign(x, c)
        assert int(labels.max()) < 3

    def test_tile_boundary_exact_multiple(self):
        rng = _rng(3)
        x = jnp.asarray(rng.normal(size=(1024, 128)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
        l_k, _ = ops.kmeans_assign(x, c, tile_p=512)
        l_r, _ = ref.kmeans_assign_ref(x, c)
        np.testing.assert_array_equal(np.array(l_k), np.array(l_r))


class TestKMeansUpdate:
    """Fused one-pass Lloyd update vs the three-pass oracle."""

    def _check(self, x, c, w=None, tol=1e-4):
        l_k, d_k, s_k, n_k = ops.kmeans_update(x, c, weights=w)
        l_r, d_r, s_r, n_r = ref.kmeans_update_ref(x, c, weights=w)
        np.testing.assert_array_equal(np.array(l_k), np.array(l_r))
        np.testing.assert_allclose(np.array(d_k), np.array(d_r), rtol=tol, atol=tol)
        np.testing.assert_allclose(np.array(s_k), np.array(s_r), rtol=tol, atol=tol)
        np.testing.assert_allclose(np.array(n_k), np.array(n_r), rtol=1e-5, atol=1e-5)
        return l_k, d_k, s_k, n_k

    @pytest.mark.parametrize("p", [8, 100, 512, 777])
    @pytest.mark.parametrize("d", [4, 37, 128])
    @pytest.mark.parametrize("k", [2, 7, 16])
    def test_shape_sweep_f32(self, p, d, k):
        rng = _rng(p * 1000 + d * 10 + k)
        x = jnp.asarray(rng.normal(size=(p, d)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(k, d)).astype(np.float32))
        self._check(x, c)

    def test_weighted(self):
        rng = _rng(21)
        x = jnp.asarray(rng.normal(size=(300, 24)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(5, 24)).astype(np.float32))
        w = jnp.asarray(rng.uniform(0.0, 2.0, 300).astype(np.float32))
        self._check(x, c, w=w)

    def test_zero_weight_points_contribute_nothing(self):
        rng = _rng(22)
        x = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(3, 8)).astype(np.float32))
        w = jnp.zeros((64,), jnp.float32).at[:10].set(1.0)
        _, _, sums, counts = self._check(x, c, w=w)
        assert float(jnp.sum(counts)) == 10.0

    def test_empty_cluster_rows_zero(self):
        """A centroid no point selects must accumulate exactly zero."""
        rng = _rng(23)
        x = jnp.asarray(rng.normal(size=(120, 16)).astype(np.float32))
        far = jnp.full((1, 16), 500.0, jnp.float32)
        c = jnp.concatenate([jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32)), far])
        labels, _, sums, counts = self._check(x, c)
        assert int(labels.max()) < 3
        assert float(counts[3]) == 0.0
        np.testing.assert_array_equal(np.array(sums[3]), np.zeros(16, np.float32))

    def test_padded_k_sentinels_sliced_off(self):
        """K=3 pads to 8 with +1e6 sentinels; outputs keep true K only."""
        rng = _rng(24)
        x = jnp.asarray(rng.normal(size=(50, 16)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(3, 16)).astype(np.float32))
        labels, _, sums, counts = ops.kmeans_update(x, c)
        assert sums.shape == (3, 16) and counts.shape == (3,)
        assert int(labels.max()) < 3
        assert float(jnp.sum(counts)) == 50.0

    def test_bf16_accumulates_f32(self):
        rng = _rng(25)
        x = jnp.asarray(rng.normal(size=(200, 64))).astype(jnp.bfloat16)
        c = jnp.asarray(rng.normal(size=(4, 64))).astype(jnp.bfloat16)
        _, _, s_k, n_k = ops.kmeans_update(x, c)
        assert s_k.dtype == jnp.float32 and n_k.dtype == jnp.float32
        _, _, s_r, n_r = ref.kmeans_update_ref(x, c)
        np.testing.assert_allclose(np.array(s_k), np.array(s_r), rtol=2e-2, atol=2e-2)

    def test_tile_boundary_exact_multiple(self):
        rng = _rng(26)
        x = jnp.asarray(rng.normal(size=(1024, 128)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(8, 128)).astype(np.float32))
        self._check(x, c)


class TestBipartiteNormalize:
    @pytest.mark.parametrize("m,n", [(16, 16), (100, 300), (257, 129), (512, 64)])
    def test_shape_sweep(self, m, n):
        rng = _rng(m + n)
        a = jnp.asarray(rng.normal(size=(m, n)).astype(np.float32))
        out_k, s1_k, s2_k = ops.bipartite_normalize(a)
        d1 = jnp.sum(jnp.abs(a), 1)
        d2 = jnp.sum(jnp.abs(a), 0)
        out_r = ref.bipartite_normalize_ref(a, d1, d2)
        np.testing.assert_allclose(np.array(out_k), np.array(out_r), rtol=1e-5, atol=1e-6)

    def test_matches_core_spectral(self):
        """Kernel path must agree with the core library's normalization."""
        from repro.core.spectral import normalize_bipartite

        rng = _rng(5)
        a = jnp.asarray(np.abs(rng.normal(size=(90, 70))).astype(np.float32))
        out_k, s1_k, s2_k = ops.bipartite_normalize(a)
        out_c, s1_c, s2_c = normalize_bipartite(a)
        np.testing.assert_allclose(np.array(out_k), np.array(out_c), rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.array(s1_k), np.array(s1_c), rtol=1e-6)

    def test_zero_rows_finite(self):
        a = jnp.zeros((20, 30), jnp.float32).at[0, 0].set(2.0)
        out, _, _ = ops.bipartite_normalize(a)
        assert bool(jnp.all(jnp.isfinite(out)))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype(self, dtype):
        rng = _rng(6)
        a = jnp.asarray(rng.normal(size=(64, 64))).astype(dtype)
        out, _, _ = ops.bipartite_normalize(a)
        assert out.dtype == dtype


class TestFlashAttention:
    def _check(self, b, hq, hkv, s, d, causal, tile, dtype=jnp.float32, tol=2e-3):
        rng = _rng(b * 10 + s)
        q = jnp.asarray(rng.normal(size=(b, hq, s, d))).astype(dtype)
        k = jnp.asarray(rng.normal(size=(b, hkv, s, d))).astype(dtype)
        v = jnp.asarray(rng.normal(size=(b, hkv, s, d))).astype(dtype)
        o_k = ops.flash_attention(q, k, v, causal=causal, tile_q=tile, tile_k=tile)
        rep = hq // hkv
        kk = jnp.repeat(k, rep, 1).reshape(b * hq, s, d)
        vv = jnp.repeat(v, rep, 1).reshape(b * hq, s, d)
        o_r = ref.attention_ref(q.reshape(b * hq, s, d), kk, vv, causal=causal)
        np.testing.assert_allclose(
            np.array(o_k, np.float32),
            np.array(o_r.reshape(b, hq, s, d), np.float32),
            rtol=tol, atol=tol,
        )

    @pytest.mark.parametrize("s", [32, 64, 100, 160])
    def test_seq_sweep_causal(self, s):
        self._check(1, 2, 2, s, 32, causal=True, tile=32)

    def test_non_causal(self):
        self._check(1, 2, 2, 96, 32, causal=False, tile=32)

    def test_gqa_expansion(self):
        self._check(2, 8, 2, 64, 16, causal=True, tile=32)

    def test_unaligned_seq_padding(self):
        # 100 is not a multiple of tile 64: padded KV must be masked out
        self._check(1, 1, 1, 100, 32, causal=True, tile=64)

    def test_bf16(self):
        self._check(1, 2, 2, 64, 32, causal=True, tile=32,
                    dtype=jnp.bfloat16, tol=2e-2)

    def test_matches_chunked_jnp_attention(self):
        """Cross-check vs the model stack's lax.scan chunked attention."""
        from repro.models.attention import chunked_causal_attention

        rng = _rng(9)
        q = jnp.asarray(rng.normal(size=(1, 4, 128, 32)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(1, 4, 128, 32)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(1, 4, 128, 32)).astype(np.float32))
        o_pallas = ops.flash_attention(q, k, v, causal=True, tile_q=32, tile_k=32)
        o_chunk = chunked_causal_attention(q, k, v, chunk_size=32)
        np.testing.assert_allclose(np.array(o_pallas), np.array(o_chunk),
                                   rtol=2e-3, atol=2e-3)


class TestKMeansPallasIntegration:
    def test_kmeans_with_pallas_assign(self):
        """core.kmeans(assign_impl='pallas') — the fused-update fast path —
        must match the jnp reference path."""
        from repro.core import kmeans as km

        rng = _rng(11)
        x = jnp.asarray(rng.normal(size=(200, 24)).astype(np.float32))
        r_jnp = km.kmeans(jax.random.key(0), x, 4, n_iter=8, assign_impl="jnp")
        r_pls = km.kmeans(jax.random.key(0), x, 4, n_iter=8, assign_impl="pallas")
        np.testing.assert_array_equal(np.array(r_jnp.labels), np.array(r_pls.labels))
        np.testing.assert_allclose(float(r_jnp.inertia), float(r_pls.inertia), rtol=1e-4)
        np.testing.assert_allclose(np.array(r_jnp.centroids), np.array(r_pls.centroids),
                                   rtol=1e-4, atol=1e-5)

    def test_weighted_kmeans_fused_matches_jnp(self):
        from repro.core import kmeans as km

        rng = _rng(12)
        x = jnp.asarray(rng.normal(size=(150, 16)).astype(np.float32))
        w = jnp.asarray(rng.uniform(0.0, 1.0, 150).astype(np.float32))
        r_jnp = km.kmeans(jax.random.key(1), x, 3, n_iter=6, assign_impl="jnp", weights=w)
        r_pls = km.kmeans(jax.random.key(1), x, 3, n_iter=6, assign_impl="pallas", weights=w)
        np.testing.assert_array_equal(np.array(r_jnp.labels), np.array(r_pls.labels))
        np.testing.assert_allclose(float(r_jnp.inertia), float(r_pls.inertia), rtol=1e-4)

    def test_fused_vmappable_over_blocks(self):
        """The LAMC hot path vmaps kmeans over a block stack."""
        from repro.core import kmeans as km

        rng = _rng(13)
        stack = jnp.asarray(rng.normal(size=(5, 40, 8)).astype(np.float32))
        keys = jax.random.split(jax.random.key(2), 5)
        lab_j = jax.vmap(lambda kk, xx: km.kmeans(kk, xx, 3, n_iter=4,
                                                  assign_impl="jnp").labels)(keys, stack)
        lab_p = jax.vmap(lambda kk, xx: km.kmeans(kk, xx, 3, n_iter=4,
                                                  assign_impl="pallas").labels)(keys, stack)
        np.testing.assert_array_equal(np.array(lab_j), np.array(lab_p))
