"""k-means: convergence on separable data, weighting, SPMD-static shapes."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import kmeans as km
from repro.core.metrics import nmi


def _blobs(rng, n_per=50, k=4, d=8, spread=0.1):
    centers = rng.normal(0, 1, (k, d)) * 4.0
    pts = np.concatenate([centers[i] + rng.normal(0, spread, (n_per, d)) for i in range(k)])
    labels = np.repeat(np.arange(k), n_per)
    perm = rng.permutation(len(pts))
    return pts[perm].astype(np.float32), labels[perm]


class TestAssign:
    def test_assign_matches_bruteforce(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(40, 6)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(5, 6)).astype(np.float32))
        labels, d2 = km.assign(x, c)
        brute = np.argmin(((np.array(x)[:, None] - np.array(c)[None]) ** 2).sum(-1), axis=1)
        np.testing.assert_array_equal(np.array(labels), brute)
        brute_d = np.min(((np.array(x)[:, None] - np.array(c)[None]) ** 2).sum(-1), axis=1)
        np.testing.assert_allclose(np.array(d2), brute_d, rtol=1e-4, atol=1e-4)


class TestKMeans:
    def test_recovers_separable_blobs(self):
        rng = np.random.default_rng(1)
        x, true = _blobs(rng)
        res = km.kmeans(jax.random.key(0), jnp.asarray(x), 4, n_iter=20)
        assert nmi(np.array(res.labels), true) > 0.95

    def test_inertia_nonincreasing_with_iters(self):
        rng = np.random.default_rng(2)
        x, _ = _blobs(rng, spread=0.5)
        xs = jnp.asarray(x)
        inertias = [
            float(km.kmeans(jax.random.key(0), xs, 4, n_iter=i).inertia)
            for i in (1, 5, 20)
        ]
        assert inertias[1] <= inertias[0] + 1e-3
        assert inertias[2] <= inertias[1] + 1e-3

    def test_weighted_ignores_zero_weight_points(self):
        rng = np.random.default_rng(3)
        x, true = _blobs(rng, n_per=30, k=3)
        # poison points far away with zero weight must not move centroids
        poison = rng.normal(100.0, 1.0, (20, x.shape[1])).astype(np.float32)
        xw = jnp.asarray(np.concatenate([x, poison]))
        w = jnp.asarray(np.concatenate([np.ones(len(x)), np.zeros(20)]).astype(np.float32))
        res = km.kmeans(jax.random.key(0), xw, 3, n_iter=20, weights=w)
        assert nmi(np.array(res.labels[: len(x)]), true) > 0.95
        # no centroid should be near the poison cloud
        assert float(jnp.max(jnp.abs(res.centroids))) < 50.0

    @given(k=st.integers(2, 6), n=st.integers(20, 60))
    @settings(max_examples=10, deadline=None)
    def test_labels_in_range_and_static_shapes(self, k, n):
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(n, 5)).astype(np.float32))
        res = km.kmeans(jax.random.key(1), x, k, n_iter=5)
        assert res.labels.shape == (n,)
        assert res.centroids.shape == (k, 5)
        lab = np.array(res.labels)
        assert lab.min() >= 0 and lab.max() < k

    def test_vmappable_over_blocks(self):
        """The LAMC hot path vmaps kmeans over a block stack."""
        rng = np.random.default_rng(5)
        stack = jnp.asarray(rng.normal(size=(6, 40, 5)).astype(np.float32))
        keys = jax.random.split(jax.random.key(0), 6)
        res = jax.vmap(lambda kk, xx: km.kmeans(kk, xx, 3, n_iter=4).labels)(keys, stack)
        assert res.shape == (6, 40)
