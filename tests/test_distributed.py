"""Distributed LAMC: multi-device correctness via subprocess (needs its own
XLA_FLAGS before jax init, so it cannot share this process)."""

import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core import LAMCConfig
    from repro.core.distributed import distributed_lamc
    from repro.core.partition import PartitionPlan
    from repro.core.metrics import cocluster_scores
    from repro.data import planted_cocluster_matrix

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))

    rng = np.random.default_rng(0)
    data = planted_cocluster_matrix(rng, 480, 400, k=4, d=4, signal=4.0, noise=0.5)
    a = jnp.asarray(data.matrix)
    plan = PartitionPlan(480, 400, m=4, n=2, phi=120, psi=200, t_p=2, seed=0)
    cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4)
    out = distributed_lamc(mesh, a, cfg, plan)
    s = cocluster_scores(np.array(out.row_labels), np.array(out.col_labels),
                         data.row_labels, data.col_labels)
    assert s["nmi"] > 0.55, s
    # deterministic across runs
    out2 = distributed_lamc(mesh, a, cfg, plan)
    assert np.array_equal(np.array(out.row_labels), np.array(out2.row_labels))
    # multiple blocks per device (16 blocks on 8 devices)
    plan2 = PartitionPlan(480, 400, m=4, n=4, phi=120, psi=100, t_p=2, seed=0)
    out3 = distributed_lamc(mesh, a, cfg, plan2)
    s3 = cocluster_scores(np.array(out3.row_labels), np.array(out3.col_labels),
                          data.row_labels, data.col_labels)
    assert s3["nmi"] > 0.55, s3
    print("DISTRIBUTED_OK", s["nmi"], s3["nmi"])
    """
)


_SCRIPT_SMALL_AND_SPARSE = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh
    from repro.core import LAMCConfig
    from repro.core.distributed import distributed_lamc
    from repro.core.partition import PartitionPlan
    from repro.data import planted_cocluster_matrix, to_bcoo

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)

    # 1. small matrix: n_rows (48) < signature_dim (64). anchor_indices
    # clamps the anchor set per axis, so the merge phase must reshape
    # signatures with the *effective* per-axis q — this crashed before.
    data = planted_cocluster_matrix(rng, 48, 400, k=3, d=3, signal=4.0, noise=0.4)
    a = jnp.asarray(data.matrix)
    plan = PartitionPlan(48, 400, m=4, n=2, phi=12, psi=200, t_p=2, seed=0)
    cfg = LAMCConfig(n_row_clusters=3, n_col_clusters=3)
    out = distributed_lamc(mesh, a, cfg, plan)
    assert out.row_labels.shape == (48,)

    # 2. bcoo input: distributed sparse path must match distributed dense
    # labels exactly (same blocks, same anchor slivers, same seeds).
    data2 = planted_cocluster_matrix(rng, 480, 400, k=4, d=4,
                                     signal=4.0, noise=0.5, density=0.2)
    a2 = jnp.asarray(data2.matrix)
    plan2 = PartitionPlan(480, 400, m=4, n=2, phi=120, psi=200, t_p=2, seed=0)
    out_d = distributed_lamc(mesh, a2, LAMCConfig(n_row_clusters=4, n_col_clusters=4), plan2)
    out_s = distributed_lamc(mesh, to_bcoo(data2.matrix),
                             LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                                        input_format="bcoo"), plan2)
    assert np.array_equal(np.array(out_d.row_labels), np.array(out_s.row_labels))
    assert np.array_equal(np.array(out_d.col_labels), np.array(out_s.col_labels))
    print("DISTRIBUTED_SMALL_SPARSE_OK")
    """
)


def _run_subprocess_script(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )


@pytest.mark.slow
def test_distributed_lamc_8dev():
    res = _run_subprocess_script(_SCRIPT)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "DISTRIBUTED_OK" in res.stdout


@pytest.mark.slow
def test_distributed_small_matrix_and_bcoo_8dev():
    """Regressions: signature_dim > axis length (per-axis q), bcoo parity."""
    res = _run_subprocess_script(_SCRIPT_SMALL_AND_SPARSE)
    assert res.returncode == 0, f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}"
    assert "DISTRIBUTED_SMALL_SPARSE_OK" in res.stdout
