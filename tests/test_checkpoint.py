"""Checkpoint round-trips for model-artifact-shaped trees.

The sharded-pytree checkpointing was originally exercised only through
the LM training stack; the CoclusterModel artifact adds trees that mix
float arrays, *integer* arrays, and plain Python scalars (config values
riding inside a NamedTuple). These tests pin that contract directly.
"""

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


class _ModelTree(NamedTuple):
    labels: jnp.ndarray
    votes: jnp.ndarray
    anchors: jnp.ndarray
    n_clusters: int
    threshold: float
    fitted: bool


@dataclasses.dataclass
class _DataclassTree:
    weights: jnp.ndarray
    ids: jnp.ndarray
    step: int


def _model_tree():
    return _ModelTree(
        labels=jnp.arange(12, dtype=jnp.int32),
        votes=jnp.ones((12, 3), jnp.float32) * 0.5,
        anchors=jnp.asarray([3, 1, 4, 1, 5], jnp.int32),
        n_clusters=3,
        threshold=0.95,
        fitted=True,
    )


class TestScalarAndIntTrees:
    def test_namedtuple_int_arrays_and_scalars_roundtrip(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 0, tree)
        back, _ = ckpt.restore(str(tmp_path), 0, tree)
        assert isinstance(back, _ModelTree)
        np.testing.assert_array_equal(np.asarray(back.labels), np.arange(12))
        assert back.labels.dtype == jnp.int32
        np.testing.assert_allclose(np.asarray(back.votes), 0.5)
        np.testing.assert_array_equal(np.asarray(back.anchors), [3, 1, 4, 1, 5])
        # Python scalars come back as Python scalars of the template's type
        assert back.n_clusters == 3 and isinstance(back.n_clusters, int)
        assert back.threshold == pytest.approx(0.95)
        assert isinstance(back.threshold, float)
        assert back.fitted is True and isinstance(back.fitted, bool)

    def test_dataclass_tree_roundtrip(self, tmp_path):
        import jax

        jax.tree_util.register_dataclass(
            _DataclassTree,
            data_fields=["weights", "ids", "step"], meta_fields=[])
        tree = _DataclassTree(weights=jnp.ones((4, 2)),
                              ids=jnp.asarray([7, 8], jnp.int32), step=42)
        ckpt.save(str(tmp_path), 1, tree)
        back, _ = ckpt.restore(str(tmp_path), 1, tree)
        np.testing.assert_array_equal(np.asarray(back.ids), [7, 8])
        assert back.step == 42

    def test_extra_meta_roundtrip(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 0, tree, extra_meta={"kind": "m", "v": 2})
        _, meta = ckpt.restore(str(tmp_path), 0, tree)
        assert meta == {"kind": "m", "v": 2}

    def test_shape_mismatch_is_loud(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 0, tree)
        bad = tree._replace(labels=jnp.arange(13, dtype=jnp.int32))
        with pytest.raises(ValueError, match="shape mismatch"):
            ckpt.restore(str(tmp_path), 0, bad)

    def test_latest_step_ignores_uncommitted(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 3, tree)
        (tmp_path / "step_00000009").mkdir()  # no _COMMITTED sentinel
        assert ckpt.latest_step(str(tmp_path)) == 3
