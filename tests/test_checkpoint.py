"""Checkpoint round-trips for model-artifact-shaped trees.

The sharded-pytree checkpointing was originally exercised only through
the LM training stack; the CoclusterModel artifact adds trees that mix
float arrays, *integer* arrays, and plain Python scalars (config values
riding inside a NamedTuple). These tests pin that contract directly.
"""

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


class _ModelTree(NamedTuple):
    labels: jnp.ndarray
    votes: jnp.ndarray
    anchors: jnp.ndarray
    n_clusters: int
    threshold: float
    fitted: bool


@dataclasses.dataclass
class _DataclassTree:
    weights: jnp.ndarray
    ids: jnp.ndarray
    step: int


def _model_tree():
    return _ModelTree(
        labels=jnp.arange(12, dtype=jnp.int32),
        votes=jnp.ones((12, 3), jnp.float32) * 0.5,
        anchors=jnp.asarray([3, 1, 4, 1, 5], jnp.int32),
        n_clusters=3,
        threshold=0.95,
        fitted=True,
    )


class TestScalarAndIntTrees:
    def test_namedtuple_int_arrays_and_scalars_roundtrip(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 0, tree)
        back, _ = ckpt.restore(str(tmp_path), 0, tree)
        assert isinstance(back, _ModelTree)
        np.testing.assert_array_equal(np.asarray(back.labels), np.arange(12))
        assert back.labels.dtype == jnp.int32
        np.testing.assert_allclose(np.asarray(back.votes), 0.5)
        np.testing.assert_array_equal(np.asarray(back.anchors), [3, 1, 4, 1, 5])
        # Python scalars come back as Python scalars of the template's type
        assert back.n_clusters == 3 and isinstance(back.n_clusters, int)
        assert back.threshold == pytest.approx(0.95)
        assert isinstance(back.threshold, float)
        assert back.fitted is True and isinstance(back.fitted, bool)

    def test_dataclass_tree_roundtrip(self, tmp_path):
        import jax

        jax.tree_util.register_dataclass(
            _DataclassTree,
            data_fields=["weights", "ids", "step"], meta_fields=[])
        tree = _DataclassTree(weights=jnp.ones((4, 2)),
                              ids=jnp.asarray([7, 8], jnp.int32), step=42)
        ckpt.save(str(tmp_path), 1, tree)
        back, _ = ckpt.restore(str(tmp_path), 1, tree)
        np.testing.assert_array_equal(np.asarray(back.ids), [7, 8])
        assert back.step == 42

    def test_extra_meta_roundtrip(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 0, tree, extra_meta={"kind": "m", "v": 2})
        _, meta = ckpt.restore(str(tmp_path), 0, tree)
        assert meta == {"kind": "m", "v": 2}

    def test_shape_mismatch_is_loud(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 0, tree)
        bad = tree._replace(labels=jnp.arange(13, dtype=jnp.int32))
        with pytest.raises(ValueError, match="shape mismatch"):
            ckpt.restore(str(tmp_path), 0, bad)

    def test_latest_step_ignores_uncommitted(self, tmp_path):
        tree = _model_tree()
        ckpt.save(str(tmp_path), 3, tree)
        (tmp_path / "step_00000009").mkdir()  # no _COMMITTED sentinel
        assert ckpt.latest_step(str(tmp_path)) == 3

    def test_latest_step_ignores_tmp_dirs(self, tmp_path):
        # a crash mid-save leaves step_X.tmp behind; it must be invisible
        tree = _model_tree()
        ckpt.save(str(tmp_path), 3, tree)
        crashed = tmp_path / "step_00000007.tmp"
        crashed.mkdir()
        (crashed / "_COMMITTED").write_text("ok")  # even with a sentinel
        assert ckpt.available_steps(str(tmp_path)) == [3]


class TestOverwrite:
    """Re-saving a committed step must never pass through a state where a
    crash loses the checkpoint: the old copy is displaced to ``.old`` and
    stays restorable until the new one is committed."""

    def test_overwrite_replaces_and_leaves_no_old(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 3, _model_tree())
        newer = _model_tree()._replace(votes=jnp.full((12, 3), 2.0, jnp.float32))
        ckpt.save(d, 3, newer)
        back, _ = ckpt.restore(d, 3, newer)
        np.testing.assert_allclose(np.asarray(back.votes), 2.0)
        assert ckpt.available_steps(d) == [3]
        assert not (tmp_path / "step_00000003.old").exists()

    def test_crashed_overwrite_falls_back_to_displaced_copy(self, tmp_path):
        # crash window: old dir already moved aside, new dir not yet renamed
        d = str(tmp_path)
        tree = _model_tree()
        ckpt.save(d, 3, tree)
        (tmp_path / "step_00000003").rename(tmp_path / "step_00000003.old")
        assert ckpt.available_steps(d) == [3]
        assert ckpt.latest_step(d) == 3
        back, _ = ckpt.restore(d, 3, tree)
        np.testing.assert_allclose(np.asarray(back.votes), 0.5)

    def test_available_steps_dedupes_final_plus_old(self, tmp_path):
        # crash window where BOTH step_X and step_X.old exist (old dir
        # displaced, new dir already renamed in, cleanup not yet run):
        # the step must be listed exactly once, not per-directory
        import shutil

        d = str(tmp_path)
        ckpt.save(d, 3, _model_tree())
        shutil.copytree(tmp_path / "step_00000003",
                        tmp_path / "step_00000003.old")
        assert ckpt.available_steps(d) == [3]

    def test_available_steps_survives_listing_race(self, tmp_path,
                                                   monkeypatch):
        # the hot-swap path lists while a background save overwrites: the
        # listdir snapshot returns the canonical name, then the saver
        # renames step_X -> step_X.old before the sentinel check runs. A
        # listing that only re-checked the snapshotted name would report
        # a committed step as transiently missing.
        import os as _os

        d = str(tmp_path)
        ckpt.save(d, 3, _model_tree())
        real_listdir = _os.listdir

        def raced_listdir(path):
            names = real_listdir(path)
            if _os.path.abspath(path) == _os.path.abspath(d):
                # simulate the rename landing right after the snapshot
                _os.rename(_os.path.join(d, "step_00000003"),
                           _os.path.join(d, "step_00000003.old"))
            return names

        monkeypatch.setattr(_os, "listdir", raced_listdir)
        assert ckpt.available_steps(d) == [3]

    def test_save_over_displaced_copy_cleans_it_up(self, tmp_path):
        d = str(tmp_path)
        ckpt.save(d, 3, _model_tree())
        (tmp_path / "step_00000003").rename(tmp_path / "step_00000003.old")
        newer = _model_tree()._replace(votes=jnp.full((12, 3), 2.0, jnp.float32))
        ckpt.save(d, 3, newer)
        assert not (tmp_path / "step_00000003.old").exists()
        back, _ = ckpt.restore(d, 3, newer)
        np.testing.assert_allclose(np.asarray(back.votes), 2.0)


class TestCrashConsistency:
    """A corrupt checkpoint must raise CheckpointCorruptError naming the
    damage — never restore silent garbage (DESIGN.md §12)."""

    def _save_one(self, tmp_path):
        tree = _model_tree()
        path = ckpt.save(str(tmp_path), 0, tree, extra_meta={"kind": "t"})
        return tree, path

    def test_hash_mismatch_names_the_bad_leaf(self, tmp_path):
        import json

        tree, path = self._save_one(tmp_path)
        # rewrite one leaf's recorded hash: the payload no longer matches
        mpath = f"{path}/manifest.json"
        meta = json.load(open(mpath))
        meta["leaves"][".votes"]["sha256"] = "0" * 64
        json.dump(meta, open(mpath, "w"))
        with pytest.raises(ckpt.CheckpointCorruptError, match="votes"):
            ckpt.restore(str(tmp_path), 0, tree)

    def test_truncated_arrays_is_loud(self, tmp_path):
        tree, path = self._save_one(tmp_path)
        npz = f"{path}/arrays.npz"
        blob = open(npz, "rb").read()
        with open(npz, "wb") as f:
            f.write(blob[: len(blob) // 2])
        with pytest.raises(ckpt.CheckpointCorruptError):
            ckpt.restore(str(tmp_path), 0, tree)

    def test_flipped_payload_byte_is_loud(self, tmp_path):
        tree, path = self._save_one(tmp_path)
        npz = f"{path}/arrays.npz"
        blob = bytearray(open(npz, "rb").read())
        # flip a byte inside the votes payload (0.5f32 = 00 00 00 3f,
        # stored verbatim — np.savez members are uncompressed)
        needle = np.asarray(tree.votes).tobytes()[:16]
        at = blob.find(needle)
        assert at > 0, "votes payload not found in npz"
        blob[at] ^= 0xFF
        with open(npz, "wb") as f:
            f.write(blob)
        with pytest.raises(ckpt.CheckpointCorruptError):
            ckpt.restore(str(tmp_path), 0, tree)

    def test_missing_arrays_is_loud(self, tmp_path):
        import os

        tree, path = self._save_one(tmp_path)
        os.remove(f"{path}/arrays.npz")
        with pytest.raises(ckpt.CheckpointCorruptError, match="arrays.npz"):
            ckpt.restore(str(tmp_path), 0, tree)

    def test_corrupt_manifest_is_loud(self, tmp_path):
        tree, path = self._save_one(tmp_path)
        with open(f"{path}/manifest.json", "w") as f:
            f.write('{"step": 0, "leav')  # truncated json
        with pytest.raises(ckpt.CheckpointCorruptError, match="manifest"):
            ckpt.read_manifest(str(tmp_path), 0)

    def test_uncommitted_is_filenotfound_not_corrupt(self, tmp_path):
        # no sentinel = "never finished", a different failure mode than
        # "finished then damaged"
        (tmp_path / "step_00000000").mkdir()
        with pytest.raises(FileNotFoundError):
            ckpt.read_manifest(str(tmp_path), 0)


class TestRestoreTree:
    def test_nested_dict_roundtrip_without_template(self, tmp_path):
        tree = {
            "scalars": np.asarray([5, 7], np.int64),
            "per_chunk": {f"{i:06d}": np.full((2, 3), i, np.float32)
                          for i in range(3)},
        }
        ckpt.save(str(tmp_path), 4, tree, extra_meta={"kind": "state"})
        back, extra = ckpt.restore_tree(str(tmp_path), 4)
        assert extra == {"kind": "state"}
        np.testing.assert_array_equal(back["scalars"], [5, 7])
        assert sorted(back["per_chunk"]) == ["000000", "000001", "000002"]
        for i in range(3):
            np.testing.assert_array_equal(back["per_chunk"][f"{i:06d}"],
                                          np.full((2, 3), i))

    def test_restore_tree_verifies_hashes(self, tmp_path):
        tree = {"a": np.arange(8, dtype=np.float32)}
        path = ckpt.save(str(tmp_path), 0, tree)
        npz = f"{path}/arrays.npz"
        blob = bytearray(open(npz, "rb").read())
        at = blob.find(tree["a"].tobytes())
        assert at > 0
        blob[at] ^= 0xFF
        with open(npz, "wb") as f:
            f.write(blob)
        with pytest.raises(ckpt.CheckpointCorruptError):
            ckpt.restore_tree(str(tmp_path), 0)
