"""Per-architecture smoke tests (spec deliverable f): reduced configs of the
same family, one forward/train step on CPU, asserting output shapes and
no NaNs — plus gradient flow and prefill->decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs, reduced
from repro.models import build_model
from repro.models import transformer

ARCHS = [a for a in list_archs() if a != "lamc-coclustering"]


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    extra = None
    if cfg.frontend == "patches":
        fe = jnp.asarray(rng.normal(size=(b, cfg.frontend_len, cfg.d_model)),
                         jnp.bfloat16)
        batch["frontend_embeds"] = fe
        extra = {"frontend_embeds": fe}
    if cfg.enc_dec:
        fe = jnp.asarray(rng.normal(size=(b, cfg.enc_seq_len, cfg.d_model)),
                         jnp.bfloat16)
        batch["frontend_embeds"] = fe
        extra = {"frontend_embeds": fe}
    return batch, extra


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = reduced(arch)
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        batch, _ = _batch(cfg)
        loss, parts = m.loss_fn(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
        # one SGD step: gradients exist, are finite, and change the loss
        grads = jax.grad(lambda p: m.loss_fn(p, batch)[0])(params)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"
        params2 = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype),
                               params, grads)
        loss2, _ = m.loss_fn(params2, batch)
        assert float(loss2) < float(loss), f"{arch}: SGD step did not reduce loss"

    def test_decode_shapes_no_nan(self, arch):
        cfg = reduced(arch)
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        batch, extra = _batch(cfg)
        cache = m.init_decode_cache(2, 64)
        dextra = None
        if cfg.enc_dec:
            rng = np.random.default_rng(1)
            dextra = {"enc_out": jnp.asarray(
                rng.normal(size=(2, cfg.enc_seq_len, cfg.d_model)), jnp.bfloat16)}
        logits, cache = m.decode_step(params, batch["tokens"][:, 0], cache,
                                      jnp.int32(0), dextra)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ["qwen3-4b", "recurrentgemma-2b", "xlstm-125m",
                                  "deepseek-moe-16b"])
def test_prefill_decode_consistency(arch):
    """decode(prefill(t[:s-1]), t[s-1]) must match forward_full's last logits.

    This exercises every cache path: KV buffers, rolling local windows,
    recurrent states."""
    cfg = reduced(arch)
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    s = 24
    batch, extra = _batch(cfg, s=s, seed=3)
    toks = batch["tokens"]

    # ground truth: full forward over all s tokens
    hidden, _, _ = transformer.forward_full(cfg, params, toks, extra,
                                            dtype=jnp.float32, remat=False)
    want = transformer.logits_from_hidden(cfg, params, hidden[:, -1:])[:, 0]

    # prefill s-1, then decode token s-1 at pos s-1
    _, caches = transformer.prefill(cfg, params, toks[:, : s - 1], extra,
                                    dtype=jnp.float32)
    cache = transformer.grow_cache(cfg, caches, s - 1, 64, dtype=jnp.float32)
    got, _ = transformer.decode_step(cfg, params, toks[:, s - 1], cache,
                                     jnp.int32(s - 1), extra, dtype=jnp.float32)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-2,
                               atol=2e-2)


def test_local_window_rolling_consistency():
    """Decode many steps past the window: rolling buffer must evict the
    oldest entries (slot alignment bug guard)."""
    cfg = reduced("recurrentgemma-2b")  # window 16
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    s = 40  # > 2x window
    batch, extra = _batch(cfg, s=s, seed=4)
    toks = batch["tokens"]
    hidden, _, _ = transformer.forward_full(cfg, params, toks, extra,
                                            dtype=jnp.float32, remat=False)
    want = transformer.logits_from_hidden(cfg, params, hidden[:, -1:])[:, 0]
    _, caches = transformer.prefill(cfg, params, toks[:, : s - 1], extra,
                                    dtype=jnp.float32)
    cache = transformer.grow_cache(cfg, caches, s - 1, 64, dtype=jnp.float32)
    got, _ = transformer.decode_step(cfg, params, toks[:, s - 1], cache,
                                     jnp.int32(s - 1), extra, dtype=jnp.float32)
    np.testing.assert_allclose(np.array(got), np.array(want), rtol=2e-2, atol=2e-2)


def test_mrope_text_degenerates_to_rope():
    """With equal position streams, M-RoPE == standard RoPE (the Qwen2-VL
    property our VLM positions rely on)."""
    from repro.models import layers

    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(2, 4, 16, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 4, 16, 32)).astype(np.float32))
    pos = jnp.arange(16)
    q1, k1 = layers.apply_rope(q, k, pos)
    pos3d = jnp.broadcast_to(pos[None, None, :], (3, 2, 16))
    q2, k2 = layers.apply_mrope(q, k, pos3d, sections=(4, 6, 6))
    np.testing.assert_allclose(np.array(q1), np.array(q2), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.array(k1), np.array(k2), rtol=1e-5, atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With capacity_factor 1.25 and balanced-ish routing, most tokens must
    be dispatched (gate weights sum near 1)."""
    from repro.models import moe as moe_mod

    cfg = reduced("deepseek-moe-16b")
    key = jax.random.key(0)
    p = moe_mod.moe_init(key, cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                         cfg.n_shared_experts)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 64, cfg.d_model)).astype(np.float32))
    out, aux = moe_mod.moe_apply(p, x, top_k=cfg.experts_per_token)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0.5  # aux ~ 1 for near-uniform routing


def test_param_count_sanity():
    """Analytic parameter counts must be within 25% of actual pytree size
    for the reduced configs (catches config/assembly drift)."""
    for arch in ["qwen3-4b", "deepseek-moe-16b", "xlstm-125m"]:
        cfg = reduced(arch)
        m = build_model(cfg)
        params = m.init(jax.random.key(0))
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        ratio = actual / analytic
        assert 0.75 < ratio < 1.35, f"{arch}: analytic {analytic} vs actual {actual}"


def test_int8_kv_cache_matches_bf16_decode():
    """Quantized decode cache (§Perf Q1): logits must track the f32-cache
    decode path closely across a full decode rollout."""
    cfg = reduced("qwen3-4b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 24)), jnp.int32)
    hidden, _, _ = transformer.forward_full(cfg, params, toks, None,
                                            dtype=jnp.float32, remat=False)
    want = transformer.logits_from_hidden(cfg, params, hidden[:, -1:])[:, 0]
    cache = m.init_decode_cache(2, 32, quantized=True)
    got = None
    for i in range(24):
        got, cache = transformer.decode_step(cfg, params, toks[:, i], cache,
                                             jnp.int32(i), None,
                                             dtype=jnp.float32)
    corr = float(np.corrcoef(np.array(got).ravel(), np.array(want).ravel())[0, 1])
    assert corr > 0.999, corr
    assert float(jnp.max(jnp.abs(got - want))) < 0.05
