"""Import-or-degrade shim for ``hypothesis``.

Property tests use hypothesis when it is installed (it is in the ``dev``
extras). When it is absent — minimal CI images, the bare TPU container —
importing it at module top level used to *error the whole collection*.
This shim keeps every non-property test running: ``@given`` tests become
individual skips instead of collection errors.

Usage in test modules::

    from hypothesis_compat import given, settings, st
"""

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            # *args so the stub fits both plain functions and methods;
            # pytest ignores varargs during fixture resolution, so the
            # original hypothesis parameter names never look like fixtures.
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (dev extra)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn

        return deco

    class _StrategyStub:
        """Absorbs any strategy construction/chaining at module import."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, _name):
            return self

    st = _StrategyStub()
