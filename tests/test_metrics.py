"""NMI/ARI metric correctness + hypothesis invariants."""

import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import metrics

labels = st.lists(st.integers(0, 5), min_size=5, max_size=60)


class TestNMI:
    def test_perfect_agreement(self):
        a = np.array([0, 0, 1, 1, 2, 2])
        assert metrics.nmi(a, a) == 1.0

    def test_permutation_invariant(self):
        a = np.array([0, 0, 1, 1, 2, 2])
        b = np.array([2, 2, 0, 0, 1, 1])
        assert abs(metrics.nmi(a, b) - 1.0) < 1e-12

    def test_independent_labels_near_zero(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 4, 20000)
        b = rng.integers(0, 4, 20000)
        assert metrics.nmi(a, b) < 0.01

    @given(a=labels)
    @settings(max_examples=30, deadline=None)
    def test_range(self, a):
        a = np.array(a)
        b = np.roll(a, 1)
        v = metrics.nmi(a, b)
        assert 0.0 <= v <= 1.0

    @given(a=labels, b=labels)
    @settings(max_examples=30, deadline=None)
    def test_symmetry(self, a, b):
        n = min(len(a), len(b))
        a, b = np.array(a[:n]), np.array(b[:n])
        assert abs(metrics.nmi(a, b) - metrics.nmi(b, a)) < 1e-10


class TestARI:
    def test_perfect(self):
        a = np.array([0, 0, 1, 1])
        assert metrics.ari(a, a) == 1.0

    def test_known_value(self):
        # classic example: sklearn-adjusted_rand_score([0,0,1,1],[0,0,1,2]) = 0.5714...
        a = np.array([0, 0, 1, 1])
        b = np.array([0, 0, 1, 2])
        assert abs(metrics.ari(a, b) - 0.5714285714285714) < 1e-10

    def test_independent_near_zero(self):
        rng = np.random.default_rng(1)
        a = rng.integers(0, 4, 20000)
        b = rng.integers(0, 4, 20000)
        assert abs(metrics.ari(a, b)) < 0.01

    @given(a=labels, b=labels)
    @settings(max_examples=30, deadline=None)
    def test_symmetry_and_range(self, a, b):
        n = min(len(a), len(b))
        a, b = np.array(a[:n]), np.array(b[:n])
        v = metrics.ari(a, b)
        assert -1.0 <= v <= 1.0
        assert abs(v - metrics.ari(b, a)) < 1e-10


class TestUnassigned:
    def test_negative_labels_dropped(self):
        a = np.array([0, 0, 1, 1, -1])
        b = np.array([0, 0, 1, 1, 1])
        assert metrics.nmi(a, b) == 1.0
        assert metrics.ari(a, b) == 1.0


class TestDegenerateBoundaries:
    """Single-cluster and all-filtered inputs score 0.0 — never NaN.

    These are reachable in overlap mode: outlier filtering can drop every
    point, and a forcing threshold can leave one populated cluster.
    """

    def test_all_points_filtered(self):
        a = np.array([-1, -1, -1])
        b = np.array([0, 1, 2])
        assert metrics.nmi(a, b) == 0.0
        assert metrics.ari(a, b) == 0.0

    def test_single_cluster_both(self):
        a = np.array([0, 0, 0, 0])
        assert metrics.nmi(a, a) == 0.0
        assert metrics.ari(a, a) == 0.0

    def test_single_cluster_vs_split(self):
        a = np.array([0, 0, 0, 0])
        b = np.array([0, 0, 1, 1])
        v = metrics.nmi(a, b)
        assert 0.0 <= v <= 1.0 and np.isfinite(v)
        assert np.isfinite(metrics.ari(a, b))

    def test_single_surviving_point(self):
        a = np.array([0, -1, -1])
        b = np.array([1, -1, -1])
        assert metrics.nmi(a, b) == 0.0
        assert metrics.ari(a, b) == 0.0

    def test_all_singletons(self):
        a = np.arange(5)
        assert metrics.ari(a, a) == 0.0  # no within-cluster pairs: chance

    def test_no_nan_on_adversarial_pairs(self):
        cases = [
            (np.array([], np.int64), np.array([], np.int64)),
            (np.array([0]), np.array([0])),
            (np.array([0, 0]), np.array([0, 1])),
            (np.array([-1, 0]), np.array([0, -1])),
        ]
        for a, b in cases:
            assert np.isfinite(metrics.nmi(a, b))
            assert np.isfinite(metrics.ari(a, b))


class TestOmegaIndex:
    def test_hand_computed_contingency(self):
        # 3 points, pairs (0,1) (0,2) (1,2).
        # a: shared counts 1, 0, 1 -> t_a = [1, 2]/3
        # b: shared counts 1, 0, 0 -> t_b = [2, 1]/3
        # agree on (0,1) and (0,2): A = 2/3
        # expected = (1/3)(2/3) + (2/3)(1/3) = 4/9
        # omega = (2/3 - 4/9) / (1 - 4/9) = 0.4
        a = np.array([[1, 0], [1, 1], [0, 1]], bool)
        b = np.array([[1, 0], [1, 0], [0, 1]], bool)
        assert abs(metrics.omega_index(a, b) - 0.4) < 1e-12

    def test_perfect_agreement_with_overlap(self):
        a = np.array([[1, 0], [1, 1], [0, 1], [0, 0]], bool)
        assert metrics.omega_index(a, a) == 1.0

    def test_reduces_to_ari_on_disjoint(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 3, 60)
        b = rng.integers(0, 3, 60)
        assert abs(metrics.omega_index(a, b) - metrics.ari(a, b)) < 1e-10

    def test_label_vectors_accepted(self):
        a = np.array([0, 0, 1, 1, -1])
        mem = metrics.membership_from_labels(a)
        assert metrics.omega_index(a, mem) == 1.0

    def test_chance_level_near_zero(self):
        rng = np.random.default_rng(1)
        a = rng.random((300, 4)) < 0.3
        b = rng.random((300, 4)) < 0.3
        assert abs(metrics.omega_index(a, b)) < 0.05

    def test_point_count_mismatch_raises(self):
        import pytest

        with pytest.raises(ValueError, match="mismatch"):
            metrics.omega_index(np.zeros((3, 2), bool), np.zeros((4, 2), bool))


class TestOverlapF1:
    def test_perfect(self):
        a = np.array([[1, 0], [1, 1], [0, 1]], bool)
        assert metrics.overlap_f1(a, a) == 1.0

    def test_hand_computed(self):
        # true cluster 0 = {0,1}, cluster 1 = {2,3}
        # pred cluster 0 = {0,1,2} -> F1 vs t0 = 2*2/(2+3) = 0.8,
        #                             F1 vs t1 = 2*1/(2+3) = 0.4
        # forward (weights 2,2): best for t0 = 0.8, t1 = 0.4 -> 0.6
        # reverse (single pred cluster): best = 0.8
        true = np.array([[1, 0], [1, 0], [0, 1], [0, 1]], bool)
        pred = np.array([[1], [1], [1], [0]], bool)
        expect = 0.5 * (0.6 + 0.8)
        assert abs(metrics.overlap_f1(pred, true) - expect) < 1e-12

    def test_empty_prediction(self):
        true = np.array([[1, 0], [0, 1]], bool)
        pred = np.zeros((2, 2), bool)
        assert metrics.overlap_f1(pred, true) == 0.0

    def test_membership_from_labels_shapes(self):
        m = metrics.membership_from_labels(np.array([0, 2, -1]), k=4)
        assert m.shape == (3, 4)
        assert m.sum() == 2 and not m[2].any()


class TestCoclusterScores:
    def test_keys_and_averaging(self):
        a = np.array([0, 0, 1, 1])
        s = metrics.cocluster_scores(a, a, a, a)
        assert s["nmi"] == 1.0 and s["ari"] == 1.0
        assert set(s) == {"row_nmi", "col_nmi", "row_ari", "col_ari", "nmi", "ari"}
