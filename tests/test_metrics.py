"""NMI/ARI metric correctness + hypothesis invariants."""

import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import metrics

labels = st.lists(st.integers(0, 5), min_size=5, max_size=60)


class TestNMI:
    def test_perfect_agreement(self):
        a = np.array([0, 0, 1, 1, 2, 2])
        assert metrics.nmi(a, a) == 1.0

    def test_permutation_invariant(self):
        a = np.array([0, 0, 1, 1, 2, 2])
        b = np.array([2, 2, 0, 0, 1, 1])
        assert abs(metrics.nmi(a, b) - 1.0) < 1e-12

    def test_independent_labels_near_zero(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 4, 20000)
        b = rng.integers(0, 4, 20000)
        assert metrics.nmi(a, b) < 0.01

    @given(a=labels)
    @settings(max_examples=30, deadline=None)
    def test_range(self, a):
        a = np.array(a)
        b = np.roll(a, 1)
        v = metrics.nmi(a, b)
        assert 0.0 <= v <= 1.0

    @given(a=labels, b=labels)
    @settings(max_examples=30, deadline=None)
    def test_symmetry(self, a, b):
        n = min(len(a), len(b))
        a, b = np.array(a[:n]), np.array(b[:n])
        assert abs(metrics.nmi(a, b) - metrics.nmi(b, a)) < 1e-10


class TestARI:
    def test_perfect(self):
        a = np.array([0, 0, 1, 1])
        assert metrics.ari(a, a) == 1.0

    def test_known_value(self):
        # classic example: sklearn-adjusted_rand_score([0,0,1,1],[0,0,1,2]) = 0.5714...
        a = np.array([0, 0, 1, 1])
        b = np.array([0, 0, 1, 2])
        assert abs(metrics.ari(a, b) - 0.5714285714285714) < 1e-10

    def test_independent_near_zero(self):
        rng = np.random.default_rng(1)
        a = rng.integers(0, 4, 20000)
        b = rng.integers(0, 4, 20000)
        assert abs(metrics.ari(a, b)) < 0.01

    @given(a=labels, b=labels)
    @settings(max_examples=30, deadline=None)
    def test_symmetry_and_range(self, a, b):
        n = min(len(a), len(b))
        a, b = np.array(a[:n]), np.array(b[:n])
        v = metrics.ari(a, b)
        assert -1.0 <= v <= 1.0
        assert abs(v - metrics.ari(b, a)) < 1e-10


class TestUnassigned:
    def test_negative_labels_dropped(self):
        a = np.array([0, 0, 1, 1, -1])
        b = np.array([0, 0, 1, 1, 1])
        assert metrics.nmi(a, b) == 1.0
        assert metrics.ari(a, b) == 1.0


class TestCoclusterScores:
    def test_keys_and_averaging(self):
        a = np.array([0, 0, 1, 1])
        s = metrics.cocluster_scores(a, a, a, a)
        assert s["nmi"] == 1.0 and s["ari"] == 1.0
        assert set(s) == {"row_nmi", "col_nmi", "row_ari", "col_ari", "nmi", "ari"}
