"""Hypothesis property tests on system-level invariants (beyond the
per-module suites): MoE conservation, signature invariances, sharding
policy totality, analytic-model sanity."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs.base import SHAPES, get_arch, list_archs
from repro.core import merging
from repro.launch.analytic import analytic_cell
from repro.launch.roofline import collective_bytes_from_hlo
from repro.models import moe as moe_mod


class TestMoEInvariants:
    @given(top_k=st.integers(1, 3), seed=st.integers(0, 5))
    @settings(max_examples=8, deadline=None)
    def test_identity_experts_preserve_input(self, top_k, seed):
        """With all experts = identity-ish zero mapping, output must be the
        shared-expert response only; with zero shared too, output ~ 0 —
        i.e. dispatch/combine conserve and never hallucinate mass."""
        d, e, ff = 16, 4, 8
        key = jax.random.key(seed)
        p = moe_mod.moe_init(key, d, ff, e, 0)
        p = jax.tree.map(jnp.zeros_like, p)  # zero experts + router
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(2, 8, d)).astype(np.float32))
        out, aux = moe_mod.moe_apply(p, x, top_k=top_k)
        assert float(jnp.max(jnp.abs(out))) < 1e-5

    @given(cf=st.sampled_from([0.5, 1.0, 2.0]))
    @settings(max_examples=6, deadline=None)
    def test_combine_weights_bounded_by_gates(self, cf):
        """Dropped tokens contribute zero; kept tokens' gate weights sum
        to at most 1 (renormalized top-k)."""
        d, e, ff = 12, 4, 8
        p = moe_mod.moe_init(jax.random.key(0), d, ff, e, 0)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(1, 16, d)).astype(np.float32))
        out, _ = moe_mod.moe_apply(p, x, top_k=2, capacity_factor=cf)
        assert bool(jnp.all(jnp.isfinite(out)))


class TestSignatureInvariances:
    @given(scale=st.floats(0.5, 4.0), seed=st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_scale_invariance(self, scale, seed):
        """Signatures are unit-normalized: scaling the data must not change
        them (the cross-block alignment relies on this)."""
        rng = np.random.default_rng(seed)
        feats = jnp.asarray(rng.normal(size=(2, 20, 8)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 3, (2, 20)), jnp.int32)
        s1, c1 = merging.atom_signatures(feats, labels, 3)
        s2, c2 = merging.atom_signatures(feats * scale, labels, 3)
        np.testing.assert_allclose(np.array(s1), np.array(s2),
                                   rtol=1e-3, atol=1e-4)
        np.testing.assert_array_equal(np.array(c1), np.array(c2))

    @given(shift=st.floats(-5.0, 5.0))
    @settings(max_examples=10, deadline=None)
    def test_feature_shift_invariance(self, shift):
        """Per-block centering: adding a constant to all features must not
        change signatures (grand-mean direction removal)."""
        rng = np.random.default_rng(3)
        feats = jnp.asarray(rng.normal(size=(1, 30, 6)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 2, (1, 30)), jnp.int32)
        s1, _ = merging.atom_signatures(feats, labels, 2)
        s2, _ = merging.atom_signatures(feats + shift, labels, 2)
        np.testing.assert_allclose(np.array(s1), np.array(s2),
                                   rtol=1e-3, atol=1e-3)


class TestAnalyticModel:
    @pytest.mark.parametrize("arch", [a for a in list_archs()
                                      if a != "lamc-coclustering"])
    def test_all_cells_finite_positive(self, arch):
        cfg = get_arch(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                continue
            ac = analytic_cell(cfg, shape, chips=256)
            assert ac.flops_global > 0
            assert ac.hbm_bytes_per_dev > 0
            assert ac.coll_bytes_per_dev >= 0
            assert np.isfinite(ac.flops_global)

    def test_train_flops_exceed_prefill(self):
        cfg = get_arch("qwen3-4b")
        tr = analytic_cell(cfg, SHAPES["train_4k"], 256)
        # same tokens forward-only would be 1/4 of train (remat + backward)
        pf_like = dataclasses.replace(SHAPES["train_4k"], kind="prefill")
        pf = analytic_cell(cfg, pf_like, 256)
        assert tr.flops_global > 3.5 * pf.flops_global


class TestHLOCensusParser:
    def test_parses_collective_shapes(self):
        hlo = """
  %ag = f32[16,128]{1,0} all-gather(f32[16,8]{1,0} %x), replica_groups={}
  %ar.1 = bf16[4,4]{1,0} all-reduce(bf16[4,4]{1,0} %y), to_apply=%add
  %a2a = (f32[2,2]{1,0}) all-to-all(f32[2,2]{1,0} %z)
"""
        out = collective_bytes_from_hlo(hlo)
        assert out["all-gather"] == 16 * 128 * 4
        assert out["all-reduce"] == 4 * 4 * 2 * 2  # 2x for ring AR
        assert out["all-to-all"] == 2 * 2 * 4
        assert out["total"] == sum(v for k, v in out.items() if k != "total")

    def test_ignores_done_halves(self):
        hlo = """
  %s = f32[8]{0} all-gather-start(f32[1]{0} %x)
  %d = f32[8]{0} all-gather-done(f32[8]{0} %s)
"""
        out = collective_bytes_from_hlo(hlo)
        assert out.get("all-gather", 0) == 8 * 4  # counted once


# Sharding policy totality: every arch's param tree gets a valid spec
# (runs in a subprocess: needs its own multi-device XLA_FLAGS).
@pytest.mark.slow
def test_sharding_policy_total_subprocess():
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.configs.base import get_arch, list_archs
        from repro.launch.steps import padded_cfg
        from repro.models import build_model
        from repro.runtime import shardings as sh
        from repro.runtime.shardings import MeshAxes
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ax = MeshAxes(data=("data",), model="model")
        for name in list_archs():
            if name == "lamc-coclustering":
                continue
            cfg = padded_cfg(get_arch(name))
            m = build_model(cfg)
            ps = jax.eval_shape(lambda m=m: m.init(jax.random.key(0)))
            specs = sh.param_specs(cfg, ps, mesh, ax)
            # every spec must be applicable: dims divide or are None
            import jax.tree_util as jtu
            for (path, leaf), (_, spec) in zip(
                    jtu.tree_flatten_with_path(ps)[0],
                    jtu.tree_flatten_with_path(
                        specs, is_leaf=lambda x: isinstance(
                            x, jax.sharding.PartitionSpec))[0]):
                for dim, s in zip(leaf.shape, tuple(spec) + (None,) * 9):
                    if s is None:
                        continue
                    size = 1
                    for a in (s if isinstance(s, tuple) else (s,)):
                        size *= mesh.shape[a]
                    assert dim % size == 0, (name, path, leaf.shape, spec)
        print("SHARDING_TOTAL_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=env,
                         cwd=os.path.dirname(os.path.dirname(
                             os.path.abspath(__file__))))
    assert res.returncode == 0, res.stderr[-2000:]
    assert "SHARDING_TOTAL_OK" in res.stdout
