"""Fault tolerance end-to-end: the recovery loop itself, kill-and-resume
equivalence for the streaming fit, elastic restore on a different device
count, and the paper's statistical fault budget (T_p) tested
differentially against real injected block failures (DESIGN.md §12).

The recovery-equivalence invariant pinned here: with equal seeds and the
same stream, a fit interrupted by ``SimulatedFailure`` (in-process) or
SIGKILL (subprocess) and resumed from its latest ``FitState`` checkpoint
produces a **bit-identical** ``CoclusterModel`` to the uninterrupted run.
"""

import dataclasses
import importlib
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro import checkpoint as ckpt
from repro.core import probability as prob
from repro.core.lamc import LAMCConfig, lamc_cocluster
from repro.core.metrics import nmi
from repro.core.partition import make_plan
from repro.data import planted_cocluster_matrix
from repro.runtime.fault_tolerance import FailureInjector, SimulatedFailure, run_with_recovery

sfit = importlib.import_module("repro.streaming.fit")

MODEL_FIELDS = ("row_labels", "col_labels", "row_votes", "col_votes",
                "row_sigs", "col_sigs", "row_mean", "col_mean",
                "anchor_rows", "anchor_cols")


def assert_models_bit_identical(a, b):
    for name in MODEL_FIELDS:
        x, y = np.asarray(getattr(a, name)), np.asarray(getattr(b, name))
        assert x.dtype == y.dtype, f"{name}: dtype {x.dtype} vs {y.dtype}"
        assert np.array_equal(x, y), f"{name} differs"


# ---------------------------------------------------------------------------
# FailureInjector (satellite: typed per-instance mutable field)
# ---------------------------------------------------------------------------


class TestFailureInjector:
    def test_fires_once_per_step(self):
        inj = FailureInjector(fail_at_steps=(2,))
        inj.maybe_fail(0)
        with pytest.raises(SimulatedFailure, match="step 2"):
            inj.maybe_fail(2)
        inj.maybe_fail(2)  # retried step passes

    def test_fired_sets_are_per_instance(self):
        # the dataclass field must be default_factory, not a shared class set
        a = FailureInjector(fail_at_steps=(1,))
        b = FailureInjector(fail_at_steps=(1,))
        with pytest.raises(SimulatedFailure):
            a.maybe_fail(1)
        with pytest.raises(SimulatedFailure):
            b.maybe_fail(1)  # a's firing must not consume b's


# ---------------------------------------------------------------------------
# run_with_recovery loop properties
# ---------------------------------------------------------------------------


def _drive_loop(tmp_path, *, total, save_every, fail_at=(), max_retries=8):
    """Integer-counter harness over the real checkpoint machinery.

    Returns (final_state_value, loop_stats, save_steps, restore_steps).
    """
    d = str(tmp_path)
    inj = FailureInjector(fail_at_steps=tuple(fail_at))
    saves, restores = [], []

    def step_fn(t, s):
        out = {"v": np.asarray(s["v"] + 1, np.int64)}
        inj.maybe_fail(t)
        return out

    def save_fn(s, st):
        saves.append(s)
        ckpt.save(d, s, st, extra_meta={"step": s})

    def restore_state(step):
        restores.append(step)
        if step < 0:
            return {"v": np.asarray(0, np.int64)}
        tree, _ = ckpt.restore(d, step, {"v": np.asarray(0, np.int64)})
        return tree

    state, stats = run_with_recovery(
        total_steps=total, step_fn=step_fn,
        state={"v": np.asarray(0, np.int64)},
        ckpt_dir=d, save_every=save_every, restore_state=restore_state,
        max_retries=max_retries, save_fn=save_fn)
    return int(state["v"]), stats, saves, restores


class TestRunWithRecovery:
    def test_monotonic_progress_and_failure_count(self, tmp_path):
        v, stats, saves, restores = _drive_loop(
            tmp_path, total=7, save_every=2, fail_at=(0, 3, 5))
        assert v == 7 and stats["final_step"] == 7
        assert stats["failures"] == 3
        # restores land on latest_step at failure time (or -1 pre-first-save)
        assert restores == [-1, 2, 4]

    def test_no_duplicate_save_when_final_step_hits_save_every(self, tmp_path):
        # total=6, save_every=3: step 6 is both a periodic save and the
        # final step — exactly one write must happen for it
        v, stats, saves, _ = _drive_loop(tmp_path, total=6, save_every=3)
        assert v == 6
        assert saves == [3, 6]
        assert ckpt.available_steps(str(tmp_path)) == [3, 6]

    def test_bounded_retries(self, tmp_path):
        class _AlwaysFail:
            def maybe_fail(self, t):
                raise SimulatedFailure("always")

        inj = _AlwaysFail()

        def step_fn(t, s):
            inj.maybe_fail(t)
            return s

        with pytest.raises(RuntimeError, match="exceeded 3 retries"):
            run_with_recovery(
                total_steps=5, step_fn=step_fn, state={"v": np.asarray(0)},
                ckpt_dir=str(tmp_path), save_every=2,
                restore_state=lambda s: {"v": np.asarray(max(s, 0))},
                max_retries=3)

    def test_stream_driven_termination_saves_tail(self, tmp_path):
        # total_steps=None: StopIteration ends the loop; the 5th step is
        # not a save_every multiple, so the post-loop save must cover it
        d = str(tmp_path)
        items = iter(range(5))
        saves = []

        def step_fn(t, s):
            next(items)
            return {"v": np.asarray(s["v"] + 1, np.int64)}

        def save_fn(s, st):
            saves.append(s)
            ckpt.save(d, s, st, extra_meta={"step": s})

        state, stats = run_with_recovery(
            total_steps=None, step_fn=step_fn,
            state={"v": np.asarray(0, np.int64)},
            ckpt_dir=d, save_every=2, save_fn=save_fn)
        assert int(state["v"]) == 5 and stats["final_step"] == 5
        assert saves == [2, 4, 5]

    def test_sized_run_ends_early_is_loud(self, tmp_path):
        def step_fn(t, s):
            raise StopIteration

        with pytest.raises(StopIteration):
            run_with_recovery(
                total_steps=3, step_fn=step_fn, state=None,
                ckpt_dir=str(tmp_path), save_every=2,
                save_fn=lambda s, st: None)

    def test_stale_checkpoint_in_dirty_dir_not_restored(self, tmp_path):
        # a fresh run into a directory holding a previous run's step 50
        # must not jump to it — pre-first-save recovery restarts clean
        ckpt.save(str(tmp_path), 50, {"v": np.asarray(999, np.int64)})
        v, stats, saves, restores = _drive_loop(
            tmp_path, total=5, save_every=2, fail_at=(1,))
        assert v == 5 and stats["final_step"] == 5
        assert restores == [-1]

    def test_stale_checkpoint_not_restored_after_own_save(self, tmp_path):
        # after this run's first save, recovery lands on *that* save, not
        # the stale higher step left over in the directory
        ckpt.save(str(tmp_path), 50, {"v": np.asarray(999, np.int64)})
        v, stats, saves, restores = _drive_loop(
            tmp_path, total=5, save_every=2, fail_at=(3,))
        assert v == 5 and stats["final_step"] == 5
        assert restores == [2]

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 12), st.integers(1, 5),
           st.sets(st.integers(0, 11), max_size=4))
    def test_property_progress_failures_saves(self, tmp_path_factory,
                                              total, save_every, fail_set):
        tmp_path = tmp_path_factory.mktemp("loop")
        fail_at = tuple(s for s in fail_set if s < total)
        v, stats, saves, _ = _drive_loop(
            tmp_path, total=total, save_every=save_every, fail_at=fail_at,
            max_retries=len(fail_at) + 2)
        assert v == total == stats["final_step"]       # monotonic progress
        assert stats["failures"] == len(fail_at)       # every failure counted
        assert saves == sorted(set(saves))             # no duplicate saves
        assert saves[-1] == total                      # final state durable
        assert ckpt.latest_step(str(tmp_path)) == total


# ---------------------------------------------------------------------------
# kill-and-resume equivalence for the streaming fit (tentpole invariant)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_stream():
    rng = np.random.default_rng(0)
    data = planted_cocluster_matrix(rng, 400, 360, k=4, d=3, signal=3.5,
                                    noise=0.4)
    cfg = sfit.StreamConfig(n_row_clusters=4, n_col_clusters=3, col_blocks=2,
                            chunk_resamples=1, signature_dim=32,
                            anchor_rows=32, seed=11, merge_restarts=2)
    return data, cfg


def _chunks(data, rows=100):
    return sfit.iter_row_chunks(data.matrix, rows)


class TestKillAndResume:
    def test_injected_failures_are_bit_identical(self, small_stream, tmp_path):
        data, cfg = small_stream
        m0, _ = sfit.fit(_chunks(data), cfg)
        inj = FailureInjector(fail_at_steps=(1, 3))
        m1, _ = sfit.fit(_chunks(data), cfg, ckpt_dir=str(tmp_path),
                         save_every=2, failure_injector=inj)
        assert inj._fired == {1, 3}
        assert_models_bit_identical(m0, m1)

    def test_empty_chunks_in_recovery_stream_terminate(self, small_stream,
                                                       tmp_path):
        # empty chunks are not steps: the replay cursor must skip them
        # instead of buffering one and spinning on it forever (also pins
        # the trailing-empty StopIteration path)
        data, cfg = small_stream
        n = data.matrix.shape[1]

        def with_empties():
            for i, chunk in enumerate(_chunks(data)):
                if i % 2 == 0:
                    yield np.zeros((0, n), data.matrix.dtype)
                yield chunk
            yield np.zeros((0, n), data.matrix.dtype)

        m0, _ = sfit.fit(_chunks(data), cfg)
        inj = FailureInjector(fail_at_steps=(1,))
        m1, stats = sfit.fit(with_empties(), cfg, ckpt_dir=str(tmp_path),
                             save_every=2, failure_injector=inj)
        assert stats.chunks == 4
        assert_models_bit_identical(m0, m1)

    def test_failure_before_first_checkpoint_restarts_clean(self, small_stream,
                                                            tmp_path):
        data, cfg = small_stream
        m0, _ = sfit.fit(_chunks(data), cfg)
        inj = FailureInjector(fail_at_steps=(0,))
        m1, _ = sfit.fit(_chunks(data), cfg, ckpt_dir=str(tmp_path),
                         save_every=2, failure_injector=inj)
        assert_models_bit_identical(m0, m1)

    def test_cross_process_style_resume(self, small_stream, tmp_path):
        # first "process": dies (exception) after checkpointing 2 chunks
        data, cfg = small_stream
        d = str(tmp_path)
        m0, _ = sfit.fit(_chunks(data), cfg)
        with pytest.raises(SimulatedFailure):
            f = sfit.StreamingCocluster(cfg)
            for t, chunk in enumerate(_chunks(data)):
                f.partial_fit(chunk)
                if (t + 1) % 2 == 0:
                    sfit.save_fit_state(d, f)
                if t == 2:
                    raise SimulatedFailure("poof")
        # second "process": resumes from the committed state and finishes
        m1, stats = sfit.fit(_chunks(data), cfg, resume_from=d,
                             ckpt_dir=d, save_every=2)
        assert_models_bit_identical(m0, m1)
        assert stats.chunks == 4

    def test_resume_nothing_committed_is_loud(self, small_stream, tmp_path):
        data, cfg = small_stream
        with pytest.raises(FileNotFoundError, match="nothing to resume"):
            sfit.fit(_chunks(data), cfg, resume_from=str(tmp_path))

    def test_resume_config_mismatch_is_loud(self, small_stream, tmp_path):
        data, cfg = small_stream
        d = str(tmp_path)
        f = sfit.StreamingCocluster(cfg)
        f.partial_fit(next(iter(_chunks(data))))
        sfit.save_fit_state(d, f)
        other = dataclasses.replace(cfg, seed=cfg.seed + 1)
        with pytest.raises(ValueError, match="seed"):
            sfit.load_fit_state(d, other)

    def test_resume_different_stream_is_loud(self, small_stream, tmp_path):
        data, cfg = small_stream
        d = str(tmp_path)
        f = sfit.StreamingCocluster(cfg)
        it = _chunks(data)
        f.partial_fit(next(it))
        f.partial_fit(next(it))
        sfit.save_fit_state(d, f)
        # replay with a different chunking: skip validation must object
        with pytest.raises(ValueError, match="same stream"):
            sfit.fit(sfit.iter_row_chunks(data.matrix, 80), cfg,
                     resume_from=d)

    def test_failure_injector_without_ckpt_is_loud(self, small_stream):
        data, cfg = small_stream
        with pytest.raises(ValueError, match="no checkpoint"):
            sfit.fit(_chunks(data), cfg,
                     failure_injector=FailureInjector(fail_at_steps=(1,)))

    def test_corrupt_checkpoint_never_restores_silently(self, small_stream,
                                                        tmp_path):
        data, cfg = small_stream
        d = str(tmp_path)
        f = sfit.StreamingCocluster(cfg)
        for t, chunk in enumerate(_chunks(data)):
            f.partial_fit(chunk)
            if t == 1:
                break
        path = sfit.save_fit_state(d, f)
        # flip bytes inside the committed payload
        npz = os.path.join(path, "arrays.npz")
        blob = bytearray(open(npz, "rb").read())
        blob[len(blob) // 2] ^= 0xFF
        with open(npz, "wb") as fh:
            fh.write(blob)
        with pytest.raises(ckpt.CheckpointCorruptError):
            sfit.load_fit_state(d, cfg)


# ---------------------------------------------------------------------------
# real SIGKILL + elastic restore (subprocess: own XLA_FLAGS / real death)
# ---------------------------------------------------------------------------


def _run_subprocess_script(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env,
    )


_COMMON = """
import numpy as np
import importlib

sfit = importlib.import_module("repro.streaming.fit")
from repro.data import planted_cocluster_matrix

rng = np.random.default_rng(0)
data = planted_cocluster_matrix(rng, 512, 400, k=4, d=3, signal=3.5, noise=0.4)
cfg = sfit.StreamConfig(n_row_clusters=4, n_col_clusters=3, col_blocks=2,
                        chunk_resamples=1, signature_dim=32, anchor_rows=32,
                        seed=11, merge_restarts=2)
def chunks():
    return sfit.iter_row_chunks(data.matrix, 128)
"""

_KILL_SCRIPT = textwrap.dedent("""
    import os, signal, sys
    """) + _COMMON + textwrap.dedent("""
    class KillAt:
        def __init__(self, at): self.at = at
        def maybe_fail(self, t):
            if t == self.at:
                os.kill(os.getpid(), signal.SIGKILL)  # no cleanup, no excuses
    sfit.fit(chunks(), cfg, ckpt_dir=sys.argv[1], save_every=2,
             failure_injector=KillAt(2))
    print("UNREACHABLE")
""")

_RESUME_SCRIPT = textwrap.dedent("""
    import sys
    """) + _COMMON + textwrap.dedent("""
    m0, _ = sfit.fit(chunks(), cfg)
    m1, _ = sfit.fit(chunks(), cfg, resume_from=sys.argv[1],
                     ckpt_dir=sys.argv[1], save_every=2)
    for name in %r:
        a, b = np.asarray(getattr(m0, name)), np.asarray(getattr(m1, name))
        assert a.dtype == b.dtype and np.array_equal(a, b), name
    print("RESUME_EQUAL")
""" % (MODEL_FIELDS,))

_ELASTIC_SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    """) + _COMMON + textwrap.dedent("""
    from repro import checkpoint as ckpt
    from repro.runtime import shardings
    from repro.runtime.fault_tolerance import elastic_restore

    assert len(jax.devices()) == 8, jax.devices()
    d = sys.argv[1]

    # "process 1" (conceptually single-device): folds 2 chunks, checkpoints
    it = chunks()
    f = sfit.StreamingCocluster(cfg)
    f.partial_fit(next(it))
    f.partial_fit(next(it))
    sfit.save_fit_state(d, f)

    # "process 2": brings the FitState up sharded across all 8 devices
    step = ckpt.latest_step(d)
    template, extra = ckpt.restore_tree(d, step)
    mesh = jax.make_mesh((8,), ("data",))
    specs = shardings.stream_state_specs(template, mesh)
    tree, extra2 = elastic_restore(d, step, template, mesh, specs)
    assert extra2["kind"] == "stream_fit_state"
    # the big leaves really are distributed: res_vals is (32, 400) -> the
    # 400-col axis splits 8 ways, 50 columns per device
    assert len(tree["res_vals"].sharding.device_set) == 8, (
        tree["res_vals"].sharding)
    f2 = sfit.StreamingCocluster.from_state_tree(
        cfg, tree, chunk_format=extra2["chunk_format"],
        chunk_dtype=extra2["chunk_dtype"])
    for chunk in it:
        f2.partial_fit(chunk)
    m1, _ = f2.finalize()

    m0, _ = sfit.fit(chunks(), cfg)
    for name in %r:
        a, b = np.asarray(getattr(m0, name)), np.asarray(getattr(m1, name))
        assert a.dtype == b.dtype and np.array_equal(a, b), name
    print("ELASTIC_EQUAL")
""" % (MODEL_FIELDS,))


@pytest.mark.slow
def test_sigkill_and_resume_bit_identical(tmp_path):
    """A real SIGKILL mid-fit, then a fresh process resumes to the same
    model — no atexit, no flush, only the committed checkpoints survive."""
    d = str(tmp_path / "ckpt")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    killed = subprocess.run(
        [sys.executable, "-c", _KILL_SCRIPT, d],
        capture_output=True, text=True, timeout=900, cwd=cwd, env=env)
    assert killed.returncode == -9, (killed.returncode, killed.stderr)
    assert "UNREACHABLE" not in killed.stdout
    import repro.checkpoint as _c
    assert _c.latest_step(d) == 2, _c.available_steps(d)

    resumed = subprocess.run(
        [sys.executable, "-c", _RESUME_SCRIPT, d],
        capture_output=True, text=True, timeout=900, cwd=cwd, env=env)
    assert resumed.returncode == 0, (
        f"stdout:\n{resumed.stdout}\nstderr:\n{resumed.stderr}")
    assert "RESUME_EQUAL" in resumed.stdout


@pytest.mark.slow
def test_elastic_restore_on_8_devices(tmp_path):
    """FitState written ungrouped, restored sharded over an 8-device mesh
    (stream_state_specs + elastic_restore), fit continued to bit-identical
    completion."""
    d = str(tmp_path / "ckpt")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    cwd = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    res = subprocess.run(
        [sys.executable, "-c", _ELASTIC_SCRIPT, d],
        capture_output=True, text=True, timeout=900, cwd=cwd, env=env)
    assert res.returncode == 0, (
        f"stdout:\n{res.stdout}\nstderr:\n{res.stderr}")
    assert "ELASTIC_EQUAL" in res.stdout


# ---------------------------------------------------------------------------
# statistical fault budget: the paper's T_p claim, tested differentially
# ---------------------------------------------------------------------------


class TestStatisticalFaultBudget:
    def test_resamples_for_failures_restores_nmi(self):
        """Drop b random blocks per resample; check that the
        ``resamples_for_failures`` bump restores NMI to within tolerance
        of the failure-free run (DESIGN.md §3's resilience budget)."""
        rng = np.random.default_rng(0)
        data = planted_cocluster_matrix(rng, 600, 500, k=5, d=4, signal=3.0,
                                        noise=1.2)
        cfg = LAMCConfig(n_row_clusters=5, n_col_clusters=4, seed=1)
        plan = make_plan(600, 500, min_cocluster_rows=120,
                         min_cocluster_cols=125, workers=4, seed=1, k=5)
        base = dataclasses.replace(plan, t_p=2)
        n_blocks = base.blocks_per_resample
        b = 2  # half the blocks of every resample die

        r0 = lamc_cocluster(data.matrix, cfg, base)
        nmi0 = nmi(np.asarray(r0.row_labels), data.row_labels)

        mask = prob.sample_block_failures(7, base.t_p, n_blocks, b)
        r1 = lamc_cocluster(data.matrix, cfg, base, block_mask=mask)
        nmi_degraded = nmi(np.asarray(r1.row_labels), data.row_labels)

        t_p_rec = prob.resamples_for_failures(base.t_p, n_blocks, b)
        assert t_p_rec > base.t_p
        rec_plan = dataclasses.replace(plan, t_p=t_p_rec)
        mask_rec = prob.sample_block_failures(7, t_p_rec, n_blocks, b)
        r2 = lamc_cocluster(data.matrix, cfg, rec_plan, block_mask=mask_rec)
        nmi_rec = nmi(np.asarray(r2.row_labels), data.row_labels)

        # failures hurt; the budgeted extra resamples buy the quality back
        assert nmi_degraded < nmi0 - 0.1, (nmi0, nmi_degraded)
        assert nmi_rec >= nmi0 - 0.05, (nmi0, nmi_degraded, nmi_rec)

    def test_all_true_mask_is_identity(self):
        rng = np.random.default_rng(3)
        data = planted_cocluster_matrix(rng, 480, 400, k=4, d=4, signal=4.0,
                                        noise=0.5)
        cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4, seed=2)
        plan = make_plan(480, 400, min_cocluster_rows=120,
                         min_cocluster_cols=100, workers=4, seed=2, k=4)
        r0 = lamc_cocluster(data.matrix, cfg, plan)
        full = np.ones((plan.t_p, plan.blocks_per_resample), bool)
        r1 = lamc_cocluster(data.matrix, cfg, plan, block_mask=full)
        assert np.array_equal(np.asarray(r0.row_labels),
                              np.asarray(r1.row_labels))
        assert np.array_equal(np.asarray(r0.col_votes),
                              np.asarray(r1.col_votes))

    def test_block_mask_shape_is_validated(self):
        rng = np.random.default_rng(3)
        data = planted_cocluster_matrix(rng, 480, 400, k=4, d=4)
        cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4, seed=2)
        plan = make_plan(480, 400, min_cocluster_rows=120,
                         min_cocluster_cols=100, workers=4, seed=2, k=4)
        with pytest.raises(ValueError, match="block_mask"):
            lamc_cocluster(data.matrix, cfg, plan,
                           block_mask=np.ones((1, 1), bool))
