"""Sparse-path (BCOO) suite: dense/sparse parity, SpMM kernel vs oracle,
plan-cost density behaviour, and the anchor-gather-order regression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import LAMCConfig, lamc_cocluster, partition, probability
from repro.core import sparse as core_sparse
from repro.core.lamc import anchor_features
from repro.core.metrics import nmi
from repro.core.partition import PartitionPlan
from repro.core.spectral import normalize_bipartite, randomized_svd, scc
from repro.data import planted_cocluster_matrix, to_bcoo
from repro.kernels import ops as kops


@pytest.fixture(scope="module")
def planted():
    rng = np.random.default_rng(0)
    return planted_cocluster_matrix(rng, 240, 200, k=4, d=4,
                                    signal=4.0, noise=0.5, density=0.15)


def _rand_sparse(rng, m, n, density):
    mat = np.where(rng.random((m, n)) < density,
                   rng.normal(size=(m, n)), 0.0).astype(np.float32)
    return mat


class TestBcooHelpers:
    def test_to_bcoo_roundtrip(self, planted):
        a = to_bcoo(planted.matrix)
        np.testing.assert_array_equal(np.asarray(a.todense()), planted.matrix)
        assert a.nse == int((planted.matrix != 0).sum())

    def test_gather_cols_dense(self, planted):
        a = to_bcoo(planted.matrix)
        cols = jnp.asarray([3, 190, 0, 77])
        out = core_sparse.gather_cols_dense(a, cols)
        np.testing.assert_array_equal(np.asarray(out),
                                      planted.matrix[:, np.array(cols)])

    def test_gather_rows_dense(self, planted):
        a = to_bcoo(planted.matrix)
        rows = jnp.asarray([10, 0, 239])
        out = core_sparse.gather_rows_dense(a, rows)
        np.testing.assert_array_equal(np.asarray(out),
                                      planted.matrix[np.array(rows)])

    def test_abs_degree_sums(self, planted):
        a = to_bcoo(planted.matrix)
        d1, d2 = core_sparse.abs_degree_sums(a)
        np.testing.assert_allclose(np.asarray(d1),
                                   np.abs(planted.matrix).sum(1), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(d2),
                                   np.abs(planted.matrix).sum(0), rtol=1e-5)

    def test_validate_rejects_non_2d(self):
        from jax.experimental import sparse as jsparse
        a3 = jsparse.BCOO.fromdense(jnp.ones((2, 3, 4)))
        with pytest.raises(ValueError, match="2-D"):
            core_sparse.validate_bcoo(a3)


class TestExtractBlocksSparse:
    def test_exact_parity_full_grid(self, planted):
        a = jnp.asarray(planted.matrix)
        a_sp = to_bcoo(planted.matrix)
        plan = PartitionPlan(240, 200, m=2, n=2, phi=120, psi=100, t_p=2, seed=0)
        bd, ri, ci = partition.extract_blocks(a, plan, 1)
        bs, ri2, ci2 = partition.extract_blocks_sparse(a_sp, plan, 1)
        np.testing.assert_array_equal(np.asarray(bd), np.asarray(bs))
        np.testing.assert_array_equal(np.asarray(ri), np.asarray(ri2))
        np.testing.assert_array_equal(np.asarray(ci), np.asarray(ci2))

    def test_exact_parity_with_dropped_rows_cols(self, planted):
        """Non-divisible grid: dropped indices must vanish, not alias."""
        a = jnp.asarray(planted.matrix)
        a_sp = to_bcoo(planted.matrix)
        # 240 % (3*79) and 200 % (3*66) both leave a remainder
        plan = PartitionPlan(240, 200, m=3, n=3, phi=79, psi=66, t_p=1, seed=5)
        bd, _, _ = partition.extract_blocks(a, plan, 0)
        bs, _, _ = partition.extract_blocks_sparse(a_sp, plan, 0)
        np.testing.assert_array_equal(np.asarray(bd), np.asarray(bs))

    @given(density=st.sampled_from([0.01, 0.1, 0.5]), seed=st.integers(0, 5))
    @settings(max_examples=8, deadline=None)
    def test_parity_sweep(self, density, seed):
        rng = np.random.default_rng(seed)
        mat = _rand_sparse(rng, 64, 48, density)
        plan = PartitionPlan(64, 48, m=2, n=2, phi=32, psi=24, t_p=1, seed=seed)
        bd, _, _ = partition.extract_blocks(jnp.asarray(mat), plan, 0)
        bs, _, _ = partition.extract_blocks_sparse(to_bcoo(mat), plan, 0)
        np.testing.assert_array_equal(np.asarray(bd), np.asarray(bs))

    def test_traced_resample_index(self, planted):
        """Must work under jit with a traced resample id (scan in lamc)."""
        a_sp = to_bcoo(planted.matrix)
        plan = PartitionPlan(240, 200, m=2, n=2, phi=120, psi=100, t_p=2, seed=0)
        f = jax.jit(lambda t: partition.extract_blocks_sparse(a_sp, plan, t)[0])
        assert f(jnp.int32(1)).shape == (4, 120, 100)


class TestSpmmKernel:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (200, 300, 130),
                                       (64, 512, 16), (300, 70, 250)])
    @pytest.mark.parametrize("density", [0.01, 0.05, 0.2])
    def test_tiled_kernel_matches_ref(self, m, k, n, density):
        rng = np.random.default_rng(m + k + n)
        mat = _rand_sparse(rng, m, k, density)
        a = to_bcoo(mat)
        b = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        want = np.asarray(kops.spmm(a, b))
        np.testing.assert_allclose(want, mat @ np.asarray(b), atol=1e-3)
        bs = kops.bcoo_to_block_sparse(a, bm=64, bk=64)
        got = np.asarray(kops.spmm_tiled(bs, b, bn=64))
        np.testing.assert_allclose(got, want, atol=1e-3)

    def test_empty_tile_rows_are_zeroed(self):
        """Rows with no nonzeros at all must come out as exact zeros."""
        mat = np.zeros((128, 64), np.float32)
        mat[5, 3] = 2.0      # only the first tile-row is occupied
        b = np.ones((64, 32), np.float32)
        bs = kops.bcoo_to_block_sparse(to_bcoo(mat), bm=32, bk=32)
        out = np.asarray(kops.spmm_tiled(bs, jnp.asarray(b), bn=32))
        np.testing.assert_array_equal(out, mat @ b)

    def test_spmm_transpose(self):
        rng = np.random.default_rng(2)
        mat = _rand_sparse(rng, 90, 110, 0.1)
        b = jnp.asarray(rng.normal(size=(90, 12)).astype(np.float32))
        got = np.asarray(kops.spmm(to_bcoo(mat), b, transpose=True))
        np.testing.assert_allclose(got, mat.T @ np.asarray(b), atol=1e-3)

    def test_sddmm_matches_dense(self):
        rng = np.random.default_rng(3)
        x = rng.normal(size=(40, 9)).astype(np.float32)
        y = rng.normal(size=(55, 9)).astype(np.float32)
        idx = np.stack([rng.integers(0, 40, 200), rng.integers(0, 55, 200)], 1)
        got = np.asarray(kops.sddmm(jnp.asarray(x), jnp.asarray(y),
                                    jnp.asarray(idx)))
        want = (x @ y.T)[idx[:, 0], idx[:, 1]]
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_spmm_ref_is_jittable(self):
        rng = np.random.default_rng(4)
        mat = _rand_sparse(rng, 60, 80, 0.1)
        a = to_bcoo(mat)
        f = jax.jit(lambda b: kops.spmm(a, b))
        out = f(jnp.ones((80, 4), jnp.float32))
        np.testing.assert_allclose(np.asarray(out), mat @ np.ones((80, 4)),
                                   atol=1e-3)


class TestSparseSpectral:
    def test_normalize_bipartite_parity(self, planted):
        a = jnp.asarray(planted.matrix)
        a_sp = to_bcoo(planted.matrix)
        an_d, d1_d, d2_d = normalize_bipartite(a)
        an_s, d1_s, d2_s = normalize_bipartite(a_sp)
        assert core_sparse.is_bcoo(an_s)          # stays sparse
        np.testing.assert_allclose(np.asarray(an_s.todense()),
                                   np.asarray(an_d), atol=1e-5)
        np.testing.assert_allclose(np.asarray(d1_s), np.asarray(d1_d), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(d2_s), np.asarray(d2_d), rtol=1e-5)

    def test_randomized_svd_spmm_subspace(self, planted):
        """Sparse-path singular triplets must match the dense path's."""
        a = jnp.asarray(planted.matrix)
        key = jax.random.key(0)
        u_d, s_d, vt_d = randomized_svd(key, a, rank=5, n_iter=6)
        u_s, s_s, vt_s = randomized_svd(key, to_bcoo(planted.matrix),
                                        rank=5, n_iter=6)
        np.testing.assert_allclose(np.asarray(s_s), np.asarray(s_d), rtol=1e-3)
        # compare subspaces (signs/rotations free): |u_d^T u_s| ~ I
        ov = np.abs(np.asarray(u_d.T @ u_s))
        np.testing.assert_allclose(np.diag(ov), 1.0, atol=1e-2)

    def test_scc_bcoo_matches_dense_labels(self, planted):
        key = jax.random.key(0)
        res_d = scc(key, jnp.asarray(planted.matrix), 4)
        res_s = scc(key, to_bcoo(planted.matrix), 4)
        assert nmi(np.asarray(res_d.row_labels), np.asarray(res_s.row_labels)) > 0.999
        assert nmi(np.asarray(res_d.col_labels), np.asarray(res_s.col_labels)) > 0.999

    def test_scc_bcoo_rejects_exact_svd(self, planted):
        with pytest.raises(ValueError, match="dense"):
            scc(jax.random.key(0), to_bcoo(planted.matrix), 4,
                svd_method="exact")

    def test_ell_operator_products(self, planted):
        """Dual-ELL gather-only products must match dense exactly enough."""
        ell = core_sparse.to_ell(to_bcoo(planted.matrix))
        assert ell.shape == planted.matrix.shape
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(200, 6)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(240, 6)).astype(np.float32))
        np.testing.assert_allclose(np.asarray(core_sparse.ell_matvec(ell, x)),
                                   planted.matrix @ np.asarray(x), atol=1e-4)
        np.testing.assert_allclose(np.asarray(core_sparse.ell_rmatvec(ell, y)),
                                   planted.matrix.T @ np.asarray(y), atol=1e-4)

    def test_scc_ell_matches_dense_labels(self, planted):
        """The amortized repeated-product operator drives scc end to end."""
        key = jax.random.key(0)
        res_d = scc(key, jnp.asarray(planted.matrix), 4)
        res_e = scc(key, core_sparse.to_ell(to_bcoo(planted.matrix)), 4)
        assert nmi(np.asarray(res_d.row_labels), np.asarray(res_e.row_labels)) > 0.999
        assert nmi(np.asarray(res_d.col_labels), np.asarray(res_e.col_labels)) > 0.999

    def test_ell_normalize_parity(self, planted):
        a = jnp.asarray(planted.matrix)
        ell = core_sparse.to_ell(to_bcoo(planted.matrix))
        an_d, d1_d, d2_d = normalize_bipartite(a)
        an_e, d1_e, d2_e = normalize_bipartite(ell)
        assert core_sparse.is_ell(an_e)
        np.testing.assert_allclose(np.asarray(d1_e), np.asarray(d1_d), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(d2_e), np.asarray(d2_d), rtol=1e-5)
        # scaled operator still multiplies correctly
        x = jnp.ones((200, 1), jnp.float32)
        np.testing.assert_allclose(np.asarray(core_sparse.ell_matvec(an_e, x)),
                                   np.asarray(an_d @ x), atol=1e-4)


class TestSparseLAMC:
    def test_e2e_exact_label_parity(self, planted):
        """Acceptance: bcoo pipeline == dense pipeline labels, same seed."""
        a = jnp.asarray(planted.matrix)
        a_sp = to_bcoo(planted.matrix)
        plan = PartitionPlan(240, 200, m=2, n=2, phi=120, psi=100, t_p=2, seed=0)
        base = dict(n_row_clusters=4, n_col_clusters=4,
                    min_cocluster_rows=48, min_cocluster_cols=40)
        out_d = lamc_cocluster(a, LAMCConfig(**base), plan=plan)
        out_s = lamc_cocluster(a_sp, LAMCConfig(**base, input_format="bcoo"),
                               plan=plan)
        np.testing.assert_array_equal(np.asarray(out_d.row_labels),
                                      np.asarray(out_s.row_labels))
        np.testing.assert_array_equal(np.asarray(out_d.col_labels),
                                      np.asarray(out_s.col_labels))
        np.testing.assert_array_equal(np.asarray(out_d.row_votes),
                                      np.asarray(out_s.row_votes))

    def test_e2e_auto_plan_runs(self):
        # easier planting than the parity fixture: the auto plan picks a
        # single-block grid here, whose one-shot full-matrix SCC needs
        # more signal to recover structure at these densities (direct scc
        # on this data scores ~0.45; the vote merge lifts it to ~0.64)
        rng = np.random.default_rng(1)
        data = planted_cocluster_matrix(rng, 240, 200, k=4, d=4,
                                        signal=8.0, noise=0.2, density=0.4)
        cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                         min_cocluster_rows=48, min_cocluster_cols=40,
                         input_format="bcoo")
        out = lamc_cocluster(to_bcoo(data.matrix), cfg)
        assert out.row_labels.shape == (240,)
        # the auto route keeps the single block in its sparse operator form
        assert out.plan.spmm_route == "tiled"
        s = nmi(np.asarray(out.row_labels), data.row_labels)
        assert s > 0.5, s

    def test_format_mismatch_raises(self, planted):
        cfg_sparse = LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                                input_format="bcoo")
        with pytest.raises(ValueError, match="BCOO"):
            lamc_cocluster(jnp.asarray(planted.matrix), cfg_sparse,
                           plan=PartitionPlan(240, 200, 2, 2, 120, 100, 1))
        cfg_dense = LAMCConfig(n_row_clusters=4, n_col_clusters=4)
        with pytest.raises(ValueError, match="input_format"):
            lamc_cocluster(to_bcoo(planted.matrix), cfg_dense,
                           plan=PartitionPlan(240, 200, 2, 2, 120, 100, 1))

    def test_distributed_format_guard(self, planted):
        """distributed_lamc must fail loudly before jit on a format mismatch."""
        from repro.core.distributed import _validate_input_format
        with pytest.raises(ValueError, match="BCOO"):
            _validate_input_format(
                jnp.asarray(planted.matrix),
                LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                           input_format="bcoo"))
        with pytest.raises(ValueError, match="input_format"):
            _validate_input_format(
                to_bcoo(planted.matrix),
                LAMCConfig(n_row_clusters=4, n_col_clusters=4))


class TestAnchorGatherRegression:
    def test_gather_order_identical_output(self, planted):
        """anchor-first gather must equal the old rows-first expression."""
        a = jnp.asarray(planted.matrix)
        anchor_cols = jnp.asarray([5, 60, 199, 0])
        anchor_rows = jnp.asarray([7, 0, 150])
        plan = PartitionPlan(240, 200, m=2, n=2, phi=120, psi=100, t_p=1, seed=0)
        row_idx, col_idx = partition.resample_indices(plan, 0)
        row_sliver, col_sliver = anchor_features(a, anchor_rows, anchor_cols)
        new_row = row_sliver[row_idx]                       # (m, phi, q)
        old_row = a[row_idx][:, :, anchor_cols]             # (m, phi, N) interm.
        np.testing.assert_array_equal(np.asarray(new_row), np.asarray(old_row))
        new_col = col_sliver[:, col_idx]
        old_col = a[anchor_rows][:, col_idx]
        np.testing.assert_array_equal(np.asarray(new_col), np.asarray(old_col))

    def test_anchor_features_sparse_parity(self, planted):
        a = jnp.asarray(planted.matrix)
        a_sp = to_bcoo(planted.matrix)
        kar, kac = jax.random.split(jax.random.key(1))
        from repro.core.merging import anchor_indices
        anchor_rows = anchor_indices(kar, 240, 64)
        anchor_cols = anchor_indices(kac, 200, 64)
        rd, cd = anchor_features(a, anchor_rows, anchor_cols)
        rs, cs = anchor_features(a_sp, anchor_rows, anchor_cols)
        np.testing.assert_array_equal(np.asarray(rd), np.asarray(rs))
        np.testing.assert_array_equal(np.asarray(cd), np.asarray(cs))


class TestSparsePlanCost:
    def test_atom_cost_monotone_in_density(self):
        costs = [probability._atom_cost(512, 512, 8, 4, 16, 8,
                                        density=d)
                 for d in (0.01, 0.05, 0.2, 1.0)]
        assert costs == sorted(costs)
        assert costs[0] < costs[-1]

    def test_exact_svd_ignores_density(self):
        c1 = probability._atom_cost(512, 512, 8, 4, 16, 8,
                                    svd_method="exact", density=0.01)
        c2 = probability._atom_cost(512, 512, 8, 4, 16, 8,
                                    svd_method="exact", density=1.0)
        assert c1 == c2

    def test_plan_cost_monotone_in_density(self):
        # workers=1 so the single-block sparse route is the best plan and
        # its density-scaled cost is what the search surfaces; with many
        # workers a multi-block plan (dense blocks, density-independent
        # cost by construction) can win at every density and the curve
        # legitimately plateaus
        kw = dict(min_cocluster_rows=256, min_cocluster_cols=256,
                  p_thresh=0.95, workers=1, k=8)
        costs = [probability.plan_partition(4096, 4096, density=d, **kw).est_cost
                 for d in (0.01, 0.05, 0.2, 1.0)]
        assert costs == sorted(costs)
        assert costs[0] < costs[-1]

    def test_multiblock_priced_dense(self):
        """Multi-block candidates densify their blocks: est_cost and the
        surfaced route must say so, whatever the density/knob."""
        kw = dict(min_cocluster_rows=256, min_cocluster_cols=256,
                  p_thresh=0.95, workers=8, k=8)
        cand = probability.plan_partition(4096, 4096, density=0.01,
                                          grid_candidates=(4,), **kw)
        assert (cand.m, cand.n) != (1, 1)
        assert cand.spmm_route == "dense"
        sparse_priced = probability.plan_partition(
            4096, 4096, density=1.0, grid_candidates=(4,), **kw)
        assert cand.est_cost == sparse_priced.est_cost  # density-independent

    def test_sparse_speedup_asymmetry(self):
        """The planner's predicted partitioning win must shrink with
        sparsity: (1,1)-grid cost / best-grid cost is the modelled
        speedup, which the paper reports ~83% (dense, exact SVD) vs ~30%
        (sparse). Dense-exact must gain strictly more than sparse."""
        kw = dict(min_cocluster_rows=512, min_cocluster_cols=512,
                  p_thresh=0.9, workers=8, k=8)
        def gain(svd_method, density):
            best = probability.plan_partition(
                8192, 8192, svd_method=svd_method, density=density,
                **kw).est_cost
            full = probability.plan_partition(
                8192, 8192, svd_method=svd_method, density=density,
                grid_candidates=(1,), **kw).est_cost
            return 1.0 - best / full
        dense_gain = gain("exact", 1.0)
        sparse_gain = gain("randomized", 0.01)
        assert dense_gain > sparse_gain, (dense_gain, sparse_gain)


class TestSpmmRouting:
    def test_route_by_density(self):
        """Calibrated crossovers: gathers below, tile GEMMs above."""
        cells = 4096.0 * 2048
        assert probability.spmm_route(0.01, cells) == "dual_ell"
        assert probability.spmm_route(0.05, cells) == "dual_ell"
        assert probability.spmm_route(0.2, cells) == "tiled"
        assert probability.spmm_route(0.95, cells) == "dense"

    def test_route_small_blocks_densify(self):
        """Sub-64x64 blocks never pay back sparse-format prep."""
        assert probability.spmm_route(0.01, 32.0 * 32) == "dense"

    def test_crossover_constant_brackets_bench(self):
        """The published crossover sits inside the measured (0.05, 0.2)
        win/loss bracket and at the cost model's parity point."""
        assert 0.05 < probability.SPMM_ELL_CROSSOVER < 0.2
        cells = 4096.0 * 2048
        below = probability.spmm_costs(0.05, cells)
        above = probability.spmm_costs(0.2, cells)
        assert below["dual_ell"] < below["tiled"]
        assert above["tiled"] < above["dual_ell"]

    def test_atom_cost_pinned_impl(self):
        """Pinning the backend prices it even when it is not cheapest."""
        kw = dict(density=0.2)
        auto = probability._atom_cost(512, 512, 8, 4, 16, 8, **kw)
        ell = probability._atom_cost(512, 512, 8, 4, 16, 8,
                                     spmm_impl="dual_ell", **kw)
        assert auto < ell

    def test_plan_surfaces_route(self):
        """make_plan exposes the per-block dispatch decision."""
        low = partition.make_plan(4096, 4096, min_cocluster_rows=256,
                                  min_cocluster_cols=256, density=0.01)
        high = partition.make_plan(4096, 4096, min_cocluster_rows=256,
                                   min_cocluster_cols=256, density=0.2)
        dense = partition.make_plan(4096, 4096, min_cocluster_rows=256,
                                    min_cocluster_cols=256)
        assert low.spmm_route == "dual_ell"
        assert high.spmm_route == "tiled"
        assert dense.spmm_route == "dense"
        pinned = partition.make_plan(4096, 4096, min_cocluster_rows=256,
                                     min_cocluster_cols=256, density=0.01,
                                     spmm_impl="tiled")
        assert pinned.spmm_route == "tiled"


class TestTiledSpectral:
    def test_randomized_svd_tiled_matches_dense(self, planted):
        """Tiled normal-equations iteration reaches the dense subspace."""
        a = jnp.asarray(planted.matrix)
        key = jax.random.key(0)
        u_d, s_d, _ = randomized_svd(key, a, rank=5, n_iter=6)
        tiled = core_sparse.to_tiled(to_bcoo(planted.matrix), bm=64, bk=64)
        u_t, s_t, _ = randomized_svd(key, tiled, rank=5, n_iter=6)
        np.testing.assert_allclose(np.asarray(s_t), np.asarray(s_d), rtol=1e-3)
        ov = np.abs(np.asarray(u_d.T @ u_t))
        np.testing.assert_allclose(np.diag(ov), 1.0, atol=1e-2)

    def test_normalize_bipartite_tiled_parity(self, planted):
        a = jnp.asarray(planted.matrix)
        tiled = core_sparse.to_tiled(to_bcoo(planted.matrix), bm=64, bk=64)
        an_d, d1_d, d2_d = normalize_bipartite(a)
        an_t, d1_t, d2_t = normalize_bipartite(tiled)
        assert core_sparse.is_tiled(an_t)
        np.testing.assert_allclose(np.asarray(d1_t), np.asarray(d1_d), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(d2_t), np.asarray(d2_d), rtol=1e-5)
        x = jnp.ones((200, 1), jnp.float32)
        from repro.kernels import ops as _kops
        np.testing.assert_allclose(np.asarray(_kops.spmm_tiled(an_t, x)),
                                   np.asarray(an_d @ x), atol=1e-4)

    def test_scc_tiled_matches_dense_labels(self, planted):
        key = jax.random.key(0)
        res_d = scc(key, jnp.asarray(planted.matrix), 4)
        res_t = scc(key, core_sparse.to_tiled(to_bcoo(planted.matrix)), 4)
        assert nmi(np.asarray(res_d.row_labels), np.asarray(res_t.row_labels)) > 0.999
        assert nmi(np.asarray(res_d.col_labels), np.asarray(res_t.col_labels)) > 0.999

    def test_scc_tiled_rejects_exact_svd(self, planted):
        with pytest.raises(ValueError, match="dense"):
            scc(jax.random.key(0), core_sparse.to_tiled(to_bcoo(planted.matrix)),
                4, svd_method="exact")


class TestSpmmImplLAMC:
    def test_multiblock_exact_parity_any_impl(self, planted):
        """Multi-block plans densify their blocks: the knob must not
        perturb the exact dense/sparse label parity."""
        a = jnp.asarray(planted.matrix)
        a_sp = to_bcoo(planted.matrix)
        plan = PartitionPlan(240, 200, m=2, n=2, phi=120, psi=100, t_p=2, seed=0)
        base = dict(n_row_clusters=4, n_col_clusters=4,
                    min_cocluster_rows=48, min_cocluster_cols=40)
        out_d = lamc_cocluster(a, LAMCConfig(**base), plan=plan)
        for impl in ("tiled", "dual_ell", "auto", "dense"):
            out_s = lamc_cocluster(
                a_sp, LAMCConfig(**base, input_format="bcoo", spmm_impl=impl),
                plan=plan)
            np.testing.assert_array_equal(np.asarray(out_d.row_labels),
                                          np.asarray(out_s.row_labels))
            np.testing.assert_array_equal(np.asarray(out_d.col_labels),
                                          np.asarray(out_s.col_labels))

    def test_single_block_operator_path(self):
        """(1,1) plans keep A in sparse-operator form; tiled and dual-ELL
        routes agree with each other and recover the planted structure."""
        rng = np.random.default_rng(1)
        data = planted_cocluster_matrix(rng, 240, 200, k=4, d=4,
                                        signal=8.0, noise=0.2, density=0.4)
        a_sp = to_bcoo(data.matrix)
        plan = PartitionPlan(240, 200, m=1, n=1, phi=240, psi=200, t_p=2,
                             seed=0)
        base = dict(n_row_clusters=4, n_col_clusters=4,
                    min_cocluster_rows=48, min_cocluster_cols=40,
                    input_format="bcoo")
        out_t = lamc_cocluster(a_sp, LAMCConfig(**base, spmm_impl="tiled"),
                               plan=plan)
        out_e = lamc_cocluster(a_sp, LAMCConfig(**base, spmm_impl="dual_ell"),
                               plan=plan)
        assert out_t.plan.spmm_route == "tiled"
        assert out_e.plan.spmm_route == "dual_ell"
        # same operator semantics -> same labels across product backends
        assert nmi(np.asarray(out_t.row_labels),
                   np.asarray(out_e.row_labels)) > 0.99
        assert nmi(np.asarray(out_t.row_labels), data.row_labels) > 0.5

    def test_single_block_subsampling_plan_falls_back(self, planted):
        """A (1,1) plan with phi < M / psi < N subsamples per resample —
        the operator path cannot represent that, so it must fall back to
        the extraction path (bit-identical to spmm_impl='dense')."""
        a_sp = to_bcoo(planted.matrix)
        plan = PartitionPlan(240, 200, m=1, n=1, phi=200, psi=160, t_p=2,
                             seed=0)
        base = dict(n_row_clusters=4, n_col_clusters=4,
                    min_cocluster_rows=48, min_cocluster_cols=40,
                    input_format="bcoo")
        out_auto = lamc_cocluster(a_sp, LAMCConfig(**base), plan=plan)
        out_dense = lamc_cocluster(a_sp, LAMCConfig(**base,
                                                    spmm_impl="dense"),
                                   plan=plan)
        assert out_auto.plan.spmm_route == "dense"
        np.testing.assert_array_equal(np.asarray(out_auto.row_labels),
                                      np.asarray(out_dense.row_labels))

    def test_invalid_impl_raises(self, planted):
        cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                         spmm_impl="csr")
        with pytest.raises(ValueError, match="spmm_impl"):
            lamc_cocluster(jnp.asarray(planted.matrix), cfg,
                           plan=PartitionPlan(240, 200, 2, 2, 120, 100, 1))
        from repro.core.distributed import _validate_input_format
        with pytest.raises(ValueError, match="spmm_impl"):
            _validate_input_format(jnp.asarray(planted.matrix), cfg)

    def test_streaming_config_carries_impl(self):
        from repro.streaming import StreamingCocluster
        from repro.streaming.fit import StreamConfig, stream_config_from_lamc
        lamc_cfg = LAMCConfig(n_row_clusters=4, n_col_clusters=4,
                              spmm_impl="tiled")
        scfg = stream_config_from_lamc(lamc_cfg)
        assert scfg.spmm_impl == "tiled"
        with pytest.raises(ValueError, match="spmm_impl"):
            StreamingCocluster(StreamConfig(n_row_clusters=4,
                                            n_col_clusters=4,
                                            spmm_impl="csr"))


class TestCoverageProbability:
    def test_min_of_axes(self):
        # rows fully covered, cols drop 20 of 100 per resample
        plan = PartitionPlan(90, 100, m=3, n=4, phi=30, psi=20, t_p=1)
        assert partition.coverage_probability(plan, axis="row") == 1.0
        assert partition.coverage_probability(plan, axis="col") == pytest.approx(0.8)
        assert partition.coverage_probability(plan) == pytest.approx(0.8)

    def test_bad_axis_raises(self):
        plan = PartitionPlan(90, 100, m=3, n=4, phi=30, psi=20, t_p=1)
        with pytest.raises(ValueError):
            partition.coverage_probability(plan, axis="diag")
