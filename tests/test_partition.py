"""Partition plan + block extraction invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import partition


def _plan(M=120, N=90, m=3, n=3, t_p=2, seed=0):
    return partition.PartitionPlan(
        n_rows=M, n_cols=N, m=m, n=n, phi=M // m, psi=N // n, t_p=t_p, seed=seed
    )


class TestResampleIndices:
    def test_shapes(self):
        plan = _plan()
        row_idx, col_idx = partition.resample_indices(plan, 0)
        assert row_idx.shape == (3, 40)
        assert col_idx.shape == (3, 30)

    def test_indices_are_disjoint_within_resample(self):
        plan = _plan()
        row_idx, col_idx = partition.resample_indices(plan, 0)
        assert len(np.unique(np.array(row_idx))) == plan.rows_used
        assert len(np.unique(np.array(col_idx))) == plan.cols_used

    def test_deterministic_in_seed_and_resample(self):
        plan = _plan()
        r1, c1 = partition.resample_indices(plan, 3)
        r2, c2 = partition.resample_indices(plan, 3)
        assert np.array_equal(np.array(r1), np.array(r2))
        r3, _ = partition.resample_indices(plan, 4)
        assert not np.array_equal(np.array(r1), np.array(r3))

    def test_traced_resample_index(self):
        """Must work under jit with a traced resample id (scan in lamc)."""
        plan = _plan()
        f = jax.jit(lambda t: partition.resample_indices(plan, t)[0])
        assert f(jnp.int32(1)).shape == (3, 40)


class TestExtractBlocks:
    def test_block_content_matches_indices(self):
        plan = _plan()
        a = jnp.arange(120 * 90, dtype=jnp.float32).reshape(120, 90)
        blocks, row_idx, col_idx = partition.extract_blocks(a, plan, 0)
        assert blocks.shape == (9, 40, 30)
        a_np = np.array(a)
        for i in range(3):
            for j in range(3):
                expect = a_np[np.array(row_idx[i])][:, np.array(col_idx[j])]
                np.testing.assert_array_equal(np.array(blocks[i * 3 + j]), expect)

    @given(
        m=st.sampled_from([1, 2, 4]),
        n=st.sampled_from([1, 2, 4]),
        seed=st.integers(0, 10),
    )
    @settings(max_examples=10, deadline=None)
    def test_every_element_appears_exactly_once(self, m, n, seed):
        M, N = 32, 24
        plan = partition.PartitionPlan(M, N, m=m, n=n, phi=M // m, psi=N // n,
                                       t_p=1, seed=seed)
        a = jnp.arange(M * N, dtype=jnp.float32).reshape(M, N)
        blocks, _, _ = partition.extract_blocks(a, plan, 0)
        vals = np.sort(np.array(blocks).ravel())
        np.testing.assert_array_equal(vals, np.arange(M * N, dtype=np.float32))

    @pytest.mark.parametrize("M,N,phi,psi", [
        (40, 400, 15, 20),   # wide: cols-first gather is cheaper
        (400, 40, 20, 15),   # tall: rows-first gather is cheaper
    ])
    def test_gather_order_is_content_invariant(self, M, N, phi, psi):
        """Cheaper-axis-first gather must produce the exact same blocks."""
        plan = partition.PartitionPlan(M, N, m=2, n=2, phi=phi, psi=psi,
                                       t_p=1, seed=5)
        a = jnp.asarray(np.random.default_rng(0).normal(size=(M, N)).astype(np.float32))
        blocks, row_idx, col_idx = partition.extract_blocks(a, plan, 0)
        rows = np.array(row_idx).reshape(-1)
        cols = np.array(col_idx).reshape(-1)
        expect = (np.array(a)[rows][:, cols]
                  .reshape(2, phi, 2, psi).transpose(0, 2, 1, 3)
                  .reshape(4, phi, psi))
        np.testing.assert_array_equal(np.array(blocks), expect)


class TestCoverage:
    def test_full_grid_covers_everything(self):
        plan = _plan()
        assert partition.coverage_probability(plan) == 1.0

    def test_partial_grid_coverage_grows_with_resamples(self):
        # 100 rows, m=3 -> phi=33 -> 99 used, 1 dropped per resample
        p1 = partition.PartitionPlan(100, 90, 3, 3, 33, 30, t_p=1)
        p5 = partition.PartitionPlan(100, 90, 3, 3, 33, 30, t_p=5)
        assert partition.coverage_probability(p5) > partition.coverage_probability(p1)

    def test_col_coverage_bounds_the_default(self):
        # rows fully covered but cols drop 12 of 96 per resample: the
        # default (min over axes) must report the col-side risk, which the
        # old row-only formula hid entirely.
        plan = partition.PartitionPlan(90, 96, 3, 4, 30, 21, t_p=2)
        assert partition.coverage_probability(plan, axis="row") == 1.0
        col = partition.coverage_probability(plan, axis="col")
        assert col == pytest.approx(1.0 - (12 / 96) ** 2)
        assert partition.coverage_probability(plan) == pytest.approx(col)


class TestMakePlan:
    def test_make_plan_smoke(self):
        plan = partition.make_plan(
            2048, 2048, min_cocluster_rows=256, min_cocluster_cols=256,
            p_thresh=0.95, workers=8, k=8,
        )
        assert plan.detection_p >= 0.95
        assert plan.rows_used <= 2048 and plan.cols_used <= 2048
