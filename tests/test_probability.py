"""Theorem-1 probabilistic model: bound validity, monotonicity, Eq.4 solver."""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core import probability as P


class TestTheorem1Bound:
    def test_failure_bound_in_unit_interval(self):
        b = P.failure_bound(100, 100, 1000, 1000, 4, 4, 2, 2)
        assert 0.0 <= b <= 1.0

    def test_bound_dominates_monte_carlo(self):
        """The analytic bound must upper-bound the true failure probability."""
        rng = np.random.default_rng(0)
        cases = [
            # (Mk, Nk, M, N, m, n, Tm, Tn)
            (100, 100, 1000, 1000, 4, 4, 2, 2),
            (50, 80, 500, 800, 2, 4, 2, 2),
            (200, 150, 1000, 600, 8, 4, 4, 4),
        ]
        for Mk, Nk, M, N, m, n, Tm, Tn in cases:
            mc = P.mc_failure_estimate(rng, Mk, Nk, M, N, m, n, Tm, Tn, trials=500)
            bound = P.failure_bound(Mk, Nk, M, N, m, n, Tm, Tn)
            assert mc <= bound + 0.05, (
                f"MC {mc} exceeded bound {bound} for case {(Mk, Nk, M, N, m, n)}"
            )

    def test_vacuous_when_margin_nonpositive(self):
        # co-cluster so small the block can't be required to catch it
        b = P.failure_bound(2, 2, 1000, 1000, 32, 32, 8, 8)
        assert b == 1.0

    @given(
        tp1=st.integers(1, 50),
        tp2=st.integers(1, 50),
    )
    @settings(max_examples=30, deadline=None)
    def test_detection_monotone_in_resamples(self, tp1, tp2):
        lo, hi = min(tp1, tp2), max(tp1, tp2)
        p_lo = P.detection_probability(lo, 100, 100, 1000, 1000, 4, 4, 2, 2)
        p_hi = P.detection_probability(hi, 100, 100, 1000, 1000, 4, 4, 2, 2)
        assert p_hi >= p_lo - 1e-12

    @given(scale=st.integers(1, 8))
    @settings(max_examples=20, deadline=None)
    def test_detection_monotone_in_cocluster_size(self, scale):
        base = P.detection_probability(4, 50, 50, 1000, 1000, 4, 4, 2, 2)
        bigger = P.detection_probability(4, 50 * scale, 50 * scale, 1000, 1000, 4, 4, 2, 2)
        assert bigger >= base - 1e-12


class TestEq4Solver:
    @given(p_thresh=st.floats(0.5, 0.999))
    @settings(max_examples=30, deadline=None)
    def test_min_resamples_achieves_threshold(self, p_thresh):
        tp = P.min_resamples(p_thresh, 100, 100, 1000, 1000, 4, 4, 2, 2)
        achieved = P.detection_probability(tp, 100, 100, 1000, 1000, 4, 4, 2, 2)
        assert achieved >= p_thresh - 1e-9

    def test_min_resamples_is_minimal(self):
        tp = P.min_resamples(0.99, 60, 60, 1000, 1000, 8, 8, 4, 4)
        if tp > 1:
            below = P.detection_probability(tp - 1, 60, 60, 1000, 1000, 8, 8, 4, 4)
            assert below < 0.99

    def test_invalid_threshold_raises(self):
        with pytest.raises(ValueError):
            P.min_resamples(1.5, 100, 100, 1000, 1000, 4, 4, 2, 2)


class TestFaultMargin:
    def test_resamples_for_failures_monotone(self):
        base = 10
        assert P.resamples_for_failures(base, 64, 0) == base
        bumped = P.resamples_for_failures(base, 64, 8)
        assert bumped >= base
        assert P.resamples_for_failures(base, 64, 16) >= bumped

    def test_sample_block_failures_exact_count_per_resample(self):
        mask = P.sample_block_failures(0, t_p=5, n_blocks=12, n_failed=3)
        assert mask.shape == (5, 12) and mask.dtype == bool
        assert ((~mask).sum(axis=1) == 3).all()

    def test_sample_block_failures_deterministic_and_varied(self):
        a = P.sample_block_failures(7, 4, 8, 2)
        b = P.sample_block_failures(7, 4, 8, 2)
        assert (a == b).all()                    # seeded = reproducible
        c = P.sample_block_failures(8, 4, 8, 2)
        assert not (a == c).all()                # seeds actually matter

    def test_sample_block_failures_bounds(self):
        assert P.sample_block_failures(0, 2, 4, 0).all()
        assert not P.sample_block_failures(0, 2, 4, 4).any()
        with pytest.raises(ValueError, match="n_failed"):
            P.sample_block_failures(0, 2, 4, 5)


class TestPlanner:
    def test_plan_feasible_and_constrained(self):
        cand = P.plan_partition(
            4096, 4096, min_cocluster_rows=512, min_cocluster_cols=512,
            p_thresh=0.95, workers=16, k=8,
        )
        assert cand.detection_p >= 0.95
        assert cand.phi >= 64 and cand.psi >= 64
        assert max(cand.m, cand.n) <= 4 * min(cand.m, cand.n) or (cand.m, cand.n) == (1, 1)

    def test_exact_svd_planner_partitions_serially(self):
        """With a superlinear atom cost, partitioning should win at 1 worker."""
        cand = P.plan_partition(
            8192, 8192, min_cocluster_rows=1024, min_cocluster_cols=1024,
            p_thresh=0.9, workers=1, k=8, svd_method="exact",
        )
        assert cand.m * cand.n > 1

    def test_more_workers_never_increases_cost(self):
        c1 = P.plan_partition(4096, 4096, min_cocluster_rows=512,
                              min_cocluster_cols=512, workers=1, k=8)
        c16 = P.plan_partition(4096, 4096, min_cocluster_rows=512,
                               min_cocluster_cols=512, workers=16, k=8)
        assert c16.est_cost <= c1.est_cost
