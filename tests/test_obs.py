"""Telemetry layer (DESIGN.md §14): spans, metrics, export, instrumentation.

The load-bearing invariants pinned here:

  * spans nest correctly, time monotonically, and are exact no-ops when
    obs is disabled — the obs-enabled jaxpr-audit entries must stage to
    **identical** jaxprs as their plain twins (the telemetry layer adds
    zero primitives and zero host syncs to traced code);
  * histogram percentiles track ``np.percentile`` within one geometric
    bucket (≤ 25% relative), with bounded memory and NaN-when-empty —
    the contract the ``serve_lamc`` percentile path rides on;
  * a streaming fit's trace carries exactly one ``chunk`` span per
    non-empty chunk, with resume-skipped and recovery-refolded chunks
    marked ``replayed=True``;
  * ``run_with_recovery`` emits structured recovery events (the
    stale-checkpoint warning names the ignored step id);
  * ``benchio.merge_rows`` leaves a provenance sidecar next to every
    trajectory file.
"""

import importlib
import json
import math

import numpy as np
import pytest

from repro import benchio, obs
from repro import checkpoint as ckpt
from repro.runtime.fault_tolerance import FailureInjector, run_with_recovery

sfit = importlib.import_module("repro.streaming.fit")


@pytest.fixture
def obs_on():
    """Enable spans for one test, with a fresh trace; restore after."""
    was = obs.enabled()
    obs.configure(enabled=True)
    tr = obs.reset_trace()
    yield tr
    obs.configure(enabled=was)
    obs.reset_trace()


@pytest.fixture
def obs_off():
    was = obs.enabled()
    obs.configure(enabled=False)
    obs.reset_trace()
    yield
    obs.configure(enabled=was)


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


class TestSpans:
    def test_nesting_paths_and_attrs(self, obs_on):
        tr = obs_on
        with obs.span("root", a=1):
            with obs.span("child1"):
                with obs.span("leaf"):
                    pass
            with obs.span("child2") as c2:
                c2.set(k="v")
        walked = [(sp.name, depth, path) for sp, depth, path in tr.walk()]
        assert walked == [("root", 0, "root"), ("child1", 1, "root/child1"),
                          ("leaf", 2, "root/child1/leaf"),
                          ("child2", 1, "root/child2")]
        assert tr.find("root")[0].attrs == {"a": 1}
        assert tr.find("child2")[0].attrs == {"k": "v"}

    def test_timing_monotonic_and_contained(self, obs_on):
        tr = obs_on
        with obs.span("outer"):
            with obs.span("inner"):
                x = sum(range(1000))  # noqa: F841 — some real work
        outer, inner = tr.find("outer")[0], tr.find("inner")[0]
        assert outer.t_end >= outer.t_start
        assert inner.duration_s >= 0
        # child starts after parent and ends before the parent's exit
        assert inner.t_start >= outer.t_start
        assert inner.t_end <= outer.t_end
        assert inner.duration_s <= outer.duration_s

    def test_fence_returns_value_and_blocks(self, obs_on):
        import jax.numpy as jnp
        with obs.span("fenced") as sp:
            y = sp.fence(jnp.ones((8, 8)) * 3.0)
        assert float(y[0, 0]) == 3.0

    def test_exception_recorded_and_stack_popped(self, obs_on):
        tr = obs_on
        with pytest.raises(ValueError):
            with obs.span("boom"):
                raise ValueError("x")
        sp = tr.find("boom")[0]
        assert sp.attrs["error"] == "ValueError"
        assert sp.t_end >= sp.t_start
        # the stack unwound: a new span is a root, not a child of "boom"
        with obs.span("after"):
            pass
        assert [r.name for r in tr.roots] == ["boom", "after"]

    def test_event_attaches_to_open_span_else_trace(self, obs_on):
        tr = obs_on
        obs.event("free", x=1)
        with obs.span("s"):
            obs.event("inside", y=2)
        assert [e["name"] for e in tr.events] == ["free"]
        assert [e["name"] for e in tr.find("s")[0].events] == ["inside"]

    def test_disabled_is_shared_noop_singleton(self, obs_off):
        s1, s2 = obs.span("a"), obs.span("b", k=1)
        assert s1 is s2  # one shared object: zero allocation per span
        with s1 as sp:
            assert sp.fence(42) == 42
            sp.set(ignored=True)
        obs.event("dropped")  # must not touch (or create) a trace
        tr = obs.current_trace()
        assert tr.roots == [] and tr.events == []


# ---------------------------------------------------------------------------
# obs adds nothing to traced programs
# ---------------------------------------------------------------------------


class TestJaxprNeutrality:
    @pytest.mark.parametrize("plain", ["lamc_dense", "streaming_chunk",
                                       "cosine_assign", "spmm_ata"])
    def test_obs_twin_traces_identically(self, plain):
        from repro.analysis import entry_points as ep
        a = ep.trace_entry(plain)
        b = ep.trace_entry(f"{plain}_obs")
        assert str(a) == str(b), (
            f"{plain}: telemetry changed the lowered program")

    def test_block_until_ready_is_traceable_noop(self):
        import jax
        import jax.numpy as jnp

        def f(x):
            with obs.span("s") as sp:
                return sp.fence(x + 1)

        was = obs.enabled()
        obs.configure(enabled=True)
        try:
            jaxpr = str(jax.make_jaxpr(f)(jnp.ones((4,))))
        finally:
            obs.configure(enabled=was)
        assert "add" in jaxpr and "callback" not in jaxpr


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class TestHistogram:
    def test_percentiles_track_numpy_within_one_bucket(self):
        rng = np.random.default_rng(7)
        samples = rng.lognormal(mean=np.log(800.0), sigma=1.2, size=5000)
        h = obs.Histogram("lat")
        for v in samples:
            h.observe(float(v))
        for p in (10, 50, 90, 99):
            oracle = float(np.percentile(samples, p))
            est = h.percentile(p)
            # geometric buckets at ratio 1.25: within one bucket of exact
            assert oracle / 1.26 <= est <= oracle * 1.26, (p, est, oracle)

    def test_empty_is_nan(self):
        h = obs.Histogram("lat")
        assert math.isnan(h.percentile(50))
        snap = h.snapshot()
        assert snap["count"] == 0 and snap["min"] is None

    def test_single_sample_is_exact(self):
        h = obs.Histogram("lat").observe(123.4)
        assert h.percentile(0) == h.percentile(50) == h.percentile(100) \
            == pytest.approx(123.4)

    def test_bounded_memory(self):
        h = obs.Histogram("lat")
        n_cells = len(h.snapshot()["counts"])
        for v in np.random.default_rng(0).uniform(0.5, 1e9, size=10_000):
            h.observe(float(v))
        assert len(h.snapshot()["counts"]) == n_cells  # never grows
        assert h.count == 10_000

    def test_bad_buckets_rejected(self):
        with pytest.raises(ValueError, match="ascending"):
            obs.Histogram("h", buckets=(1.0, 1.0, 2.0))
        with pytest.raises(ValueError, match="at least one"):
            obs.Histogram("h", buckets=())


class TestRegistry:
    def test_counter_labels_and_negative_inc(self):
        reg = obs.Registry()
        c = reg.counter("dispatch")
        c.labels(op="spmm", tier="ref").inc()
        c.labels(op="spmm", tier="ref").inc()
        c.labels(tier="jnp", op="ata").inc()  # kwarg order is normalized
        snap = c.snapshot()
        assert snap["series"] == {"op=spmm,tier=ref": 2.0,
                                  "op=ata,tier=jnp": 1.0}
        with pytest.raises(ValueError, match=">= 0"):
            c.inc(-1)

    def test_type_conflict_is_loud(self):
        reg = obs.Registry()
        reg.counter("x")
        with pytest.raises(TypeError, match="already registered as counter"):
            reg.gauge("x")
        reg.histogram("h", buckets=(1.0, 2.0))
        with pytest.raises(ValueError, match="different"):
            reg.histogram("h", buckets=(1.0, 3.0))

    def test_snapshot_json_roundtrip_and_diff(self):
        reg = obs.Registry()
        reg.counter("c").inc(3)
        reg.gauge("g").set(1.5)
        reg.histogram("h", buckets=(10.0, 100.0)).observe(5).observe(50)
        snap0 = reg.snapshot()
        assert json.loads(json.dumps(snap0)) == snap0  # JSON-able, exactly
        reg.counter("c").inc(2)
        reg.gauge("g").set(9.0)
        reg.histogram("h").observe(500)
        d = obs.Registry.diff(reg.snapshot(), snap0)
        assert d["c"]["value"] == 2.0
        assert d["g"]["value"] == 9.0              # gauges: newer value
        assert d["h"]["count"] == 1
        assert sum(d["h"]["counts"]) == 1

    def test_to_rows_flattens_histograms(self):
        reg = obs.Registry()
        reg.histogram("lat_us").observe(100.0)
        reg.counter("n").inc(4)
        rows = reg.to_rows(prefix="serve_")
        assert rows["serve_n"] == 4.0
        assert rows["serve_lat_us_count"] == 1
        assert rows["serve_lat_us_p50"] == pytest.approx(100.0)


# ---------------------------------------------------------------------------
# export: JSONL round-trip, validation, CLI
# ---------------------------------------------------------------------------


class TestExport:
    def _small_trace(self):
        tr = obs.reset_trace()
        with obs.span("root", n=2):
            with obs.span("child"):
                obs.event("tick", i=0)
        return tr

    def test_jsonl_roundtrip_validates(self, obs_on, tmp_path):
        self._small_trace()
        path = str(tmp_path / "t.jsonl")
        obs.write_trace_jsonl(path)
        assert obs.validate_trace_jsonl(path) == []
        rows = obs.read_trace_jsonl(path)
        assert rows[0] == {"type": "trace",
                           "version": obs.TRACE_SCHEMA_VERSION}
        spans = [r for r in rows if r["type"] == "span"]
        assert [s["path"] for s in spans] == ["root", "root/child"]
        events = [r for r in rows if r["type"] == "event"]
        assert events[0]["name"] == "tick" and events[0]["path"] == "root/child"

    def test_corruption_is_detected(self, obs_on, tmp_path):
        self._small_trace()
        path = str(tmp_path / "t.jsonl")
        obs.write_trace_jsonl(path)
        lines = open(path).read().splitlines()
        lines[1] = lines[1][:-5]  # truncate one row mid-JSON
        open(path, "w").write("\n".join(lines) + "\n")
        errors = obs.validate_trace_jsonl(path)
        assert errors and "not valid JSON" in errors[0]

    def test_missing_header_is_an_error(self):
        errs = obs.validate_rows([{"type": "span", "name": "x", "path": "x",
                                   "depth": 0, "t_start_s": 0.0, "dur_s": 0.0,
                                   "attrs": {}}])
        assert any("first row" in e for e in errs)

    def test_render_smoke(self, obs_on):
        tr = self._small_trace()
        text = obs.render_trace(tr)
        assert "root" in text and "child" in text and "schema v1" in text

    def test_cli_validate_and_render(self, obs_on, tmp_path, capsys):
        from repro.obs.__main__ import main
        self._small_trace()
        path = str(tmp_path / "t.jsonl")
        obs.write_trace_jsonl(path)
        assert main([path, "--validate"]) == 0
        assert "OK" in capsys.readouterr().out
        assert main([path]) == 0
        assert "root" in capsys.readouterr().out
        bad = str(tmp_path / "bad.jsonl")
        open(bad, "w").write('{"type": "span"}\n')
        assert main([bad, "--validate"]) == 1


# ---------------------------------------------------------------------------
# instrumentation: lamc, kernels, recovery, streaming, serving, benchio
# ---------------------------------------------------------------------------


def _stream_cfg(**over):
    base = dict(n_row_clusters=2, n_col_clusters=2, col_blocks=2,
                signature_dim=8, anchor_rows=8, svd_iters=2, kmeans_iters=2,
                merge_kmeans_iters=2, merge_restarts=1, seed=0)
    base.update(over)
    return sfit.StreamConfig(**base)


def _chunks(n_chunks=4, rows=32, cols=64, empty_at=()):
    rng = np.random.default_rng(3)
    out = []
    for i in range(n_chunks):
        if i in empty_at:
            out.append(np.zeros((0, cols), np.float32))
        out.append(rng.standard_normal((rows, cols)).astype(np.float32))
    return out


class TestLamcTrace:
    def test_span_tree_and_plan_attrs(self, obs_on):
        import jax.numpy as jnp
        from repro.core.lamc import LAMCConfig, lamc_cocluster
        tr = obs_on
        a = jnp.asarray(np.random.default_rng(0).standard_normal((32, 32)),
                        jnp.float32)
        cfg = LAMCConfig(n_row_clusters=2, n_col_clusters=2, svd_iters=2,
                         kmeans_iters=2, merge_kmeans_iters=2,
                         merge_restarts=1, signature_dim=8)
        lamc_cocluster(a, cfg)
        root = tr.find("lamc")[0]
        names = [c.name for c in root.children]
        assert names == ["plan", "pipeline", "finalize"]
        for key in ("m", "n", "phi", "psi", "t_p", "spmm_route", "density"):
            assert key in root.attrs, f"missing plan attr {key}"
        assert root.attrs["rows"] == 32
        pipeline = tr.find("pipeline")[0]
        assert pipeline.attrs["phases"] == "partition/extract->atom->merge"


class TestKernelDispatch:
    def test_counts_by_op_and_tier(self):
        import jax.numpy as jnp
        from jax.experimental import sparse as jsparse
        from repro.kernels import ops
        obs.reset_metrics()
        dense = np.zeros((16, 16), np.float32)
        dense[0, 0] = 1.0
        a = jsparse.BCOO.fromdense(jnp.asarray(dense))
        ops.spmm(a, jnp.ones((16, 4)))
        ops.spmm(a, jnp.ones((16, 4)))
        series = obs.get_registry().counter("kernel_dispatch").snapshot()["series"]
        assert series["op=spmm,tier=ref"] == 2.0

    def test_spmm_ata_records_vmem_verdict(self, obs_on):
        import jax.numpy as jnp
        from jax.experimental import sparse as jsparse
        from repro.kernels import ops
        tr = obs_on
        obs.reset_metrics()
        rng = np.random.default_rng(11)
        dense = np.where(rng.random((256, 256)) < 0.1,
                         rng.standard_normal((256, 256)), 0.0)
        a = ops.bcoo_to_block_sparse(
            jsparse.BCOO.fromdense(jnp.asarray(dense, jnp.float32)),
            bm=128, bk=128)
        with obs.span("host"):
            ops.spmm_ata(a, jnp.ones((256, 8), jnp.float32))
        evs = [e for e in tr.find("host")[0].events
               if e["name"] == "kernel_dispatch"]
        assert evs and evs[0]["attrs"]["op"] == "spmm_ata"
        assert "fused" in evs[0]["attrs"]
        series = obs.get_registry().counter("kernel_dispatch").snapshot()["series"]
        assert sum(v for k, v in series.items() if "op=spmm_ata" in k) >= 1


class TestRecoveryEvents:
    def _loop(self, d, *, fail_at, total=3, save_every=5):
        inj = FailureInjector(fail_at_steps=tuple(fail_at))

        def step_fn(t, s):
            out = {"v": np.asarray(s["v"] + 1, np.int64)}
            inj.maybe_fail(t)
            return out

        def restore_state(step):
            if step < 0:
                return {"v": np.asarray(0, np.int64)}
            tree, _ = ckpt.restore(d, step, {"v": np.asarray(0, np.int64)})
            return tree

        return run_with_recovery(
            total_steps=total, step_fn=step_fn,
            state={"v": np.asarray(0, np.int64)}, ckpt_dir=d,
            save_every=save_every, restore_state=restore_state)

    def test_stale_checkpoint_event_names_ignored_step(self, obs_on, tmp_path):
        tr = obs_on
        obs.reset_metrics()
        d = str(tmp_path)
        # a previous run left step 50 here; THIS run never saved it
        ckpt.save(d, 50, {"v": np.asarray(99, np.int64)},
                  extra_meta={"step": 50})
        state, stats = self._loop(d, fail_at=(1,))
        assert int(state["v"]) == 3 and stats["failures"] == 1
        stale = [e for e in tr.events
                 if e["name"] == "recovery.stale_checkpoint"]
        assert len(stale) == 1
        assert stale[0]["attrs"]["ignored_step"] == 50
        assert stale[0]["attrs"]["last_saved"] is None
        rest = [e for e in tr.events if e["name"] == "recovery.restore"]
        assert rest[0]["attrs"]["failed_step"] == 1
        assert rest[0]["attrs"]["target"] == -1  # from scratch, not step 50
        reg = obs.get_registry()
        assert reg.counter("recovery_stale_checkpoints").value == 1.0
        assert reg.counter("recovery_restores").value == 1.0

    def test_checkpoint_saved_events(self, obs_on, tmp_path):
        tr = obs_on
        self._loop(str(tmp_path), fail_at=(), total=4, save_every=2)
        saved = [e["attrs"]["step"] for e in tr.events
                 if e["name"] == "recovery.checkpoint_saved"]
        assert saved == [2, 4]


class TestStreamingTrace:
    def test_one_chunk_span_per_nonempty_chunk(self, obs_on):
        tr = obs_on
        chunks = _chunks(n_chunks=3, empty_at=(1,))  # 3 real + 1 empty
        sfit.fit(iter(chunks), _stream_cfg())
        spans = tr.find("chunk")
        assert len(spans) == 3  # the empty chunk left no span
        assert [s.attrs["t"] for s in spans] == [0, 1, 2]
        assert all(s.attrs["replayed"] is False for s in spans)
        assert [c.name for c in spans[0].children] == \
            ["blocks", "atoms", "reservoir"]
        root = tr.find("stream_fit")[0]
        assert root.attrs["chunks"] == 3
        fin = tr.find("finalize")[0]
        assert [c.name for c in fin.children] == ["align", "votes", "columns"]

    def test_resume_marks_skipped_chunks_replayed(self, obs_on, tmp_path):
        cfg = _stream_cfg()
        chunks = _chunks(n_chunks=4)
        d = str(tmp_path)
        fitter = sfit.StreamingCocluster(cfg)
        for c in chunks[:2]:
            fitter.partial_fit(c)
        sfit.save_fit_state(d, fitter)

        # "new process": fresh trace, resume the fit over the same stream
        tr = obs.reset_trace()
        model, stats = sfit.fit(iter(chunks), cfg, ckpt_dir=d, save_every=2,
                                resume_from=d)
        assert stats.chunks == 4
        spans = tr.find("chunk")
        assert len(spans) == 4  # exactly one span per non-empty chunk
        flags = [(s.attrs["replayed"], s.attrs.get("skipped", False))
                 for s in spans]
        assert flags == [(True, True), (True, True),
                         (False, False), (False, False)]
        # and the trace round-trips through the JSONL schema
        path = str(tmp_path / "fit_trace.jsonl")
        obs.write_trace_jsonl(path, tr)
        assert obs.validate_trace_jsonl(path) == []

    def test_injected_failure_refold_marked_replayed(self, obs_on, tmp_path):
        tr = obs_on
        chunks = _chunks(n_chunks=4)
        sfit.fit(iter(chunks), _stream_cfg(), ckpt_dir=str(tmp_path),
                 save_every=2,
                 failure_injector=FailureInjector(fail_at_steps=(2,)))
        spans = tr.find("chunk")
        # chunk 2 folded, failed post-fold, restored to ckpt step 2, refolded
        refolds = [s for s in spans if s.attrs["replayed"]]
        assert len(refolds) == 1 and refolds[0].attrs["t"] == 2
        restores = [e for e in tr.find("stream_fit")[0].events
                    if e["name"] == "recovery.restore"]
        assert len(restores) == 1 and restores[0]["attrs"]["failed_step"] == 2


class TestServeMetrics:
    def _save_model(self, tmp_path):
        from repro import streaming
        rng = np.random.default_rng(5)
        k, q, n = 2, 8, 32
        sigs = rng.standard_normal((k, q)).astype(np.float32)
        sigs /= np.linalg.norm(sigs, axis=1, keepdims=True)
        model = streaming.CoclusterModel(
            row_labels=np.zeros(n, np.int32),
            col_labels=np.zeros(n, np.int32),
            row_votes=np.zeros((n, k), np.float32),
            col_votes=np.zeros((n, k), np.float32),
            row_sigs=sigs, col_sigs=sigs.copy(),
            row_mean=np.zeros(q, np.float32),
            col_mean=np.zeros(q, np.float32),
            anchor_rows=np.arange(q, dtype=np.int32),
            anchor_cols=np.arange(q, dtype=np.int32),
        )
        streaming.save_model(str(tmp_path), model)
        return str(tmp_path)

    def test_histogram_percentiles_and_error_counter(self, tmp_path):
        from repro.launch import serve_lamc
        d = self._save_model(tmp_path)
        reg = obs.Registry()
        out = serve_lamc.serve(d, batch=4, requests=6, warmup=1,
                               adversarial=3, registry=reg)
        h = reg.get("serve_assign_rows_latency_us")
        assert h.count == 6  # adversarial batches are never timed
        assert out["serve_assign_rows_errors"] == 3
        assert out["serve_assign_rows_p50_us"] == pytest.approx(
            h.percentile(50))
        assert out["serve_assign_rows_qps"] > 0
        # bounded memory: bucket vector, not a sample list
        assert len(h.snapshot()["counts"]) == len(h.buckets) + 1

    def test_all_rejected_reports_nan_percentiles(self, tmp_path):
        from repro.launch import serve_lamc
        d = self._save_model(tmp_path)
        out = serve_lamc.serve(d, batch=4, requests=0, warmup=1,
                               adversarial=3)
        assert math.isnan(out["serve_assign_rows_p50_us"])
        assert math.isnan(out["serve_assign_rows_p99_us"])
        assert out["serve_assign_rows_errors"] == 3
        assert out["serve_assign_rows_qps"] == 0.0

    def test_serve_emits_span_trace(self, obs_on, tmp_path):
        from repro.launch import serve_lamc
        tr = obs_on
        d = self._save_model(tmp_path)
        serve_lamc.serve(d, batch=4, requests=2, warmup=1, adversarial=1)
        root = tr.find("serve")[0]
        assert [c.name for c in root.children] == ["warmup", "request_loop"]
        assert root.attrs["served"] == 2 and root.attrs["errors"] == 1
        rejected = [e for e in tr.find("request_loop")[0].events
                    if e["name"] == "request_rejected"]
        assert len(rejected) == 1


class TestBenchMeta:
    def test_merge_rows_writes_provenance_sidecar(self, tmp_path):
        path = str(tmp_path / "BENCH_x.json")
        benchio.merge_rows(path, {"x_a": 1.0}, own_prefixes=("x_",))
        meta = json.load(open(str(tmp_path / benchio.META_BASENAME)))
        entry = meta["BENCH_x.json"]
        for key in ("git_sha", "jax_version", "backend", "device_kind",
                    "timestamp"):
            assert key in entry, f"missing provenance field {key}"
        assert entry["rows"] == 1
        assert entry["git_sha"] != ""  # repo checkout: a real sha
        # a second trajectory file merges into the same sidecar
        benchio.merge_rows(str(tmp_path / "BENCH_y.json"), {"y_b": 2.0})
        meta = json.load(open(str(tmp_path / benchio.META_BASENAME)))
        assert set(meta) == {"BENCH_x.json", "BENCH_y.json"}

    def test_provenance_never_raises(self):
        info = benchio.provenance()
        assert set(info) >= {"git_sha", "jax_version", "backend",
                             "device_kind", "timestamp"}
