"""Spectral co-clustering atom: normalization, randomized SVD, end-to-end SCC."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import spectral
from repro.core.metrics import cocluster_scores
from repro.data import planted_cocluster_matrix


class TestNormalize:
    def test_matches_definition(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(np.abs(rng.normal(size=(30, 20))).astype(np.float32))
        a_n, d1i, d2i = spectral.normalize_bipartite(a)
        expect = np.diag(np.array(d1i)) @ np.array(a) @ np.diag(np.array(d2i))
        np.testing.assert_allclose(np.array(a_n), expect, rtol=1e-5)

    def test_zero_rows_finite(self):
        a = jnp.zeros((5, 4), jnp.float32).at[0, 0].set(1.0)
        a_n, _, _ = spectral.normalize_bipartite(a)
        assert bool(jnp.all(jnp.isfinite(a_n)))


class TestRandomizedSVD:
    @given(
        m=st.integers(20, 80),
        n=st.integers(20, 80),
        rank=st.integers(2, 6),
    )
    @settings(max_examples=10, deadline=None)
    def test_recovers_lowrank_spectrum(self, m, n, rank):
        rng = np.random.default_rng(m * 100 + n)
        u = np.linalg.qr(rng.normal(size=(m, rank)))[0]
        v = np.linalg.qr(rng.normal(size=(n, rank)))[0]
        s = np.sort(rng.uniform(1.0, 5.0, rank))[::-1]
        a = jnp.asarray((u * s) @ v.T, dtype=jnp.float32)
        _, s_est, _ = spectral.randomized_svd(jax.random.key(0), a, rank, n_iter=6)
        np.testing.assert_allclose(np.array(s_est), s, rtol=1e-2)

    def test_singular_vectors_match_exact(self):
        # spiked spectrum: subspace iteration resolves well-separated leading
        # singular values; a flat Marchenko-Pastur tail is out of scope.
        rng = np.random.default_rng(7)
        base = rng.normal(size=(60, 40)).astype(np.float32)
        u0, s0, vt0 = np.linalg.svd(base, full_matrices=False)
        s0[:3] = [40.0, 25.0, 15.0]  # spike the top three
        a = jnp.asarray((u0 * s0) @ vt0)
        u_r, s_r, vt_r = spectral.randomized_svd(jax.random.key(0), a, 3, n_iter=12)
        u_e, s_e, vt_e = np.linalg.svd(np.array(a), full_matrices=False)
        np.testing.assert_allclose(np.array(s_r), s_e[:3], rtol=1e-2)
        # vectors up to sign
        for i in range(3):
            dot = abs(float(np.dot(np.array(u_r[:, i]), u_e[:, i])))
            assert dot > 0.98, f"singular vector {i} misaligned: {dot}"


class TestSCC:
    def test_recovers_planted_coclusters(self):
        rng = np.random.default_rng(0)
        data = planted_cocluster_matrix(rng, 300, 240, k=4, d=4, signal=4.0, noise=0.5)
        res = spectral.scc(jax.random.key(0), jnp.asarray(data.matrix), 4, 4)
        s = cocluster_scores(np.array(res.row_labels), np.array(res.col_labels),
                             data.row_labels, data.col_labels)
        assert s["nmi"] > 0.7, s

    def test_exact_and_randomized_agree_on_easy_data(self):
        rng = np.random.default_rng(1)
        data = planted_cocluster_matrix(rng, 200, 160, k=3, d=3, signal=6.0, noise=0.3)
        a = jnp.asarray(data.matrix)
        r1 = spectral.scc(jax.random.key(0), a, 3, 3, svd_method="exact")
        r2 = spectral.scc(jax.random.key(0), a, 3, 3, svd_method="randomized")
        s1 = cocluster_scores(np.array(r1.row_labels), np.array(r1.col_labels),
                              data.row_labels, data.col_labels)
        s2 = cocluster_scores(np.array(r2.row_labels), np.array(r2.col_labels),
                              data.row_labels, data.col_labels)
        assert abs(s1["nmi"] - s2["nmi"]) < 0.15

    def test_different_row_col_cluster_counts(self):
        rng = np.random.default_rng(2)
        data = planted_cocluster_matrix(rng, 240, 180, k=4, d=3, signal=5.0, noise=0.4)
        res = spectral.scc(jax.random.key(0), jnp.asarray(data.matrix), 4, 3)
        assert res.row_labels.shape == (240,)
        assert res.col_labels.shape == (180,)
        assert int(res.col_labels.max()) < 3

    def test_vmappable(self):
        rng = np.random.default_rng(3)
        stack = jnp.asarray(rng.normal(size=(4, 50, 40)).astype(np.float32))
        keys = jax.random.split(jax.random.key(0), 4)
        rl, cl = jax.vmap(
            lambda kk, b: (lambda r: (r.row_labels, r.col_labels))(
                spectral.scc(kk, b, 3, 3)
            )
        )(keys, stack)
        assert rl.shape == (4, 50) and cl.shape == (4, 40)
