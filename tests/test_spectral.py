"""Spectral co-clustering atom: normalization, randomized SVD, end-to-end SCC."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.core import spectral
from repro.core.metrics import cocluster_scores
from repro.data import planted_cocluster_matrix


class TestNormalize:
    def test_matches_definition(self):
        rng = np.random.default_rng(0)
        a = jnp.asarray(np.abs(rng.normal(size=(30, 20))).astype(np.float32))
        a_n, d1i, d2i = spectral.normalize_bipartite(a)
        expect = np.diag(np.array(d1i)) @ np.array(a) @ np.diag(np.array(d2i))
        np.testing.assert_allclose(np.array(a_n), expect, rtol=1e-5)

    def test_zero_rows_finite(self):
        a = jnp.zeros((5, 4), jnp.float32).at[0, 0].set(1.0)
        a_n, _, _ = spectral.normalize_bipartite(a)
        assert bool(jnp.all(jnp.isfinite(a_n)))


class TestRandomizedSVD:
    @given(
        m=st.integers(20, 80),
        n=st.integers(20, 80),
        rank=st.integers(2, 6),
    )
    @settings(max_examples=10, deadline=None)
    def test_recovers_lowrank_spectrum(self, m, n, rank):
        rng = np.random.default_rng(m * 100 + n)
        u = np.linalg.qr(rng.normal(size=(m, rank)))[0]
        v = np.linalg.qr(rng.normal(size=(n, rank)))[0]
        s = np.sort(rng.uniform(1.0, 5.0, rank))[::-1]
        a = jnp.asarray((u * s) @ v.T, dtype=jnp.float32)
        _, s_est, _ = spectral.randomized_svd(jax.random.key(0), a, rank, n_iter=6)
        np.testing.assert_allclose(np.array(s_est), s, rtol=1e-2)

    def test_singular_vectors_match_exact(self):
        # spiked spectrum: subspace iteration resolves well-separated leading
        # singular values; a flat Marchenko-Pastur tail is out of scope.
        rng = np.random.default_rng(7)
        base = rng.normal(size=(60, 40)).astype(np.float32)
        u0, s0, vt0 = np.linalg.svd(base, full_matrices=False)
        s0[:3] = [40.0, 25.0, 15.0]  # spike the top three
        a = jnp.asarray((u0 * s0) @ vt0)
        u_r, s_r, vt_r = spectral.randomized_svd(jax.random.key(0), a, 3, n_iter=12)
        u_e, s_e, vt_e = np.linalg.svd(np.array(a), full_matrices=False)
        np.testing.assert_allclose(np.array(s_r), s_e[:3], rtol=1e-2)
        # vectors up to sign
        for i in range(3):
            dot = abs(float(np.dot(np.array(u_r[:, i]), u_e[:, i])))
            assert dot > 0.98, f"singular vector {i} misaligned: {dot}"


class TestCholeskyQR:
    """Gram-based (CholeskyQR) subspace iteration vs the LAPACK-QR path."""

    def _spiked(self, m, n, spikes, seed=7):
        rng = np.random.default_rng(seed)
        base = rng.normal(size=(m, n)).astype(np.float32)
        u0, s0, vt0 = np.linalg.svd(base, full_matrices=False)
        s0[: len(spikes)] = spikes
        return jnp.asarray((u0 * s0) @ vt0)

    def test_subspace_matches_qr_path(self):
        a = self._spiked(120, 80, [50.0, 30.0, 20.0, 12.0])
        u1, s1, _ = spectral.randomized_svd(jax.random.key(0), a, 4, n_iter=8,
                                            qr_method="qr")
        u2, s2, _ = spectral.randomized_svd(jax.random.key(0), a, 4, n_iter=8,
                                            qr_method="cholesky")
        np.testing.assert_allclose(np.array(s1), np.array(s2), rtol=1e-3)
        # principal angles between the two computed subspaces
        sv = np.linalg.svd(np.array(u1).T @ np.array(u2), compute_uv=False)
        max_angle = float(np.max(np.arccos(np.clip(sv, -1.0, 1.0))))
        assert max_angle <= 1e-3, max_angle

    def test_q_is_orthonormal(self):
        a = self._spiked(90, 60, [20.0, 10.0, 6.0])
        u, _, _ = spectral.randomized_svd(jax.random.key(1), a, 3, n_iter=6,
                                          qr_method="cholesky")
        gram = np.array(u).T @ np.array(u)
        np.testing.assert_allclose(gram, np.eye(3), atol=1e-4)

    def test_recovers_spiked_spectrum(self):
        a = self._spiked(60, 40, [40.0, 25.0, 15.0])
        _, s_r, _ = spectral.randomized_svd(jax.random.key(0), a, 3, n_iter=12,
                                            qr_method="cholesky")
        s_e = np.linalg.svd(np.array(a), compute_uv=False)
        np.testing.assert_allclose(np.array(s_r), s_e[:3], rtol=1e-2)

    def test_vmappable_over_block_stack(self):
        """The batched-subspace-iteration claim: no per-block LAPACK QR."""
        rng = np.random.default_rng(3)
        stack = jnp.asarray(rng.normal(size=(4, 50, 40)).astype(np.float32))
        keys = jax.random.split(jax.random.key(0), 4)
        u, s, vt = jax.vmap(
            lambda kk, b: spectral.randomized_svd(kk, b, 3, n_iter=4,
                                                  qr_method="cholesky")
        )(keys, stack)
        assert u.shape == (4, 50, 3) and s.shape == (4, 3) and vt.shape == (4, 3, 40)
        assert bool(jnp.all(jnp.isfinite(u)))


class TestSCC:
    def test_recovers_planted_coclusters(self):
        rng = np.random.default_rng(0)
        data = planted_cocluster_matrix(rng, 300, 240, k=4, d=4, signal=4.0, noise=0.5)
        res = spectral.scc(jax.random.key(0), jnp.asarray(data.matrix), 4, 4)
        s = cocluster_scores(np.array(res.row_labels), np.array(res.col_labels),
                             data.row_labels, data.col_labels)
        assert s["nmi"] > 0.7, s

    def test_exact_and_randomized_agree_on_easy_data(self):
        rng = np.random.default_rng(1)
        data = planted_cocluster_matrix(rng, 200, 160, k=3, d=3, signal=6.0, noise=0.3)
        a = jnp.asarray(data.matrix)
        r1 = spectral.scc(jax.random.key(0), a, 3, 3, svd_method="exact")
        r2 = spectral.scc(jax.random.key(0), a, 3, 3, svd_method="randomized")
        s1 = cocluster_scores(np.array(r1.row_labels), np.array(r1.col_labels),
                              data.row_labels, data.col_labels)
        s2 = cocluster_scores(np.array(r2.row_labels), np.array(r2.col_labels),
                              data.row_labels, data.col_labels)
        assert abs(s1["nmi"] - s2["nmi"]) < 0.15

    def test_different_row_col_cluster_counts(self):
        rng = np.random.default_rng(2)
        data = planted_cocluster_matrix(rng, 240, 180, k=4, d=3, signal=5.0, noise=0.4)
        res = spectral.scc(jax.random.key(0), jnp.asarray(data.matrix), 4, 3)
        assert res.row_labels.shape == (240,)
        assert res.col_labels.shape == (180,)
        assert int(res.col_labels.max()) < 3

    def test_cholesky_qr_method_quality(self):
        rng = np.random.default_rng(4)
        data = planted_cocluster_matrix(rng, 300, 240, k=4, d=4, signal=4.0, noise=0.5)
        a = jnp.asarray(data.matrix)
        r1 = spectral.scc(jax.random.key(0), a, 4, 4, qr_method="qr")
        r2 = spectral.scc(jax.random.key(0), a, 4, 4, qr_method="cholesky")
        s1 = cocluster_scores(np.array(r1.row_labels), np.array(r1.col_labels),
                              data.row_labels, data.col_labels)
        s2 = cocluster_scores(np.array(r2.row_labels), np.array(r2.col_labels),
                              data.row_labels, data.col_labels)
        assert s2["nmi"] > 0.7, s2
        assert abs(s1["nmi"] - s2["nmi"]) < 0.15

    def test_vmappable(self):
        rng = np.random.default_rng(3)
        stack = jnp.asarray(rng.normal(size=(4, 50, 40)).astype(np.float32))
        keys = jax.random.split(jax.random.key(0), 4)
        rl, cl = jax.vmap(
            lambda kk, b: (lambda r: (r.row_labels, r.col_labels))(
                spectral.scc(kk, b, 3, 3)
            )
        )(keys, stack)
        assert rl.shape == (4, 50) and cl.shape == (4, 40)
