"""Assignment service + model registry (DESIGN.md §15).

Pins the serving-layer contracts:
  * coalesced service responses are bit-equal to the direct jitted
    assign step, for every request size the coalescer can see (1-row,
    odd, full-batch, zero-row) and for top-k and column traffic;
  * admission rejects carry machine-readable reason codes and never
    raise into the caller (bad shape/dtype/payload, bad k, oversize,
    queue_full load shedding, post-close shutdown);
  * hot swap is atomic: under continuous multi-thread traffic every
    response is attributable to exactly one model version and its
    labels match that version's model exactly — no torn batches, no
    dropped or errored requests (the zero-drop guarantee);
  * the registry's publish/load round-trip, monotonic version ids, and
    crash-consistency (a claimed-but-uncommitted version is invisible);
  * the serving sharding policy (``serve_model_specs``) shards exactly
    the 2-D tables whose leading dim divides the mesh, and the sharded
    service returns the same labels as the single-device one (slow,
    8-device subprocess).
"""

import os
import subprocess
import sys
import textwrap
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs, streaming
from repro.data import planted_cocluster_matrix


@pytest.fixture(scope="module")
def fitted():
    rng = np.random.default_rng(0)
    data = planted_cocluster_matrix(rng, 256, 128, k=4, d=4,
                                    signal=4.0, noise=0.6)
    cfg = streaming.StreamConfig(n_row_clusters=4, n_col_clusters=4, seed=0)
    model, _ = streaming.fit(streaming.iter_row_chunks(data.matrix, 128), cfg)
    return model, cfg


def _service(model, **over):
    kw = dict(batch=16, replicas=2)
    kw.update(over)
    return streaming.AssignService(
        model, version="v1", config=streaming.ServeConfig(**kw),
        metrics=obs.Registry())


class TestServiceParity:
    def test_coalesced_matches_direct(self, fitted):
        model, _ = fitted
        rng = np.random.default_rng(1)
        sizes = [1, 3, 16, 0, 7, 5]
        reqs = [rng.normal(size=(s, model.n_cols)).astype(np.float32)
                for s in sizes]
        direct = [streaming.assign_rows(model, jnp.asarray(x)) for x in reqs]
        with _service(model) as svc:
            tickets = [svc.submit(x) for x in reqs]
            for x, t, want in zip(reqs, tickets, direct):
                res = t.result(timeout=60.0)
                assert res.ok, (res.reason, res.detail)
                assert res.version == "v1"
                assert res.labels.shape == (x.shape[0],)
                np.testing.assert_array_equal(res.labels,
                                              np.asarray(want.labels))
                np.testing.assert_allclose(res.scores,
                                           np.asarray(want.score),
                                           rtol=1e-5, atol=1e-6)

    def test_topk_and_cols_traffic(self, fitted):
        model, _ = fitted
        rng = np.random.default_rng(2)
        xr = rng.normal(size=(6, model.n_cols)).astype(np.float32)
        xc = rng.normal(size=(6, model.n_rows)).astype(np.float32)
        want_k = streaming.assign_rows_topk(model, jnp.asarray(xr), k=3)
        want_c = streaming.assign_cols(model, jnp.asarray(xc))
        with _service(model) as svc:
            rk = svc.submit(xr, axis="rows", k=3).result(timeout=60.0)
            rc = svc.submit(xc, axis="cols").result(timeout=60.0)
        assert rk.ok and rk.labels.shape == (6, 3)
        np.testing.assert_array_equal(rk.labels, np.asarray(want_k.labels))
        assert rc.ok
        np.testing.assert_array_equal(rc.labels, np.asarray(want_c.labels))

    def test_zero_row_submit_completes_immediately(self, fitted):
        model, _ = fitted
        with _service(model) as svc:
            res = svc.submit(
                np.zeros((0, model.n_cols), np.float32)).result(timeout=5.0)
            assert res.ok and res.labels.shape == (0,)
            res_k = svc.submit(np.zeros((0, model.n_cols), np.float32),
                               k=2).result(timeout=5.0)
            assert res_k.ok and res_k.labels.shape == (0, 2)


class TestAdmission:
    def test_malformed_requests_reject_with_codes(self, fitted):
        model, _ = fitted
        dim = model.n_cols
        bad = np.zeros((2, dim), np.float32)
        bad[0, 0] = np.inf
        cases = [
            (np.zeros((dim,), np.float32), {}, "bad_rank"),
            (np.zeros((2, dim + 1), np.float32), {}, "bad_width"),
            (np.zeros((2, dim), np.int32), {}, "bad_dtype"),
            (bad, {}, "non_finite"),
            (np.zeros((2, dim), np.float32), {"k": 0}, "bad_k"),
            (np.zeros((2, dim), np.float32), {"k": 99}, "bad_k"),
            (np.zeros((17, dim), np.float32), {}, "oversize"),
        ]
        with _service(model, batch=16) as svc:
            for x, kw, code in cases:
                res = svc.submit(x, **kw).result(timeout=5.0)
                assert not res.ok and res.reason == code, (res.reason, code)
                assert res.version is None and res.labels is None
            with pytest.raises(ValueError, match="axis"):
                svc.submit(np.zeros((2, dim), np.float32), axis="diag")

    def test_queue_full_sheds_load(self, fitted):
        model, _ = fitted
        gate = threading.Event()
        with _service(model, batch=4, replicas=1, max_queue_rows=8) as svc:
            orig = svc._score_batch

            def stalled(key, reqs):
                gate.wait(30.0)
                orig(key, reqs)

            svc._score_batch = stalled
            x4 = np.zeros((4, model.n_cols), np.float32)
            first = svc.submit(x4)           # taken by the (stalled) worker
            deadline = time.time() + 10.0
            while svc.stats()["queued_rows"] and time.time() < deadline:
                time.sleep(0.005)
            held = [svc.submit(x4), svc.submit(x4)]   # fills the 8-row budget
            shed = svc.submit(x4).result(timeout=5.0)
            assert not shed.ok and shed.reason == "queue_full"
            gate.set()
            for t in [first] + held:
                assert t.result(timeout=60.0).ok

    def test_internal_error_rejects_batch_not_worker(self, fitted):
        model, _ = fitted
        with _service(model, replicas=1) as svc:

            def boom(x):
                raise RuntimeError("injected scorer failure")

            with svc._engine._lock:
                svc._engine._scorers[("rows", 1)] = boom
            x = np.zeros((2, model.n_cols), np.float32)
            res = svc.submit(x).result(timeout=30.0)
            assert not res.ok and res.reason == "internal_error"
            assert "injected" in res.detail
            # the worker survived: fix the scorer, traffic flows again
            with svc._engine._lock:
                del svc._engine._scorers[("rows", 1)]
            res2 = svc.submit(x).result(timeout=60.0)
            assert res2.ok

    def test_shutdown_rejects_after_close(self, fitted):
        model, _ = fitted
        svc = _service(model)
        x = np.zeros((2, model.n_cols), np.float32)
        assert svc.submit(x).result(timeout=60.0).ok
        svc.close()
        res = svc.submit(x).result(timeout=5.0)
        assert not res.ok and res.reason == "shutdown"

    def test_rejects_are_counted_per_reason(self, fitted):
        model, _ = fitted
        reg = obs.Registry()
        svc = streaming.AssignService(
            model, version="v1",
            config=streaming.ServeConfig(batch=8, replicas=1), metrics=reg)
        svc.submit(np.zeros((3,), np.float32)).result(timeout=5.0)
        svc.submit(np.zeros((9, model.n_cols), np.float32)).result(timeout=5.0)
        svc.close()
        rejected = svc.stats()["rejected"]
        assert rejected["reason=bad_rank"] == 1
        assert rejected["reason=oversize"] == 1


class TestHotSwap:
    """Swap atomicity: the successor model's signature table is a
    cyclic roll of the original's, so labels map deterministically —
    every response must match exactly one version's mapping."""

    def test_every_response_attributable_to_one_version(self, fitted):
        model, _ = fitted
        k = model.n_row_clusters
        model2 = model._replace(
            row_sigs=jnp.roll(model.row_sigs, 1, axis=0))
        rng = np.random.default_rng(3)
        x = rng.normal(size=(4, model.n_cols)).astype(np.float32)
        want_v1 = np.asarray(streaming.assign_rows(model, jnp.asarray(x)).labels)
        want_v2 = (want_v1 + 1) % k   # rolled sigs shift every argmax by 1
        np.testing.assert_array_equal(
            np.asarray(streaming.assign_rows(model2, jnp.asarray(x)).labels),
            want_v2)

        results: list = []
        lock = threading.Lock()
        stop = threading.Event()
        with _service(model, batch=8, replicas=2) as svc:

            def pump():
                while not stop.is_set():
                    res = svc.submit(x).result(timeout=60.0)
                    with lock:
                        results.append(res)

            threads = [threading.Thread(target=pump) for _ in range(3)]
            for t in threads:
                t.start()
            while len(results) < 20:
                time.sleep(0.002)
            displaced = svc.swap(model2, "v2")
            with lock:
                at_swap = len(results)
            while len(results) < at_swap + 20:
                time.sleep(0.002)
            stop.set()
            for t in threads:
                t.join(timeout=60.0)
        assert displaced == "v1"
        versions = {r.version for r in results}
        assert versions == {"v1", "v2"}, versions
        for res in results:
            assert res.ok, (res.reason, res.detail)
            want = want_v1 if res.version == "v1" else want_v2
            np.testing.assert_array_equal(res.labels, want)

    def test_swap_prewarms_previously_compiled_shapes(self, fitted):
        model, _ = fitted
        with _service(model, replicas=1) as svc:
            svc.submit(np.zeros((2, model.n_cols), np.float32),
                       k=2).result(timeout=60.0)
            warmed_before = set(svc._engine.warmed_keys())
            assert ("rows", 2) in warmed_before
            svc.swap(model, "v2")
            assert set(svc._engine.warmed_keys()) >= warmed_before
            assert svc.version == "v2"

    def test_swap_async_resolves_and_serves(self, fitted):
        model, _ = fitted
        with _service(model, replicas=1) as svc:
            done = svc.swap_async(lambda: model, "v2")
            res = done.result(timeout=60.0)
            assert res.ok and res.version == "v2"
            out = svc.submit(
                np.zeros((2, model.n_cols), np.float32)).result(timeout=60.0)
            assert out.version == "v2"
            fail = svc.swap_async(
                lambda: (_ for _ in ()).throw(RuntimeError("fit died")),
                "v3")
            bad = fail.result(timeout=60.0)
            assert not bad.ok and bad.reason == "internal_error"
            assert svc.version == "v2"   # failed swap changes nothing


class TestRegistry:
    def test_publish_load_roundtrip_with_provenance(self, fitted, tmp_path):
        model, cfg = fitted
        reg = streaming.ModelRegistry(str(tmp_path))
        ent = reg.publish("planted", model, cfg=cfg,
                          metrics={"row_nmi": 0.97},
                          data_fingerprint="stream:demo")
        assert ent.version == "v_000001"
        assert ent.config_hash == streaming.config_hash(cfg)
        back, ent2 = reg.load("planted")
        assert ent2 == ent
        assert ent2.metrics == {"row_nmi": 0.97}
        assert ent2.data_fingerprint == "stream:demo"
        np.testing.assert_array_equal(np.asarray(back.row_sigs),
                                      np.asarray(model.row_sigs))

    def test_versions_are_monotonic_and_immutable(self, fitted, tmp_path):
        model, cfg = fitted
        reg = streaming.ModelRegistry(str(tmp_path))
        reg.publish("m", model, cfg=cfg)
        reg.publish("m", model, cfg=cfg)
        assert reg.versions("m") == ["v_000001", "v_000002"]
        assert reg.latest("m") == "v_000002"
        assert reg.names() == ["m"]

    def test_crashed_publish_is_invisible_and_skipped(self, fitted, tmp_path):
        model, cfg = fitted
        reg = streaming.ModelRegistry(str(tmp_path))
        reg.publish("m", model, cfg=cfg)
        # a claim that never committed (publisher crashed after mkdir)
        os.mkdir(tmp_path / "m" / "v_000099")
        assert reg.versions("m") == ["v_000001"]
        with pytest.raises(streaming.ModelLoadError, match="no committed"):
            reg.entry("m", "v_000099")
        # the next publish allocates past the dead claim, never into it
        ent = reg.publish("m", model, cfg=cfg)
        assert ent.version == "v_000100"

    def test_bad_name_is_loud(self, tmp_path):
        reg = streaming.ModelRegistry(str(tmp_path))
        with pytest.raises(ValueError, match="bad model name"):
            reg.versions("../escape")

    def test_fingerprint_tracks_content(self, fitted):
        model, cfg = fitted
        fp = streaming.model_fingerprint(model)
        assert fp == streaming.model_fingerprint(model)
        bumped = model._replace(
            row_votes=model.row_votes.at[0, 0].add(1.0))
        assert streaming.model_fingerprint(bumped) != fp
        assert streaming.config_hash({"b": 1, "a": 2}) == \
            streaming.config_hash({"a": 2, "b": 1})
        assert streaming.config_hash(cfg) != streaming.config_hash(None)

    def test_registry_feeds_swap_async(self, fitted, tmp_path):
        # the intended deploy loop: background fit -> publish -> swap
        model, cfg = fitted
        reg = streaming.ModelRegistry(str(tmp_path))
        ent = reg.publish("live", model, cfg=cfg)
        with _service(model, replicas=1) as svc:
            done = svc.swap_async(lambda: reg.load("live")[0], ent.version)
            assert done.result(timeout=120.0).ok
            assert svc.version == "v_000001"


class TestShardingPolicy:
    def test_specs_shard_divisible_leading_dims_only(self, fitted):
        import jax
        from jax.sharding import Mesh
        from jax.sharding import PartitionSpec as P

        from repro.runtime import shardings

        model, _ = fitted
        # a 1-device mesh exercises the policy shape (size-1 divides all)
        mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
        specs = shardings.serve_model_specs(model, mesh)
        assert specs.row_sigs == P("data", None)       # (K, q) 2-D table
        assert specs.row_votes == P("data", None)      # (M, K)
        assert specs.anchor_rows == P(None)            # 1-D replicates
        assert specs.row_mean == P(None)

    def test_indivisible_dims_relax_to_replication(self):
        from jax.sharding import PartitionSpec as P

        from repro.runtime import shardings

        class FakeMesh:
            # stand-in exposing only .shape, to test the divisibility
            # rule against a mesh size no single-device host can build
            shape = {"data": 8}

        tree = {"sigs": np.zeros((24, 7)), "odd": np.zeros((9, 4)),
                "vec": np.zeros((24,))}
        specs = shardings.serve_model_specs(tree, FakeMesh())
        assert specs["sigs"] == P("data", None)   # 24 % 8 == 0
        assert specs["odd"] == P(None, None)      # 9 % 8 != 0 -> replicate
        assert specs["vec"] == P(None)


@pytest.mark.slow
def test_sharded_service_matches_single_device():
    """8-device host mesh (subprocess): the cluster-sharded service
    returns byte-identical labels to an unsharded one."""
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import numpy as np
        from repro import obs, streaming
        from repro.data import planted_cocluster_matrix

        assert len(jax.devices()) == 8, jax.devices()
        rng = np.random.default_rng(0)
        data = planted_cocluster_matrix(rng, 256, 128, k=4, d=4,
                                        signal=4.0, noise=0.6)
        cfg = streaming.StreamConfig(n_row_clusters=4, n_col_clusters=4,
                                     seed=0)
        model, _ = streaming.fit(
            streaming.iter_row_chunks(data.matrix, 128), cfg)
        x = rng.normal(size=(32, model.n_cols)).astype(np.float32)

        def run(shard):
            svc = streaming.AssignService(
                model, version="v1",
                config=streaming.ServeConfig(batch=16, replicas=2,
                                             shard=shard),
                metrics=obs.Registry())
            with svc:
                if shard:
                    assert svc._engine.mesh is not None
                res = svc.submit(x[:16]).result(timeout=120.0)
                res2 = svc.submit(x[16:], k=2).result(timeout=120.0)
            assert res.ok and res2.ok
            return res.labels, res2.labels

        a1, a2 = run(shard=True)
        b1, b2 = run(shard=False)
        np.testing.assert_array_equal(a1, b1)
        np.testing.assert_array_equal(a2, b2)
        print("SHARDED_PARITY_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARDED_PARITY_OK" in proc.stdout
