"""Differential fuzz: execution paths of the LAMC pipeline must agree.

Seeded sweep over shapes, densities, and plan configs, checking three
differential contracts on every drawn case:

  * dense vs ``input_format="bcoo"`` — exact label parity (the sparse
    block scatter is bit-exact, DESIGN.md §9);
  * ``spmm_impl="tiled"`` vs ``"dual_ell"`` vs ``"dense"`` on the BCOO
    path — multi-block plans densify their blocks, so the backend knob
    must not perturb labels at all;
  * hard mode vs degenerate overlap mode (``overlap_threshold > 0.5``,
    ``min_membership=1``) — the threshold-reduction invariant
    (DESIGN.md §11) on both the dense and sparse paths.

A small always-on subset keeps the contracts in the default gate; the
full sweep is ``-m slow`` (CI's slow lane) because every case pays its
own jit trace.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import LAMCConfig, lamc_cocluster
from repro.core.partition import PartitionPlan
from repro.data import planted_cocluster_matrix, to_bcoo


def _draw_case(seed: int):
    """One fuzz case: planted matrix + a valid multi-block plan + config."""
    rng = np.random.default_rng(seed)
    m_grid = int(rng.choice([1, 2]))
    n_grid = int(rng.choice([2, 2, 4]))
    phi = int(rng.choice([48, 64, 96]))
    psi = int(rng.choice([40, 48, 64]))
    rows = m_grid * phi + int(rng.integers(0, 8))     # ragged leftovers too
    cols = n_grid * psi + int(rng.integers(0, 8))
    k = int(rng.choice([2, 3, 4]))
    density = float(rng.choice([1.0, 0.4, 0.15]))
    t_p = int(rng.choice([2, 3]))
    data = planted_cocluster_matrix(
        rng, rows, cols, k=k, d=k, signal=5.0, noise=0.5, density=density)
    plan = PartitionPlan(rows, cols, m=m_grid, n=n_grid, phi=phi, psi=psi,
                         t_p=t_p, seed=seed)
    cfg = LAMCConfig(n_row_clusters=k, n_col_clusters=k)
    return data, plan, cfg


def _labels(out):
    return np.asarray(out.row_labels), np.asarray(out.col_labels)


def _check_case(seed: int):
    data, plan, cfg = _draw_case(seed)
    a = jnp.asarray(data.matrix)
    a_sp = to_bcoo(data.matrix)
    ctx = f"seed={seed} shape={data.shape} plan=({plan.m}x{plan.n}) t_p={plan.t_p}"

    out_dense = lamc_cocluster(a, cfg, plan=plan)
    rl, cl = _labels(out_dense)

    # dense vs bcoo, and the SpMM backend knob on the bcoo path
    for impl in ("auto", "tiled", "dual_ell"):
        out_sp = lamc_cocluster(
            a_sp, dataclasses.replace(cfg, input_format="bcoo",
                                      spmm_impl=impl), plan=plan)
        rs, cs = _labels(out_sp)
        assert np.array_equal(rl, rs), (ctx, impl)
        assert np.array_equal(cl, cs), (ctx, impl)

    # hard vs degenerate overlap on both input formats
    forced = dataclasses.replace(cfg, assignment="overlap",
                                 overlap_threshold=0.75, min_membership=1)
    for inp, c in ((a, forced),
                   (a_sp, dataclasses.replace(forced, input_format="bcoo"))):
        out_f = lamc_cocluster(inp, c, plan=plan)
        rf, cf = _labels(out_f)
        assert np.array_equal(rl, rf), ctx
        assert np.array_equal(cl, cf), ctx
        mem = np.asarray(out_f.row_membership)
        assert (mem.sum(1) == 1).all(), ctx
        assert (mem.argmax(1) == rl).all(), ctx
        cmem = np.asarray(out_f.col_membership)
        assert (cmem.sum(1) == 1).all() and (cmem.argmax(1) == cl).all(), ctx


# always-on subset: two seeds cover a dense and a sparse draw (seeds
# chosen so the drawn densities differ); the full sweep runs in the slow
# lane
ALWAYS_ON = [0, 3]


@pytest.mark.parametrize("seed", ALWAYS_ON)
def test_parity_fuzz_fast(seed):
    _check_case(seed)


@pytest.mark.slow
@pytest.mark.parametrize("seed", [s for s in range(12) if s not in ALWAYS_ON])
def test_parity_fuzz_sweep(seed):
    _check_case(seed)
