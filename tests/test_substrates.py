"""Substrate tests: optimizer, schedules, gradient compression, checkpoint
manager, fault-tolerant training loop, data pipeline."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.checkpoint import checkpoint as ckpt
from repro.data.tokens import TokenBatchSpec, make_batch
from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    cosine_schedule,
    grad_compress,
    wsd_schedule,
)
from repro.runtime.fault_tolerance import FailureInjector, SimulatedFailure, run_with_recovery


class TestAdamW:
    def _quadratic(self):
        target = {"a": jnp.array([1.0, -2.0, 3.0]), "b": jnp.array([[0.5]])}
        def loss(p):
            return sum(jnp.sum((x - t) ** 2)
                       for x, t in zip(jax.tree.leaves(p), jax.tree.leaves(target)))
        return target, loss

    def test_converges_on_quadratic(self):
        target, loss = self._quadratic()
        params = jax.tree.map(jnp.zeros_like, target)
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        for _ in range(300):
            grads = jax.grad(loss)(params)
            params, state, _ = adamw_update(cfg, params, grads, state)
        assert float(loss(params)) < 1e-3

    def test_weight_decay_shrinks(self):
        params = {"w": jnp.ones((4,))}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5)
        zero_grads = {"w": jnp.zeros((4,))}
        params2, _, _ = adamw_update(cfg, params, zero_grads, state)
        assert float(jnp.max(params2["w"])) < 1.0

    def test_clipping_bounds_update(self):
        params = {"w": jnp.zeros((4,))}
        state = adamw_init(params)
        cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
        huge = {"w": jnp.full((4,), 1e6)}
        _, _, m = adamw_update(cfg, params, huge, state)
        assert float(m["grad_norm"]) > 1e5  # reported pre-clip norm

    def test_state_tree_matches_params(self):
        params = {"x": jnp.zeros((2, 3)), "nested": {"y": jnp.zeros((4,))}}
        state = adamw_init(params)
        assert jax.tree.structure(state.m) == jax.tree.structure(params)


class TestSchedules:
    def test_wsd_phases(self):
        kw = dict(warmup_steps=10, stable_steps=100, decay_steps=50)
        assert float(wsd_schedule(0, **kw)) < 0.2
        assert abs(float(wsd_schedule(50, **kw)) - 1.0) < 1e-6
        assert abs(float(wsd_schedule(109, **kw)) - 1.0) < 1e-6
        end = float(wsd_schedule(160, **kw))
        assert abs(end - 0.1) < 0.02

    @given(step=st.integers(0, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_schedules_bounded(self, step):
        w = float(wsd_schedule(step, warmup_steps=100, stable_steps=5000,
                               decay_steps=1000))
        c = float(cosine_schedule(step, warmup_steps=100, total_steps=10_000))
        assert 0.0 < w <= 1.0 + 1e-6
        assert 0.0 < c <= 1.0 + 1e-6


class TestGradCompression:
    def test_int8_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(256,)).astype(np.float32))
        q, scale = grad_compress.int8_compress(g)
        back = grad_compress.int8_decompress(q, scale)
        assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.51 + 1e-6

    def test_topk_keeps_largest(self):
        g = jnp.asarray(np.array([0.1, -5.0, 0.2, 4.0, -0.05], np.float32))
        vals, idx, shape = grad_compress.topk_compress(g, fraction=0.4)
        back = grad_compress.topk_decompress(vals, idx, shape)
        np.testing.assert_allclose(np.array(back),
                                   [0.0, -5.0, 0.0, 4.0, 0.0], atol=1e-6)

    def test_error_feedback_preserves_signal(self):
        """Sum of (decompressed + residual) over steps ~= sum of raw grads."""
        rng = np.random.default_rng(1)
        residual = jnp.zeros((64,))
        total_sent = jnp.zeros((64,))
        total_raw = jnp.zeros((64,))
        for i in range(20):
            g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
            total_raw += g
            gf = g + residual
            vals, idx, shape = grad_compress.topk_compress(gf, 0.25)
            approx = grad_compress.topk_decompress(vals, idx, shape)
            residual = gf - approx
            total_sent += approx
        # residual bounded -> accumulated signal close
        err = float(jnp.linalg.norm(total_sent + residual - total_raw))
        assert err < 1e-4

    def test_payload_sizes(self):
        g = jnp.zeros((1000,))
        assert grad_compress.payload_bytes(g, "int8") == 1004
        assert grad_compress.payload_bytes(g, "topk", 0.05) == 50 * 8
        assert grad_compress.payload_bytes(g, "none") == 4000


class TestCheckpoint:
    def _tree(self):
        return {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b16": jnp.ones((4,), jnp.bfloat16),
                "nested": {"s": jnp.zeros((), jnp.int32)}}

    def test_save_restore_roundtrip(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 5, tree, extra_meta={"note": "x"})
        like = jax.eval_shape(lambda: tree)
        back, extra = ckpt.restore(str(tmp_path), 5, like)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
        assert back["b16"].dtype == jnp.bfloat16
        assert extra == {"note": "x"}

    def test_latest_and_keep(self, tmp_path):
        tree = self._tree()
        for s in (1, 3, 2):
            ckpt.save(str(tmp_path), s, tree)
        assert ckpt.latest_step(str(tmp_path)) == 3
        assert ckpt.available_steps(str(tmp_path)) == [1, 2, 3]

    def test_uncommitted_checkpoint_ignored(self, tmp_path):
        tree = self._tree()
        ckpt.save(str(tmp_path), 1, tree)
        # simulate a crash mid-write: tmp dir without sentinel
        os.makedirs(tmp_path / "step_00000009.tmp")
        assert ckpt.latest_step(str(tmp_path)) == 1

    def test_shape_mismatch_rejected(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"w": jnp.zeros((2, 2))})
        like = jax.eval_shape(lambda: {"w": jnp.zeros((3, 3))})
        with pytest.raises(ValueError, match="shape mismatch"):
            ckpt.restore(str(tmp_path), 1, like)


class TestFaultTolerance:
    def test_injector_fires_once(self):
        inj = FailureInjector(fail_at_steps=(3,))
        inj.maybe_fail(2)
        with pytest.raises(SimulatedFailure):
            inj.maybe_fail(3)
        inj.maybe_fail(3)  # second time: no raise

    def test_run_with_recovery_completes(self, tmp_path):
        inj = FailureInjector(fail_at_steps=(4, 7))
        log = []

        def step_fn(step, state):
            inj.maybe_fail(step)
            log.append(step)
            return state + 1

        def restore_state(step):
            if step < 0:
                return 0
            tree, _ = ckpt.restore(str(tmp_path), step,
                                   jax.eval_shape(lambda: jnp.zeros((), jnp.int32)))
            return tree

        final, stats = run_with_recovery(
            total_steps=10,
            step_fn=step_fn,
            state=jnp.zeros((), jnp.int32),
            ckpt_dir=str(tmp_path),
            save_every=2,
            restore_state=restore_state,
            )
        assert stats["failures"] == 2
        assert stats["final_step"] == 10
        assert int(final) >= 10 - 2  # restored state may replay some steps


class TestDataPipeline:
    def test_deterministic_restart(self):
        spec = TokenBatchSpec(batch_size=4, seq_len=32, vocab_size=1000, seed=7)
        b1 = make_batch(spec, 5)
        b2 = make_batch(spec, 5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_targets_are_shifted_tokens(self):
        spec = TokenBatchSpec(batch_size=2, seq_len=16, vocab_size=500, seed=0)
        b = make_batch(spec, 0)
        assert b["tokens"].shape == (2, 16)
        assert b["targets"].shape == (2, 16)
        # bigram structure: some fraction of targets follow succ map
        assert (b["targets"][:, :-1] == b["tokens"][:, 1:]).all()

    def test_learnable_structure_present(self):
        """The injected bigram rule must hold ~50% of the time."""
        spec = TokenBatchSpec(batch_size=8, seq_len=256, vocab_size=8192, seed=1)
        b = make_batch(spec, 0)
        probs = 8192
        succ = (np.arange(probs) * 31 + 7) % probs
        hits = (succ[b["tokens"][:, :-1]] == b["tokens"][:, 1:]).mean()
        assert 0.35 < hits < 0.7, hits
