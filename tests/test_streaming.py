"""Streaming subsystem: out-of-core fit, model artifact, online assignment.

Covers the DESIGN.md §10 contracts:
  * streaming.fit over row chunks reproduces the batch co-clustering
    (NMI >= 0.9 at equal seeds) for dense AND BCOO chunk streams, with
    peak resident data bounded by chunk + model;
  * the CoclusterModel artifact round-trips through repro.checkpoint and
    load_model fails loudly on unfitted/stale checkpoints;
  * out-of-sample assign_rows/assign_cols agree with the fitted labels
    and recover planted labels on held-out rows;
  * the Pallas cosine scoring kernel matches its ref oracle.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro import streaming
from repro.core import LAMCConfig, lamc_cocluster
from repro.core.metrics import nmi
from repro.core.partition import PartitionPlan
from repro.data import planted_cocluster_matrix, to_bcoo


@pytest.fixture(scope="module")
def planted():
    rng = np.random.default_rng(0)
    return planted_cocluster_matrix(rng, 600, 500, k=5, d=5,
                                    signal=4.0, noise=0.6)


@pytest.fixture(scope="module")
def batch_result(planted):
    cfg = LAMCConfig(n_row_clusters=5, n_col_clusters=5,
                     min_cocluster_rows=120, min_cocluster_cols=100)
    plan = PartitionPlan(600, 500, m=2, n=2, phi=300, psi=250, t_p=3, seed=0)
    return cfg, lamc_cocluster(jnp.asarray(planted.matrix), cfg, plan=plan)


@pytest.fixture(scope="module")
def stream_model(planted, batch_result):
    cfg, _ = batch_result
    scfg = streaming.stream_config_from_lamc(cfg, chunk_resamples=2)
    return streaming.fit(streaming.iter_row_chunks(planted.matrix, 150), scfg)


class TestModelArtifact:
    def test_batch_result_carries_serving_fields(self, batch_result):
        _, out = batch_result
        assert out.row_sigs.shape == (5, 64)
        assert out.col_sigs.shape == (5, 64)
        assert out.anchor_rows.shape == (64,)
        assert out.anchor_cols.shape == (64,)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(out.row_sigs), axis=1), 1.0, atol=1e-5)

    def test_model_roundtrip_through_checkpoint(self, batch_result, tmp_path):
        cfg, out = batch_result
        model = streaming.model_from_result(out)
        streaming.save_model(str(tmp_path), model, cfg=cfg, plan=out.plan)
        back, meta = streaming.load_model(str(tmp_path))
        assert meta["kind"] == streaming.MODEL_KIND
        assert meta["config"]["n_row_clusters"] == 5
        assert meta["plan"]["t_p"] == 3
        for a, b in zip(model, back):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_load_model_unfitted_dir_is_loud(self, tmp_path):
        with pytest.raises(streaming.ModelLoadError, match="fit a model first"):
            streaming.load_model(str(tmp_path / "nope"))

    def test_load_model_foreign_checkpoint_is_loud(self, tmp_path):
        from repro import checkpoint as ckpt

        ckpt.save(str(tmp_path), 0, {"weights": jnp.ones((3, 3))})
        with pytest.raises(streaming.ModelLoadError, match="not a CoclusterModel"):
            streaming.load_model(str(tmp_path))

    def test_model_from_result_rejects_stripped_result(self, batch_result):
        _, out = batch_result
        stripped = out._replace(row_sigs=None)
        with pytest.raises(ValueError, match="missing serving fields"):
            streaming.model_from_result(stripped)


class TestStreamingFit:
    def test_dense_stream_matches_batch(self, planted, batch_result, stream_model):
        _, out = batch_result
        model, stats = stream_model
        assert nmi(np.asarray(model.row_labels), np.asarray(out.row_labels)) >= 0.9
        assert nmi(np.asarray(model.col_labels), np.asarray(out.col_labels)) >= 0.9
        assert stats.rows_seen == 600 and stats.chunks == 4

    def test_bcoo_stream_matches_dense_stream(self, planted, batch_result,
                                              stream_model):
        cfg, _ = batch_result
        dense_model, _ = stream_model
        scfg = streaming.stream_config_from_lamc(cfg, chunk_resamples=2)
        model, _ = streaming.fit(
            streaming.iter_row_chunks(planted.matrix, 150, format="bcoo"), scfg)
        assert nmi(np.asarray(model.row_labels),
                   np.asarray(dense_model.row_labels)) >= 0.99
        assert nmi(np.asarray(model.col_labels),
                   np.asarray(dense_model.col_labels)) >= 0.99

    def test_memory_is_chunk_plus_model_bound(self, planted, stream_model):
        _, stats = stream_model
        full = planted.matrix.nbytes
        assert stats.peak_chunk_bytes == 150 * 500 * 4   # one chunk, not M x N
        assert stats.peak_chunk_bytes < full / 2
        # accumulator state is model-sized: O(M*K + K*N + q*N), not O(M*N)
        assert stats.state_bytes < full / 2

    def test_deterministic_given_seed(self, planted, batch_result):
        cfg, _ = batch_result
        scfg = streaming.stream_config_from_lamc(cfg)
        m1, _ = streaming.fit(streaming.iter_row_chunks(planted.matrix, 200), scfg)
        m2, _ = streaming.fit(streaming.iter_row_chunks(planted.matrix, 200), scfg)
        np.testing.assert_array_equal(np.asarray(m1.row_labels),
                                      np.asarray(m2.row_labels))
        np.testing.assert_array_equal(np.asarray(m1.col_labels),
                                      np.asarray(m2.col_labels))

    def test_mismatched_chunk_width_is_loud(self, planted, batch_result):
        cfg, _ = batch_result
        fitter = streaming.StreamingCocluster(
            streaming.stream_config_from_lamc(cfg))
        fitter.partial_fit(jnp.asarray(planted.matrix[:100]))
        with pytest.raises(ValueError, match="columns"):
            fitter.partial_fit(jnp.asarray(planted.matrix[:100, :250]))

    def test_empty_stream_is_loud(self, batch_result):
        cfg, _ = batch_result
        with pytest.raises(ValueError, match="empty"):
            streaming.fit([], streaming.stream_config_from_lamc(cfg))

    def test_chunk_error_names_the_chunk_index(self, planted, batch_result):
        cfg, _ = batch_result
        fitter = streaming.StreamingCocluster(
            streaming.stream_config_from_lamc(cfg))
        fitter.partial_fit(jnp.asarray(planted.matrix[:100]))
        fitter.partial_fit(jnp.asarray(planted.matrix[100:200]))
        with pytest.raises(ValueError, match="chunk 2"):
            fitter.partial_fit(jnp.asarray(planted.matrix[:100, :250]))

    def test_dtype_drift_is_loud(self, planted, batch_result):
        cfg, _ = batch_result
        fitter = streaming.StreamingCocluster(
            streaming.stream_config_from_lamc(cfg))
        fitter.partial_fit(planted.matrix[:100].astype(np.float32))
        with pytest.raises(ValueError, match="dtype"):
            fitter.partial_fit(planted.matrix[100:200].astype(np.float16))

    def test_dense_bcoo_flip_is_loud(self, planted, batch_result):
        cfg, _ = batch_result
        fitter = streaming.StreamingCocluster(
            streaming.stream_config_from_lamc(cfg))
        fitter.partial_fit(jnp.asarray(planted.matrix[:100]))
        with pytest.raises(ValueError, match="BCOO"):
            fitter.partial_fit(to_bcoo(planted.matrix[100:200]))

    def test_wrong_rank_is_loud(self, planted, batch_result):
        cfg, _ = batch_result
        fitter = streaming.StreamingCocluster(
            streaming.stream_config_from_lamc(cfg))
        with pytest.raises(ValueError, match="2-D"):
            fitter.partial_fit(jnp.asarray(planted.matrix[0]))


class TestOutOfSampleAssignment:
    """Held-out rows scored against signatures must recover the clustering."""

    @pytest.fixture(scope="class")
    def heldout(self):
        # one planted population, row-split into train + held-out
        rng = np.random.default_rng(7)
        data = planted_cocluster_matrix(rng, 760, 500, k=5, d=5,
                                        signal=4.0, noise=0.6)
        return (data.matrix[:600], data.row_labels[:600],
                data.matrix[600:], data.row_labels[600:], data.col_labels)

    @pytest.mark.parametrize("fmt", ["dense", "bcoo"])
    def test_heldout_rows_recover_planted_labels(self, heldout, fmt):
        train, train_truth, test, test_truth, _ = heldout
        scfg = streaming.StreamConfig(n_row_clusters=5, n_col_clusters=5,
                                      chunk_resamples=2, seed=0)
        model, _ = streaming.fit(
            streaming.iter_row_chunks(train, 150, format=fmt), scfg)
        assert nmi(np.asarray(model.row_labels), train_truth) >= 0.9
        res = streaming.assign_rows(model, jnp.asarray(test))
        assert nmi(np.asarray(res.labels), test_truth) >= 0.9

    def test_assignment_agrees_with_batch_fit(self, batch_result, planted):
        _, out = batch_result
        model = streaming.model_from_result(out)
        a = jnp.asarray(planted.matrix)
        rows = streaming.assign_rows(model, a)
        cols = streaming.assign_cols(model, a.T)
        assert nmi(np.asarray(rows.labels), np.asarray(out.row_labels)) >= 0.9
        assert nmi(np.asarray(cols.labels), np.asarray(out.col_labels)) >= 0.9

    def test_bcoo_requests(self, batch_result, planted):
        _, out = batch_result
        model = streaming.model_from_result(out)
        dense = streaming.assign_rows(model, jnp.asarray(planted.matrix[:64]))
        sparse_req = streaming.assign_rows(model, to_bcoo(planted.matrix[:64]))
        np.testing.assert_array_equal(np.asarray(dense.labels),
                                      np.asarray(sparse_req.labels))

    def test_zero_row_batch_returns_empty(self, batch_result):
        # a coalescer flush (or an empty poll) legitimately produces a
        # zero-row request; it must return empty results, not crash the
        # scoring kernel with a zero-size grid
        _, out = batch_result
        model = streaming.model_from_result(out)
        res = streaming.assign_rows(model, jnp.zeros((0, model.n_cols)))
        assert res.labels.shape == (0,) and res.score.shape == (0,)
        assert res.labels.dtype == jnp.int32
        cres = streaming.assign_cols(model, jnp.zeros((0, model.n_rows)))
        assert cres.labels.shape == (0,)
        topk = streaming.assign_rows_topk(
            model, jnp.zeros((0, model.n_cols)), k=3)
        assert topk.labels.shape == (0, 3) and topk.scores.shape == (0, 3)
        # empty batches still validate k: a bad k is a caller bug at any size
        with pytest.raises(ValueError, match="k"):
            streaming.assign_rows_topk(
                model, jnp.zeros((0, model.n_cols)), k=99)

    def test_wrong_width_is_loud(self, batch_result, planted):
        _, out = batch_result
        model = streaming.model_from_result(out)
        with pytest.raises(ValueError, match="row vectors"):
            streaming.assign_rows(model, jnp.ones((4, 123)))
        with pytest.raises(ValueError, match="column vectors"):
            streaming.assign_cols(model, jnp.ones((4, 123)))
        # BCOO requests must hit the same validation — out-of-range anchor
        # gathers would otherwise silently read zeros
        with pytest.raises(ValueError, match="row vectors"):
            streaming.assign_rows(model, to_bcoo(np.ones((4, 123))))


class TestCosineAssignKernel:
    def test_matches_ref_oracle(self):
        from repro.kernels import ops, ref

        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(133, 70)).astype(np.float32))
        s = rng.normal(size=(5, 70)).astype(np.float32)
        s /= np.linalg.norm(s, axis=1, keepdims=True)
        s = jnp.asarray(s)
        labels, score = ops.cosine_assign(x, s)
        ref_labels, ref_score = ref.cosine_assign_ref(x, s)
        np.testing.assert_array_equal(np.asarray(labels), np.asarray(ref_labels))
        np.testing.assert_allclose(np.asarray(score), np.asarray(ref_score),
                                   rtol=1e-5, atol=1e-5)

    def test_padded_signature_rows_never_win(self):
        """All-negative real scores: zero-padded rows would tie at 0 and
        win without the k_valid mask."""
        from repro.kernels import ops

        x = -jnp.ones((9, 33), jnp.float32)
        s = jnp.ones((3, 33), jnp.float32) / np.sqrt(33.0)  # pads K 3 -> 8
        labels, score = ops.cosine_assign(x, s)
        assert int(np.max(np.asarray(labels))) < 3
        assert float(np.max(np.asarray(score))) < 0.0


class TestServeDriver:
    def test_fit_save_serve_loop(self, tmp_path):
        from repro.launch import serve_lamc

        ckpt_dir = str(tmp_path / "model")
        serve_lamc.fit_demo_model(ckpt_dir, n_rows=256, n_cols=128, k=3,
                                  chunk_rows=128)
        out = serve_lamc.serve(ckpt_dir, batch=8, requests=4, warmup=1,
                               axis="rows")
        assert out["serve_assign_rows_p50_us"] > 0
        assert out["serve_assign_rows_qps"] > 0
        assert out["_model_kind"] == streaming.MODEL_KIND
        assert len(out["_labels_sample"]) == 8

    def test_partial_final_batch_qps_counts_real_rows(self, tmp_path):
        # 40 rows in 16-row batches = 2 full + one 8-row tail. The old
        # QPS formula charged batch * hist.count = 48 rows — an
        # over-report whenever the tail batch was short.
        from repro.launch import serve_lamc

        ckpt_dir = str(tmp_path / "model")
        serve_lamc.fit_demo_model(ckpt_dir, n_rows=256, n_cols=128, k=3,
                                  chunk_rows=128)
        out = serve_lamc.serve(ckpt_dir, batch=16, rows=40, warmup=1,
                               axis="rows")
        assert out["serve_assign_rows_rows"] == 40
        # labels sample comes from the last (8-row) batch
        assert len(out["_labels_sample"]) == 8
        assert out["serve_assign_rows_qps"] > 0

    def test_all_requests_rejected_still_reports(self, tmp_path):
        # every batch bounced: the error counter must come back without
        # tripping over empty percentiles or a never-assigned output
        from repro.launch import serve_lamc

        ckpt_dir = str(tmp_path / "model")
        serve_lamc.fit_demo_model(ckpt_dir, n_rows=128, n_cols=64, k=2,
                                  chunk_rows=64)
        out = serve_lamc.serve(ckpt_dir, batch=8, requests=0, warmup=1,
                               axis="rows", adversarial=3)
        assert out["serve_assign_rows_errors"] == 3
        assert np.isnan(out["serve_assign_rows_p50_us"])
        assert np.isnan(out["serve_assign_rows_p99_us"])
        assert out["serve_assign_rows_qps"] == 0.0
        assert out["_labels_sample"] == []
