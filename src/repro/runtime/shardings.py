"""Auto-sharding policy: divisibility-aware TP + FSDP PartitionSpecs for
every parameter / activation / cache in the model zoo.

Policy (DESIGN.md):
  * TP over ``model`` (16): attention heads when ``H % 16 == 0``, else the
    head axis is replicated (smollm's 15 heads, recurrentgemma's 10);
    d_ff always (all assigned d_ff are multiples of 16); vocab (padded to a
    multiple first — see ``pad_vocab``); experts when ``E % 16 == 0`` (EP).
  * FSDP over ``data`` (16, and ``pod`` x ``data`` = 32 in multi-pod): the
    largest remaining dim of every big tensor. XLA re-gathers per layer
    under the scan — the standard FSDP schedule.
  * Activations: batch over (``pod``,) ``data``; decode KV caches shard
    heads over ``model`` when divisible, else the *sequence* axis
    (distributed-softmax decode — attention reductions lower to psum).

Rules are name-based over the param pytree paths, with per-tensor
divisibility checks that relax to replication (never fail to lower).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = ["MeshAxes", "pad_vocab", "param_specs", "param_shardings",
           "batch_specs", "cache_specs", "path_name", "stream_state_specs",
           "serve_model_specs", "serve_model_shardings"]


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical axis names present in the mesh."""
    data: tuple[str, ...] = ("data",)      # ("pod","data") for multi-pod
    model: str = "model"

    @property
    def fsdp(self) -> tuple[str, ...]:
        return self.data


def pad_vocab(vocab: int, multiple: int = 256) -> int:
    return ((vocab + multiple - 1) // multiple) * multiple


def path_name(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


def _div(n: int, mesh: Mesh, axes) -> bool:
    size = 1
    for ax in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[ax]
    return n % size == 0 and n >= size


def _spec_for(name: str, shape: tuple[int, ...], mesh: Mesh, ax: MeshAxes,
              stacked: bool) -> P:
    """PartitionSpec for one parameter tensor.

    ``stacked``: leading dim is the scan/layer axis (never sharded).
    """
    dims: list[Any] = [None] * len(shape)
    core = shape[1:] if stacked else shape
    off = 1 if stacked else 0

    def set_dim(i, axis):
        dims[off + i] = axis

    model = ax.model
    leaf = name.rsplit("/", 1)[-1]

    if leaf == "table":                      # embedding (V, D)
        if _div(core[0], mesh, model):
            set_dim(0, model)
        if _div(core[1], mesh, ax.fsdp):
            set_dim(1, ax.fsdp)
    elif leaf in ("wq", "wk", "wv"):          # (D, H, Dh)
        if _div(core[1], mesh, model):
            set_dim(1, model)
        if _div(core[0], mesh, ax.fsdp):
            set_dim(0, ax.fsdp)
    elif leaf == "wo":                        # (H, Dh, D)
        if _div(core[0], mesh, model):
            set_dim(0, model)
        if _div(core[2], mesh, ax.fsdp):
            set_dim(2, ax.fsdp)
    elif "w_in" in name or "w_gate" in name or "w_out" in name:
        if len(core) == 3:                    # experts (E, D, F) / (E, F, D)
            if _div(core[0], mesh, model):
                set_dim(0, model)             # expert parallelism
            if _div(core[1], mesh, ax.fsdp):
                set_dim(1, ax.fsdp)
        elif len(core) == 2:                  # dense mlp (D, F) / (F, D)
            big = 0 if core[0] >= core[1] else 1
            ff_dim = big                      # ff is the larger dim
            if _div(core[ff_dim], mesh, model):
                set_dim(ff_dim, model)
            other = 1 - ff_dim
            if _div(core[other], mesh, ax.fsdp):
                set_dim(other, ax.fsdp)
    elif leaf == "w" and len(core) == 2:      # router (D,E), lm head (D,V), generic
        if _div(core[1], mesh, model):
            set_dim(1, model)
        if _div(core[0], mesh, ax.fsdp):
            set_dim(0, ax.fsdp)
    elif leaf == "w" and len(core) == 3:      # slstm gate (D, H, Dh)
        if _div(core[1], mesh, model):
            set_dim(1, model)
        if _div(core[0], mesh, ax.fsdp):
            set_dim(0, ax.fsdp)
    elif len(core) == 2 and min(core) >= 128:  # big square-ish (rglru gates...)
        if _div(core[1], mesh, model):
            set_dim(1, model)
        if _div(core[0], mesh, ax.fsdp):
            set_dim(0, ax.fsdp)
    # 1-D scales/biases and small tensors stay replicated
    return P(*dims)


def param_specs(cfg: ArchConfig, params_shape, mesh: Mesh,
                ax: MeshAxes = MeshAxes()):
    """Pytree of PartitionSpecs matching ``params_shape`` (eval_shape out)."""
    def one(path, leaf):
        name = path_name(path)
        stacked = name.startswith("units/") or name.startswith("encoder/blocks")
        return _spec_for(name, tuple(leaf.shape), mesh, ax, stacked)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(cfg: ArchConfig, params_shape, mesh: Mesh,
                    ax: MeshAxes = MeshAxes()):
    specs = param_specs(cfg, params_shape, mesh, ax)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def unit_gather_shardings(cfg: ArchConfig, params_shape, mesh: Mesh,
                          ax: MeshAxes = MeshAxes()):
    """TP-only shardings for ONE scan unit's parameter slice.

    Forces GSPMD to all-gather the (small) FSDP weight shards before each
    unit's matmuls instead of computing partial products against
    contraction-dim-sharded weights and all-reducing the (huge)
    activation-sized outputs — measured 34 GB -> ~2 GB of per-unit
    all-reduce traffic on llama4 train_4k (benchmarks/README.md §Perf M1).

    Returns a pytree matching ``params_shape['units']`` with the leading
    stack dim dropped and every FSDP (data) axis replaced by replication;
    None where no constraint is needed.
    """
    if "units" not in params_shape:
        return None
    full = param_specs(cfg, params_shape, mesh, ax)["units"]

    def strip(spec):
        if not isinstance(spec, P):
            return None
        dims = list(spec)[1:]  # drop the stacked-unit dim
        out = []
        for d_ in dims:
            if d_ is None:
                out.append(None)
            elif isinstance(d_, tuple):
                kept = tuple(x for x in d_ if x not in set(ax.fsdp))
                out.append(kept if kept else None)
            else:
                out.append(None if d_ in set(ax.fsdp) else d_)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(strip, full, is_leaf=lambda x: isinstance(x, P))


def stream_state_specs(tree, mesh: Mesh, axis: str = "data"):
    """Shard-or-replicate PartitionSpecs for an accumulated-state pytree.

    The elastic-restore policy for checkpoints whose structure is only
    known at load time (a streaming ``FitState``, an eval accumulator):
    each array leaf shards its *largest* ``axis``-divisible dimension over
    the mesh's ``axis`` and replicates everything else — small leaves
    (counters, per-chunk label rows, signature blocks) replicate whole.
    Pairs with ``fault_tolerance.elastic_restore`` to bring a fit state
    up on a different device count than the one that wrote it
    (tests/test_fault_tolerance.py drives this on a forced 8-device host
    mesh).
    """
    size = mesh.shape[axis]

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        dims: list[Any] = [None] * len(shape)
        for i in sorted(range(len(shape)), key=lambda i: -shape[i]):
            if shape[i] % size == 0 and shape[i] >= size:
                dims[i] = axis
                break
        return P(*dims)

    return jax.tree.map(one, tree)


def serve_model_specs(model, mesh: Mesh, axis: str = "data"):
    """PartitionSpecs for a ``CoclusterModel``'s serving tables.

    Policy for the assignment service (DESIGN.md §15): the per-cluster
    signature tables (``row_sigs``/``col_sigs``, ``(K, q)``) and the
    vote tables (``(M, K)``/``(N, K)``) shard their *leading* dimension
    over ``axis`` when divisible — the cosine scoring contraction is
    over ``q``, so a cluster-sharded table scores a slice of clusters
    per device and GSPMD lowers the argmax/top-k to a cross-device
    reduce. Everything 1-D (anchor index vectors, centering means,
    labels) replicates: the scorer gathers anchor coordinates on every
    device. Leaves whose leading dim does not divide the mesh relax to
    replication, never fail to lower (same contract as ``param_specs``).
    """
    size = mesh.shape[axis]

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if len(shape) >= 2 and shape[0] % size == 0 and shape[0] >= size:
            return P(axis, *([None] * (len(shape) - 1)))
        return P(*([None] * len(shape)))

    return jax.tree.map(one, model)


def serve_model_shardings(model, mesh: Mesh, axis: str = "data"):
    """``NamedSharding`` pytree for :func:`serve_model_specs`."""
    specs = serve_model_specs(model, mesh, axis)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_specs(cfg: ArchConfig, mesh: Mesh, ax: MeshAxes = MeshAxes(),
                batch: int | None = None):
    """Input batch sharding: batch over data axes when divisible."""
    data_ax = ax.fsdp
    ok = batch is None or _div(batch, mesh, data_ax)
    bdim = data_ax if ok else None
    return {
        "tokens": P(bdim, None),
        "targets": P(bdim, None),
        "frontend_embeds": P(bdim, None, None),
    }


def cache_specs(cfg: ArchConfig, cache_shape, mesh: Mesh,
                ax: MeshAxes = MeshAxes(), batch: int | None = None):
    """Decode-cache shardings: batch->data; heads->model if divisible, else
    seq->model (distributed-softmax decode)."""
    data_ax = ax.fsdp
    b_ok = batch is None or _div(batch, mesh, data_ax)
    bdim = data_ax if b_ok else None
    heads_div = _div(cfg.n_kv_heads, mesh, ax.model)

    def one(path, leaf):
        name = path_name(path)
        shape = tuple(leaf.shape)
        rank = len(shape)
        leaf_name = name.rsplit("/", 1)[-1]
        stacked = name.startswith("units")    # leading scan-unit axis
        off = 1 if stacked else 0
        dims = [None] * rank
        if leaf_name in ("k", "v"):
            # (B, Hkv, S, Dh), + unit axis when stacked
            dims[off + 0] = bdim
            if heads_div:
                dims[off + 1] = ax.model
            elif _div(shape[off + 2], mesh, ax.model):
                dims[off + 2] = ax.model      # shard sequence instead
        elif leaf_name in ("ks", "vs"):
            # int8-cache scales (B, Hkv, S): follow the k/v layout
            dims[off + 0] = bdim
            if heads_div:
                dims[off + 1] = ax.model
            elif _div(shape[off + 2], mesh, ax.model):
                dims[off + 2] = ax.model
        else:
            # recurrent states: (B, ...) after the optional unit axis
            if rank > off:
                dims[off] = bdim
        return P(*dims)

    return jax.tree_util.tree_map_with_path(one, cache_shape)
