"""Fault-tolerance runtime: failure simulation/detection, retry-from-
checkpoint, elastic re-meshing, straggler notes.

On a real pod, process failure surfaces as a collective timeout / ICI
error; the recovery loop is always the same shape:

    while step < total:
        try:
            step_out = train_step(...)
        except DeviceFailure:
            remesh if topology changed
            restore latest checkpoint
            continue

This module provides that loop's pieces in a testable form:

  * ``FailureInjector`` — deterministic step-indexed fault schedule
    (raises ``SimulatedFailure`` inside the step callable) so tests and the
    example driver exercise the real recovery path;
  * ``run_with_recovery`` — the retry loop: restore-from-latest + bounded
    retries + monotonic progress assertion;
  * ``elastic_restore`` — re-place a checkpoint onto a different mesh
    (shrunk/grown device count), using checkpoint.restore's sharding arg.

LAMC-specific resilience is handled upstream by the probabilistic model:
``probability.resamples_for_failures`` converts an expected block-failure
count into extra resamples T_p (DESIGN.md) — a *statistical* fault
budget no retry loop needs to see.

Straggler mitigation (design note, validated by construction): every
per-device program in this framework has static shapes and static trip
counts — no data-dependent loop bounds anywhere (fixed k-means/SVD/NMTF
iterations, fixed scan lengths, capacity-bounded MoE dispatch). A straggler
can therefore only be a hardware-slow chip, which synchronous SPMD absorbs
at the next collective; the LAMC resample margin additionally makes the
*output* robust to a straggler's blocks being dropped entirely.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable

import jax

from repro import obs
from repro.checkpoint import checkpoint as ckpt

logger = logging.getLogger("repro.fault_tolerance")

__all__ = ["SimulatedFailure", "FailureInjector", "run_with_recovery",
           "elastic_restore"]


class SimulatedFailure(RuntimeError):
    """Stands in for a device/process failure in tests and examples."""


@dataclasses.dataclass
class FailureInjector:
    """Raises at the configured steps — exactly once each."""
    fail_at_steps: tuple[int, ...] = ()
    _fired: set[int] = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected failure at step {step}")


def run_with_recovery(
    *,
    total_steps: int | None,
    step_fn: Callable[[int, Any], Any],       # (step, state) -> state
    state: Any,
    ckpt_dir: str,
    save_every: int,
    state_for_save: Callable[[Any], Any] = lambda s: s,
    restore_state: Callable[[int], Any] | None = None,
    max_retries: int = 8,
    start_step: int = 0,
    save_fn: Callable[[int, Any], None] | None = None,
) -> tuple[Any, dict]:
    """Drive ``step_fn`` with checkpoint/restart fault tolerance.

    ``restore_state(step)`` rebuilds runtime state from checkpoint ``step``
    (``restore_state(-1)`` = from scratch; defaults to requiring the
    caller to capture restore in step state). ``total_steps=None`` runs
    stream-driven: the loop ends when ``step_fn`` raises ``StopIteration``
    (an exhausted chunk iterator), with a final checkpoint of whatever
    progress followed the last periodic save. ``save_fn(step, state)``
    overrides the default ``checkpoint.save`` call (callers that attach
    their own ``extra_meta``/kind to the checkpoint). A step that is both
    a ``save_every`` multiple and the final step is saved exactly once.
    Returns (final_state, stats).
    """
    step = step0 = start_step
    retries = 0
    failures = 0
    last_saved: int | None = None

    _metrics = obs.get_registry()

    def _save(s: int, st: Any) -> None:
        nonlocal last_saved
        if s == last_saved:
            return  # already durable at this step — skip the duplicate write
        if save_fn is not None:
            save_fn(s, st)
        else:
            ckpt.save(ckpt_dir, s, state_for_save(st), extra_meta={"step": s})
        last_saved = s
        obs.event("recovery.checkpoint_saved", step=s)
        _metrics.counter(
            "recovery_checkpoints",
            help="checkpoints committed by run_with_recovery").inc()

    while total_steps is None or step < total_steps:
        try:
            state = step_fn(step, state)
        except StopIteration:
            if total_steps is not None:
                raise  # sized runs must not end early — surface the bug
            break  # stream exhausted: normal termination
        except SimulatedFailure as e:
            failures += 1
            retries += 1
            _metrics.counter(
                "recovery_failures",
                help="step failures seen by run_with_recovery").inc()
            if retries > max_retries:
                obs.event("recovery.retries_exhausted", failed_step=step,
                          retries=retries - 1, max_retries=max_retries)
                raise RuntimeError(f"exceeded {max_retries} retries") from e
            latest = ckpt.latest_step(ckpt_dir)
            if latest is not None and (last_saved is None
                                       or latest > last_saved):
                # ckpt_dir may hold stale steps from a previous run (fresh
                # fit into a dirty directory): only restore what THIS run
                # committed, else fall back to from-scratch/resume-point
                logger.warning(
                    "ignoring checkpoint step %s in %s: not written by this "
                    "run (last saved here: %s)", latest, ckpt_dir, last_saved)
                obs.event("recovery.stale_checkpoint", ignored_step=latest,
                          last_saved=last_saved)
                _metrics.counter(
                    "recovery_stale_checkpoints",
                    help="foreign checkpoint steps ignored on restore").inc()
                latest = last_saved
            logger.warning("step %d failed (%s); restoring from %s",
                           step, e, latest)
            obs.event("recovery.restore", failed_step=step,
                      target=-1 if latest is None else latest,
                      retries=retries, chunks_replayed=(
                          step - (step0 if latest is None else latest)))
            _metrics.counter(
                "recovery_restores",
                help="restore-from-checkpoint recoveries").inc()
            if latest is None:
                step = step0  # restart from scratch
                if restore_state is not None:
                    state = restore_state(-1)
            else:
                assert latest >= step0, (
                    f"checkpoint {latest} predates start step {step0}")
                step = latest
                if restore_state is not None:
                    state = restore_state(latest)
            continue
        step += 1
        retries = 0
        if step % save_every == 0 or (total_steps is not None
                                      and step == total_steps):
            _save(step, state)
    if step > step0:
        _save(step, state)  # no-op unless progress followed the last save
    return state, {"failures": failures, "final_step": step}


def elastic_restore(ckpt_dir: str, step: int, like, mesh, specs):
    """Restore a checkpoint onto ``mesh`` with ``specs`` PartitionSpecs —
    device count may differ from the writing mesh (elastic scaling)."""
    from jax.sharding import NamedSharding

    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    flat_sh = jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
    return ckpt.restore(ckpt_dir, step, like, shardings=flat_sh)
