from . import fault_tolerance, shardings

__all__ = ["fault_tolerance", "shardings"]
