"""Phase-level tracing spans (DESIGN.md §14).

``span("phase", **attrs)`` opens a nestable timing span; spans form a
per-run tree (one :class:`Trace` per thread) exportable as JSONL
(``obs.export``) and pretty-printable as a text flamegraph
(``python -m repro.obs trace.jsonl``).

Two rules make the numbers honest and the hot paths safe:

* **Fencing.** JAX dispatch is asynchronous — a wall-clock around a jit
  call measures *enqueue*, not compute. A span that wraps device work
  registers its outputs via ``sp.fence(out)``; span exit calls
  ``jax.block_until_ready`` on everything fenced *before* reading the
  clock, so the span's duration includes the device time it claims to
  measure. ``fence`` returns its argument unchanged, and under tracing
  (``jax.make_jaxpr``) ``block_until_ready`` is a no-op on tracers — a
  fenced span inside a staged function adds zero primitives to the jaxpr
  (the obs-enabled entries in ``analysis.entry_points`` pin this).
* **Off by default.** When disabled (the default; enable with
  ``configure(enabled=True)`` or ``REPRO_OBS=1``), ``span`` returns a
  shared no-op singleton: no allocation, no clock reads, no fencing —
  instrumented code pays one dict lookup and one no-op ``with``.

Hooks live strictly outside jit: spans never touch tracer values (fence
stores a reference, it never inspects), attrs must be host scalars, and
nothing here forces a device sync except the explicit exit fence.
"""

from __future__ import annotations

import os
import threading
import time

__all__ = ["Span", "Trace", "span", "event", "configure", "enabled",
           "current_trace", "reset_trace", "TRACE_SCHEMA_VERSION"]

#: bumped when the JSONL row shape changes; validators check it.
TRACE_SCHEMA_VERSION = 1

_cfg = {"enabled": os.environ.get("REPRO_OBS", "") not in ("", "0")}
_tls = threading.local()


def configure(enabled: bool | None = None) -> None:
    """Flip the global span switch (``None`` leaves it unchanged)."""
    if enabled is not None:
        _cfg["enabled"] = bool(enabled)


def enabled() -> bool:
    return _cfg["enabled"]


class Span:
    """One timed phase: name, attrs, child spans, point events."""

    __slots__ = ("name", "attrs", "children", "events", "t_start", "t_end")

    def __init__(self, name: str, attrs: dict):
        self.name = name
        self.attrs = attrs
        self.children: list[Span] = []
        self.events: list[dict] = []
        self.t_start = 0.0
        self.t_end = 0.0

    @property
    def duration_s(self) -> float:
        return max(self.t_end - self.t_start, 0.0)

    def __repr__(self) -> str:  # debugging aid only
        return (f"Span({self.name!r}, {self.duration_s * 1e3:.2f}ms, "
                f"{len(self.children)} children)")


class Trace:
    """Per-thread span forest plus free (out-of-span) events."""

    __slots__ = ("roots", "events", "t0")

    def __init__(self):
        self.roots: list[Span] = []
        self.events: list[dict] = []
        self.t0 = time.perf_counter()

    def walk(self):
        """Depth-first ``(span, depth, path)`` over the whole forest."""
        def rec(sp: Span, depth: int, prefix: str):
            path = f"{prefix}/{sp.name}" if prefix else sp.name
            yield sp, depth, path
            for c in sp.children:
                yield from rec(c, depth + 1, path)
        for root in self.roots:
            yield from rec(root, 0, "")

    def find(self, name: str) -> list[Span]:
        """All spans named ``name``, depth-first order."""
        return [sp for sp, _, _ in self.walk() if sp.name == name]


def _stack() -> list:
    st = getattr(_tls, "stack", None)
    if st is None:
        st = _tls.stack = []
    return st


def current_trace() -> Trace:
    tr = getattr(_tls, "trace", None)
    if tr is None:
        tr = _tls.trace = Trace()
    return tr


def reset_trace() -> Trace:
    """Start a fresh trace for this thread (returns it)."""
    _tls.trace = Trace()
    _tls.stack = []
    return _tls.trace


class _ActiveSpan:
    """Context manager yielded by :func:`span` when obs is enabled."""

    __slots__ = ("_span", "_fenced")

    def __init__(self, name: str, attrs: dict):
        self._span = Span(name, attrs)
        self._fenced: list | None = None

    def __enter__(self) -> "_ActiveSpan":
        stack = _stack()
        parent = stack[-1] if stack else None
        (parent.children if parent is not None
         else current_trace().roots).append(self._span)
        stack.append(self._span)
        self._span.t_start = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        sp = self._span
        if self._fenced is not None:
            import jax
            jax.block_until_ready(self._fenced)
            self._fenced = None
        sp.t_end = time.perf_counter()
        if exc_type is not None:
            sp.attrs.setdefault("error", exc_type.__name__)
        stack = _stack()
        if stack and stack[-1] is sp:
            stack.pop()
        return False

    def fence(self, value):
        """Register device outputs to ``block_until_ready`` at span exit.

        Returns ``value`` unchanged so call sites stay expression-shaped.
        """
        if self._fenced is None:
            self._fenced = [value]
        else:
            self._fenced.append(value)
        return value

    def set(self, **attrs) -> "_ActiveSpan":
        """Attach/overwrite structured attributes (host scalars only)."""
        self._span.attrs.update(attrs)
        return self

    @property
    def span(self) -> Span:
        return self._span


class _NoopSpan:
    """Disabled-mode singleton: every method is a no-op passthrough."""

    __slots__ = ()
    span = None

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def fence(self, value):
        return value

    def set(self, **attrs) -> "_NoopSpan":
        return self


_NOOP = _NoopSpan()


def span(name: str, **attrs):
    """Open a span named ``name`` (no-op singleton when obs is disabled)."""
    if not _cfg["enabled"]:
        return _NOOP
    return _ActiveSpan(name, attrs)


def event(name: str, **attrs) -> None:
    """Record a point event on the current span (or the trace root).

    Structured sibling of a log line: recovery restores, stale-checkpoint
    warnings, kernel dispatch decisions. No-op when obs is disabled —
    callers that need the signal unconditionally should also log/count.
    """
    if not _cfg["enabled"]:
        return
    tr = current_trace()
    rec = {"name": name, "t": time.perf_counter() - tr.t0, "attrs": attrs}
    stack = _stack()
    (stack[-1].events if stack else tr.events).append(rec)
