"""Trace JSONL export, schema validation, and the text flamegraph view.

Row schema (``TRACE_SCHEMA_VERSION`` 1), one JSON object per line:

* header — ``{"type": "trace", "version": 1}`` (always the first line)
* span   — ``{"type": "span", "name", "path", "depth", "t_start_s",
  "dur_s", "attrs"}`` with ``path`` the ``/``-joined ancestry, times in
  seconds relative to the trace epoch
* event  — ``{"type": "event", "name", "path", "t_s", "attrs"}`` where
  ``path`` names the span the event fired inside (``""`` = trace-level)

``validate_trace_jsonl`` is the schema check the CI obs-smoke lane runs
against emitted traces; ``render_rows`` is the
``python -m repro.obs trace.jsonl`` flamegraph-text view.
"""

from __future__ import annotations

import json

from .trace import TRACE_SCHEMA_VERSION, Trace, current_trace

__all__ = ["trace_rows", "write_trace_jsonl", "read_trace_jsonl",
           "validate_trace_jsonl", "validate_rows", "render_rows",
           "render_trace"]

_SPAN_KEYS = {"type", "name", "path", "depth", "t_start_s", "dur_s", "attrs"}
_EVENT_KEYS = {"type", "name", "path", "t_s", "attrs"}


def trace_rows(tr: Trace | None = None) -> list[dict]:
    """Flatten a trace to schema rows (header + spans + events)."""
    tr = tr if tr is not None else current_trace()
    rows: list[dict] = [{"type": "trace", "version": TRACE_SCHEMA_VERSION}]
    for sp, depth, path in tr.walk():
        rows.append({
            "type": "span", "name": sp.name, "path": path, "depth": depth,
            "t_start_s": round(sp.t_start - tr.t0, 9),
            "dur_s": round(sp.duration_s, 9),
            "attrs": sp.attrs,
        })
        for ev in sp.events:
            rows.append({"type": "event", "name": ev["name"], "path": path,
                         "t_s": round(ev["t"], 9), "attrs": ev["attrs"]})
    for ev in tr.events:
        rows.append({"type": "event", "name": ev["name"], "path": "",
                     "t_s": round(ev["t"], 9), "attrs": ev["attrs"]})
    return rows


def write_trace_jsonl(path: str, tr: Trace | None = None) -> str:
    """Write the trace as JSONL; returns ``path``.

    Attrs are serialized with ``default=str`` so a stray non-primitive
    degrades to its repr instead of killing the export.
    """
    with open(path, "w") as f:
        for row in trace_rows(tr):
            f.write(json.dumps(row, default=str) + "\n")
    return path


def read_trace_jsonl(path: str) -> list[dict]:
    rows = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return rows


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def validate_rows(rows: list[dict]) -> list[str]:
    """Schema errors in a row list (empty list = valid)."""
    errors: list[str] = []
    if not rows:
        return ["empty trace: no rows"]
    head = rows[0]
    if head.get("type") != "trace":
        errors.append(f"row 1: first row must be the trace header, "
                      f"got type={head.get('type')!r}")
    elif head.get("version") != TRACE_SCHEMA_VERSION:
        errors.append(f"row 1: unsupported schema version "
                      f"{head.get('version')!r} (expected "
                      f"{TRACE_SCHEMA_VERSION})")
    for i, row in enumerate(rows[1:], start=2):
        kind = row.get("type")
        if kind == "span":
            missing = _SPAN_KEYS - set(row)
            if missing:
                errors.append(f"row {i}: span missing {sorted(missing)}")
                continue
            if not isinstance(row["name"], str) or not row["name"]:
                errors.append(f"row {i}: span name must be a non-empty str")
            if not isinstance(row["depth"], int) or row["depth"] < 0:
                errors.append(f"row {i}: span depth must be an int >= 0")
            if not _is_num(row["dur_s"]) or row["dur_s"] < 0:
                errors.append(f"row {i}: span dur_s must be a number >= 0")
            if not _is_num(row["t_start_s"]):
                errors.append(f"row {i}: span t_start_s must be a number")
            if not isinstance(row["attrs"], dict):
                errors.append(f"row {i}: span attrs must be an object")
            if not isinstance(row["path"], str) or \
                    not row["path"].endswith(row.get("name", "")):
                errors.append(f"row {i}: span path must end with its name")
        elif kind == "event":
            missing = _EVENT_KEYS - set(row)
            if missing:
                errors.append(f"row {i}: event missing {sorted(missing)}")
                continue
            if not isinstance(row["name"], str) or not row["name"]:
                errors.append(f"row {i}: event name must be a non-empty str")
            if not _is_num(row["t_s"]):
                errors.append(f"row {i}: event t_s must be a number")
            if not isinstance(row["attrs"], dict):
                errors.append(f"row {i}: event attrs must be an object")
        elif kind == "trace":
            errors.append(f"row {i}: duplicate trace header")
        else:
            errors.append(f"row {i}: unknown row type {kind!r}")
    return errors


def validate_trace_jsonl(path: str) -> list[str]:
    """Schema errors in a JSONL file (bad JSON lines are errors too)."""
    rows = []
    errors = []
    with open(path) as f:
        for n, line in enumerate(f, start=1):
            line = line.strip()
            if not line:
                continue
            try:
                rows.append(json.loads(line))
            except ValueError as e:
                errors.append(f"line {n}: not valid JSON ({e})")
    return errors + validate_rows(rows)


def _fmt_dur(s: float) -> str:
    if s >= 1.0:
        return f"{s:8.2f}s "
    if s >= 1e-3:
        return f"{s * 1e3:8.2f}ms"
    return f"{s * 1e6:8.1f}µs"


def _fmt_attrs(attrs: dict, limit: int = 60) -> str:
    if not attrs:
        return ""
    body = " ".join(f"{k}={v}" for k, v in attrs.items())
    return body if len(body) <= limit else body[: limit - 1] + "…"


def render_rows(rows: list[dict], bar_width: int = 24) -> str:
    """Flamegraph-text view: indented span tree with duration bars.

    Bars scale each span against its root span, so one glance shows
    where a phase's time went; events print as ``·`` lines under their
    span.
    """
    lines = []
    root_dur = 0.0
    for row in rows:
        if row.get("type") != "span":
            continue
        if row["depth"] == 0:
            root_dur = max(row["dur_s"], 1e-12)
            lines.append("")
        frac = min(row["dur_s"] / max(root_dur, 1e-12), 1.0)
        bar = "█" * max(int(round(frac * bar_width)), 1 if frac > 0 else 0)
        indent = "  " * row["depth"]
        name = f"{indent}{row['name']}"
        lines.append(f"{name:<38}{_fmt_dur(row['dur_s'])} {frac * 100:5.1f}% "
                     f"{bar:<{bar_width}} {_fmt_attrs(row['attrs'])}".rstrip())
    for row in rows:
        if row.get("type") == "event":
            where = f" in {row['path']}" if row["path"] else ""
            lines.append(f"· {row['name']} @{row['t_s']:.6f}s{where} "
                         f"{_fmt_attrs(row['attrs'], limit=80)}".rstrip())
    n_spans = sum(1 for r in rows if r.get("type") == "span")
    n_events = sum(1 for r in rows if r.get("type") == "event")
    header = (f"trace: {n_spans} span(s), {n_events} event(s) "
              f"(schema v{TRACE_SCHEMA_VERSION})")
    return "\n".join([header] + lines)


def render_trace(tr: Trace | None = None) -> str:
    """Render a live :class:`Trace` (default: the current one)."""
    return render_rows(trace_rows(tr))
