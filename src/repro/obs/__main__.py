"""``python -m repro.obs trace.jsonl`` — validate + pretty-print a trace.

Default mode renders the flamegraph-text span tree (after a schema
check); ``--validate`` only checks the schema and exits 1 on any error —
the machine gate the CI obs-smoke lane runs on emitted traces.
"""

from __future__ import annotations

import argparse
import sys

from .export import read_trace_jsonl, render_rows, validate_trace_jsonl


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="validate and pretty-print a repro.obs trace JSONL file")
    ap.add_argument("trace", help="trace JSONL file (obs.write_trace_jsonl)")
    ap.add_argument("--validate", action="store_true",
                    help="schema check only; exit 1 on any violation")
    args = ap.parse_args(argv)

    errors = validate_trace_jsonl(args.trace)
    for e in errors:
        print(f"schema: {e}", file=sys.stderr)
    if args.validate:
        status = "OK" if not errors else f"{len(errors)} schema error(s)"
        print(f"{args.trace}: {status}")
        return 1 if errors else 0
    if errors:
        print(f"{args.trace}: refusing to render an invalid trace "
              f"({len(errors)} schema error(s))", file=sys.stderr)
        return 1
    print(render_rows(read_trace_jsonl(args.trace)))
    return 0


if __name__ == "__main__":
    sys.exit(main())
