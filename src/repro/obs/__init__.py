"""Unified telemetry layer (DESIGN.md §14): spans, metrics, trace export.

Three pieces, one import:

* ``trace``   — nestable ``span("phase")`` context managers with
  block-until-ready fencing at span exit (honest device time under JAX
  async dispatch) and structured ``event``s; **off by default**
  (``configure(enabled=True)`` or ``REPRO_OBS=1``), disabled spans are a
  shared no-op singleton.
* ``metrics`` — typed counters/gauges/fixed-bucket histograms in a
  global default :class:`Registry` (always on: host-side, O(1),
  bounded memory), with ``snapshot``/``diff`` and ``to_rows`` for
  ``benchio`` export.
* ``export``  — per-run JSONL trace files, a schema validator (the CI
  obs-smoke gate), and the ``python -m repro.obs trace.jsonl``
  flamegraph-text pretty-printer.

Sync-safety contract: every hook lives strictly outside jit-compiled
code paths. The one exception is :func:`kernel_dispatch`, which the
``kernels.ops`` wrappers call with *static* dispatch facts (tier, tile
config, VMEM verdict) — under tracing it runs once at trace time, touches
no tracer values, and adds nothing to the jaxpr; the obs-enabled entries
in ``analysis.entry_points`` keep that provable in CI.
"""

from .export import (
    read_trace_jsonl,
    render_rows,
    render_trace,
    trace_rows,
    validate_rows,
    validate_trace_jsonl,
    write_trace_jsonl,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    Registry,
    default_latency_buckets_us,
    get_registry,
    reset_metrics,
)
from .trace import (
    TRACE_SCHEMA_VERSION,
    Span,
    Trace,
    configure,
    current_trace,
    enabled,
    event,
    reset_trace,
    span,
)

__all__ = [
    "TRACE_SCHEMA_VERSION", "Span", "Trace", "span", "event", "configure",
    "enabled", "current_trace", "reset_trace",
    "Counter", "Gauge", "Histogram", "Registry", "get_registry",
    "reset_metrics", "default_latency_buckets_us",
    "trace_rows", "write_trace_jsonl", "read_trace_jsonl",
    "validate_trace_jsonl", "validate_rows", "render_rows", "render_trace",
    "kernel_dispatch",
]


def kernel_dispatch(op: str, tier: str, **attrs) -> None:
    """Record one kernel-dispatch decision (which tier ran, and why).

    Increments ``kernel_dispatch{op=...,tier=...}`` in the default
    registry and, when spans are enabled, attaches a ``kernel_dispatch``
    event (carrying ``attrs`` — e.g. the VMEM-estimator verdict) to the
    current span. All arguments must be static host values: inside a jit
    trace this runs once, at trace time, so the counters meter *compiled
    dispatch decisions*, not per-call execution — exactly the property
    that makes it safe to leave in traced code.
    """
    get_registry().counter(
        "kernel_dispatch",
        help="kernel tier decisions, by op (counted per trace)",
    ).labels(op=op, tier=tier).inc()
    if enabled():
        event("kernel_dispatch", op=op, tier=tier, **attrs)
