"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

Everything here is **host-side only** and O(1) per update with bounded
memory — a histogram is a fixed vector of bucket counts, never a list of
samples, so a flood of adversarial requests cannot grow the process
(the ``serve_lamc`` percentile fix rides on this). Metrics are *always
active* (unlike spans, which are gated by ``obs.configure``): they are
cheap enough to leave on, and consumers like the serving error counters
are part of the product output, not optional telemetry.

None of these methods may be called with tracer values — callers pass
host ints/floats. Updates are plain attribute writes (GIL-atomic); the
registry takes a lock only on metric *creation*.

``Registry.snapshot()`` returns a JSON-able dict; ``Registry.diff``
subtracts two snapshots (counters/histograms by delta, gauges by the
newer value) so a caller can meter one phase of a long-lived process.
``to_rows`` flattens to the scalar rows ``benchio.merge_rows`` consumes.
"""

from __future__ import annotations

import bisect
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "Registry", "get_registry",
           "reset_metrics", "default_latency_buckets_us"]


def default_latency_buckets_us(lo: float = 1.0, hi: float = 1e8,
                               ratio: float = 1.25) -> tuple[float, ...]:
    """Geometric latency buckets (µs): 1µs .. 100s at 25% resolution.

    The ratio bounds the percentile estimation error: a reported p99 is
    within one bucket (≤ 25% relative) of the exact order statistic.
    """
    out = []
    b = float(lo)
    while b < hi:
        out.append(b)
        b *= ratio
    return tuple(out)


def _series_key(kv: dict) -> str:
    return ",".join(f"{k}={v}" for k, v in sorted(kv.items()))


class Counter:
    """Monotonic counter with optional label series."""

    kind = "counter"
    __slots__ = ("name", "help", "_value", "_series")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._series: dict[str, Counter] = {}

    def inc(self, n: float = 1.0) -> "Counter":
        if n < 0:
            raise ValueError(f"counter {self.name}: inc must be >= 0, got {n}")
        self._value += n
        return self

    @property
    def value(self) -> float:
        return self._value

    def labels(self, **kv) -> "Counter":
        """Child counter for one label combination (e.g. op=..., tier=...)."""
        key = _series_key(kv)
        child = self._series.get(key)
        if child is None:
            child = self._series[key] = Counter(f"{self.name}{{{key}}}")
        return child

    def snapshot(self) -> dict:
        out = {"type": "counter", "value": self._value}
        if self._series:
            out["series"] = {k: c._value for k, c in sorted(self._series.items())}
        return out


class Gauge:
    """Last-write-wins scalar (queue depth, resident bytes, final step)."""

    kind = "gauge"
    __slots__ = ("name", "help", "_value")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0

    def set(self, v: float) -> "Gauge":
        self._value = v
        return self

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": self._value}


class Histogram:
    """Fixed-bucket histogram with interpolated percentile estimates.

    ``buckets`` are ascending upper bounds; values above the last bound
    land in an implicit overflow bucket. Memory is ``len(buckets) + 1``
    ints regardless of sample count. ``percentile`` matches
    ``np.percentile`` (linear interpolation) to within one bucket span —
    the oracle test in ``tests/test_obs.py`` pins the tolerance.
    """

    kind = "histogram"
    __slots__ = ("name", "help", "buckets", "_counts", "count", "sum",
                 "min", "max")

    def __init__(self, name: str, buckets=None, help: str = ""):
        self.name = name
        self.help = help
        bs = tuple(float(b) for b in (buckets if buckets is not None
                                      else default_latency_buckets_us()))
        if list(bs) != sorted(set(bs)):
            raise ValueError(
                f"histogram {name}: buckets must be strictly ascending")
        if not bs:
            raise ValueError(f"histogram {name}: needs at least one bucket")
        self.buckets = bs
        self._counts = [0] * (len(bs) + 1)
        self.count = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v) -> "Histogram":
        self._counts[bisect.bisect_left(self.buckets, v)] += 1
        self.count += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        return self

    def percentile(self, p: float) -> float:
        """Estimated ``p``-th percentile (NaN when empty).

        Linear-interpolated rank (the ``np.percentile`` default), located
        in bucket space and interpolated within the bucket; clamped to
        the observed [min, max] envelope so a one-sample histogram
        reports the sample, not a bucket edge.
        """
        if self.count == 0:
            return math.nan
        rank = p / 100.0 * (self.count - 1)  # 0-indexed fractional rank
        cum = 0.0
        for i, c in enumerate(self._counts):
            if c == 0:
                continue
            if rank < cum + c:
                lo = self.buckets[i - 1] if i > 0 else self.min
                hi = (self.buckets[i] if i < len(self.buckets) else self.max)
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - cum + 0.5) / c  # mid-rank within the bucket
                est = lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                return min(max(est, self.min), self.max)
            cum += c
        return self.max

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "buckets": list(self.buckets),
            "counts": list(self._counts),
            "count": self.count,
            "sum": self.sum,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }


class Registry:
    """Named metric store. Get-or-create accessors enforce one type per
    name; re-registering with a different type (or histogram bucket set)
    fails loudly instead of silently splitting the series."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, name: str, cls, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kwargs)
                return m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {name!r} already registered as {m.kind}, "
                f"requested {cls.kind}")
        return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(name, Gauge, help=help)

    def histogram(self, name: str, buckets=None, help: str = "") -> Histogram:
        h = self._get_or_create(name, Histogram, buckets=buckets, help=help)
        if buckets is not None and tuple(float(b) for b in buckets) != h.buckets:
            raise ValueError(
                f"histogram {name!r} already registered with different "
                "buckets")
        return h

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def get(self, name: str):
        return self._metrics.get(name)

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()

    def snapshot(self) -> dict:
        """JSON-able ``{name: metric.snapshot()}`` of every metric."""
        return {name: m.snapshot()
                for name, m in sorted(self._metrics.items())}

    @staticmethod
    def diff(new: dict, old: dict) -> dict:
        """Delta between two snapshots (``new`` minus ``old``).

        Counters and histogram counts subtract (a name absent from
        ``old`` counts from zero); gauges take the newer value. The
        result uses the snapshot schema, so it round-trips through the
        same consumers.
        """
        out: dict = {}
        for name, m in new.items():
            o = old.get(name)
            if o is not None and o.get("type") != m.get("type"):
                raise TypeError(
                    f"metric {name!r} changed type between snapshots: "
                    f"{o.get('type')} -> {m.get('type')}")
            if m["type"] == "counter":
                d = {"type": "counter",
                     "value": m["value"] - (o or {}).get("value", 0.0)}
                series = {
                    k: v - ((o or {}).get("series") or {}).get(k, 0.0)
                    for k, v in (m.get("series") or {}).items()}
                if series:
                    d["series"] = series
                out[name] = d
            elif m["type"] == "gauge":
                out[name] = {"type": "gauge", "value": m["value"]}
            else:  # histogram
                oc = (o or {}).get("counts") or [0] * len(m["counts"])
                out[name] = {
                    "type": "histogram",
                    "buckets": list(m["buckets"]),
                    "counts": [a - b for a, b in zip(m["counts"], oc)],
                    "count": m["count"] - (o or {}).get("count", 0),
                    "sum": m["sum"] - (o or {}).get("sum", 0.0),
                    "min": m["min"], "max": m["max"],
                }
        return out

    def to_rows(self, prefix: str = "") -> dict:
        """Flatten to ``{key: number}`` rows for ``benchio.merge_rows``.

        Histograms flatten to ``_count``/``_sum``/``_p50``/``_p99``
        derived keys — the trajectory-file shape, not the full buckets.
        """
        rows: dict = {}
        for name, m in sorted(self._metrics.items()):
            key = f"{prefix}{name}"
            if isinstance(m, Histogram):
                rows[f"{key}_count"] = m.count
                rows[f"{key}_sum"] = m.sum
                rows[f"{key}_p50"] = m.percentile(50)
                rows[f"{key}_p99"] = m.percentile(99)
            elif isinstance(m, Counter):
                rows[key] = m.value
                for sk, sc in sorted(m._series.items()):
                    rows[f"{key}{{{sk}}}"] = sc._value
            else:
                rows[key] = m.value
        return rows


_default = Registry()


def get_registry() -> Registry:
    """The process-global default registry."""
    return _default


def reset_metrics() -> None:
    """Clear the default registry (tests; a fresh serve run)."""
    _default.reset()
