"""Attention: chunked (flash-style) training/prefill path, decode path with
KV cache, sliding-window variant, GQA throughout.

The chunked path is the pure-JAX twin of ``kernels/flash_attention.py``
(cross-checked in tests): a ``lax.scan`` over KV chunks with running
(max, denominator, accumulator) — O(chunk) memory, so 32k-token prefill
never materializes a (S, S) score matrix. On TPU the Pallas kernel replaces
it via ``use_pallas=True``; XLA's fusion of this scan is the CPU/dry-run
fallback.

Shapes: q (B, Hq, Sq, Dh); k,v (B, Hkv, Skv, Dh); GQA expands Hkv -> Hq by
repeat (Hq % Hkv == 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "chunked_causal_attention",
    "decode_attention",
    "sliding_window_mask_attention",
]

_NEG_INF = -1e30


def _expand_gqa(k, v, hq):
    hkv = k.shape[1]
    if hkv == hq:
        return k, v
    rep = hq // hkv
    return jnp.repeat(k, rep, axis=1), jnp.repeat(v, rep, axis=1)


def chunked_causal_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    chunk_size: int = 1024,
    window: int = 0,          # 0 = full causal; >0 = sliding window
    q_offset: int = 0,        # global position of q[0] (prefill continuation)
) -> jax.Array:
    """Flash-style causal attention via lax.scan over KV chunks."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    k, v = _expand_gqa(k, v, hq)
    scale = 1.0 / (dh ** 0.5)
    nchunks = -(-skv // chunk_size)
    pad = nchunks * chunk_size - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(b, hq, nchunks, chunk_size, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hq, nchunks, chunk_size, dh).transpose(2, 0, 1, 3, 4)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        ci, k_i, v_i = inp
        k_pos = ci * chunk_size + jnp.arange(chunk_size)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                       k_i.astype(jnp.float32)) * scale
        mask = (q_pos[:, None] >= k_pos[None, :]) & (k_pos[None, :] < skv)
        if window > 0:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        s = jnp.where(mask[None, None], s, _NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhqk,bhkd->bhqd", p, v_i.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hq, sq), _NEG_INF, jnp.float32),
        jnp.zeros((b, hq, sq), jnp.float32),
        jnp.zeros((b, hq, sq, dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (jnp.arange(nchunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,            # (B, Hq, 1, Dh) — single new token
    k_cache: jax.Array,      # (B, Hkv, S, Dh) bf16, or int8 with scales
    v_cache: jax.Array,
    *,
    cache_len: jax.Array | int,   # number of valid cache positions
    window: int = 0,
    k_scale: jax.Array | None = None,  # (B, Hkv, S) f32 per-token scales
    v_scale: jax.Array | None = None,
) -> jax.Array:
    """Single-step decode attention over a (possibly sharded) KV cache.

    Direct einsum: the score tensor is (B, H, 1, S) — tiny — and the
    softmax-over-sharded-S reduction lowers to psum when the cache's S dim
    is model-sharded (the distributed-softmax decode path; DESIGN.md).

    With ``k_scale``/``v_scale`` the cache is int8-quantized per (token,
    head) — halves decode HBM footprint AND bandwidth (the memory-bound
    roofline term) at <1e-2 logit error (tests/test_models_smoke.py).
    """
    b, hq, _, dh = q.shape
    if k_scale is not None:
        k_cache = k_cache.astype(jnp.float32) * k_scale[..., None]
        v_cache = v_cache.astype(jnp.float32) * v_scale[..., None]
    k_cache, v_cache = _expand_gqa(k_cache, v_cache, hq)
    s = k_cache.shape[2]
    scale = 1.0 / (dh ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    pos = jnp.arange(s)
    mask = pos[None, None, None, :] < cache_len
    if window > 0:
        mask &= pos[None, None, None, :] >= (cache_len - window)
    logits = jnp.where(mask, logits, _NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def sliding_window_mask_attention(q, k, v, *, window: int,
                                  chunk_size: int = 1024, q_offset: int = 0):
    """Convenience wrapper: chunked attention with a sliding window
    (recurrentgemma local-attention blocks)."""
    return chunked_causal_attention(
        q, k, v, chunk_size=chunk_size, window=window, q_offset=q_offset)
