"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (the "recurrent block" of Griffin):
    x-branch: Dense(d -> d_rnn) -> causal depthwise Conv1D(width 4) -> RG-LRU
    gate    : Dense(d -> d_rnn) -> GeLU
    out     : (x_branch * gate) -> Dense(d_rnn -> d)

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a u_t + b_a)          recurrence gate
    i_t = sigmoid(W_x u_t + b_x)          input gate
    a_t = a^(c * r_t),  a = sigmoid(Lambda)   with c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill uses ``jax.lax.associative_scan`` over the linear
recurrence (log-depth on TPU — the hardware-adapted replacement for the
sequential CUDA scan kernel the paper uses). Decode is a single fused step
carrying ``(h, conv_window)`` state — O(1) memory in sequence length, which
is what qualifies this arch for the 512k-token cell (DESIGN.md).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers

__all__ = ["rglru_block_init", "rglru_block_apply", "rglru_block_step",
           "rglru_init_state"]

_C = 8.0
_CONV_W = 4


def rglru_block_init(key, d: int, d_rnn: int, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    scale = 1.0 / math.sqrt(d)
    # Lambda init so that a = sigmoid(L)^c covers (0.9, 0.999) as in Griffin
    u = jax.random.uniform(k6, (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log((u ** (1.0 / _C)) / (1.0 - u ** (1.0 / _C)))
    return {
        "w_x": layers.dense_init(k1, d, d_rnn, dtype),
        "w_gate": layers.dense_init(k2, d, d_rnn, dtype),
        "w_out": layers.dense_init(k3, d_rnn, d, dtype),
        "conv": jax.random.normal(k4, (_CONV_W, d_rnn), dtype) * scale,
        "gates": {
            "w_a": jax.random.normal(k5, (d_rnn, d_rnn), jnp.float32) * (1.0 / math.sqrt(d_rnn)),
            "b_a": jnp.zeros((d_rnn,), jnp.float32),
            "w_i": jax.random.normal(k7, (d_rnn, d_rnn), jnp.float32) * (1.0 / math.sqrt(d_rnn)),
            "b_i": jnp.zeros((d_rnn,), jnp.float32),
        },
        "lambda": lam,
    }


def rglru_init_state(batch: int, d_rnn: int, dtype=jnp.float32) -> dict:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_W - 1, d_rnn), dtype),
    }


def _gates(p, u):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ p["gates"]["w_a"] + p["gates"]["b_a"])
    i = jax.nn.sigmoid(uf @ p["gates"]["w_i"] + p["gates"]["b_i"])
    log_a = _C * r * jax.nn.log_sigmoid(p["lambda"])  # (d_rnn,) broadcasts
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * uf)
    return a, b


def _causal_conv(p, u, prefix=None):
    """Depthwise causal conv width 4. u: (B, S, d_rnn)."""
    w = p["conv"].astype(u.dtype)                        # (4, d_rnn)
    if prefix is None:
        prefix = jnp.zeros((u.shape[0], _CONV_W - 1, u.shape[2]), u.dtype)
    up = jnp.concatenate([prefix, u], axis=1)            # (B, S+3, d)
    out = sum(up[:, i : i + u.shape[1]] * w[i] for i in range(_CONV_W))
    return out


def rglru_block_apply(p: dict, x: jax.Array, h0: jax.Array | None = None):
    """Full-sequence apply. x: (B, S, d). Returns (out, final_state)."""
    u = layers.dense(p["w_x"], x)                        # (B, S, d_rnn)
    u = _causal_conv(p, u)
    a, b = _gates(p, u)                                  # f32 (B, S, d_rnn)
    if h0 is not None:
        # fold carried state into the first step: h_1 = a_1 h_0 + b_1
        b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_sc, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(layers.dense(p["w_gate"], x))
    out = layers.dense(p["w_out"], (h.astype(x.dtype) * gate))
    state = {
        "h": h[:, -1],
        "conv": jnp.concatenate(
            [jnp.zeros((x.shape[0], _CONV_W - 1, u.shape[-1]), u.dtype),
             layers.dense(p["w_x"], x)], axis=1)[:, -(_CONV_W - 1):],
    }
    return out, state


def rglru_block_step(p: dict, x: jax.Array, state: dict):
    """Single decode step. x: (B, 1, d). Returns (out (B,1,d), new_state)."""
    u = layers.dense(p["w_x"], x)                        # (B, 1, d_rnn)
    window = jnp.concatenate([state["conv"], u], axis=1)  # (B, 4, d_rnn)
    w = p["conv"].astype(u.dtype)
    u_c = jnp.sum(window * w[None], axis=1, keepdims=True)  # (B,1,d_rnn)
    a, b = _gates(p, u_c)
    h = a[:, 0] * state["h"] + b[:, 0]                   # (B, d_rnn)
    gate = jax.nn.gelu(layers.dense(p["w_gate"], x))
    out = layers.dense(p["w_out"], h[:, None].astype(x.dtype) * gate)
    return out, {"h": h, "conv": window[:, 1:]}
