"""Mixture-of-Experts layer: top-k routing, per-row capacity dispatch,
grouped-einsum experts, shared experts, load-balance aux loss.

SPMD design (DESIGN.md): routing/capacity math is computed *per sequence
row* (cumsum over the S axis only), never across the token-global axis —
so no cross-device cumsum appears when batch is data-sharded, and the
dispatch scatter stays device-local. Experts are stacked on a leading E
axis that shards over the ``model`` mesh axis (expert parallelism); the
grouped einsums contract d/ff locally per expert shard.

Capacity per row: ``C = ceil(S * top_k / E * capacity_factor)`` — overflow
tokens are dropped (standard dropping MoE), which keeps every shape static.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers

__all__ = ["moe_init", "moe_apply", "row_capacity"]


def row_capacity(seq_len: int, top_k: int, n_experts: int,
                 capacity_factor: float = 1.25) -> int:
    return max(1, math.ceil(seq_len * top_k / n_experts * capacity_factor))


def moe_init(key, d: int, d_ff: int, n_experts: int, n_shared: int,
             dtype=jnp.float32) -> dict:
    kr, ke1, ke2, ke3, ks = jax.random.split(key, 5)
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": {"w": jax.random.normal(kr, (d, n_experts), jnp.float32) * scale},
        "w_in": jax.random.normal(ke1, (n_experts, d, d_ff), dtype) * scale,
        "w_gate": jax.random.normal(ke2, (n_experts, d, d_ff), dtype) * scale,
        "w_out": jax.random.normal(ke3, (n_experts, d_ff, d), dtype) / math.sqrt(d_ff),
    }
    if n_shared > 0:
        p["shared"] = layers.mlp_init(ks, d, d_ff * n_shared, dtype)
    return p


def moe_apply(p: dict, x: jax.Array, *, top_k: int, act: str = "silu",
              capacity_factor: float = 1.25) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d). Returns (out (B,S,d), aux_loss scalar)."""
    b, s, d = x.shape
    e = p["w_in"].shape[0]
    c = row_capacity(s, top_k, e, capacity_factor)

    # --- routing (f32 for stability) ---
    logits = x.astype(jnp.float32) @ p["router"]["w"]          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (B,S,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)           # renormalize

    # --- load-balance aux loss (Switch-style) ---
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)

    # --- per-row slot assignment: position of each (token,k) in its expert ---
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)    # (B,S,K,E)
    flat = onehot.reshape(b, s * top_k, e)                     # row-major (s,k)
    pos = jnp.cumsum(flat, axis=1) - 1                         # (B,S*K,E)
    pos = jnp.sum(pos * flat, axis=-1).reshape(b, s, top_k)    # own-expert rank
    keep = pos < c                                             # (B,S,K)

    # --- dispatch/combine via one-hot einsums (GSPMD-friendly: scatter/
    # gather ops made XLA replicate the batch axis — measured multi-GB
    # f32 batch all-gathers on llama4 train; einsums partition cleanly
    # over (data: B, model: E). benchmarks/README.md §Perf M2 ---
    e_hot = jax.nn.one_hot(expert_idx, e, dtype=x.dtype)       # (B,S,K,E)
    c_hot = jax.nn.one_hot(jnp.where(keep, pos, c), c, dtype=x.dtype)  # (B,S,K,C)
    dispatch = jnp.einsum("bske,bskc->bsec", e_hot, c_hot)     # (B,S,E,C)
    buf = jnp.einsum("bsec,bsd->becd", dispatch, x)            # (B,E,C,d)

    # --- grouped expert MLP (expert axis shards over `model`) ---
    h = jnp.einsum("becd,edf->becf", buf, p["w_in"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype))
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    out_buf = jnp.einsum("becf,efd->becd", h * g, p["w_out"].astype(x.dtype))

    # --- combine: gate-weighted version of the dispatch mask ---
    combine = jnp.einsum("bsk,bske,bskc->bsec",
                         gate_vals.astype(x.dtype), e_hot, c_hot)
    out = jnp.einsum("bsec,becd->bsd", combine, out_buf)

    if "shared" in p:
        out = out + layers.mlp(p["shared"], x, act=act)
    return out, aux
