"""Assigned-architecture model zoo (pure functional JAX, scan-over-layers)."""

from .model import Model, build_model

__all__ = ["Model", "build_model"]
