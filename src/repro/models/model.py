"""Public model API: ``build_model(cfg)`` -> init/loss/prefill/decode.

This is the layer the launcher, dry-run, examples and tests consume; the
assembly details live in ``transformer.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

from repro.configs.base import ArchConfig

from . import transformer

__all__ = ["Model", "build_model"]


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init: Callable          # (key) -> params
    loss_fn: Callable       # (params, batch) -> (loss, aux)
    prefill: Callable       # (params, tokens[, extra]) -> (logits, caches)
    decode_step: Callable   # (params, token, cache, pos[, extra]) -> (logits, cache)
    init_decode_cache: Callable  # (batch, max_len) -> cache pytree


def build_model(cfg: ArchConfig, dtype=jnp.bfloat16,
                param_dtype=jnp.float32, act_sharding=None,
                unit_constraint=None) -> Model:
    def init(key):
        return transformer.init_params(cfg, key, dtype=param_dtype)

    def loss_fn(params, batch):
        """batch: {"tokens": (B,S) int32, "targets": (B,S) int32,
        optional "frontend_embeds": (B,F,d)}."""
        extra = {k: v for k, v in batch.items()
                 if k not in ("tokens", "targets")} or None
        hidden, _, aux = transformer.forward_full(
            cfg, params, batch["tokens"], extra, dtype=dtype, remat=True,
            act_sharding=act_sharding, unit_constraint=unit_constraint)
        xent = transformer.chunked_cross_entropy(
            cfg, params, hidden, batch["targets"])
        return xent + 0.01 * aux, {"xent": xent, "aux": aux}

    def prefill_fn(params, tokens, extra=None):
        return transformer.prefill(cfg, params, tokens, extra, dtype=dtype,
                                   act_sharding=act_sharding)

    def decode_fn(params, token, cache, pos, extra=None):
        return transformer.decode_step(cfg, params, token, cache, pos,
                                       extra, dtype=dtype)

    def init_cache(batch, max_len, quantized=False):
        return transformer.init_decode_cache(cfg, batch, max_len, dtype=dtype,
                                             quantized=quantized)

    return Model(cfg=cfg, init=init, loss_fn=loss_fn, prefill=prefill_fn,
                 decode_step=decode_fn, init_decode_cache=init_cache)
