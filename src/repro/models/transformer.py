"""Generic block-pattern transformer: one assembly covering all 10 assigned
architectures (dense GQA, MoE, RG-LRU hybrid, xLSTM, enc-dec, VLM backbone).

Layer stacking: ``cfg.block_pattern`` is the repeating unit (e.g.
``("attn",)`` for dense, ``("rglru", "rglru", "local")`` for
recurrentgemma, ``("mlstm", "slstm")`` for xlstm). Full units are stacked
and applied under ``lax.scan`` (compact HLO, O(1) compile size in depth,
standard remat point); the ``n_layers mod unit`` remainder becomes
unstacked tail layers.

Three entry points (same params):
    ``forward_full``  — logits for a whole sequence (train / prefill)
    ``prefill``       — forward_full + per-layer decode caches
    ``decode_step``   — one token through cached states

Caches are pytrees mirroring the params tree. Attention caches are fixed
``(B, Hkv, S_max, Dh)`` buffers written at ``pos`` (rolling ``pos % window``
for local attention); recurrent blocks carry O(1) states.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

from . import attention, layers, moe, rglru, xlstm

__all__ = ["init_params", "forward_full", "prefill", "decode_step",
           "chunked_cross_entropy", "pattern_layout"]


# ---------------------------------------------------------------------------
# layout helpers
# ---------------------------------------------------------------------------


def pattern_layout(cfg: ArchConfig) -> tuple[int, tuple[str, ...]]:
    """(n_full_units, tail_kinds).

    Layers = n_dense_layers (deepseek-style leading dense attn blocks)
           + units x pattern + tail."""
    pat = cfg.block_pattern
    n_scan = cfg.n_layers - cfg.n_dense_layers
    n_units = n_scan // len(pat)
    tail_len = n_scan - n_units * len(pat)
    return n_units, pat[:tail_len]


def _ffn_kind(cfg: ArchConfig, layer_idx) -> str:
    """'moe' | 'dense' | 'none' for the FFN half of a block."""
    if cfg.d_ff == 0 and not cfg.is_moe:
        return "none"
    if cfg.is_moe:
        return "moe"
    return "dense"


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _attn_init(cfg: ArchConfig, key, dtype) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, hq, dh), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, hkv, dh), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, hkv, dh), dtype) * s,
        "wo": jax.random.normal(ks[3], (hq, dh, d), dtype) / math.sqrt(hq * dh),
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.rmsnorm_init(dh)
        p["k_norm"] = layers.rmsnorm_init(dh)
    return p


def _ffn_init(cfg: ArchConfig, key, dtype, dense_override: int = 0) -> dict:
    if dense_override:
        return {"mlp": layers.mlp_init(key, cfg.d_model, dense_override, dtype)}
    if cfg.is_moe:
        return {"moe": moe.moe_init(key, cfg.d_model, cfg.moe_d_ff or cfg.d_ff,
                                    cfg.n_experts, cfg.n_shared_experts, dtype)}
    if cfg.d_ff == 0:
        return {}
    return {"mlp": layers.mlp_init(key, cfg.d_model, cfg.d_ff, dtype)}


def _block_init(cfg: ArchConfig, kind: str, key, dtype,
                dense_override: int = 0, cross: bool = False) -> dict:
    d = cfg.d_model
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": layers.norm_init(cfg.norm, d)}
    if kind in ("attn", "local"):
        p["attn"] = _attn_init(cfg, k1, dtype)
    elif kind == "rglru":
        p["rec"] = rglru.rglru_block_init(k1, d, _d_rnn(cfg), dtype)
    elif kind == "mlstm":
        p["cell"] = xlstm.mlstm_block_init(k1, d, cfg.n_heads, dtype)
    elif kind == "slstm":
        p["cell"] = xlstm.slstm_block_init(k1, d, cfg.n_heads, dtype)
    else:
        raise ValueError(f"unknown block kind {kind}")
    if cross:
        p["ln_x"] = layers.norm_init(cfg.norm, d)
        p["xattn"] = _attn_init(cfg, k4, dtype)
    ffn = _ffn_init(cfg, k2, dtype, dense_override)
    if ffn:
        p["ln2"] = layers.norm_init(cfg.norm, d)
        p.update(ffn)
    return p


def _d_rnn(cfg: ArchConfig) -> int:
    # Griffin: lru width ~ d_model (RG-2B uses 2560 = d_model)
    return cfg.d_model


def init_params(cfg: ArchConfig, key, dtype=jnp.float32) -> dict:
    """``dtype`` here is the *parameter storage* dtype (f32 master copy in
    training; bf16 directly for inference-only dry runs)."""
    n_units, tail = pattern_layout(cfg)
    kemb, khead, kunits, ktail, kenc, kpos = jax.random.split(key, 6)
    params: dict[str, Any] = {
        "embed": layers.embedding_init(kemb, cfg.vocab_size, cfg.d_model),
        "final_norm": layers.norm_init(cfg.norm, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {
            "w": jax.random.normal(khead, (cfg.d_model, cfg.vocab_size), dtype)
            / math.sqrt(cfg.d_model)
        }

    def unit_init(k):
        ks = jax.random.split(k, len(cfg.block_pattern))
        unit = {}
        for i, kind in enumerate(cfg.block_pattern):
            # deepseek-style leading dense layers are handled in the tail
            unit[str(i)] = _block_init(cfg, kind, ks[i], dtype,
                                       cross=cfg.enc_dec)
        return unit

    if n_units > 0:
        params["units"] = jax.vmap(unit_init)(jax.random.split(kunits, n_units))
    tail_params = []
    for i, kind in enumerate(tail):
        tail_params.append(_block_init(cfg, kind, jax.random.fold_in(ktail, i),
                                       dtype, cross=cfg.enc_dec))
    if cfg.n_dense_layers > 0:
        # leading dense layers (deepseek): prepend as extra tail-style blocks
        dense_blocks = [
            _block_init(cfg, "attn", jax.random.fold_in(ktail, 1000 + i),
                        dtype, dense_override=cfg.dense_d_ff or cfg.d_ff)
            for i in range(cfg.n_dense_layers)
        ]
        params["head_layers"] = dense_blocks
    if tail_params:
        params["tail"] = tail_params
    if cfg.enc_dec:
        kencs = jax.random.split(kenc, cfg.n_enc_layers)
        params["encoder"] = {
            "blocks": jax.vmap(
                lambda k: _block_init(cfg, "attn", k, dtype)
            )(kencs),
            "final_norm": layers.norm_init(cfg.norm, cfg.d_model),
            "pos": jax.random.normal(kpos, (cfg.enc_seq_len, cfg.d_model),
                                     jnp.float32) * 0.02,
        }
        # learned decoder positions, sized for the largest assigned decoder
        # shape (prefill_32k / decode_32k); whisper skips long_500k.
        params["dec_pos"] = jax.random.normal(
            jax.random.fold_in(kpos, 1), (65_536, cfg.d_model), jnp.float32) * 0.02
    return params


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _project_qkv(cfg, p, h, dtype):
    q = jnp.einsum("bsd,dhk->bhsk", h, p["wq"].astype(dtype))
    k = jnp.einsum("bsd,dhk->bhsk", h, p["wk"].astype(dtype))
    v = jnp.einsum("bsd,dhk->bhsk", h, p["wv"].astype(dtype))
    if cfg.qk_norm:
        q = layers.rmsnorm(p["q_norm"], q)
        k = layers.rmsnorm(p["k_norm"], k)
    return q, k, v


def _apply_rope(cfg, q, k, pos_info):
    if cfg.rope == "none":
        return q, k
    if cfg.rope == "mrope":
        return layers.apply_mrope(q, k, pos_info["pos3d"],
                                  sections=_mrope_sections(cfg))
    if cfg.rope == "half":
        return layers.apply_rope_half(q, k, pos_info["pos"])
    return layers.apply_rope(q, k, pos_info["pos"])


def _mrope_sections(cfg) -> tuple[int, int, int]:
    half = cfg.head_dim_ // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


def _attn_full(cfg, p, x, pos_info, *, window, causal, kv_len_cap,
               enc_out=None, aux_dtype=jnp.float32):
    """Full-sequence attention block. Returns (x, cache_entry, aux)."""
    dtype = x.dtype
    h = layers.norm_apply(cfg.norm, p["ln1"], x)
    q, k, v = _project_qkv(cfg, p["attn"], h, dtype)
    q, k = _apply_rope(cfg, q, k, pos_info)
    attn_out = attention.chunked_causal_attention(
        q, k, v, chunk_size=1024, window=window) if causal else \
        _full_bidir_attention(q, k, v)
    out = jnp.einsum("bhsk,hkd->bsd", attn_out, p["attn"]["wo"].astype(dtype))
    x = x + out
    if enc_out is not None and "xattn" in p:
        hx = layers.norm_apply(cfg.norm, p["ln_x"], x)
        qx = jnp.einsum("bsd,dhk->bhsk", hx, p["xattn"]["wq"].astype(dtype))
        kx = jnp.einsum("bsd,dhk->bhsk", enc_out, p["xattn"]["wk"].astype(dtype))
        vx = jnp.einsum("bsd,dhk->bhsk", enc_out, p["xattn"]["wv"].astype(dtype))
        xo = _full_bidir_attention(qx, kx, vx)
        x = x + jnp.einsum("bhsk,hkd->bsd", xo, p["xattn"]["wo"].astype(dtype))
    aux = jnp.zeros((), aux_dtype)
    if "mlp" in p or "moe" in p:
        h2 = layers.norm_apply(cfg.norm, p["ln2"], x)
        if "moe" in p:
            f, aux = moe.moe_apply(p["moe"], h2, top_k=cfg.experts_per_token,
                                   act=cfg.act,
                                   capacity_factor=cfg.capacity_factor)
        else:
            f = layers.mlp(p["mlp"], h2, act=cfg.act)
        x = x + f
    # cache: keep only the last kv_len_cap positions (local attention)
    if kv_len_cap and kv_len_cap < k.shape[2]:
        k = k[:, :, -kv_len_cap:]
        v = v[:, :, -kv_len_cap:]
    return x, {"k": k, "v": v}, aux


def _full_bidir_attention(q, k, v):
    """Non-causal attention (encoder / cross-attn): seqs are short (<=1500)."""
    hq = q.shape[1]
    k, v = attention._expand_gqa(k, v, hq)
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _quantize_kv(t):
    """Per-(token, head) int8 quantization: t (B,Hkv,1,Dh) -> (q, scale)."""
    tf = t.astype(jnp.float32)
    scale = jnp.max(jnp.abs(tf), axis=-1) / 127.0           # (B,Hkv,1)
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(tf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def _cache_write(cache, new, pos, axis=2):
    """Write one token into the cache via a one-hot select.

    ``dynamic_update_slice`` with a traced start on a *sharded* seq dim
    makes GSPMD gather/rematerialize the whole cache (measured +14 GB temp
    on minicpm decode_32k); the elementwise ``where(iota == pos)`` form
    partitions trivially on every axis (§Perf D1, the MaxText recipe)."""
    s = cache.shape[axis]
    shape = [1] * cache.ndim
    shape[axis] = s
    mask = (jax.lax.iota(jnp.int32, s) == pos).reshape(shape)
    return jnp.where(mask, new.astype(cache.dtype), cache)


def _attn_step(cfg, p, x, cache, pos, *, window, enc_out=None):
    """Single-token attention block. cache: {"k","v"} (B,Hkv,Smax,Dh)
    (+ {"ks","vs"} per-token scales when int8-quantized)."""
    dtype = x.dtype
    h = layers.norm_apply(cfg.norm, p["ln1"], x)
    q, k, v = _project_qkv(cfg, p["attn"], h, dtype)  # (B,H,1,Dh)
    pos_info = _step_pos_info(cfg, x.shape[0], pos)
    q, k = _apply_rope(cfg, q, k, pos_info)
    s_max = cache["k"].shape[2]
    write = pos % window if window else pos
    write = jnp.minimum(write, s_max - 1)
    quantized = "ks" in cache
    if quantized:
        k_w, k_s = _quantize_kv(k)
        v_w, v_s = _quantize_kv(v)
        new_cache = {
            "k": _cache_write(cache["k"], k_w, write),
            "v": _cache_write(cache["v"], v_w, write),
            "ks": _cache_write(cache["ks"], k_s, write),
            "vs": _cache_write(cache["vs"], v_s, write),
        }
        kq = dict(k_scale=new_cache["ks"], v_scale=new_cache["vs"])
        k_cache, v_cache = new_cache["k"], new_cache["v"]
    else:
        k_cache = _cache_write(cache["k"], k, write)
        v_cache = _cache_write(cache["v"], v, write)
        new_cache = {"k": k_cache, "v": v_cache}
        kq = {}
    if window:
        # rolling buffer holds min(pos+1, window) valid entries; decode
        # attention masks by slot-validity, not recency order (RoPE already
        # encodes absolute positions so order within the buffer is irrelevant)
        valid = jnp.minimum(pos + 1, s_max)
        attn_out = attention.decode_attention(q, k_cache, v_cache,
                                              cache_len=valid, **kq)
    else:
        attn_out = attention.decode_attention(q, k_cache, v_cache,
                                              cache_len=pos + 1, **kq)
    out = jnp.einsum("bhsk,hkd->bsd", attn_out, p["attn"]["wo"].astype(dtype))
    x = x + out
    if enc_out is not None and "xattn" in p:
        hx = layers.norm_apply(cfg.norm, p["ln_x"], x)
        qx = jnp.einsum("bsd,dhk->bhsk", hx, p["xattn"]["wq"].astype(dtype))
        kx = jnp.einsum("bsd,dhk->bhsk", enc_out, p["xattn"]["wk"].astype(dtype))
        vx = jnp.einsum("bsd,dhk->bhsk", enc_out, p["xattn"]["wv"].astype(dtype))
        xo = _full_bidir_attention(qx, kx, vx)
        x = x + jnp.einsum("bhsk,hkd->bsd", xo, p["xattn"]["wo"].astype(dtype))
    if "mlp" in p or "moe" in p:
        h2 = layers.norm_apply(cfg.norm, p["ln2"], x)
        if "moe" in p:
            f, _ = moe.moe_apply(p["moe"], h2, top_k=cfg.experts_per_token,
                                 act=cfg.act,
                                 capacity_factor=cfg.capacity_factor)
        else:
            f = layers.mlp(p["mlp"], h2, act=cfg.act)
        x = x + f
    return x, new_cache


def _recurrent_full(cfg, p, x, kind):
    h = layers.norm_apply(cfg.norm, p["ln1"], x)
    if kind == "rglru":
        out, state = rglru.rglru_block_apply(p["rec"], h)
    elif kind == "mlstm":
        out, state = xlstm.mlstm_apply(p["cell"], h, cfg.n_heads)
    else:
        out, state = xlstm.slstm_apply(p["cell"], h, cfg.n_heads)
    x = x + out
    aux = jnp.zeros((), jnp.float32)
    if "mlp" in p or "moe" in p:
        h2 = layers.norm_apply(cfg.norm, p["ln2"], x)
        if "moe" in p:
            f, aux = moe.moe_apply(p["moe"], h2, top_k=cfg.experts_per_token,
                                   act=cfg.act,
                                   capacity_factor=cfg.capacity_factor)
        else:
            f = layers.mlp(p["mlp"], h2, act=cfg.act)
        x = x + f
    return x, state, aux


def _recurrent_step(cfg, p, x, cache, kind):
    h = layers.norm_apply(cfg.norm, p["ln1"], x)
    if kind == "rglru":
        out, state = rglru.rglru_block_step(p["rec"], h, cache)
    elif kind == "mlstm":
        out, state = xlstm.mlstm_step(p["cell"], h, cfg.n_heads, cache)
    else:
        out, state = xlstm.slstm_step(p["cell"], h, cfg.n_heads, cache)
    x = x + out
    if "mlp" in p or "moe" in p:
        h2 = layers.norm_apply(cfg.norm, p["ln2"], x)
        if "moe" in p:
            f, _ = moe.moe_apply(p["moe"], h2, top_k=cfg.experts_per_token,
                                 act=cfg.act,
                                 capacity_factor=cfg.capacity_factor)
        else:
            f = layers.mlp(p["mlp"], h2, act=cfg.act)
        x = x + f
    return x, state


def _block_full(cfg, kind, p, x, pos_info, enc_out):
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        return _attn_full(cfg, p, x, pos_info, window=window, causal=True,
                          kv_len_cap=window, enc_out=enc_out)
    return _recurrent_full(cfg, p, x, kind)


def _block_step(cfg, kind, p, x, cache, pos, enc_out):
    if kind in ("attn", "local"):
        window = cfg.window if kind == "local" else 0
        return _attn_step(cfg, p, x, cache, pos, window=window, enc_out=enc_out)
    return _recurrent_step(cfg, p, x, cache, kind)


# ---------------------------------------------------------------------------
# position info
# ---------------------------------------------------------------------------


def _full_pos_info(cfg, batch, seq, frontend_len=0):
    pos = jnp.arange(seq)
    info = {"pos": pos}
    if cfg.rope == "mrope":
        # text tokens: all three streams equal; patch positions get a
        # (t=0, h, w) grid over the stub frontend span.
        grid_w = max(1, int(math.sqrt(max(frontend_len, 1))))
        idx = jnp.arange(seq)
        is_patch = idx < frontend_len
        t = jnp.where(is_patch, 0, idx)
        h = jnp.where(is_patch, idx // grid_w, idx)
        w = jnp.where(is_patch, idx % grid_w, idx)
        info["pos3d"] = jnp.broadcast_to(
            jnp.stack([t, h, w])[:, None, :], (3, batch, seq))
    return info


def _step_pos_info(cfg, batch, pos):
    p = jnp.full((batch, 1), pos, jnp.int32)
    info = {"pos": p}
    if cfg.rope == "mrope":
        info["pos3d"] = jnp.broadcast_to(p[None], (3, batch, 1))
    return info


# ---------------------------------------------------------------------------
# encoder (whisper)
# ---------------------------------------------------------------------------


def _encode(cfg, params, frames):
    """frames: (B, enc_seq, d_model) stub embeddings (post-conv)."""
    x = frames + params["encoder"]["pos"].astype(frames.dtype)[None]

    def block(x, p):
        h = layers.norm_apply(cfg.norm, p["ln1"], x)
        q, k, v = _project_qkv(cfg, p["attn"], h, x.dtype)
        out = _full_bidir_attention(q, k, v)
        x = x + jnp.einsum("bhsk,hkd->bsd", out, p["attn"]["wo"].astype(x.dtype))
        h2 = layers.norm_apply(cfg.norm, p["ln2"], x)
        x = x + layers.mlp(p["mlp"], h2, act=cfg.act)
        return x, None

    # remat the encoder blocks: without it the full (B, enc_seq, D)
    # residuals of all 24 layers are saved for backward (whisper train_4k
    # measured 17.5 GB/dev; §Perf G1)
    x, _ = jax.lax.scan(jax.checkpoint(block), x, params["encoder"]["blocks"])
    return layers.norm_apply(cfg.norm, params["encoder"]["final_norm"], x)


# ---------------------------------------------------------------------------
# full forward / prefill / decode
# ---------------------------------------------------------------------------


def _embed_inputs(cfg, params, tokens, extra, dtype):
    x = layers.embed(params["embed"], tokens, dtype)
    if (cfg.frontend != "none" and not cfg.enc_dec
            and extra is not None and "frontend_embeds" in extra):
        # VLM stub: the first F positions are precomputed patch embeddings
        # (enc-dec archs route frontend embeddings to the encoder instead)
        fe = extra["frontend_embeds"].astype(dtype)      # (B, F, d)
        f = fe.shape[1]
        x = jnp.concatenate([fe, x[:, f:]], axis=1)
    if cfg.enc_dec:
        s = tokens.shape[1]
        x = x + params["dec_pos"][:s].astype(dtype)[None]
    return x


def forward_full(cfg: ArchConfig, params, tokens, extra=None,
                 dtype=jnp.bfloat16, remat: bool = True,
                 collect_cache: bool = False, act_sharding=None,
                 unit_constraint=None):
    """Logits for the whole sequence.

    Returns ``(hidden, cache, aux)`` where hidden is pre-head (B,S,D);
    use ``logits_from_hidden``/``chunked_cross_entropy`` for the head —
    callers choose whether full logits are ever materialized.
    """
    b, s = tokens.shape
    x = _embed_inputs(cfg, params, tokens, extra, dtype)

    def _constrain(t):
        # Megatron-SP-style activation sharding: between layer units the
        # (B, S, D) carry is sharded on the sequence axis over `model` —
        # the dominant persistent memory (one carry per unit is saved for
        # the rematerialized backward) drops by the model-axis width, at
        # the cost of an all-gather/reduce-scatter pair per unit that XLA
        # inserts around the attention/MLP compute (benchmarks/README.md §Perf).
        if act_sharding is None:
            return t
        return jax.lax.with_sharding_constraint(t, act_sharding)

    x = _constrain(x)
    enc_out = None
    if cfg.enc_dec:
        enc_out = _encode(cfg, params, extra["frontend_embeds"].astype(dtype)) \
            if (extra and "frontend_embeds" in extra) else None
        if enc_out is None:
            raise ValueError("enc_dec arch requires extra['frontend_embeds']")
    pos_info = _full_pos_info(cfg, b, s, cfg.frontend_len)
    n_units, tail = pattern_layout(cfg)

    def unit_apply(x, unit_p):
        if unit_constraint is not None:
            # Force FSDP weight shards to all-gather per unit (small) rather
            # than partial-sum + activation-sized all-reduce (runtime/
            # shardings.unit_gather_shardings; §Perf M1). Cast float params
            # to the compute dtype FIRST so the gather moves bf16, not the
            # f32 master copies (2x wire; §Perf M3).
            def _cast_constrain(w, s):
                if hasattr(w, "dtype") and jnp.issubdtype(w.dtype, jnp.floating):
                    w = w.astype(dtype)
                return w if s is None else jax.lax.with_sharding_constraint(w, s)

            unit_p = jax.tree.map(
                _cast_constrain, unit_p, unit_constraint,
                is_leaf=lambda v: v is None or hasattr(v, "shape"))
        caches, auxes = [], []
        for i, kind in enumerate(cfg.block_pattern):
            x, c, a = _block_full(cfg, kind, unit_p[str(i)], x, pos_info, enc_out)
            caches.append(c)
            auxes.append(a)
        return _constrain(x), caches, sum(auxes)

    unit_fn = unit_apply
    if remat:
        unit_fn = jax.checkpoint(
            unit_apply,
            policy=jax.checkpoint_policies.save_only_these_names())

    aux_total = jnp.zeros((), jnp.float32)
    all_caches: dict[str, Any] = {}
    # deepseek-style leading dense layers
    for i, p in enumerate(params.get("head_layers", [])):
        x, c, a = _attn_full(cfg, p, x, pos_info, window=0, causal=True,
                             kv_len_cap=0, enc_out=enc_out)
        x = _constrain(x)
        aux_total += a
        if collect_cache:
            all_caches[f"head_{i}"] = c

    if n_units > 0:
        def scan_body(carry, unit_p):
            x, aux = carry
            x, caches, a = unit_fn(x, unit_p)
            ys = caches if collect_cache else None
            return (x, aux + a), ys

        (x, aux_total), unit_caches = jax.lax.scan(
            scan_body, (x, aux_total), params["units"])
        if collect_cache:
            all_caches["units"] = unit_caches

    for i, (kind, p) in enumerate(zip(tail, params.get("tail", []))):
        x, c, a = _block_full(cfg, kind, p, x, pos_info, enc_out)
        aux_total += a
        if collect_cache:
            all_caches[f"tail_{i}"] = c

    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    return x, (all_caches if collect_cache else None), aux_total


def logits_from_hidden(cfg, params, hidden):
    if cfg.tie_embeddings:
        table = params["embed"]["table"].astype(hidden.dtype)
        return hidden @ table.T
    return hidden @ params["lm_head"]["w"].astype(hidden.dtype)


def chunked_cross_entropy(cfg, params, hidden, targets, chunk: int = 512):
    """Mean token cross-entropy without materializing (B,S,V) logits:
    the LM head matmul + log-softmax run per sequence chunk (memory lever
    recorded in benchmarks/README.md §Perf)."""
    b, s, d = hidden.shape
    n_chunks = s // chunk if s % chunk == 0 else -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    hc = hidden.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    tc = targets.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(h, t):
        # rematted: the (B, chunk, V) f32 logits are recomputed in backward
        # instead of saved per chunk (saving them would reconstitute the
        # full-logits memory footprint the chunking exists to avoid)
        logits = logits_from_hidden(cfg, params, h).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(t, 0)[..., None], axis=-1)[..., 0]
        valid = (t >= 0).astype(jnp.float32)
        return ((logz - gold) * valid).sum(), valid.sum()

    def body(carry, inp):
        h, t = inp
        nll, nvalid = chunk_nll(h, t)
        return (carry[0] + nll, carry[1] + nvalid), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hc, tc))
    return total / jnp.maximum(count, 1.0)


def prefill(cfg: ArchConfig, params, tokens, extra=None, dtype=jnp.bfloat16,
            act_sharding=None):
    """Returns (last_token_logits, caches)."""
    hidden, caches, _ = forward_full(cfg, params, tokens, extra, dtype,
                                     remat=False, collect_cache=True,
                                     act_sharding=act_sharding)
    logits = logits_from_hidden(cfg, params, hidden[:, -1:])
    return logits[:, 0], caches


def init_decode_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, quantized: bool = False):
    """Zero caches sized for ``max_len`` decode positions.

    ``quantized=True`` stores K/V as int8 with per-(token, head) f32 scales
    — halves the cache footprint and read bandwidth of the memory-bound
    decode cells (benchmarks/README.md §Perf Q1)."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim_
    n_units, tail = pattern_layout(cfg)

    def kv(s):
        if quantized:
            return {"k": jnp.zeros((batch, hkv, s, dh), jnp.int8),
                    "v": jnp.zeros((batch, hkv, s, dh), jnp.int8),
                    "ks": jnp.zeros((batch, hkv, s), jnp.float32),
                    "vs": jnp.zeros((batch, hkv, s), jnp.float32)}
        return {"k": jnp.zeros((batch, hkv, s, dh), dtype),
                "v": jnp.zeros((batch, hkv, s, dh), dtype)}

    def entry(kind):
        if kind == "attn":
            return kv(max_len)
        if kind == "local":
            return kv(min(cfg.window or max_len, max_len))
        if kind == "rglru":
            return rglru.rglru_init_state(batch, _d_rnn(cfg), dtype)
        if kind == "mlstm":
            return xlstm.mlstm_init_state(batch, cfg.n_heads,
                                          cfg.d_model // cfg.n_heads)
        if kind == "slstm":
            return xlstm.slstm_init_state(batch, cfg.n_heads,
                                          cfg.d_model // cfg.n_heads)
        raise ValueError(kind)

    cache: dict[str, Any] = {}
    for i in range(cfg.n_dense_layers):
        cache[f"head_{i}"] = entry("attn")
    if n_units > 0:
        def stack(e):
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x[None], (n_units,) + x.shape), e)
        cache["units"] = [stack(entry(k)) for k in cfg.block_pattern]
    for i, kind in enumerate(tail):
        cache[f"tail_{i}"] = entry(kind)
    return cache


def grow_cache(cfg: ArchConfig, caches, prefill_len: int, max_len: int,
               dtype=jnp.bfloat16):
    """Adapt ``prefill`` caches into fixed decode buffers of ``max_len``.

    Full-attention entries are zero-padded on the seq axis (decode masks by
    ``pos+1``). Local-attention entries are *rolled* so that the entry for
    absolute position ``p`` sits at slot ``p % window`` — the invariant
    ``decode_step`` writes with (slot ordering is irrelevant to attention
    itself since RoPE encodes absolute positions, but eviction must hit the
    oldest slot). Recurrent states pass through unchanged.
    """
    window = cfg.window

    def _pad_seq(x, pad):
        widths = [(0, 0)] * x.ndim
        widths[-2] = (0, pad)
        return jnp.pad(x, widths)

    def fix(kind, entry):
        if kind not in ("attn", "local"):
            return entry  # recurrent state passes through
        k, v = entry["k"], entry["v"]  # rank 4, or rank 5 when unit-stacked
        if kind == "local" and window:
            # chronological [prefill_len - s .. prefill_len) -> slot p % window
            shift = prefill_len % window if prefill_len >= window else 0
            if shift:
                k = jnp.roll(k, shift, axis=-2)
                v = jnp.roll(v, shift, axis=-2)
            target = min(window, max_len)
        else:
            target = max_len
        pad = target - k.shape[-2]
        if pad > 0:
            k, v = _pad_seq(k, pad), _pad_seq(v, pad)
        return {"k": k.astype(dtype), "v": v.astype(dtype)}

    n_units, tail = pattern_layout(cfg)
    out = {}
    for key, val in caches.items():
        if key == "units":
            # val: list over pattern elements of stacked entries
            out["units"] = [fix(cfg.block_pattern[i], e)
                            for i, e in enumerate(val)]
        elif key.startswith("head_"):
            out[key] = fix("attn", val)
        elif key.startswith("tail_"):
            out[key] = fix(tail[int(key.split("_")[1])], val)
        else:
            out[key] = val
    return out


def decode_step(cfg: ArchConfig, params, token, cache, pos, extra=None,
                dtype=jnp.bfloat16):
    """One decode step. token: (B,) int32; pos: scalar int32 (same for all
    rows — continuous batching offsets are handled a level up).
    Returns (logits (B, V), new_cache)."""
    x = layers.embed(params["embed"], token[:, None], dtype)
    if cfg.enc_dec:
        x = x + jax.lax.dynamic_slice_in_dim(
            params["dec_pos"], pos, 1, axis=0).astype(dtype)[None]
    enc_out = None
    if cfg.enc_dec:
        if extra is None or "enc_out" not in extra:
            raise ValueError("enc_dec decode needs extra['enc_out']")
        enc_out = extra["enc_out"]

    new_cache: dict[str, Any] = {}
    for i in range(cfg.n_dense_layers):
        p = params["head_layers"][i]
        x, c = _attn_step(cfg, p, x, cache[f"head_{i}"], pos, window=0,
                          enc_out=enc_out)
        new_cache[f"head_{i}"] = c

    n_units, tail = pattern_layout(cfg)
    if n_units > 0:
        def scan_body(x, inp):
            unit_p = inp[0]
            unit_caches = inp[1:]
            new_cs = []
            for i, kind in enumerate(cfg.block_pattern):
                x, c = _block_step(cfg, kind, unit_p[str(i)], x,
                                   unit_caches[i], pos, enc_out)
                new_cs.append(c)
            return x, tuple(new_cs)

        x, new_unit_caches = jax.lax.scan(
            scan_body, x, (params["units"], *cache["units"]))
        new_cache["units"] = list(new_unit_caches)

    for i, (kind, p) in enumerate(zip(tail, params.get("tail", []))):
        x, c = _block_step(cfg, kind, p, x, cache[f"tail_{i}"], pos, enc_out)
        new_cache[f"tail_{i}"] = c

    x = layers.norm_apply(cfg.norm, params["final_norm"], x)
    logits = logits_from_hidden(cfg, params, x)
    return logits[:, 0], new_cache
