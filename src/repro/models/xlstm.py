"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) and sLSTM
(scalar memory, exponential gating), stacked alternately.

mLSTM recurrence (per head, stabilized exponential gating):
    C_t = f_t C_{t-1} + i_t v_t k_t^T        (Dh x Dh matrix memory)
    n_t = f_t n_{t-1} + i_t k_t
    m_t = max(log f_t + m_{t-1}, log i_t)    (stabilizer)
    h_t = (C_t q_t) / max(|n_t . q_t|, 1)

sLSTM keeps per-head scalar cell state with exponential gates and a
recurrent (h_{t-1} -> gates) path, making it inherently sequential.

Both are implemented as ``lax.scan`` over the sequence for train/prefill
and a fused single step for decode. The constant-size state ``(C, n, m)``
is what qualifies xlstm for the 512k cell. A chunkwise-parallel mLSTM
(quadratic-within-chunk, recurrent-across-chunk) is the documented TPU
perf path (benchmarks/README.md §Perf discusses the trade-off); the sequential
scan is the always-correct reference implementation.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers

__all__ = [
    "mlstm_block_init", "mlstm_apply", "mlstm_step", "mlstm_init_state",
    "slstm_block_init", "slstm_apply", "slstm_step", "slstm_init_state",
    "SEQ_CHUNK",
]

# Sequence scans are checkpointed per chunk: the backward pass stores only
# chunk-boundary states and recomputes the in-chunk recurrence, instead of
# saving every per-step residual (for mLSTM that residual includes the
# (B, H, Dh, Dh) matrix memory — 4096 steps of it measured 110 GB/device
# on the train_4k cell; chunking drops it ~S/chunk-fold at the cost of one
# extra forward recompute. benchmarks/README.md §Perf iteration X1).
SEQ_CHUNK = 256


def _chunked_scan(cell, state, xs, chunk: int = SEQ_CHUNK):
    """lax.scan over time with per-chunk jax.checkpoint. ``xs`` leaves have
    leading dim S; requires S % chunk == 0 (callers fall back to chunk=S)."""
    s = jax.tree.leaves(xs)[0].shape[0]
    chunk = min(chunk, s)
    if s % chunk != 0:
        chunk = s  # degenerate: single chunk (smoke-test sizes)
    n_chunks = s // chunk
    xs_c = jax.tree.map(
        lambda x: x.reshape((n_chunks, chunk) + x.shape[1:]), xs)

    @jax.checkpoint
    def chunk_body(st, x_chunk):
        return jax.lax.scan(cell, st, x_chunk)

    state, ys = jax.lax.scan(chunk_body, state, xs_c)
    return state, jax.tree.map(
        lambda y: y.reshape((s,) + y.shape[2:]), ys)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_block_init(key, d: int, n_heads: int, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    return {
        "w_q": layers.dense_init(ks[0], d, d, dtype),
        "w_k": layers.dense_init(ks[1], d, d, dtype),
        "w_v": layers.dense_init(ks[2], d, d, dtype),
        "w_i": jax.random.normal(ks[3], (d, n_heads), jnp.float32) * s,
        "w_f": jax.random.normal(ks[4], (d, n_heads), jnp.float32) * s,
        "b_i": jnp.zeros((n_heads,), jnp.float32),
        "b_f": jnp.ones((n_heads,), jnp.float32) * 3.0,  # open forget gates
        "w_o": layers.dense_init(ks[5], d, d, dtype),
        "skip": layers.dense_init(ks[6], d, d, dtype),
    }


def mlstm_init_state(batch: int, n_heads: int, dh: int) -> dict:
    return {
        "c": jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, n_heads, dh), jnp.float32),
        "m": jnp.full((batch, n_heads), -1e30, jnp.float32),
    }


def _mlstm_qkv(p, x, nh):
    b, s, d = x.shape
    dh = d // nh
    q = layers.dense(p["w_q"], x).reshape(b, s, nh, dh)
    k = layers.dense(p["w_k"], x).reshape(b, s, nh, dh) / math.sqrt(dh)
    v = layers.dense(p["w_v"], x).reshape(b, s, nh, dh)
    xf = x.astype(jnp.float32)
    log_i = (xf @ p["w_i"] + p["b_i"])          # (B,S,H) pre-exp input gate
    log_f = jax.nn.log_sigmoid(xf @ p["w_f"] + p["b_f"])
    return q, k, v, log_i, log_f


def _mlstm_cell(state, q_t, k_t, v_t, log_i_t, log_f_t):
    """One recurrence step; all f32. Shapes: q/k/v (B,H,Dh), gates (B,H)."""
    m_new = jnp.maximum(log_f_t + state["m"], log_i_t)
    f_ = jnp.exp(log_f_t + state["m"] - m_new)[..., None]        # (B,H,1)
    i_ = jnp.exp(log_i_t - m_new)[..., None]                     # (B,H,1)
    c_new = f_[..., None] * state["c"] + i_[..., None] * (
        v_t[..., :, None] * k_t[..., None, :])                   # (B,H,Dh,Dh)
    n_new = f_ * state["n"] + i_ * k_t
    h_num = jnp.einsum("bhij,bhj->bhi", c_new, q_t)
    h_den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, q_t)), 1.0)
    h = h_num / h_den[..., None]
    return {"c": c_new, "n": n_new, "m": m_new}, h


def mlstm_apply(p: dict, x: jax.Array, n_heads: int, state: dict | None = None):
    """Full sequence. x: (B,S,d) -> (out, final_state)."""
    b, s, d = x.shape
    nh = n_heads
    dh = d // nh
    q, k, v, log_i, log_f = _mlstm_qkv(p, x, nh)
    if state is None:
        state = mlstm_init_state(b, nh, dh)

    def body(st, inp):
        q_t, k_t, v_t, li_t, lf_t = inp
        st, h = _mlstm_cell(st, q_t.astype(jnp.float32),
                            k_t.astype(jnp.float32),
                            v_t.astype(jnp.float32), li_t, lf_t)
        return st, h

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_i.transpose(1, 0, 2),
          log_f.transpose(1, 0, 2))
    state, hs = _chunked_scan(body, state, xs)
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = layers.dense(p["w_o"], h) + layers.dense(p["skip"], x)
    return out, state


def mlstm_step(p: dict, x: jax.Array, n_heads: int, state: dict):
    """Single decode step. x: (B,1,d)."""
    b, _, d = x.shape
    q, k, v, log_i, log_f = _mlstm_qkv(p, x, n_heads)
    state, h = _mlstm_cell(
        state, q[:, 0].astype(jnp.float32), k[:, 0].astype(jnp.float32),
        v[:, 0].astype(jnp.float32), log_i[:, 0], log_f[:, 0])
    h = h.reshape(b, 1, d).astype(x.dtype)
    out = layers.dense(p["w_o"], h) + layers.dense(p["skip"], x)
    return out, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_block_init(key, d: int, n_heads: int, dtype=jnp.float32) -> dict:
    dh = d // n_heads
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    sh = 1.0 / math.sqrt(dh)
    def gate(k):
        return {
            "w": jax.random.normal(k, (d, n_heads, dh), jnp.float32) * s,
            "r": jax.random.normal(jax.random.fold_in(k, 1),
                                   (n_heads, dh, dh), jnp.float32) * sh,
            "b": jnp.zeros((n_heads, dh), jnp.float32),
        }
    return {
        "z": gate(ks[0]), "i": gate(ks[1]), "f": gate(ks[2]), "o": gate(ks[3]),
        "w_out": layers.dense_init(ks[4], d, d, dtype),
        "skip": layers.dense_init(ks[5], d, d, dtype),
    }


def slstm_init_state(batch: int, n_heads: int, dh: int) -> dict:
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full_like(z, -1e30)}


def _slstm_cell(p, st, x_t):
    """x_t: (B, d) f32. Recurrent gates use h_{t-1}."""
    def pre(g):
        return (jnp.einsum("bd,dhk->bhk", x_t, p[g]["w"])
                + jnp.einsum("bhk,hkj->bhj", st["h"], p[g]["r"])
                + p[g]["b"])
    z = jnp.tanh(pre("z"))
    o = jax.nn.sigmoid(pre("o"))
    log_i = pre("i")
    log_f = jax.nn.log_sigmoid(pre("f"))
    m_new = jnp.maximum(log_f + st["m"], log_i)
    i_ = jnp.exp(log_i - m_new)
    f_ = jnp.exp(log_f + st["m"] - m_new)
    c_new = f_ * st["c"] + i_ * z
    n_new = f_ * st["n"] + i_
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new


def slstm_apply(p: dict, x: jax.Array, n_heads: int, state: dict | None = None):
    b, s, d = x.shape
    nh = n_heads
    dh = d // nh
    if state is None:
        state = slstm_init_state(b, nh, dh)
    xf = x.astype(jnp.float32)

    def body(st, x_t):
        return _slstm_cell(p, st, x_t)

    state, hs = _chunked_scan(body, state, xf.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype)
    out = layers.dense(p["w_out"], h) + layers.dense(p["skip"], x)
    return out, state


def slstm_step(p: dict, x: jax.Array, n_heads: int, state: dict):
    b, _, d = x.shape
    state, h = _slstm_cell(p, state, x[:, 0].astype(jnp.float32))
    h = h.reshape(b, 1, d).astype(x.dtype)
    out = layers.dense(p["w_out"], h) + layers.dense(p["skip"], x)
    return out, state
