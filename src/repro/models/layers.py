"""Common model layers — pure functional JAX (params are plain pytrees).

Conventions:
  * every ``init_*`` returns a dict pytree; every ``apply``-style function
    takes ``(params, x, ...)``;
  * weights are stored in ``param_dtype`` (f32 master; cast to ``dtype``
    at use — the standard mixed-precision recipe);
  * layers are written to be ``vmap``/``scan``-stackable: no python state.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "dense", "rmsnorm_init", "rmsnorm", "layernorm_init",
    "layernorm", "norm_init", "norm_apply", "embedding_init", "embed",
    "mlp_init", "mlp", "rotary_angles", "apply_rope", "apply_rope_half",
    "apply_mrope",
]


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> dict:
    scale = 1.0 / math.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), dtype) * scale}


def dense(p: dict, x: jax.Array, dtype=None) -> jax.Array:
    w = p["w"].astype(dtype or x.dtype)
    return x @ w


def rmsnorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(dt)


def layernorm_init(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(dt)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> dict:
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


def embedding_init(key, vocab: int, d: int, dtype=jnp.float32) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), dtype) * 0.02}


def embed(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


# --------------------------------------------------------------------------
# Gated MLP (silu/gelu)
# --------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_in": dense_init(k1, d, d_ff, dtype),
        "w_gate": dense_init(k2, d, d_ff, dtype),
        "w_out": dense_init(k3, d_ff, d, dtype),
    }


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    h = dense(p["w_in"], x)
    g = dense(p["w_gate"], x)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return dense(p["w_out"], h * g)


# --------------------------------------------------------------------------
# Rotary position embeddings: standard, half (chatglm 2d), M-RoPE (qwen2-vl)
# --------------------------------------------------------------------------


def rotary_angles(positions: jax.Array, dim: int, base: float = 10_000.0):
    """(..., dim/2) angles for ``positions`` (any int shape)."""
    inv = 1.0 / (base ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv  # (..., dim/2)


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Rotate pairs (even, odd) of the last dim by ``angles``.

    x: (B, H, S, D) or (B, S, D); angles: (B?, S, D/2) broadcastable.
    """
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    cos = jnp.cos(angles).astype(x.dtype)
    sin = jnp.sin(angles).astype(x.dtype)
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    out = jnp.stack([r1, r2], axis=-1)
    return out.reshape(x.shape)


def apply_rope(q: jax.Array, k: jax.Array, positions: jax.Array,
               base: float = 10_000.0):
    """Standard RoPE over the full head dim. q,k: (B, H, S, Dh);
    positions: (B, S) or (S,)."""
    dh = q.shape[-1]
    ang = rotary_angles(positions, dh, base)          # (B?, S, Dh/2)
    if ang.ndim == 2:                                  # (S, Dh/2)
        ang = ang[None]
    ang = ang[:, None]                                 # (B, 1, S, Dh/2)
    return _rotate(q, ang), _rotate(k, ang)


def apply_rope_half(q: jax.Array, k: jax.Array, positions: jax.Array,
                    base: float = 10_000.0):
    """ChatGLM-style 2D RoPE: rotate only the first half of the head dim,
    pass the second half through."""
    dh = q.shape[-1]
    half = dh // 2
    ang = rotary_angles(positions, half, base)
    if ang.ndim == 2:
        ang = ang[None]
    ang = ang[:, None]
    q_rot = _rotate(q[..., :half], ang)
    k_rot = _rotate(k[..., :half], ang)
    return (jnp.concatenate([q_rot, q[..., half:]], -1),
            jnp.concatenate([k_rot, k[..., half:]], -1))


def apply_mrope(q: jax.Array, k: jax.Array, positions_3d: jax.Array,
                sections: tuple[int, int, int] = (16, 24, 24),
                base: float = 10_000.0):
    """Qwen2-VL M-RoPE: the head dim is split into (temporal, height, width)
    sections, each rotated by its own position stream.

    positions_3d: (3, B, S) — for pure-text positions all three streams are
    equal, which makes M-RoPE degenerate to standard RoPE (the property the
    paper relies on, asserted in tests).
    sections: half-dim sizes per stream; sum must be head_dim/2.
    """
    dh = q.shape[-1]
    assert sum(sections) == dh // 2, (sections, dh)
    # global frequency table, split contiguously across sections (Qwen2-VL):
    # with equal position streams this reproduces standard RoPE exactly
    # (property asserted in tests/test_models_smoke.py).
    inv_all = 1.0 / (base ** (jnp.arange(0, dh, 2, dtype=jnp.float32) / dh))
    angs = []
    start = 0
    for i, sec in enumerate(sections):
        inv = inv_all[start : start + sec]
        start += sec
        angs.append(positions_3d[i].astype(jnp.float32)[..., None] * inv)
    ang = jnp.concatenate(angs, axis=-1)               # (B, S, dh/2)
    ang = ang[:, None]                                 # (B, 1, S, dh/2)
    return _rotate(q, ang), _rotate(k, ang)
