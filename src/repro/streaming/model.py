"""Fitted co-cluster model artifact (DESIGN.md §10).

A :class:`CoclusterModel` is everything the serving path needs to assign
new rows/columns to an existing co-clustering without the data matrix:

  * consensus labels + vote tables (the batch result, for training-set
    lookups and confidence),
  * per-cluster *serving signatures* — unit-normalized cluster means over
    the globally shared anchor features (``merging.cluster_signatures``),
    plus the centering means,
  * the anchor index sets themselves (which coordinates of an incoming
    vector to read).

Every field is an array, so the model is a plain pytree and goes through
``repro.checkpoint`` unchanged; the non-array fit context (LAMCConfig /
PartitionPlan / provenance) rides along in the checkpoint's ``extra_meta``
and is restored next to it. ``save_model``/``load_model`` wrap that
round-trip; ``load_model`` fails loudly on unfitted or stale checkpoints
(wrong kind, missing signatures) instead of serving garbage.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import numpy as np

from repro import checkpoint as _ckpt
from repro.core.lamc import LAMCConfig, LAMCResult
from repro.core.partition import PartitionPlan

__all__ = ["CoclusterModel", "model_from_result", "model_memberships",
           "save_model", "load_model", "ModelLoadError", "MODEL_KIND"]

MODEL_KIND = "cocluster_model"
_MODEL_VERSION = 1


class CoclusterModel(NamedTuple):
    """Serving artifact — array leaves only (checkpoint-friendly pytree)."""

    row_labels: jax.Array   # (M,) int32 consensus labels
    col_labels: jax.Array   # (N,) int32
    row_votes: jax.Array    # (M, K_row) f32 vote counts
    col_votes: jax.Array    # (N, K_col)
    row_sigs: jax.Array     # (K_row, q_row) unit-normalized cluster signatures
    col_sigs: jax.Array     # (K_col, q_col)
    row_mean: jax.Array     # (q_row,) centering mean of the anchor-col features
    col_mean: jax.Array     # (q_col,)
    anchor_rows: jax.Array  # (q_col,) int32 global row ids (features for cols)
    anchor_cols: jax.Array  # (q_row,) int32 global col ids (features for rows)

    @property
    def n_rows(self) -> int:
        return self.row_labels.shape[0]

    @property
    def n_cols(self) -> int:
        return self.col_labels.shape[0]

    @property
    def n_row_clusters(self) -> int:
        return self.row_sigs.shape[0]

    @property
    def n_col_clusters(self) -> int:
        return self.col_sigs.shape[0]


class ModelLoadError(RuntimeError):
    """A checkpoint exists but does not contain a servable fitted model."""


def model_from_result(result: LAMCResult) -> CoclusterModel:
    """Pack a fitted ``LAMCResult`` into the serving artifact.

    Requires the signature fields threaded through the merge (populated by
    ``lamc_cocluster`` / ``distributed_lamc``); a result built without them
    cannot serve out-of-sample assignment and is rejected here rather than
    at request time.
    """
    missing = [f for f in ("row_sigs", "col_sigs", "row_mean", "col_mean",
                           "anchor_rows", "anchor_cols")
               if getattr(result, f) is None]
    if missing:
        raise ValueError(
            f"LAMCResult is missing serving fields {missing}; re-fit with the "
            "current lamc_cocluster/distributed_lamc (older results carry "
            "labels only and cannot assign out-of-sample points)")
    return CoclusterModel(
        row_labels=result.row_labels, col_labels=result.col_labels,
        row_votes=result.row_votes, col_votes=result.col_votes,
        row_sigs=result.row_sigs, col_sigs=result.col_sigs,
        row_mean=result.row_mean, col_mean=result.col_mean,
        anchor_rows=result.anchor_rows, anchor_cols=result.anchor_cols,
    )


def model_memberships(model: CoclusterModel, overlap_threshold: float = 0.25,
                      min_membership: int = 0):
    """Overlap-mode membership matrices from the fitted vote tables.

    ``(row_membership (M, K_row) bool, col_membership (N, K_col) bool)``
    under the vote-share rule of ``merging.memberships_from_votes``
    (DESIGN.md §11). The vote tables are part of the artifact, so the
    membership view is *derived at load time* with any knobs — the
    checkpoint schema stays fixed and one saved model serves hard labels,
    top-k assignment, and thresholded membership alike. A model whose
    ``col_votes`` are the one-hot of its column labels (the streaming
    fitter's finalize) yields single memberships for columns, as it
    should: the stream saw each column profile once.
    """
    from repro.core import merging as _merging

    return (_merging.memberships_from_votes(
                model.row_votes, overlap_threshold, min_membership),
            _merging.memberships_from_votes(
                model.col_votes, overlap_threshold, min_membership))


def save_model(ckpt_dir: str, model: CoclusterModel,
               cfg: LAMCConfig | None = None,
               plan: PartitionPlan | None = None,
               step: int = 0, extra: dict | None = None) -> str:
    """Persist the model via ``repro.checkpoint`` (atomic commit)."""
    meta = {
        "kind": MODEL_KIND,
        "version": _MODEL_VERSION,
        "config": dataclasses.asdict(cfg) if cfg is not None else None,
        "plan": dataclasses.asdict(plan) if plan is not None else None,
    }
    if extra:
        meta.update(extra)
    return _ckpt.save(ckpt_dir, step, model, extra_meta=meta)


def _model_template(ckpt_dir: str, step: int) -> CoclusterModel:
    """Build the restore template from the manifest's shapes/dtypes.

    The checkpoint machinery restores *into* a structure; for a model we
    only know the NamedTuple, so shapes come from the manifest itself.
    Goes through ``checkpoint.read_manifest`` so a missing/truncated
    manifest surfaces as ``CheckpointCorruptError``, not a JSON traceback.
    """
    meta = _ckpt.read_manifest(ckpt_dir, step)
    leaves = meta["leaves"]
    # leaf names come from the checkpoint's own flattener so the template
    # construction can never drift from the save-side naming
    dummy = CoclusterModel(*([0] * len(CoclusterModel._fields)))
    names, _, _ = _ckpt.checkpoint._flatten_with_names(dummy)
    if sorted(leaves) != sorted(names):
        raise ModelLoadError(
            f"checkpoint at {ckpt_dir!r} step {step} has leaves "
            f"{sorted(leaves)} — not a CoclusterModel ({sorted(names)}); "
            "stale artifact from a different schema?")
    vals = []
    for name in names:
        info = leaves[name]
        vals.append(np.zeros(tuple(info["shape"]), dtype=np.dtype(info["dtype"])))
    return CoclusterModel(*vals)


def load_model(ckpt_dir: str, step: int | None = None
               ) -> tuple[CoclusterModel, dict]:
    """Restore ``(model, meta)`` from ``ckpt_dir``; loud failure modes.

    Raises :class:`ModelLoadError` when the directory holds no committed
    checkpoint (unfitted), or a checkpoint that is not a cocluster model
    (stale/foreign artifact) — with a message that says what to do.
    """
    if step is None:
        step = _ckpt.latest_step(ckpt_dir)
    if step is None:
        raise ModelLoadError(
            f"no committed checkpoint under {ckpt_dir!r} — fit a model first "
            "(streaming.fit or lamc_cocluster + model_from_result) and "
            "save_model() it")
    template = _model_template(ckpt_dir, step)
    model, meta = _ckpt.restore(ckpt_dir, step, template)
    meta = meta or {}
    if meta.get("kind") != MODEL_KIND:
        raise ModelLoadError(
            f"checkpoint at {ckpt_dir!r} step {step} is "
            f"kind={meta.get('kind')!r}, expected {MODEL_KIND!r} — this is "
            "not a fitted co-cluster model (stale or foreign checkpoint)")
    return model, meta
