"""Sharded, multi-replica assignment service with hot model swap.

The production serving layer over ``streaming.assign`` (DESIGN.md §15):
callers :meth:`AssignService.submit` variable-size request batches and
get back a :class:`Ticket`; worker replicas coalesce admitted requests
into **fixed-shape** jit batches (zero-padded to ``ServeConfig.batch``
rows, so every (axis, k) pair compiles exactly once per model version),
score them against the current :class:`_Engine`, and fulfil the tickets
with host numpy results stamped with the model version that served them.

Admission is load-shedding, not blocking: a request is rejected *at
submit* — with a machine-readable reason code counted per-reason in
``repro.obs`` (``serve_svc_rejected{reason=...}``) — when it is
malformed (rank/width/dtype/non-finite), larger than one jit batch
(``oversize``), the queue's bounded row budget is exhausted
(``queue_full``), or the service is closed (``shutdown``). An admitted
request is never dropped: workers drain the queue on close, and a swap
never touches in-flight work.

Hot swap protocol: a new model (fitted or loaded in the background —
see :meth:`swap_async` and :class:`streaming.registry.ModelRegistry`) is
wrapped in a fresh engine, its scorers are **pre-warmed** for every
(axis, k) shape the old engine had compiled, and only then is the
engine reference swapped — one atomic assignment. Workers read the
reference once per batch, so every batch (and therefore every response)
is attributable to exactly one version; there is no torn state to read
because an engine is immutable after construction.

Sharding: with more than one device visible, the per-cluster signature
and vote tables are placed via ``runtime.shardings.serve_model_specs``
(cluster-sharded scoring; anchors/means replicate) — the single-device
path is the same code with replicated specs.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Callable, NamedTuple

import jax
import numpy as np

from repro import obs as _obs
from repro.runtime import shardings as _shardings

from .assign import assign_cols, assign_cols_topk, assign_rows, assign_rows_topk
from .model import CoclusterModel

__all__ = ["AssignService", "ServeConfig", "ServeResult", "Ticket",
           "validate_request", "REJECT_REASONS"]

#: admission reject reason codes (the ``reason`` label of
#: ``serve_svc_rejected``); ``internal_error`` is the post-admission
#: failure path (a batch that raised inside the scorer).
REJECT_REASONS = ("bad_rank", "bad_width", "bad_dtype", "non_finite",
                  "bad_k", "oversize", "queue_full", "shutdown",
                  "internal_error")


def validate_request(x, dim: int) -> tuple[str, str] | None:
    """``(reason_code, detail)`` for one request batch, or None if servable.

    Checks are host-side and cheap relative to the assign kernel: rank
    and width (a wrong-width batch would be a jit shape error five
    frames deep), non-float payloads, and non-finite values (NaN/Inf
    scores would win/lose every argmax and silently poison the labels).
    Zero-row batches are *valid* — the coalescer's flush can produce
    them and ``assign_rows``/``assign_cols`` return empty results.
    """
    shape = tuple(np.shape(x))
    if len(shape) != 2:
        return ("bad_rank",
                f"expected (batch, {dim}), got shape {shape}")
    if shape[1] != dim:
        return ("bad_width",
                f"model expects {dim} features, request has {shape[1]} "
                f"(shape {shape})")
    arr = np.asarray(x)
    if not np.issubdtype(arr.dtype, np.floating):
        return ("bad_dtype", f"expected float features, got {arr.dtype}")
    if not np.isfinite(arr).all():
        bad = int(np.size(arr) - np.isfinite(arr).sum())
        return ("non_finite", f"{bad} NaN/Inf values in the batch")
    return None


class ServeResult(NamedTuple):
    """Terminal state of one submitted request."""

    ok: bool
    labels: np.ndarray | None     # (r,) int32 for k=1, (r, k) for k>1
    scores: np.ndarray | None     # same leading shape, f32
    version: str | None           # model version that served it (ok only)
    reason: str | None = None     # reject code (one of REJECT_REASONS)
    detail: str | None = None     # human-readable reject detail


class Ticket:
    """Completion handle for one submitted request (thread-safe)."""

    __slots__ = ("_event", "_result")

    def __init__(self):
        self._event = threading.Event()
        self._result: ServeResult | None = None

    def _fulfill(self, result: ServeResult) -> None:
        self._result = result
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> ServeResult:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"request not served within {timeout}s (queue backlog or "
                "service stopped?)")
        assert self._result is not None
        return self._result


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Service knobs (all static for the life of the service)."""

    batch: int = 64               # fixed jit batch rows; also max request size
    replicas: int = 1             # scoring worker threads
    max_queue_rows: int = 4096    # admission budget; beyond it -> queue_full
    poll_timeout_s: float = 0.05  # worker wake-up cadence while idle
    shard: bool = True            # device-shard tables when >1 device
    mesh_axis: str = "data"


class _Request(NamedTuple):
    seq: int
    x: np.ndarray                 # (r, dim) float32, host
    rows: int
    ticket: Ticket
    t_submit: float


class _Engine:
    """One immutable model version + its per-(axis, k) jitted scorers.

    Engines are constructed, warmed, and then only *read* — the swap
    protocol relies on that: a worker that grabbed an engine reference
    can keep scoring against it while the service reference already
    points at a successor. Scorer creation is get-or-create under a
    lock (jit tracing may be triggered from any worker thread).
    """

    def __init__(self, model: CoclusterModel, version: str, *,
                 shard: bool = True, mesh_axis: str = "data"):
        self.version = version
        self.mesh = None
        devices = jax.devices()
        if shard and len(devices) > 1:
            self.mesh = jax.sharding.Mesh(
                np.asarray(devices), (mesh_axis,))
            placed = jax.device_put(
                model, _shardings.serve_model_shardings(
                    model, self.mesh, mesh_axis))
            self.model = placed
        else:
            self.model = model
        self._scorers: dict[tuple[str, int], Callable] = {}
        self._lock = threading.Lock()

    def dim(self, axis: str) -> int:
        return self.model.n_cols if axis == "rows" else self.model.n_rows

    def n_clusters(self, axis: str) -> int:
        return (self.model.n_row_clusters if axis == "rows"
                else self.model.n_col_clusters)

    def scorer(self, axis: str, k: int) -> Callable:
        key = (axis, k)
        with self._lock:
            fn = self._scorers.get(key)
            if fn is not None:
                return fn
            model = self.model
            if k == 1:
                base = assign_rows if axis == "rows" else assign_cols
                fn = jax.jit(lambda x: base(model, x))
            else:
                base = (assign_rows_topk if axis == "rows"
                        else assign_cols_topk)
                fn = jax.jit(lambda x: base(model, x, k=k))
            self._scorers[key] = fn
            return fn

    def warm(self, axis: str, k: int, batch: int) -> None:
        """Compile + execute the (axis, k) scorer at the service's fixed
        batch shape — the pre-warm step of the swap protocol."""
        x = np.zeros((batch, self.dim(axis)), np.float32)
        jax.block_until_ready(self.scorer(axis, k)(x))

    def warmed_keys(self) -> tuple[tuple[str, int], ...]:
        with self._lock:
            return tuple(self._scorers)


class AssignService:
    """Multi-replica assignment service over one live ``CoclusterModel``.

    ``submit`` is the only request door; ``swap``/``swap_async`` replace
    the model without dropping anything; ``close`` drains and stops.
    Usable as a context manager. All results are host numpy.
    """

    def __init__(self, model: CoclusterModel, *, version: str = "v1",
                 config: ServeConfig = ServeConfig(),
                 metrics: _obs.Registry | None = None,
                 warm: bool = True):
        self.config = config
        if config.batch < 1:
            raise ValueError(f"batch must be >= 1, got {config.batch}")
        if config.replicas < 1:
            raise ValueError(
                f"replicas must be >= 1, got {config.replicas}")
        self._metrics = metrics if metrics is not None else _obs.get_registry()
        self._rejected = self._metrics.counter(
            "serve_svc_rejected", help="rejected requests, by reason")
        self._submitted = self._metrics.counter(
            "serve_svc_submitted", help="requests admitted to the queue")
        self._rows_served = self._metrics.counter(
            "serve_svc_rows", help="rows scored and returned")
        self._batches = self._metrics.counter(
            "serve_svc_batches", help="jit batches dispatched")
        self._swaps = self._metrics.counter(
            "serve_svc_swaps", help="hot model swaps")
        self._queue_gauge = self._metrics.gauge(
            "serve_svc_queue_rows", help="rows waiting for a worker")
        self._batch_lat = self._metrics.histogram(
            "serve_svc_batch_latency_us", help="score+fulfill per batch, µs")
        self._req_lat = self._metrics.histogram(
            "serve_svc_request_latency_us", help="submit->fulfill, µs")
        self._batch_fill = self._metrics.histogram(
            "serve_svc_batch_fill_pct", buckets=tuple(range(5, 101, 5)),
            help="per-batch fill: coalesced rows / batch capacity, %")

        self._engine = _Engine(model, version, shard=config.shard,
                               mesh_axis=config.mesh_axis)
        if warm:
            self._engine.warm("rows", 1, config.batch)

        self._cond = threading.Condition()
        self._queues: dict[tuple[str, int], deque[_Request]] = {}
        self._queued_rows = 0
        self._seq = 0
        self._closed = False
        self._workers = [
            threading.Thread(target=self._worker_loop, daemon=True,
                             name=f"assign-serve-{i}")
            for i in range(config.replicas)]
        for w in self._workers:
            w.start()

    # -- admission -------------------------------------------------------
    def _reject(self, code: str, detail: str) -> Ticket:
        self._rejected.labels(reason=code).inc()
        _obs.event("serve_reject", reason=code, detail=detail)
        t = Ticket()
        t._fulfill(ServeResult(ok=False, labels=None, scores=None,
                               version=None, reason=code, detail=detail))
        return t

    def submit(self, x, axis: str = "rows", k: int = 1) -> Ticket:
        """Admit one request batch; never blocks on the queue.

        ``x``: ``(r, dim)`` float array (``r <= config.batch``); ``axis``
        picks row- vs column-cluster assignment; ``k`` the top-k width
        (``k=1`` returns flat ``(r,)`` labels/scores like
        ``assign_rows``). Returns a :class:`Ticket` — already fulfilled
        with a reject reason when admission fails.
        """
        if axis not in ("rows", "cols"):
            raise ValueError(f"axis must be 'rows' or 'cols', got {axis!r}")
        engine = self._engine
        if self._closed:
            return self._reject("shutdown", "service is closed")
        bad = validate_request(x, engine.dim(axis))
        if bad is not None:
            return self._reject(*bad)
        if not 1 <= k <= engine.n_clusters(axis):
            return self._reject(
                "bad_k", f"k must be in [1, {engine.n_clusters(axis)}] for "
                         f"axis={axis!r}, got {k}")
        arr = np.ascontiguousarray(np.asarray(x), dtype=np.float32)
        rows = arr.shape[0]
        if rows > self.config.batch:
            return self._reject(
                "oversize", f"request has {rows} rows; one jit batch holds "
                            f"{self.config.batch} — split the request")
        if rows == 0:
            # legitimately empty (a coalescer flush upstream): complete
            # immediately with empty arrays of the served shapes
            shape = (0,) if k == 1 else (0, k)
            t = Ticket()
            self._submitted.inc()
            t._fulfill(ServeResult(
                ok=True, labels=np.zeros(shape, np.int32),
                scores=np.zeros(shape, np.float32), version=engine.version))
            return t
        ticket = Ticket()
        with self._cond:
            if self._closed:
                return self._reject("shutdown", "service is closed")
            if self._queued_rows + rows > self.config.max_queue_rows:
                return self._reject(
                    "queue_full",
                    f"{self._queued_rows} rows queued of "
                    f"{self.config.max_queue_rows} budget; shedding load")
            self._seq += 1
            req = _Request(self._seq, arr, rows, ticket, time.perf_counter())
            self._queues.setdefault((axis, k), deque()).append(req)
            self._queued_rows += rows
            self._queue_gauge.set(float(self._queued_rows))
            self._submitted.inc()
            self._cond.notify()
        return ticket

    # -- scoring workers -------------------------------------------------
    def _take_batch(self) -> tuple[tuple[str, int], list[_Request]] | None:
        """Pop a coalesced batch for the (axis, k) with the oldest head
        request. Caller holds ``self._cond``."""
        best_key, best_seq = None, None
        for key, q in self._queues.items():
            if q and (best_seq is None or q[0].seq < best_seq):
                best_key, best_seq = key, q[0].seq
        if best_key is None:
            return None
        q = self._queues[best_key]
        out: list[_Request] = []
        rows = 0
        while q and rows + q[0].rows <= self.config.batch:
            r = q.popleft()
            out.append(r)
            rows += r.rows
        self._queued_rows -= rows
        self._queue_gauge.set(float(self._queued_rows))
        return best_key, out

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                while not self._closed and not any(self._queues.values()):
                    self._cond.wait(self.config.poll_timeout_s)
                taken = self._take_batch()
                if taken is None:
                    if self._closed:
                        return
                    continue
            self._score_batch(*taken)

    def _score_batch(self, key: tuple[str, int], reqs: list[_Request]) -> None:
        axis, k = key
        # one reference read: the whole batch — and every response in it
        # — is served by exactly this engine/version
        engine = self._engine
        rows = sum(r.rows for r in reqs)
        t0 = time.perf_counter()
        try:
            # the fill is inside the guard: a swap to a model with a
            # different feature width turns queued old-width requests
            # into per-request internal_error rejects, never a dead
            # worker thread
            xb = np.zeros((self.config.batch, engine.dim(axis)), np.float32)
            off = 0
            for r in reqs:
                xb[off:off + r.rows] = r.x
                off += r.rows
            out = jax.block_until_ready(engine.scorer(axis, k)(xb))
        except Exception as e:  # noqa: BLE001 — a worker must survive any batch
            detail = f"scorer failed for axis={axis} k={k}: {e!r}"
            for r in reqs:
                self._rejected.labels(reason="internal_error").inc()
                r.ticket._fulfill(ServeResult(
                    ok=False, labels=None, scores=None, version=None,
                    reason="internal_error", detail=detail))
            return
        dt_us = (time.perf_counter() - t0) * 1e6
        labels = np.asarray(out[0])
        scores = np.asarray(out[1])
        now = time.perf_counter()
        off = 0
        for r in reqs:
            sl = slice(off, off + r.rows)
            r.ticket._fulfill(ServeResult(
                ok=True, labels=labels[sl].copy(), scores=scores[sl].copy(),
                version=engine.version))
            self._req_lat.observe((now - r.t_submit) * 1e6)
            off += r.rows
        self._batches.inc()
        self._rows_served.inc(rows)
        self._batch_lat.observe(dt_us)
        self._batch_fill.observe(100.0 * rows / self.config.batch)

    # -- swap protocol ---------------------------------------------------
    @property
    def version(self) -> str:
        return self._engine.version

    @property
    def model(self) -> CoclusterModel:
        return self._engine.model

    def swap(self, model: CoclusterModel, version: str) -> str:
        """Warm-swap to ``model`` without dropping in-flight requests.

        Builds the successor engine, pre-compiles every (axis, k) scorer
        the current engine has warmed — at the service's fixed batch
        shape, so the first post-swap batch pays zero trace time — then
        publishes it with one atomic reference assignment. Returns the
        displaced version id.
        """
        old = self._engine
        new = _Engine(model, version, shard=self.config.shard,
                      mesh_axis=self.config.mesh_axis)
        warmed = old.warmed_keys() or (("rows", 1),)
        for axis, k in warmed:
            if k <= new.n_clusters(axis):
                new.warm(axis, k, self.config.batch)
        self._engine = new
        self._swaps.inc()
        _obs.event("serve_swap", old=old.version, new=version)
        return old.version

    def swap_async(self, loader: Callable[[], CoclusterModel],
                   version: str) -> Ticket:
        """Fit/load a successor in the background, then warm-swap to it.

        ``loader`` runs on a daemon thread (a registry ``load``, a
        streaming ``fit``, ...); traffic keeps flowing on the current
        engine the whole time. The returned :class:`Ticket` resolves
        with ``version`` (ok) once the swap is published, or with
        ``reason='internal_error'`` if the loader raised.
        """
        ticket = Ticket()

        def _run():
            try:
                model = loader()
                old = self.swap(model, version)
                ticket._fulfill(ServeResult(
                    ok=True, labels=None, scores=None, version=version,
                    detail=f"swapped from {old}"))
            except Exception as e:  # noqa: BLE001 — surface via the ticket
                ticket._fulfill(ServeResult(
                    ok=False, labels=None, scores=None, version=None,
                    reason="internal_error", detail=repr(e)))

        threading.Thread(target=_run, daemon=True,
                         name=f"assign-swap-{version}").start()
        return ticket

    # -- lifecycle -------------------------------------------------------
    def close(self, timeout: float | None = 30.0) -> None:
        """Stop admitting, drain the queue, join the workers.

        Every request admitted before ``close`` is still served (the
        zero-drop guarantee); submissions after it reject with
        ``shutdown``.
        """
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        for w in self._workers:
            w.join(timeout)

    def __enter__(self) -> "AssignService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def stats(self) -> dict:
        """Point-in-time snapshot of this service's metric values."""
        return {
            "version": self.version,
            "queued_rows": self._queued_rows,
            "submitted": self._submitted.value,
            "rows_served": self._rows_served.value,
            "batches": self._batches.value,
            "swaps": self._swaps.value,
            "rejected": {key: c.value
                         for key, c in self._rejected._series.items()},
            "p50_request_us": self._req_lat.percentile(50),
            "p99_request_us": self._req_lat.percentile(99),
            "mean_batch_fill_pct": (self._batch_fill.sum
                                    / max(self._batch_fill.count, 1)),
        }
