"""Out-of-core streaming co-clustering fit (DESIGN.md §10, §12).

``fit(chunks, cfg)`` consumes the data matrix as a stream of **row
chunks** (dense arrays or BCOO, each ``(r, N)``) and grows a
:class:`~repro.streaming.model.CoclusterModel` without ever holding the
``M x N`` matrix: peak resident data is one chunk plus model-sized state.

Per chunk ``t`` (all static-shape, DESIGN.md §2 — one jit trace per chunk
shape, keys counter-derived from ``(seed, t, block)``):

  1. **Atom phase.** The chunk is cut into ``col_blocks`` column blocks
     (``(r, psi)`` each) for each of ``chunk_resamples`` independent
     column permutations (re-derived from ``fold_in(seed, t, resample)``
     — the streaming analogue of the batch ``T_p``), and the atom
     co-clusterer (SCC) runs vmapped over the block stack — the same
     embarrassingly parallel unit as the batch pipeline, with the chunk
     playing the role of one row-band of a resample.
  2. **Signature fold.** Each block's atoms are reduced to anchor-column
     signatures (``merging.atom_signatures``) with member counts and raw
     anchor-feature sums, and those **atom summaries** — never the chunk
     — are folded into the growing model state: ``O(B * k * q)`` floats
     plus the ``(B, r)`` local labels per chunk. This is the hierarchy of
     the batch merge (block -> signature local reduce) applied stream-side.
  3. **Anchor-row reservoir.** A uniform reservoir sample (Algorithm R)
     of ``anchor_rows`` rows is maintained with its ``(q, N)`` data
     sliver; at finalize it is the anchor-row feature space in which
     columns are clustered and served.

``finalize()`` completes the hierarchical merge exactly as the batch
pipeline does: one best-of-restarts signature k-means over **all** chunk
atoms (``merging.cluster_atoms_best`` — the same global alignment the
batch merge runs over all resample atoms), per-row votes through each
chunk's aligned atoms, and column clustering + serving signatures in the
reservoir sliver space. Because the global alignment sees every atom —
not a first-chunk bootstrap — streaming consensus quality matches the
batch merge instead of depending on the first chunk's luck.

**Resumable chunk steps (DESIGN.md §12).** Every chunk fold is a keyed,
re-runnable unit: its randomness is counter-derived from ``(seed, t)``
(atom keys, column permutations, AND the reservoir draws — a fresh
``default_rng([seed + 13, t])`` per chunk, never a sequential host RNG),
and the whole accumulator is a serializable pytree (``state_tree`` /
``from_state_tree``) checkpointed via ``repro.checkpoint``. ``fit``
accepts ``ckpt_dir``/``save_every`` (periodic ``FitState`` checkpoints
driven through ``runtime.fault_tolerance.run_with_recovery``),
``failure_injector`` (a ``SimulatedFailure`` mid-fit restores the latest
state and refolds the lost chunks from a bounded replay buffer), and
``resume_from`` (a new process continues a killed fit). An interrupted
fit that resumes produces a **bit-identical** ``CoclusterModel`` to the
uninterrupted run at equal seeds — the recovery-equivalence invariant
``tests/test_fault_tolerance.py`` pins, including across a real SIGKILL
and an elastic restore onto a different device count.

Memory audit (the O(chunk + model) claim): resident at any time are one
chunk (``r x N``), the reservoir sliver (``anchor_rows x N``), and the
accumulated atom summaries + local labels, which are O(atoms * q + M *
B/r) — proportional to model/label state, never ``M x N``. With recovery
enabled, a replay buffer of the last ``save_every + 2`` chunks is also
resident (the chunks a restore may need to refold). ``FitStats`` reports
the measured peaks.
"""

from __future__ import annotations

import dataclasses
import functools
import logging
import time
from collections import OrderedDict
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as _ckpt
from repro import obs
from repro.core import merging as _merging
from repro.core import sparse as _sparse
from repro.core import spectral as _spectral
from repro.core.lamc import LAMCConfig
from repro.core.lamc import validate_assignment as _validate_assignment

from .model import CoclusterModel

__all__ = ["StreamConfig", "FitStats", "StreamingCocluster", "fit",
           "iter_row_chunks", "stream_config_from_lamc",
           "FIT_STATE_KIND", "save_fit_state", "load_fit_state"]

logger = logging.getLogger("repro.streaming.fit")

#: extra_meta["kind"] tag of a FitState checkpoint — distinguishes an
#: in-progress fit from a servable CoclusterModel artifact.
FIT_STATE_KIND = "stream_fit_state"
_FIT_STATE_VERSION = 1


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    n_row_clusters: int
    n_col_clusters: int
    # block k/d: clusters the atom method looks for inside one chunk block
    atom_row_clusters: int | None = None
    atom_col_clusters: int | None = None
    col_blocks: int = 4             # column blocks per chunk resample
    chunk_resamples: int = 1        # independent column permutations per chunk
    signature_dim: int = 64         # shared anchor columns q (row signatures)
    anchor_rows: int = 64           # row reservoir size (column features)
    seed: int = 0
    svd_iters: int = 4
    kmeans_iters: int = 16
    merge_kmeans_iters: int = 25
    merge_restarts: int = 4
    assign_impl: str = "jnp"        # "jnp" | "pallas" — atom k-means hot path
    qr_method: str = "qr"           # "qr" | "cholesky"
    # Sparse-route knob, mirrored from LAMCConfig so stream/batch configs
    # stay interchangeable (stream_config_from_lamc). For BCOO chunks it
    # decides how column blocks materialize: only a gather route
    # ("dual_ell" pinned, or "auto" below the probability.spmm_route
    # crossover) keeps the chunk sparse and scatters each resample's
    # blocks straight from the nonzeros, O(chunk nnz) per resample; any
    # other verdict densifies the chunk once (streaming has no tiled
    # backend — its trade is scatter vs densify). Either way the block
    # values are bit-identical — this is a memory/compute trade only.
    spmm_impl: str = "auto"
    # Assignment knobs mirrored from LAMCConfig (DESIGN.md §11), applied
    # at finalize(): "overlap" marks rows whose vote share clears no
    # cluster as outliers (label -1), exactly like the batch drivers.
    # The CoclusterModel keeps the full vote tables either way, so
    # membership *matrices* stay a load-time view
    # (``model_memberships``) with whatever knobs the caller passes.
    assignment: str = "hard"
    overlap_threshold: float = 0.25
    min_membership: int = 0

    @property
    def atom_k(self) -> int:
        return self.atom_row_clusters or self.n_row_clusters

    @property
    def atom_d(self) -> int:
        return self.atom_col_clusters or self.n_col_clusters

    @property
    def blocks_per_chunk(self) -> int:
        return self.col_blocks * self.chunk_resamples


def stream_config_from_lamc(cfg: LAMCConfig, **overrides) -> StreamConfig:
    """Carry the shared knobs of a batch LAMCConfig into a StreamConfig."""
    base = dict(
        n_row_clusters=cfg.n_row_clusters, n_col_clusters=cfg.n_col_clusters,
        atom_row_clusters=cfg.atom_row_clusters,
        atom_col_clusters=cfg.atom_col_clusters,
        signature_dim=cfg.signature_dim, seed=cfg.seed,
        svd_iters=cfg.svd_iters, kmeans_iters=cfg.kmeans_iters,
        merge_kmeans_iters=cfg.merge_kmeans_iters,
        merge_restarts=cfg.merge_restarts, assign_impl=cfg.assign_impl,
        qr_method=cfg.qr_method, spmm_impl=cfg.spmm_impl,
        assignment=cfg.assignment, overlap_threshold=cfg.overlap_threshold,
        min_membership=cfg.min_membership,
    )
    base.update(overrides)
    return StreamConfig(**base)


class FitStats(NamedTuple):
    rows_seen: int
    n_cols: int
    chunks: int
    fit_seconds: float
    rows_per_s: float
    peak_chunk_bytes: int   # largest single chunk held resident
    state_bytes: int        # model-sized accumulator footprint at finalize


@functools.partial(jax.jit, static_argnames=("cfg",))
def _chunk_atoms(cfg: StreamConfig, chunk_blocks: jax.Array,
                 feats: jax.Array, t: jax.Array):
    """Atom phase + signature reduce for one chunk (static per (r, psi)).

    ``chunk_blocks``: (blocks_per_chunk, r, psi) dense block stack;
    ``feats``: (r, q) anchor-column features. Returns per-block row
    labels, centered/unit atom signatures with member counts, and the
    *raw* per-atom anchor-feature sums (for the serving signatures —
    those are centered globally, not per block).
    """
    b = cfg.blocks_per_chunk
    keys = jax.vmap(
        lambda i: jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed + 1), t), i)
    )(jnp.arange(b))

    def atom(key, block):
        res = _spectral.scc(
            key, block, cfg.atom_k, cfg.atom_d,
            svd_iters=cfg.svd_iters, kmeans_iters=cfg.kmeans_iters,
            assign_impl=cfg.assign_impl, qr_method=cfg.qr_method)
        return res.row_labels

    row_labels = jax.vmap(atom)(keys, chunk_blocks)          # (B, r)
    r = feats.shape[0]
    block_feats = jnp.broadcast_to(feats[None], (b, r, feats.shape[1]))
    sigs, counts = _merging.atom_signatures(block_feats, row_labels, cfg.atom_k)
    onehot = jax.nn.one_hot(row_labels, cfg.atom_k, dtype=jnp.float32)
    raw_sums = jnp.einsum("brk,rq->bkq", onehot, feats.astype(jnp.float32))
    return row_labels, sigs, counts, raw_sums


def _nbytes(x) -> int:
    if _sparse.is_bcoo(x):
        return int(x.data.size * x.data.dtype.itemsize
                   + x.indices.size * x.indices.dtype.itemsize)
    return int(np.asarray(x).nbytes if isinstance(x, np.ndarray)
               else x.size * x.dtype.itemsize)


def _chunk_fingerprint(chunk) -> tuple[str, np.dtype]:
    """(format, value dtype) of one chunk — the trace-shaping properties a
    stream must hold constant (validated per chunk, DESIGN.md §12)."""
    if _sparse.is_bcoo(chunk):
        return "bcoo", np.dtype(chunk.data.dtype)
    return "dense", np.dtype(chunk.dtype)


class StreamingCocluster:
    """Stateful out-of-core fitter: ``partial_fit`` chunks, then ``finalize``.

    State is model-sized only: per-chunk atom summaries (signatures,
    counts, anchor-feature sums — ``O(B * k * q)`` each), per-chunk local
    labels (``(B, r)`` ints), and the ``(anchor_rows, N)`` reservoir
    sliver. The data chunks themselves are never retained. The whole
    accumulator serializes to a checkpointable pytree (``state_tree``)
    and rebuilds from one (``from_state_tree``) — every source of
    randomness is counter-derived from ``(cfg.seed, chunk index)``, so a
    rebuilt fitter continues bit-identically.
    """

    def __init__(self, cfg: StreamConfig):
        _sparse.validate_spmm_impl(cfg.spmm_impl)
        # StreamConfig mirrors every attribute the shared validator reads
        _validate_assignment(cfg)
        self.cfg = cfg
        self._n_cols: int | None = None
        self._anchor_cols: jax.Array | None = None
        self._atom_sigs: list[np.ndarray] = []       # per chunk (B*k, q)
        self._atom_cnts: list[np.ndarray] = []       # per chunk (B*k,)
        self._atom_sums: list[np.ndarray] = []       # per chunk (B*k, q) raw
        self._chunk_labels: list[np.ndarray] = []    # per chunk (B, r) int32
        self._anchor_sum: np.ndarray | None = None   # (q,)
        self._res_ids: np.ndarray | None = None      # (q_res,) global row ids
        self._res_vals: np.ndarray | None = None     # (q_res, N)
        self._res_fill = 0
        self._chunk_format: str | None = None        # "dense" | "bcoo"
        self._chunk_dtype: np.dtype | None = None
        self.rows_seen = 0
        self.chunks = 0
        self._t0 = time.perf_counter()
        self._peak_chunk_bytes = 0
        # (t, id(chunk)) -> (chunk ref, blocks, feats): recovery replays
        # refold the same chunk objects the cursor window retained, so the
        # densify/gather/permute prep of a refold is a pure repeat — serve
        # it from this bounded identity-keyed cache instead. Session-local
        # (never serialized): a restored fitter has no chunk objects.
        self._prep_cache: "OrderedDict[tuple, tuple]" = OrderedDict()

    # ------------------------------------------------------------------ setup

    def _init_state(self, n_cols: int) -> None:
        cfg = self.cfg
        self._n_cols = n_cols
        kroot = jax.random.key(cfg.seed + 7)
        _, kac, _ = jax.random.split(kroot, 3)
        self._anchor_cols = _merging.anchor_indices(kac, n_cols, cfg.signature_dim)
        q = int(self._anchor_cols.shape[0])
        self._anchor_sum = np.zeros((q,), np.float32)
        self._res_ids = np.zeros((cfg.anchor_rows,), np.int64)
        self._res_vals = np.zeros((cfg.anchor_rows, n_cols), np.float32)

    def _chunk_route(self, chunk) -> str:
        """Resolve cfg.spmm_impl for one BCOO chunk (host-side)."""
        from repro.core import probability as _prob

        if self.cfg.spmm_impl != "auto":
            return self.cfg.spmm_impl
        r, n = chunk.shape
        return _prob.spmm_route(chunk.nse / float(max(r * n, 1)),
                                float(r) * n)

    # --------------------------------------------------------------- validate

    def _validate_chunk(self, chunk, t: int) -> None:
        """Loud, chunk-indexed failure on a malformed mid-stream chunk.

        Catches the three drifts that otherwise surface as a deep jit
        shape/dtype error many frames below the ingest loop: wrong column
        count, value-dtype drift, and a dense<->BCOO format flip.
        """
        if _sparse.is_bcoo(chunk):
            _sparse.validate_bcoo(chunk)
        shape = tuple(chunk.shape)
        if len(shape) != 2:
            raise ValueError(
                f"chunk {t}: must be 2-D (rows, n_cols), got shape {shape}")
        fmt, dtype = _chunk_fingerprint(chunk)
        if self._n_cols is None:
            return  # first chunk defines the stream fingerprint
        if int(shape[1]) != self._n_cols:
            raise ValueError(
                f"chunk {t}: chunk has {shape[1]} columns, stream started "
                f"with {self._n_cols} — expected shape "
                f"(rows, {self._n_cols}), got {shape}")
        if self._chunk_format is not None and fmt != self._chunk_format:
            raise ValueError(
                f"chunk {t}: stream started with {self._chunk_format} "
                f"chunks, got {fmt} — a dense/BCOO flip mid-stream changes "
                "the compiled chunk program; convert upstream "
                "(data.synthetic.to_bcoo or .todense) instead")
        if self._chunk_dtype is not None and dtype != self._chunk_dtype:
            raise ValueError(
                f"chunk {t}: value dtype drifted — stream started with "
                f"{self._chunk_dtype}, got {dtype}; cast the chunk before "
                "partial_fit")

    def check_replayed_chunk(self, chunk, t: int) -> None:
        """Validate a chunk being skipped on resume against the recorded
        fold: its shape must match what checkpoint step ``t`` folded."""
        if t >= self.chunks:
            raise ValueError(
                f"chunk {t} replayed but only {self.chunks} chunks are in "
                "the restored state")
        want_rows = int(self._chunk_labels[t].shape[1])
        shape = tuple(chunk.shape)
        if shape != (want_rows, self._n_cols):
            raise ValueError(
                f"resumed stream does not match the checkpoint: chunk {t} "
                f"was folded with shape ({want_rows}, {self._n_cols}), the "
                f"replayed stream yields {shape} — resume requires the "
                "same chunking of the same stream")

    # -------------------------------------------------------------- reservoir

    def _reservoir_update(self, chunk, r: int, t: int) -> None:
        """Algorithm R over the arriving rows (uniform over the stream).

        Vectorized per chunk: one RNG call draws every row's slot
        candidate, so ingest pays no per-row Python loop. Duplicate slot
        hits within a chunk resolve to the *last* arriving row (numpy
        fancy assignment applies writes in index order), matching the
        sequential formulation. The generator is counter-derived from
        ``(seed, t)`` — chunk ``t``'s draws are a pure function of the
        chunk index, never of how many draws preceded them, so a fit
        resumed from a checkpoint replays the identical reservoir
        (DESIGN.md §12 RNG-provenance invariant).
        """
        cap = self.cfg.anchor_rows
        rng = np.random.default_rng([self.cfg.seed + 13, t])
        gids = self.rows_seen + np.arange(r, dtype=np.int64)
        n_fill = min(max(cap - self._res_fill, 0), r)
        fill_slots = np.arange(self._res_fill, self._res_fill + n_fill)
        j = rng.integers(0, gids[n_fill:] + 1)                  # (r - n_fill,)
        keep = j < cap
        rows = np.concatenate([np.arange(n_fill), n_fill + np.nonzero(keep)[0]])
        slots = np.concatenate([fill_slots, j[keep]])
        self._res_fill += n_fill
        if rows.size == 0:
            return
        self._res_ids[slots] = gids[rows]
        if _sparse.is_bcoo(chunk):
            vals = np.asarray(_sparse.gather_rows_dense(chunk, jnp.asarray(rows)))
        else:
            vals = np.asarray(chunk)[rows].astype(np.float32)
        self._res_vals[slots] = vals

    # ------------------------------------------------------------------- fold

    def _blocks_and_feats(self, chunk, t: int):
        """(blocks_per_chunk, r, psi) block stack + (r, q) anchor features.

        Each of the ``chunk_resamples`` local resamples cuts the chunk's
        columns with an independent permutation (counter-derived from
        ``(seed, t, resample)``) — the streaming analogue of the batch
        ``T_p``: more independent atoms per row, stronger consensus.

        Keyed by ``(t, chunk identity)`` in a small cache: a recovery
        replay refolds the *same* chunk object at the same step index, so
        its prep (densify/gather + permutation assembly) is served from
        the first fold — bit-identical by construction (same objects,
        same counter-derived permutations).
        """
        cfg = self.cfg
        chunk_obj = chunk               # identity anchor (chunk is rebound)
        ck = (t, id(chunk))
        hit = self._prep_cache.get(ck)
        if hit is not None and hit[0] is chunk:
            obs.get_registry().counter(
                "stream_chunk_prep",
                help="streaming chunk prep cache events",
            ).labels(event="hit").inc()
            return hit[1], hit[2]
        obs.get_registry().counter(
            "stream_chunk_prep",
            help="streaming chunk prep cache events",
        ).labels(event="miss").inc()
        n = self._n_cols
        psi = n // cfg.col_blocks
        key_t = jax.random.fold_in(jax.random.key(cfg.seed), t)
        perms = [
            jax.random.permutation(jax.random.fold_in(key_t, ri),
                                   n)[: cfg.col_blocks * psi]
            for ri in range(cfg.chunk_resamples)
        ]
        if _sparse.is_bcoo(chunk) and self._chunk_route(chunk) != "dual_ell":
            # Streaming has no tiled backend — the chunk trade is
            # scatter-vs-densify only, so any non-gather verdict (tiled
            # or dense; BENCH_sparse: gathers lose ~1.9x by d = 0.2)
            # densifies the chunk once instead of paying a per-resample
            # scatter. Same values bit-exact either way (each cell holds
            # one stored nonzero or zero).
            chunk = chunk.todense()
        if _sparse.is_bcoo(chunk):
            # one gather per resample: gather_cols_dense inverts the column
            # map, so the index set must be duplicate-free — true within one
            # permutation, not across the concatenation of several
            sub = jnp.concatenate(
                [_sparse.gather_cols_dense(chunk, p) for p in perms], axis=1)
            feats = _sparse.gather_cols_dense(chunk, self._anchor_cols)
        else:
            dense = jnp.asarray(chunk)
            sub = dense[:, jnp.concatenate(perms)]
            feats = dense[:, self._anchor_cols]
        r = sub.shape[0]
        blocks = jnp.transpose(
            sub.reshape(r, cfg.blocks_per_chunk, psi), (1, 0, 2))
        feats = feats.astype(jnp.float32)
        self._prep_cache[ck] = (chunk_obj, blocks, feats)
        # bound by the cursor's replay window: older steps can't refold
        while len(self._prep_cache) > 4:
            self._prep_cache.popitem(last=False)
        return blocks, feats

    def partial_fit(self, chunk, *, replayed: bool = False
                    ) -> "StreamingCocluster":
        """Fold one ``(r, N)`` row chunk (dense or BCOO) into the model.

        ``replayed=True`` marks the chunk span as a refold — the fit
        driver passes it when a recovery rolled the step counter back, so
        a trace distinguishes first-time folds from recovery replays.
        """
        t = self.chunks
        self._validate_chunk(chunk, t)
        shape = tuple(chunk.shape)
        if self._n_cols is None:
            self._init_state(int(shape[1]))
        if self._chunk_format is None:
            # first chunk — or a fitter rebuilt from a tree without stream
            # metadata (elastic restore): adopt this chunk's fingerprint
            self._chunk_format, self._chunk_dtype = _chunk_fingerprint(chunk)
        r = int(shape[0])
        if r == 0:
            return self  # not a step: no span either (one span per fold)
        self._peak_chunk_bytes = max(self._peak_chunk_bytes, _nbytes(chunk))

        with obs.span("chunk", t=t, rows=r, replayed=replayed):
            with obs.span("blocks"):
                blocks, feats = self._blocks_and_feats(chunk, t)
            with obs.span("atoms") as asp:
                row_labels, sigs, counts, raw_sums = asp.fence(_chunk_atoms(
                    self.cfg, blocks, feats, jnp.int32(t)))

            q = sigs.shape[-1]
            self._atom_sigs.append(np.asarray(sigs).reshape(-1, q))
            self._atom_cnts.append(np.asarray(counts).reshape(-1))
            self._atom_sums.append(np.asarray(raw_sums).reshape(-1, q))
            self._chunk_labels.append(np.asarray(row_labels))
            self._anchor_sum += np.asarray(feats, dtype=np.float32).sum(axis=0)

            with obs.span("reservoir"):
                self._reservoir_update(chunk, r, t)
        self.rows_seen += r
        self.chunks += 1
        return self

    # ------------------------------------------------------------- checkpoint

    def state_tree(self) -> dict:
        """The fit accumulator as a checkpointable pytree (host arrays).

        Everything ``from_state_tree`` needs to continue the fit
        bit-identically: atom summaries + local labels per chunk (keyed
        by zero-padded chunk index so flattened leaf names sort), the
        reservoir (ids, sliver, fill), the running anchor sum, and the
        integer counters packed into one ``scalars`` vector. RNG state is
        deliberately absent — all randomness is ``(seed, chunk)``
        counter-derived, so provenance is the counters themselves.
        """
        if self._n_cols is None:
            raise ValueError("no chunks folded yet — nothing to checkpoint")
        scalars = np.asarray(
            [self._n_cols, self.rows_seen, self.chunks, self._res_fill,
             self._peak_chunk_bytes], np.int64)
        return {
            "scalars": scalars,
            "anchor_cols": np.asarray(self._anchor_cols),
            "anchor_sum": np.asarray(self._anchor_sum),
            "res_ids": np.asarray(self._res_ids),
            "res_vals": np.asarray(self._res_vals),
            "atom_sigs": {f"{i:06d}": a for i, a in enumerate(self._atom_sigs)},
            "atom_cnts": {f"{i:06d}": a for i, a in enumerate(self._atom_cnts)},
            "atom_sums": {f"{i:06d}": a for i, a in enumerate(self._atom_sums)},
            "chunk_labels": {f"{i:06d}": a
                             for i, a in enumerate(self._chunk_labels)},
        }

    @classmethod
    def from_state_tree(cls, cfg: StreamConfig, tree: dict,
                        chunk_format: str | None = None,
                        chunk_dtype: str | None = None
                        ) -> "StreamingCocluster":
        """Rebuild a fitter from a ``state_tree`` pytree (leaves may be
        numpy or device arrays — an elastic restore hands sharded device
        arrays straight in; they are gathered to host here)."""
        self = cls(cfg)
        sc = np.asarray(tree["scalars"]).astype(np.int64)
        self._n_cols = int(sc[0])
        self.rows_seen = int(sc[1])
        self.chunks = int(sc[2])
        self._res_fill = int(sc[3])
        self._peak_chunk_bytes = int(sc[4])
        self._anchor_cols = jnp.asarray(np.asarray(tree["anchor_cols"]))
        # explicit copies: these are mutated in place by partial_fit, and
        # np.asarray of a device array yields a read-only view
        self._anchor_sum = np.array(tree["anchor_sum"], np.float32)
        self._res_ids = np.array(tree["res_ids"], np.int64)
        self._res_vals = np.array(tree["res_vals"], np.float32)
        for field, dst in (("atom_sigs", self._atom_sigs),
                           ("atom_cnts", self._atom_cnts),
                           ("atom_sums", self._atom_sums),
                           ("chunk_labels", self._chunk_labels)):
            node = tree.get(field, {})
            for key in sorted(node):
                dst.append(np.asarray(node[key]))
            if len(dst) != self.chunks:
                raise ValueError(
                    f"fit state is inconsistent: {self.chunks} chunks "
                    f"recorded but {field} holds {len(dst)} entries — "
                    "partial or foreign checkpoint")
        if chunk_format is not None:
            self._chunk_format = chunk_format
        if chunk_dtype is not None:
            self._chunk_dtype = np.dtype(chunk_dtype)
        return self

    # --------------------------------------------------------------- finalize

    def finalize(self) -> tuple[CoclusterModel, FitStats]:
        if self.rows_seen == 0:
            raise ValueError("no chunks were fit; stream was empty")
        cfg = self.cfg
        k_row, k_col = cfg.n_row_clusters, cfg.n_col_clusters
        n = self._n_cols
        k = cfg.atom_k
        b = cfg.blocks_per_chunk

        with obs.span("finalize", chunks=self.chunks,
                      rows=self.rows_seen) as fin:
            # global atom alignment: the batch merge's signature k-means over
            # ALL chunk atoms (count-weighted, best-of-restarts) — the top of
            # the streaming hierarchy (block -> signature -> global clusters)
            with obs.span("align", atoms=sum(len(c) for c in self._atom_cnts)):
                flat_sigs = jnp.asarray(np.concatenate(self._atom_sigs, axis=0))
                flat_cnt = jnp.asarray(np.concatenate(self._atom_cnts, axis=0))
                kmerge = jax.random.fold_in(jax.random.key(cfg.seed + 7), 2)
                atom_global = np.asarray(_merging.cluster_atoms_best(
                    kmerge, flat_sigs, flat_cnt, k_row,
                    cfg.merge_kmeans_iters, n_restarts=cfg.merge_restarts))

            with obs.span("votes") as vsp:
                # per-row votes through each chunk's aligned atoms (numpy:
                # chunk sizes vary, keep this off the jit cache)
                vote_rows = []
                for t, labels in enumerate(self._chunk_labels):
                    ag = atom_global[t * b * k:(t + 1) * b * k].reshape(b, k)
                    point_global = np.take_along_axis(ag, labels, axis=1)  # (B, r)
                    r = labels.shape[1]
                    votes = np.zeros((r, k_row), np.float32)
                    np.add.at(votes,
                              (np.arange(r)[None, :].repeat(b, 0), point_global),
                              1.0)
                    vote_rows.append(votes)
                row_votes = jnp.asarray(np.concatenate(vote_rows, axis=0))
                # assignment semantics shared with the batch drivers (§11):
                # overlap mode marks rows whose vote share clears no cluster as
                # outliers (-1); the vote tables ride in the model either way
                row_labels, _ = _merging.finalize_assignment(
                    row_votes, cfg.assignment, cfg.overlap_threshold,
                    cfg.min_membership)

                # row serving signatures: atom anchor-feature sums grouped by
                # the atoms' global cluster, centered by the global anchor mean
                row_mean = jnp.asarray(self._anchor_sum / self.rows_seen)
                sums = np.concatenate(self._atom_sums, axis=0)      # (A, q)
                cnts = np.concatenate(self._atom_cnts, axis=0)      # (A,)
                sig_sum = np.zeros((k_row, sums.shape[1]), np.float32)
                sig_cnt = np.zeros((k_row,), np.float32)
                np.add.at(sig_sum, atom_global, sums)
                np.add.at(sig_cnt, atom_global, cnts)
                sig = (jnp.asarray(sig_sum) / jnp.maximum(
                    jnp.asarray(sig_cnt)[:, None], 1.0)) - row_mean[None, :]
                row_sigs = sig / jnp.maximum(
                    jnp.linalg.norm(sig, axis=1, keepdims=True), 1e-12)
                vsp.fence((row_labels, row_sigs))

            with obs.span("columns") as csp:
                # columns: clustered in the reservoir-sliver feature space
                # (the anchor-row features serving uses), centered +
                # unit-normalized so profile *direction* decides, then the
                # same best-of-restarts k-means as the row alignment
                fill = max(self._res_fill, 1)
                sliver = jnp.asarray(self._res_vals[:fill])         # (q_res, N)
                feats_c = sliver.T                                  # (N, q_res)
                feats_c = feats_c - jnp.mean(feats_c, axis=0, keepdims=True)
                feats_c = feats_c / jnp.maximum(
                    jnp.linalg.norm(feats_c, axis=1, keepdims=True), 1e-12)
                kcols = jax.random.fold_in(jax.random.key(cfg.seed + 7), 3)
                col_labels = _merging.cluster_atoms_best(
                    kcols, feats_c, jnp.ones((n,), jnp.float32), k_col,
                    cfg.merge_kmeans_iters, n_restarts=cfg.merge_restarts)
                col_votes = jax.nn.one_hot(col_labels, k_col, dtype=jnp.float32)
                col_sigs, col_mean, _ = _merging.cluster_signatures(
                    sliver.T, col_labels, k_col)
                anchor_rows = jnp.asarray(self._res_ids[:fill].astype(np.int32))

                model = csp.fence(CoclusterModel(
                    row_labels=row_labels,
                    col_labels=col_labels.astype(jnp.int32),
                    row_votes=row_votes, col_votes=col_votes,
                    row_sigs=row_sigs, col_sigs=col_sigs,
                    row_mean=row_mean.astype(jnp.float32),
                    col_mean=col_mean.astype(jnp.float32),
                    anchor_rows=anchor_rows,
                    anchor_cols=self._anchor_cols.astype(jnp.int32),
                ))
            fin.fence(model)
        dt = time.perf_counter() - self._t0
        state_bytes = int(
            sum(v.nbytes for vs in (self._atom_sigs, self._atom_cnts,
                                    self._atom_sums, self._chunk_labels)
                for v in vs)
            + self._res_vals.nbytes + self._anchor_sum.nbytes)
        stats = FitStats(
            rows_seen=self.rows_seen, n_cols=n, chunks=self.chunks,
            fit_seconds=dt, rows_per_s=self.rows_seen / max(dt, 1e-9),
            peak_chunk_bytes=self._peak_chunk_bytes, state_bytes=state_bytes)
        return model, stats


# ---------------------------------------------------------------------------
# FitState checkpoint round-trip
# ---------------------------------------------------------------------------


def save_fit_state(ckpt_dir: str, fitter: StreamingCocluster) -> str:
    """Checkpoint an in-progress fit (atomic, hash-manifested commit).

    The checkpoint step is the number of chunks folded, so
    ``checkpoint.latest_step`` IS the resume point.
    """
    meta = {
        "kind": FIT_STATE_KIND,
        "version": _FIT_STATE_VERSION,
        "stream_config": dataclasses.asdict(fitter.cfg),
        "chunks": fitter.chunks,
        "rows_seen": fitter.rows_seen,
        "chunk_format": fitter._chunk_format,
        "chunk_dtype": (str(fitter._chunk_dtype)
                        if fitter._chunk_dtype is not None else None),
    }
    return _ckpt.save(ckpt_dir, fitter.chunks, fitter.state_tree(),
                      extra_meta=meta)


def load_fit_state(ckpt_dir: str, cfg: StreamConfig, step: int | None = None
                   ) -> tuple[StreamingCocluster, int]:
    """Restore ``(fitter, chunks_folded)`` from a FitState checkpoint.

    Loud failure modes: no committed checkpoint (``FileNotFoundError``),
    foreign/stale checkpoint kind, and a config that differs from the
    one the state was fit with — recovery equivalence (DESIGN.md §12)
    only holds when the resumed fit runs the *same* program, so every
    differing field is named instead of silently continuing. Corrupt or
    truncated payloads surface as ``checkpoint.CheckpointCorruptError``
    naming the bad leaf.
    """
    if step is None:
        step = _ckpt.latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(
            f"no committed fit state under {ckpt_dir!r} — nothing to resume "
            "from (the fit died before its first checkpoint, or the path is "
            "wrong); rerun without resume_from")
    tree, meta = _ckpt.restore_tree(ckpt_dir, step)
    meta = meta or {}
    if meta.get("kind") != FIT_STATE_KIND:
        raise ValueError(
            f"checkpoint at {ckpt_dir!r} step {step} is "
            f"kind={meta.get('kind')!r}, expected {FIT_STATE_KIND!r} — not "
            "an in-progress streaming fit (a finished CoclusterModel "
            "artifact loads via streaming.load_model instead)")
    saved_cfg = meta.get("stream_config") or {}
    want_cfg = dataclasses.asdict(cfg)
    diffs = sorted(k for k in want_cfg
                   if saved_cfg.get(k) != want_cfg[k])
    if diffs:
        detail = ", ".join(
            f"{k}: checkpoint={saved_cfg.get(k)!r} vs resume={want_cfg[k]!r}"
            for k in diffs)
        raise ValueError(
            "resume config mismatch — recovery equivalence requires the "
            f"identical StreamConfig; differing fields: {detail}")
    fitter = StreamingCocluster.from_state_tree(
        cfg, tree, chunk_format=meta.get("chunk_format"),
        chunk_dtype=meta.get("chunk_dtype"))
    if fitter.chunks != int(meta.get("chunks", fitter.chunks)):
        raise ValueError(
            f"fit state at step {step} records {meta.get('chunks')} chunks "
            f"in its meta but {fitter.chunks} in its tree — corrupt or "
            "hand-edited checkpoint")
    return fitter, fitter.chunks


# ---------------------------------------------------------------------------
# fit driver: plain loop, or resumable chunk steps through run_with_recovery
# ---------------------------------------------------------------------------


class _ChunkCursor:
    """Stream cursor with a bounded replay buffer.

    ``get(t)`` returns chunk ``t``: from the buffer when a recovery
    rolled the step counter back, else by advancing the underlying
    iterator (strictly sequential). The buffer keeps the last
    ``save_every + 2`` chunks — exactly the window a restore from the
    latest checkpoint can need to refold — so recovery never requires a
    rewindable stream. Raises ``StopIteration`` on exhaustion (the
    stream-driven termination signal of ``run_with_recovery``).
    """

    def __init__(self, it, start: int, keep: int):
        self._it = it
        self._next = start
        self._keep = max(keep, 1)
        self._buf: dict = {}

    def get(self, t: int):
        if t in self._buf:
            return self._buf[t]
        if t != self._next:
            raise RuntimeError(
                f"chunk {t} requested but the replay buffer holds "
                f"{sorted(self._buf)} and the stream cursor is at "
                f"{self._next} — the restore point fell behind the "
                f"{self._keep}-chunk buffer (save_every too large for the "
                "failure pattern?)")
        chunk = next(self._it)          # StopIteration = stream exhausted
        while _skip_empty(chunk):
            chunk = next(self._it)      # empty chunks are not steps
        self._buf[t] = chunk
        if len(self._buf) > self._keep:
            del self._buf[min(self._buf)]
        self._next = t + 1
        return chunk


def _skip_empty(chunk) -> bool:
    return int(chunk.shape[0]) == 0 if len(chunk.shape) == 2 else False


def fit(chunks: Iterable, cfg: StreamConfig, *,
        ckpt_dir: str | None = None, save_every: int = 0,
        resume_from: str | None = None,
        failure_injector=None, max_retries: int = 8
        ) -> tuple[CoclusterModel, FitStats]:
    """Out-of-core fit over an iterable of row chunks (dense or BCOO).

    Rows are assigned global ids by arrival order. Returns
    ``(model, stats)``; peak resident data is one chunk + the model-sized
    accumulators (``stats`` reports both).

    Crash-consistent, resumable operation (DESIGN.md §12):

    ``ckpt_dir`` + ``save_every``
        checkpoint the ``FitState`` every ``save_every`` chunks (and at
        stream end) via ``repro.checkpoint`` — atomic, fsync'd,
        hash-manifested commits. The chunk loop runs through
        ``runtime.fault_tolerance.run_with_recovery``.
    ``resume_from``
        restore the latest committed ``FitState`` from this directory
        before consuming the stream; the already-folded chunks are drawn
        off the iterable and shape-checked against the recorded folds.
        Raises ``FileNotFoundError`` when nothing is committed there.
    ``failure_injector``
        a ``runtime.fault_tolerance.FailureInjector`` whose
        ``maybe_fail(t)`` runs after each chunk fold — a
        ``SimulatedFailure`` exercises the real restore path (state is
        rebuilt from the latest checkpoint and the lost chunks refold
        from a bounded replay buffer). Requires ``ckpt_dir``.

    Equivalence guarantee: with equal seeds and the same stream, an
    interrupted-and-resumed fit returns a bit-identical
    ``CoclusterModel`` to an uninterrupted one — every chunk step's
    randomness is ``(seed, t)`` counter-derived and the accumulator
    round-trips exactly.
    """
    if save_every < 0:
        raise ValueError(f"save_every must be >= 0, got {save_every}")
    if (ckpt_dir is None) != (save_every == 0):
        raise ValueError(
            "checkpointing needs both knobs: pass ckpt_dir AND save_every "
            f">= 1 together (got ckpt_dir={ckpt_dir!r}, "
            f"save_every={save_every})")
    recovery = ckpt_dir is not None
    if failure_injector is not None and not recovery:
        raise ValueError(
            "failure_injector without ckpt_dir/save_every cannot recover — "
            "there is no checkpoint to restore from")

    if resume_from is not None:
        fitter, start = load_fit_state(resume_from, cfg)
        logger.info("resuming fit from %s at chunk %d (%d rows folded)",
                    resume_from, start, fitter.rows_seen)
    else:
        fitter, start = StreamingCocluster(cfg), 0

    with obs.span("stream_fit", resumed=resume_from is not None,
                  resume_step=start, recovery=recovery) as root:
        it = iter(chunks)

        # draw the already-folded chunks off the stream, checking each
        # against the recorded fold — a different stream/chunking cannot
        # silently masquerade as a resume. Each skipped fold gets a trivial
        # span so the trace still shows one chunk span per non-empty chunk,
        # marked as a replay that was not re-folded.
        skipped = 0
        while skipped < start:
            try:
                chunk = next(it)
            except StopIteration:
                raise ValueError(
                    f"resume_from state has {start} chunks folded but the "
                    f"stream ended after {skipped} — resuming needs the same "
                    "stream, re-chunked identically") from None
            if _skip_empty(chunk):
                continue
            with obs.span("chunk", t=skipped, rows=int(chunk.shape[0]),
                          replayed=True, skipped=True):
                fitter.check_replayed_chunk(chunk, skipped)
            skipped += 1

        if not recovery:
            for chunk in it:
                fitter.partial_fit(chunk)
            out = fitter.finalize()
            root.set(chunks=out[1].chunks, rows_seen=out[1].rows_seen)
            return out

        cursor = _ChunkCursor(it, start=start, keep=save_every + 2)
        hi = {"max": start}  # high-water chunk step: steps below it are refolds

        def step_fn(t: int, f: StreamingCocluster) -> StreamingCocluster:
            # the cursor never buffers empty chunks, so every step folds rows
            f.partial_fit(cursor.get(t), replayed=t < hi["max"])
            hi["max"] = max(hi["max"], t + 1)
            if failure_injector is not None:
                # post-fold: the in-memory state is dirty, so recovery must
                # genuinely rebuild from the checkpoint, not shrug and retry
                failure_injector.maybe_fail(t)
            return f

        def restore_state(step: int) -> StreamingCocluster:
            if step < 0:
                # no checkpoint committed yet: from scratch (or the
                # resume point)
                if resume_from is not None:
                    f, _ = load_fit_state(resume_from, cfg)
                    return f
                return StreamingCocluster(cfg)
            f, _ = load_fit_state(ckpt_dir, cfg, step=step)
            return f

        from repro.runtime import fault_tolerance as _ft

        fitter, loop_stats = _ft.run_with_recovery(
            total_steps=None, step_fn=step_fn, state=fitter,
            ckpt_dir=ckpt_dir, save_every=save_every,
            restore_state=restore_state, max_retries=max_retries,
            start_step=start,
            save_fn=lambda _step, f: save_fit_state(ckpt_dir, f))
        if loop_stats["failures"]:
            logger.info("fit recovered from %d injected failure(s); final "
                        "chunk step %d", loop_stats["failures"],
                        loop_stats["final_step"])
        out = fitter.finalize()
        root.set(chunks=out[1].chunks, rows_seen=out[1].rows_seen,
                 failures=loop_stats["failures"])
        return out


def iter_row_chunks(matrix: np.ndarray, chunk_rows: int,
                    format: str = "dense"):
    """Yield ``(chunk_rows, N)`` row chunks of an in-memory matrix.

    Test/benchmark helper: real out-of-core callers stream chunks from
    disk or the wire. ``format='bcoo'`` converts each chunk (only the
    chunk — O(chunk nnz)) via ``data.synthetic.to_bcoo``. The yielded
    chunking is deterministic, so the same call replays the same stream
    — what ``fit(resume_from=...)`` needs to continue a killed fit.
    """
    if format not in ("dense", "bcoo"):
        raise ValueError(f"format must be 'dense' or 'bcoo', got {format!r}")
    m = matrix.shape[0]
    for start in range(0, m, chunk_rows):
        chunk = np.asarray(matrix[start: start + chunk_rows])
        if format == "bcoo":
            from repro.data.synthetic import to_bcoo

            yield to_bcoo(chunk)
        else:
            yield jnp.asarray(chunk)
