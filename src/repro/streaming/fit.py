"""Out-of-core streaming co-clustering fit (DESIGN.md §10).

``fit(chunks, cfg)`` consumes the data matrix as a stream of **row
chunks** (dense arrays or BCOO, each ``(r, N)``) and grows a
:class:`~repro.streaming.model.CoclusterModel` without ever holding the
``M x N`` matrix: peak resident data is one chunk plus model-sized state.

Per chunk ``t`` (all static-shape, DESIGN.md §2 — one jit trace per chunk
shape, keys counter-derived from ``(seed, t, block)``):

  1. **Atom phase.** The chunk is cut into ``col_blocks`` column blocks
     (``(r, psi)`` each) for each of ``chunk_resamples`` independent
     column permutations (re-derived from ``fold_in(seed, t, resample)``
     — the streaming analogue of the batch ``T_p``), and the atom
     co-clusterer (SCC) runs vmapped over the block stack — the same
     embarrassingly parallel unit as the batch pipeline, with the chunk
     playing the role of one row-band of a resample.
  2. **Signature fold.** Each block's atoms are reduced to anchor-column
     signatures (``merging.atom_signatures``) with member counts and raw
     anchor-feature sums, and those **atom summaries** — never the chunk
     — are folded into the growing model state: ``O(B * k * q)`` floats
     plus the ``(B, r)`` local labels per chunk. This is the hierarchy of
     the batch merge (block -> signature local reduce) applied stream-side.
  3. **Anchor-row reservoir.** A uniform reservoir sample (Algorithm R)
     of ``anchor_rows`` rows is maintained with its ``(q, N)`` data
     sliver; at finalize it is the anchor-row feature space in which
     columns are clustered and served.

``finalize()`` completes the hierarchical merge exactly as the batch
pipeline does: one best-of-restarts signature k-means over **all** chunk
atoms (``merging.cluster_atoms_best`` — the same global alignment the
batch merge runs over all resample atoms), per-row votes through each
chunk's aligned atoms, and column clustering + serving signatures in the
reservoir sliver space. Because the global alignment sees every atom —
not a first-chunk bootstrap — streaming consensus quality matches the
batch merge instead of depending on the first chunk's luck.

Memory audit (the O(chunk + model) claim): resident at any time are one
chunk (``r x N``), the reservoir sliver (``anchor_rows x N``), and the
accumulated atom summaries + local labels, which are O(atoms * q + M *
B/r) — proportional to model/label state, never ``M x N``. ``FitStats``
reports the measured peaks.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import merging as _merging
from repro.core import sparse as _sparse
from repro.core import spectral as _spectral
from repro.core.lamc import LAMCConfig
from repro.core.lamc import validate_assignment as _validate_assignment

from .model import CoclusterModel

__all__ = ["StreamConfig", "FitStats", "StreamingCocluster", "fit",
           "iter_row_chunks", "stream_config_from_lamc"]


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    n_row_clusters: int
    n_col_clusters: int
    # block k/d: clusters the atom method looks for inside one chunk block
    atom_row_clusters: int | None = None
    atom_col_clusters: int | None = None
    col_blocks: int = 4             # column blocks per chunk resample
    chunk_resamples: int = 1        # independent column permutations per chunk
    signature_dim: int = 64         # shared anchor columns q (row signatures)
    anchor_rows: int = 64           # row reservoir size (column features)
    seed: int = 0
    svd_iters: int = 4
    kmeans_iters: int = 16
    merge_kmeans_iters: int = 25
    merge_restarts: int = 4
    assign_impl: str = "jnp"        # "jnp" | "pallas" — atom k-means hot path
    qr_method: str = "qr"           # "qr" | "cholesky"
    # Sparse-route knob, mirrored from LAMCConfig so stream/batch configs
    # stay interchangeable (stream_config_from_lamc). For BCOO chunks it
    # decides how column blocks materialize: only a gather route
    # ("dual_ell" pinned, or "auto" below the probability.spmm_route
    # crossover) keeps the chunk sparse and scatters each resample's
    # blocks straight from the nonzeros, O(chunk nnz) per resample; any
    # other verdict densifies the chunk once (streaming has no tiled
    # backend — its trade is scatter vs densify). Either way the block
    # values are bit-identical — this is a memory/compute trade only.
    spmm_impl: str = "auto"
    # Assignment knobs mirrored from LAMCConfig (DESIGN.md §11), applied
    # at finalize(): "overlap" marks rows whose vote share clears no
    # cluster as outliers (label -1), exactly like the batch drivers.
    # The CoclusterModel keeps the full vote tables either way, so
    # membership *matrices* stay a load-time view
    # (``model_memberships``) with whatever knobs the caller passes.
    assignment: str = "hard"
    overlap_threshold: float = 0.25
    min_membership: int = 0

    @property
    def atom_k(self) -> int:
        return self.atom_row_clusters or self.n_row_clusters

    @property
    def atom_d(self) -> int:
        return self.atom_col_clusters or self.n_col_clusters

    @property
    def blocks_per_chunk(self) -> int:
        return self.col_blocks * self.chunk_resamples


def stream_config_from_lamc(cfg: LAMCConfig, **overrides) -> StreamConfig:
    """Carry the shared knobs of a batch LAMCConfig into a StreamConfig."""
    base = dict(
        n_row_clusters=cfg.n_row_clusters, n_col_clusters=cfg.n_col_clusters,
        atom_row_clusters=cfg.atom_row_clusters,
        atom_col_clusters=cfg.atom_col_clusters,
        signature_dim=cfg.signature_dim, seed=cfg.seed,
        svd_iters=cfg.svd_iters, kmeans_iters=cfg.kmeans_iters,
        merge_kmeans_iters=cfg.merge_kmeans_iters,
        merge_restarts=cfg.merge_restarts, assign_impl=cfg.assign_impl,
        qr_method=cfg.qr_method, spmm_impl=cfg.spmm_impl,
        assignment=cfg.assignment, overlap_threshold=cfg.overlap_threshold,
        min_membership=cfg.min_membership,
    )
    base.update(overrides)
    return StreamConfig(**base)


class FitStats(NamedTuple):
    rows_seen: int
    n_cols: int
    chunks: int
    fit_seconds: float
    rows_per_s: float
    peak_chunk_bytes: int   # largest single chunk held resident
    state_bytes: int        # model-sized accumulator footprint at finalize


@functools.partial(jax.jit, static_argnames=("cfg",))
def _chunk_atoms(cfg: StreamConfig, chunk_blocks: jax.Array,
                 feats: jax.Array, t: jax.Array):
    """Atom phase + signature reduce for one chunk (static per (r, psi)).

    ``chunk_blocks``: (blocks_per_chunk, r, psi) dense block stack;
    ``feats``: (r, q) anchor-column features. Returns per-block row
    labels, centered/unit atom signatures with member counts, and the
    *raw* per-atom anchor-feature sums (for the serving signatures —
    those are centered globally, not per block).
    """
    b = cfg.blocks_per_chunk
    keys = jax.vmap(
        lambda i: jax.random.fold_in(
            jax.random.fold_in(jax.random.key(cfg.seed + 1), t), i)
    )(jnp.arange(b))

    def atom(key, block):
        res = _spectral.scc(
            key, block, cfg.atom_k, cfg.atom_d,
            svd_iters=cfg.svd_iters, kmeans_iters=cfg.kmeans_iters,
            assign_impl=cfg.assign_impl, qr_method=cfg.qr_method)
        return res.row_labels

    row_labels = jax.vmap(atom)(keys, chunk_blocks)          # (B, r)
    r = feats.shape[0]
    block_feats = jnp.broadcast_to(feats[None], (b, r, feats.shape[1]))
    sigs, counts = _merging.atom_signatures(block_feats, row_labels, cfg.atom_k)
    onehot = jax.nn.one_hot(row_labels, cfg.atom_k, dtype=jnp.float32)
    raw_sums = jnp.einsum("brk,rq->bkq", onehot, feats.astype(jnp.float32))
    return row_labels, sigs, counts, raw_sums


def _nbytes(x) -> int:
    if _sparse.is_bcoo(x):
        return int(x.data.size * x.data.dtype.itemsize
                   + x.indices.size * x.indices.dtype.itemsize)
    return int(np.asarray(x).nbytes if isinstance(x, np.ndarray)
               else x.size * x.dtype.itemsize)


class StreamingCocluster:
    """Stateful out-of-core fitter: ``partial_fit`` chunks, then ``finalize``.

    State is model-sized only: per-chunk atom summaries (signatures,
    counts, anchor-feature sums — ``O(B * k * q)`` each), per-chunk local
    labels (``(B, r)`` ints), and the ``(anchor_rows, N)`` reservoir
    sliver. The data chunks themselves are never retained.
    """

    def __init__(self, cfg: StreamConfig):
        _sparse.validate_spmm_impl(cfg.spmm_impl)
        # StreamConfig mirrors every attribute the shared validator reads
        _validate_assignment(cfg)
        self.cfg = cfg
        self._n_cols: int | None = None
        self._anchor_cols: jax.Array | None = None
        self._atom_sigs: list[np.ndarray] = []       # per chunk (B*k, q)
        self._atom_cnts: list[np.ndarray] = []       # per chunk (B*k,)
        self._atom_sums: list[np.ndarray] = []       # per chunk (B*k, q) raw
        self._chunk_labels: list[np.ndarray] = []    # per chunk (B, r) int32
        self._anchor_sum: np.ndarray | None = None   # (q,)
        self._res_rng: np.random.Generator = np.random.default_rng(cfg.seed + 13)
        self._res_ids: np.ndarray | None = None      # (q_res,) global row ids
        self._res_vals: np.ndarray | None = None     # (q_res, N)
        self._res_fill = 0
        self.rows_seen = 0
        self.chunks = 0
        self._t0 = time.perf_counter()
        self._peak_chunk_bytes = 0

    # ------------------------------------------------------------------ setup

    def _init_state(self, n_cols: int) -> None:
        cfg = self.cfg
        self._n_cols = n_cols
        kroot = jax.random.key(cfg.seed + 7)
        _, kac, _ = jax.random.split(kroot, 3)
        self._anchor_cols = _merging.anchor_indices(kac, n_cols, cfg.signature_dim)
        q = int(self._anchor_cols.shape[0])
        self._anchor_sum = np.zeros((q,), np.float32)
        self._res_ids = np.zeros((cfg.anchor_rows,), np.int64)
        self._res_vals = np.zeros((cfg.anchor_rows, n_cols), np.float32)

    def _chunk_route(self, chunk) -> str:
        """Resolve cfg.spmm_impl for one BCOO chunk (host-side)."""
        from repro.core import probability as _prob

        if self.cfg.spmm_impl != "auto":
            return self.cfg.spmm_impl
        r, n = chunk.shape
        return _prob.spmm_route(chunk.nse / float(max(r * n, 1)),
                                float(r) * n)

    # -------------------------------------------------------------- reservoir

    def _reservoir_update(self, chunk, r: int) -> None:
        """Algorithm R over the arriving rows (uniform over the stream).

        Vectorized per chunk: one RNG call draws every row's slot
        candidate, so ingest pays no per-row Python loop. Duplicate slot
        hits within a chunk resolve to the *last* arriving row (numpy
        fancy assignment applies writes in index order), matching the
        sequential formulation.
        """
        cap = self.cfg.anchor_rows
        gids = self.rows_seen + np.arange(r, dtype=np.int64)
        n_fill = min(max(cap - self._res_fill, 0), r)
        fill_slots = np.arange(self._res_fill, self._res_fill + n_fill)
        j = self._res_rng.integers(0, gids[n_fill:] + 1)        # (r - n_fill,)
        keep = j < cap
        rows = np.concatenate([np.arange(n_fill), n_fill + np.nonzero(keep)[0]])
        slots = np.concatenate([fill_slots, j[keep]])
        self._res_fill += n_fill
        if rows.size == 0:
            return
        self._res_ids[slots] = gids[rows]
        if _sparse.is_bcoo(chunk):
            vals = np.asarray(_sparse.gather_rows_dense(chunk, jnp.asarray(rows)))
        else:
            vals = np.asarray(chunk)[rows].astype(np.float32)
        self._res_vals[slots] = vals

    # ------------------------------------------------------------------- fold

    def _blocks_and_feats(self, chunk, t: int):
        """(blocks_per_chunk, r, psi) block stack + (r, q) anchor features.

        Each of the ``chunk_resamples`` local resamples cuts the chunk's
        columns with an independent permutation (counter-derived from
        ``(seed, t, resample)``) — the streaming analogue of the batch
        ``T_p``: more independent atoms per row, stronger consensus.
        """
        cfg = self.cfg
        n = self._n_cols
        psi = n // cfg.col_blocks
        key_t = jax.random.fold_in(jax.random.key(cfg.seed), t)
        perms = [
            jax.random.permutation(jax.random.fold_in(key_t, ri),
                                   n)[: cfg.col_blocks * psi]
            for ri in range(cfg.chunk_resamples)
        ]
        if _sparse.is_bcoo(chunk) and self._chunk_route(chunk) != "dual_ell":
            # Streaming has no tiled backend — the chunk trade is
            # scatter-vs-densify only, so any non-gather verdict (tiled
            # or dense; BENCH_sparse: gathers lose ~1.9x by d = 0.2)
            # densifies the chunk once instead of paying a per-resample
            # scatter. Same values bit-exact either way (each cell holds
            # one stored nonzero or zero).
            chunk = chunk.todense()
        if _sparse.is_bcoo(chunk):
            # one gather per resample: gather_cols_dense inverts the column
            # map, so the index set must be duplicate-free — true within one
            # permutation, not across the concatenation of several
            sub = jnp.concatenate(
                [_sparse.gather_cols_dense(chunk, p) for p in perms], axis=1)
            feats = _sparse.gather_cols_dense(chunk, self._anchor_cols)
        else:
            dense = jnp.asarray(chunk)
            sub = dense[:, jnp.concatenate(perms)]
            feats = dense[:, self._anchor_cols]
        r = sub.shape[0]
        blocks = jnp.transpose(
            sub.reshape(r, cfg.blocks_per_chunk, psi), (1, 0, 2))
        return blocks, feats.astype(jnp.float32)

    def partial_fit(self, chunk) -> "StreamingCocluster":
        """Fold one ``(r, N)`` row chunk (dense or BCOO) into the model."""
        if _sparse.is_bcoo(chunk):
            _sparse.validate_bcoo(chunk)
        shape = chunk.shape
        if len(shape) != 2:
            raise ValueError(f"chunk must be 2-D (rows, n_cols), got {shape}")
        if self._n_cols is None:
            self._init_state(int(shape[1]))
        elif int(shape[1]) != self._n_cols:
            raise ValueError(
                f"chunk has {shape[1]} columns, stream started with "
                f"{self._n_cols}")
        r = int(shape[0])
        if r == 0:
            return self
        t = self.chunks
        self._peak_chunk_bytes = max(self._peak_chunk_bytes, _nbytes(chunk))

        blocks, feats = self._blocks_and_feats(chunk, t)
        row_labels, sigs, counts, raw_sums = _chunk_atoms(
            self.cfg, blocks, feats, jnp.int32(t))

        q = sigs.shape[-1]
        self._atom_sigs.append(np.asarray(sigs).reshape(-1, q))
        self._atom_cnts.append(np.asarray(counts).reshape(-1))
        self._atom_sums.append(np.asarray(raw_sums).reshape(-1, q))
        self._chunk_labels.append(np.asarray(row_labels))
        self._anchor_sum += np.asarray(feats, dtype=np.float32).sum(axis=0)

        self._reservoir_update(chunk, r)
        self.rows_seen += r
        self.chunks += 1
        return self

    # --------------------------------------------------------------- finalize

    def finalize(self) -> tuple[CoclusterModel, FitStats]:
        if self.rows_seen == 0:
            raise ValueError("no chunks were fit; stream was empty")
        cfg = self.cfg
        k_row, k_col = cfg.n_row_clusters, cfg.n_col_clusters
        n = self._n_cols
        k = cfg.atom_k
        b = cfg.blocks_per_chunk

        # global atom alignment: the batch merge's signature k-means over
        # ALL chunk atoms (count-weighted, best-of-restarts) — the top of
        # the streaming hierarchy (block -> signature -> global clusters)
        flat_sigs = jnp.asarray(np.concatenate(self._atom_sigs, axis=0))
        flat_cnt = jnp.asarray(np.concatenate(self._atom_cnts, axis=0))
        kmerge = jax.random.fold_in(jax.random.key(cfg.seed + 7), 2)
        atom_global = np.asarray(_merging.cluster_atoms_best(
            kmerge, flat_sigs, flat_cnt, k_row,
            cfg.merge_kmeans_iters, n_restarts=cfg.merge_restarts))

        # per-row votes through each chunk's aligned atoms (numpy: chunk
        # sizes vary, keep this off the jit cache)
        vote_rows = []
        for t, labels in enumerate(self._chunk_labels):
            ag = atom_global[t * b * k:(t + 1) * b * k].reshape(b, k)
            point_global = np.take_along_axis(ag, labels, axis=1)   # (B, r)
            r = labels.shape[1]
            votes = np.zeros((r, k_row), np.float32)
            np.add.at(votes, (np.arange(r)[None, :].repeat(b, 0), point_global),
                      1.0)
            vote_rows.append(votes)
        row_votes = jnp.asarray(np.concatenate(vote_rows, axis=0))
        # assignment semantics shared with the batch drivers (§11):
        # overlap mode marks rows whose vote share clears no cluster as
        # outliers (-1); the vote tables ride in the model either way
        row_labels, _ = _merging.finalize_assignment(
            row_votes, cfg.assignment, cfg.overlap_threshold,
            cfg.min_membership)

        # row serving signatures: atom anchor-feature sums grouped by the
        # atoms' global cluster, centered by the global anchor mean
        row_mean = jnp.asarray(self._anchor_sum / self.rows_seen)
        sums = np.concatenate(self._atom_sums, axis=0)          # (A, q)
        cnts = np.concatenate(self._atom_cnts, axis=0)          # (A,)
        sig_sum = np.zeros((k_row, sums.shape[1]), np.float32)
        sig_cnt = np.zeros((k_row,), np.float32)
        np.add.at(sig_sum, atom_global, sums)
        np.add.at(sig_cnt, atom_global, cnts)
        sig = (jnp.asarray(sig_sum) / jnp.maximum(
            jnp.asarray(sig_cnt)[:, None], 1.0)) - row_mean[None, :]
        row_sigs = sig / jnp.maximum(
            jnp.linalg.norm(sig, axis=1, keepdims=True), 1e-12)

        # columns: clustered in the reservoir-sliver feature space (the
        # anchor-row features serving uses), centered + unit-normalized so
        # profile *direction* decides, then the same best-of-restarts
        # k-means as the row alignment
        fill = max(self._res_fill, 1)
        sliver = jnp.asarray(self._res_vals[:fill])             # (q_res, N)
        feats_c = sliver.T                                      # (N, q_res)
        feats_c = feats_c - jnp.mean(feats_c, axis=0, keepdims=True)
        feats_c = feats_c / jnp.maximum(
            jnp.linalg.norm(feats_c, axis=1, keepdims=True), 1e-12)
        kcols = jax.random.fold_in(jax.random.key(cfg.seed + 7), 3)
        col_labels = _merging.cluster_atoms_best(
            kcols, feats_c, jnp.ones((n,), jnp.float32), k_col,
            cfg.merge_kmeans_iters, n_restarts=cfg.merge_restarts)
        col_votes = jax.nn.one_hot(col_labels, k_col, dtype=jnp.float32)
        col_sigs, col_mean, _ = _merging.cluster_signatures(
            sliver.T, col_labels, k_col)
        anchor_rows = jnp.asarray(self._res_ids[:fill].astype(np.int32))

        model = CoclusterModel(
            row_labels=row_labels, col_labels=col_labels.astype(jnp.int32),
            row_votes=row_votes, col_votes=col_votes,
            row_sigs=row_sigs, col_sigs=col_sigs,
            row_mean=row_mean.astype(jnp.float32),
            col_mean=col_mean.astype(jnp.float32),
            anchor_rows=anchor_rows,
            anchor_cols=self._anchor_cols.astype(jnp.int32),
        )
        dt = time.perf_counter() - self._t0
        state_bytes = int(
            sum(v.nbytes for vs in (self._atom_sigs, self._atom_cnts,
                                    self._atom_sums, self._chunk_labels)
                for v in vs)
            + self._res_vals.nbytes + self._anchor_sum.nbytes)
        stats = FitStats(
            rows_seen=self.rows_seen, n_cols=n, chunks=self.chunks,
            fit_seconds=dt, rows_per_s=self.rows_seen / max(dt, 1e-9),
            peak_chunk_bytes=self._peak_chunk_bytes, state_bytes=state_bytes)
        return model, stats


def fit(chunks: Iterable, cfg: StreamConfig) -> tuple[CoclusterModel, FitStats]:
    """Out-of-core fit over an iterable of row chunks (dense or BCOO).

    Rows are assigned global ids by arrival order. Returns
    ``(model, stats)``; peak resident data is one chunk + the model-sized
    accumulators (``stats`` reports both).
    """
    fitter = StreamingCocluster(cfg)
    for chunk in chunks:
        fitter.partial_fit(chunk)
    return fitter.finalize()


def iter_row_chunks(matrix: np.ndarray, chunk_rows: int,
                    format: str = "dense"):
    """Yield ``(chunk_rows, N)`` row chunks of an in-memory matrix.

    Test/benchmark helper: real out-of-core callers stream chunks from
    disk or the wire. ``format='bcoo'`` converts each chunk (only the
    chunk — O(chunk nnz)) via ``data.synthetic.to_bcoo``.
    """
    if format not in ("dense", "bcoo"):
        raise ValueError(f"format must be 'dense' or 'bcoo', got {format!r}")
    m = matrix.shape[0]
    for start in range(0, m, chunk_rows):
        chunk = np.asarray(matrix[start: start + chunk_rows])
        if format == "bcoo":
            from repro.data.synthetic import to_bcoo

            yield to_bcoo(chunk)
        else:
            yield jnp.asarray(chunk)
