"""Online out-of-sample assignment against a fitted CoclusterModel.

``assign_rows(model, x)`` scores a batch of full-width row vectors
``(B, N)`` against the model's row-cluster signatures; ``assign_cols``
does the same for column vectors ``(B, M)``. The scoring rule
(DESIGN.md §10):

    f = x[:, anchor_cols] - row_mean          # restrict + center
    label = argmax_k  f . row_sigs[k]         # cosine vs unit signatures

Only the ``q`` anchor coordinates of each request are read, so a request
costs ``O(q)`` gather + one ``(B, q) @ (q, K)`` MXU contraction — the
matrix the model was fitted on is not needed. The contraction + argmax
runs through the Pallas scoring kernel (``kernels.ops.cosine_assign``,
oracle ``kernels.ref.cosine_assign_ref``).

Sparse requests: a BCOO batch is accepted and only its anchor columns are
densified (``(B, q)``), never the full request matrix.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import sparse as _sparse
from repro.kernels import ops as _kops

from .model import CoclusterModel

__all__ = ["AssignResult", "TopKAssignResult", "assign_rows", "assign_cols",
           "assign_rows_topk", "assign_cols_topk"]


class AssignResult(NamedTuple):
    labels: jax.Array   # (B,) int32 assigned cluster ids
    score: jax.Array    # (B,) f32 winning cosine score (confidence)


class TopKAssignResult(NamedTuple):
    """Multi-assignment serving result (DESIGN.md §11): the ``k`` best
    clusters per request, descending by score. ``labels[:, 0]`` /
    ``scores[:, 0]`` equal the k=1 :class:`AssignResult` exactly."""

    labels: jax.Array   # (B, k) int32 cluster ids, best first
    scores: jax.Array   # (B, k) f32 cosine scores, descending


def _assign(feats: jax.Array, mean: jax.Array, sigs: jax.Array) -> AssignResult:
    if feats.shape[0] == 0:
        # The service's batch coalescer can legitimately flush an empty
        # batch; the scoring kernel's tile slicing cannot take B=0 (its
        # fixed point tile is wider than the operand), so short-circuit
        # to an empty result of the kernel's exact dtypes. Shapes are
        # static, so this branch resolves at trace time under jit.
        return AssignResult(jnp.zeros((0,), jnp.int32),
                            jnp.zeros((0,), jnp.float32))
    f = feats.astype(jnp.float32) - mean[None, :]
    labels, score = _kops.cosine_assign(f, sigs)
    return AssignResult(labels, score)


def _assign_topk(feats: jax.Array, mean: jax.Array, sigs: jax.Array,
                 k: int) -> TopKAssignResult:
    if feats.shape[0] == 0:
        # same zero-row guard as ``_assign`` (see there); k is validated
        # against the signature count by the kernel wrapper on the
        # non-empty path, so mirror the check before returning
        if not 1 <= k <= sigs.shape[0]:
            raise ValueError(
                f"top-k width must be in [1, {sigs.shape[0]}] (the "
                f"signature count), got k={k}")
        return TopKAssignResult(jnp.zeros((0, k), jnp.int32),
                                jnp.zeros((0, k), jnp.float32))
    f = feats.astype(jnp.float32) - mean[None, :]
    labels, scores = _kops.cosine_topk(f, sigs, k)
    return TopKAssignResult(labels, scores)


def _gather_anchor(x, anchor: jax.Array) -> jax.Array:
    if _sparse.is_bcoo(x):
        return _sparse.gather_cols_dense(x, anchor)
    return jnp.asarray(x)[:, anchor]


def _request_shape(x) -> tuple:
    return tuple(x.shape) if _sparse.is_bcoo(x) else tuple(jnp.asarray(x).shape)


def assign_rows(model: CoclusterModel, x) -> AssignResult:
    """Assign new row vectors ``x (B, N)`` (dense or BCOO) to row clusters."""
    shape = _request_shape(x)
    if len(shape) != 2 or shape[1] != model.n_cols:
        raise ValueError(
            f"assign_rows expects (B, {model.n_cols}) row vectors, got {shape}")
    return _assign(_gather_anchor(x, model.anchor_cols),
                   model.row_mean, model.row_sigs)


def assign_cols(model: CoclusterModel, y) -> AssignResult:
    """Assign new column vectors ``y (B, M)`` (dense or BCOO) to col clusters."""
    shape = _request_shape(y)
    if len(shape) != 2 or shape[1] != model.n_rows:
        raise ValueError(
            f"assign_cols expects (B, {model.n_rows}) column vectors, got "
            f"{shape}")
    return _assign(_gather_anchor(y, model.anchor_rows),
                   model.col_mean, model.col_sigs)


def assign_rows_topk(model: CoclusterModel, x, k: int = 4) -> TopKAssignResult:
    """Top-``k`` row-cluster assignment of ``x (B, N)`` (dense or BCOO).

    The overlap-mode serving path: instead of argmax-ing the signature
    scores, return the ``k`` best clusters per request (descending), so
    a caller can threshold the score column for soft multi-membership —
    the serving analogue of the vote-share membership rule. Runs through
    the top-k Pallas scoring kernel (``kernels.ops.cosine_topk``, oracle
    ``kernels.ref.cosine_topk_ref``).
    """
    shape = _request_shape(x)
    if len(shape) != 2 or shape[1] != model.n_cols:
        raise ValueError(
            f"assign_rows_topk expects (B, {model.n_cols}) row vectors, got "
            f"{shape}")
    return _assign_topk(_gather_anchor(x, model.anchor_cols),
                        model.row_mean, model.row_sigs, k)


def assign_cols_topk(model: CoclusterModel, y, k: int = 4) -> TopKAssignResult:
    """Top-``k`` col-cluster assignment of ``y (B, M)`` (dense or BCOO)."""
    shape = _request_shape(y)
    if len(shape) != 2 or shape[1] != model.n_rows:
        raise ValueError(
            f"assign_cols_topk expects (B, {model.n_rows}) column vectors, "
            f"got {shape}")
    return _assign_topk(_gather_anchor(y, model.anchor_rows),
                        model.col_mean, model.col_sigs, k)
