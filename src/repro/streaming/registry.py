"""Versioned model registry: the store that makes hot swaps safe.

A :class:`ModelRegistry` is a directory of named model lines, each a
sequence of immutable versions (DRYML's ``dry_repo`` versioned-artifact
pattern, DESIGN.md §15)::

    root/
      <name>/
        v_000001/   one repro.checkpoint dir (save_model format)
        v_000002/
        ...

Every version records *provenance* next to the arrays: the config hash
(so "did the knobs change?" is one string compare), a data fingerprint
(what the model was fitted on — caller-supplied, e.g. a stream id or
:func:`model_fingerprint` of the artifact itself), and free-form metrics
(fit NMI, rows/s). Publishing is crash-consistent for free: the version
directory is claimed atomically (``mkdir``), the payload commits through
``repro.checkpoint``'s fsync'd rename, and a version without a committed
checkpoint (a crash mid-publish) is invisible to ``versions``/``load``.
Versions are immutable — a republish allocates the next id, it never
rewrites history — which is exactly what lets the serving path swap
between them without coordination: any version a reader resolved stays
readable forever.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import threading
import time
from typing import NamedTuple

import jax
import numpy as np

from repro import checkpoint as _ckpt

from .model import CoclusterModel, ModelLoadError, load_model, save_model

__all__ = ["ModelRegistry", "RegistryEntry", "config_hash",
           "model_fingerprint"]

_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9_.-]*$")
_VERSION_RE = re.compile(r"^v_(\d{6})$")


def config_hash(cfg) -> str:
    """Stable hash of a fit config (dataclass, dict, or None).

    Key order is canonicalized, so two configs with equal fields hash
    equal regardless of construction order; ``None`` hashes to a fixed
    sentinel so "no config recorded" is still a comparable value.
    """
    if cfg is None:
        payload = "null"
    else:
        d = dataclasses.asdict(cfg) if dataclasses.is_dataclass(cfg) else cfg
        payload = json.dumps(d, sort_keys=True, default=str)
    return hashlib.blake2b(payload.encode(), digest_size=8).hexdigest()


def model_fingerprint(model: CoclusterModel) -> str:
    """Content hash over every array leaf (name, dtype, shape, bytes).

    Two bit-identical models fingerprint equal; any retrain that moves a
    single vote count does not. Usable as the registry's
    ``data_fingerprint`` when no upstream dataset id exists.
    """
    h = hashlib.blake2b(digest_size=8)
    for field in model._fields:
        arr = np.asarray(jax.device_get(getattr(model, field)))
        h.update(field.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


class RegistryEntry(NamedTuple):
    """One committed version's identity + provenance (no arrays)."""

    name: str
    version: str
    path: str                     # the version's checkpoint directory
    config_hash: str
    data_fingerprint: str | None
    metrics: dict
    created: float | None         # unix seconds at publish


class ModelRegistry:
    """Named, versioned ``CoclusterModel`` store over ``repro.checkpoint``.

    Single registry object per process is the expected shape (the
    service and the background fitter share one); publishing is guarded
    by a lock in-process and by atomic ``mkdir`` claims across
    processes, so concurrent publishers can never allocate the same
    version id.
    """

    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)
        self._lock = threading.Lock()

    # -- naming ----------------------------------------------------------
    def _line_dir(self, name: str) -> str:
        if not _NAME_RE.match(name):
            raise ValueError(
                f"bad model name {name!r}: must match {_NAME_RE.pattern} "
                "(a path-safe identifier)")
        return os.path.join(self.root, name)

    def names(self) -> list[str]:
        """Model lines with at least one committed version."""
        if not os.path.isdir(self.root):
            return []
        return sorted(n for n in os.listdir(self.root)
                      if _NAME_RE.match(n) and self.versions(n))

    def versions(self, name: str) -> list[str]:
        """Committed version ids for ``name``, oldest first."""
        line = self._line_dir(name)
        if not os.path.isdir(line):
            return []
        out = []
        for entry in os.listdir(line):
            if not _VERSION_RE.match(entry):
                continue
            # a claimed-but-never-committed version dir (crash mid-
            # publish) has no committed checkpoint step and is invisible
            if _ckpt.latest_step(os.path.join(line, entry)) is not None:
                out.append(entry)
        return sorted(out)

    def latest(self, name: str) -> str | None:
        vs = self.versions(name)
        return vs[-1] if vs else None

    # -- write path ------------------------------------------------------
    def publish(self, name: str, model: CoclusterModel, *, cfg=None,
                metrics: dict | None = None,
                data_fingerprint: str | None = None,
                extra: dict | None = None) -> RegistryEntry:
        """Commit ``model`` as the next version of line ``name``.

        Returns the committed :class:`RegistryEntry`. The version id is
        claimed with an atomic ``mkdir`` (retried past ids claimed by
        racing publishers), then the payload lands via ``save_model``'s
        fsync'd rename — so a crash at any point leaves either a fully
        committed version or an empty claim that listing ignores.
        """
        line = self._line_dir(name)
        os.makedirs(line, exist_ok=True)
        with self._lock:
            n = 0
            for entry in os.listdir(line):
                m = _VERSION_RE.match(entry)
                if m:
                    n = max(n, int(m.group(1)))
            while True:
                n += 1
                version = f"v_{n:06d}"
                vdir = os.path.join(line, version)
                try:
                    os.mkdir(vdir)  # atomic claim, also across processes
                    break
                except FileExistsError:
                    continue
        reg_meta = {
            "name": name,
            "version": version,
            "config_hash": config_hash(cfg),
            "data_fingerprint": data_fingerprint,
            "metrics": dict(metrics or {}),
            "created": time.time(),
        }
        payload = {"registry": reg_meta}
        if extra:
            payload.update(extra)
        cfg_arg = cfg if (dataclasses.is_dataclass(cfg)
                          and not isinstance(cfg, type)) else None
        save_model(vdir, model, cfg=cfg_arg, extra=payload)
        return self._entry(name, version, vdir, reg_meta)

    # -- read path -------------------------------------------------------
    @staticmethod
    def _entry(name: str, version: str, vdir: str,
               reg_meta: dict) -> RegistryEntry:
        return RegistryEntry(
            name=name, version=version, path=vdir,
            config_hash=reg_meta.get("config_hash", ""),
            data_fingerprint=reg_meta.get("data_fingerprint"),
            metrics=dict(reg_meta.get("metrics") or {}),
            created=reg_meta.get("created"))

    def entry(self, name: str, version: str | None = None) -> RegistryEntry:
        """Provenance of one version (latest by default) — manifest only,
        no array payload is read."""
        version = version or self.latest(name)
        if version is None:
            raise ModelLoadError(
                f"registry has no committed versions of {name!r} under "
                f"{self.root!r} — publish one first")
        vdir = os.path.join(self._line_dir(name), version)
        step = _ckpt.latest_step(vdir)
        if step is None:
            raise ModelLoadError(
                f"registry version {name}/{version} has no committed "
                "checkpoint (crashed publish?) — pick another version")
        meta = _ckpt.read_manifest(vdir, step)
        reg_meta = (meta.get("extra") or {}).get("registry") or {}
        return self._entry(name, version, vdir, reg_meta)

    def entries(self, name: str) -> list[RegistryEntry]:
        return [self.entry(name, v) for v in self.versions(name)]

    def load(self, name: str, version: str | None = None
             ) -> tuple[CoclusterModel, RegistryEntry]:
        """Restore ``(model, entry)`` for ``name`` at ``version``
        (latest when omitted); hash-verified via ``load_model``."""
        ent = self.entry(name, version)
        model, _ = load_model(ent.path)
        return model, ent
