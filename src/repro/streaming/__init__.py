"""Streaming co-clustering subsystem (DESIGN.md §10).

Turns LAMC from a one-shot batch algorithm into a fit/save/load/serve
system:

    model.py    CoclusterModel artifact + checkpoint round-trip
    fit.py      out-of-core fit over row chunks (dense or BCOO)
    assign.py   online out-of-sample assignment (Pallas-backed scoring)
    registry.py named, versioned model store (config hash + fingerprint
                + metrics per version; DESIGN.md §15)
    serve.py    sharded multi-replica assignment service: admission
                queue, fixed-shape batch coalescing, load shedding, hot
                model swap (DESIGN.md §15)

``launch/serve_lamc.py`` is the thin driver on top;
``benchmarks/bench_serve.py`` is the load-test harness.
"""

from .assign import (
    AssignResult,
    TopKAssignResult,
    assign_cols,
    assign_cols_topk,
    assign_rows,
    assign_rows_topk,
)
from .fit import (
    FIT_STATE_KIND,
    FitStats,
    StreamConfig,
    StreamingCocluster,
    fit,
    iter_row_chunks,
    load_fit_state,
    save_fit_state,
    stream_config_from_lamc,
)
from .model import (
    MODEL_KIND,
    CoclusterModel,
    ModelLoadError,
    load_model,
    model_from_result,
    model_memberships,
    save_model,
)
from .registry import (
    ModelRegistry,
    RegistryEntry,
    config_hash,
    model_fingerprint,
)
from .serve import (
    REJECT_REASONS,
    AssignService,
    ServeConfig,
    ServeResult,
    Ticket,
    validate_request,
)

__all__ = [
    "CoclusterModel", "ModelLoadError", "MODEL_KIND",
    "model_from_result", "model_memberships", "save_model", "load_model",
    "StreamConfig", "StreamingCocluster", "FitStats", "fit",
    "iter_row_chunks", "stream_config_from_lamc",
    "FIT_STATE_KIND", "save_fit_state", "load_fit_state",
    "AssignResult", "TopKAssignResult", "assign_rows", "assign_cols",
    "assign_rows_topk", "assign_cols_topk",
    "ModelRegistry", "RegistryEntry", "config_hash", "model_fingerprint",
    "AssignService", "ServeConfig", "ServeResult", "Ticket",
    "validate_request", "REJECT_REASONS",
]
