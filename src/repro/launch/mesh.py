"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so tests/benches see 1 CPU device while
the dry-run process sees 512 forced host devices).

Topology (TPU v5e):
    single pod : (data=16, model=16)            = 256 chips
    multi-pod  : (pod=2, data=16, model=16)     = 512 chips
The ``pod`` axis carries only small payloads (gradient all-reduce for LM
training, LAMC signature gathers) — matching the DCN-connected reality of
cross-pod links.
"""

from __future__ import annotations

import jax

from repro.runtime.shardings import MeshAxes

__all__ = ["make_production_mesh", "mesh_axes", "make_test_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> MeshAxes:
    """MeshAxes view of a mesh created by make_production_mesh."""
    if "pod" in mesh.axis_names:
        return MeshAxes(data=("pod", "data"), model="model")
    return MeshAxes(data=("data",), model="model")


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh over however many (forced) devices a test process has."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
