"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as its own process (``python -m repro.launch.dryrun``): the
first two lines force 512 host devices BEFORE any jax import — smoke tests
and benches must never see this.

For each live cell (see configs.base.cells): builds the appropriate step
(train_step for train shapes, serve prefill/decode for inference shapes),
``jit(...).lower(*ShapeDtypeStructs)`` with explicit in/out shardings,
``.compile()``, then records memory_analysis + cost_analysis + the HLO
collective-byte census into a JSONL file consumed by benchmarks/README.md and
benchmarks/bench_roofline.py.

Also dry-runs the paper's own workload (distributed LAMC co-clustering,
``--arch lamc-coclustering``) on the same meshes.
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import SHAPES, cells, get_arch  # noqa: E402
from repro.core import LAMCConfig  # noqa: E402
from repro.core.distributed import lamc_input_specs, lamc_step_fn  # noqa: E402
from repro.core.partition import PartitionPlan  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

# The paper's own workload cells: (name, rows, cols, m, n, t_p, k)
LAMC_SHAPES = {
    "lamc_1m": dict(rows=1_048_576, cols=262_144, m=16, n=16, t_p=2, k=16),
    "lamc_4m": dict(rows=4_194_304, cols=262_144, m=16, n=16, t_p=1, k=16),
}


def _mesh_for(name: str):
    if name == "multipod":
        return make_production_mesh(multi_pod=True)
    return make_production_mesh(multi_pod=False)


def dryrun_lm_cell(arch_name: str, shape_name: str, mesh_name: str) -> dict:
    mesh = _mesh_for(mesh_name)
    cfg = get_arch(arch_name)
    shape = SHAPES[shape_name]
    t0 = time.time()
    if shape.kind == "train":
        step, structs, in_sh, out_sh = steps_mod.build_train_step(cfg, shape, mesh)
        state_struct, ispecs = structs
        # donate the train state: the production loop aliases it in place —
        # without donation buffer assignment double-counts params+opt as temp
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=None,
                     donate_argnums=(0,))
        with mesh:
            lowered = fn.lower(state_struct, ispecs)
    elif shape.kind == "prefill":
        step, structs, in_sh, out_sh = steps_mod.build_prefill_step(cfg, shape, mesh)
        p_struct, ispecs = structs
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=None)
        with mesh:
            lowered = fn.lower(p_struct, ispecs)
    else:
        step, structs, in_sh, out_sh = steps_mod.build_decode_step(cfg, shape, mesh)
        p_struct, cache_struct, ispecs = structs
        p_sh, c_sh, i_sh = in_sh
        args = [p_struct, cache_struct, ispecs["token"], ispecs["pos"]]
        shards = [p_sh, c_sh, i_sh["token"], i_sh["pos"]]
        if "enc_out" in ispecs:
            args.append(ispecs["enc_out"])
            shards.append(i_sh["enc_out"])
        # donate the KV cache (serving updates it in place)
        fn = jax.jit(step, in_shardings=tuple(shards), out_shardings=None,
                     donate_argnums=(1,))
        with mesh:
            lowered = fn.lower(*args)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = dict(compiled.cost_analysis() or {})
    mem = compiled.memory_analysis()
    mem_stats = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes"):
            mem_stats[attr] = getattr(mem, attr, None)
    hlo = compiled.as_text()
    chips = mesh.devices.size
    rep = rl.roofline_terms(steps_mod.padded_cfg(cfg), shape, mesh_name,
                            chips, cost, hlo)
    rec = dataclasses.asdict(rep)
    rec.update(memory=mem_stats, lower_s=round(lower_s, 1),
               compile_s=round(compile_s, 1), status="ok")
    return rec


def dryrun_lamc_cell(shape_name: str, mesh_name: str) -> dict:
    mesh = _mesh_for(mesh_name)
    spec = LAMC_SHAPES[shape_name]
    m, n, t_p = spec["m"], spec["n"], spec["t_p"]
    block_axes = ("data", "model")
    resample_axis = None
    if "pod" in mesh.axis_names:
        if t_p % mesh.shape["pod"] == 0:
            # pod axis parallelizes the T_p resamples (§Perf L3)
            resample_axis = "pod"
        else:
            # T_p=1: split the block grid across pods instead
            m *= mesh.shape["pod"]
            block_axes = ("pod", "data", "model")
    plan = PartitionPlan(
        n_rows=spec["rows"], n_cols=spec["cols"], m=m, n=n,
        phi=spec["rows"] // m, psi=spec["cols"] // n, t_p=t_p, seed=0)
    cfg = LAMCConfig(n_row_clusters=spec["k"], n_col_clusters=spec["k"],
                     svd_iters=4, kmeans_iters=16)
    step, in_sh, out_sh = lamc_step_fn(cfg, plan, mesh, block_axes,
                                       resample_axis=resample_axis)
    a_spec = lamc_input_specs(plan)
    t0 = time.time()
    fn = jax.jit(step, in_shardings=in_sh, out_shardings=None)
    with mesh:
        lowered = fn.lower(a_spec)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    cost = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    chips = mesh.devices.size
    coll = rl.collective_bytes_from_hlo(hlo)
    flops = float(cost.get("flops", 0.0))
    hbytes = float(cost.get("bytes accessed", 0.0))
    rec = dict(
        arch="lamc-coclustering", shape=shape_name, mesh=mesh_name,
        chips=chips, hlo_flops=flops, hlo_bytes=hbytes,
        collective_bytes=coll["total"], collectives=coll,
        compute_s=flops / (chips * rl.HW["flops_bf16"]),
        memory_s=hbytes / (chips * rl.HW["hbm_bw"]),
        collective_s=coll["total"] / (chips * rl.HW["ici_bw"]),
        lower_s=round(lower_s, 1), compile_s=round(compile_s, 1),
        status="ok",
    )
    terms = {k: rec[k] for k in ("compute_s", "memory_s", "collective_s")}
    rec["dominant"] = max(terms, key=terms.get).replace("_s", "")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch (default: all)")
    ap.add_argument("--shape", default=None, help="single shape (default: all)")
    ap.add_argument("--mesh", default="singlepod",
                    choices=["singlepod", "multipod", "both"])
    ap.add_argument("--out", default="dryrun_results.jsonl")
    ap.add_argument("--include-lamc", action="store_true", default=True)
    ap.add_argument("--skip-lamc", dest="include_lamc", action="store_false")
    args = ap.parse_args()

    meshes = ["singlepod", "multipod"] if args.mesh == "both" else [args.mesh]
    todo = []
    if args.arch == "lamc-coclustering":
        for m in meshes:
            for s in (LAMC_SHAPES if args.shape is None else [args.shape]):
                todo.append(("lamc", s, m))
    else:
        for cfg, shape, live, why in cells(include_skipped=True):
            if args.arch and cfg.name != args.arch:
                continue
            if args.shape and shape.name != args.shape:
                continue
            for m in meshes:
                todo.append(("lm", (cfg.name, shape.name, live, why), m))
        if args.include_lamc and args.arch is None and args.shape is None:
            for m in meshes:
                for s in LAMC_SHAPES:
                    todo.append(("lamc", s, m))

    done = set()
    if os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") == "ok" or r.get("status") == "skipped":
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    with open(args.out, "a") as f:
        for kind, payload, mesh_name in todo:
            if kind == "lm":
                arch, shape, live, why = payload
                key = (arch, shape, mesh_name)
                if key in done:
                    print(f"[skip-cached] {key}", flush=True)
                    continue
                if not live:
                    rec = dict(arch=arch, shape=shape, mesh=mesh_name,
                               status="skipped", reason=why)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    print(f"[skipped] {key}: {why}", flush=True)
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = dryrun_lm_cell(arch, shape, mesh_name)
                except Exception as e:  # noqa: BLE001 — record and continue
                    rec = dict(arch=arch, shape=shape, mesh=mesh_name,
                               status="error", error=f"{type(e).__name__}: {e}",
                               tb=traceback.format_exc()[-2000:])
            else:
                key = ("lamc-coclustering", payload, mesh_name)
                if key in done:
                    print(f"[skip-cached] {key}", flush=True)
                    continue
                print(f"[dryrun] {key} ...", flush=True)
                try:
                    rec = dryrun_lamc_cell(payload, mesh_name)
                except Exception as e:  # noqa: BLE001
                    rec = dict(arch="lamc-coclustering", shape=payload,
                               mesh=mesh_name, status="error",
                               error=f"{type(e).__name__}: {e}",
                               tb=traceback.format_exc()[-2000:])
            f.write(json.dumps(rec) + "\n")
            f.flush()
            status = rec["status"]
            extra = ""
            if status == "ok":
                extra = (f" dominant={rec.get('dominant')}"
                         f" compute={rec.get('compute_s', 0):.4f}s"
                         f" mem={rec.get('memory_s', 0):.4f}s"
                         f" coll={rec.get('collective_s', 0):.4f}s"
                         f" compile={rec.get('compile_s')}s")
            print(f"[{status}] {key}{extra}", flush=True)


if __name__ == "__main__":
    main()
