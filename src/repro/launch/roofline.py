"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds (DESIGN.md §8):

    compute    = HLO_FLOPs   / (chips x 197e12 bf16 FLOP/s)
    memory     = HLO_bytes   / (chips x 819e9  B/s HBM)
    collective = coll_bytes  / (chips x 50e9   B/s ICI per link)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum the output
shape bytes of every all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute op (output size = bytes that actually cross links for
AG; for all-reduce we count 2x the operand — reduce-scatter + all-gather
decomposition of a ring).

Also derives MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""

from __future__ import annotations

import dataclasses
import json
import re

from repro.configs.base import ArchConfig, ShapeConfig

__all__ = ["HW", "collective_bytes_from_hlo", "roofline_terms",
           "model_flops", "RooflineReport"]

# TPU v5e per-chip constants (system prompt / public spec)
HW = {
    "flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,          # B/s
    "ici_bw": 50e9,           # B/s per link
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(",
    re.MULTILINE,
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of 'bf16[128,4096]{...}' or tuple '(f32[2], bf16[4,4])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo_text: str) -> dict[str, int]:
    """Sum output bytes per collective kind (deduping -start/-done pairs:
    only -start (or the plain op) is counted)."""
    out: dict[str, int] = {}
    seen_done_skip = 0
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        # skip the -done half of async pairs (shape repeats)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if f"{kind}-done(" in line:
            seen_done_skip += 1
            continue
        nbytes = _shape_bytes(shape_str)
        if kind == "all-reduce":
            nbytes *= 2  # ring AR = RS + AG worth of wire bytes
        out[kind] = out.get(kind, 0) + nbytes
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE); D = tokens processed.

    decode shapes process global_batch tokens per step; train includes the
    3x backward factor already via the 6 (2 fwd + 4 bwd); for pure-forward
    shapes (prefill/decode) use 2*N*D."""
    n = cfg.active_param_count() if cfg.is_moe else cfg.param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # one token per sequence
    return 2.0 * n * tokens


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collectives: dict
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    bytes_per_device: float | None = None
    peak_memory_per_device: float | None = None

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


def roofline_terms(cfg: ArchConfig, shape: ShapeConfig, mesh_name: str,
                   chips: int, cost: dict, hlo_text: str,
                   memory_stats: dict | None = None) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    # cost_analysis 'bytes accessed' aggregates operand+output HBM traffic
    hbytes = float(cost.get("bytes accessed", 0.0))
    coll = collective_bytes_from_hlo(hlo_text)
    compute_s = flops / (chips * HW["flops_bf16"])
    memory_s = hbytes / (chips * HW["hbm_bw"])
    collective_s = coll["total"] / (chips * HW["ici_bw"])
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return RooflineReport(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=hbytes,
        collective_bytes=float(coll["total"]), collectives=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=mf,
        useful_ratio=(mf / flops if flops else 0.0),
        peak_memory_per_device=(memory_stats or {}).get("bytes_per_device"),
    )
