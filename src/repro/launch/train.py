"""End-to-end training driver.

``python -m repro.launch.train --arch smollm-360m --steps 300 ...``

Production-shaped loop: config -> mesh -> sharded init -> jit'd train step
(forward + backward + AdamW, WSD schedule) -> synthetic restartable data
pipeline -> checkpoint manager (atomic, keep-k, auto-resume) -> fault
tolerance (optional injected failures exercise the restore path).

On this CPU container it is exercised with reduced configs
(examples/train_lm.py trains a ~smollm-family model for a few hundred
steps); on a pod the same driver takes the full configs.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as ckpt
from repro.configs.base import ShapeConfig, get_arch, reduced
from repro.data.tokens import TokenBatchSpec, make_batch
from repro.launch import steps as steps_mod
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.fault_tolerance import FailureInjector, SimulatedFailure

__all__ = ["TrainRun", "train_loop", "main"]


@dataclasses.dataclass
class TrainRun:
    losses: list
    final_step: int
    failures: int
    wall_s: float


def train_loop(*, arch: str, steps: int, batch_size: int, seq_len: int,
               ckpt_dir: str, save_every: int = 50, use_reduced: bool = True,
               mesh=None, fail_at: tuple[int, ...] = (), keep_last: int = 3,
               lr: float = 3e-3, log_every: int = 10,
               log_fn=print) -> TrainRun:
    cfg = reduced(arch) if use_reduced else get_arch(arch)
    shape = ShapeConfig("custom", seq_len, batch_size, "train")
    if mesh is None:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    opt_cfg = AdamWConfig(lr=lr, weight_decay=0.01)
    step_fn, structs, in_sh, _ = steps_mod.build_train_step(
        cfg, shape, mesh, opt_cfg)
    state_struct, _ = structs
    state_shard, batch_shard = in_sh
    jit_step = jax.jit(step_fn, in_shardings=in_sh, out_shardings=None,
                       donate_argnums=(0,))

    pcfg = steps_mod.padded_cfg(cfg, mesh)
    from repro.models import build_model

    model = build_model(pcfg)

    def fresh_state():
        with mesh:
            params = jax.jit(model.init, out_shardings=state_shard["params"])(
                jax.random.key(0))
            opt = jax.jit(adamw_init, out_shardings=state_shard["opt"])(params)
        return {"params": params, "opt": opt}

    spec = TokenBatchSpec(batch_size=batch_size, seq_len=seq_len,
                          vocab_size=cfg.vocab_size, seed=0)
    rng = np.random.default_rng(0)

    def batch_for(step: int):
        b = make_batch(spec, step)
        out = {"tokens": jnp.asarray(b["tokens"]),
               "targets": jnp.asarray(b["targets"])}
        if pcfg.frontend == "patches":
            out["frontend_embeds"] = jnp.asarray(
                rng.normal(size=(batch_size, pcfg.frontend_len, pcfg.d_model)),
                jnp.bfloat16)
        if pcfg.enc_dec:
            out["frontend_embeds"] = jnp.asarray(
                rng.normal(size=(batch_size, pcfg.enc_seq_len, pcfg.d_model)),
                jnp.bfloat16)
        return out

    # ---- auto-resume ----
    start = 0
    latest = ckpt.latest_step(ckpt_dir)
    state = fresh_state()
    if latest is not None:
        state, _ = ckpt.restore(ckpt_dir, latest, jax.eval_shape(lambda: state))
        start = latest
        log_fn(f"[train] resumed from step {latest}")

    injector = FailureInjector(fail_at_steps=fail_at)
    losses = []
    failures = 0
    t0 = time.time()
    step = start
    while step < steps:
        try:
            injector.maybe_fail(step)
            batch = batch_for(step)
            with mesh:
                state, metrics = jit_step(state, batch)
            step += 1
            if step % log_every == 0 or step == steps:
                loss = float(metrics["loss"])
                losses.append((step, loss))
                log_fn(f"[train] step {step} loss {loss:.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f}")
            if step % save_every == 0 or step == steps:
                ckpt.save(ckpt_dir, step, state, extra_meta={"arch": arch})
                for old in ckpt.available_steps(ckpt_dir)[:-keep_last]:
                    import shutil, os
                    shutil.rmtree(os.path.join(ckpt_dir, f"step_{old:08d}"))
        except SimulatedFailure as e:
            failures += 1
            latest = ckpt.latest_step(ckpt_dir)
            log_fn(f"[train] FAILURE at step {step} ({e}); "
                   f"restoring from {latest}")
            state = fresh_state()
            if latest is not None:
                state, _ = ckpt.restore(ckpt_dir, latest,
                                        jax.eval_shape(lambda: state))
                step = latest
            else:
                step = 0
    return TrainRun(losses=losses, final_step=step, failures=failures,
                    wall_s=time.time() - t0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (pod-scale) config instead of reduced")
    args = ap.parse_args()
    run = train_loop(arch=args.arch, steps=args.steps,
                     batch_size=args.batch_size, seq_len=args.seq_len,
                     ckpt_dir=args.ckpt_dir, save_every=args.save_every,
                     use_reduced=not args.full_config, lr=args.lr)
    print(json.dumps({"final_step": run.final_step,
                      "first_loss": run.losses[0][1] if run.losses else None,
                      "last_loss": run.losses[-1][1] if run.losses else None,
                      "failures": run.failures,
                      "wall_s": round(run.wall_s, 1)}))


if __name__ == "__main__":
    main()
