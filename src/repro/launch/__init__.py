"""Launchers: mesh construction, multi-pod dry-run, train/serve drivers.

NOTE: do not import ``dryrun`` from library code — it mutates XLA_FLAGS at
import time (by design, for its own process).
"""

from . import mesh, roofline, steps

__all__ = ["mesh", "roofline", "steps"]
