"""Scan-aware collective/FLOP census by unit extrapolation.

``compiled.cost_analysis()`` and naive HLO parsing count while-loop bodies
once (benchmarks/README.md §Roofline methodology). This tool compiles the SAME
cell at ``n_layers = 0 units`` and ``n_layers = 1 unit`` and extrapolates:

    total(L) = cost(0) + L * (cost(1) - cost(0))

which is exact for scanned stacks (every unit is identical HLO) and keeps
everything derived from compiled artifacts. Used by the §Perf hillclimbs
to measure collective-byte deltas of sharding changes.

Run as:  python -m repro.launch.unit_census --arch X --shape Y [--mesh ...]
(own process: forces 512 host devices).
"""

import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import SHAPES, get_arch  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def _cell_costs(cfg, shape, mesh):
    if shape.kind == "train":
        step, structs, in_sh, _ = steps_mod.build_train_step(cfg, shape, mesh)
        args = structs
    elif shape.kind == "prefill":
        step, structs, in_sh, _ = steps_mod.build_prefill_step(cfg, shape, mesh)
        args = structs
    else:
        step, structs, in_sh, _ = steps_mod.build_decode_step(cfg, shape, mesh)
        p_struct, cache_struct, ispecs = structs
        p_sh, c_sh, i_sh = in_sh
        args = [p_struct, cache_struct, ispecs["token"], ispecs["pos"]]
        in_sh = tuple([p_sh, c_sh, i_sh["token"], i_sh["pos"]])
        if "enc_out" in ispecs:
            args.append(ispecs["enc_out"])
            in_sh = in_sh + (i_sh["enc_out"],)
        with mesh:
            compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
        return _extract(compiled)
    with mesh:
        compiled = jax.jit(step, in_shardings=in_sh).lower(*args).compile()
    return _extract(compiled)


def _extract(compiled):
    cost = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    coll = rl.collective_bytes_from_hlo(hlo)
    mem = compiled.memory_analysis()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
        "temp_gb": (mem.temp_size_in_bytes / 2**30) if mem else None,
    }


def unit_census(arch: str, shape_name: str, multi_pod: bool = False,
                cfg_override=None):
    """Returns (c0, c1, extrapolated_total) cost dicts."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg_override or get_arch(arch)
    shape = SHAPES[shape_name]
    unit = len(cfg.block_pattern)
    nd = cfg.n_dense_layers
    cfg0 = dataclasses.replace(cfg, n_layers=nd, n_dense_layers=nd)
    cfg1 = dataclasses.replace(cfg, n_layers=nd + unit, n_dense_layers=nd)
    c0 = _cell_costs(cfg0, shape, mesh)
    c1 = _cell_costs(cfg1, shape, mesh)
    n_units = (cfg.n_layers - nd) // unit
    total = {}
    for k in ("flops", "bytes"):
        total[k] = c0[k] + n_units * (c1[k] - c0[k])
    total["coll_total"] = (c0["coll"]["total"]
                           + n_units * (c1["coll"]["total"] - c0["coll"]["total"]))
    total["coll_kinds"] = {
        kind: c0["coll"].get(kind, 0)
        + n_units * (c1["coll"].get(kind, 0) - c0["coll"].get(kind, 0))
        for kind in set(c0["coll"]) | set(c1["coll"]) if kind != "total"
    }
    return c0, c1, total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()
    c0, c1, total = unit_census(args.arch, args.shape, args.multipod)
    chips = 512 if args.multipod else 256
    print(json.dumps({
        "c0_coll": c0["coll"], "c1_coll": c1["coll"],
        "extrapolated": total,
        "coll_s_per_dev": total["coll_total"] / chips / rl.HW["ici_bw"],
        "flops_s": total["flops"] * chips / chips / rl.HW["flops_bf16"],
    }, indent=1))


if __name__ == "__main__":
    main()
