"""Jittable train/prefill/decode step builders shared by the dry-run,
the training driver and the serving driver.

``build_train_step`` returns the full production step: forward + backward
+ gradient all-reduce (implicit via shardings) + AdamW update — the real
per-step cost the roofline measures. ``build_decode_step`` returns the
single-token serve step over a KV cache.

All builders also return ShapeDtypeStruct input specs and shardings so the
dry-run can ``.lower(...).compile()`` without allocating anything.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, wsd_schedule
from repro.runtime import shardings as sh

__all__ = ["padded_cfg", "input_specs", "build_train_step",
           "build_prefill_step", "build_decode_step"]


def padded_cfg(cfg: ArchConfig, mesh: Mesh | None = None) -> ArchConfig:
    """Pad vocab to a shardable multiple (DESIGN.md)."""
    v = sh.pad_vocab(cfg.vocab_size)
    if v != cfg.vocab_size:
        cfg = dataclasses.replace(cfg, vocab_size=v)
    return cfg


def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.frontend == "patches":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), dtype)
        if cfg.enc_dec:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq_len, cfg.d_model), dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.frontend == "patches":
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.frontend_len, cfg.d_model), dtype)
        if cfg.enc_dec:
            specs["frontend_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.enc_seq_len, cfg.d_model), dtype)
        return specs
    # decode: one new token against a seq_len-deep cache
    specs = {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
             "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if cfg.enc_dec:
        specs["enc_out"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq_len, cfg.d_model), dtype)
    return specs


def _param_struct(cfg: ArchConfig, model):
    return jax.eval_shape(lambda: model.init(jax.random.key(0)))


def _auto_microbatches(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> int:
    """Gradient-accumulation factor for train cells whose activation
    footprint would exceed the 16 GB/device budget (qwen2-vl / llama4 /
    whisper at global_batch=256; §Perf G1). Napkin: per-device activation
    temp ~ layers x (B,S,D) x ~6 bytes-equivalents / chips."""
    act_gb = (cfg.n_layers * shape.global_batch * shape.seq_len * cfg.d_model
              * 2 * 6) / mesh.devices.size / 2**30
    mb = 1
    while act_gb / mb > 5.0 and mb < 8 and shape.global_batch % (2 * mb) == 0:
        mb *= 2
    return mb


def build_train_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh,
                     opt_cfg: AdamWConfig | None = None,
                     microbatches: int | None = None):
    """Returns (step_fn, arg_structs, in_shardings, out_shardings).

    step_fn(train_state, batch) -> (train_state, metrics); train_state is
    {"params":…, "opt": AdamWState} — optimizer states share the param
    shardings (co-located, update fully local). ``microbatches > 1``
    accumulates gradients over sequential micro-steps (activation memory
    / k at the cost of k-fold FSDP re-gathers; auto-enabled for cells over
    the HBM budget).
    """
    cfg = padded_cfg(cfg, mesh)
    from repro.launch.mesh import mesh_axes
    axes = mesh_axes(mesh)
    act_sharding = None
    if shape.seq_len % mesh.shape[axes.model] == 0:
        # enc-dec included: the constraint applies to decoder carries only
        act_sharding = NamedSharding(mesh, P(axes.fsdp, axes.model, None))
    # probe the param structure once to build the per-unit gather constraint
    probe = build_model(cfg)
    p_probe = _param_struct(cfg, probe)
    unit_constraint = sh.unit_gather_shardings(cfg, p_probe, mesh, axes)
    model = build_model(cfg, act_sharding=act_sharding,
                        unit_constraint=unit_constraint)
    opt_cfg = opt_cfg or AdamWConfig()

    p_struct = _param_struct(cfg, model)
    p_specs = sh.param_specs(cfg, p_struct, mesh, axes)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    opt_struct = jax.eval_shape(adamw_init, p_struct)
    opt_shard = type(opt_struct)(
        step=NamedSharding(mesh, P()),
        m=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                       is_leaf=lambda x: isinstance(x, P)),
        v=jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                       is_leaf=lambda x: isinstance(x, P)),
    )
    b_specs = sh.batch_specs(cfg, mesh, axes, batch=shape.global_batch)
    ispecs = input_specs(cfg, shape)
    b_shard = {k: NamedSharding(mesh, b_specs[k]) for k in ispecs}

    n_mb = microbatches if microbatches is not None else \
        _auto_microbatches(cfg, shape, mesh)

    def step(state, batch):
        if n_mb > 1:
            mb_batch = jax.tree.map(
                lambda x: x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:]),
                batch)

            def accum(carry, mb):
                gsum, lsum = carry
                (loss, _parts), grads = jax.value_and_grad(
                    model.loss_fn, has_aux=True)(state["params"], mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / n_mb, gsum, grads)
                return (gsum, lsum + loss / n_mb), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            (grads, loss), _ = jax.lax.scan(
                accum, (zeros, jnp.zeros((), jnp.float32)), mb_batch)
            parts = {}
        else:
            (loss, parts), grads = jax.value_and_grad(
                model.loss_fn, has_aux=True)(state["params"], batch)
        lr_scale = wsd_schedule(state["opt"].step, warmup_steps=200,
                                stable_steps=10_000, decay_steps=2_000)
        new_p, new_opt, om = adamw_update(opt_cfg, state["params"], grads,
                                          state["opt"], lr_scale)
        metrics = {"loss": loss, **{k: v for k, v in parts.items()}, **om}
        return {"params": new_p, "opt": new_opt}, metrics

    state_struct = {"params": p_struct, "opt": opt_struct}
    state_shard = {"params": p_shard, "opt": opt_shard}
    out_shard = (state_shard, None)
    return step, (state_struct, ispecs), (state_shard, b_shard), out_shard


def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """serve prefill: (params, batch) -> (last logits, caches)."""
    cfg = padded_cfg(cfg, mesh)
    from repro.launch.mesh import mesh_axes
    axes = mesh_axes(mesh)
    # SP activation sharding matters even more for prefill than training:
    # without it the chunked-attention f32 accumulators replicate across the
    # model axis (measured 137 GB/dev -> 1.1 GB/dev on smollm prefill_32k;
    # benchmarks/README.md §Perf).
    act_sharding = None
    if shape.seq_len % mesh.shape[axes.model] == 0:
        # enc-dec included: the constraint applies to decoder carries only
        act_sharding = NamedSharding(mesh, P(axes.fsdp, axes.model, None))
    model = build_model(cfg, param_dtype=jnp.bfloat16, act_sharding=act_sharding)
    p_struct = _param_struct(cfg, model)
    p_specs = sh.param_specs(cfg, p_struct, mesh, axes)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    ispecs = input_specs(cfg, shape)
    b_specs = sh.batch_specs(cfg, mesh, axes, batch=shape.global_batch)
    b_shard = {k: NamedSharding(mesh, b_specs[k]) for k in ispecs}

    def step(params, batch):
        extra = {k: v for k, v in batch.items() if k != "tokens"} or None
        logits, caches = model.prefill(params, batch["tokens"], extra)
        return logits

    return step, (p_struct, ispecs), (p_shard, b_shard), None


def build_decode_step(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh):
    """serve decode: (params, cache, token, pos) -> (logits, cache)."""
    cfg = padded_cfg(cfg, mesh)
    from repro.launch.mesh import mesh_axes
    axes = mesh_axes(mesh)
    model = build_model(cfg, param_dtype=jnp.bfloat16)
    p_struct = _param_struct(cfg, model)
    p_specs = sh.param_specs(cfg, p_struct, mesh, axes)
    p_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), p_specs,
                           is_leaf=lambda x: isinstance(x, P))
    b = shape.global_batch
    # auto-quantize the KV cache to int8 when the bf16 cache would exceed
    # ~25% of v5e HBM per device: the decode step scan double-buffers the
    # cache carry, so peak ~= 2.5x cache + weights (minicpm/qwen2-vl
    # decode_32k measured; §Perf Q1/D1)
    n_attn = sum(1 for k in range(cfg.n_layers)
                 if cfg.block_pattern[k % len(cfg.block_pattern)] in ("attn",))
    cache_gb = (b * cfg.n_kv_heads * shape.seq_len * cfg.head_dim_ * 2 * 2
                * max(n_attn, 1)) / mesh.devices.size / 2**30
    quantized = cache_gb > 0.25 * 16
    cache_struct = jax.eval_shape(
        functools.partial(model.init_decode_cache, b, shape.seq_len,
                          quantized=quantized))
    c_specs = sh.cache_specs(cfg, cache_struct, mesh, axes, batch=b)
    c_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), c_specs,
                           is_leaf=lambda x: isinstance(x, P))
    ispecs = input_specs(cfg, shape)
    i_shard = {
        "token": NamedSharding(mesh, P(axes.fsdp if b % _n(mesh, axes.fsdp) == 0
                                       else None)),
        "pos": NamedSharding(mesh, P()),
    }
    if "enc_out" in ispecs:
        i_shard["enc_out"] = NamedSharding(
            mesh, P(axes.fsdp if b % _n(mesh, axes.fsdp) == 0 else None,
                    None, None))

    def step(params, cache, token, pos, enc_out=None):
        extra = {"enc_out": enc_out} if enc_out is not None else None
        logits, new_cache = model.decode_step(params, token, cache, pos, extra)
        return logits, new_cache

    return step, (p_struct, cache_struct, ispecs), (p_shard, c_shard, i_shard), None


def _n(mesh, axes) -> int:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n
