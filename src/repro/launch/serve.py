"""Serving driver: batched prefill + decode loop with KV caches.

``python -m repro.launch.serve --arch qwen3-4b --batch 4 --prompt-len 32
--gen 16`` runs reduced-config serving on CPU; the same driver with
``--full-config`` on a pod serves the real architectures (the dry-run
proves the full-config decode step lowers/compiles on the production
mesh).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_arch, reduced
from repro.models import build_model, transformer

__all__ = ["generate", "main"]


def generate(*, arch: str, batch: int, prompt_len: int, gen_len: int,
             use_reduced: bool = True, seed: int = 0, greedy: bool = True):
    cfg = reduced(arch) if use_reduced else get_arch(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(seed))
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    extra = None
    if cfg.frontend == "patches":
        extra = {"frontend_embeds": jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.d_model)),
            jnp.bfloat16)}
    if cfg.enc_dec:
        extra = {"frontend_embeds": jnp.asarray(
            rng.normal(size=(batch, cfg.enc_seq_len, cfg.d_model)),
            jnp.bfloat16)}

    max_len = prompt_len + gen_len
    t0 = time.time()
    last_logits, caches = model.prefill(params, prompts, extra)
    cache = transformer.grow_cache(cfg, caches, prompt_len, max_len)
    prefill_s = time.time() - t0

    dextra = None
    if cfg.enc_dec:
        # encoder output is computed once and reused each decode step
        enc = transformer._encode(cfg, params,
                                  extra["frontend_embeds"].astype(jnp.bfloat16))
        dextra = {"enc_out": enc}

    decode = jax.jit(
        lambda p, tok, c, pos: model.decode_step(p, tok, c, pos, dextra))

    tok = jnp.argmax(last_logits, -1).astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    for i in range(gen_len - 1):
        logits, cache = decode(params, tok, cache, jnp.int32(prompt_len + i))
        if greedy:
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            key = jax.random.fold_in(jax.random.key(seed + 1), i)
            tok = jax.random.categorical(key, logits).astype(jnp.int32)
        out_tokens.append(tok)
    decode_s = time.time() - t0
    seqs = jnp.stack(out_tokens, axis=1)  # (B, gen)
    return {
        "tokens": np.asarray(seqs),
        "prefill_s": prefill_s,
        "decode_s": decode_s,
        "tokens_per_s": batch * (gen_len - 1) / max(decode_s, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--sample", action="store_true")
    args = ap.parse_args()
    out = generate(arch=args.arch, batch=args.batch,
                   prompt_len=args.prompt_len, gen_len=args.gen,
                   use_reduced=not args.full_config,
                   greedy=not args.sample)
    print(json.dumps({
        "batch": args.batch, "gen": args.gen,
        "prefill_s": round(out["prefill_s"], 3),
        "decode_s": round(out["decode_s"], 3),
        "tokens_per_s": round(out["tokens_per_s"], 1),
        "sample_tokens": out["tokens"][0][:8].tolist(),
    }))


if __name__ == "__main__":
    main()
