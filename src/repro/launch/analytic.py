"""Analytic roofline cost model — trip-count-exact FLOPs / HBM bytes /
collective bytes per (arch x shape x mesh) cell.

WHY THIS EXISTS (measured, see benchmarks/README.md §Roofline methodology):
XLA's ``HloCostAnalysis`` visits each while-loop body ONCE, ignoring trip
counts. Every layer stack here is a ``lax.scan`` (48-80 iterations) and
several blocks contain inner scans (KV-chunk attention, xLSTM sequence
scan, chunked cross-entropy), so ``compiled.cost_analysis()`` undercounts
FLOPs by ~2-3 orders of magnitude (calibrated against a no-scan config
where both agree). The dry-run still records the raw numbers; the roofline
*terms* come from this model, which is exact for our known program
structure (we wrote every loop, so we know every trip count).

Conventions:
  * FLOPs are global per step; 1 MAC = 2 FLOPs.
  * Backward = 2x forward matmul FLOPs; full-unit remat adds 1x forward
    recompute (our checkpoint policy saves nothing inside a unit).
  * HBM bytes are per-device, converted to a global-equivalent by x chips
    (the roofline divides by chips x BW again, so terms stay per-device
    honest).
  * Collective bytes are wire bytes per device (ring algorithms:
    all-gather of an N-byte tensor over k peers moves N*(k-1)/k per
    device; all-reduce = 2x that; all-to-all = N*(k-1)/k).
"""

from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig, ShapeConfig

__all__ = ["analytic_cell", "AnalyticCosts"]


@dataclasses.dataclass
class AnalyticCosts:
    flops_global: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    breakdown: dict


def _ring(nbytes: float, k: int) -> float:
    """Per-device wire bytes for an all-gather/reduce-scatter over k peers."""
    if k <= 1:
        return 0.0
    return nbytes * (k - 1) / k


def _attn_flops_fwd(cfg: ArchConfig, b: int, s: int, kv: int,
                    causal_frac: float = 0.5) -> float:
    """Scores + AV for one layer, forward."""
    dh = cfg.head_dim_
    return 4.0 * b * cfg.n_heads * s * kv * dh * causal_frac


def _layer_matmul_params(cfg: ArchConfig, kind: str, moe_active: bool) -> float:
    """Matmul-visible parameters of one block (what multiplies tokens)."""
    d, dh = cfg.d_model, cfg.head_dim_
    attn = d * cfg.n_heads * dh * 2 + d * cfg.n_kv_heads * dh * 2
    ff = cfg.moe_d_ff or cfg.d_ff

    def mlp_p(f):
        return 3 * d * f

    total = 0.0
    if kind in ("attn", "local"):
        total += attn
    elif kind == "rglru":
        d_rnn = d
        total += 2 * d * d_rnn + d_rnn * d + 2 * d_rnn * d_rnn  # x/gate/out + a,i gates
    elif kind == "mlstm":
        total += 5 * d * d
    elif kind == "slstm":
        total += 6 * d * d + 4 * d * d / max(cfg.n_heads, 1)
    if cfg.is_moe:
        active = cfg.experts_per_token if moe_active else cfg.n_experts
        total += active * mlp_p(ff) + cfg.n_shared_experts * mlp_p(ff)
        total += d * cfg.n_experts  # router
    elif cfg.d_ff > 0:
        total += mlp_p(cfg.d_ff)
    return total


def _layer_kinds(cfg: ArchConfig):
    kinds = ["attn"] * cfg.n_dense_layers
    pat = cfg.block_pattern
    n_scan = cfg.n_layers - cfg.n_dense_layers
    for i in range(n_scan):
        kinds.append(pat[i % len(pat)])
    return kinds


def analytic_cell(cfg: ArchConfig, shape: ShapeConfig, chips: int,
                  model_axis: int = 16, fsdp_axis: int = 16,
                  pod_axis: int = 1) -> AnalyticCosts:
    b, s = shape.global_batch, shape.seq_len
    d, v = cfg.d_model, cfg.vocab_size
    kinds = _layer_kinds(cfg)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()

    # ----- FLOPs (global) -----
    if shape.kind == "decode":
        tokens = b
        kv = s
        fwd = 0.0
        for kind in kinds:
            fwd += 2.0 * _layer_matmul_params(cfg, kind, moe_active=True) * tokens
            if kind == "attn":
                fwd += _attn_flops_fwd(cfg, tokens, 1, kv, causal_frac=1.0)
            elif kind == "local":
                fwd += _attn_flops_fwd(cfg, tokens, 1, min(kv, cfg.window or kv),
                                       causal_frac=1.0)
            elif kind in ("mlstm",):
                fwd += 10.0 * tokens * d * cfg.head_dim_
        fwd += 2.0 * d * v * tokens  # lm head
        if cfg.enc_dec:
            fwd += len(kinds) * _attn_flops_fwd(cfg, tokens, 1, cfg.enc_seq_len, 1.0)
        flops = fwd
    else:
        tokens = b * s
        fwd = 0.0
        for kind in kinds:
            fwd += 2.0 * _layer_matmul_params(cfg, kind, moe_active=True) * tokens
            if kind == "attn":
                fwd += _attn_flops_fwd(cfg, b, s, s)
            elif kind == "local":
                w = cfg.window or s
                fwd += _attn_flops_fwd(cfg, b, s, min(w, s), causal_frac=1.0 if w < s else 0.5)
            elif kind == "mlstm":
                fwd += 10.0 * tokens * d * cfg.head_dim_
        fwd += 2.0 * d * v * tokens  # lm head
        if cfg.enc_dec:
            enc_tokens = b * cfg.enc_seq_len
            enc_p = cfg.n_enc_layers * (_layer_matmul_params(
                dataclasses.replace(cfg, n_experts=0), "attn", True))
            fwd += 2.0 * enc_p * enc_tokens
            fwd += cfg.n_enc_layers * _attn_flops_fwd(cfg, b, cfg.enc_seq_len,
                                                      cfg.enc_seq_len, 1.0)
            fwd += len(kinds) * _attn_flops_fwd(cfg, b, s, cfg.enc_seq_len, 1.0)
        if shape.kind == "train":
            # fwd + full-unit remat recompute + backward(2x) = 4x fwd matmuls
            flops = 4.0 * fwd
        else:
            flops = fwd

    # ----- HBM bytes (per device) -----
    p_local = n_params / chips  # FSDP x TP shards across the whole mesh
    bd = {}
    if shape.kind == "train":
        # weights: bf16 read fwd+remat+bwd (3x2B) + f32 master+m+v read/write
        w_bytes = p_local * (3 * 2 + 8 * 4)
        # activation carries: one (B,S,D) bf16 per layer, read+write ~3x,
        # sharded over data x model (SP)
        act_local = len(kinds) * (b * s * d * 2) / chips
        a_bytes = 3 * act_local
        # logits chunks: (B,S,V) f32 never materialized; chunk traffic ~
        # 2 passes x f32, sharded over mesh
        l_bytes = 2 * (b * s * v * 4) / chips
        hbm = w_bytes + a_bytes + l_bytes
        bd.update(weight_bytes=w_bytes, act_bytes=a_bytes, logit_bytes=l_bytes)
    elif shape.kind == "prefill":
        w_bytes = p_local * 2
        act_local = len(kinds) * (b * s * d * 2) / chips
        kv_local = sum(
            (b * cfg.n_kv_heads * (min(cfg.window, s) if k == "local" and cfg.window else s)
             * cfg.head_dim_ * 2 * 2) / chips
            for k in kinds if k in ("attn", "local"))
        hbm = w_bytes + 2 * act_local + kv_local
        bd.update(weight_bytes=w_bytes, act_bytes=2 * act_local, kv_bytes=kv_local)
    else:  # decode
        w_bytes = (n_active if cfg.is_moe else n_params) / chips * 2
        kv_local = sum(
            (b * cfg.n_kv_heads * (min(cfg.window, s) if k == "local" and cfg.window else s)
             * cfg.head_dim_ * 2 * 2) / chips
            for k in kinds if k in ("attn", "local"))
        state_local = 0.0
        for k in kinds:
            if k == "mlstm":
                state_local += b * cfg.n_heads * cfg.head_dim_ ** 2 * 4 / chips
            elif k in ("slstm", "rglru"):
                state_local += b * d * 4 * 4 / chips
        hbm = w_bytes + kv_local + state_local
        bd.update(weight_bytes=w_bytes, kv_bytes=kv_local, state_bytes=state_local)

    # ----- collective bytes (per device wire) -----
    coll = 0.0
    n_layers = len(kinds)
    if shape.kind == "train":
        # FSDP param all-gather (bf16) x3 passes + grad reduce-scatter (f32->bf16)
        shard_after_tp = n_params * 2 / model_axis  # bytes per data-group
        coll += 3 * _ring(shard_after_tp, fsdp_axis)
        coll += 2 * _ring(shard_after_tp, fsdp_axis)       # grad RS+AG (AR)
        # SP boundary AG (enter block) + RS (leave block) per layer x
        # (fwd, remat, bwd). NOTE: the RS *is* the TP partial-sum reduction
        # (Megatron-SP) — counting a separate TP psum would double-count.
        x_bytes = b * s * d * 2 / (fsdp_axis * pod_axis)
        coll += 3 * 2 * n_layers * _ring(x_bytes, model_axis)
        if pod_axis > 1:
            coll += 2 * _ring(n_params * 2 / (model_axis * fsdp_axis), pod_axis)
        if cfg.is_moe:
            # all-to-all token dispatch+combine, fwd+remat+bwd
            moe_layers = n_layers - cfg.n_dense_layers
            tok_bytes = b * s * d * 2 / chips * cfg.experts_per_token
            coll += 3 * 2 * moe_layers * _ring(tok_bytes, model_axis)
    elif shape.kind == "prefill":
        shard_after_tp = n_params * 2 / model_axis
        coll += _ring(shard_after_tp, fsdp_axis)
        x_bytes = b * s * d * 2 / (fsdp_axis * pod_axis)
        coll += 2 * n_layers * _ring(x_bytes, model_axis)  # SP AG+RS, fwd only
        if cfg.is_moe:
            tok_bytes = b * s * d * 2 / chips * cfg.experts_per_token
            coll += 2 * n_layers * _ring(tok_bytes, model_axis)
    else:  # decode: TP psums of (B,1,D) per layer + logits gather
        x_bytes = b * d * 2 / max(fsdp_axis * pod_axis // 1, 1)
        coll += 2 * n_layers * _ring(x_bytes, model_axis)
        coll += _ring(b * v * 2 / (fsdp_axis * pod_axis), model_axis)
        if cfg.is_moe:
            coll += 2 * n_layers * _ring(x_bytes * cfg.experts_per_token, model_axis)
    bd["coll_bytes"] = coll

    return AnalyticCosts(flops_global=flops, hbm_bytes_per_dev=hbm,
                         coll_bytes_per_dev=coll, breakdown=bd)
