"""Online co-cluster assignment server (thin driver).

``python -m repro.launch.serve_lamc --ckpt /tmp/lamc_model --fit-demo``
fits a small planted model out-of-core (``streaming.fit``), saves it, and
then serves batched ``assign_rows``/``assign_cols`` requests *from the
restored checkpoint* — proving the full fit → save → load → serve loop.
Against an existing checkpoint, drop ``--fit-demo``.

This module is deliberately thin: request validation, admission,
batching, and hot swap live in ``repro.streaming.serve`` (DESIGN.md
§15); the default mode here is the single-process direct loop (the
per-PR latency trajectory in BENCH_stream.json), and ``--service`` runs
the same synthetic stream through a full :class:`streaming.AssignService`
(admission queue + coalescer + worker replicas). The adversarial load
mix and swap-under-load live in ``benchmarks/bench_serve.py``.

Modeled on ``launch.serve``: the assignment function is jitted once,
warmed up, and driven by a request loop; per-batch wall-clock latencies
are aggregated into p50/p99 and QPS (requests = rows assigned). Rows are
merged into ``BENCH_stream.json`` (same contract as ``benchmarks/run.py``)
so serving latency is tracked per-PR next to the chunked-fit throughput.

Malformed requests (wrong width/rank, non-finite payloads) are *rejected
per request* — counted in ``serve_assign_*_errors`` next to p50/p99 —
instead of crashing the loop or poisoning the latency stats with NaN
scores. ``--adversarial N`` interleaves N bad batches into the stream to
demonstrate the path (the smoke lane runs it).

Latency aggregation runs on an ``obs.Histogram`` (fixed geometric
buckets), not a materialized sample list: memory stays O(buckets)
however long the request stream runs — an adversarial flood cannot grow
the process — and p50/p99 come from the bucket interpolation the oracle
test in ``tests/test_obs.py`` pins against ``np.percentile``. With
``REPRO_OBS=1`` (or ``--trace-out``) the loop also emits a span trace.
"""

from __future__ import annotations

import argparse
import json
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, streaming
from repro.data import planted_cocluster_matrix

__all__ = ["fit_demo_model", "validate_request", "serve", "serve_service",
           "main"]


def fit_demo_model(ckpt_dir: str, *, n_rows: int = 1024, n_cols: int = 512,
                   k: int = 5, chunk_rows: int = 256, seed: int = 0) -> None:
    """Out-of-core fit on a planted matrix and save the model artifact."""
    rng = np.random.default_rng(seed)
    data = planted_cocluster_matrix(rng, n_rows, n_cols, k=k, d=k,
                                    signal=4.0, noise=0.6)
    cfg = streaming.StreamConfig(n_row_clusters=k, n_col_clusters=k, seed=seed)
    model, stats = streaming.fit(
        streaming.iter_row_chunks(data.matrix, chunk_rows), cfg)
    streaming.save_model(ckpt_dir, model, extra={
        "fit_stats": {"rows_seen": stats.rows_seen, "chunks": stats.chunks,
                      "rows_per_s": round(stats.rows_per_s, 1)}})
    print(f"fit-demo: {stats.rows_seen}x{stats.n_cols} in {stats.chunks} "
          f"chunks ({stats.rows_per_s:.0f} rows/s) -> saved to {ckpt_dir}")


def validate_request(x, dim: int) -> str | None:
    """Reject reason for one request batch, or None if servable.

    Thin wrapper over the service layer's reason-coded validator
    (``streaming.serve.validate_request``) — one taxonomy for the
    direct loop and the admission queue; this driver keeps the legacy
    flat-string form.
    """
    bad = streaming.validate_request(x, dim)
    if bad is None:
        return None
    code, detail = bad
    return f"{code}: {detail}"


def _adversarial_batch(i: int, batch: int, dim: int):
    """Deterministic rotation of the malformed-request taxonomy."""
    kind = i % 3
    if kind == 0:
        return np.zeros((batch, dim + 3), np.float32)       # wrong width
    if kind == 1:
        x = np.zeros((batch, dim), np.float32)
        x[0, 0] = np.nan                                    # poisoned payload
        return x
    return np.zeros((batch * dim,), np.float32)             # wrong rank


def serve(ckpt_dir: str, *, batch: int = 64, requests: int = 32,
          rows: int | None = None, warmup: int = 3, axis: str = "rows",
          seed: int = 1, adversarial: int = 0,
          registry: obs.Registry | None = None) -> dict:
    """Serve a stream of synthetic request batches; report latency/QPS.

    The stream is ``requests`` full ``batch``-row batches, unless
    ``rows`` is given — then exactly ``rows`` rows are served in
    ``batch``-row batches with a final *partial* batch for the
    remainder, which is why QPS is computed from the rows actually
    served (summed per batch), never ``batch * hist.count``: the old
    formula over-reported whenever the tail batch was short.

    ``adversarial`` extra malformed batches are interleaved into the
    stream; each is rejected (logged + counted), never timed — the
    error counter rides next to the latency stats so a deploy that
    starts bouncing requests is visible in the same bench row.

    Latencies fold into a ``serve_assign_{axis}_latency_us`` histogram on
    ``registry`` (default: a fresh per-call :class:`obs.Registry`, so one
    serve's stats never bleed into another's); rejections increment
    ``serve_assign_{axis}_errors``. Memory is O(buckets) regardless of
    stream length. When every batch was rejected the percentiles are NaN
    (empty histogram) — the error counter is the whole story.
    """
    reg = registry if registry is not None else obs.Registry()
    hist = reg.histogram(f"serve_assign_{axis}_latency_us",
                         help="per-batch assign latency, µs")
    err_ct = reg.counter(f"serve_assign_{axis}_errors",
                         help="rejected request batches")
    if rows is not None:
        sizes = [batch] * (rows // batch) + ([rows % batch]
                                             if rows % batch else [])
    else:
        sizes = [batch] * requests
    with obs.span("serve", axis=axis, batch=batch, requests=len(sizes),
                  adversarial=adversarial) as root:
        model, meta = streaming.load_model(ckpt_dir)
        dim = model.n_cols if axis == "rows" else model.n_rows
        assign = (streaming.assign_rows if axis == "rows"
                  else streaming.assign_cols)
        step = jax.jit(lambda x: assign(model, x))

        rng = np.random.default_rng(seed)
        reqs = jnp.asarray(rng.normal(size=(batch, dim)).astype(np.float32))
        with obs.span("warmup", iters=warmup):
            for _ in range(warmup):
                jax.block_until_ready(step(reqs))
            if sizes and sizes[-1] != batch:
                # pre-compile the tail shape so the partial batch's
                # latency sample measures serving, not tracing
                jax.block_until_ready(step(reqs[:sizes[-1]]))

        # interleave adversarial batches roughly uniformly through the stream
        stream: list[tuple[bool, object]] = list(enumerate(sizes))
        for i in range(adversarial):
            pos = min(len(stream),
                      1 + i * max(1, len(sizes) // max(adversarial, 1)))
            stream.insert(pos, (i, None))

        out = None
        rows_served = 0
        with obs.span("request_loop", total=len(stream)):
            for i, size in stream:
                x = ((reqs[:size] + jnp.float32(i)) if size is not None
                     else _adversarial_batch(i, batch, dim))
                reason = validate_request(x, dim)
                if reason is not None:
                    err_ct.inc()
                    obs.event("request_rejected", reason=reason)
                    print(f"serve[{axis}]: rejected request: {reason}")
                    continue
                t0 = time.perf_counter()
                out = jax.block_until_ready(step(x))
                hist.observe((time.perf_counter() - t0) * 1e6)
                rows_served += int(np.shape(x)[0])

        # percentiles straight off the bucket counts; NaN when every batch
        # was rejected (empty histogram) — same contract as before. QPS is
        # rows actually served over time actually measured: a final
        # partial batch contributes its true row count.
        p50 = hist.percentile(50)
        p99 = hist.percentile(99)
        qps = (rows_served / max(hist.sum / 1e6, 1e-9)
               if hist.count else 0.0)
        root.set(served=hist.count, rows=rows_served,
                 errors=int(err_ct.value),
                 p50_us=None if math.isnan(p50) else round(p50, 1))
    return {
        f"serve_assign_{axis}_p50_us": p50,
        f"serve_assign_{axis}_p99_us": p99,
        f"serve_assign_{axis}_qps": qps,
        f"serve_assign_{axis}_rows": rows_served,
        f"serve_assign_{axis}_errors": int(err_ct.value),
        "_labels_sample": (np.asarray(out.labels[:8]).tolist()
                           if out is not None else []),
        "_model_kind": meta.get("kind"),
        "_batch": batch,
    }


def serve_service(ckpt_dir: str, *, batch: int = 64, requests: int = 32,
                  warmup: int = 3, axis: str = "rows", seed: int = 1,
                  replicas: int = 2, k: int = 1) -> dict:
    """Drive the same synthetic stream through a full ``AssignService``.

    Unlike :func:`serve` (the direct jit loop), this path exercises the
    whole service stack — admission, coalescing into fixed-shape jit
    batches, worker replicas — and reports the *service's* latency
    percentiles (submit → fulfil, which includes queueing). Requests are
    quarter-batch sized so the coalescer has real work to do; every
    ticket is awaited and checked, so a reject or a dropped request
    fails loudly rather than skewing the stats.
    """
    model, meta = streaming.load_model(ckpt_dir)
    reg = obs.Registry()
    cfg = streaming.ServeConfig(batch=batch, replicas=replicas)
    size = max(1, batch // 4)
    dim = model.n_cols if axis == "rows" else model.n_rows
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(size, dim)).astype(np.float32)
    t_wall = time.perf_counter()
    with streaming.AssignService(model, version="serve_lamc",
                                 config=cfg, metrics=reg) as svc:
        for _ in range(warmup):
            svc.submit(base, axis=axis, k=k).result(timeout=60.0)
        t_wall = time.perf_counter()
        tickets = [svc.submit(base + np.float32(i), axis=axis, k=k)
                   for i in range(requests)]
        rows_served = 0
        for t in tickets:
            res = t.result(timeout=60.0)
            if not res.ok:
                raise RuntimeError(
                    f"service rejected a well-formed request: "
                    f"{res.reason}: {res.detail}")
            rows_served += len(res.labels)
        wall_s = time.perf_counter() - t_wall
        stats = svc.stats()
    qps = rows_served / max(wall_s, 1e-9)
    return {
        f"serve_svc_{axis}_p50_us": stats["p50_request_us"],
        f"serve_svc_{axis}_p99_us": stats["p99_request_us"],
        f"serve_svc_{axis}_qps": qps,
        f"serve_svc_{axis}_rows": rows_served,
        f"serve_svc_{axis}_fill_pct": stats["mean_batch_fill_pct"],
        "_model_kind": meta.get("kind"),
        "_replicas": replicas,
        "_batch": batch,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", required=True, help="model checkpoint directory")
    ap.add_argument("--fit-demo", action="store_true",
                    help="fit + save a small planted model first")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--rows", type=int, default=None,
                    help="serve exactly this many rows (final batch may be "
                         "partial) instead of --requests full batches")
    ap.add_argument("--warmup", type=int, default=3)
    ap.add_argument("--axis", choices=["rows", "cols", "both"], default="both")
    ap.add_argument("--adversarial", type=int, default=0,
                    help="interleave N malformed request batches (rejected + "
                         "counted, never crash the loop)")
    ap.add_argument("--service", action="store_true",
                    help="route the stream through streaming.AssignService "
                         "(admission queue + coalescer + replicas) instead "
                         "of the direct jit loop")
    ap.add_argument("--replicas", type=int, default=2,
                    help="worker replicas for --service")
    ap.add_argument("--bench-out", default="BENCH_stream.json",
                    help="merge latency rows into this file ('' to skip)")
    ap.add_argument("--trace-out", default="",
                    help="write the serve span trace as JSONL here "
                         "(implies enabling obs spans)")
    args = ap.parse_args(argv)

    if args.trace_out:
        obs.configure(enabled=True)
    if obs.enabled():
        obs.reset_trace()
    if args.fit_demo:
        fit_demo_model(args.ckpt)
    axes = ["rows", "cols"] if args.axis == "both" else [args.axis]
    report = {}
    for axis in axes:
        if args.service:
            out = serve_service(args.ckpt, batch=args.batch,
                                requests=args.requests, warmup=args.warmup,
                                axis=axis, replicas=args.replicas)
        else:
            out = serve(args.ckpt, batch=args.batch, requests=args.requests,
                        rows=args.rows, warmup=args.warmup, axis=axis,
                        adversarial=args.adversarial)
        report.update(out)
    bench_rows = {k: round(v, 1) for k, v in report.items()
                  if not k.startswith("_")}
    if args.bench_out:
        from repro.benchio import merge_rows

        merge_rows(args.bench_out, bench_rows,
                   own_prefixes=("stream_", "serve_"))
    if args.trace_out:
        obs.write_trace_jsonl(args.trace_out)
        print(f"serve trace -> {args.trace_out}")
    print(json.dumps({**bench_rows, "batch": args.batch,
                      "requests": args.requests}, indent=2))


if __name__ == "__main__":
    main()
