"""Spectral co-clustering (Dhillon 2001) — the paper's atom co-clusterer (§IV-C).

Pipeline (Eqs. 5-8 of the paper):
  1. ``A_n = D1^{-1/2} A D2^{-1/2}`` — bipartite graph normalization.
  2. Singular vectors ``u_2..u_{l+1}``, ``v_2..v_{l+1}`` of ``A_n``.
  3. ``Z = [D1^{-1/2} U_hat ; D2^{-1/2} V_hat]`` stacked embedding.
  4. k-means on rows of ``Z``; rows of A get ``labels[:M]``, cols ``labels[M:]``.

TPU adaptation (DESIGN.md §2): exact LAPACK SVD is replaced by fixed-iteration
randomized subspace iteration — pure matmul/QR, MXU-aligned, identical trip
count on every device. ``l = n_singular_vectors`` defaults to
``ceil(log2(k)) + 1`` per Dhillon's analysis but is configurable.

Sparse inputs (DESIGN.md §9): ``normalize_bipartite``, ``randomized_svd``
and ``scc`` accept a BCOO matrix, a dual-ELL operator
(``sparse.EllOperator``, gather-only products) or a tiled block-sparse
operator (``kernels.spmm.BlockSparseMatrix``, MXU tile products with the
fused ``Aᵀ(A·X)`` normal-equations pass). Normalization stays in the
operand's format (degree sums + a data rescale, same sparsity pattern);
the subspace iteration's heavy ops become SpMM — cost O(nnz * rank) (or
O(occupied tiles) for tiled) per pass instead of O(M * N * rank). Only
the (M, l)/(N, l) embeddings densify. ``probability.spmm_route`` picks
the format per matrix from its density.

The normalization has a fused Pallas twin (``repro.kernels.bipartite_normalize``)
used on TPU; this file is also its reference oracle.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import kmeans as _kmeans
from . import sparse as _sparse

__all__ = ["normalize_bipartite", "randomized_svd", "scc", "SCCResult"]


class SCCResult(NamedTuple):
    row_labels: jax.Array   # (M,) int32 in [0, k)
    col_labels: jax.Array   # (N,) int32 in [0, k)
    row_embed: jax.Array    # (M, l) spectral embedding (for merge signatures)
    col_embed: jax.Array    # (N, l)
    inertia: jax.Array


def normalize_bipartite(a: jax.Array, eps: float = 1e-8):
    """``A_n = D1^{-1/2} A D2^{-1/2}`` with degree clamping.

    Degrees are taken on |A| so the construction tolerates signed data
    (the bipartite-graph weights of Eq. 5 assume non-negative affinities).
    Returns ``(a_n, d1_isqrt, d2_isqrt)``; a BCOO input yields a BCOO
    ``a_n`` with the same sparsity pattern (zeros contribute nothing to
    degrees, and the rescale is elementwise on the stored data).
    """
    if _sparse.is_bcoo(a) or _sparse.is_ell(a) or _sparse.is_tiled(a):
        if _sparse.is_ell(a):
            d1, d2 = _sparse.ell_abs_degree_sums(a)
            scale = _sparse.ell_scale_rows_cols
        elif _sparse.is_tiled(a):
            d1, d2 = _sparse.tiled_abs_degree_sums(a)
            scale = _sparse.tiled_scale_rows_cols
        else:
            d1, d2 = _sparse.abs_degree_sums(a)
            scale = _sparse.scale_rows_cols
        d1_isqrt = jax.lax.rsqrt(jnp.maximum(d1, eps))
        d2_isqrt = jax.lax.rsqrt(jnp.maximum(d2, eps))
        return scale(a, d1_isqrt, d2_isqrt), d1_isqrt, d2_isqrt
    aa = jnp.abs(a)
    d1 = jnp.sum(aa, axis=1)
    d2 = jnp.sum(aa, axis=0)
    d1_isqrt = jax.lax.rsqrt(jnp.maximum(d1, eps))
    d2_isqrt = jax.lax.rsqrt(jnp.maximum(d2, eps))
    return a * d1_isqrt[:, None] * d2_isqrt[None, :], d1_isqrt, d2_isqrt


def _orth_from_gram(yf: jax.Array, g: jax.Array,
                    eps: float = 1e-7) -> jax.Array:
    """CholeskyQR from a precomputed Gram: ``Q = Y L^{-T}``, ``G = LLᵀ``.

    Split out of :func:`_cholesky_orth` so the tiled subspace iteration
    can feed it the Gram emitted by the fused ``spmm_ata`` launch
    (``with_gram=True``) — the ``(M, r)`` factor is then never re-read to
    form ``YᵀY``. A trace-scaled ridge keeps the Cholesky finite when
    ``Y`` is (numerically) rank-deficient.
    """
    r = g.shape[0]
    ridge = eps * (jnp.trace(g) / r + 1.0)
    l = jnp.linalg.cholesky(g + ridge * jnp.eye(r, dtype=g.dtype))
    # Solve Q @ Lᵀ = Y  =>  Q = Y L^{-T}.
    return jax.lax.linalg.triangular_solve(
        l, yf, left_side=False, lower=True, transpose_a=True)


def _cholesky_orth(y: jax.Array, eps: float = 1e-7) -> jax.Array:
    """Gram-based orthonormalization: ``Q = Y (YᵀY)^{-1/2}`` (CholeskyQR).

    The Gram matrix is a tiny ``(r, r)`` — the only non-matmul work is its
    Cholesky and a triangular solve, both on an ``(r, r)`` operand, so the
    tall-skinny factor never goes through LAPACK QR. A trace-scaled ridge
    keeps the Cholesky finite when ``Y`` is (numerically) rank-deficient;
    see DESIGN.md §5 for the conditioning argument (squares ``cond(Y)``,
    fine for the normalized-affinity matrices of the SCC atom).
    """
    yf = y.astype(jnp.float32)
    g = yf.T @ yf                                   # (r, r) Gram — MXU
    return _orth_from_gram(yf, g, eps).astype(y.dtype)


def randomized_svd(key: jax.Array, a: jax.Array, rank: int, n_iter: int = 4,
                   qr_method: str = "qr"):
    """Randomized subspace iteration for the top-``rank`` singular triplets.

    ``n_iter`` stabilized power iterations; all heavy ops are matmuls (MXU)
    and a final tiny ``(rank, rank)`` exact SVD. Deterministic in ``key``.
    Returns ``(U (M,r), S (r,), Vt (r,N))``.

    ``qr_method`` selects the per-iteration orthonormalization:
      * ``"qr"`` — Householder ``jnp.linalg.qr`` (LAPACK-exact, but lowers
        to a sequential panel algorithm per block when vmapped on TPU);
      * ``"cholesky"`` — Gram-based CholeskyQR (``_cholesky_orth``):
        matmul + ``(r, r)`` Cholesky only, batch-friendly, MXU-resident.

    A BCOO ``a`` routes every product through SpMM (``kernels.ops.spmm``):
    the power iteration touches only the stored nonzeros, O(nnz * r) per
    pass; the sketch/projection operands stay dense tall-skinny. A
    dual-ELL operand keeps the same two-sided iteration with gather-only
    products. A tiled ``BlockSparseMatrix`` operand runs the *fused
    normal-equations* form instead: each power step is one
    ``A.T @ (A @ X)`` pass (``kernels.ops.spmm_ata`` — a single kernel
    launch whose intermediate never leaves VMEM on TPU), iterating the
    ``(N, r)`` sketch and mapping through ``A`` once at the end. Both
    forms apply the same polynomial of ``A``, so they converge to the
    same subspace: ``span(A (AᵀA)^t Ω) = span((AAᵀ)^t A Ω)``.
    """
    m, n = a.shape
    r = min(rank, m, n)
    orth = _cholesky_orth if qr_method == "cholesky" else (
        lambda y: jnp.linalg.qr(y)[0])
    sparse_in = _sparse.is_bcoo(a) or _sparse.is_ell(a) or _sparse.is_tiled(a)
    if _sparse.is_ell(a):
        # gather-only dual-ELL products — the amortized repeated-product
        # path (converted once per matrix, see sparse.EllOperator)
        matvec = lambda x: _sparse.ell_matvec(a, x)
        rmatvec = lambda x: _sparse.ell_rmatvec(a, x)
        ata = ata_step = None
    elif _sparse.is_tiled(a):
        from repro.kernels import ops as _kops  # lazy: kernels optional on CPU

        matvec = lambda x: _kops.spmm_tiled(a, x)
        rmatvec = lambda x: _kops.spmm_tiled(a, x, transpose=True)
        ata = lambda x: _kops.spmm_ata(a, x)
        if qr_method == "cholesky":
            # fused subspace-iteration step: one spmm_ata launch returns
            # both Z = A.T(A X) and its (r, r) Gram (computed from the
            # still-VMEM-resident stripe on TPU), feeding CholeskyQR
            # directly — Z is never re-read to form ZᵀZ
            ata_step = lambda x: _orth_from_gram(
                *_kops.spmm_ata(a, x, with_gram=True))
        else:
            ata_step = lambda x: orth(ata(x))
    elif _sparse.is_bcoo(a):
        from repro.kernels import ops as _kops

        matvec = lambda x: _kops.spmm(a, x)                  # A @ x
        rmatvec = lambda x: _kops.spmm(a, x, transpose=True)  # A.T @ x
        ata = ata_step = None
    else:
        matvec = lambda x: a @ x
        rmatvec = lambda x: a.T @ x
        ata = ata_step = None
    omega = jax.random.normal(key, (n, r), dtype=jnp.float32 if sparse_in
                              else a.dtype)
    if sparse_in:
        # Orthonormalize the sketch before the first product. Same span, and
        # the QR custom call forces the RNG output to materialize: without
        # it XLA fuses the threefry generator into the SpMM gather and
        # recomputes it per gathered element (measured ~7x slower on CPU).
        omega = orth(omega)
    if ata is not None:
        # fused normal-equations power iteration on the (N, r) sketch
        x = jax.lax.fori_loop(0, n_iter, lambda _, x: ata_step(x), omega)
        q = orth(matvec(x))                         # (M, r)
    else:
        q = orth(matvec(omega))                     # (M, r)

        def body(_, q):
            z = orth(rmatvec(q))                    # (N, r)
            return orth(matvec(z))                  # (M, r)

        q = jax.lax.fori_loop(0, n_iter, body, q)
    b = rmatvec(q).T if sparse_in else q.T @ a      # (r, N)
    # exact SVD of the small projected matrix
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return u, s, vt


def exact_svd(a: jax.Array, rank: int):
    """LAPACK-style full SVD truncated to ``rank`` — the paper's original
    atom cost profile (O(M N min(M,N)), superlinear). Baseline mode for the
    Table II speedup reproduction; ``randomized_svd`` is the TPU-adapted
    default (DESIGN.md §2)."""
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    return u[:, :rank], s[:rank], vt[:rank, :]


@functools.partial(
    jax.jit,
    static_argnames=("n_row_clusters", "n_col_clusters", "n_singular_vectors",
                     "svd_iters", "kmeans_iters", "assign_impl", "svd_method",
                     "qr_method"),
)
def scc(
    key: jax.Array,
    a: jax.Array,
    n_row_clusters: int,
    n_col_clusters: int | None = None,
    n_singular_vectors: int | None = None,
    svd_iters: int = 4,
    kmeans_iters: int = 16,
    assign_impl: str = "jnp",
    svd_method: str = "randomized",
    qr_method: str = "qr",
) -> SCCResult:
    """Spectral co-clustering of one (sub)matrix.

    When ``n_col_clusters == n_row_clusters`` (the bipartite-partition case
    of the paper) rows and columns are clustered *jointly* in the stacked
    ``Z`` space — exactly Dhillon's algorithm. Otherwise rows and columns
    get separate k-means in the same spectral space.
    """
    k = n_row_clusters
    d = n_col_clusters if n_col_clusters is not None else k
    # Dhillon: l = ceil(log2 k) singular vectors carry the k-modal structure;
    # bit_length() gives ceil(log2 x)+1 — one extra vector for robustness —
    # and is a static python int so jit sees a fixed SVD rank.
    l = n_singular_vectors if n_singular_vectors is not None else max(k, d).bit_length()

    if ((_sparse.is_bcoo(a) or _sparse.is_ell(a) or _sparse.is_tiled(a))
            and svd_method == "exact"):
        raise ValueError(
            "svd_method='exact' (LAPACK) requires a dense matrix; the sparse "
            "path supports svd_method='randomized' (SpMM subspace iteration)")
    a_n, d1_isqrt, d2_isqrt = normalize_bipartite(a)
    ksvd, kkm1, kkm2 = jax.random.split(key, 3)
    if svd_method == "exact":
        u, s, vt = exact_svd(a_n, rank=l + 1)
    else:
        u, s, vt = randomized_svd(ksvd, a_n, rank=l + 1, n_iter=svd_iters,
                                  qr_method=qr_method)
    # Drop the leading (trivial) singular pair: u_2..u_{l+1}, v_2..v_{l+1}.
    u_hat = u[:, 1 : l + 1]
    v_hat = vt[1 : l + 1, :].T
    row_embed = d1_isqrt[:, None] * u_hat           # (M, l)
    col_embed = d2_isqrt[:, None] * v_hat           # (N, l)

    if k == d:
        z = jnp.concatenate([row_embed, col_embed], axis=0)
        res = _kmeans.kmeans(kkm1, z, k, n_iter=kmeans_iters, assign_impl=assign_impl)
        row_labels = res.labels[: a.shape[0]]
        col_labels = res.labels[a.shape[0] :]
        inertia = res.inertia
    else:
        res_r = _kmeans.kmeans(kkm1, row_embed, k, n_iter=kmeans_iters, assign_impl=assign_impl)
        res_c = _kmeans.kmeans(kkm2, col_embed, d, n_iter=kmeans_iters, assign_impl=assign_impl)
        row_labels, col_labels = res_r.labels, res_c.labels
        inertia = res_r.inertia + res_c.inertia

    return SCCResult(row_labels, col_labels, row_embed, col_embed, inertia)
