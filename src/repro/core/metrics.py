"""Clustering quality metrics: NMI and ARI (paper §V, Table III).

Pure numpy implementations (evaluation is host-side); definitions match the
standard ones (NMI with arithmetic-mean normalization, ARI per Hubert &
Arabie 1985). Inputs are integer label vectors; ``-1`` labels (unassigned)
are dropped from both vectors.
"""

from __future__ import annotations

import numpy as np

__all__ = ["contingency", "nmi", "ari", "cocluster_scores"]


def _clean(a, b):
    a = np.asarray(a).ravel()
    b = np.asarray(b).ravel()
    if a.shape != b.shape:
        raise ValueError(f"label shape mismatch: {a.shape} vs {b.shape}")
    keep = (a >= 0) & (b >= 0)
    return a[keep], b[keep]


def contingency(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Contingency table (k_a, k_b) of two label vectors."""
    a, b = _clean(a, b)
    ka = int(a.max()) + 1 if a.size else 1
    kb = int(b.max()) + 1 if b.size else 1
    table = np.zeros((ka, kb), np.int64)
    np.add.at(table, (a, b), 1)
    return table


def nmi(a: np.ndarray, b: np.ndarray) -> float:
    """Normalized mutual information, arithmetic normalization in [0, 1]."""
    t = contingency(a, b).astype(np.float64)
    n = t.sum()
    if n == 0:
        return 0.0
    pa = t.sum(1) / n
    pb = t.sum(0) / n
    pab = t / n
    with np.errstate(divide="ignore", invalid="ignore"):
        mi_terms = pab * (np.log(pab) - np.log(pa[:, None]) - np.log(pb[None, :]))
    mi = np.nansum(mi_terms)
    ha = -np.sum(pa * np.where(pa > 0, np.log(np.where(pa > 0, pa, 1.0)), 0.0))
    hb = -np.sum(pb * np.where(pb > 0, np.log(np.where(pb > 0, pb, 1.0)), 0.0))
    denom = 0.5 * (ha + hb)
    if denom <= 0:
        return 1.0 if mi <= 0 else 0.0
    return float(np.clip(mi / denom, 0.0, 1.0))


def ari(a: np.ndarray, b: np.ndarray) -> float:
    """Adjusted Rand index in [-1, 1]."""
    t = contingency(a, b).astype(np.float64)
    n = t.sum()
    if n < 2:
        return 1.0
    comb = lambda x: x * (x - 1.0) / 2.0
    sum_ij = comb(t).sum()
    sum_a = comb(t.sum(1)).sum()
    sum_b = comb(t.sum(0)).sum()
    expected = sum_a * sum_b / comb(n)
    max_index = 0.5 * (sum_a + sum_b)
    if max_index == expected:
        return 1.0
    return float((sum_ij - expected) / (max_index - expected))


def cocluster_scores(
    row_pred, col_pred, row_true, col_true
) -> dict[str, float]:
    """Joint co-clustering quality: average of row and column NMI/ARI
    (the convention used for Table III-style reporting)."""
    return {
        "row_nmi": nmi(row_pred, row_true),
        "col_nmi": nmi(col_pred, col_true),
        "row_ari": ari(row_pred, row_true),
        "col_ari": ari(col_pred, col_true),
        "nmi": 0.5 * (nmi(row_pred, row_true) + nmi(col_pred, col_true)),
        "ari": 0.5 * (ari(row_pred, row_true) + ari(col_pred, col_true)),
    }
