"""Clustering quality metrics: NMI and ARI (paper §V, Table III) plus
overlap-aware scores for the non-exhaustive mode (DESIGN.md §11).

Pure numpy implementations (evaluation is host-side); definitions match the
standard ones (NMI with arithmetic-mean normalization, ARI per Hubert &
Arabie 1985, omega index per Collins & Dent 1988). Inputs to NMI/ARI are
integer label vectors; ``-1`` labels (unassigned) are dropped from both
vectors. Degenerate inputs — every point filtered out, or fewer than two
points/clusters surviving, where mutual information and the adjusted Rand
numerator are identically zero — score 0.0 by definition (no information
recovered), never NaN.

Overlap metrics take boolean membership matrices ``(P, K)`` (a label
vector is accepted and one-hot expanded, ``-1`` rows all-False):
``omega_index`` generalizes ARI to pairs agreeing on *how many* shared
clusters; ``overlap_f1`` is the size-weighted best-match-F1 averaged over
both matching directions.
"""

from __future__ import annotations

import numpy as np

__all__ = ["contingency", "nmi", "ari", "cocluster_scores",
           "membership_from_labels", "omega_index", "overlap_f1"]


def _clean(a, b):
    a = np.asarray(a).ravel()
    b = np.asarray(b).ravel()
    if a.shape != b.shape:
        raise ValueError(f"label shape mismatch: {a.shape} vs {b.shape}")
    keep = (a >= 0) & (b >= 0)
    return a[keep], b[keep]


def contingency(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Contingency table (k_a, k_b) of two label vectors."""
    a, b = _clean(a, b)
    ka = int(a.max()) + 1 if a.size else 1
    kb = int(b.max()) + 1 if b.size else 1
    table = np.zeros((ka, kb), np.int64)
    np.add.at(table, (a, b), 1)
    return table


def nmi(a: np.ndarray, b: np.ndarray) -> float:
    """Normalized mutual information, arithmetic normalization in [0, 1].

    Degenerate inputs score 0.0: an empty intersection (every point
    filtered as unassigned) carries no information, and a single-cluster
    labeling has zero entropy — MI is identically 0 and the normalizer
    vanishes, so the 0/0 is *defined* as 0.0 rather than NaN (the
    boundary the overlap mode's outlier filtering can actually reach).
    """
    t = contingency(a, b).astype(np.float64)
    n = t.sum()
    if n == 0:
        return 0.0
    pa = t.sum(1) / n
    pb = t.sum(0) / n
    pab = t / n
    with np.errstate(divide="ignore", invalid="ignore"):
        mi_terms = pab * (np.log(pab) - np.log(pa[:, None]) - np.log(pb[None, :]))
    mi = np.nansum(mi_terms)
    ha = -np.sum(pa * np.where(pa > 0, np.log(np.where(pa > 0, pa, 1.0)), 0.0))
    hb = -np.sum(pb * np.where(pb > 0, np.log(np.where(pb > 0, pb, 1.0)), 0.0))
    denom = 0.5 * (ha + hb)
    if denom <= 0:
        return 0.0
    return float(np.clip(mi / denom, 0.0, 1.0))


def ari(a: np.ndarray, b: np.ndarray) -> float:
    """Adjusted Rand index in [-1, 1].

    Degenerate inputs score 0.0 (chance level): fewer than two surviving
    points have no pairs to agree on, and the both-single-cluster /
    all-singletons boundary has ``max_index == expected`` — the adjusted
    numerator and denominator are both identically zero, so the 0/0 is
    defined as 0.0 rather than a division error.
    """
    t = contingency(a, b).astype(np.float64)
    n = t.sum()
    if n < 2:
        return 0.0
    comb = lambda x: x * (x - 1.0) / 2.0
    sum_ij = comb(t).sum()
    sum_a = comb(t.sum(1)).sum()
    sum_b = comb(t.sum(0)).sum()
    expected = sum_a * sum_b / comb(n)
    max_index = 0.5 * (sum_a + sum_b)
    if max_index == expected:
        return 0.0
    return float((sum_ij - expected) / (max_index - expected))


def membership_from_labels(labels: np.ndarray, k: int | None = None) -> np.ndarray:
    """Label vector -> boolean membership ``(P, k)``; ``-1`` = no cluster."""
    labels = np.asarray(labels).ravel().astype(np.int64)
    if k is None:
        k = int(labels.max()) + 1 if (labels >= 0).any() else 1
    member = np.zeros((labels.size, k), bool)
    covered = labels >= 0
    member[np.nonzero(covered)[0], labels[covered]] = True
    return member


def _as_membership(x: np.ndarray) -> np.ndarray:
    x = np.asarray(x)
    if x.ndim == 1:
        return membership_from_labels(x)
    if x.ndim != 2:
        raise ValueError(f"membership must be (P,) labels or (P, K), got {x.shape}")
    return x.astype(bool)


def omega_index(a: np.ndarray, b: np.ndarray) -> float:
    """Omega index (Collins & Dent 1988): chance-adjusted pairwise
    agreement on the *number* of shared clusters.

    The overlapping generalization of ARI: a pair of points agrees when
    both solutions place it together in exactly the same number of
    clusters (0, 1, 2, ...); agreement is adjusted by the expected
    agreement of independent solutions with the same together-count
    histograms. Inputs are ``(P, K)`` boolean memberships (label vectors
    are one-hot expanded, ``-1`` = member of nothing); for disjoint
    exhaustive memberships omega reduces to ARI. O(P^2) pairs — host-side
    evaluation on test-sized P.
    """
    a, b = _as_membership(a), _as_membership(b)
    if a.shape[0] != b.shape[0]:
        raise ValueError(f"point count mismatch: {a.shape[0]} vs {b.shape[0]}")
    p = a.shape[0]
    n_pairs = p * (p - 1) // 2
    if n_pairs == 0:
        return 0.0
    iu = np.triu_indices(p, 1)
    shared_a = (a.astype(np.int64) @ a.astype(np.int64).T)[iu]   # pairs x 1
    shared_b = (b.astype(np.int64) @ b.astype(np.int64).T)[iu]
    agree = float(np.mean(shared_a == shared_b))
    width = int(max(shared_a.max(), shared_b.max())) + 1
    ta = np.bincount(shared_a, minlength=width) / n_pairs
    tb = np.bincount(shared_b, minlength=width) / n_pairs
    expected = float(np.sum(ta * tb))
    if expected >= 1.0:
        return 1.0 if agree >= 1.0 else 0.0
    return float((agree - expected) / (1.0 - expected))


def overlap_f1(pred: np.ndarray, true: np.ndarray) -> float:
    """Size-weighted best-match per-cluster F1 for overlapping memberships.

    Every true cluster is matched to the predicted cluster maximizing F1
    of their member sets, weighted by true-cluster size; averaged with
    the reverse direction so inventing or dropping clusters is penalized
    (the average-F1 convention of the overlapping-community literature).
    Returns a score in [0, 1]; 1.0 iff the cluster family matches exactly.
    """
    pred, true = _as_membership(pred), _as_membership(true)
    if pred.shape[0] != true.shape[0]:
        raise ValueError(f"point count mismatch: {pred.shape[0]} vs {true.shape[0]}")

    def directed(x, y):
        sizes = x.sum(0).astype(np.float64)                      # (Kx,)
        if sizes.sum() == 0 or y.shape[1] == 0:
            return 0.0
        inter = x.astype(np.float64).T @ y.astype(np.float64)    # (Kx, Ky)
        denom = sizes[:, None] + y.sum(0).astype(np.float64)[None, :]
        f1 = np.where(denom > 0, 2.0 * inter / np.maximum(denom, 1e-12), 0.0)
        best = f1.max(axis=1)
        return float(np.sum(best * sizes) / sizes.sum())

    return 0.5 * (directed(true, pred) + directed(pred, true))


def cocluster_scores(
    row_pred, col_pred, row_true, col_true
) -> dict[str, float]:
    """Joint co-clustering quality: average of row and column NMI/ARI
    (the convention used for Table III-style reporting)."""
    return {
        "row_nmi": nmi(row_pred, row_true),
        "col_nmi": nmi(col_pred, col_true),
        "row_ari": ari(row_pred, row_true),
        "col_ari": ari(col_pred, col_true),
        "nmi": 0.5 * (nmi(row_pred, row_true) + nmi(col_pred, col_true)),
        "ari": 0.5 * (ari(row_pred, row_true) + ari(col_pred, col_true)),
    }
