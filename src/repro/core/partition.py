"""Large-matrix partitioning (paper §IV-B).

A :class:`PartitionPlan` describes how ``A (M x N)`` is cut into an ``m x n``
grid of uniform ``phi x psi`` blocks, repeated for ``T_p`` independent random
resamples. Permutations are derived from a counter-based PRNG
(``jax.random.fold_in``) so that in the distributed runtime every device can
re-derive its block's row/col indices from ``(seed, resample_index)`` alone —
no index lists ever cross the interconnect (DESIGN.md §2).

Rows/cols that do not fit the uniform grid (``M mod m*phi``) are simply left
out of that resample; across ``T_p`` random resamples every index is covered
with overwhelming probability, and the Theorem-1 budget already accounts for
per-resample misses. ``coverage_probability`` quantifies it.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from . import probability

__all__ = ["PartitionPlan", "make_plan", "resample_indices", "extract_blocks",
           "extract_blocks_sparse", "coverage_probability"]


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    n_rows: int
    n_cols: int
    m: int            # row-blocks per resample
    n: int            # col-blocks per resample
    phi: int          # rows per block
    psi: int          # cols per block
    t_p: int          # number of resamples
    seed: int = 0
    detection_p: float = 1.0  # Theorem-1 lower bound used to pick t_p
    # SpMM backend the plan priced its blocks with ("dense" | "dual_ell" |
    # "tiled") — the density-adaptive dispatch decision, surfaced for
    # callers and tests. "dense" for dense inputs and user-built plans.
    spmm_route: str = "dense"

    @property
    def blocks_per_resample(self) -> int:
        return self.m * self.n

    @property
    def total_blocks(self) -> int:
        return self.m * self.n * self.t_p

    @property
    def rows_used(self) -> int:
        return self.m * self.phi

    @property
    def cols_used(self) -> int:
        return self.n * self.psi


def make_plan(
    n_rows: int,
    n_cols: int,
    *,
    min_cocluster_rows: int,
    min_cocluster_cols: int,
    p_thresh: float = 0.95,
    workers: int = 1,
    seed: int = 0,
    k: int = 8,
    expected_failed_blocks: int = 0,
    grid_candidates=(1, 2, 4, 8, 16, 32),
    svd_method: str = "randomized",
    density: float = 1.0,
    spmm_impl: str = "auto",
) -> PartitionPlan:
    """Optimal plan via the probabilistic model (Eq. 4 + cost search).

    ``density`` (nnz fraction) feeds the sparse-aware atom cost model —
    the SpMM subspace iteration scales with nnz (gather backends) or tile
    occupancy (tiled backend), not block area (``probability._atom_cost``).
    ``spmm_impl`` pins the backend the blocks are priced with; ``"auto"``
    resolves per block density (``probability.spmm_route``) and the
    decision is surfaced on ``PartitionPlan.spmm_route``.
    """
    cand = probability.plan_partition(
        n_rows,
        n_cols,
        min_cocluster_rows=min_cocluster_rows,
        min_cocluster_cols=min_cocluster_cols,
        p_thresh=p_thresh,
        workers=workers,
        k=k,
        expected_failed_blocks=expected_failed_blocks,
        grid_candidates=grid_candidates,
        svd_method=svd_method,
        density=density,
        spmm_impl=spmm_impl,
    )
    return PartitionPlan(
        n_rows=n_rows,
        n_cols=n_cols,
        m=cand.m,
        n=cand.n,
        phi=cand.phi,
        psi=cand.psi,
        t_p=cand.t_p,
        seed=seed,
        detection_p=cand.detection_p,
        spmm_route=cand.spmm_route,
    )


def coverage_probability(plan: PartitionPlan, axis: str | None = None) -> float:
    """P(a given index appears in >= 1 of the T_p resamples).

    ``axis='row'`` / ``'col'`` gives the per-axis coverage; the default
    (``None``) returns their min — the guarantee that holds for *every*
    index of the matrix. (The row-only form silently overstated coverage
    whenever the column grid dropped more of its axis than the row grid.)
    """
    miss_row = 1.0 - plan.rows_used / plan.n_rows
    miss_col = 1.0 - plan.cols_used / plan.n_cols
    row_cov = 1.0 - miss_row**plan.t_p
    col_cov = 1.0 - miss_col**plan.t_p
    if axis == "row":
        return row_cov
    if axis == "col":
        return col_cov
    if axis is not None:
        raise ValueError(f"axis must be 'row', 'col' or None, got {axis!r}")
    return min(row_cov, col_cov)


def resample_indices(plan: PartitionPlan, resample: jax.Array | int):
    """Row/col index groups for one resample.

    Returns ``(row_idx, col_idx)`` of shapes ``(m, phi)`` / ``(n, psi)``:
    ``row_idx[i]`` are the global row ids landing in block-row ``i``.
    Deterministic in ``(plan.seed, resample)`` — re-derivable anywhere.
    """
    key = jax.random.fold_in(jax.random.key(plan.seed), resample)
    krow, kcol = jax.random.split(key)
    row_perm = jax.random.permutation(krow, plan.n_rows)[: plan.rows_used]
    col_perm = jax.random.permutation(kcol, plan.n_cols)[: plan.cols_used]
    row_idx = row_perm.reshape(plan.m, plan.phi)
    col_idx = col_perm.reshape(plan.n, plan.psi)
    return row_idx, col_idx


def extract_blocks(a: jax.Array, plan: PartitionPlan, resample: jax.Array | int):
    """Extract the ``(m*n, phi, psi)`` block stack for one resample.

    Also returns the index maps so labels can be scattered back:
    ``blocks[i * n + j] == a[row_idx[i]][:, col_idx[j]]``.
    """
    row_idx, col_idx = resample_indices(plan, resample)
    rows, cols = row_idx.reshape(-1), col_idx.reshape(-1)
    # Two gathers; the first one materializes an intermediate whose size
    # depends on order — (rows_used, N) rows-first vs (M, cols_used)
    # cols-first. Gather the axis that shrinks the matrix most first, so
    # peak gather traffic is min(rows_used*N, M*cols_used) + blocks, not
    # always rows_used*N (which loses badly when N >> cols_used).
    if plan.rows_used * plan.n_cols <= plan.n_rows * plan.cols_used:
        sub = a[rows][:, cols]                            # (m*phi, n*psi)
    else:
        sub = a[:, cols][rows]                            # (m*phi, n*psi)
    blocks = (
        sub.reshape(plan.m, plan.phi, plan.n, plan.psi)
        .transpose(0, 2, 1, 3)
        .reshape(plan.m * plan.n, plan.phi, plan.psi)
    )
    return blocks, row_idx, col_idx


def extract_blocks_sparse(a, plan: PartitionPlan, resample: jax.Array | int):
    """``extract_blocks`` for a BCOO matrix — O(nnz), never densifies A.

    Instead of gathering a ``(m*phi, n*psi)`` dense submatrix, every
    stored nonzero computes its own destination through the *inverse*
    resample permutation — ``(block, row-in-block, col-in-block)`` — and
    scatters straight into the dense block stack. Nonzeros whose row or
    column misses this resample's uniform grid map to an out-of-range
    block id and are dropped (``mode='drop'``), mirroring the dense
    path's "rows that don't fit are left out". The blocks themselves
    densify (they are the atom work unit and must be MXU-shaped), but
    peak memory is ``m*n*phi*psi + O(nnz)`` — the dense ``M x N`` matrix
    never exists.

    Bit-exact vs ``extract_blocks`` on the densified input: each block
    cell receives exactly one stored value or stays zero (BCOO indices
    are unique), so there is no summation-order drift.
    """
    from . import sparse as _sparse  # local: keep partition importable sans jax.experimental

    _sparse.validate_bcoo(a)
    row_idx, col_idx = resample_indices(plan, resample)
    inv_row = jnp.full((plan.n_rows,), plan.rows_used, jnp.int32).at[
        row_idx.reshape(-1)].set(jnp.arange(plan.rows_used, dtype=jnp.int32))
    inv_col = jnp.full((plan.n_cols,), plan.cols_used, jnp.int32).at[
        col_idx.reshape(-1)].set(jnp.arange(plan.cols_used, dtype=jnp.int32))
    pr = inv_row[a.indices[:, 0]]                 # position among used rows
    pc = inv_col[a.indices[:, 1]]
    i, p = pr // plan.phi, pr % plan.phi          # block-row, row-in-block
    j, s = pc // plan.psi, pc % plan.psi
    bid = i * plan.n + j
    # The row sentinel alone lands out of range (i == m -> bid >= m*n), but
    # the col sentinel gives j == n which can alias a valid block id for
    # i < m - 1 — force every dropped nonzero out of range explicitly.
    valid = (pr < plan.rows_used) & (pc < plan.cols_used)
    bid = jnp.where(valid, bid, plan.m * plan.n)
    blocks = jnp.zeros((plan.m * plan.n, plan.phi, plan.psi), a.data.dtype)
    blocks = blocks.at[bid, p, s].add(a.data, mode="drop")
    return blocks, row_idx, col_idx
