"""Distributed LAMC — the paper's parallel structure mapped onto a TPU mesh.

Phase map (DESIGN.md §2):

  1. **Block scatter** (jit + GSPMD): ``extract_blocks`` gathers the
     permuted row/col groups out of the mesh-sharded data matrix. XLA emits
     the all-to-all; this is the only phase that moves matrix data, and it
     moves each element exactly once per resample.

  2. **Per-block co-clustering** (shard_map): every device owns
     ``m*n / n_devices`` blocks and runs the atom co-clusterer *locally* —
     small per-device SVD/QR/k-means, never a partitioned factorization.
     This is the paper's "parallel co-clustering of submatrices": identical
     static shapes, zero communication.

  3. **Hierarchical merge** (shard_map collectives): devices exchange only
     atom *signatures* (``k x q`` floats each) via ``all_gather`` — a
     log-depth tree on ICI — cluster them identically everywhere (tiny
     replicated k-means), then ``psum`` the per-point vote tables.
     Total bytes on the wire per resample: ``B*(k+d)*q*4`` + the two vote
     tables — independent of the data matrix size. This is the paper's
     communication-overhead fix realized as collectives.

The pipeline is one jitted program; resamples run under ``lax.scan``.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from .. import obs
from . import merging, partition
from . import sparse as _sparse
from .lamc import LAMCConfig, LAMCResult, _atom_fn, anchor_features, validate_assignment


def _validate_input_format(a, cfg: LAMCConfig) -> None:
    """Same format/knob guards as ``lamc_cocluster`` — fail loudly before jit.

    ``cfg.spmm_impl`` is validated here too; the distributed driver always
    densifies its (device-local, MXU-shaped) blocks, so the knob's
    single-block sparse-operator route is the single-host driver's — a
    multi-device mesh implies a multi-block plan.
    """
    _sparse.validate_spmm_impl(cfg.spmm_impl)
    validate_assignment(cfg)
    if cfg.input_format == "bcoo":
        _sparse.validate_bcoo(a)
    elif _sparse.is_bcoo(a):
        raise ValueError(
            "got a BCOO matrix with input_format='dense'; set "
            "LAMCConfig(input_format='bcoo') for the sparse path")

__all__ = ["distributed_lamc", "lamc_step_fn", "lamc_input_specs"]


def _merge_votes_local(point_global, index_of_points, n_points, k_global):
    """Scatter votes for this device's blocks into a global vote table."""
    votes = jnp.zeros((n_points, k_global), jnp.float32)
    return votes.at[index_of_points.reshape(-1), point_global.reshape(-1)].add(1.0)


def lamc_step_fn(cfg: LAMCConfig, plan: partition.PartitionPlan,
                 mesh: Mesh, block_axes: Sequence[str],
                 resample_axis: str | None = None):
    """Build the jitted distributed-LAMC step for ``mesh``.

    ``block_axes``: mesh axis names the block dimension is sharded over
    (e.g. ``("data", "model")``). ``resample_axis``: optional extra mesh
    axis (the cross-pod one) that parallelizes the ``T_p`` resamples —
    the paper's resamples are embarrassingly parallel, so on a multi-pod
    mesh each pod runs its own subset of resamples instead of duplicating
    them (without this, every pod recomputes identical blocks and the
    signature gathers span 2x the devices for zero extra information —
    measured collective-bound in benchmarks/README.md §Perf iteration L3.1).
    Requires ``plan.t_p %% mesh.shape[resample_axis] == 0``.
    Returns ``(step, in_shardings, out_shardings)``.
    """
    n_dev = 1
    for ax in block_axes:
        n_dev *= mesh.shape[ax]
    b_total = plan.blocks_per_resample
    if b_total % n_dev != 0:
        raise ValueError(
            f"blocks per resample ({plan.m}x{plan.n}={b_total}) must be a "
            f"multiple of the device count {n_dev}; adjust the plan grid"
        )
    if resample_axis is not None and plan.t_p % mesh.shape[resample_axis] != 0:
        raise ValueError(
            f"T_p={plan.t_p} must be a multiple of the resample axis size "
            f"{mesh.shape[resample_axis]}")
    b_loc = b_total // n_dev
    axes = tuple(block_axes)
    # Effective per-axis signature widths: anchor_indices clamps the anchor
    # set to the axis length, so row signatures (means over anchor *cols*)
    # carry min(signature_dim, n_cols) features and col signatures
    # min(signature_dim, n_rows) — reshaping with the raw cfg.signature_dim
    # crashed on matrices with a short axis.
    q_row = min(cfg.signature_dim, plan.n_cols)
    q_col = min(cfg.signature_dim, plan.n_rows)

    block_spec = P(axes, None, None)     # blocks sharded over all mesh axes
    rep = P()                            # replicated

    def local_atom_phase(blocks, keys, row_feats, col_feats):
        """shard_map body, phase 2: blocks (b_loc, phi, psi) device-local.

        Pure local compute — small per-device SVD/QR/k-means, identical
        static shapes everywhere, zero communication.
        """
        row_labels, col_labels = jax.vmap(_atom_fn(cfg))(keys, blocks)
        row_sigs, row_counts = merging.atom_signatures(row_feats, row_labels, cfg.atom_k)
        col_sigs, col_counts = merging.atom_signatures(col_feats, col_labels, cfg.atom_d)
        return row_labels, col_labels, row_sigs, row_counts, col_sigs, col_counts

    atom_phase = shard_map(
        local_atom_phase,
        mesh=mesh,
        in_specs=(block_spec, P(axes), block_spec, block_spec),
        out_specs=(P(axes, None), P(axes, None), block_spec, P(axes, None),
                   block_spec, P(axes, None)),
        check_rep=False,
    )

    def local_atom_phase_tp(blocks, keys, row_feats, col_feats):
        """Like local_atom_phase but with a leading local-resample dim."""
        f = jax.vmap(local_atom_phase)
        return f(blocks, keys, row_feats, col_feats)

    ra = resample_axis
    tp_block = P(ra, axes, None, None)
    atom_phase_tp = shard_map(
        local_atom_phase_tp,
        mesh=mesh,
        in_specs=(tp_block, P(ra, axes), tp_block, tp_block),
        out_specs=(P(ra, axes, None), P(ra, axes, None), tp_block,
                   P(ra, axes, None), tp_block, P(ra, axes, None)),
        check_rep=False,
    ) if ra is not None else None

    def merge_phase(row_sigs, row_counts, row_labels, row_pos,
                    col_sigs, col_counts, col_labels, col_pos, merge_key):
        """shard_map body, phase 3: one joint merge over ALL resamples.

        Inputs are (T_p, b_loc, ...) device-local stacks. Only signatures
        (k x q floats per atom) cross the interconnect; the tiny consensus
        k-means runs replicated so no broadcast of its result is needed.
        """
        all_row_sigs, all_row_counts = row_sigs, row_counts
        all_col_sigs, all_col_counts = col_sigs, col_counts
        # log-tree per axis. Gather order matters: P(("data","model")) lays
        # blocks out data-major, and each tiled all_gather makes the gathered
        # axis *outermost* — so gather the innermost mesh axis first.
        for ax in reversed(axes):
            all_row_sigs = jax.lax.all_gather(all_row_sigs, ax, axis=1, tiled=True)
            all_row_counts = jax.lax.all_gather(all_row_counts, ax, axis=1, tiled=True)
            all_col_sigs = jax.lax.all_gather(all_col_sigs, ax, axis=1, tiled=True)
            all_col_counts = jax.lax.all_gather(all_col_counts, ax, axis=1, tiled=True)
        if resample_axis is not None:
            # resample dim sharded over the pod axis: gather it on axis 0
            all_row_sigs = jax.lax.all_gather(all_row_sigs, resample_axis,
                                              axis=0, tiled=True)
            all_row_counts = jax.lax.all_gather(all_row_counts, resample_axis,
                                                axis=0, tiled=True)
            all_col_sigs = jax.lax.all_gather(all_col_sigs, resample_axis,
                                              axis=0, tiled=True)
            all_col_counts = jax.lax.all_gather(all_col_counts, resample_axis,
                                                axis=0, tiled=True)

        kr, kc = jax.random.split(merge_key)
        # joint clustering across resamples AND blocks: one shared label
        # space, exactly like the single-host merge (label spaces from
        # different resamples must not be mixed unaligned).
        atom_global_r = merging.cluster_atoms_best(
            kr, all_row_sigs.reshape(-1, q_row), all_row_counts.reshape(-1),
            cfg.n_row_clusters, cfg.merge_kmeans_iters,
            n_restarts=cfg.merge_restarts,
        ).reshape(plan.t_p, b_total, cfg.atom_k)
        atom_global_c = merging.cluster_atoms_best(
            kc, all_col_sigs.reshape(-1, q_col), all_col_counts.reshape(-1),
            cfg.n_col_clusters, cfg.merge_kmeans_iters,
            n_restarts=cfg.merge_restarts,
        ).reshape(plan.t_p, b_total, cfg.atom_d)

        # this device's slice of the replicated global atom table
        dev_linear = jnp.int32(0)
        stride = 1
        for ax in reversed(axes):
            dev_linear = dev_linear + jax.lax.axis_index(ax) * stride
            stride = stride * mesh.shape[ax]
        my_atoms_r = jax.lax.dynamic_slice_in_dim(
            atom_global_r, dev_linear * b_loc, b_loc, axis=1)
        my_atoms_c = jax.lax.dynamic_slice_in_dim(
            atom_global_c, dev_linear * b_loc, b_loc, axis=1)
        if resample_axis is not None:
            t_loc = plan.t_p // mesh.shape[resample_axis]
            t_start = jax.lax.axis_index(resample_axis) * t_loc
            my_atoms_r = jax.lax.dynamic_slice_in_dim(
                my_atoms_r, t_start, t_loc, axis=0)
            my_atoms_c = jax.lax.dynamic_slice_in_dim(
                my_atoms_c, t_start, t_loc, axis=0)

        point_global_r = jnp.take_along_axis(my_atoms_r, row_labels, axis=2)
        point_global_c = jnp.take_along_axis(my_atoms_c, col_labels, axis=2)
        row_votes = _merge_votes_local(
            point_global_r, row_pos, plan.n_rows, cfg.n_row_clusters)
        col_votes = _merge_votes_local(
            point_global_c, col_pos, plan.n_cols, cfg.n_col_clusters)
        reduce_axes = axes + ((resample_axis,) if resample_axis else ())
        for ax in reduce_axes:
            row_votes = jax.lax.psum(row_votes, ax)
            col_votes = jax.lax.psum(col_votes, ax)
        return row_votes, col_votes

    # (T_p, blocks, ...) stacks: blocks sharded on axis 1; resample dim on
    # axis 0 sharded over the pod axis when resample parallelism is on.
    tdim = resample_axis  # None -> replicated t dim
    tblock = P(tdim, axes)
    merge = shard_map(
        merge_phase,
        mesh=mesh,
        in_specs=(P(tdim, axes, None, None), tblock, P(tdim, axes, None), tblock,
                  P(tdim, axes, None, None), tblock, P(tdim, axes, None), tblock,
                  rep),
        out_specs=(rep, rep),
        check_rep=False,
    )

    def step(a):
        kroot = jax.random.key(plan.seed + 7)
        kar, kac, kmerge = jax.random.split(kroot, 3)
        anchor_rows = merging.anchor_indices(kar, plan.n_rows, cfg.signature_dim)
        anchor_cols = merging.anchor_indices(kac, plan.n_cols, cfg.signature_dim)
        b = plan.blocks_per_resample
        i_of_b = jnp.arange(b) // plan.n
        j_of_b = jnp.arange(b) % plan.n
        extract_fn = (partition.extract_blocks_sparse
                      if cfg.input_format == "bcoo" else partition.extract_blocks)

        def extract(t):
            # phase 1: block scatter (GSPMD all-to-all, data moves once)
            blocks, row_idx, col_idx = extract_fn(a, plan, t)
            keys = jax.vmap(
                lambda i: jax.random.fold_in(
                    jax.random.fold_in(jax.random.key(plan.seed + 1), t), i)
            )(jnp.arange(b))
            # anchor slivers first ((M, q_row) / (q_col, N)) — indexing rows
            # first would materialize an (m, phi, N) intermediate (same
            # gather-order fix as extract_blocks).
            row_sliver, col_sliver = anchor_features(a, anchor_rows, anchor_cols)
            row_feats = row_sliver[row_idx][i_of_b]             # (B, phi, q_row)
            col_feats = jnp.transpose(
                col_sliver[:, col_idx], (1, 2, 0))[j_of_b]      # (B, psi, q_col)
            return blocks, keys, row_feats, col_feats, row_idx[i_of_b], col_idx[j_of_b]

        if resample_axis is None:
            # resamples run sequentially (lax.scan) — single-pod path
            def body(_, t):
                blocks, keys, row_feats, col_feats, row_pos, col_pos = extract(t)
                blocks = jax.lax.with_sharding_constraint(
                    blocks, NamedSharding(mesh, block_spec))
                rl, cl, rs, rc, cs, cc = atom_phase(blocks, keys, row_feats,
                                                    col_feats)
                return None, dict(
                    row_labels=rl, col_labels=cl,
                    row_sigs=rs, row_counts=rc, col_sigs=cs, col_counts=cc,
                    row_pos=row_pos, col_pos=col_pos,
                )

            _, stk = jax.lax.scan(body, None, jnp.arange(plan.t_p))
        else:
            # resamples parallel over the pod axis: (T_p, B, ...) sharded
            # (pod, (data, model), ...) — one block-task per device, no
            # duplicated work across pods.
            ext = jax.vmap(extract)(jnp.arange(plan.t_p))
            blocks_t = jax.lax.with_sharding_constraint(
                ext[0], NamedSharding(mesh, P(resample_axis, axes, None, None)))
            rl, cl, rs, rc, cs, cc = atom_phase_tp(
                blocks_t, ext[1], ext[2], ext[3])
            stk = dict(row_labels=rl, col_labels=cl, row_sigs=rs,
                       row_counts=rc, col_sigs=cs, col_counts=cc,
                       row_pos=ext[4], col_pos=ext[5])

        # phase 3: one hierarchical merge across all resamples
        row_votes, col_votes = merge(
            stk["row_sigs"], stk["row_counts"], stk["row_labels"], stk["row_pos"],
            stk["col_sigs"], stk["col_counts"], stk["col_labels"], stk["col_pos"],
            kmerge,
        )
        # assignment semantics shared with the single-host merge: the psum'd
        # vote tables are bit-identical to the single-host scatter (small
        # integer counts in f32, exact under any summation order), so the
        # labels AND the overlap memberships match bit-for-bit at equal
        # seeds (DESIGN.md §11).
        row_labels, row_member = merging.finalize_assignment(
            row_votes, cfg.assignment, cfg.overlap_threshold,
            cfg.min_membership)
        col_labels, col_member = merging.finalize_assignment(
            col_votes, cfg.assignment, cfg.overlap_threshold,
            cfg.min_membership)
        # serving signatures: cluster means over the anchor slivers under the
        # final consensus labels — tiny (K x q), replicated; GSPMD emits the
        # gathers for the sliver reads of the sharded matrix.
        row_sliver, col_sliver = anchor_features(a, anchor_rows, anchor_cols)
        row_sigs, row_mean, _ = merging.cluster_signatures(
            row_sliver, row_labels, cfg.n_row_clusters)
        col_sigs, col_mean, _ = merging.cluster_signatures(
            col_sliver.T, col_labels, cfg.n_col_clusters)
        return dict(
            row_labels=row_labels,
            col_labels=col_labels,
            row_votes=row_votes,
            col_votes=col_votes,
            row_sigs=row_sigs, col_sigs=col_sigs,
            row_mean=row_mean, col_mean=col_mean,
            anchor_rows=anchor_rows, anchor_cols=anchor_cols,
            row_membership=row_member, col_membership=col_member,
        )

    # data matrix sharded over the first two trailing mesh axes (row, col);
    # a BCOO input replicates — its (nse,)/(nse, 2) leaves have no grid
    # layout, and the O(nnz) block scatter is re-derived per device.
    if cfg.input_format == "bcoo":
        a_spec = P()
    elif len(block_axes) >= 2:
        a_axes = list(block_axes)
        a_spec = P(tuple(a_axes[:-1]), a_axes[-1])
    else:
        a_spec = P(block_axes[0], None)
    in_shardings = NamedSharding(mesh, a_spec)
    out_shardings = NamedSharding(mesh, P())
    return step, in_shardings, out_shardings


def lamc_input_specs(plan: partition.PartitionPlan, dtype=jnp.float32):
    """ShapeDtypeStruct stand-in for the data matrix (dry-run input)."""
    return jax.ShapeDtypeStruct((plan.n_rows, plan.n_cols), dtype)


def distributed_lamc(mesh: Mesh, a: jax.Array, cfg: LAMCConfig,
                     plan: partition.PartitionPlan,
                     block_axes: Sequence[str] = ("data", "model"),
                     resample_axis: str | None = None) -> LAMCResult:
    """Run distributed LAMC on ``mesh``. See module docstring."""
    _validate_input_format(a, cfg)
    with obs.span("distributed_lamc", devices=mesh.size,
                  mesh=str(dict(mesh.shape)),
                  block_axes="/".join(block_axes),
                  resample_axis=resample_axis or "",
                  m=plan.m, n=plan.n, phi=plan.phi, psi=plan.psi,
                  t_p=plan.t_p, spmm_route=plan.spmm_route):
        with obs.span("build_step"):
            step, in_sh, out_sh = lamc_step_fn(cfg, plan, mesh, block_axes,
                                               resample_axis=resample_axis)
            step_c = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        # All three distributed phases (scatter -> atoms -> merge) are one
        # XLA program; one fenced span covers the lot (DESIGN.md §14).
        with obs.span("pipeline",
                      phases="scatter->atom->merge") as ps:
            with mesh:
                out = ps.fence(step_c(a))
        with obs.span("finalize") as fs:
            return fs.fence(LAMCResult(
                out["row_labels"], out["col_labels"],
                out["row_votes"], out["col_votes"], plan,
                row_sigs=out["row_sigs"], col_sigs=out["col_sigs"],
                row_mean=out["row_mean"], col_mean=out["col_mean"],
                anchor_rows=out["anchor_rows"],
                anchor_cols=out["anchor_cols"],
                row_membership=out["row_membership"],
                col_membership=out["col_membership"]))
