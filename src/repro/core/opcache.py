"""Pattern-keyed sparse-operator conversion cache (DESIGN.md §9).

The resample loop (``lamc_cocluster``/``scc``) and the streaming
re-chunk path repeatedly prepare operators whose *sparsity pattern* is
stable while values change (normalization rescales data in place;
resamples reuse the same matrix outright). Conversion cost splits the
same way — the pattern half (tile discovery, visit order, scatter
offsets: ``kernels.spmm.block_sparse_plan`` / ``sparse.ell_plan``) is
the expensive part; the values half is one flat scatter. This cache
keys converted operators by ``(indices fingerprint, shape, tile config,
values dtype)`` so:

  * same indices object + same data object  -> **hit**: the cached
    operator is returned as-is (zero work);
  * same pattern, new values               -> **refresh**: the cached
    plan re-applies in one scatter, no tile discovery;
  * anything else                          -> **miss**: full conversion,
    result cached.

Fingerprinting hashes the raw index bytes (blake2b), which costs real
milliseconds at bench nnz — so fingerprints are memoized by the index
array's object identity (strong refs pin the ids against reuse), and the
hot hit path never hashes at all. The dtype of the values participates
in the key so a pattern warmed at one dtype can never serve another; the
tile config (``bm``/``bk`` or the ELL tag) likewise.

Counters (``repro.obs``): ``tiled_conv_cache{event=hit|miss|refresh}``.
Disable with ``REPRO_TILED_CACHE=0`` (every lookup degrades to a miss
that bypasses storage — conversion semantics are identical either way,
which is also the tested invariant).

Thread safety: the serving path scores requests on worker threads while
a background fit/swap converts operators through the same process-wide
:func:`default_cache`. All mutable state (LRU order, entry table, the
fingerprint memo) is guarded by one re-entrant lock, metric-creation
style (cf. ``obs/metrics.py``): lookups/installs hold it, the conversion
work itself (``plan_fn``/``apply_fn``, the expensive part) runs outside
it, so two threads may both convert on a cold miss — last-install-wins,
which is correct because conversion is deterministic in ``(pattern,
values, config)``.
"""

from __future__ import annotations

import hashlib
import os
import threading
from collections import OrderedDict
from typing import Any, Callable, NamedTuple

import numpy as np

from repro import obs as _obs

__all__ = ["PatternCache", "cache_enabled", "default_cache"]


def cache_enabled() -> bool:
    return os.environ.get("REPRO_TILED_CACHE", "1") != "0"


class _Entry(NamedTuple):
    plan: Any        # reusable pattern half (BlockSparsePlan / EllPlan)
    operator: Any    # the converted operator built from (plan, data_obj)
    data_obj: Any    # strong ref: identity check for the zero-work hit


class PatternCache:
    """Bounded LRU of converted sparse operators, keyed by pattern.

    One process-wide instance (:func:`default_cache`) backs
    ``core.sparse.prepare_operator``; tests construct their own. Entries
    hold the full converted operator (block stacks are the dominant
    footprint), so ``capacity`` stays small — the real workloads touch
    one or two distinct patterns at a time.
    """

    def __init__(self, capacity: int = 4, counter: str = "tiled_conv_cache"):
        self.capacity = capacity
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        # id(indices) -> (indices strong ref, digest). The ref pins the
        # id: without it a collected array's id could be reused by a new
        # array and serve a stale digest.
        self._fp_memo: dict[int, tuple[Any, bytes]] = {}
        # One lock over entries + memo: concurrent serving threads and a
        # background fit interleave convert()/clear() freely. RLock so a
        # plan_fn that re-enters the cache (nested prepare) cannot
        # deadlock. Conversion work runs outside the lock.
        self._lock = threading.RLock()
        self._counter = counter
        self.hits = 0
        self.misses = 0
        self.refreshes = 0

    def _count(self, event: str) -> None:
        _obs.get_registry().counter(
            self._counter,
            help="pattern-keyed sparse conversion cache events",
        ).labels(event=event).inc()

    def _fingerprint(self, indices) -> bytes:
        with self._lock:
            memo = self._fp_memo.get(id(indices))
            if memo is not None and memo[0] is indices:
                return memo[1]
        # hash outside the lock (milliseconds at bench nnz); a racing
        # thread hashing the same indices lands on the same digest
        digest = hashlib.blake2b(
            np.ascontiguousarray(np.asarray(indices)).tobytes(),
            digest_size=16).digest()
        with self._lock:
            if len(self._fp_memo) >= 4 * max(self.capacity, 1):
                self._fp_memo.clear()
            self._fp_memo[id(indices)] = (indices, digest)
        return digest

    def convert(self, a, config: tuple, plan_fn: Callable[[Any], Any],
                apply_fn: Callable[[Any, Any], Any]):
        """Convert BCOO ``a`` under ``config``, reusing cached pattern work.

        ``plan_fn(a)`` builds the pattern plan + operator on a miss (it
        returns ``(plan, operator)``); ``apply_fn(plan, data)`` rebuilds
        an operator from a cached plan and fresh values. ``config`` is
        the static part of the key — tile shape or format tag; the values
        dtype is appended here so cross-dtype reuse is structurally
        impossible.
        """
        if not cache_enabled():
            plan, op = plan_fn(a)
            return op
        key = (self._fingerprint(a.indices), tuple(a.shape), *config,
               np.dtype(a.data.dtype).str)
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                if entry.data_obj is a.data:
                    self.hits += 1
                    hit_op = entry.operator
                    entry = None          # resolved: zero-work hit
                else:
                    hit_op = None         # resolved: values refresh
            else:
                hit_op = None             # resolved: full miss
        if hit_op is not None:
            self._count("hit")
            return hit_op
        if entry is not None:
            # same pattern, new values: one scatter through the old plan.
            # Runs outside the lock — a concurrent refresh of the same
            # key does the same deterministic work; last install wins.
            op = apply_fn(entry.plan, a.data)
            with self._lock:
                self._entries[key] = _Entry(entry.plan, op, a.data)
                self._entries.move_to_end(key)
                self.refreshes += 1
            self._count("refresh")
            return op
        plan, op = plan_fn(a)
        with self._lock:
            self._entries[key] = _Entry(plan, op, a.data)
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
            self.misses += 1
        self._count("miss")
        return op

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._fp_memo.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)


_DEFAULT: PatternCache | None = None
_DEFAULT_LOCK = threading.Lock()


def default_cache() -> PatternCache:
    global _DEFAULT
    if _DEFAULT is None:
        with _DEFAULT_LOCK:
            if _DEFAULT is None:
                _DEFAULT = PatternCache()
    return _DEFAULT
