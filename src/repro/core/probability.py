"""Probabilistic partition model — Theorem 1 / Eqs. (1)-(4) of the LAMC paper.

The model bounds the probability of *failing* to detect a co-cluster ``C_k``
(of size ``M_k x N_k`` inside an ``M x N`` matrix) when the matrix is
partitioned into an ``m x n`` grid of uniform ``phi x psi`` blocks, and the
atom co-clusterer needs at least ``T_m`` rows and ``T_n`` columns of the
co-cluster to land inside one block.

All formulas follow the paper's Appendix:

    s(k) = M_k / M - (T_m - 1) / phi              (Eq. 16)
    t(k) = N_k / N - (T_n - 1) / psi
    P(omega_k) <= exp{-2 [phi m s^2 + psi n t^2]} (Eq. 17 / Thm. 1)
    P_detect  >= 1 - P(omega_k)^{T_p}             (Eq. 18 / Eq. 3)

and Eq. (4) is solved in closed form for the minimal number of resamples
``T_p`` achieving a target success probability.

Everything here is plain float math (host side): these quantities drive the
*plan*, not the on-device compute, and are consumed before any jit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = [
    "margin_terms",
    "failure_exponent",
    "failure_bound",
    "detection_probability",
    "min_resamples",
    "PartitionSpec1D",
    "PlanCandidate",
    "plan_partition",
    "mc_failure_estimate",
    "resamples_for_failures",
    "sample_block_failures",
    "spmm_costs",
    "spmm_route",
    "resolve_spmm_route",
    "SPMM_GATHER_REL",
    "SPMM_TILED_OVERHEAD",
    "SPMM_ELL_CROSSOVER",
]


def margin_terms(
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    phi: int,
    psi: int,
    t_m: int,
    t_n: int,
) -> tuple[float, float]:
    """``(s, t)`` margins of Eq. (16).

    ``s`` (resp. ``t``) is the gap between the co-cluster's row (col) density
    and the fraction of a block the atom method needs to see. Non-positive
    margins mean Theorem 1 gives a vacuous bound (block too small for the
    co-cluster to be reliably caught).
    """
    s = cocluster_rows / n_rows - (t_m - 1) / phi
    t = cocluster_cols / n_cols - (t_n - 1) / psi
    return s, t


def failure_exponent(
    s: float, t: float, phi: int, psi: int, m: int, n: int
) -> float:
    """Exponent ``2[phi m s^2 + psi n t^2]`` of Theorem 1 (clamped at 0)."""
    if s <= 0.0 or t <= 0.0:
        return 0.0
    return 2.0 * (phi * m * s * s + psi * n * t * t)


def failure_bound(
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
) -> float:
    """Upper bound on ``P(omega_k)`` — one resample failing to expose C_k.

    Uses uniform blocks ``phi = M/m``, ``psi = N/n`` (paper's final form).
    """
    phi = max(1, n_rows // m)
    psi = max(1, n_cols // n)
    s, t = margin_terms(cocluster_rows, cocluster_cols, n_rows, n_cols, phi, psi, t_m, t_n)
    return math.exp(-failure_exponent(s, t, phi, psi, m, n))


def detection_probability(
    t_p: int,
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
) -> float:
    """Lower bound on detection probability after ``T_p`` resamples (Eq. 3)."""
    fail = failure_bound(cocluster_rows, cocluster_cols, n_rows, n_cols, m, n, t_m, t_n)
    return 1.0 - fail**t_p


def min_resamples(
    p_thresh: float,
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
    max_resamples: int = 4096,
) -> int:
    """Closed-form solution of Eq. (4):

    ``T_p = ceil( ln(1 - P_thresh) / ln(P(omega_k)) )``

    Returns ``max_resamples`` when the Theorem-1 bound is vacuous (margin
    <= 0) — the caller should then grow the block sizes instead.
    """
    if not 0.0 < p_thresh < 1.0:
        raise ValueError(f"p_thresh must be in (0,1), got {p_thresh}")
    fail = failure_bound(cocluster_rows, cocluster_cols, n_rows, n_cols, m, n, t_m, t_n)
    if fail >= 1.0:  # vacuous bound
        return max_resamples
    if fail <= 0.0:
        return 1
    t_p = math.ceil(math.log(1.0 - p_thresh) / math.log(fail))
    return int(min(max(t_p, 1), max_resamples))


def resamples_for_failures(
    base_t_p: int,
    n_blocks: int,
    expected_failed_blocks: int,
) -> int:
    """Fault-tolerance margin: bump ``T_p`` so that losing
    ``expected_failed_blocks`` of ``n_blocks`` per resample keeps the same
    detection exponent.

    Losing a fraction ``f`` of blocks scales the Theorem-1 exponent by
    ``(1 - f)`` (fewer independent block trials), so the exponent is restored
    by ``T_p' = T_p / (1 - f)``. This is the paper's over-sampling knob
    repurposed as a resilience budget (DESIGN.md §3).
    """
    if expected_failed_blocks <= 0:
        return base_t_p
    f = min(expected_failed_blocks / max(n_blocks, 1), 0.9)
    return int(math.ceil(base_t_p / (1.0 - f)))


def sample_block_failures(
    seed: int,
    t_p: int,
    n_blocks: int,
    n_failed: int,
) -> np.ndarray:
    """``(t_p, n_blocks)`` bool *survival* mask with exactly ``n_failed``
    blocks down (False) in each resample, drawn uniformly without
    replacement.

    The simulation half of :func:`resamples_for_failures`: feed the mask
    to ``lamc_cocluster(..., block_mask=...)`` and the dropped blocks'
    atoms contribute nothing to the merge — exactly what a died-mid-atom
    worker looks like to the consensus. The differential test
    (tests/test_fault_tolerance.py) pairs the two to check the paper's
    T_p fault-budget claim against real injected failures.
    """
    if not 0 <= n_failed <= n_blocks:
        raise ValueError(
            f"n_failed must be in [0, {n_blocks}], got {n_failed}")
    rng = np.random.default_rng(seed)
    mask = np.ones((t_p, n_blocks), dtype=bool)
    for i in range(t_p):
        mask[i, rng.choice(n_blocks, size=n_failed, replace=False)] = False
    return mask


@dataclasses.dataclass(frozen=True)
class PartitionSpec1D:
    """Uniform split of one axis: ``count`` groups of size ``size``."""

    count: int
    size: int


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One evaluated (m, n, T_p) configuration with its cost estimate."""

    m: int
    n: int
    phi: int
    psi: int
    t_p: int
    detection_p: float
    est_cost: float  # arbitrary units: block-work x blocks / workers
    # SpMM backend the cost model priced this candidate's blocks with
    # ("dense" | "dual_ell" | "tiled") — surfaced so callers/tests can
    # assert the density-adaptive dispatch decision.
    spmm_route: str = "dense"


# --------------------------------------------------------------------------
# SpMM backend cost model (DESIGN.md §9 routing policy)
#
# Calibrated against BENCH_sparse.json micro-benches (4096x2048, r=9, CPU):
# a dual-ELL gather product costs ~16 ns per stored nonzero while a tiled /
# dense tile-GEMM product costs ~1 ns per (occupied-tile) cell — per-element
# gathers pay the scatter/gather unit, batched tile contractions pay the
# BLAS/MXU unit. The ratio is the calibration constant below; the measured
# atom-phase crossover (dual-ELL wins at d = 0.05, loses by d = 0.2)
# brackets the derived parity point SPMM_ELL_CROSSOVER ~= 0.072.
# --------------------------------------------------------------------------

#: Relative cost of one gathered nonzero vs one contiguously-contracted
#: tile cell (measured: dual-ELL products ~16 ns/nnz vs tile GEMMs ~1
#: ns/cell on the bench machine; TPU scatter units are no cheaper).
SPMM_GATHER_REL = 16.0

#: Tile-format overhead vs one ideal dense cell at full occupancy (the
#: tile segment-sum + payload indirection).
SPMM_TILED_OVERHEAD = 0.15

#: Dense-operand overhead per cell for the *two-sided* subspace
#: iteration: the ``A.T @ Q`` products materialize a transposed copy of
#: the operand, which the tiled format's per-tile transpose contraction
#: avoids (measured atom ratio dense/tiled ~1.3 at d = 0.2).
SPMM_DENSE_REL = 1.3

#: Density above which the dual-ELL gather path loses to tile GEMMs —
#: derived from the cost-parity condition of the two models
#: (SPMM_GATHER_REL * d = 1 + SPMM_TILED_OVERHEAD at full occupancy,
#: ~= 0.072), so retuning either constant moves the published crossover
#: with the actual ``spmm_route`` decision. Sits inside the measured
#: (0.05, 0.2) win/loss bracket from BENCH_sparse.json.
SPMM_ELL_CROSSOVER = (1.0 + SPMM_TILED_OVERHEAD) / SPMM_GATHER_REL

#: Below this cell count a block is too small for any sparse format to
#: pay back its prep; route dense.
_SPMM_MIN_SPARSE_CELLS = 64 * 64


def _tile_occupancy(density: float, tile_cells: int) -> float:
    """Expected fraction of tiles holding >= 1 nonzero (uniform sparsity)."""
    d = max(min(density, 1.0), 0.0)
    return 1.0 - (1.0 - d) ** tile_cells


def spmm_costs(density: float, cells: float,
               tile_cells: int = 128 * 128) -> dict:
    """Per-product cost of each SpMM backend, in dense-cell units.

    ``cells`` is the block area ``phi * psi``; one unit is one cell of a
    dense matmul pass. Host-side plain float math like the rest of the
    plan model.
    """
    d = max(min(density, 1.0), 0.0)
    occ = _tile_occupancy(d, tile_cells)
    return {
        "dual_ell": SPMM_GATHER_REL * d * cells,
        "tiled": (1.0 + SPMM_TILED_OVERHEAD) * occ * cells,
        "dense": SPMM_DENSE_REL * cells,
    }


def resolve_spmm_route(spmm_impl: str, density: float, cells: float, *,
                       single: bool = True,
                       svd_method: str = "randomized") -> str:
    """The one routing decision tree — used by the plan search for both
    pricing and surfacing, and by the drivers for execution, so the three
    can never drift.

    ``single``: whether the candidate can actually run the sparse
    operator (a single SCC block covering the whole matrix); everything
    else densifies its blocks and is ``dense`` whatever the knob says,
    as are exact-SVD atoms and (near-)dense inputs.
    """
    if not single or svd_method == "exact" or density >= 1.0:
        return "dense"
    if spmm_impl == "auto":
        return spmm_route(density, cells)
    return spmm_impl


def spmm_route(density: float, cells: float = 4096 * 2048,
               tile_cells: int = 128 * 128) -> str:
    """Density-adaptive SpMM backend: ``dual_ell`` | ``tiled`` | ``dense``.

    Picks the cheapest backend under ``spmm_costs``; sub-``64x64`` blocks
    and (near-)dense matrices route ``dense`` outright — no sparse format
    pays back its host prep there. This is the ``spmm_impl="auto"``
    resolution rule used by ``lamc_cocluster`` and surfaced on
    ``PartitionPlan.spmm_route``, and it removes the measured d = 0.2
    regression by construction: past the dual-ELL crossover the route is
    a tile/dense contraction, never a per-nonzero gather.
    """
    if cells < _SPMM_MIN_SPARSE_CELLS or density >= 0.9:
        return "dense"
    costs = spmm_costs(density, cells, tile_cells)
    return min(costs, key=costs.get)


def _atom_cost(phi: int, psi: int, rank: int, svd_iters: int, kmeans_iters: int,
               k: int, svd_method: str = "randomized",
               density: float = 1.0, spmm_impl: str = "auto") -> float:
    """Napkin cost of spectral co-clustering one ``phi x psi`` block.

    ``randomized``: ``svd_iters`` passes of ``A @ Omega``-style matmuls
    (2*phi*psi*rank each) + k-means over phi+psi points in rank dims —
    linear in the block area, so partitioning pays off only via workers.
    ``exact``: LAPACK-style O(phi*psi*min(phi,psi)) — superlinear, so
    partitioning wins even serially (the paper's dense-matrix regime).

    ``density < 1`` prices the sparse path through the calibrated SpMM
    backend model (``spmm_costs``): ``spmm_impl`` fixes the backend, or
    ``"auto"`` takes the cheapest (= ``spmm_route``'s pick). Gather
    backends scale with nnz, tile backends with occupied tiles — this
    keeps the paper's dense-vs-sparse speedup asymmetry (~83% vs ~30%):
    on sparse data the atom phase is already nnz-/occupancy-bound, so
    partitioning has less superlinear cost to shave and the planner
    correctly expects a smaller win. ``exact`` ignores density — LAPACK
    SVD cannot exploit sparsity.
    """
    if svd_method == "exact":
        svd = float(phi) * psi * min(phi, psi)
    else:
        cells = float(phi) * psi
        d = max(min(density, 1.0), 1e-6)
        # "auto" prices the backend spmm_route actually picks — including
        # its small-block / near-dense guards — so est_cost and the
        # surfaced route always describe the same backend.
        impl = spmm_route(d, cells) if spmm_impl == "auto" else spmm_impl
        unit = spmm_costs(d, cells)[impl]
        svd = 4.0 * svd_iters * unit * rank
    km = 2.0 * kmeans_iters * (phi + psi) * rank * k
    return svd + km


def plan_partition(
    n_rows: int,
    n_cols: int,
    *,
    min_cocluster_rows: int,
    min_cocluster_cols: int,
    t_m: int = 2,
    t_n: int = 2,
    p_thresh: float = 0.95,
    workers: int = 1,
    rank: int = 8,
    svd_iters: int = 4,
    kmeans_iters: int = 16,
    k: int = 8,
    grid_candidates: Sequence[int] = (1, 2, 4, 8, 16, 32),
    max_resamples: int = 4096,
    expected_failed_blocks: int = 0,
    svd_method: str = "randomized",
    density: float = 1.0,
    spmm_impl: str = "auto",
    min_phi: int | None = None,
    min_psi: int | None = None,
) -> PlanCandidate:
    """Pick the (m, n, T_p) minimizing estimated wall-cost subject to
    ``P_detect >= p_thresh`` (paper §IV-B.2, Eq. 4).

    ``min_cocluster_{rows,cols}`` is the smallest co-cluster the caller
    still wants to detect — the adversarial ``C_k`` of Theorem 1.
    ``workers`` is the number of parallel processing units (devices); cost
    is total block work divided by workers, in waves of ``m*n`` blocks.
    ``density`` is the input's nnz fraction (1.0 = dense); it rescales the
    SVD term of the atom cost so sparse inputs are planned against their
    SpMM cost (see ``_atom_cost``). ``spmm_impl`` fixes the SpMM backend
    the blocks are priced with (``"auto"`` = cheapest per the calibrated
    model); the per-block route is surfaced on the returned candidate.

    Besides the Theorem-1 feasibility check, candidates must satisfy atom
    *resolvability*: a block needs at least ``min_phi x min_psi`` entries
    (default ``8k x 8k``) to host ``k`` separable clusters — degenerate
    sliver blocks pass the detection bound but starve the atom method of
    context, so they are pruned here.
    """
    if min_phi is None:
        min_phi = max(32, 8 * k)
    if min_psi is None:
        min_psi = max(32, 8 * k)
    best: PlanCandidate | None = None
    for m in grid_candidates:
        if m > n_rows:
            continue
        for n in grid_candidates:
            if n > n_cols:
                continue
            phi = max(1, n_rows // m)
            psi = max(1, n_cols // n)
            if (m, n) != (1, 1) and (phi < min_phi or psi < min_psi):
                continue
            # aspect cap: sliver blocks (m >> n or n >> m) minimize the
            # exact-SVD cost model but starve the atom method; bound the
            # grid anisotropy to 4x.
            if max(m, n) > 4 * min(m, n) and (m, n) != (1, 1):
                continue
            t_p = min_resamples(
                p_thresh,
                min_cocluster_rows,
                min_cocluster_cols,
                n_rows,
                n_cols,
                m,
                n,
                t_m,
                t_n,
                max_resamples=max_resamples,
            )
            t_p = resamples_for_failures(t_p, m * n, expected_failed_blocks)
            p = detection_probability(
                t_p, min_cocluster_rows, min_cocluster_cols,
                n_rows, n_cols, m, n, t_m, t_n,
            )
            if p < p_thresh and (m, n) != (1, 1):
                continue  # infeasible under the bound; (1,1) always "detects"
            blocks = m * n * t_p
            waves = math.ceil(blocks / max(workers, 1))
            # Only a single-block candidate can execute the sparse-operator
            # route (the driver enables it when blocks_per_resample == 1);
            # multi-block candidates densify their phi x psi blocks. One
            # resolver produces the route, and the cost is priced with
            # that same route, so est_cost and spmm_route always describe
            # the same backend.
            route = resolve_spmm_route(
                spmm_impl, density, float(phi) * psi,
                single=(m, n) == (1, 1), svd_method=svd_method)
            cost = waves * _atom_cost(phi, psi, rank, svd_iters, kmeans_iters, k,
                                      svd_method=svd_method, density=density,
                                      spmm_impl=route)
            cand = PlanCandidate(m=m, n=n, phi=phi, psi=psi, t_p=t_p,
                                 detection_p=p, est_cost=cost,
                                 spmm_route=route)
            if best is None or cand.est_cost < best.est_cost:
                best = cand
    assert best is not None, "grid_candidates produced no feasible plan"
    return best


def mc_failure_estimate(
    rng: np.random.Generator,
    cocluster_rows: int,
    cocluster_cols: int,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
    trials: int = 2000,
) -> float:
    """Monte-Carlo estimate of the true P(omega_k) for validating Theorem 1.

    Samples random row/col permutations, splits into uniform blocks, and
    checks whether *no* block receives >= T_m co-cluster rows and >= T_n
    co-cluster cols. Used by tests to confirm the analytic bound dominates.
    """
    phi = n_rows // m
    psi = n_cols // n
    failures = 0
    for _ in range(trials):
        row_hits = rng.permutation(n_rows)[: m * phi].reshape(m, phi) < cocluster_rows
        col_hits = rng.permutation(n_cols)[: n * psi].reshape(n, psi) < cocluster_cols
        rows_per_block = row_hits.sum(axis=1)  # (m,)
        cols_per_block = col_hits.sum(axis=1)  # (n,)
        detected = (rows_per_block[:, None] >= t_m) & (cols_per_block[None, :] >= t_n)
        if not detected.any():
            failures += 1
    return failures / trials
