"""Probabilistic partition model — Theorem 1 / Eqs. (1)-(4) of the LAMC paper.

The model bounds the probability of *failing* to detect a co-cluster ``C_k``
(of size ``M_k x N_k`` inside an ``M x N`` matrix) when the matrix is
partitioned into an ``m x n`` grid of uniform ``phi x psi`` blocks, and the
atom co-clusterer needs at least ``T_m`` rows and ``T_n`` columns of the
co-cluster to land inside one block.

All formulas follow the paper's Appendix:

    s(k) = M_k / M - (T_m - 1) / phi              (Eq. 16)
    t(k) = N_k / N - (T_n - 1) / psi
    P(omega_k) <= exp{-2 [phi m s^2 + psi n t^2]} (Eq. 17 / Thm. 1)
    P_detect  >= 1 - P(omega_k)^{T_p}             (Eq. 18 / Eq. 3)

and Eq. (4) is solved in closed form for the minimal number of resamples
``T_p`` achieving a target success probability.

Everything here is plain float math (host side): these quantities drive the
*plan*, not the on-device compute, and are consumed before any jit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

import numpy as np

__all__ = [
    "margin_terms",
    "failure_exponent",
    "failure_bound",
    "detection_probability",
    "min_resamples",
    "PartitionSpec1D",
    "PlanCandidate",
    "plan_partition",
    "mc_failure_estimate",
    "resamples_for_failures",
]


def margin_terms(
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    phi: int,
    psi: int,
    t_m: int,
    t_n: int,
) -> tuple[float, float]:
    """``(s, t)`` margins of Eq. (16).

    ``s`` (resp. ``t``) is the gap between the co-cluster's row (col) density
    and the fraction of a block the atom method needs to see. Non-positive
    margins mean Theorem 1 gives a vacuous bound (block too small for the
    co-cluster to be reliably caught).
    """
    s = cocluster_rows / n_rows - (t_m - 1) / phi
    t = cocluster_cols / n_cols - (t_n - 1) / psi
    return s, t


def failure_exponent(
    s: float, t: float, phi: int, psi: int, m: int, n: int
) -> float:
    """Exponent ``2[phi m s^2 + psi n t^2]`` of Theorem 1 (clamped at 0)."""
    if s <= 0.0 or t <= 0.0:
        return 0.0
    return 2.0 * (phi * m * s * s + psi * n * t * t)


def failure_bound(
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
) -> float:
    """Upper bound on ``P(omega_k)`` — one resample failing to expose C_k.

    Uses uniform blocks ``phi = M/m``, ``psi = N/n`` (paper's final form).
    """
    phi = max(1, n_rows // m)
    psi = max(1, n_cols // n)
    s, t = margin_terms(cocluster_rows, cocluster_cols, n_rows, n_cols, phi, psi, t_m, t_n)
    return math.exp(-failure_exponent(s, t, phi, psi, m, n))


def detection_probability(
    t_p: int,
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
) -> float:
    """Lower bound on detection probability after ``T_p`` resamples (Eq. 3)."""
    fail = failure_bound(cocluster_rows, cocluster_cols, n_rows, n_cols, m, n, t_m, t_n)
    return 1.0 - fail**t_p


def min_resamples(
    p_thresh: float,
    cocluster_rows: float,
    cocluster_cols: float,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
    max_resamples: int = 4096,
) -> int:
    """Closed-form solution of Eq. (4):

    ``T_p = ceil( ln(1 - P_thresh) / ln(P(omega_k)) )``

    Returns ``max_resamples`` when the Theorem-1 bound is vacuous (margin
    <= 0) — the caller should then grow the block sizes instead.
    """
    if not 0.0 < p_thresh < 1.0:
        raise ValueError(f"p_thresh must be in (0,1), got {p_thresh}")
    fail = failure_bound(cocluster_rows, cocluster_cols, n_rows, n_cols, m, n, t_m, t_n)
    if fail >= 1.0:  # vacuous bound
        return max_resamples
    if fail <= 0.0:
        return 1
    t_p = math.ceil(math.log(1.0 - p_thresh) / math.log(fail))
    return int(min(max(t_p, 1), max_resamples))


def resamples_for_failures(
    base_t_p: int,
    n_blocks: int,
    expected_failed_blocks: int,
) -> int:
    """Fault-tolerance margin: bump ``T_p`` so that losing
    ``expected_failed_blocks`` of ``n_blocks`` per resample keeps the same
    detection exponent.

    Losing a fraction ``f`` of blocks scales the Theorem-1 exponent by
    ``(1 - f)`` (fewer independent block trials), so the exponent is restored
    by ``T_p' = T_p / (1 - f)``. This is the paper's over-sampling knob
    repurposed as a resilience budget (DESIGN.md §3).
    """
    if expected_failed_blocks <= 0:
        return base_t_p
    f = min(expected_failed_blocks / max(n_blocks, 1), 0.9)
    return int(math.ceil(base_t_p / (1.0 - f)))


@dataclasses.dataclass(frozen=True)
class PartitionSpec1D:
    """Uniform split of one axis: ``count`` groups of size ``size``."""

    count: int
    size: int


@dataclasses.dataclass(frozen=True)
class PlanCandidate:
    """One evaluated (m, n, T_p) configuration with its cost estimate."""

    m: int
    n: int
    phi: int
    psi: int
    t_p: int
    detection_p: float
    est_cost: float  # arbitrary units: block-work x blocks / workers


def _atom_cost(phi: int, psi: int, rank: int, svd_iters: int, kmeans_iters: int,
               k: int, svd_method: str = "randomized",
               density: float = 1.0) -> float:
    """Napkin cost of spectral co-clustering one ``phi x psi`` block.

    ``randomized``: ``svd_iters`` passes of ``A @ Omega``-style matmuls
    (2*phi*psi*rank each) + k-means over phi+psi points in rank dims —
    linear in the block area, so partitioning pays off only via workers.
    ``exact``: LAPACK-style O(phi*psi*min(phi,psi)) — superlinear, so
    partitioning wins even serially (the paper's dense-matrix regime).

    ``density < 1`` models the sparse path: the SpMM subspace iteration
    touches only the block's expected ``density * phi * psi`` nonzeros,
    so the SVD term scales with nnz while the k-means term (dense
    spectral embedding) does not. This is the source of the paper's
    dense-vs-sparse speedup asymmetry (~83% vs ~30%): on sparse data the
    atom phase is already nnz-bound, so partitioning has less superlinear
    (or even linear-constant) cost to shave and the planner correctly
    expects a smaller win. ``exact`` ignores density — LAPACK SVD cannot
    exploit sparsity.
    """
    if svd_method == "exact":
        svd = float(phi) * psi * min(phi, psi)
    else:
        nnz = max(min(density, 1.0), 1e-6) * phi * psi
        svd = 4.0 * svd_iters * nnz * rank
    km = 2.0 * kmeans_iters * (phi + psi) * rank * k
    return svd + km


def plan_partition(
    n_rows: int,
    n_cols: int,
    *,
    min_cocluster_rows: int,
    min_cocluster_cols: int,
    t_m: int = 2,
    t_n: int = 2,
    p_thresh: float = 0.95,
    workers: int = 1,
    rank: int = 8,
    svd_iters: int = 4,
    kmeans_iters: int = 16,
    k: int = 8,
    grid_candidates: Sequence[int] = (1, 2, 4, 8, 16, 32),
    max_resamples: int = 4096,
    expected_failed_blocks: int = 0,
    svd_method: str = "randomized",
    density: float = 1.0,
    min_phi: int | None = None,
    min_psi: int | None = None,
) -> PlanCandidate:
    """Pick the (m, n, T_p) minimizing estimated wall-cost subject to
    ``P_detect >= p_thresh`` (paper §IV-B.2, Eq. 4).

    ``min_cocluster_{rows,cols}`` is the smallest co-cluster the caller
    still wants to detect — the adversarial ``C_k`` of Theorem 1.
    ``workers`` is the number of parallel processing units (devices); cost
    is total block work divided by workers, in waves of ``m*n`` blocks.
    ``density`` is the input's nnz fraction (1.0 = dense); it rescales the
    SVD term of the atom cost so sparse inputs are planned against their
    SpMM cost (see ``_atom_cost``).

    Besides the Theorem-1 feasibility check, candidates must satisfy atom
    *resolvability*: a block needs at least ``min_phi x min_psi`` entries
    (default ``8k x 8k``) to host ``k`` separable clusters — degenerate
    sliver blocks pass the detection bound but starve the atom method of
    context, so they are pruned here.
    """
    if min_phi is None:
        min_phi = max(32, 8 * k)
    if min_psi is None:
        min_psi = max(32, 8 * k)
    best: PlanCandidate | None = None
    for m in grid_candidates:
        if m > n_rows:
            continue
        for n in grid_candidates:
            if n > n_cols:
                continue
            phi = max(1, n_rows // m)
            psi = max(1, n_cols // n)
            if (m, n) != (1, 1) and (phi < min_phi or psi < min_psi):
                continue
            # aspect cap: sliver blocks (m >> n or n >> m) minimize the
            # exact-SVD cost model but starve the atom method; bound the
            # grid anisotropy to 4x.
            if max(m, n) > 4 * min(m, n) and (m, n) != (1, 1):
                continue
            t_p = min_resamples(
                p_thresh,
                min_cocluster_rows,
                min_cocluster_cols,
                n_rows,
                n_cols,
                m,
                n,
                t_m,
                t_n,
                max_resamples=max_resamples,
            )
            t_p = resamples_for_failures(t_p, m * n, expected_failed_blocks)
            p = detection_probability(
                t_p, min_cocluster_rows, min_cocluster_cols,
                n_rows, n_cols, m, n, t_m, t_n,
            )
            if p < p_thresh and (m, n) != (1, 1):
                continue  # infeasible under the bound; (1,1) always "detects"
            blocks = m * n * t_p
            waves = math.ceil(blocks / max(workers, 1))
            cost = waves * _atom_cost(phi, psi, rank, svd_iters, kmeans_iters, k,
                                      svd_method=svd_method, density=density)
            cand = PlanCandidate(m=m, n=n, phi=phi, psi=psi, t_p=t_p,
                                 detection_p=p, est_cost=cost)
            if best is None or cand.est_cost < best.est_cost:
                best = cand
    assert best is not None, "grid_candidates produced no feasible plan"
    return best


def mc_failure_estimate(
    rng: np.random.Generator,
    cocluster_rows: int,
    cocluster_cols: int,
    n_rows: int,
    n_cols: int,
    m: int,
    n: int,
    t_m: int,
    t_n: int,
    trials: int = 2000,
) -> float:
    """Monte-Carlo estimate of the true P(omega_k) for validating Theorem 1.

    Samples random row/col permutations, splits into uniform blocks, and
    checks whether *no* block receives >= T_m co-cluster rows and >= T_n
    co-cluster cols. Used by tests to confirm the analytic bound dominates.
    """
    phi = n_rows // m
    psi = n_cols // n
    failures = 0
    for _ in range(trials):
        row_hits = rng.permutation(n_rows)[: m * phi].reshape(m, phi) < cocluster_rows
        col_hits = rng.permutation(n_cols)[: n * psi].reshape(n, psi) < cocluster_cols
        rows_per_block = row_hits.sum(axis=1)  # (m,)
        cols_per_block = col_hits.sum(axis=1)  # (n,)
        detected = (rows_per_block[:, None] >= t_m) & (cols_per_block[None, :] >= t_n)
        if not detected.any():
            failures += 1
    return failures / trials
