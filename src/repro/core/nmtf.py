"""Non-negative Matrix Tri-Factorization atom co-clusterer.

Implements orthogonal NMTF (Ding et al. 2006; the serial core of the
"PNMTF [11]" baseline in the paper's Table II): ``A ~= F S G^T`` with
``F (M,k) >= 0``, ``G (N,d) >= 0``, multiplicative updates, fixed iteration
count (SPMD-uniform, see DESIGN.md §2). Row labels = argmax_k F, col labels
= argmax_d G.

Used two ways:
  * as a drop-in atom method for LAMC (``LAMC-PNMTF`` row of Table II), and
  * unpartitioned, as the ``PNMTF`` baseline itself (``core.baselines``).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import kmeans as _kmeans

__all__ = ["NMTFResult", "nmtf"]

_EPS = 1e-9


class NMTFResult(NamedTuple):
    row_labels: jax.Array   # (M,)
    col_labels: jax.Array   # (N,)
    f: jax.Array            # (M,k)
    s: jax.Array            # (k,d)
    g: jax.Array            # (N,d)
    loss: jax.Array         # ||A - F S G^T||_F^2


@functools.partial(jax.jit, static_argnames=("k", "d", "n_iter"))
def nmtf(key: jax.Array, a: jax.Array, k: int, d: int | None = None,
         n_iter: int = 64) -> NMTFResult:
    """Orthogonal tri-factorization with multiplicative updates.

    ``a`` is shifted to be non-negative (co-clustering affinities are
    magnitudes; the shift is removed from the reported loss baseline).
    """
    if d is None:
        d = k
    a = a - jnp.minimum(jnp.min(a), 0.0)  # enforce non-negativity
    m, n = a.shape
    kf, kg = jax.random.split(key)
    # k-means init (Ding et al. recommend it): F = onehot(rows) + 0.2,
    # G = onehot(cols) + 0.2 — orders of magnitude faster convergence than
    # random init for the multiplicative updates.
    row_km = _kmeans.kmeans(kf, a, k, n_iter=8)
    col_km = _kmeans.kmeans(kg, a.T, d, n_iter=8)
    f = jax.nn.one_hot(row_km.labels, k, dtype=a.dtype) + 0.2
    g = jax.nn.one_hot(col_km.labels, d, dtype=a.dtype) + 0.2
    s = f.T @ a @ g / jnp.maximum(jnp.sum(f, 0)[:, None] * jnp.sum(g, 0)[None, :], _EPS)

    def step(carry, _):
        f, s, g = carry
        # G <- G * sqrt( (A^T F S) / (G G^T A^T F S) )
        num_g = a.T @ (f @ s)                               # (N,d)
        den_g = g @ (g.T @ num_g)
        g = g * jnp.sqrt(num_g / jnp.maximum(den_g, _EPS))
        # F <- F * sqrt( (A G S^T) / (F F^T A G S^T) )
        num_f = a @ (g @ s.T)                               # (M,k)
        den_f = f @ (f.T @ num_f)
        f = f * jnp.sqrt(num_f / jnp.maximum(den_f, _EPS))
        # S <- S * sqrt( (F^T A G) / (F^T F S G^T G) )
        num_s = f.T @ a @ g                                 # (k,d)
        den_s = (f.T @ f) @ s @ (g.T @ g)
        s = s * jnp.sqrt(num_s / jnp.maximum(den_s, _EPS))
        return (f, s, g), None

    (f, s, g), _ = jax.lax.scan(step, (f, s, g), None, length=n_iter)
    recon = f @ s @ g.T
    loss = jnp.sum((a - recon) ** 2)
    return NMTFResult(
        row_labels=jnp.argmax(f, axis=1).astype(jnp.int32),
        col_labels=jnp.argmax(g, axis=1).astype(jnp.int32),
        f=f, s=s, g=g, loss=loss,
    )
