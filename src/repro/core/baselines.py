"""Unpartitioned baselines from the paper's Table II/III.

* ``scc_full``  — Spectral Co-Clustering on the whole matrix (SCC [18]).
* ``nmtf_full`` — (P)NMTF on the whole matrix (PNMTF [11]; parallelism in the
  original is across worker nodes — here the whole-matrix factorization *is*
  the baseline cost being compared against).

These exist so the benchmark harness can reproduce the paper's speedup
claims (~83% dense / ~30% sparse reduction) with identical atom settings.
"""

from __future__ import annotations

from typing import NamedTuple

import jax

# NOTE: import the functions, not the submodules — the package __init__
# re-exports `nmtf` (the function), shadowing the submodule attribute.
from .nmtf import nmtf as _nmtf_fn
from .spectral import scc as _scc_fn

__all__ = ["BaselineResult", "scc_full", "nmtf_full"]


class BaselineResult(NamedTuple):
    row_labels: jax.Array
    col_labels: jax.Array


def scc_full(key: jax.Array, a: jax.Array, k: int, d: int | None = None,
             svd_iters: int = 4, kmeans_iters: int = 16,
             svd_method: str = "randomized") -> BaselineResult:
    res = _scc_fn(key, a, k, d if d is not None else k,
                  svd_iters=svd_iters, kmeans_iters=kmeans_iters,
                  svd_method=svd_method)
    return BaselineResult(res.row_labels, res.col_labels)


def nmtf_full(key: jax.Array, a: jax.Array, k: int, d: int | None = None,
              n_iter: int = 64) -> BaselineResult:
    res = _nmtf_fn(key, a, k, d, n_iter=n_iter)
    return BaselineResult(res.row_labels, res.col_labels)
