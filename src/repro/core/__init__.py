"""LAMC core — the paper's contribution as a composable JAX library.

Public API:
    LAMCConfig, lamc_cocluster      full pipeline (Algorithm 1)
    make_plan, PartitionPlan        probabilistic partition planning (§IV-B)
    scc, nmtf                       atom co-clusterers (§IV-C)
    signature_merge, jaccard_merge_host   hierarchical merging (§IV-D)
    nmi, ari                        evaluation metrics (§V)
"""

from .lamc import LAMCConfig, LAMCResult, lamc_cocluster
from .merging import (
    cluster_signatures,
    finalize_assignment,
    jaccard_merge_host,
    memberships_from_votes,
    signature_merge,
)
from .metrics import ari, cocluster_scores, membership_from_labels, nmi, omega_index, overlap_f1
from .nmtf import nmtf
from .partition import (
    PartitionPlan,
    coverage_probability,
    extract_blocks,
    extract_blocks_sparse,
    make_plan,
    resample_indices,
)
from .probability import detection_probability, failure_bound, min_resamples, plan_partition
from .spectral import normalize_bipartite, randomized_svd, scc

__all__ = [
    "LAMCConfig", "LAMCResult", "lamc_cocluster",
    "PartitionPlan", "make_plan", "extract_blocks", "extract_blocks_sparse",
    "resample_indices", "coverage_probability",
    "detection_probability", "failure_bound", "min_resamples", "plan_partition",
    "scc", "nmtf", "normalize_bipartite", "randomized_svd",
    "signature_merge", "jaccard_merge_host", "cluster_signatures",
    "memberships_from_votes", "finalize_assignment",
    "nmi", "ari", "cocluster_scores",
    "membership_from_labels", "omega_index", "overlap_f1",
]
