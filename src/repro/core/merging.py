"""Hierarchical co-cluster merging (paper §IV-D).

The paper specifies the merge only behaviourally (iteratively combine
per-submatrix co-clusters into a consensus, robust to heterogeneity and
model uncertainty). We provide two implementations:

1. ``signature`` (primary, jittable, distributed-friendly): every atom
   co-cluster is summarized by a *signature* — its member-mean over a small
   set of globally shared ANCHOR columns (for row atoms; anchor rows for
   column atoms). Anchor indices are derived from the plan seed, so every
   device picks the same ``q`` anchors locally. Because all signatures are
   means over the *same* feature subset, same-cluster atoms from ANY two
   blocks/resamples are correlated — unlike per-block random projections,
   whose inner products vanish for blocks with disjoint column sets (a bug
   caught by tests/test_merging.py). Atoms are then aligned by one small
   global k-means over signatures (``T_p*m*n*k`` points of dim ``q``), and
   every point casts one vote per resample for its atom's global cluster;
   final labels = argmax of votes.
   The hierarchy: block -> signature (local reduce), signatures -> global
   clusters (small shared clustering), votes -> labels (scatter reduce).
   Communication is *labels + k x q floats per block* — never matrix data
   (anchor features are a tiny ``phi x q`` gather each device does locally).

2. ``jaccard`` (host-side numpy, paper-literal): atoms merge greedily along
   block-columns, then block-rows, then across resamples whenever row/col
   index-set Jaccard overlap exceeds a threshold (union-find). Quadratic in
   atom count; used for validation and small problems.

Both are exercised and cross-checked in ``tests/test_merging.py``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import kmeans as _kmeans

__all__ = [
    "MergeResult",
    "anchor_indices",
    "atom_signatures",
    "cluster_signatures",
    "memberships_from_votes",
    "finalize_assignment",
    "signature_merge",
    "jaccard_merge_host",
]


class MergeResult(NamedTuple):
    row_labels: jax.Array   # (M,) int32 (-1 = outlier in overlap mode)
    col_labels: jax.Array   # (N,) int32
    row_votes: jax.Array    # (M, K_row) vote counts (support/confidence)
    col_votes: jax.Array    # (N, K_col)
    # Serving signatures (cluster_signatures over the anchor slivers) —
    # populated when signature_merge is given the slivers; None otherwise.
    row_sigs: jax.Array | None = None   # (K_row, q_row) unit rows
    col_sigs: jax.Array | None = None   # (K_col, q_col)
    row_mean: jax.Array | None = None   # (q_row,) centering mean
    col_mean: jax.Array | None = None   # (q_col,)
    # Boolean membership matrices (DESIGN.md §11): hard mode emits the
    # one-hot of the labels; overlap mode keeps every cluster whose vote
    # share clears the threshold (a point clearing none is an outlier —
    # all-False row, label -1).
    row_membership: jax.Array | None = None  # (M, K_row) bool
    col_membership: jax.Array | None = None  # (N, K_col) bool


def memberships_from_votes(
    votes: jax.Array,          # (P, K) per-point vote counts
    overlap_threshold: float,
    min_membership: int = 0,
) -> jax.Array:
    """Boolean membership ``(P, K)`` from a vote table (DESIGN.md §11).

    A point joins every cluster whose *vote share* — its votes divided by
    the point's total votes — is at least ``overlap_threshold``; clearing
    none leaves the row all-False (the point is an outlier).
    ``min_membership > 0`` guarantees the top-``min_membership`` clusters
    by share regardless of the threshold (ties broken toward the lower
    cluster id, exactly like ``argmax``), so ``min_membership=1`` rules
    outliers out and ``overlap_threshold > 0.5`` with ``min_membership=1``
    reduces membership to the one-hot of the hard labels — shares sum to
    1, so at most one cluster can clear a majority threshold and the
    argmax guarantee fills in when none does. Jittable; shared by the
    single-host merge, the distributed merge (applied to the psum'd vote
    tables — bit-identical because the votes are), and the streaming
    model helpers.
    """
    votes = votes.astype(jnp.float32)
    total = jnp.sum(votes, axis=1, keepdims=True)
    share = votes / jnp.maximum(total, 1.0)
    member = share >= overlap_threshold
    if min_membership > 0:
        # rank clusters per point by descending share; stable argsort
        # keeps the lower id first among ties, matching argmax
        order = jnp.argsort(-share, axis=1, stable=True)
        rank = jnp.argsort(order, axis=1, stable=True)
        member = member | (rank < min_membership)
    return member


def finalize_assignment(
    votes: jax.Array,
    assignment: str = "hard",
    overlap_threshold: float = 0.25,
    min_membership: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """``(labels, membership)`` from a vote table.

    ``assignment="hard"``: labels are the plain argmax (bit-identical to
    the pre-overlap pipeline) and membership is their one-hot.
    ``assignment="overlap"``: membership per
    :func:`memberships_from_votes`; labels keep the argmax for points
    with at least one membership and mark the rest ``-1`` (outliers).
    The single source of assignment semantics for the single-host merge,
    the distributed merge, and the streaming model.
    """
    argmax = jnp.argmax(votes, axis=1).astype(jnp.int32)
    if assignment == "hard":
        k = votes.shape[1]
        return argmax, jax.nn.one_hot(argmax, k, dtype=jnp.bool_)
    if assignment != "overlap":
        raise ValueError(
            f"assignment must be 'hard' or 'overlap', got {assignment!r}")
    member = memberships_from_votes(votes, overlap_threshold, min_membership)
    labels = jnp.where(jnp.any(member, axis=1), argmax, -1).astype(jnp.int32)
    return labels, member


def anchor_indices(seed_key: jax.Array, length: int, q: int) -> jax.Array:
    """``q`` shared anchor indices into an axis of length ``length``.

    Derived from the plan seed: every worker regenerates them identically —
    nothing is broadcast (DESIGN.md §2).
    """
    return jax.random.choice(seed_key, length, (min(q, length),), replace=False)


def atom_signatures(
    feats: jax.Array,        # (B, P, q) anchor features per point
    labels: jax.Array,       # (B, P) local labels in [0,k)
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Per-atom signatures ``(B, k, q)`` and member counts ``(B, k)``.

    ``feats[b, p]`` is point ``p``'s restriction to the globally shared
    anchor set (for row atoms: ``A[row, anchor_cols]``). The signature is
    the member mean, centered and unit-normalized.

    Centering (subtracting the per-block feature mean) matters: raw cluster
    means are dominated by the shared grand-mean direction (pairwise cosine
    ~0.9 between *different* clusters), which destroys separability;
    centered signatures isolate the cluster-specific deviation and are
    near-orthogonal across clusters (measured in tests/test_merging.py).
    """
    feats = feats - jnp.mean(feats, axis=1, keepdims=True)       # center
    onehot = jax.nn.one_hot(labels, k, dtype=feats.dtype)        # (B, P, k)
    sums = jnp.einsum("bpk,bpq->bkq", onehot, feats)             # (B, k, q)
    counts = jnp.sum(onehot, axis=1)                             # (B, k)
    sig = sums / jnp.maximum(counts[..., None], 1.0)
    # unit-normalize: scale-invariant alignment across blocks
    norm = jnp.linalg.norm(sig, axis=-1, keepdims=True)
    return sig / jnp.maximum(norm, 1e-12), counts


def cluster_signatures(
    feats: jax.Array,        # (P, q) anchor features per point
    labels: jax.Array,       # (P,) global cluster labels in [0, k)
    k: int,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-*cluster* serving signatures ``(sigs (k, q), mean (q,), counts (k,))``.

    The out-of-sample counterpart of :func:`atom_signatures`: member means
    over the shared anchor features, centered by the **global** feature
    mean (an out-of-sample point has no block to center against) and
    unit-normalized. A new point is scored by the cosine between its
    centered anchor features and these signatures (``streaming.assign``)
    — the NEO-CC-style "score against cluster signatures instead of
    re-running the fit". Empty clusters keep a zero signature (cosine
    score 0: selected only if every real score is negative).
    """
    feats = feats.astype(jnp.float32)
    mean = jnp.mean(feats, axis=0)                               # (q,)
    f = feats - mean
    onehot = jax.nn.one_hot(labels, k, dtype=f.dtype)            # (P, k)
    sums = onehot.T @ f                                          # (k, q)
    counts = jnp.sum(onehot, axis=0)                             # (k,)
    sig = sums / jnp.maximum(counts[:, None], 1.0)
    norm = jnp.linalg.norm(sig, axis=-1, keepdims=True)
    return sig / jnp.maximum(norm, 1e-12), mean, counts


def cluster_atoms_best(key, flat, w, k_global, n_iter, n_restarts: int = 4):
    """Weighted k-means over flattened atom signatures, best of
    ``n_restarts`` seedings by inertia.

    The signature set is tiny (``T_p*m*n*k`` points of dim ``q``), so the
    restarts cost nothing next to the atom phase — but this k-means is the
    single step most exposed to bad local optima: one unlucky seeding
    scrambles the global atom alignment and visibly degrades end-to-end
    NMI. Empty atoms carry zero weight and never attract centroids.
    Deterministic in ``key``; vmapped restarts keep trip counts static
    (DESIGN.md §2).
    """
    keys = jax.random.split(key, n_restarts)
    res = jax.vmap(
        lambda kk: _kmeans.kmeans(kk, flat, k_global, n_iter=n_iter, weights=w)
    )(keys)
    best = jnp.argmin(res.inertia)
    return res.labels[best]  # (n_atoms,)


def _cluster_atoms(key, sigs, counts, k_global, n_iter, n_restarts):
    """Small shared k-means over atom signatures (see cluster_atoms_best)."""
    flat = sigs.reshape(-1, sigs.shape[-1])
    w = counts.reshape(-1)
    return cluster_atoms_best(key, flat, w, k_global, n_iter, n_restarts)


def signature_merge(
    key: jax.Array,
    *,
    row_sigs: jax.Array,     # (T_p, B, k, q)
    row_counts: jax.Array,   # (T_p, B, k)
    row_labels: jax.Array,   # (T_p, B, phi) local labels
    row_index: jax.Array,    # (T_p, m, phi) global row ids per block-row
    col_sigs: jax.Array,     # (T_p, B, d, q)
    col_counts: jax.Array,
    col_labels: jax.Array,   # (T_p, B, psi)
    col_index: jax.Array,    # (T_p, n, psi)
    n_rows: int,
    n_cols: int,
    k_row: int,
    k_col: int,
    m: int,
    n: int,
    kmeans_iters: int = 25,
    n_restarts: int = 4,
    row_features: jax.Array | None = None,   # (M, q_row) anchor-col sliver
    col_features: jax.Array | None = None,   # (N, q_col) anchor-row sliver
    assignment: str = "hard",
    overlap_threshold: float = 0.25,
    min_membership: int = 0,
    block_mask: jax.Array | None = None,     # (T_p, B) bool: True = survived
) -> MergeResult:
    """Jittable consensus merge. See module docstring for the scheme.

    ``block_mask`` simulates block-level worker failure (DESIGN.md §12):
    a ``False`` entry removes that (resample, block) atom from the
    consensus entirely — zero weight in the global signature k-means and
    zero votes for its points — which is what losing the worker mid-atom
    looks like to the merge. Pair with
    ``probability.sample_block_failures`` /
    ``resamples_for_failures`` to test the statistical fault budget.

    When the anchor slivers are supplied (``row_features`` =
    ``A[:, anchor_cols]``, ``col_features`` = ``A[anchor_rows].T``), the
    result additionally carries the per-cluster serving signatures
    (:func:`cluster_signatures`) so the fitted model can assign
    out-of-sample rows/columns without the data matrix.

    ``assignment="overlap"`` keeps the per-point vote tables un-argmax'd:
    membership matrices come from :func:`memberships_from_votes` (soft,
    non-exhaustive — points may join several clusters or none), labels
    carry ``-1`` for outliers, and serving signatures are means over the
    non-outlier points only (``one_hot(-1)`` is the zero row).
    """
    kr, kc = jax.random.split(key)
    t_p, b, k, _q = row_sigs.shape
    d = col_sigs.shape[2]
    if block_mask is not None:
        w_mask = block_mask.astype(jnp.float32)              # (T_p, B)
        row_counts = row_counts * w_mask[:, :, None]
        col_counts = col_counts * w_mask[:, :, None]
    else:
        w_mask = None

    # --- rows ---
    atom_global = _cluster_atoms(kr, row_sigs, row_counts, k_row, kmeans_iters,
                                 n_restarts)
    atom_global = atom_global.reshape(t_p, b, k)             # (T_p,B,k)
    # each point's global cluster per (resample, col-block) vote
    point_global = jnp.take_along_axis(
        atom_global, row_labels, axis=2
    )                                                        # (T_p,B,phi) via labels indexing k-axis
    # global row id of each voting point: block b = i*n + j -> row-group i
    i_of_b = jnp.arange(b) // n                              # (B,)
    rows_of_block = row_index[:, i_of_b, :]                  # (T_p,B,phi)
    phi = rows_of_block.shape[-1]
    row_w = (1.0 if w_mask is None
             else jnp.broadcast_to(w_mask[:, :, None],
                                   (t_p, b, phi)).reshape(-1))
    row_votes = jnp.zeros((n_rows, k_row), jnp.float32).at[
        rows_of_block.reshape(-1),
        point_global.reshape(-1),
    ].add(row_w)
    final_rows, row_member = finalize_assignment(
        row_votes, assignment, overlap_threshold, min_membership)

    # --- cols ---
    atom_global_c = _cluster_atoms(kc, col_sigs, col_counts, k_col, kmeans_iters,
                                   n_restarts)
    atom_global_c = atom_global_c.reshape(t_p, b, d)
    point_global_c = jnp.take_along_axis(atom_global_c, col_labels, axis=2)
    j_of_b = jnp.arange(b) % n
    cols_of_block = col_index[:, j_of_b, :]                  # (T_p,B,psi)
    psi = cols_of_block.shape[-1]
    col_w = (1.0 if w_mask is None
             else jnp.broadcast_to(w_mask[:, :, None],
                                   (t_p, b, psi)).reshape(-1))
    col_votes = jnp.zeros((n_cols, k_col), jnp.float32).at[
        cols_of_block.reshape(-1),
        point_global_c.reshape(-1),
    ].add(col_w)
    final_cols, col_member = finalize_assignment(
        col_votes, assignment, overlap_threshold, min_membership)

    row_sigs = col_sigs_out = row_mean = col_mean = None
    if row_features is not None:
        row_sigs, row_mean, _ = cluster_signatures(row_features, final_rows, k_row)
    if col_features is not None:
        col_sigs_out, col_mean, _ = cluster_signatures(col_features, final_cols, k_col)
    return MergeResult(final_rows, final_cols, row_votes, col_votes,
                       row_sigs=row_sigs, col_sigs=col_sigs_out,
                       row_mean=row_mean, col_mean=col_mean,
                       row_membership=row_member, col_membership=col_member)


# ---------------------------------------------------------------------------
# Host-side paper-literal hierarchical merge (validation / small problems)
# ---------------------------------------------------------------------------


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, x: int) -> int:
        while self.parent[x] != x:
            self.parent[x] = self.parent[self.parent[x]]
            x = self.parent[x]
        return x

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)


def _jaccard(a: set, b: set) -> float:
    if not a or not b:
        return 0.0
    inter = len(a & b)
    return inter / (len(a) + len(b) - inter)


def jaccard_merge_host(
    atoms: list[dict],
    n_rows: int,
    n_cols: int,
    tau: float = 0.3,
    min_support: int = 1,
) -> tuple[np.ndarray, np.ndarray]:
    """Greedy hierarchical union-find merge over atom co-clusters.

    ``atoms``: list of {"rows": set[int], "cols": set[int], "resample": int,
    "block": (i, j)}. Merge order follows the paper's hierarchy: same
    row-group across column blocks (row-overlap), then across row-groups
    (col-overlap), then across resamples (row+col overlap). Returns
    (row_labels, col_labels) with -1 for unassigned.
    """
    n_atoms = len(atoms)
    uf = _UnionFind(n_atoms)

    def stage(pred, score):
        for x in range(n_atoms):
            for y in range(x + 1, n_atoms):
                if uf.find(x) == uf.find(y):
                    continue
                if pred(atoms[x], atoms[y]) and score(atoms[x], atoms[y]) >= tau:
                    uf.union(x, y)

    # 1) same resample, same row-group, different col blocks: share rows
    stage(
        lambda a_, b_: a_["resample"] == b_["resample"] and a_["block"][0] == b_["block"][0],
        lambda a_, b_: _jaccard(a_["rows"], b_["rows"]),
    )
    # 2) same resample, different row-groups: share cols
    stage(
        lambda a_, b_: a_["resample"] == b_["resample"],
        lambda a_, b_: _jaccard(a_["cols"], b_["cols"]),
    )
    # 3) across resamples: share both
    stage(
        lambda a_, b_: True,
        lambda a_, b_: 0.5 * (_jaccard(a_["rows"], b_["rows"]) + _jaccard(a_["cols"], b_["cols"])),
    )

    groups: dict[int, list[int]] = {}
    for x in range(n_atoms):
        groups.setdefault(uf.find(x), []).append(x)

    row_votes = np.zeros((n_rows, len(groups)), np.int64)
    col_votes = np.zeros((n_cols, len(groups)), np.int64)
    for gi, members in enumerate(groups.values()):
        if len(members) < min_support:
            continue
        for a_idx in members:
            for r in atoms[a_idx]["rows"]:
                row_votes[r, gi] += 1
            for c in atoms[a_idx]["cols"]:
                col_votes[c, gi] += 1
    row_labels = np.where(row_votes.sum(1) > 0, row_votes.argmax(1), -1)
    col_labels = np.where(col_votes.sum(1) > 0, col_votes.argmax(1), -1)
    return row_labels, col_labels
