"""LAMC driver — partition -> parallel atom co-clustering -> hierarchical merge.

Single-host reference implementation of the full Algorithm 1 pipeline. The
multi-device version (``core.distributed``) reuses the same pieces under
``shard_map``; this module is its oracle in tests.

Per resample ``t``:
  1. ``partition.extract_blocks`` gathers the (m*n, phi, psi) block stack.
  2. The atom co-clusterer (SCC or NMTF) runs *vmapped* over the stack —
     on real hardware this is the embarrassingly parallel phase.
  3. Atom signatures are computed in the shared projection space.
Afterwards, ``merging.signature_merge`` produces consensus labels.

Everything except the plan search is jittable; the resample loop is a
``lax.scan`` so the whole pipeline lowers to one XLA program.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .. import obs
from . import merging, nmtf, partition, probability, spectral
from . import sparse as _sparse

__all__ = ["LAMCConfig", "LAMCResult", "lamc_cocluster", "run_resample",
           "anchor_features", "validate_assignment"]


@dataclasses.dataclass(frozen=True)
class LAMCConfig:
    n_row_clusters: int
    n_col_clusters: int
    # block k/d: clusters the atom method looks for inside one block.
    atom_row_clusters: int | None = None
    atom_col_clusters: int | None = None
    atom: str = "scc"               # "scc" | "nmtf"
    min_cocluster_rows: int = 8     # adversarial C_k for the Theorem-1 plan
    min_cocluster_cols: int = 8
    p_thresh: float = 0.95
    workers: int = 1
    seed: int = 0
    svd_iters: int = 4
    kmeans_iters: int = 16
    nmtf_iters: int = 64
    merge_kmeans_iters: int = 25
    merge_restarts: int = 4    # best-of-N seedings for the signature k-means
    signature_dim: int = 64    # number of shared anchor rows/cols for merging
    expected_failed_blocks: int = 0
    grid_candidates: tuple = (1, 2, 4, 8, 16, 32)
    assign_impl: str = "jnp"        # "jnp" | "pallas" — k-means hot path
    svd_method: str = "randomized"  # "randomized" (TPU-adapted) | "exact" (paper)
    qr_method: str = "qr"           # "qr" (LAPACK) | "cholesky" (Gram, batched)
    input_format: str = "dense"     # "dense" | "bcoo" — sparse execution path
    # SpMM backend for the sparse spectral path: "auto" routes per matrix
    # density (probability.spmm_route), or pin "dense" | "dual_ell" |
    # "tiled". Decides how a single-block (m = n = 1) plan's full-matrix
    # atom runs: a non-dense route keeps A in its sparse operator form
    # (converted once, amortized across all resamples) instead of
    # densifying the block. Multi-block plans always densify their
    # phi x psi blocks (the MXU-shaped atom work unit, DESIGN.md §9).
    spmm_impl: str = "auto"
    # Assignment mode (DESIGN.md §11). "hard" (default): every point gets
    # exactly the argmax of its vote table — bit-identical to the
    # pre-overlap pipeline. "overlap": non-exhaustive soft assignment —
    # a point joins every cluster whose vote share clears
    # overlap_threshold (membership matrices on the result); clearing
    # none marks it an outlier (label -1) unless min_membership > 0
    # guarantees its top clusters. overlap_threshold > 0.5 with
    # min_membership=1 reduces exactly to hard mode.
    assignment: str = "hard"
    overlap_threshold: float = 0.25
    min_membership: int = 0

    @property
    def atom_k(self) -> int:
        return self.atom_row_clusters or self.n_row_clusters

    @property
    def atom_d(self) -> int:
        return self.atom_col_clusters or self.n_col_clusters


class LAMCResult(NamedTuple):
    row_labels: jax.Array
    col_labels: jax.Array
    row_votes: jax.Array
    col_votes: jax.Array
    plan: partition.PartitionPlan
    # Serving artifact fields (merged cluster signatures in anchor space +
    # the anchor index sets) — what ``streaming.model_from_result`` packs
    # into a CoclusterModel. None only for results built by old callers.
    row_sigs: jax.Array | None = None     # (K_row, q_row) unit rows
    col_sigs: jax.Array | None = None     # (K_col, q_col)
    row_mean: jax.Array | None = None     # (q_row,) centering mean
    col_mean: jax.Array | None = None     # (q_col,)
    anchor_rows: jax.Array | None = None  # (q_col,) int32 global row ids
    anchor_cols: jax.Array | None = None  # (q_row,) int32 global col ids
    # Boolean membership matrices (DESIGN.md §11): one-hot of the labels
    # in hard mode; soft non-exhaustive membership in overlap mode
    # (all-False row = outlier, label -1).
    row_membership: jax.Array | None = None  # (M, K_row) bool
    col_membership: jax.Array | None = None  # (N, K_col) bool


def _atom_fn(cfg: LAMCConfig):
    if cfg.atom == "scc":
        def atom(key, block):
            res = spectral.scc(
                key, block, cfg.atom_k, cfg.atom_d,
                svd_iters=cfg.svd_iters, kmeans_iters=cfg.kmeans_iters,
                assign_impl=cfg.assign_impl, svd_method=cfg.svd_method,
                qr_method=cfg.qr_method,
            )
            return res.row_labels, res.col_labels
    elif cfg.atom == "nmtf":
        def atom(key, block):
            res = nmtf.nmtf(key, block, cfg.atom_k, cfg.atom_d, n_iter=cfg.nmtf_iters)
            return res.row_labels, res.col_labels
    else:
        raise ValueError(f"unknown atom method {cfg.atom!r}")
    return atom


def anchor_features(a, anchor_rows, anchor_cols):
    """Anchor slivers ``(A[:, anchor_cols] (M, q), A[anchor_rows] (q, N))``.

    Gather order matters on the dense path: restricting to the ``q``
    anchor columns *first* keeps the intermediate at ``(M, q)`` — indexing
    rows first would materialize an ``(m, phi, N)`` tensor, the same
    gather-order bug ``extract_blocks`` fixed for blocks. A BCOO input
    scatters its nonzeros straight into the slivers, O(nnz).
    """
    if _sparse.is_bcoo(a):
        return (_sparse.gather_cols_dense(a, anchor_cols),
                _sparse.gather_rows_dense(a, anchor_rows))
    return a[:, anchor_cols], a[anchor_rows]


def run_resample(a, plan, cfg: LAMCConfig, anchor_rows, anchor_cols, t,
                 operator=None):
    """One resample: extract blocks, co-cluster them (vmapped), summarize.

    ``anchor_rows`` / ``anchor_cols`` are the globally shared anchor index
    sets (see ``merging.anchor_indices``). Returns the per-resample tensors
    consumed by ``merging.signature_merge``. ``a`` may be dense or BCOO
    (``cfg.input_format``); the block stack and anchor slivers the atom
    phase consumes are identical either way.

    ``operator`` (single-block plans only): a prepared sparse operand of
    the whole matrix (``sparse.prepare_operator``). The atom then runs
    SCC directly on it — SpMM subspace iteration, O(nnz)/O(occupied
    tiles) per product — and the ``M x N`` block is never densified. The
    per-resample row/col permutation is skipped (with one block it only
    reorders points *within* the block, which block membership ignores),
    so labels can differ from the densify path by k-means seeding order.
    """
    b = plan.blocks_per_resample
    if operator is not None:
        assert b == 1, "operator path requires a single-block plan"
        key_b = jax.random.fold_in(
            jax.random.fold_in(jax.random.key(plan.seed + 1), t), 0)
        res = spectral.scc(
            key_b, operator, cfg.atom_k, cfg.atom_d,
            svd_iters=cfg.svd_iters, kmeans_iters=cfg.kmeans_iters,
            assign_impl=cfg.assign_impl, svd_method=cfg.svd_method,
            qr_method=cfg.qr_method,
        )
        row_labels = res.row_labels[None]                  # (1, phi)
        col_labels = res.col_labels[None]                  # (1, psi)
        row_idx = jnp.arange(plan.n_rows, dtype=jnp.int32).reshape(
            plan.m, plan.phi)
        col_idx = jnp.arange(plan.n_cols, dtype=jnp.int32).reshape(
            plan.n, plan.psi)
    else:
        extract = (partition.extract_blocks_sparse
                   if cfg.input_format == "bcoo" else partition.extract_blocks)
        blocks, row_idx, col_idx = extract(a, plan, t)
        keys = jax.vmap(
            lambda i: jax.random.fold_in(jax.random.fold_in(jax.random.key(plan.seed + 1), t), i)
        )(jnp.arange(b))
        row_labels, col_labels = jax.vmap(_atom_fn(cfg))(keys, blocks)  # (B,phi),(B,psi)

    # anchor features: every block's points restricted to the shared anchors
    j_of_b = jnp.arange(b) % plan.n
    i_of_b = jnp.arange(b) // plan.n
    row_sliver, col_sliver = anchor_features(a, anchor_rows, anchor_cols)
    row_feats = row_sliver[row_idx]                    # (m, phi, q)
    col_feats = col_sliver[:, col_idx]                 # (q, n, psi)
    col_feats = jnp.transpose(col_feats, (1, 2, 0))    # (n, psi, q)
    row_sigs, row_counts = merging.atom_signatures(
        row_feats[i_of_b], row_labels, cfg.atom_k)
    col_sigs, col_counts = merging.atom_signatures(
        col_feats[j_of_b], col_labels, cfg.atom_d)
    return dict(
        row_sigs=row_sigs, row_counts=row_counts, row_labels=row_labels,
        row_index=row_idx,
        col_sigs=col_sigs, col_counts=col_counts, col_labels=col_labels,
        col_index=col_idx,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "plan"))
def _lamc_jit(a, cfg: LAMCConfig, plan: partition.PartitionPlan,
              operator=None, block_mask=None):
    q = cfg.signature_dim
    kproj = jax.random.key(plan.seed + 7)
    kar, kac, kmerge = jax.random.split(kproj, 3)
    anchor_rows = merging.anchor_indices(kar, plan.n_rows, q)
    anchor_cols = merging.anchor_indices(kac, plan.n_cols, q)

    def body(_, t):
        out = run_resample(a, plan, cfg, anchor_rows, anchor_cols, t,
                           operator=operator)
        return None, out

    _, stacked = jax.lax.scan(body, None, jnp.arange(plan.t_p))
    # serving signatures are cluster means over the same anchor slivers the
    # merge consumes — computed from the final consensus labels
    row_sliver, col_sliver = anchor_features(a, anchor_rows, anchor_cols)
    merged = merging.signature_merge(
        kmerge,
        n_rows=plan.n_rows, n_cols=plan.n_cols,
        k_row=cfg.n_row_clusters, k_col=cfg.n_col_clusters,
        m=plan.m, n=plan.n,
        kmeans_iters=cfg.merge_kmeans_iters,
        n_restarts=cfg.merge_restarts,
        row_features=row_sliver, col_features=col_sliver.T,
        assignment=cfg.assignment,
        overlap_threshold=cfg.overlap_threshold,
        min_membership=cfg.min_membership,
        block_mask=block_mask,
        **stacked,
    )
    return merged, anchor_rows, anchor_cols


def validate_assignment(cfg: LAMCConfig) -> None:
    """Fail loudly on bad assignment knobs before any jit trace."""
    if cfg.assignment not in ("hard", "overlap"):
        raise ValueError(
            f"assignment must be 'hard' or 'overlap', got {cfg.assignment!r}")
    if not 0.0 < cfg.overlap_threshold <= 1.0:
        raise ValueError(
            f"overlap_threshold must be in (0, 1], got {cfg.overlap_threshold}")
    if not 0 <= cfg.min_membership <= min(cfg.n_row_clusters,
                                          cfg.n_col_clusters):
        raise ValueError(
            f"min_membership must be in [0, n_clusters], got "
            f"{cfg.min_membership}")


def lamc_cocluster(a, cfg: LAMCConfig,
                   plan: partition.PartitionPlan | None = None,
                   block_mask=None) -> LAMCResult:
    """Full LAMC pipeline (Algorithm 1). ``plan=None`` derives the optimal
    plan from the probabilistic model.

    ``cfg.input_format='bcoo'`` runs the sparse execution path: ``a`` must
    be a 2-D BCOO matrix, which is never densified — blocks and anchor
    slivers are scattered out of the nonzeros, and the auto-plan is priced
    against the matrix's actual density. ``cfg.spmm_impl`` picks the SpMM
    backend for the spectral step (``"auto"`` routes on density; the
    decision is surfaced on ``result.plan.spmm_route``); on a
    single-block plan a non-dense route runs the atom straight on the
    sparse operator — converted once, amortized across all resamples.

    ``block_mask`` (``(T_p, blocks_per_resample)`` bool, True = survived)
    drops the masked blocks' atoms from the consensus merge — the
    simulation seam for worker failure (DESIGN.md §12). See
    ``probability.sample_block_failures`` and the T_p fault-budget
    differential test.
    """
    _sparse.validate_spmm_impl(cfg.spmm_impl)
    validate_assignment(cfg)
    if cfg.input_format == "bcoo":
        _sparse.validate_bcoo(a)
        density = _sparse.density(a)
    elif _sparse.is_bcoo(a):
        raise ValueError(
            "got a BCOO matrix with input_format='dense'; set "
            "LAMCConfig(input_format='bcoo') for the sparse path")
    else:
        density = 1.0
    n_rows, n_cols = a.shape
    with obs.span("lamc", rows=int(n_rows), cols=int(n_cols),
                  input_format=cfg.input_format, atom=cfg.atom) as root:
        if plan is None:
            with obs.span("plan"):
                plan = partition.make_plan(
                    n_rows, n_cols,
                    min_cocluster_rows=cfg.min_cocluster_rows,
                    min_cocluster_cols=cfg.min_cocluster_cols,
                    p_thresh=cfg.p_thresh,
                    workers=cfg.workers,
                    seed=cfg.seed,
                    k=cfg.atom_k,
                    expected_failed_blocks=cfg.expected_failed_blocks,
                    grid_candidates=cfg.grid_candidates,
                    svd_method=cfg.svd_method,
                    density=density,
                    spmm_impl=cfg.spmm_impl,
                )
        operator = None
        if cfg.input_format == "bcoo":
            # Only a single-block SCC plan covering the whole matrix can run
            # on the sparse operator (a subsampling (1,1) plan — phi < M or
            # psi < N — still needs the per-resample extraction); every other
            # plan densifies its blocks, so its route is "dense" whatever the
            # knob says. The shared resolver keeps this decision identical to
            # the plan search's pricing/surfacing — what runs is what was
            # priced.
            single = (plan.blocks_per_resample == 1 and cfg.atom == "scc"
                      and plan.phi == plan.n_rows and plan.psi == plan.n_cols)
            route = probability.resolve_spmm_route(
                cfg.spmm_impl, density, float(plan.phi) * plan.psi,
                single=single, svd_method=cfg.svd_method)
            if plan.spmm_route != route:
                plan = dataclasses.replace(plan, spmm_route=route)
            if single and route != "dense":
                # single-block plan: the block IS the matrix — keep it sparse.
                # One conversion (device-resident on TPU), reused by every
                # resample's ~10 subspace-iteration products, and served
                # from the pattern cache (core.opcache) when the fit loop
                # re-prepares a matrix whose sparsity pattern it has seen —
                # a repeat fit/resample pays a values refresh at most.
                with obs.span("prepare_operator", route=route):
                    operator = _sparse.prepare_operator(a, route)
        # Resolved-plan attributes on the root span: what actually ran.
        root.set(m=plan.m, n=plan.n, phi=plan.phi, psi=plan.psi,
                 t_p=plan.t_p, spmm_route=plan.spmm_route,
                 density=round(float(density), 6))
        if block_mask is not None:
            block_mask = jnp.asarray(block_mask, dtype=bool)
            want = (plan.t_p, plan.blocks_per_resample)
            if tuple(block_mask.shape) != want:
                raise ValueError(
                    f"block_mask must be (t_p, blocks_per_resample) = {want}, "
                    f"got {tuple(block_mask.shape)}")
        # The partition/extract -> atom -> merge phases fuse into one XLA
        # program (_lamc_jit), so they share one fenced span: splitting it
        # would mean splitting the jit (DESIGN.md §14).
        with obs.span("pipeline",
                      phases="partition/extract->atom->merge") as ps:
            merged, anchor_rows, anchor_cols = ps.fence(
                _lamc_jit(a, cfg, plan, operator, block_mask))
        with obs.span("finalize") as fs:
            return fs.fence(LAMCResult(
                merged.row_labels, merged.col_labels,
                merged.row_votes, merged.col_votes, plan,
                row_sigs=merged.row_sigs, col_sigs=merged.col_sigs,
                row_mean=merged.row_mean, col_mean=merged.col_mean,
                anchor_rows=anchor_rows, anchor_cols=anchor_cols,
                row_membership=merged.row_membership,
                col_membership=merged.col_membership))
