"""Fixed-iteration k-means in pure JAX.

SPMD design notes (DESIGN.md §2): iteration count is *static* — every device
runs the identical program regardless of data, so block co-clustering never
creates shape- or trip-count-stragglers. Convergence is monitored (inertia is
returned) but never branched on.

The Lloyd iteration is the hot spot (the paper's inner loop). The jnp path
implements it via the MXU-friendly expansion ``|x-c|^2 = |x|^2 - 2 x.c +
|c|^2`` plus a materialized one-hot update; ``assign_impl='pallas'`` routes
the whole iteration through the fused one-pass kernel
``repro.kernels.kmeans_update`` (assignment + per-centroid sum/count
accumulation in VMEM — one HBM read of ``x`` per iteration instead of
three, DESIGN.md §4), validated against this reference in tests.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["KMeansResult", "assign", "kmeans", "kmeanspp_init"]


class KMeansResult(NamedTuple):
    labels: jax.Array      # (P,) int32
    centroids: jax.Array   # (K, D)
    inertia: jax.Array     # () float32 — sum of squared distances


def assign(x: jax.Array, centroids: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Nearest-centroid assignment. Returns (labels, min_sq_dist)."""
    # |x-c|^2 = |x|^2 - 2 x.c + |c|^2 ; |x|^2 constant wrt argmin but needed
    # for inertia.
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)            # (P,1)
    c2 = jnp.sum(centroids * centroids, axis=-1)           # (K,)
    d2 = x2 - 2.0 * (x @ centroids.T) + c2[None, :]        # (P,K)
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return labels, jnp.maximum(jnp.min(d2, axis=-1), 0.0)


def _pallas_assign(x, centroids):
    from repro.kernels import ops as _kops  # lazy: kernels are optional on CPU

    return _kops.kmeans_assign(x, centroids)


def _pallas_update(x, centroids, weights):
    from repro.kernels import ops as _kops  # lazy: kernels are optional on CPU

    return _kops.kmeans_update(x, centroids, weights=weights)


def kmeanspp_init(key: jax.Array, x: jax.Array, k: int,
                  weights: jax.Array | None = None) -> jax.Array:
    """k-means++ seeding with a static-trip-count ``fori_loop``.

    With ``weights``, seeds are sampled proportional to ``w * d^2`` (zero-
    weight points are never selected).
    """
    p = x.shape[0]
    w = jnp.ones((p,), x.dtype) if weights is None else weights.astype(x.dtype)
    kfirst, krest = jax.random.split(key)
    first = jax.random.choice(kfirst, p, p=w / jnp.sum(w))
    cents = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    x2 = jnp.sum(x * x, axis=-1, keepdims=True)            # loop-invariant

    def body(i, carry):
        cents, key = carry
        key, sub = jax.random.split(key)
        # distance to nearest of the first i centroids; mask out unset rows
        c2 = jnp.sum(cents * cents, axis=-1)
        d2 = x2 - 2.0 * (x @ cents.T) + c2[None, :]        # (P,K)
        valid = jnp.arange(k) < i
        d2 = jnp.where(valid[None, :], d2, jnp.inf)
        dmin = jnp.maximum(jnp.min(d2, axis=-1), 1e-12) * w
        probs = dmin / jnp.sum(dmin)
        nxt = jax.random.choice(sub, p, p=probs)
        return cents.at[i].set(x[nxt]), key

    cents, _ = jax.lax.fori_loop(1, k, body, (cents, key))
    return cents


@functools.partial(jax.jit, static_argnames=("k", "n_iter", "assign_impl"))
def kmeans(
    key: jax.Array,
    x: jax.Array,
    k: int,
    n_iter: int = 16,
    assign_impl: str = "jnp",
    weights: jax.Array | None = None,
) -> KMeansResult:
    """Lloyd's algorithm, ``n_iter`` static iterations, k-means++ init.

    Empty clusters keep their previous centroid (standard fix that preserves
    SPMD static shapes). ``weights`` makes both seeding and centroid updates
    weighted (zero-weight points contribute nothing). ``assign_impl='pallas'``
    routes each full Lloyd iteration through the fused Pallas kernel
    (``kernels.kmeans_update``): assignment *and* sum/count accumulation in
    one pass over ``x``, with no materialized ``(P, K)`` one-hot.
    """
    assign_fn = _pallas_assign if assign_impl == "pallas" else assign
    w = None if weights is None else weights.astype(x.dtype)
    cents0 = kmeanspp_init(key, x, k, weights=w)

    if assign_impl == "pallas":
        def step(cents, _):
            _labels, _d, sums, counts = _pallas_update(x, cents, w)
            new = jnp.where(
                counts[:, None] > 0,
                (sums / jnp.maximum(counts, 1e-9)[:, None]).astype(x.dtype),
                cents,
            )
            return new, None

        cents, _ = jax.lax.scan(step, cents0, None, length=n_iter)
    else:
        def step(cents, _):
            labels, _d = assign_fn(x, cents)
            onehot = jax.nn.one_hot(labels, k, dtype=x.dtype)   # (P,K)
            if w is not None:
                onehot = onehot * w[:, None]
            counts = jnp.sum(onehot, axis=0)                    # (K,)
            sums = onehot.T @ x                                 # (K,D)
            new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1e-9)[:, None], cents)
            return new, None

        cents, _ = jax.lax.scan(step, cents0, None, length=n_iter)
    labels, d2 = assign_fn(x, cents)
    if w is not None:
        d2 = d2 * w
    return KMeansResult(labels=labels, centroids=cents, inertia=jnp.sum(d2))
