"""BCOO utilities for the sparse LAMC path (DESIGN.md §9).

The sparse execution path keeps the full ``M x N`` data matrix in
``jax.experimental.sparse`` BCOO form end-to-end; only *block-sized*
dense tensors (``phi x psi`` blocks, ``M x q`` anchor features) are ever
materialized. Everything here is O(nnz) gather/scatter work with static
shapes (``nse`` is static in a BCOO), so it composes with jit and
``lax.scan`` exactly like the dense path.

The inverse-permutation scatters use ``mode="drop"``: indices that fall
outside a resample's uniform grid (or outside the anchor set) are mapped
to an out-of-range sentinel and silently dropped — the same semantics as
the dense path's "rows that don't fit the grid are left out".

Assumes canonical 2-D BCOO (``n_batch == n_dense == 0``) with unique
index pairs, which is what ``BCOO.fromdense`` / ``data.synthetic.to_bcoo``
produce. Duplicate indices would sum (matching ``todense``) but break the
bit-exact dense/sparse parity contract, so ``validate_bcoo`` documents
the requirement.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import sparse as jsparse

__all__ = [
    "is_bcoo",
    "validate_bcoo",
    "density",
    "abs_degree_sums",
    "scale_rows_cols",
    "gather_cols_dense",
    "gather_rows_dense",
    "EllOperator",
    "EllPlan",
    "ell_plan",
    "ell_apply",
    "to_ell",
    "is_ell",
    "ell_matvec",
    "ell_rmatvec",
    "ell_abs_degree_sums",
    "ell_scale_rows_cols",
    "is_tiled",
    "to_tiled",
    "tiled_abs_degree_sums",
    "tiled_scale_rows_cols",
    "SPMM_IMPLS",
    "validate_spmm_impl",
    "prepare_operator",
]


def is_bcoo(a) -> bool:
    """True if ``a`` is a ``jax.experimental.sparse`` BCOO matrix."""
    return isinstance(a, jsparse.BCOO)


def validate_bcoo(a: jsparse.BCOO) -> jsparse.BCOO:
    """Check the sparse path's input contract (2-D BCOO, no batch/dense dims)."""
    if not is_bcoo(a):
        raise ValueError(
            f"sparse path needs a jax.experimental.sparse BCOO matrix, got "
            f"{type(a).__name__}")
    if a.ndim != 2:
        raise ValueError(f"sparse path needs a 2-D BCOO matrix, got shape {a.shape}")
    if a.n_batch != 0 or a.n_dense != 0:
        raise ValueError(
            f"sparse path needs canonical BCOO (n_batch=n_dense=0), got "
            f"n_batch={a.n_batch}, n_dense={a.n_dense}")
    return a


def density(a: jsparse.BCOO) -> float:
    """Static nnz fraction (``nse`` is static, so this is a python float)."""
    m, n = a.shape
    return a.nse / float(m * n)


def abs_degree_sums(a: jsparse.BCOO) -> tuple[jax.Array, jax.Array]:
    """Row/col sums of ``|A|`` — the bipartite degrees of Eq. 5, O(nnz)."""
    rows, cols = a.indices[:, 0], a.indices[:, 1]
    av = jnp.abs(a.data)
    d1 = jax.ops.segment_sum(av, rows, num_segments=a.shape[0])
    d2 = jax.ops.segment_sum(av, cols, num_segments=a.shape[1])
    return d1, d2


def scale_rows_cols(a: jsparse.BCOO, s1: jax.Array, s2: jax.Array) -> jsparse.BCOO:
    """``diag(s1) @ A @ diag(s2)`` without leaving BCOO (same sparsity)."""
    rows, cols = a.indices[:, 0], a.indices[:, 1]
    data = a.data * s1[rows] * s2[cols]
    return jsparse.BCOO((data, a.indices), shape=a.shape,
                        indices_sorted=a.indices_sorted,
                        unique_indices=a.unique_indices)


def gather_cols_dense(a: jsparse.BCOO, cols: jax.Array) -> jax.Array:
    """Dense ``A[:, cols]`` of shape ``(M, q)`` from a BCOO, O(nnz).

    This is the anchor-feature gather of the merge phase: ``q`` is tiny
    (``signature_dim``), so the output is a sliver — the full matrix is
    never densified. Columns outside ``cols`` scatter to an out-of-range
    sentinel and are dropped.
    """
    m, n = a.shape
    q = cols.shape[0]
    inv = jnp.full((n,), q, jnp.int32).at[cols].set(
        jnp.arange(q, dtype=jnp.int32))
    pc = inv[a.indices[:, 1]]
    out = jnp.zeros((m, q), a.data.dtype)
    return out.at[a.indices[:, 0], pc].add(a.data, mode="drop")


def gather_rows_dense(a: jsparse.BCOO, rows: jax.Array) -> jax.Array:
    """Dense ``A[rows, :]`` of shape ``(q, N)`` from a BCOO, O(nnz)."""
    m, n = a.shape
    q = rows.shape[0]
    inv = jnp.full((m,), q, jnp.int32).at[rows].set(
        jnp.arange(q, dtype=jnp.int32))
    pr = inv[a.indices[:, 0]]
    out = jnp.zeros((q, n), a.data.dtype)
    return out.at[pr, a.indices[:, 1]].add(a.data, mode="drop")


# ---------------------------------------------------------------------------
# Dual-ELL operator: gather-only SpMM for repeated products
# ---------------------------------------------------------------------------


class EllOperator(NamedTuple):
    """Padded-row (ELL) layout of a sparse matrix, in *both* orientations.

    A COO scatter (segment-sum) pays the scatter unit on every product;
    the subspace iteration multiplies by the same matrix ~10 times per
    SVD, so the sparse atom phase converts once and makes every product
    gather-only: ``out[i] = sum_w vals[i, w] * x[cols[i, w]]`` — dense
    einsum over a ``(M, W)`` layout, W = max nonzeros per row. Padding
    slots carry value 0 / index 0, contributing exactly nothing. The
    transpose orientation is precomputed (``col_*``) so ``A.T @ Q`` is
    the same gather-only product; nothing is resorted at product time.

    Built host-side (``to_ell``) because W is data-dependent; the arrays
    are an ordinary pytree, so the operator passes straight into jitted
    code (retracing only when W changes). Skewed rows inflate W toward N
    — ELL is the right layout for the quasi-uniform document-term
    sparsity the benchmarks model, not for power-law adjacency.
    """

    row_vals: jax.Array    # (M, W)  values, 0-padded
    row_cols: jax.Array    # (M, W)  column of each value, 0-padded
    col_vals: jax.Array    # (N, Wt) transpose orientation
    col_rows: jax.Array    # (N, Wt)

    # shape is derived, not a field: NamedTuple fields are pytree leaves,
    # and a (m, n) int tuple would turn into tracers under jit.
    @property
    def shape(self) -> tuple[int, int]:
        return self.row_vals.shape[0], self.col_vals.shape[0]

    @property
    def dtype(self):
        return self.row_vals.dtype


def is_ell(a) -> bool:
    return isinstance(a, EllOperator)


class _EllSidePlan(NamedTuple):
    """Pattern half of one ELL orientation: where each value lands."""

    r_sorted: np.ndarray   # (nnz,) destination row per sorted value
    slot: np.ndarray       # (nnz,) destination slot per sorted value
    order: np.ndarray      # (nnz,) stable sort permutation of the values
    ell_idx: jax.Array     # (m, width) gather indices (pattern-only)
    m: int
    width: int


class EllPlan(NamedTuple):
    """Reusable pattern half of a BCOO -> dual-ELL conversion.

    The ``core.opcache`` analogue of ``kernels.spmm.BlockSparsePlan``:
    both orientations' sort/slot layouts plus the (values-independent)
    gather-index grids, so a values refresh is two fancy scatters.
    """

    row: _EllSidePlan
    col: _EllSidePlan


def _ell_side(rows: np.ndarray, cols: np.ndarray, m: int) -> _EllSidePlan:
    counts = np.bincount(rows, minlength=m)
    width = max(int(counts.max()) if counts.size else 0, 1)
    order = np.argsort(rows, kind="stable")
    r_sorted = rows[order]
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot = np.arange(len(rows)) - starts[r_sorted]
    ell_idx = np.zeros((m, width), np.int32)
    ell_idx[r_sorted, slot] = cols[order]
    return _EllSidePlan(r_sorted=r_sorted, slot=slot, order=order,
                        ell_idx=jnp.asarray(ell_idx), m=m, width=width)


def _ell_side_vals(p: _EllSidePlan, vals: np.ndarray) -> jax.Array:
    ell_vals = np.zeros((p.m, p.width), np.float32)
    ell_vals[p.r_sorted, p.slot] = vals[p.order]
    return jnp.asarray(ell_vals)


def ell_plan(a: jsparse.BCOO) -> EllPlan:
    """Pattern half of the dual-ELL conversion (sorting, slots, widths)."""
    m, n = a.shape
    rows = np.asarray(a.indices[:, 0])
    cols = np.asarray(a.indices[:, 1])
    return EllPlan(row=_ell_side(rows, cols, m), col=_ell_side(cols, rows, n))


def ell_apply(plan: EllPlan, data) -> EllOperator:
    """Values half: scatter fresh values through a cached pattern plan."""
    vals = np.asarray(data, dtype=np.float32)
    return EllOperator(
        row_vals=_ell_side_vals(plan.row, vals), row_cols=plan.row.ell_idx,
        col_vals=_ell_side_vals(plan.col, vals), col_rows=plan.col.ell_idx,
    )


def to_ell(a: jsparse.BCOO, cache=None) -> EllOperator:
    """One-time host-side conversion BCOO -> dual-ELL (O(nnz)).

    With a ``core.opcache.PatternCache``, repeated conversions of the
    same sparsity pattern skip the sort/slot pattern pass (values-only
    refresh) or the whole conversion (same data object).
    """
    validate_bcoo(a)
    if cache is None:
        return ell_apply(ell_plan(a), a.data)
    return cache.convert(
        a, ("ell",),
        plan_fn=lambda x: ((p := ell_plan(x)), ell_apply(p, x.data)),
        apply_fn=ell_apply)


def ell_matvec(a: EllOperator, x: jax.Array) -> jax.Array:
    """``A @ x`` — gather rows of ``x``, one fused multiply-reduce."""
    return jnp.einsum("mw,mwr->mr", a.row_vals, x[a.row_cols])


def ell_rmatvec(a: EllOperator, x: jax.Array) -> jax.Array:
    """``A.T @ x`` via the precomputed transpose orientation."""
    return jnp.einsum("nw,nwr->nr", a.col_vals, x[a.col_rows])


def ell_abs_degree_sums(a: EllOperator) -> tuple[jax.Array, jax.Array]:
    """Bipartite degrees — padding is exact zero, so plain row sums."""
    return jnp.sum(jnp.abs(a.row_vals), 1), jnp.sum(jnp.abs(a.col_vals), 1)


def ell_scale_rows_cols(a: EllOperator, s1: jax.Array,
                        s2: jax.Array) -> EllOperator:
    """``diag(s1) @ A @ diag(s2)`` in ELL form (both orientations)."""
    return a._replace(
        row_vals=a.row_vals * s1[:, None] * s2[a.row_cols],
        col_vals=a.col_vals * s2[:, None] * s1[a.col_rows],
    )


# ---------------------------------------------------------------------------
# Tiled block-sparse operator: MXU-resident SpMM for repeated products
# ---------------------------------------------------------------------------


def is_tiled(a) -> bool:
    """True if ``a`` is a ``kernels.spmm.BlockSparseMatrix`` operand."""
    try:
        from repro.kernels.spmm import BlockSparseMatrix
    except ImportError:  # kernels unavailable (minimal install)
        return False
    return isinstance(a, BlockSparseMatrix)


def to_tiled(a: jsparse.BCOO, bm: int = 128, bk: int = 128, *, cache=None):
    """One-time conversion BCOO -> tile-level block-sparse.

    The counterpart of ``to_ell`` for the MXU regime: only tiles holding
    nonzeros keep a dense payload, and every subsequent product is a
    batched ``(bm, bk) @ (bk, r)`` contraction (``kernels.ops.spmm_tiled``
    / the fused ``spmm_ata``) whose cost scales with *tile occupancy*
    instead of per-element gathers. Preferred above the dual-ELL
    crossover density (``probability.spmm_route``), where gather width
    makes ELL products nnz-bound. Runs as a jitted device scan/scatter
    on TPU and vectorized numpy elsewhere (``kernels.spmm``); a
    ``core.opcache.PatternCache`` makes repeat conversions of a stable
    sparsity pattern values-only (or free for an identical matrix).
    """
    from repro.kernels.spmm import (
        block_sparse_apply,
        block_sparse_plan,
    )

    validate_bcoo(a)

    def _plan_fn(x):
        plan = block_sparse_plan(x, bm=bm, bk=bk)
        return plan, block_sparse_apply(plan, x.data)

    if cache is None:
        return _plan_fn(a)[1]
    return cache.convert(a, ("tiled", bm, bk), plan_fn=_plan_fn,
                         apply_fn=block_sparse_apply)


def _tile_pad(v: jax.Array, tiles: int, width: int) -> jax.Array:
    """(L,) vector -> (tiles, width) grid view, zero-padded."""
    return jnp.pad(v, (0, tiles * width - v.shape[0])).reshape(tiles, width)


def tiled_abs_degree_sums(a) -> tuple[jax.Array, jax.Array]:
    """Bipartite degrees of Eq. 5 from the payload tiles, O(G * bm * bk)."""
    a = a.materialize_scales()  # degrees of the *effective* operator
    bm, bk = a.tile_shape
    n_tr, n_tc = a.n_tiles
    av = jnp.abs(a.blocks)
    d1 = jax.ops.segment_sum(jnp.sum(av, axis=2), a.block_rows,
                             num_segments=n_tr).reshape(n_tr * bm)
    d2 = jax.ops.segment_sum(jnp.sum(av, axis=1), a.block_cols,
                             num_segments=n_tc).reshape(n_tc * bk)
    return d1[: a.shape[0]], d2[: a.shape[1]]


def tiled_scale_rows_cols(a, s1: jax.Array, s2: jax.Array):
    """``diag(s1) @ A @ diag(s2)`` on the payload tiles (same tiling).

    Padding cells hold exact zeros, so the (arbitrary) padded scale
    entries multiply nothing.

    On the Pallas/interpret tiers the scales are attached *lazily*
    (``row_scale``/``col_scale`` grid views) and applied to each tile in
    VMEM by the SpMM kernels — the normalized operator never exists as a
    second block stack in HBM. The jnp tier folds them into the payloads
    here, eagerly: its tile reference has no fused variant, and an
    unfused lazy scale inside the subspace iteration's ``fori_loop``
    would be re-applied every iteration. Both forms use the identical
    multiply order, so results are bit-exact across tiers.
    """
    bm, bk = a.tile_shape
    n_tr, n_tc = a.n_tiles
    rs = _tile_pad(s1, n_tr, bm)                       # (n_tr, bm)
    cs = _tile_pad(s2, n_tc, bk)                       # (n_tc, bk)
    import repro.kernels.spmm as _spmm
    from repro.kernels import ops as _kops

    if _kops.tiled_scale_fusion():
        if a.row_scale is not None:                    # compose scalings
            rs = a.row_scale * rs
            cs = a.col_scale * cs
        return _spmm.BlockSparseMatrix(
            blocks=a.blocks, block_rows=a.block_rows,
            block_cols=a.block_cols, t_order=a.t_order, shape=a.shape,
            row_scale=rs, col_scale=cs)
    am = a.materialize_scales()
    s1t = rs[a.block_rows]                             # (G, bm)
    s2t = cs[a.block_cols]                             # (G, bk)
    return _spmm.BlockSparseMatrix(
        blocks=am.blocks * s1t[:, :, None] * s2t[:, None, :],
        block_rows=a.block_rows, block_cols=a.block_cols,
        t_order=a.t_order, shape=a.shape)


# ---------------------------------------------------------------------------
# SpMM backend selection
# ---------------------------------------------------------------------------

#: Valid values for the ``spmm_impl`` knob threaded through LAMCConfig /
#: StreamConfig -> scc/randomized_svd. ``auto`` resolves per matrix from
#: its nnz density (``probability.spmm_route``).
SPMM_IMPLS = ("auto", "dense", "dual_ell", "tiled")


def validate_spmm_impl(impl: str) -> str:
    """Shared guard for the ``spmm_impl`` knob — one message, every driver."""
    if impl not in SPMM_IMPLS:
        raise ValueError(
            f"spmm_impl must be one of {SPMM_IMPLS}, got {impl!r}")
    return impl


def prepare_operator(a: jsparse.BCOO, impl: str, *, bm: int = 128,
                     bk: int = 128, cache="default"):
    """Conversion of a BCOO matrix to the routed SpMM operand.

    ``impl`` must be a *resolved* route (``dense`` | ``dual_ell`` |
    ``tiled`` — resolve ``auto`` first via ``probability.spmm_route``).
    Conversions go through the process-wide pattern cache
    (``core.opcache``) by default, so the resample loop and streaming
    re-chunks that keep a sparsity pattern pay the pattern pass once and
    refresh values only (``cache=None`` bypasses; ``REPRO_TILED_CACHE=0``
    disables globally). ``dense`` returns the densified matrix (the
    caller decided sparsity is not worth the format).
    """
    from repro.core import opcache

    validate_bcoo(a)
    if cache == "default":
        cache = opcache.default_cache() if opcache.cache_enabled() else None
    if impl == "dense":
        return a.todense()
    if impl == "dual_ell":
        return to_ell(a, cache=cache)
    if impl == "tiled":
        return to_tiled(a, bm=bm, bk=bk, cache=cache)
    raise ValueError(
        f"impl must be a resolved route ('dense', 'dual_ell' or 'tiled'), "
        f"got {impl!r}")
