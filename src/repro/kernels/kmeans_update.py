"""Pallas TPU kernel: fused one-pass Lloyd iteration (assign + accumulate).

``kmeans_assign`` answers "which centroid?"; a full Lloyd iteration also
needs the *update* statistics — per-centroid coordinate sums and member
counts. The jnp reference does that with three passes over ``x`` (assign,
``one_hot.T @ x``, count reduction) and materializes a ``(P, K)`` one-hot
in HBM. This kernel fuses all of it: per point-tile it

  1. computes ``d2 = |x|^2 - 2 x @ c^T + |c|^2`` on the MXU,
  2. takes argmin labels / min distances,
  3. builds the *tile-local* one-hot in VMEM (never written to HBM) and
     accumulates ``sums += one_hot^T @ x`` (a second MXU contraction) and
     ``counts += sum(one_hot)`` into carried output blocks,

so one Lloyd iteration reads ``x`` from HBM exactly once and writes only
``(K, D) + (1, K)`` accumulators plus the labels.

Weighted k-means folds weights into the one-hot (``one_hot * w``), which
also makes padded points (weight 0) contribute nothing — the wrapper in
``ops.py`` exploits this for point padding.

VMEM budget per grid step (DESIGN.md §4): ``tile_p*D`` (x tile) + ``K*D``
(centroids) + ``tile_p*K`` (d2 + one-hot) + ``K*D + K`` (accumulators)
floats — e.g. tile_p=512, D=256, K=64: ~1.1 MB, far under the ~16 MB/core
of a v5e, leaving headroom for double-buffering.

Grid: ``(ceil(P / tile_p),)`` — sequential on TPU, so the accumulator
blocks (index_map pinned to block 0) carry across steps; step 0 zeroes
them via ``pl.when``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["kmeans_update_pallas"]


def _kernel(x_ref, c_ref, w_ref, labels_ref, d2_ref, sums_ref, counts_ref):
    @pl.when(pl.program_id(0) == 0)
    def _zero_accumulators():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...].astype(jnp.float32)               # (TP, D)
    c = c_ref[...].astype(jnp.float32)               # (K, D)
    w = w_ref[...].astype(jnp.float32)               # (TP,)
    tp = x.shape[0]
    k = c.shape[0]

    x2 = jnp.sum(x * x, axis=-1, keepdims=True)      # (TP, 1)
    c2 = jnp.sum(c * c, axis=-1)                     # (K,)
    xc = jax.lax.dot_general(
        x, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (TP, K) on the MXU
    d2 = x2 - 2.0 * xc + c2[None, :]
    labels = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    labels_ref[...] = labels
    d2_ref[...] = jnp.maximum(jnp.min(d2, axis=-1), 0.0)

    # Tile-local weighted one-hot — lives only in VMEM.
    ids = jax.lax.broadcasted_iota(jnp.int32, (tp, k), 1)
    onehot = jnp.where(ids == labels[:, None], w[:, None], 0.0)   # (TP, K)
    sums_ref[...] += jax.lax.dot_general(
        onehot, x,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (K, D) on the MXU
    counts_ref[...] += jnp.sum(onehot, axis=0)[None, :]           # (1, K)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def kmeans_update_pallas(
    x: jax.Array,          # (P, D) — P and D already padded by ops.py
    centroids: jax.Array,  # (K, D) — K padded with +1e6-distance sentinels
    weights: jax.Array,    # (P,) — padded points carry weight 0
    tile_p: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Raw kernel invocation; returns ``(labels, d2, sums, counts)`` with
    ``counts`` shaped ``(1, K)``. Use ``repro.kernels.ops.kmeans_update``
    for the shape-safe public wrapper (padding, sentinels, CPU fallback)."""
    p, d = x.shape
    k, _ = centroids.shape
    grid = (pl.cdiv(p, tile_p),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_p, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.int32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(x, centroids, weights)
