"""Pallas TPU kernels: tiled k-means assignment (distance + argmin) and
cosine scoring (dot + argmax) against a signature table.

The paper's hottest inner loop: every k-means iteration on every block
assigns ``P`` points to ``K`` centroids. The kernel tiles points into VMEM
blocks of ``tile_p`` rows, keeps the (small) centroid table resident in
VMEM, and computes

    d2 = |x|^2 - 2 x @ c^T + |c|^2

with the ``x @ c^T`` contraction on the MXU (``preferred_element_type``
pinned to f32 so bf16 inputs accumulate in f32). Outputs are per-point
argmin labels and min distances.

``cosine_assign_pallas`` is the serving twin (online assignment of new
rows/cols to a fitted co-clustering, DESIGN.md §10): same tiling, but the
score is the raw dot ``x @ s^T`` against *unit-normalized* cluster
signatures and the reduction is an argmax. For unit signatures the dot
ordering equals the Euclidean ordering (``|x - s|^2 = |x|^2 - 2 x.s + 1``),
so no norms are needed; padded signature rows are masked to -inf via the
static ``k_valid`` so they can never win.

VMEM budget per grid step: ``tile_p*D + K*D + tile_p*K`` floats — e.g.
(512 x 256) + (64 x 256) + (512 x 64) ~ 0.7 MB, comfortably under the
~16 MB/core VMEM of a v5e, leaving room for double-buffering.

Grid: ``(ceil(P / tile_p),)`` — 1-D over point tiles; centroids are
broadcast to every step (index_map returns block 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["kmeans_assign_pallas", "cosine_assign_pallas",
           "cosine_topk_pallas"]


def _kernel(x_ref, c_ref, labels_ref, d2_ref):
    x = x_ref[...].astype(jnp.float32)               # (TP, D)
    c = c_ref[...].astype(jnp.float32)               # (K, D)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)      # (TP, 1)
    c2 = jnp.sum(c * c, axis=-1)                     # (K,)
    xc = jax.lax.dot_general(
        x, c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (TP, K) on the MXU
    d2 = x2 - 2.0 * xc + c2[None, :]
    labels_ref[...] = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    d2_ref[...] = jnp.maximum(jnp.min(d2, axis=-1), 0.0)


@functools.partial(jax.jit, static_argnames=("tile_p", "interpret"))
def kmeans_assign_pallas(
    x: jax.Array,          # (P, D) — P and D already padded by ops.py
    centroids: jax.Array,  # (K, D) — K padded with +inf-distance sentinels
    tile_p: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel invocation. Use ``repro.kernels.ops.kmeans_assign`` for the
    shape-safe public wrapper (padding, sentinel handling, CPU fallback)."""
    p, d = x.shape
    k, _ = centroids.shape
    grid = (pl.cdiv(p, tile_p),)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_p, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.int32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centroids)


def _cosine_kernel(k_valid, x_ref, s_ref, labels_ref, score_ref):
    x = x_ref[...].astype(jnp.float32)               # (TP, D)
    s = s_ref[...].astype(jnp.float32)               # (K, D)
    xs = jax.lax.dot_general(
        x, s,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (TP, K) on the MXU
    # mask padded signature rows: zero-padded rows score 0, which would
    # beat any all-negative real row — force them unselectable instead
    valid = jax.lax.broadcasted_iota(jnp.int32, xs.shape, 1) < k_valid
    xs = jnp.where(valid, xs, -jnp.inf)
    labels_ref[...] = jnp.argmax(xs, axis=-1).astype(jnp.int32)
    score_ref[...] = jnp.max(xs, axis=-1)


def _cosine_topk_kernel(k_valid, k_top, x_ref, s_ref, labels_ref, score_ref):
    x = x_ref[...].astype(jnp.float32)               # (TP, D)
    s = s_ref[...].astype(jnp.float32)               # (K, D)
    xs = jax.lax.dot_general(
        x, s,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (TP, K) on the MXU
    valid = jax.lax.broadcasted_iota(jnp.int32, xs.shape, 1) < k_valid
    xs = jnp.where(valid, xs, -jnp.inf)
    # iterative select-and-mask: k_top is static and small, so this
    # unrolls to k_top argmax/VPU passes over the VMEM-resident (TP, K)
    # score tile — no sort network, no HBM traffic. Ties go to the lower
    # cluster id each round, matching jax.lax.top_k (the ref oracle).
    labs, scores = [], []
    for _ in range(k_top):
        lab = jnp.argmax(xs, axis=-1).astype(jnp.int32)   # (TP,)
        scores.append(jnp.max(xs, axis=-1))
        labs.append(lab)
        taken = jax.lax.broadcasted_iota(jnp.int32, xs.shape, 1) == lab[:, None]
        xs = jnp.where(taken, -jnp.inf, xs)
    labels_ref[...] = jnp.stack(labs, axis=1)
    score_ref[...] = jnp.stack(scores, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("k_valid", "k_top", "tile_p", "interpret"))
def cosine_topk_pallas(
    x: jax.Array,           # (P, D) — P and D already padded by ops.py
    signatures: jax.Array,  # (K, D) — K padded with zero rows
    k_valid: int,
    k_top: int,
    tile_p: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Top-``k_top`` signature scoring: the multi-assignment serving twin
    of :func:`cosine_assign_pallas` (DESIGN.md §11). Returns
    ``(labels (P, k_top) int32, scores (P, k_top) f32)`` ordered by
    descending score. Use ``repro.kernels.ops.cosine_topk`` for the
    shape-safe public wrapper (padding, k validation, CPU fallback)."""
    p, d = x.shape
    k, _ = signatures.shape
    grid = (pl.cdiv(p, tile_p),)
    return pl.pallas_call(
        functools.partial(_cosine_topk_kernel, k_valid, k_top),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_p, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p, k_top), lambda i: (i, 0)),
            pl.BlockSpec((tile_p, k_top), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, k_top), jnp.int32),
            jax.ShapeDtypeStruct((p, k_top), jnp.float32),
        ],
        interpret=interpret,
    )(x, signatures)


@functools.partial(jax.jit, static_argnames=("k_valid", "tile_p", "interpret"))
def cosine_assign_pallas(
    x: jax.Array,           # (P, D) — P and D already padded by ops.py
    signatures: jax.Array,  # (K, D) — K padded with zero rows
    k_valid: int,
    tile_p: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Raw kernel invocation. Use ``repro.kernels.ops.cosine_assign`` for
    the shape-safe public wrapper (padding, CPU fallback)."""
    p, d = x.shape
    k, _ = signatures.shape
    grid = (pl.cdiv(p, tile_p),)
    return pl.pallas_call(
        functools.partial(_cosine_kernel, k_valid),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_p, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_p,), lambda i: (i,)),
            pl.BlockSpec((tile_p,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p,), jnp.int32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
        ],
        interpret=interpret,
    )(x, signatures)
