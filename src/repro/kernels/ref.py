"""Pure-jnp reference oracles for every Pallas kernel.

Each function is the semantic ground truth its kernel twin is tested
against (``tests/test_kernels_*.py`` sweeps shapes/dtypes and
``assert_allclose``s). They are also the CPU execution path selected by
``ops.py`` when no TPU is present.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["kmeans_assign_ref", "kmeans_update_ref", "cosine_assign_ref",
           "cosine_topk_ref", "bipartite_normalize_ref", "attention_ref",
           "spmm_ref", "spmm_block_ref", "sddmm_ref"]


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array):
    """Nearest-centroid assignment: (labels int32, min squared distance)."""
    x = x.astype(jnp.float32)
    c = centroids.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)
    d2 = x2 - 2.0 * (x @ c.T) + c2[None, :]
    return jnp.argmin(d2, axis=-1).astype(jnp.int32), jnp.maximum(jnp.min(d2, -1), 0.0)


def kmeans_update_ref(x: jax.Array, centroids: jax.Array,
                      weights: jax.Array | None = None):
    """Fused Lloyd-iteration oracle: ``(labels, d2, sums, counts)``.

    ``sums[k] = sum_{i: labels[i]==k} w[i] * x[i]`` and
    ``counts[k] = sum_{i: labels[i]==k} w[i]`` — the statistics one Lloyd
    step needs to form new centroids. This is the deliberately-naive
    three-pass / materialized-one-hot formulation the fused kernel is
    measured against.
    """
    labels, d2 = kmeans_assign_ref(x, centroids)
    k = centroids.shape[0]
    onehot = jax.nn.one_hot(labels, k, dtype=jnp.float32)          # (P, K)
    if weights is not None:
        onehot = onehot * weights.astype(jnp.float32)[:, None]
    sums = onehot.T @ x.astype(jnp.float32)                        # (K, D)
    counts = jnp.sum(onehot, axis=0)                               # (K,)
    return labels, d2, sums, counts


def cosine_assign_ref(x: jax.Array, signatures: jax.Array):
    """Dot-score assignment against unit signatures: (labels int32, score).

    ``score[i] = max_k x[i] . signatures[k]`` — for unit-normalized
    signature rows this orders identically to Euclidean distance
    (``|x - s|^2 = |x|^2 - 2 x.s + 1``), so it is the serving-side scoring
    rule of the fitted co-cluster model (DESIGN.md §10).
    """
    xs = x.astype(jnp.float32) @ signatures.astype(jnp.float32).T   # (P, K)
    return jnp.argmax(xs, axis=-1).astype(jnp.int32), jnp.max(xs, axis=-1)


def cosine_topk_ref(x: jax.Array, signatures: jax.Array, k: int):
    """Top-``k`` dot-score assignment: ``(labels (P, k), scores (P, k))``.

    The multi-assignment serving oracle (DESIGN.md §11): the ``k`` best
    clusters per point by cosine against unit signatures, descending.
    ``jax.lax.top_k`` breaks ties toward the lower cluster id — the same
    order as iterating argmax-and-mask, which is what the Pallas twin
    does. Row ``[:, 0]`` equals :func:`cosine_assign_ref` exactly.
    """
    xs = x.astype(jnp.float32) @ signatures.astype(jnp.float32).T   # (P, K)
    scores, labels = jax.lax.top_k(xs, k)
    return labels.astype(jnp.int32), scores


def bipartite_normalize_ref(a: jax.Array, d1: jax.Array, d2: jax.Array,
                            eps: float = 1e-8):
    """``A * rsqrt(max(d1,eps))[:,None] * rsqrt(max(d2,eps))[None,:]``."""
    s1 = jax.lax.rsqrt(jnp.maximum(d1.astype(jnp.float32), eps))
    s2 = jax.lax.rsqrt(jnp.maximum(d2.astype(jnp.float32), eps))
    return (a.astype(jnp.float32) * s1[:, None] * s2[None, :]).astype(a.dtype)


def spmm_ref(data: jax.Array, rows: jax.Array, cols: jax.Array,
             n_out: int, b: jax.Array) -> jax.Array:
    """Element-level SpMM oracle: ``out[r] += v * b[c]`` per nonzero.

    ``(data, rows, cols)`` are the COO triplets of a sparse ``A`` whose
    output axis has ``n_out`` entries; computes ``A @ b`` as a gather of
    rhs rows followed by a segment-sum over the output axis — O(nnz * r),
    fully jittable (``nse`` static). ``A.T @ b`` is the same call with
    ``rows``/``cols`` swapped; the ops wrapper does that.
    """
    contrib = data.astype(jnp.float32)[:, None] * b.astype(jnp.float32)[cols]
    return jax.ops.segment_sum(contrib, rows, num_segments=n_out)


def spmm_block_ref(blocks: jax.Array, block_rows: jax.Array,
                   block_cols: jax.Array, n_tile_rows: int, n_tile_cols: int,
                   b: jax.Array, transpose: bool = False) -> jax.Array:
    """Tile-level SpMM oracle: one batched tile GEMM + a tile segment-sum.

    ``blocks (G, bm, bk)`` with tile coordinates ``block_rows``/
    ``block_cols`` is the ``spmm.BlockSparseMatrix`` payload list; ``b``
    must be padded to the tile grid on its contracted axis
    (``n_tile_cols * bk`` rows, or ``n_tile_rows * bm`` when
    ``transpose``). Semantically identical to ``spmm_ref`` on the
    expanded COO triplets; it is also the fast CPU execution path for
    ``ops.spmm_tiled`` — a batched ``(bm, bk) @ (bk, r)`` einsum keeps
    the contraction in the BLAS batch unit instead of the per-element
    scatter unit, so its cost scales with tile occupancy, not nnz.
    """
    g, bm, bk = blocks.shape
    bf = b.astype(jnp.float32)
    if transpose:
        tiles = bf.reshape(n_tile_rows, bm, -1)
        contrib = jnp.einsum("gab,gar->gbr", blocks.astype(jnp.float32),
                             tiles[block_rows])
        out = jax.ops.segment_sum(contrib, block_cols,
                                  num_segments=n_tile_cols)
        return out.reshape(n_tile_cols * bk, -1)
    tiles = bf.reshape(n_tile_cols, bk, -1)
    contrib = jnp.einsum("gab,gbr->gar", blocks.astype(jnp.float32),
                         tiles[block_cols])
    out = jax.ops.segment_sum(contrib, block_rows, num_segments=n_tile_rows)
    return out.reshape(n_tile_rows * bm, -1)


def sddmm_ref(x: jax.Array, y: jax.Array, rows: jax.Array,
              cols: jax.Array) -> jax.Array:
    """Sampled dense-dense matmul oracle: ``(x @ y.T)`` at sparse positions.

    Returns the ``(nnz,)`` values ``sum_d x[rows, d] * y[cols, d]`` — the
    building block for sparse residuals / graph-regularized variants;
    gather-dot, never materializes the ``(M, N)`` product.
    """
    xf, yf = x.astype(jnp.float32), y.astype(jnp.float32)
    return jnp.sum(xf[rows] * yf[cols], axis=-1)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True):
    """Exact softmax attention. q,k,v: (BH, S, D); f32 math."""
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    scale = 1.0 / (q.shape[-1] ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", qf, kf) * scale
    if causal:
        sq, skv = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vf).astype(q.dtype)
