"""Pallas TPU kernel: blockwise (flash) causal attention, forward pass.

The LM substrate's compute hot spot for train/prefill. Standard flash
recurrence: for each query tile, stream KV tiles through VMEM keeping a
running row-max ``m``, normalizer ``l`` and output accumulator in f32
scratch; never materializes the (Sq, Skv) score matrix.

Grid: ``(B*H, Sq/tile_q, Skv/tile_k)`` — the innermost (KV) axis is
sequential on TPU, which is exactly the flash streaming order. Causal
tiles strictly above the diagonal are skipped via ``pl.when`` (no compute,
no VMEM traffic for the masked region beyond the block fetch).

VMEM per step: ``tile_q*d + 2*tile_k*d + tile_q*tile_k + tile_q*(d+2)``
floats ~ 1.4 MB at (tile_q, tile_k, d) = (512, 512, 128) f32 — room for
double buffering in 16 MB v5e VMEM. MXU contractions pinned to f32
accumulation via ``preferred_element_type``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, tile_q, tile_k, nk, kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * tile_q + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 0)
    k_pos = ki * tile_k + jax.lax.broadcasted_iota(jnp.int32, (tile_q, tile_k), 1)
    # skip tiles strictly above the causal diagonal (no compute for them)
    tile_live = (qi * tile_q + tile_q - 1 >= ki * tile_k) if causal else (ki >= 0)

    @pl.when(tile_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (TQ, D)
        k = k_ref[0].astype(jnp.float32)             # (TK, D)
        v = v_ref[0].astype(jnp.float32)             # (TK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                     # (TQ, TK)
        mask = k_pos < kv_len                        # padded KV tail
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_scr[...]                           # (TQ,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])               # (TQ, TK)
        corr = jnp.exp(m_prev - m_new)                # (TQ,)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "tile_q", "tile_k", "interpret", "kv_len"),
)
def flash_attention_pallas(
    q: jax.Array,    # (BH, Sq, D) — heads already folded, padded by ops.py
    k: jax.Array,    # (BH, Skv, D)
    v: jax.Array,    # (BH, Skv, D)
    *,
    kv_len: int,           # true (unpadded) KV length for masking
    causal: bool = True,
    tile_q: int = 512,
    tile_k: int = 512,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    _, skv, _ = k.shape
    nq = pl.cdiv(sq, tile_q)
    nk = pl.cdiv(skv, tile_k)
    scale = 1.0 / (d ** 0.5)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal,
        tile_q=tile_q, tile_k=tile_k, nk=nk, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tile_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tile_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tile_q,), jnp.float32),
            pltpu.VMEM((tile_q,), jnp.float32),
            pltpu.VMEM((tile_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
