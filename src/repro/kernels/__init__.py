"""Pallas TPU kernels for the perf-critical compute layers.

Layout per kernel: ``<name>.py`` (pl.pallas_call + BlockSpec), with shared
``ops.py`` (jit'd, shape-safe wrappers) and ``ref.py`` (pure-jnp oracles).
On non-TPU backends ops run the kernels in interpret mode (tests) or fall
back to the references.
"""

from . import ops, ref

__all__ = ["ops", "ref"]
