"""Public jit'd wrappers around the Pallas kernels.

Each op:
  * pads inputs to hardware-aligned tile multiples (MXU wants multiples of
    128 in the contracted/lane dims; sublane multiples of 8 for f32),
  * handles semantic edge cases the raw kernels don't (centroid-count
    sentinels, GQA head expansion, unpadding),
  * dispatches: real Pallas lowering on TPU, ``interpret=True`` elsewhere
    (the kernel body executes on CPU — used by the test suite), or the
    pure-jnp reference for very small inputs where padding overhead
    dominates.

Set ``REPRO_FORCE_INTERPRET=1`` to force interpret mode on any backend.

Every wrapper records which tier it dispatched to via
``obs.kernel_dispatch`` (a labeled counter + optional trace event). The
hook runs at *trace time* with static values only — under jit it counts
compiled dispatch decisions, not executions — so it adds nothing to the
lowered program (the obs-enabled jaxpr-audit entries pin this).
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro import obs as _obs
from repro.analysis import vmem

from . import ref
from .bipartite_normalize import scale_apply_pallas
from .flash_attention import flash_attention_pallas
from .kmeans_assign import cosine_assign_pallas, cosine_topk_pallas, kmeans_assign_pallas
from .kmeans_update import kmeans_update_pallas
from .spmm import (
    BlockSparseMatrix,
    bcoo_to_block_sparse,
    spmm_ata_pallas,
    spmm_pallas,
    spmm_t_pallas,
)

__all__ = ["kmeans_assign", "kmeans_update", "cosine_assign", "cosine_topk",
           "bipartite_normalize", "flash_attention", "spmm", "sddmm",
           "spmm_tiled", "spmm_ata", "BlockSparseMatrix",
           "bcoo_to_block_sparse", "tiled_scale_fusion"]


def _interpret() -> bool:
    if os.environ.get("REPRO_FORCE_INTERPRET"):
        return True
    return jax.default_backend() != "tpu"


def _tiled_backend() -> str:
    """Dispatch tier for the tile-level SpMM family.

    ``interpret`` when forced (kernel correctness CI — like
    ``_interpret``, the env switch wins on any backend), ``pallas`` on
    TPU (real lowering), ``jnp`` otherwise: off-TPU the interpret-mode
    grid loop is a correctness tool, not an execution path, so
    production CPU calls use the batched-einsum tile reference
    (``ref.spmm_block_ref``) — same semantics, BLAS-speed.
    """
    if os.environ.get("REPRO_FORCE_INTERPRET"):
        return "interpret"
    if jax.default_backend() == "tpu":
        return "pallas"
    return "jnp"


def tiled_scale_fusion() -> bool:
    """True when the current tiled backend applies pending diagonal
    scales inside the kernels (pallas / interpret tiers).

    ``core.sparse.tiled_scale_rows_cols`` consults this to decide between
    attaching lazy scales (kernel-fused, zero extra HBM) and eagerly
    materializing the scaled block stack (the jnp tier, where the tile
    reference has no fused variant and re-scaling per product inside a
    ``fori_loop`` body would repeat the work every iteration).
    """
    return _tiled_backend() != "jnp"


def _pad_to(x: jax.Array, axis: int, multiple: int, value=0.0) -> jax.Array:
    size = x.shape[axis]
    target = ((size + multiple - 1) // multiple) * multiple
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def kmeans_assign(x: jax.Array, centroids: jax.Array,
                  tile_p: int = 512) -> tuple[jax.Array, jax.Array]:
    """Tiled nearest-centroid assignment. x: (P, D); centroids: (K, D).

    Padded centroids are +1e6 sentinels — farther than any real centroid,
    so argmin never selects them; padded points are sliced off the output.
    """
    p, d = x.shape
    _obs.kernel_dispatch(
        "kmeans_assign", "interpret" if _interpret() else "pallas")
    xp = _pad_to(_pad_to(x, 1, 128), 0, tile_p)
    cp = _pad_to(_pad_to(centroids, 1, 128), 0, 8, value=1e6)
    labels, d2 = kmeans_assign_pallas(xp, cp, tile_p=tile_p, interpret=_interpret())
    return labels[:p], d2[:p]


def cosine_assign(x: jax.Array, signatures: jax.Array,
                  tile_p: int = 512) -> tuple[jax.Array, jax.Array]:
    """Batched signature scoring: argmax of ``x @ signatures.T``.

    The online-serving hot path (``streaming.assign_rows`` /
    ``assign_cols``): score incoming vectors against the fitted model's
    unit-normalized cluster signatures. x: (P, D); signatures: (K, D).
    Padded signature rows are zeros and masked to -inf inside the kernel
    (static ``k_valid``), so they can never be selected; padded points
    are sliced off the output. Returns ``(labels (P,), score (P,))``.
    """
    p, d = x.shape
    k = signatures.shape[0]
    _obs.kernel_dispatch(
        "cosine_assign", "interpret" if _interpret() else "pallas")
    xp = _pad_to(_pad_to(x, 1, 128), 0, tile_p)
    sp = _pad_to(_pad_to(signatures, 1, 128), 0, 8)
    labels, score = cosine_assign_pallas(
        xp, sp, k_valid=k, tile_p=tile_p, interpret=_interpret())
    return labels[:p], score[:p]


def cosine_topk(x: jax.Array, signatures: jax.Array, k: int,
                tile_p: int = 512) -> tuple[jax.Array, jax.Array]:
    """Top-``k`` signature scoring: the multi-assignment serving variant
    of :func:`cosine_assign` (DESIGN.md §11).

    Returns ``(labels (P, k), scores (P, k))`` ordered by descending
    score, ties toward the lower cluster id (matching ``jax.lax.top_k``
    and the k=1 ``cosine_assign`` argmax exactly). ``k`` must not exceed
    the number of real signature rows — padded rows are masked to -inf
    and must never surface in a top-k slot.
    """
    p, d = x.shape
    n_sigs = signatures.shape[0]
    if not 1 <= k <= n_sigs:
        raise ValueError(
            f"top-k width must be in [1, {n_sigs}] (the signature count), "
            f"got k={k}")
    _obs.kernel_dispatch(
        "cosine_topk", "interpret" if _interpret() else "pallas")
    xp = _pad_to(_pad_to(x, 1, 128), 0, tile_p)
    sp = _pad_to(_pad_to(signatures, 1, 128), 0, 8)
    labels, scores = cosine_topk_pallas(
        xp, sp, k_valid=n_sigs, k_top=k, tile_p=tile_p,
        interpret=_interpret())
    return labels[:p], scores[:p]


def kmeans_update(x: jax.Array, centroids: jax.Array,
                  weights: jax.Array | None = None,
                  tile_p: int = 512) -> tuple[jax.Array, jax.Array,
                                              jax.Array, jax.Array]:
    """Fused one-pass Lloyd iteration. x: (P, D); centroids: (K, D).

    Returns ``(labels (P,), d2 (P,), sums (K, D) f32, counts (K,) f32)``
    matching ``ref.kmeans_update_ref``. Padded centroids are +1e6
    sentinels (never argmin-selected, so their sums/counts rows stay
    zero and are sliced off); padded points enter with weight 0, so they
    contribute nothing to the accumulators.
    """
    p, d = x.shape
    k = centroids.shape[0]
    _obs.kernel_dispatch(
        "kmeans_update", "interpret" if _interpret() else "pallas")
    w = jnp.ones((p,), jnp.float32) if weights is None else weights.astype(jnp.float32)
    xp = _pad_to(_pad_to(x, 1, 128), 0, tile_p)
    cp = _pad_to(_pad_to(centroids, 1, 128), 0, 8, value=1e6)
    wp = _pad_to(w, 0, tile_p)
    labels, d2, sums, counts = kmeans_update_pallas(
        xp, cp, wp, tile_p=tile_p, interpret=_interpret())
    return labels[:p], d2[:p], sums[:k, :d], counts[0, :k]


def spmm(a, b: jax.Array, *, transpose: bool = False) -> jax.Array:
    """SpMM against a BCOO matrix: ``A @ b`` (or ``A.T @ b``).

    Jittable everywhere (``nse`` is static): element-level gather +
    segment-sum, the formulation ``randomized_svd`` uses inside the
    jitted sparse atom phase. On TPU, callers that own the matrix for
    many products (the full-matrix sparse SCC baseline) should pre-tile
    once with ``bcoo_to_block_sparse`` and use ``spmm_tiled`` — the
    tile-level kernel keeps the contraction on the MXU instead of the
    scatter unit.
    """
    _obs.kernel_dispatch("spmm", "ref")
    rows, cols = a.indices[:, 0], a.indices[:, 1]
    if transpose:
        rows, cols = cols, rows
    n_out = a.shape[1] if transpose else a.shape[0]
    return ref.spmm_ref(a.data, rows, cols, n_out, b)


def sddmm(x: jax.Array, y: jax.Array, indices: jax.Array) -> jax.Array:
    """Sampled dense-dense matmul: values of ``x @ y.T`` at ``indices``.

    ``indices``: (nnz, 2) row/col pairs (a BCOO's ``.indices``). Pure
    gather-dot — no Pallas twin yet: it is not on the atom hot path
    (needed for future sparse-residual / graph-regularized workloads),
    and per-element dynamic gathers don't map onto TPU DMA without the
    tile-level format ``spmm_tiled`` uses.
    """
    _obs.kernel_dispatch("sddmm", "ref")
    return ref.sddmm_ref(x, y, indices[:, 0], indices[:, 1])


def spmm_tiled(a: BlockSparseMatrix, b: jax.Array, *,
               transpose: bool = False, bn: int = 128) -> jax.Array:
    """Tile-level SpMM: ``A @ b`` (or ``A.T @ b``) with ``A`` pre-tiled.

    ``a`` comes from ``bcoo_to_block_sparse`` (one-time host prep,
    amortized across every product that consumes the operator). ``b`` may
    carry any number of RHS columns — the kernel grids over ``bn``-wide
    column stripes. ``b`` is padded on its contracted axis to the tile
    grid (padded rows multiply zero payload cells only) and, on the
    Pallas tiers, on its column axis to ``bn``; padded output is sliced
    off. Dispatch: TPU -> Pallas kernel; ``REPRO_FORCE_INTERPRET`` ->
    interpret-mode kernel; otherwise the batched-einsum tile reference.
    """
    m, k = a.shape
    bm, bk = a.tile_shape
    n_tr, n_tc = a.n_tiles
    backend = _tiled_backend()
    _obs.kernel_dispatch("spmm_tiled", backend, transpose=transpose,
                         scaled=a.has_scales)
    out_rows = k if transpose else m
    if backend == "jnp":
        # the tile reference has no fused-scale variant: fold pending
        # scales into the payload stack once, outside any product loop
        a = a.materialize_scales()
        bp = _pad_to(b.astype(jnp.float32), 0, bm if transpose else bk)
        out = ref.spmm_block_ref(a.blocks, a.block_rows, a.block_cols,
                                 n_tr, n_tc, bp, transpose=transpose)
        return out[:out_rows, : b.shape[1]]
    interp = backend == "interpret"
    bp = _pad_to(_pad_to(b.astype(jnp.float32), 0, bm if transpose else bk),
                 1, bn)
    if transpose:
        out = spmm_t_pallas(a.block_rows, a.block_cols, a.t_order, a.blocks,
                            bp, k_out=n_tc * bk, bn=bn, interpret=interp,
                            row_scale=a.row_scale, col_scale=a.col_scale)
    else:
        out = spmm_pallas(a.block_rows, a.block_cols, a.blocks, bp,
                          m_out=n_tr * bm, bn=bn, interpret=interp,
                          row_scale=a.row_scale, col_scale=a.col_scale)
    return out[:out_rows, : b.shape[1]]


def spmm_ata(a: BlockSparseMatrix, x: jax.Array, *, bn: int = 128,
             with_gram: bool = False):
    """Fused normal-equations pass: ``A.T @ (A @ x)`` in one sweep.

    The subspace iteration's hot step (DESIGN.md §9): both products of
    one power-iteration application run in a single kernel launch, with
    the ``(M, q)`` intermediate held in VMEM scratch instead of
    round-tripping through HBM. Falls back to two ``spmm_tiled`` calls
    when the resident stripes would not fit the VMEM budget (or on the
    jnp tier, where the composition is already fused by XLA).

    ``with_gram=True`` returns ``(z, gram)`` with ``gram = z.T @ z``
    ``(q, q)`` — the fused subspace-iteration step: on the kernel path
    the Gram comes off the still-VMEM-resident output stripe inside the
    same launch (requires ``x`` to fit one ``bn`` column stripe), so the
    CholeskyQR orthonormalization that follows never re-reads ``z`` from
    HBM. Tiers without the fused kernel compute the same Gram outside.
    """
    m, k = a.shape
    bm, bk = a.tile_shape
    n_tr, n_tc = a.n_tiles
    n = x.shape[1]
    backend = _tiled_backend()
    # the fused in-kernel Gram covers exactly one output column stripe
    gram_in_kernel = with_gram and n <= bn
    if backend == "jnp":
        _obs.kernel_dispatch("spmm_ata", "jnp", fused=False,
                             scaled=a.has_scales, with_gram=with_gram)
        am = a.materialize_scales()
        xp = _pad_to(x.astype(jnp.float32), 0, bk)
        y = ref.spmm_block_ref(am.blocks, am.block_rows, am.block_cols,
                               n_tr, n_tc, xp)
        out = ref.spmm_block_ref(am.blocks, am.block_rows, am.block_cols,
                                 n_tr, n_tc, y, transpose=True)
        out = out[:k, :n]
        if with_gram:
            return out, out.T @ out
        return out
    # fused-kernel residency (Y stripe + output stripe + scales + Gram)
    # priced by the same estimator the A4 static audit uses — one budget,
    # runtime and lint
    stripes = vmem.ata_resident_bytes(n_tr, n_tc, bm, bk, bn,
                                      with_gram=gram_in_kernel,
                                      scaled=a.has_scales)
    budget = vmem.vmem_budget_bytes("tpu")
    if stripes > budget:
        _obs.kernel_dispatch("spmm_ata", backend, fused=False,
                             scaled=a.has_scales, with_gram=with_gram,
                             vmem_bytes=stripes, vmem_budget=budget)
        _obs.get_registry().counter(
            "spmm_ata_vmem_fallback",
            help="fused A.T@(A@x) declined by the VMEM estimator").inc()
        y = spmm_tiled(a, x, bn=bn)
        out = spmm_tiled(a, y, transpose=True, bn=bn)
        if with_gram:
            return out, out.T @ out
        return out
    _obs.kernel_dispatch("spmm_ata", backend, fused=True,
                         scaled=a.has_scales, with_gram=with_gram,
                         vmem_bytes=stripes, vmem_budget=budget)
    interp = backend == "interpret"
    xp = _pad_to(_pad_to(x.astype(jnp.float32), 0, bk), 1, bn)
    res = spmm_ata_pallas(a.block_rows, a.block_cols, a.blocks, xp,
                          m_pad=n_tr * bm, bn=bn, interpret=interp,
                          row_scale=a.row_scale, col_scale=a.col_scale,
                          with_gram=gram_in_kernel)
    if gram_in_kernel:
        out, gram = res
        return out[:k, :n], gram[:n, :n]
    out = res[:k, :n]
    if with_gram:
        return out, out.T @ out
    return out


def bipartite_normalize(a: jax.Array, eps: float = 1e-8,
                        tile_m: int = 256, tile_n: int = 256):
    """Fused ``A_n = D1^{-1/2} A D2^{-1/2}`` (degrees on |A|).

    Returns ``(a_n, d1_isqrt, d2_isqrt)`` with the same contract as
    ``core.spectral.normalize_bipartite``.
    """
    m, n = a.shape
    _obs.kernel_dispatch(
        "bipartite_normalize", "interpret" if _interpret() else "pallas")
    aa = jnp.abs(a)
    d1 = jnp.sum(aa, axis=1)
    d2 = jnp.sum(aa, axis=0)
    ap = _pad_to(_pad_to(a, 0, tile_m), 1, tile_n)
    d1p = _pad_to(d1, 0, tile_m, value=1.0)
    d2p = _pad_to(d2, 0, tile_n, value=1.0)
    out = scale_apply_pallas(ap, d1p, d2p, tile_m=tile_m, tile_n=tile_n,
                             eps=eps, interpret=_interpret())
    d1_isqrt = jax.lax.rsqrt(jnp.maximum(d1, eps))
    d2_isqrt = jax.lax.rsqrt(jnp.maximum(d2, eps))
    return out[:m, :n], d1_isqrt, d2_isqrt


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, tile_q: int = 512,
                    tile_k: int = 512) -> jax.Array:
    """Blockwise attention. q: (B, Hq, Sq, D); k,v: (B, Hkv, Skv, D).

    GQA: ``Hq`` must be a multiple of ``Hkv``; KV heads are expanded here
    (the kernel sees folded (B*H, S, D)). Sequences are padded to tile
    multiples; the kernel masks padded KV columns via ``kv_len`` and padded
    query rows are sliced off.
    """
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    assert hq % hkv == 0, f"GQA heads mismatch: {hq} % {hkv}"
    _obs.kernel_dispatch(
        "flash_attention", "interpret" if _interpret() else "pallas")
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    tq = min(tile_q, max(8, sq))
    tk = min(tile_k, max(128, skv))
    qf = _pad_to(q.reshape(b * hq, sq, dh), 1, tq)
    kf = _pad_to(k.reshape(b * hq, skv, dh), 1, tk)
    vf = _pad_to(v.reshape(b * hq, skv, dh), 1, tk)
    out = flash_attention_pallas(
        qf, kf, vf, kv_len=skv, causal=causal,
        tile_q=tq, tile_k=tk, interpret=_interpret(),
    )
    return out[:, :sq].reshape(b, hq, sq, dh)
