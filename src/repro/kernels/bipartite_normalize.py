"""Pallas TPU kernel: fused bipartite-graph normalization scale-apply.

Computes ``out = A * rsqrt(max(d1,eps))[:,None] * rsqrt(max(d2,eps))[None,:]``
(Eq. 7's ``A_n = D1^{-1/2} A D2^{-1/2}``) in a single pass: the naive jnp
formulation materializes two broadcast intermediates (HBM traffic ~4|A|);
the fused kernel reads A once and writes A_n once (~2|A|), with the rsqrt
folded into the tile compute. Degree sums themselves are row/col reductions
XLA already fuses well; they stay in jnp (see ops.bipartite_normalize).

Grid: 2-D over (row tiles, col tiles). VMEM per step:
``tile_m*tile_n + tile_m + tile_n`` floats — 256 KB at 256 x 256 f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["scale_apply_pallas"]


def _kernel(a_ref, d1_ref, d2_ref, out_ref, *, eps: float):
    a = a_ref[...].astype(jnp.float32)                 # (TM, TN)
    d1 = d1_ref[...].astype(jnp.float32)               # (TM,)
    d2 = d2_ref[...].astype(jnp.float32)               # (TN,)
    s1 = jax.lax.rsqrt(jnp.maximum(d1, eps))
    s2 = jax.lax.rsqrt(jnp.maximum(d2, eps))
    out_ref[...] = (a * s1[:, None] * s2[None, :]).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "eps", "interpret"))
def scale_apply_pallas(
    a: jax.Array,    # (M, N)
    d1: jax.Array,   # (M,) raw row degrees
    d2: jax.Array,   # (N,) raw col degrees
    tile_m: int = 256,
    tile_n: int = 256,
    eps: float = 1e-8,
    interpret: bool = False,
) -> jax.Array:
    m, n = a.shape
    grid = (pl.cdiv(m, tile_m), pl.cdiv(n, tile_n))
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
            pl.BlockSpec((tile_m,), lambda i, j: (i,)),
            pl.BlockSpec((tile_n,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, d1, d2)
