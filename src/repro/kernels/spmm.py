"""Pallas TPU kernels: tiled block-sparse SpMM family (DESIGN.md §9).

The sparse atom phase's hot matmuls are ``A @ Omega`` / ``A.T @ Q`` with
``A`` sparse and the other operand a tall-skinny dense sketch. A BCOO's
per-element indices cannot drive TPU DMA, so the kernels consume a
*tile-level* sparse format: ``A`` is cut into a ``(M/bm, K/bk)`` grid
and only tiles containing nonzeros are kept, as

  * ``blocks``     (G, bm, bk) f32 — dense payload of each surviving tile
  * ``block_rows`` (G,) i32        — tile-row of each payload, sorted
  * ``block_cols`` (G,) i32        — tile-col of each payload
  * ``t_order``    (G,) i32        — payload visit order for transposed
                                     products (sorted by tile-col)
  * ``row_scale``  (n_tr, bm) f32  — optional per-row scale, applied to
    ``col_scale``  (n_tc, bk) f32    the tile *inside* the kernel so a
                                     normalized operator is never
                                     materialized as a second block stack

Conversion runs in two stages so the build is jittable (DESIGN.md §9):
the surviving-tile count ``G`` is data-dependent, so stage 1
(:func:`block_sparse_pattern_device`) reduces the nonzeros to a tile
occupancy bitmap whose population count is the *only* scalar synced to
the host; stage 2 (:func:`block_sparse_build_device`, static ``G``)
derives the tile id list by a prefix-scan over the bitmap and scatters
every value by a precomputed flat offset. Scanning the (small) tile-id
space instead of segment-sorting the nonzeros drops the O(nnz log nnz)
sort entirely — segment boundaries come from ``cumsum(occupancy)``, the
scan analogue of the shifted-compare trick on sorted ids. Off-TPU the
same plan/apply split runs as a vectorized numpy path
(:func:`block_sparse_plan`); ``bcoo_to_block_sparse_host`` keeps the
original union1d/lexsort formulation as the bit-exact oracle for both.
The plan (pattern work) and apply (value scatter) stages are separable
so the pattern-keyed conversion cache (``core.opcache``) can refresh
values only when a resample or re-chunk reuses a sparsity pattern.

Three kernels share the format:

``spmm_pallas``      ``A @ B``: grid ``(N/bn, G)`` — payloads innermost,
    so consecutive steps that share a tile-row revisit the *same* output
    block while it is resident in VMEM. ``block_rows``/``block_cols``
    ride in as scalar-prefetch operands (``pltpu.PrefetchScalarGridSpec``)
    so the index maps can route each payload's B-tile and out-tile before
    the body runs. The output block is zeroed exactly when the tile-row
    changes; the converter seeds every tile-row with at least one payload
    so every output block is visited and initialized.

``spmm_t_pallas``    ``A.T @ B``: the same sweep driven through
    ``t_order`` — payloads visited in tile-col order so the transposed
    product enjoys the identical out-block residency property. The
    converter seeds every tile-*col* too, so both orientations have all
    output tiles initialized.

``spmm_ata_pallas``  fused normal-equations pass ``A.T @ (A @ X)``: one
    kernel launch whose grid sweeps the tile list once per phase
    (``grid = (N/bn, 2, G)``). Phase 0 accumulates the intermediate
    ``Y = A @ X`` stripe into a VMEM scratch; phase 1 streams the same
    payloads again and applies ``out[col] += B.T @ Y[row]`` against the
    still-resident scratch. ``Y`` never round-trips through HBM and the
    two products cost one launch instead of two. With ``with_gram=True``
    the launch is a full fused *subspace-iteration step*: after the last
    payload, the ``(r, r)`` Gram ``Z.T @ Z`` of the still-resident output
    stripe is emitted as a second output, so the CholeskyQR
    orthonormalization (``core.spectral._orth_from_gram``) needs no
    extra pass over ``Z`` — SpMM, Gram and the Cholesky factor's operand
    all come out of one launch.

When ``row_scale``/``col_scale`` are attached (``normalize_bipartite``
on the Pallas tiers), each kernel rescales the payload tile in VMEM as
``tile * rs[:, None] * cs[None, :]`` — the exact multiply order of the
materialized ``tiled_scale_rows_cols`` path, so results stay bit-exact
while ``D_r^{-1/2} A D_c^{-1/2}`` costs no second HBM-resident operator.

Compute per grid step is one ``(bm, bk) @ (bk, bn)`` MXU contraction —
identical to a dense matmul kernel's inner step; the win is skipping the
empty tiles entirely: FLOPs and HBM traffic scale with the *tile-level*
occupancy instead of ``M*K``.

Like every kernel here they run under ``interpret=True`` off-TPU; the
semantics oracles are ``ref.spmm_ref`` (element-level segment-sum) and
``ref.spmm_block_ref`` (tile-level, also the fast jnp CPU path).
"""

from __future__ import annotations

import functools
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["BlockSparseMatrix", "BlockSparsePlan", "bcoo_to_block_sparse",
           "bcoo_to_block_sparse_host", "block_sparse_plan",
           "block_sparse_apply", "block_sparse_pattern_device",
           "block_sparse_build_device", "spmm_pallas", "spmm_t_pallas",
           "spmm_ata_pallas"]


@jax.tree_util.register_pytree_node_class
class BlockSparseMatrix:
    """Tile-level sparse operand for the SpMM kernels.

    A registered pytree whose logical ``shape`` is static aux data, so the
    operand passes through ``jit``/``scan`` boundaries with ``.shape``
    usable for Python-level shape math (the same reason
    ``sparse.EllOperator`` derives its shape instead of storing it).

    ``row_scale``/``col_scale`` (optional, attached together) carry a
    pending diagonal scaling ``diag(rs) @ A @ diag(cs)`` as ``(n_tr, bm)``
    / ``(n_tc, bk)`` grid views. On the Pallas tiers the SpMM kernels
    apply them to the payload tile in VMEM; :meth:`materialize_scales`
    folds them into ``blocks`` (the jnp-tier / oracle form).
    """

    def __init__(self, blocks, block_rows, block_cols, t_order, shape,
                 row_scale=None, col_scale=None):
        self.blocks = blocks            # (G, bm, bk) dense tile payloads
        self.block_rows = block_rows    # (G,) i32 tile-row ids, sorted
        self.block_cols = block_cols    # (G,) i32 tile-col ids
        self.t_order = t_order          # (G,) i32, payloads in tile-col order
        self.shape = tuple(shape)       # logical (M, K) — unpadded, static
        self.row_scale = row_scale      # (n_tr, bm) f32 or None
        self.col_scale = col_scale      # (n_tc, bk) f32 or None

    @property
    def tile_shape(self) -> tuple[int, int]:
        return self.blocks.shape[1], self.blocks.shape[2]

    @property
    def n_tiles(self) -> tuple[int, int]:
        """Tile-grid shape ``(M/bm, K/bk)`` (ceil)."""
        bm, bk = self.tile_shape
        return -(-self.shape[0] // bm), -(-self.shape[1] // bk)

    @property
    def dtype(self):
        return self.blocks.dtype

    @property
    def has_scales(self) -> bool:
        return self.row_scale is not None

    def materialize_scales(self) -> "BlockSparseMatrix":
        """Fold pending scales into the payload stack (one new block stack).

        Multiply order matches the scale-fused kernels exactly
        (``blk * rs[:, None] * cs[None, :]``), so the lazy and
        materialized operators are bit-identical under every product.
        """
        if self.row_scale is None:
            return self
        rs = self.row_scale[self.block_rows]            # (G, bm)
        cs = self.col_scale[self.block_cols]            # (G, bk)
        return BlockSparseMatrix(
            blocks=self.blocks * rs[:, :, None] * cs[:, None, :],
            block_rows=self.block_rows, block_cols=self.block_cols,
            t_order=self.t_order, shape=self.shape)

    def tree_flatten(self):
        return ((self.blocks, self.block_rows, self.block_cols,
                 self.t_order, self.row_scale, self.col_scale), self.shape)

    @classmethod
    def tree_unflatten(cls, shape, children):
        blocks, block_rows, block_cols, t_order, row_scale, col_scale = children
        return cls(blocks, block_rows, block_cols, t_order, shape=shape,
                   row_scale=row_scale, col_scale=col_scale)


class BlockSparsePlan(NamedTuple):
    """Reusable pattern half of a BCOO -> block-sparse conversion.

    Everything derived from the *indices* alone: the surviving-tile list,
    the transposed visit order, and the per-nonzero flat scatter offset
    into the ``(G * bm * bk,)`` payload stack. ``block_sparse_apply``
    turns a plan plus a values vector into a ``BlockSparseMatrix`` — the
    values-only refresh path the pattern cache (``core.opcache``) takes
    when a matrix keeps its sparsity pattern across resamples.
    """

    block_rows: jax.Array       # (G,) i32, sorted
    block_cols: jax.Array       # (G,) i32
    t_order: jax.Array          # (G,) i32
    flat_idx: object            # (nnz,) scatter offsets — np i64 or jnp i32
    g: int                      # surviving tile count (static)
    bm: int
    bk: int
    shape: tuple[int, int]
    on_device: bool             # True -> jitted apply, False -> numpy apply


def bcoo_to_block_sparse_host(a, bm: int = 128,
                              bk: int = 128) -> BlockSparseMatrix:
    """Original host-side conversion — the bit-exact oracle.

    O(nnz) numpy (union1d over tile ids + fancy scatter); retained as the
    semantics reference for the fast plan/apply host path and the jitted
    device path, both tested field-for-field against it. Empty tile-rows
    get one zero payload (tile-col 0) and empty tile-cols one zero
    payload (tile-row 0) so both product orientations initialize every
    output block. Rows are padded up to a ``bm`` multiple, cols to ``bk``.
    """
    m, k = a.shape
    rows = np.asarray(a.indices[:, 0]).astype(np.int64)
    cols = np.asarray(a.indices[:, 1]).astype(np.int64)
    vals = np.asarray(a.data, dtype=np.float32)
    n_tr, n_tc = -(-m // bm), -(-k // bk)
    # linearized tile ids; seed every tile-row with (row, col 0) and every
    # tile-col with (row 0, col) so each output block of either product
    # orientation gets initialized even when its tile-row/-col is empty
    tile_of_nnz = (rows // bm) * n_tc + cols // bk
    seeds = np.concatenate([np.arange(n_tr, dtype=np.int64) * n_tc,
                            np.arange(n_tc, dtype=np.int64)])
    tile_ids = np.union1d(tile_of_nnz, seeds)
    g_of = np.searchsorted(tile_ids, tile_of_nnz)
    blocks = np.zeros((len(tile_ids), bm, bk), np.float32)
    blocks[g_of, rows % bm, cols % bk] = vals
    tile_rows = tile_ids // n_tc
    tile_cols = tile_ids % n_tc
    t_order = np.lexsort((tile_rows, tile_cols))  # tile-col-major visit order
    return BlockSparseMatrix(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(tile_rows, jnp.int32),
        block_cols=jnp.asarray(tile_cols, jnp.int32),
        t_order=jnp.asarray(t_order, jnp.int32),
        shape=(m, k),
    )


def _plan_host(a, bm: int, bk: int) -> BlockSparsePlan:
    """Fast numpy pattern pass: occupancy bitmap + prefix-scan.

    Same tile list and ordering as the union1d oracle — the sorted unique
    tile ids *are* ``flatnonzero`` of the occupancy bitmap — without the
    O(nnz log nnz) sort union1d pays.
    """
    m, k = a.shape
    rows = np.asarray(a.indices[:, 0]).astype(np.int64)
    cols = np.asarray(a.indices[:, 1]).astype(np.int64)
    n_tr, n_tc = -(-m // bm), -(-k // bk)
    tile_of_nnz = (rows // bm) * n_tc + cols // bk
    occ = np.zeros(n_tr * n_tc, np.bool_)
    occ[tile_of_nnz] = True
    occ[np.arange(n_tr, dtype=np.int64) * n_tc] = True   # tile-row seeds
    occ[:n_tc] = True                                    # tile-col seeds
    lut = np.cumsum(occ, dtype=np.int64) - 1             # tile id -> g
    g = int(lut[-1]) + 1
    flat_idx = lut[tile_of_nnz] * (bm * bk) + (rows % bm) * bk + (cols % bk)
    tile_ids = np.flatnonzero(occ)
    tile_rows = tile_ids // n_tc
    tile_cols = tile_ids % n_tc
    t_order = np.lexsort((tile_rows, tile_cols))
    return BlockSparsePlan(
        block_rows=jnp.asarray(tile_rows, jnp.int32),
        block_cols=jnp.asarray(tile_cols, jnp.int32),
        t_order=jnp.asarray(t_order, jnp.int32),
        flat_idx=flat_idx, g=g, bm=bm, bk=bk, shape=(m, k), on_device=False)


@functools.partial(jax.jit, static_argnames=("n_tr", "n_tc", "bm", "bk"))
def block_sparse_pattern_device(rows: jax.Array, cols: jax.Array,
                                n_tr: int, n_tc: int, bm: int, bk: int):
    """Conversion stage 1 (jittable): tile occupancy bitmap + its popcount.

    The popcount is the single data-dependent scalar of the whole
    conversion — the wrapper syncs it once to fix the static ``G`` of
    stage 2.
    """
    tile_of = (rows // bm) * n_tc + (cols // bk)
    occ = jnp.zeros((n_tr * n_tc,), jnp.int32).at[tile_of].max(1)
    occ = occ.at[jnp.arange(n_tr) * n_tc].max(1)         # tile-row seeds
    occ = occ.at[jnp.arange(n_tc)].max(1)                # tile-col seeds
    return occ, jnp.sum(occ)


@functools.partial(jax.jit, static_argnames=("g", "n_tc", "bm", "bk"))
def block_sparse_build_device(rows: jax.Array, cols: jax.Array,
                              vals: jax.Array, occ: jax.Array,
                              g: int, n_tc: int, bm: int, bk: int):
    """Conversion stage 2 (jittable, static ``G``): scan + scatter.

    ``cumsum(occ) - 1`` is the segment scan that maps every tile id to
    its payload slot; values land by one flat scatter (indices unique by
    the BCOO contract). Returns the block stack, tile coordinates, the
    tile-col-major visit order and the reusable flat scatter offsets.
    """
    lut = jnp.cumsum(occ) - 1
    tile_of = (rows // bm) * n_tc + (cols // bk)
    flat_idx = lut[tile_of] * (bm * bk) + (rows % bm) * bk + (cols % bk)
    blocks = jnp.zeros((g * bm * bk,), jnp.float32).at[flat_idx].set(
        vals.astype(jnp.float32), unique_indices=True).reshape(g, bm, bk)
    tile_ids = jnp.nonzero(occ, size=g)[0].astype(jnp.int32)
    tile_rows = tile_ids // n_tc
    tile_cols = tile_ids % n_tc
    # unique ids are already row-major sorted, so a stable sort by
    # tile-col alone reproduces lexsort((tile_rows, tile_cols)) exactly
    t_order = jnp.argsort(tile_cols, stable=True).astype(jnp.int32)
    return blocks, tile_rows, tile_cols, t_order, flat_idx


@functools.partial(jax.jit, static_argnames=("g", "bm", "bk"))
def _apply_device(flat_idx: jax.Array, vals: jax.Array,
                  g: int, bm: int, bk: int) -> jax.Array:
    return jnp.zeros((g * bm * bk,), jnp.float32).at[flat_idx].set(
        vals.astype(jnp.float32), unique_indices=True).reshape(g, bm, bk)


def _device_conversion() -> bool:
    """Device path on TPU (and under the interpret-CI switch); numpy path
    on CPU, where XLA's serial scatter loses to the vectorized host
    scatter (measured ~2.5x at the bench shapes)."""
    if os.environ.get("REPRO_FORCE_INTERPRET"):
        return True
    return jax.default_backend() == "tpu"


def block_sparse_plan(a, bm: int = 128, bk: int = 128) -> BlockSparsePlan:
    """Pattern half of the conversion (dispatching device/host).

    One host sync of the surviving-tile popcount on the device path —
    ``G`` must be static for the stage-2 jit and the kernel grids.
    """
    m, k = a.shape
    n_tr, n_tc = -(-m // bm), -(-k // bk)
    if not _device_conversion() or n_tr * n_tc * bm * bk >= 2**31:
        # second clause: the i32 flat offsets of the device build would
        # overflow — the host plan carries i64 offsets
        return _plan_host(a, bm, bk)
    rows = a.indices[:, 0]
    cols = a.indices[:, 1]
    occ, count = block_sparse_pattern_device(rows, cols, n_tr, n_tc, bm, bk)
    g = int(count)                                       # the one host sync
    _, tile_rows, tile_cols, t_order, flat_idx = block_sparse_build_device(
        rows, cols, a.data, occ, g, n_tc, bm, bk)
    return BlockSparsePlan(block_rows=tile_rows, block_cols=tile_cols,
                           t_order=t_order, flat_idx=flat_idx, g=g, bm=bm,
                           bk=bk, shape=(m, k), on_device=True)


def block_sparse_apply(plan: BlockSparsePlan, data) -> BlockSparseMatrix:
    """Values half of the conversion: scatter ``data`` through a plan.

    This is the whole cost of a pattern-cache values refresh — no tile
    discovery, no sort, just one flat scatter sized by nnz.
    """
    bm, bk = plan.bm, plan.bk
    if plan.on_device:
        blocks = _apply_device(plan.flat_idx, data, plan.g, bm, bk)
    else:
        flat = np.zeros(plan.g * bm * bk, np.float32)
        flat[plan.flat_idx] = np.asarray(data, dtype=np.float32)
        blocks = jnp.asarray(flat.reshape(plan.g, bm, bk))
    return BlockSparseMatrix(blocks=blocks, block_rows=plan.block_rows,
                             block_cols=plan.block_cols,
                             t_order=plan.t_order, shape=plan.shape)


def bcoo_to_block_sparse(a, bm: int = 128, bk: int = 128) -> BlockSparseMatrix:
    """Tile a BCOO matrix, keeping only tiles with nonzeros.

    Two-stage plan/apply conversion: jitted on-device scan + scatter on
    TPU (one scalar sync for the surviving-tile count), vectorized numpy
    off-TPU. Bit-exact against :func:`bcoo_to_block_sparse_host` on both
    paths. Callers that convert the same sparsity pattern repeatedly
    should go through ``core.sparse.to_tiled``, which adds the
    pattern-keyed cache (``core.opcache``) on top of this.
    """
    return block_sparse_apply(block_sparse_plan(a, bm=bm, bk=bk), a.data)


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------


def _tile(blk_ref, rs_ref, cs_ref, g):
    """Payload tile ``g`` with any pending diagonal scales applied in VMEM.

    The multiply order (row scale, then col scale) matches
    ``BlockSparseMatrix.materialize_scales`` exactly — the fused and
    materialized operators stay bit-identical.
    """
    tile = blk_ref[0]
    if rs_ref is not None:
        tile = tile * rs_ref[0][:, None] * cs_ref[0][None, :]
    return tile


def _kernel(*refs, scaled: bool):
    if scaled:
        rows_ref, cols_ref, blk_ref, rs_ref, cs_ref, b_ref, out_ref = refs
    else:
        rows_ref, cols_ref, blk_ref, b_ref, out_ref = refs
        rs_ref = cs_ref = None
    g = pl.program_id(1)
    # New tile-row (payloads are row-sorted) -> fresh output block.
    first = jnp.logical_or(g == 0,
                           rows_ref[g] != rows_ref[jnp.maximum(g - 1, 0)])

    @pl.when(first)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot(
        _tile(blk_ref, rs_ref, cs_ref, g), b_ref[...],
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("m_out", "bn", "interpret"))
def spmm_pallas(
    block_rows: jax.Array,   # (G,) i32, sorted
    block_cols: jax.Array,   # (G,) i32
    blocks: jax.Array,       # (G, bm, bk) f32
    b: jax.Array,            # (K_padded, N_padded) dense rhs
    m_out: int,              # padded output rows (n_tile_rows * bm)
    bn: int = 128,
    interpret: bool = False,
    row_scale: jax.Array | None = None,   # (n_tr, bm) f32
    col_scale: jax.Array | None = None,   # (n_tc, bk) f32
) -> jax.Array:
    """Raw kernel invocation: ``out (m_out, N) = A_blocksparse @ b``.

    Use ``repro.kernels.ops.spmm_tiled`` for the shape-safe wrapper
    (padding, unpadding, backend dispatch). When scales are given the
    payload tile is rescaled in VMEM before the contraction.
    """
    g_total, bm, bk = blocks.shape
    _, n = b.shape
    grid = (n // bn, g_total)
    scaled = row_scale is not None
    in_specs = [pl.BlockSpec((1, bm, bk), lambda j, g, rows, cols: (g, 0, 0))]
    operands = [blocks]
    if scaled:
        in_specs += [
            pl.BlockSpec((1, bm), lambda j, g, rows, cols: (rows[g], 0)),
            pl.BlockSpec((1, bk), lambda j, g, rows, cols: (cols[g], 0)),
        ]
        operands += [row_scale, col_scale]
    in_specs.append(
        pl.BlockSpec((bk, bn), lambda j, g, rows, cols: (cols[g], j)))
    operands.append(b)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda j, g, rows, cols: (rows[g], j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, scaled=scaled),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_out, n), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, *operands)


def _kernel_t(*refs, scaled: bool):
    if scaled:
        rows_ref, cols_ref, order_ref, blk_ref, rs_ref, cs_ref, b_ref, out_ref = refs
    else:
        rows_ref, cols_ref, order_ref, blk_ref, b_ref, out_ref = refs
        rs_ref = cs_ref = None
    g = pl.program_id(1)
    # Payloads are visited in tile-col order (order_ref): a new tile-col
    # means a fresh output block, mirroring the row-sorted forward sweep.
    here = cols_ref[order_ref[g]]
    prev = cols_ref[order_ref[jnp.maximum(g - 1, 0)]]
    first = jnp.logical_or(g == 0, here != prev)

    @pl.when(first)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    # (bm, bk).T @ (bm, bn): contract the sublane (row) dim of the payload.
    out_ref[...] += jax.lax.dot_general(
        _tile(blk_ref, rs_ref, cs_ref, g), b_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("k_out", "bn", "interpret"))
def spmm_t_pallas(
    block_rows: jax.Array,   # (G,) i32, sorted by tile-row
    block_cols: jax.Array,   # (G,) i32
    t_order: jax.Array,      # (G,) i32 — payload visit order, tile-col major
    blocks: jax.Array,       # (G, bm, bk) f32
    b: jax.Array,            # (M_padded, N_padded) dense rhs
    k_out: int,              # padded output rows (n_tile_cols * bk)
    bn: int = 128,
    interpret: bool = False,
    row_scale: jax.Array | None = None,   # (n_tr, bm) f32
    col_scale: jax.Array | None = None,   # (n_tc, bk) f32
) -> jax.Array:
    """Raw transposed product: ``out (k_out, N) = A_blocksparse.T @ b``.

    The scalar-prefetched ``t_order`` permutation re-sorts the sweep by
    tile-col without materializing a transposed payload copy: the DMA
    engine fetches ``blocks[t_order[g]]`` and the MXU contracts its row
    dimension against the matching tile-row of ``b``.
    """
    g_total, bm, bk = blocks.shape
    _, n = b.shape
    grid = (n // bn, g_total)
    scaled = row_scale is not None
    in_specs = [pl.BlockSpec((1, bm, bk),
                             lambda j, g, rows, cols, order: (order[g], 0, 0))]
    operands = [blocks]
    if scaled:
        in_specs += [
            pl.BlockSpec((1, bm),
                         lambda j, g, rows, cols, order: (rows[order[g]], 0)),
            pl.BlockSpec((1, bk),
                         lambda j, g, rows, cols, order: (cols[order[g]], 0)),
        ]
        operands += [row_scale, col_scale]
    in_specs.append(
        pl.BlockSpec((bm, bn),
                     lambda j, g, rows, cols, order: (rows[order[g]], j)))
    operands.append(b)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(
            (bk, bn), lambda j, g, rows, cols, order: (cols[order[g]], j)),
    )
    return pl.pallas_call(
        functools.partial(_kernel_t, scaled=scaled),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_out, n), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, t_order, *operands)


def _kernel_ata(*refs, scaled: bool, with_gram: bool):
    if scaled:
        rows_ref, cols_ref, blk_ref, rs_ref, cs_ref, x_ref, *outs = refs
    else:
        rows_ref, cols_ref, blk_ref, x_ref, *outs = refs
        rs_ref = cs_ref = None
    if with_gram:
        out_ref, gram_ref, y_ref = outs
    else:
        out_ref, y_ref = outs
    p = pl.program_id(1)
    g = pl.program_id(2)
    bm = blk_ref.shape[1]
    bk = blk_ref.shape[2]

    @pl.when(jnp.logical_and(p == 0, g == 0))
    def _init():
        # fresh column stripe: clear the Y scratch and the output stripe
        y_ref[...] = jnp.zeros_like(y_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = _tile(blk_ref, rs_ref, cs_ref, g)

    @pl.when(p == 0)
    def _forward():
        # phase 0: Y[row] += B @ X[col] — the whole Y stripe lives in VMEM
        y_ref[pl.ds(rows_ref[g] * bm, bm), :] += jax.lax.dot(
            tile, x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(p == 1)
    def _backward():
        # phase 1: out[col] += B.T @ Y[row] against the resident scratch
        out_ref[pl.ds(cols_ref[g] * bk, bk), :] += jax.lax.dot_general(
            tile, y_ref[pl.ds(rows_ref[g] * bm, bm), :],
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    if with_gram:
        @pl.when(jnp.logical_and(p == 1, g == pl.num_programs(2) - 1))
        def _gram():
            # last payload applied: the (k_pad, bn) output stripe is final
            # and still resident — emit its (bn, bn) Gram without another
            # HBM pass (the CholeskyQR operand of the fused subspace step)
            gram_ref[...] = jax.lax.dot_general(
                out_ref[...], out_ref[...], (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("m_pad", "bn", "interpret", "with_gram"))
def spmm_ata_pallas(
    block_rows: jax.Array,   # (G,) i32, sorted by tile-row
    block_cols: jax.Array,   # (G,) i32
    blocks: jax.Array,       # (G, bm, bk) f32
    x: jax.Array,            # (K_padded, N_padded) dense sketch
    m_pad: int,              # padded intermediate rows (n_tile_rows * bm)
    bn: int = 128,
    interpret: bool = False,
    row_scale: jax.Array | None = None,   # (n_tr, bm) f32
    col_scale: jax.Array | None = None,   # (n_tc, bk) f32
    with_gram: bool = False,
):
    """Raw fused normal-equations pass: ``out = A.T @ (A @ x)``.

    One launch; the ``(m_pad, bn)`` intermediate ``Y = A @ x`` stripe is a
    VMEM scratch that never reaches HBM. Both the ``Y`` stripe and the
    ``(k_pad, bn)`` output stripe must fit VMEM — the ops wrapper falls
    back to two kernel launches for operands past that budget.

    ``with_gram=True`` (single column stripe only: ``x.shape[1] == bn``)
    additionally returns the ``(bn, bn)`` Gram ``out.T @ out`` computed
    from the still-resident output stripe — the fused subspace-iteration
    step. Returns ``out`` or ``(out, gram)``.
    """
    g_total, bm, bk = blocks.shape
    k_pad, n = x.shape
    if with_gram and n != bn:
        raise ValueError(
            f"fused Gram needs a single column stripe (n == bn), got "
            f"n={n}, bn={bn}")
    grid = (n // bn, 2, g_total)
    scaled = row_scale is not None
    in_specs = [pl.BlockSpec((1, bm, bk),
                             lambda j, p, g, rows, cols: (g, 0, 0))]
    operands = [blocks]
    if scaled:
        in_specs += [
            pl.BlockSpec((1, bm), lambda j, p, g, rows, cols: (rows[g], 0)),
            pl.BlockSpec((1, bk), lambda j, p, g, rows, cols: (cols[g], 0)),
        ]
        operands += [row_scale, col_scale]
    in_specs.append(
        pl.BlockSpec((bk, bn), lambda j, p, g, rows, cols: (cols[g], j)))
    operands.append(x)
    # one whole-stripe output block: resident for the full (p, g) sweep,
    # so phase-1 accumulation never depends on out-block revisit order
    out_specs = pl.BlockSpec((k_pad, bn), lambda j, p, g, rows, cols: (0, j))
    out_shape = jax.ShapeDtypeStruct((k_pad, n), jnp.float32)
    if with_gram:
        out_specs = [out_specs,
                     pl.BlockSpec((bn, bn), lambda j, p, g, rows, cols: (0, 0))]
        out_shape = [out_shape, jax.ShapeDtypeStruct((bn, bn), jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=[pltpu.VMEM((m_pad, bn), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel_ata, scaled=scaled, with_gram=with_gram),
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_rows, block_cols, *operands)
