"""Pallas TPU kernel: tiled block-sparse SpMM (DESIGN.md §9).

The sparse atom phase's hot matmuls are ``A @ Omega`` / ``A.T @ Q`` with
``A`` sparse and the other operand a tall-skinny dense sketch. A BCOO's
per-element indices cannot drive TPU DMA, so the kernel consumes a
*tile-level* sparse format: ``A`` is cut into a ``(M/bm, K/bk)`` grid
and only tiles containing nonzeros are kept, as

  * ``blocks``     (G, bm, bk) f32 — dense payload of each surviving tile
  * ``block_rows`` (G,) i32        — tile-row of each payload, sorted
  * ``block_cols`` (G,) i32        — tile-col of each payload

Grid is ``(N/bn, G)`` — payloads innermost, so consecutive steps that
share a tile-row revisit the *same* output block while it is resident in
VMEM. ``block_rows``/``block_cols`` ride in as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``) so the index maps can route each
payload's B-tile and out-tile before the body runs. The output block is
zeroed exactly when the tile-row changes (or at g == 0); because the
converter guarantees every tile-row owns at least one payload (zero
padding tiles for empty rows), every output block is visited and
initialized.

Compute per grid step is one ``(bm, bk) @ (bk, bn)`` MXU contraction —
identical to a dense matmul kernel's inner step; the win is skipping the
empty tiles entirely: FLOPs and HBM traffic scale with the *tile-level*
occupancy instead of ``M*K``.

Like every kernel here it runs under ``interpret=True`` off-TPU; the
semantics oracle is ``ref.spmm_ref`` (element-level segment-sum).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["BlockSparseMatrix", "bcoo_to_block_sparse", "spmm_pallas"]


class BlockSparseMatrix(NamedTuple):
    """Tile-level sparse operand for ``spmm_pallas`` (host-prepared)."""

    blocks: jax.Array        # (G, bm, bk) dense tile payloads
    block_rows: jax.Array    # (G,) i32 tile-row ids, sorted ascending
    block_cols: jax.Array    # (G,) i32 tile-col ids
    shape: tuple[int, int]   # logical (M, K) — unpadded

    @property
    def tile_shape(self) -> tuple[int, int]:
        return self.blocks.shape[1], self.blocks.shape[2]


def bcoo_to_block_sparse(a, bm: int = 128, bk: int = 128) -> BlockSparseMatrix:
    """Tile a BCOO matrix, keeping only tiles with nonzeros (host-side).

    One-time O(nnz) preprocessing per matrix — done *outside* jit because
    the surviving-tile count is data-dependent. Empty tile-rows get one
    zero payload (tile-col 0) so the kernel initializes every output
    block. Rows are padded up to a ``bm`` multiple, cols to ``bk``.
    """
    m, k = a.shape
    rows = np.asarray(a.indices[:, 0]).astype(np.int64)
    cols = np.asarray(a.indices[:, 1]).astype(np.int64)
    vals = np.asarray(a.data, dtype=np.float32)
    n_tr, n_tc = -(-m // bm), -(-k // bk)
    # linearized tile ids; seed every tile-row with (row, col 0) so each
    # output block gets initialized even when the row is empty
    tile_of_nnz = (rows // bm) * n_tc + cols // bk
    tile_ids = np.union1d(tile_of_nnz, np.arange(n_tr, dtype=np.int64) * n_tc)
    g_of = np.searchsorted(tile_ids, tile_of_nnz)
    blocks = np.zeros((len(tile_ids), bm, bk), np.float32)
    blocks[g_of, rows % bm, cols % bk] = vals
    return BlockSparseMatrix(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(tile_ids // n_tc, jnp.int32),
        block_cols=jnp.asarray(tile_ids % n_tc, jnp.int32),
        shape=(m, k),
    )


def _kernel(rows_ref, cols_ref, blk_ref, b_ref, out_ref):
    g = pl.program_id(1)
    # New tile-row (payloads are row-sorted) -> fresh output block.
    first = jnp.logical_or(g == 0,
                           rows_ref[g] != rows_ref[jnp.maximum(g - 1, 0)])

    @pl.when(first)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot(
        blk_ref[0], b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("m_out", "bn", "interpret"))
def spmm_pallas(
    block_rows: jax.Array,   # (G,) i32, sorted
    block_cols: jax.Array,   # (G,) i32
    blocks: jax.Array,       # (G, bm, bk) f32
    b: jax.Array,            # (K_padded, N_padded) dense rhs
    m_out: int,              # padded output rows (n_tile_rows * bm)
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw kernel invocation: ``out (m_out, N) = A_blocksparse @ b``.

    Use ``repro.kernels.ops.spmm_tiled`` for the shape-safe wrapper
    (padding, unpadding, backend dispatch).
    """
    g_total, bm, bk = blocks.shape
    _, n = b.shape
    grid = (n // bn, g_total)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda j, g, rows, cols: (g, 0, 0)),
            pl.BlockSpec((bk, bn), lambda j, g, rows, cols: (cols[g], j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, g, rows, cols: (rows[g], j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_out, n), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, blocks, b)
