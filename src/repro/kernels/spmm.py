"""Pallas TPU kernels: tiled block-sparse SpMM family (DESIGN.md §9).

The sparse atom phase's hot matmuls are ``A @ Omega`` / ``A.T @ Q`` with
``A`` sparse and the other operand a tall-skinny dense sketch. A BCOO's
per-element indices cannot drive TPU DMA, so the kernels consume a
*tile-level* sparse format: ``A`` is cut into a ``(M/bm, K/bk)`` grid
and only tiles containing nonzeros are kept, as

  * ``blocks``     (G, bm, bk) f32 — dense payload of each surviving tile
  * ``block_rows`` (G,) i32        — tile-row of each payload, sorted
  * ``block_cols`` (G,) i32        — tile-col of each payload
  * ``t_order``    (G,) i32        — payload visit order for transposed
                                     products (sorted by tile-col)

Three kernels share the format:

``spmm_pallas``      ``A @ B``: grid ``(N/bn, G)`` — payloads innermost,
    so consecutive steps that share a tile-row revisit the *same* output
    block while it is resident in VMEM. ``block_rows``/``block_cols``
    ride in as scalar-prefetch operands (``pltpu.PrefetchScalarGridSpec``)
    so the index maps can route each payload's B-tile and out-tile before
    the body runs. The output block is zeroed exactly when the tile-row
    changes; the converter seeds every tile-row with at least one payload
    so every output block is visited and initialized.

``spmm_t_pallas``    ``A.T @ B``: the same sweep driven through
    ``t_order`` — payloads visited in tile-col order so the transposed
    product enjoys the identical out-block residency property. The
    converter seeds every tile-*col* too, so both orientations have all
    output tiles initialized.

``spmm_ata_pallas``  fused normal-equations pass ``A.T @ (A @ X)``: one
    kernel launch whose grid sweeps the tile list once per phase
    (``grid = (N/bn, 2, G)``). Phase 0 accumulates the intermediate
    ``Y = A @ X`` stripe into a VMEM scratch; phase 1 streams the same
    payloads again and applies ``out[col] += B.T @ Y[row]`` against the
    still-resident scratch. ``Y`` never round-trips through HBM and the
    two products cost one launch instead of two — per subspace-iteration
    step the only HBM traffic beyond the payload tiles is the tiny
    ``(K, q)`` sketch in and out. (The payload tiles are streamed once
    per phase — the same nonzero traffic as the two-launch formulation,
    minus the ``(M, q)`` intermediate round-trip.)

Compute per grid step is one ``(bm, bk) @ (bk, bn)`` MXU contraction —
identical to a dense matmul kernel's inner step; the win is skipping the
empty tiles entirely: FLOPs and HBM traffic scale with the *tile-level*
occupancy instead of ``M*K``.

Like every kernel here they run under ``interpret=True`` off-TPU; the
semantics oracles are ``ref.spmm_ref`` (element-level segment-sum) and
``ref.spmm_block_ref`` (tile-level, also the fast jnp CPU path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["BlockSparseMatrix", "bcoo_to_block_sparse", "spmm_pallas",
           "spmm_t_pallas", "spmm_ata_pallas"]


@jax.tree_util.register_pytree_node_class
class BlockSparseMatrix:
    """Tile-level sparse operand for the SpMM kernels (host-prepared).

    A registered pytree whose logical ``shape`` is static aux data, so the
    operand passes through ``jit``/``scan`` boundaries with ``.shape``
    usable for Python-level shape math (the same reason
    ``sparse.EllOperator`` derives its shape instead of storing it).
    """

    def __init__(self, blocks, block_rows, block_cols, t_order, shape):
        self.blocks = blocks            # (G, bm, bk) dense tile payloads
        self.block_rows = block_rows    # (G,) i32 tile-row ids, sorted
        self.block_cols = block_cols    # (G,) i32 tile-col ids
        self.t_order = t_order          # (G,) i32, payloads in tile-col order
        self.shape = tuple(shape)       # logical (M, K) — unpadded, static

    @property
    def tile_shape(self) -> tuple[int, int]:
        return self.blocks.shape[1], self.blocks.shape[2]

    @property
    def n_tiles(self) -> tuple[int, int]:
        """Tile-grid shape ``(M/bm, K/bk)`` (ceil)."""
        bm, bk = self.tile_shape
        return -(-self.shape[0] // bm), -(-self.shape[1] // bk)

    @property
    def dtype(self):
        return self.blocks.dtype

    def tree_flatten(self):
        return ((self.blocks, self.block_rows, self.block_cols,
                 self.t_order), self.shape)

    @classmethod
    def tree_unflatten(cls, shape, children):
        return cls(*children, shape=shape)


def bcoo_to_block_sparse(a, bm: int = 128, bk: int = 128) -> BlockSparseMatrix:
    """Tile a BCOO matrix, keeping only tiles with nonzeros (host-side).

    One-time O(nnz) preprocessing per matrix — done *outside* jit because
    the surviving-tile count is data-dependent; in the LAMC sparse route
    the cost is amortized across every resample and subspace-iteration
    product that consumes the operator. Empty tile-rows get one zero
    payload (tile-col 0) and empty tile-cols one zero payload (tile-row
    0) so both product orientations initialize every output block. Rows
    are padded up to a ``bm`` multiple, cols to ``bk``.
    """
    m, k = a.shape
    rows = np.asarray(a.indices[:, 0]).astype(np.int64)
    cols = np.asarray(a.indices[:, 1]).astype(np.int64)
    vals = np.asarray(a.data, dtype=np.float32)
    n_tr, n_tc = -(-m // bm), -(-k // bk)
    # linearized tile ids; seed every tile-row with (row, col 0) and every
    # tile-col with (row 0, col) so each output block of either product
    # orientation gets initialized even when its tile-row/-col is empty
    tile_of_nnz = (rows // bm) * n_tc + cols // bk
    seeds = np.concatenate([np.arange(n_tr, dtype=np.int64) * n_tc,
                            np.arange(n_tc, dtype=np.int64)])
    tile_ids = np.union1d(tile_of_nnz, seeds)
    g_of = np.searchsorted(tile_ids, tile_of_nnz)
    blocks = np.zeros((len(tile_ids), bm, bk), np.float32)
    blocks[g_of, rows % bm, cols % bk] = vals
    tile_rows = tile_ids // n_tc
    tile_cols = tile_ids % n_tc
    t_order = np.lexsort((tile_rows, tile_cols))  # tile-col-major visit order
    return BlockSparseMatrix(
        blocks=jnp.asarray(blocks),
        block_rows=jnp.asarray(tile_rows, jnp.int32),
        block_cols=jnp.asarray(tile_cols, jnp.int32),
        t_order=jnp.asarray(t_order, jnp.int32),
        shape=(m, k),
    )


def _kernel(rows_ref, cols_ref, blk_ref, b_ref, out_ref):
    g = pl.program_id(1)
    # New tile-row (payloads are row-sorted) -> fresh output block.
    first = jnp.logical_or(g == 0,
                           rows_ref[g] != rows_ref[jnp.maximum(g - 1, 0)])

    @pl.when(first)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jax.lax.dot(
        blk_ref[0], b_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("m_out", "bn", "interpret"))
def spmm_pallas(
    block_rows: jax.Array,   # (G,) i32, sorted
    block_cols: jax.Array,   # (G,) i32
    blocks: jax.Array,       # (G, bm, bk) f32
    b: jax.Array,            # (K_padded, N_padded) dense rhs
    m_out: int,              # padded output rows (n_tile_rows * bm)
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw kernel invocation: ``out (m_out, N) = A_blocksparse @ b``.

    Use ``repro.kernels.ops.spmm_tiled`` for the shape-safe wrapper
    (padding, unpadding, backend dispatch).
    """
    g_total, bm, bk = blocks.shape
    _, n = b.shape
    grid = (n // bn, g_total)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda j, g, rows, cols: (g, 0, 0)),
            pl.BlockSpec((bk, bn), lambda j, g, rows, cols: (cols[g], j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda j, g, rows, cols: (rows[g], j)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m_out, n), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, blocks, b)


def _kernel_t(rows_ref, cols_ref, order_ref, blk_ref, b_ref, out_ref):
    g = pl.program_id(1)
    # Payloads are visited in tile-col order (order_ref): a new tile-col
    # means a fresh output block, mirroring the row-sorted forward sweep.
    here = cols_ref[order_ref[g]]
    prev = cols_ref[order_ref[jnp.maximum(g - 1, 0)]]
    first = jnp.logical_or(g == 0, here != prev)

    @pl.when(first)
    def _zero():
        out_ref[...] = jnp.zeros_like(out_ref)

    # (bm, bk).T @ (bm, bn): contract the sublane (row) dim of the payload.
    out_ref[...] += jax.lax.dot_general(
        blk_ref[0], b_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("k_out", "bn", "interpret"))
def spmm_t_pallas(
    block_rows: jax.Array,   # (G,) i32, sorted by tile-row
    block_cols: jax.Array,   # (G,) i32
    t_order: jax.Array,      # (G,) i32 — payload visit order, tile-col major
    blocks: jax.Array,       # (G, bm, bk) f32
    b: jax.Array,            # (M_padded, N_padded) dense rhs
    k_out: int,              # padded output rows (n_tile_cols * bk)
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw transposed product: ``out (k_out, N) = A_blocksparse.T @ b``.

    The scalar-prefetched ``t_order`` permutation re-sorts the sweep by
    tile-col without materializing a transposed payload copy: the DMA
    engine fetches ``blocks[t_order[g]]`` and the MXU contracts its row
    dimension against the matching tile-row of ``b``.
    """
    g_total, bm, bk = blocks.shape
    _, n = b.shape
    grid = (n // bn, g_total)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk),
                         lambda j, g, rows, cols, order: (order[g], 0, 0)),
            pl.BlockSpec((bm, bn),
                         lambda j, g, rows, cols, order: (rows[order[g]], j)),
        ],
        out_specs=pl.BlockSpec(
            (bk, bn), lambda j, g, rows, cols, order: (cols[order[g]], j)),
    )
    return pl.pallas_call(
        _kernel_t,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_out, n), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, t_order, blocks, b)


def _kernel_ata(rows_ref, cols_ref, blk_ref, x_ref, out_ref, y_ref):
    p = pl.program_id(1)
    g = pl.program_id(2)
    bm = blk_ref.shape[1]
    bk = blk_ref.shape[2]

    @pl.when(jnp.logical_and(p == 0, g == 0))
    def _init():
        # fresh column stripe: clear the Y scratch and the output stripe
        y_ref[...] = jnp.zeros_like(y_ref)
        out_ref[...] = jnp.zeros_like(out_ref)

    @pl.when(p == 0)
    def _forward():
        # phase 0: Y[row] += B @ X[col] — the whole Y stripe lives in VMEM
        y_ref[pl.ds(rows_ref[g] * bm, bm), :] += jax.lax.dot(
            blk_ref[0], x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(p == 1)
    def _backward():
        # phase 1: out[col] += B.T @ Y[row] against the resident scratch
        out_ref[pl.ds(cols_ref[g] * bk, bk), :] += jax.lax.dot_general(
            blk_ref[0], y_ref[pl.ds(rows_ref[g] * bm, bm), :],
            (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("m_pad", "bn", "interpret"))
def spmm_ata_pallas(
    block_rows: jax.Array,   # (G,) i32, sorted by tile-row
    block_cols: jax.Array,   # (G,) i32
    blocks: jax.Array,       # (G, bm, bk) f32
    x: jax.Array,            # (K_padded, N_padded) dense sketch
    m_pad: int,              # padded intermediate rows (n_tile_rows * bm)
    bn: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Raw fused normal-equations pass: ``out = A.T @ (A @ x)``.

    One launch; the ``(m_pad, bn)`` intermediate ``Y = A @ x`` stripe is a
    VMEM scratch that never reaches HBM. Both the ``Y`` stripe and the
    ``(k_pad, bn)`` output stripe must fit VMEM — the ops wrapper falls
    back to two kernel launches for operands past that budget.
    """
    g_total, bm, bk = blocks.shape
    k_pad, n = x.shape
    grid = (n // bn, 2, g_total)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda j, p, g, rows, cols: (g, 0, 0)),
            pl.BlockSpec((bk, bn), lambda j, p, g, rows, cols: (cols[g], j)),
        ],
        # one whole-stripe output block: resident for the full (p, g) sweep,
        # so phase-1 accumulation never depends on out-block revisit order
        out_specs=pl.BlockSpec((k_pad, bn), lambda j, p, g, rows, cols: (0, j)),
        scratch_shapes=[pltpu.VMEM((m_pad, bn), jnp.float32)],
    )
    return pl.pallas_call(
        _kernel_ata,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k_pad, n), jnp.float32),
        interpret=interpret,
    )(block_rows, block_cols, blocks, x)
