"""Llama-4 Scout 17B-active / 16 experts. [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

MoE with 16 routed experts, top-1 routing, plus one shared expert (Scout's
published layout); early-fusion multimodality is out of scope for the LM
backbone cells (text path only).
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    head_dim=128,
    n_experts=16,
    n_shared_experts=1,
    experts_per_token=1,
    moe_d_ff=8192,
    rope="standard",
    norm="rmsnorm",
    act="silu",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
    notes="MoE top-1, 1 shared expert; early fusion frontend not modeled",
)

REDUCED = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    n_experts=4,
    n_shared_experts=1,
    experts_per_token=1,
    moe_d_ff=128,
    rope="standard",
)

register(FULL, REDUCED)
