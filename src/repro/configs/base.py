"""Architecture + shape configuration system.

Every assigned architecture is a frozen ``ArchConfig`` in its own module
(``repro/configs/<id>.py``), registered by name for ``--arch <id>``
selection. Shape cells (train_4k / prefill_32k / decode_32k / long_500k)
are ``ShapeConfig``s; ``cells()`` enumerates the live (arch x shape) grid
with the spec-mandated skips (sub-quadratic requirement for long_500k).
"""

from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "register", "get_arch",
           "list_archs", "cells", "reduced"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0              # per-expert ff width (0 -> d_ff)
    n_dense_layers: int = 0        # leading dense layers (deepseek style)
    dense_d_ff: int = 0            # ff width of those dense layers
    capacity_factor: float = 1.25  # MoE dispatch overflow margin
    # --- attention / positional ---
    rope: str = "standard"         # standard | half (2d) | mrope
    qk_norm: bool = False
    window: int = 0                # sliding-window size for local attention
    block_pattern: tuple[str, ...] = ("attn",)  # repeating unit; see transformer.py
    # --- misc ---
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "silu"              # silu | gelu
    enc_dec: bool = False
    n_enc_layers: int = 0
    enc_seq_len: int = 0           # fixed encoder length (whisper frames)
    frontend: str = "none"         # none | frames | patches (stub embeddings)
    frontend_len: int = 0          # stub positions prepended/provided
    tie_embeddings: bool = True
    notes: str = ""
    source: str = ""

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state is O(1)/O(window) in sequence length —
        the long_500k eligibility criterion."""
        return self.family in ("hybrid", "ssm")

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for roofline
        MODEL_FLOPS and memory sanity checks."""
        d, hd = self.d_model, self.head_dim_
        n_q, n_kv = self.n_heads, self.n_kv_heads
        attn = d * n_q * hd + 2 * d * n_kv * hd + n_q * hd * d

        def mlp_p(ff):
            return 3 * d * ff  # gated: w_in, w_gate, w_out

        total = self.vocab_size * d  # embedding (tied head)
        if not self.tie_embeddings:
            total += self.vocab_size * d
        pattern = self.block_pattern
        for li in range(self.n_layers):
            kind = pattern[li % len(pattern)]
            if kind in ("attn", "local"):
                total += attn
            elif kind == "rglru":
                d_rnn = self.d_ff // 3  # lru width heuristic (see rglru.py)
                total += 2 * d * d_rnn + 4 * d_rnn  # in/out proj + gates
            elif kind == "mlstm":
                total += 5 * d * d  # q,k,v,o,skip projections
            elif kind == "slstm":
                h = max(self.n_heads, 1)
                total += 6 * d * d + 4 * d * d // h  # 4 gates (+recurrent) + out + skip
            if kind in ("attn", "local", "rglru"):
                if self.is_moe and li >= self.n_dense_layers:
                    ff = self.moe_d_ff or self.d_ff
                    total += self.n_experts * mlp_p(ff)
                    total += self.n_shared_experts * mlp_p(ff)
                    total += d * self.n_experts  # router
                elif self.d_ff > 0:
                    ff = self.dense_d_ff if (self.is_moe and li < self.n_dense_layers) else self.d_ff
                    total += mlp_p(ff)
        if self.enc_dec:
            # encoder blocks + decoder cross-attention
            total += self.n_enc_layers * (attn + mlp_p(self.d_ff))
            total += self.n_layers * attn  # cross-attn per decoder layer
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed-to experts) — the N
        in MODEL_FLOPS = 6*N_active*D for MoE archs."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        ff = self.moe_d_ff or self.d_ff
        per_expert = 3 * d * ff
        inactive = (self.n_experts - self.experts_per_token) * per_expert
        layers_moe = self.n_layers - self.n_dense_layers
        return self.param_count() - layers_moe * inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

_REGISTRY: dict[str, ArchConfig] = {}
_REDUCED: dict[str, ArchConfig] = {}

_ARCH_MODULES = [
    "llama4_scout_17b_a16e",
    "deepseek_moe_16b",
    "chatglm3_6b",
    "smollm_360m",
    "minicpm_2b",
    "qwen3_4b",
    "recurrentgemma_2b",
    "qwen2_vl_72b",
    "xlstm_125m",
    "whisper_medium",
    "lamc_coclustering",
]


def register(cfg: ArchConfig, reduced_cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    _REDUCED[cfg.name] = reduced_cfg
    return cfg


def _load_all() -> None:
    for mod in _ARCH_MODULES:
        importlib.import_module(f"repro.configs.{mod}")


def get_arch(name: str) -> ArchConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def reduced(name: str) -> ArchConfig:
    """CPU-smoke-test-sized config of the same family (see spec)."""
    _load_all()
    return _REDUCED[name]


def list_archs() -> list[str]:
    _load_all()
    return sorted(_REGISTRY)


def cells(include_skipped: bool = False):
    """The assigned (arch x shape) grid. Yields (arch, shape, live, why)."""
    _load_all()
    for name in _ARCH_MODULES:
        if name == "lamc_coclustering":
            continue  # the paper's own workload has its own shape set
        cfg = _REGISTRY[_mod_to_name(name)]
        for shape in SHAPES.values():
            live, why = True, ""
            if shape.name == "long_500k" and not cfg.sub_quadratic:
                live, why = False, (
                    "full-attention arch: 512k decode needs sub-quadratic "
                    "attention (DESIGN.md)"
                )
            if live or include_skipped:
                yield cfg, shape, live, why


def _mod_to_name(mod: str) -> str:
    return mod.replace("_", "-")
