"""SmolLM-360M. [hf:HuggingFaceTB/SmolLM-360M; hf]

Llama-architecture small model. 15 heads / 5 KV heads do not divide the
model-axis 16 — the sharding policy replicates attention heads and keeps
TP on d_ff/vocab (runtime/shardings.py).
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49_152,
    rope="standard",
    norm="rmsnorm",
    act="silu",
    source="hf:HuggingFaceTB/SmolLM-360M",
)

REDUCED = ArchConfig(
    name="smollm-360m",
    family="dense",
    n_layers=2,
    d_model=60,
    n_heads=3,
    n_kv_heads=1,
    d_ff=160,
    vocab_size=256,
)

register(FULL, REDUCED)
