"""Architecture configs: one module per assigned arch + the paper's own
workload. Use ``get_arch(name)`` / ``reduced(name)`` / ``cells()``."""

from .base import SHAPES, ArchConfig, ShapeConfig, cells, get_arch, list_archs, reduced

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get_arch", "reduced",
           "list_archs", "cells"]
