"""Qwen3-4B. [hf:Qwen/Qwen3-4B; hf]

GQA kv=8 with QK-RMSNorm (qk_norm) and head_dim 128.
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope="standard",
    norm="rmsnorm",
    act="silu",
    source="hf:Qwen/Qwen3-8B family",
)

REDUCED = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    head_dim=16,
    qk_norm=True,
)

register(FULL, REDUCED)
