"""RecurrentGemma-2B (Griffin). [arXiv:2402.19427; hf]

Hybrid: repeating (RG-LRU, RG-LRU, local-attention) unit — 1 attention per
2 recurrent blocks; local window 2048; MQA (kv=1). Sub-quadratic decode
state, so the long_500k cell runs for this arch.
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    window=2048,
    block_pattern=("rglru", "rglru", "local"),
    rope="standard",
    norm="rmsnorm",
    act="gelu",
    source="arXiv:2402.19427",
    notes="RG-LRU + local attn 1:2; window 2048; 26 = 8 units + 2 tail rglru",
)

REDUCED = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=2,
    n_kv_heads=1,
    d_ff=192,
    vocab_size=256,
    head_dim=32,
    window=16,
    block_pattern=("rglru", "rglru", "local"),
    act="gelu",
)

register(FULL, REDUCED)
