"""ChatGLM3-6B. [arXiv:2406.12793; hf]

GQA with 2 KV heads; 2D RoPE (rotary on the first half of the head dim).
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13_696,
    vocab_size=65_024,
    rope="half",
    norm="rmsnorm",
    act="silu",
    source="arXiv:2406.12793",
    notes="RoPE 2d (half-dim rotation), GQA kv=2",
)

REDUCED = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    rope="half",
)

register(FULL, REDUCED)
