"""xLSTM-125M. [arXiv:2405.04517; unverified]

Alternating mLSTM (matrix memory) / sLSTM (scalar memory) blocks;
d_ff = 0 — the blocks carry their own projections. O(1) decode state ->
long_500k cell runs.
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm", "slstm"),
    rope="none",
    norm="layernorm",
    act="gelu",
    source="arXiv:2405.04517 (unverified)",
)

REDUCED = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab_size=256,
    block_pattern=("mlstm", "slstm"),
    rope="none",
    norm="layernorm",
)

register(FULL, REDUCED)
