"""Qwen2-VL-72B backbone. [arXiv:2409.12191; hf]

VLM: the transformer BACKBONE only — the vision frontend is a stub
(input_specs provides precomputed patch embeddings for the leading
``frontend_len`` positions). M-RoPE (temporal/height/width sections) on the
positions; text-only positions degenerate to standard RoPE.
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29_568,
    vocab_size=152_064,
    rope="mrope",
    frontend="patches",
    frontend_len=256,
    norm="rmsnorm",
    act="silu",
    source="arXiv:2409.12191",
    notes="M-RoPE, dynamic resolution stubbed to 256 patch embeddings",
)

REDUCED = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    rope="mrope",
    frontend="patches",
    frontend_len=8,
)

register(FULL, REDUCED)
