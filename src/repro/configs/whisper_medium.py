"""Whisper-medium. [arXiv:2212.04356; unverified]

Encoder-decoder; the conv frontend is a STUB — input_specs provides
precomputed frame embeddings (B, 1500, d) as the encoder input. Decoder:
causal self-attn + cross-attn, learned positions, no RoPE. Decode shapes
run on the decoder with cached encoder output.
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    enc_dec=True,
    n_enc_layers=24,
    enc_seq_len=1500,
    frontend="frames",
    frontend_len=1500,
    rope="none",
    norm="layernorm",
    act="gelu",
    tie_embeddings=True,
    source="arXiv:2212.04356 (unverified)",
    notes="conv frontend stubbed; vocab padded for sharding",
)

REDUCED = ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    enc_dec=True,
    n_enc_layers=2,
    enc_seq_len=30,
    frontend="frames",
    frontend_len=30,
    rope="none",
    norm="layernorm",
    act="gelu",
)

register(FULL, REDUCED)
