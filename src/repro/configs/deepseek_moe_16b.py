"""DeepSeekMoE 16B. [arXiv:2401.06066; hf]

Fine-grained experts: 64 routed (top-6) + 2 shared, expert ff width 1408;
the first layer is a dense MLP (width 10944) per the released config.
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    n_experts=64,
    n_shared_experts=2,
    experts_per_token=6,
    moe_d_ff=1408,
    n_dense_layers=1,
    dense_d_ff=10_944,
    rope="standard",
    norm="rmsnorm",
    act="silu",
    source="arXiv:2401.06066",
    notes="2 shared + 64 routed top-6, fine-grained; first layer dense",
)

REDUCED = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab_size=256,
    n_experts=8,
    n_shared_experts=2,
    experts_per_token=2,
    moe_d_ff=48,
    n_dense_layers=1,
    dense_d_ff=128,
    capacity_factor=8.0,  # reduced config: no dropping, so prefill->decode
                          # consistency tests isolate cache correctness
)

register(FULL, REDUCED)
