"""MiniCPM-2B. [arXiv:2404.06395; hf]

Llama-like dense arch (MHA, 36 heads); trained with the WSD schedule —
provided in optim/schedule.py and used by examples/train_lm.py. The odd
vocab (122753) is padded to 122880 for mesh divisibility (see
runtime/shardings.pad_vocab; logits for pad ids are masked at -inf).
"""

from .base import ArchConfig, register

FULL = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab_size=122_753,
    rope="standard",
    norm="rmsnorm",
    act="silu",
    source="arXiv:2404.06395",
    notes="WSD schedule; vocab padded 122753->122880 for sharding",
)

REDUCED = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab_size=253,  # odd on purpose: exercises vocab padding
)

register(FULL, REDUCED)
