"""The paper's own workload as a dry-runnable config: distributed LAMC
co-clustering of a production-scale dense matrix on the full mesh.

Shapes (rows x cols, block grid matched to the mesh):
    lamc_1m   1,048,576 x 262,144  — 16x16 blocks (1 block/device/resample)
    lamc_4m   4,194,304 x 262,144  — memory-bound stress cell

These are NOT part of the 40 LM cells; they carry the §Roofline entry for
the paper's technique itself (the third mandated hillclimb target).

The sparse record covers the RCV1-class regime (DESIGN.md §9): same
driver with ``LAMCConfig(input_format="bcoo")``, where the data matrix
stays BCOO end-to-end and per-block atom cost is nnz-bound — its
roofline compute term scales with density, not area.
"""

from .base import ArchConfig, register

# ArchConfig is reused as a thin registry record; the LAMC driver reads the
# partition geometry from launch/dryrun.py's shape table instead.
FULL = ArchConfig(
    name="lamc-coclustering",
    family="coclustering",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    source="this paper (SMC 2024)",
    notes="distributed LAMC workload; see launch/dryrun.py LAMC_SHAPES",
)

REDUCED = FULL

# Not register()ed: the LM-stack smoke/analytic suites enumerate the
# registry and exclude co-clustering records by the FULL name; the sparse
# twin is a workload descriptor for the benchmark/roofline layer only.
SPARSE = ArchConfig(
    name="lamc-coclustering-sparse",
    family="coclustering",
    n_layers=0, d_model=0, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=0,
    source="this paper (SMC 2024)",
    notes="RCV1-scale BCOO workload (input_format='bcoo', density<=0.05); "
          "atom FLOPs scale with nnz — see DESIGN.md §9 and "
          "benchmarks/README.md §Sparse",
)

register(FULL, REDUCED)
