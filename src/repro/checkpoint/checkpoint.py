"""Sharded pytree checkpointing without external deps (orbax-free).

Format: one directory per step —
    ckpt_dir/step_000123/
        manifest.json        (tree structure, shapes, dtypes, per-leaf
                              sha256 content hashes, mesh info)
        arrays.npz           (flat leaf name -> host array)
        _COMMITTED           (sentinel written last: atomicity marker)

Crash consistency: writes go to ``step_X.tmp``; every file is fsync'd,
then the temp directory is fsync'd, then atomically renamed, then the
parent directory is fsync'd — a crash at any point leaves either the old
committed checkpoint or a ``.tmp`` directory ``latest_step`` ignores,
never a half-written checkpoint it would pick up. Overwriting an already
committed step displaces it to ``step_X.old`` first (removed only after
the new directory is renamed in and the parent fsync'd); the restore and
listing paths fall back to the ``.old`` copy, so a crash anywhere in the
overwrite still leaves a committed, discoverable checkpoint. Restore verifies each
leaf against its recorded sha256 and raises
:class:`CheckpointCorruptError` *naming the bad leaf* on any mismatch,
truncation, or missing payload — a corrupt checkpoint can never restore
silently. Restore is also *elastic*: arrays are loaded on host and
re-placed under whatever sharding the caller provides — restoring a
16x16-mesh checkpoint onto an 8x16 (or single-device) mesh is the same
code path (tests/test_checkpoint.py exercises it).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import zipfile

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "restore_tree", "read_manifest",
           "latest_step", "available_steps", "CheckpointCorruptError"]

_SENTINEL = "_COMMITTED"


class CheckpointCorruptError(RuntimeError):
    """A committed checkpoint failed integrity verification.

    Raised when the manifest or array payload is missing, truncated, or
    fails its recorded content hash — the message names the offending
    leaf/file. Distinct from :class:`FileNotFoundError` (no committed
    checkpoint at all) and ``ValueError`` (template mismatch): this one
    means bytes on disk changed after commit, and restoring them would
    be silent garbage.
    """


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def _leaf_hash(arr: np.ndarray) -> str:
    """sha256 over the raw bytes + shape/dtype (shape collisions matter)."""
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def _fsync_path(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def save(ckpt_dir: str, step: int, tree, extra_meta: dict | None = None) -> str:
    """Atomically write ``tree`` as checkpoint ``step`` (fsync'd commit)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    names, leaves, _ = _flatten_with_names(tree)
    host = {}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        host[name] = arr
    # bf16 isn't portable through np.savez: view as uint16 and record dtype
    meta = {"step": step, "leaves": {}}
    packed = {}
    for name, arr in host.items():
        if arr.dtype == jnp.bfloat16:
            stored = arr.view(np.uint16)
            packed[name] = stored
            meta["leaves"][name] = {"dtype": "bfloat16", "shape": list(arr.shape),
                                    "sha256": _leaf_hash(stored)}
        else:
            packed[name] = arr
            meta["leaves"][name] = {"dtype": str(arr.dtype), "shape": list(arr.shape),
                                    "sha256": _leaf_hash(arr)}
    if extra_meta:
        meta["extra"] = extra_meta
    arrays_path = os.path.join(tmp, "arrays.npz")
    manifest_path = os.path.join(tmp, "manifest.json")
    sentinel_path = os.path.join(tmp, _SENTINEL)
    np.savez(arrays_path, **packed)
    with open(manifest_path, "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(arrays_path)
    # sentinel last: its presence asserts the payload + manifest are durable
    with open(sentinel_path, "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    _fsync_path(tmp)
    # Overwriting a committed step must never pass through a state with no
    # durable copy: displace the old directory to ``.old`` (restore paths
    # fall back to it), rename the new one in, and only then drop the old.
    old = final + ".old"
    if os.path.exists(final):
        if os.path.exists(old):
            shutil.rmtree(old)  # stale leftover from a crashed overwrite
        os.rename(final, old)
    os.rename(tmp, final)
    _fsync_path(ckpt_dir)
    if os.path.exists(old):
        shutil.rmtree(old)
    return final


def _step_dir(ckpt_dir: str, step: int) -> str:
    """Committed directory for ``step`` — the canonical path, or the
    ``.old`` copy displaced mid-overwrite if a crash left only that."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(os.path.join(final, _SENTINEL)):
        return final
    old = final + ".old"
    if os.path.exists(os.path.join(old, _SENTINEL)):
        return old
    return final


def available_steps(ckpt_dir: str) -> list[int]:
    """Committed step numbers under ``ckpt_dir``, each listed exactly once.

    Mid-overwrite both ``step_X`` and ``step_X.old`` can exist (the
    crash window between ``save``'s two renames) — both stems map to the
    same step, so candidates are deduped *by step number*, never listed
    twice. The sentinel check consults both the canonical directory and
    its ``.old`` displacement regardless of which name ``listdir``
    returned: a concurrent overwrite can rename ``step_X`` to
    ``step_X.old`` between the listing and the check, and a listing that
    only re-checked the snapshotted name would transiently report a
    committed step as missing (the hot-swap path lists while a
    background save commits).
    """
    if not os.path.isdir(ckpt_dir):
        return []
    steps = set()
    for name in os.listdir(ckpt_dir):
        # ``.old`` copies count: they are the committed checkpoint when a
        # crash interrupted an overwrite between the two renames
        stem = name[:-len(".old")] if name.endswith(".old") else name
        if not (stem.startswith("step_") and stem[len("step_"):].isdigit()):
            continue
        final = os.path.join(ckpt_dir, stem)
        if (os.path.exists(os.path.join(final, _SENTINEL))
                or os.path.exists(os.path.join(final + ".old", _SENTINEL))):
            steps.add(int(stem[len("step_"):]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: int) -> dict:
    """Load + parse a committed checkpoint's manifest; loud on corruption."""
    path = _step_dir(ckpt_dir, step)
    if not os.path.exists(os.path.join(path, _SENTINEL)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    manifest_path = os.path.join(path, "manifest.json")
    if not os.path.exists(manifest_path):
        raise CheckpointCorruptError(
            f"checkpoint {path} is committed but manifest.json is missing — "
            "the directory was partially deleted or tampered with")
    try:
        with open(manifest_path) as f:
            meta = json.load(f)
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path}: manifest.json is unparseable ({e}) — "
            "truncated or corrupted after commit") from e
    if "leaves" not in meta:
        raise CheckpointCorruptError(
            f"checkpoint {path}: manifest.json has no 'leaves' table")
    return meta


def _open_arrays(path: str):
    arrays_path = os.path.join(path, "arrays.npz")
    if not os.path.exists(arrays_path):
        raise CheckpointCorruptError(
            f"checkpoint {path} is committed but arrays.npz is missing")
    try:
        return np.load(arrays_path)
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path}: arrays.npz failed to open ({e}) — "
            "truncated or corrupted after commit") from e


def _load_leaf(data, meta: dict, name: str, path: str) -> np.ndarray:
    """One verified leaf off the npz: existence + content-hash check."""
    if name not in meta["leaves"]:
        raise KeyError(f"checkpoint missing leaf {name!r}")
    info = meta["leaves"][name]
    if name not in getattr(data, "files", ()):
        raise CheckpointCorruptError(
            f"checkpoint {path}: leaf {name!r} is in the manifest but "
            "missing from arrays.npz — partial write or truncation")
    try:
        arr = data[name]
    except (zipfile.BadZipFile, OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path}: leaf {name!r} failed to decompress ({e}) — "
            "truncated or corrupted after commit") from e
    want = info.get("sha256")
    if want is not None:
        got = _leaf_hash(arr)
        if got != want:
            raise CheckpointCorruptError(
                f"checkpoint {path}: leaf {name!r} failed its content hash "
                f"(manifest {want[:12]}…, on disk {got[:12]}…) — the payload "
                "changed after commit; refusing to restore silent garbage")
    if info["dtype"] == "bfloat16":
        arr = arr.view(jnp.bfloat16)
    return arr


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore checkpoint ``step`` into the structure of ``like``.

    ``like`` supplies the pytree structure + expected shapes/dtypes (e.g.
    ``jax.eval_shape`` output). ``shardings`` (same structure or a single
    sharding) controls placement — pass the *current* mesh's shardings for
    elastic restore onto a different topology.
    Returns (tree, extra_meta). Every leaf is verified against the
    manifest's content hash before placement (CheckpointCorruptError
    names the bad leaf on mismatch).
    """
    path = _step_dir(ckpt_dir, step)
    meta = read_manifest(ckpt_dir, step)
    data = _open_arrays(path)

    names, leaves, treedef = _flatten_with_names(like)
    shard_list = None
    if shardings is not None:
        if isinstance(shardings, (list, tuple)):
            shard_list = list(shardings)
        else:
            try:
                shard_list = jax.tree.leaves(
                    shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
                if len(shard_list) != len(leaves):
                    shard_list = [shardings] * len(leaves)
            except Exception:
                shard_list = [shardings] * len(leaves)

    out = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = _load_leaf(data, meta, name, path)
        # np.shape, not leaf.shape: ``like`` may carry Python int/float/bool
        # leaves (config scalars inside a model NamedTuple) that have no
        # .shape attribute — they save as 0-d arrays and round-trip back to
        # Python scalars of the template's type.
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs model {want_shape}")
        if isinstance(leaf, (bool, int, float)) and not isinstance(leaf, np.ndarray):
            out.append(type(leaf)(arr[()]))
        elif shard_list is not None:
            out.append(jax.device_put(arr, shard_list[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), meta.get("extra")


def restore_tree(ckpt_dir: str, step: int):
    """Template-free restore: rebuild a nested dict from the manifest.

    Leaf names are split on ``/`` into nested dict keys, so any tree that
    was saved as (possibly nested) dicts round-trips without the caller
    holding a ``like`` template — the restore path for accumulated state
    whose shape is only known from the checkpoint itself (e.g. a
    streaming ``FitState`` with a per-chunk entry count). All leaves come
    back as host numpy arrays, hash-verified. Returns (tree, extra_meta).
    """
    path = _step_dir(ckpt_dir, step)
    meta = read_manifest(ckpt_dir, step)
    data = _open_arrays(path)
    tree: dict = {}
    for name in sorted(meta["leaves"]):
        arr = _load_leaf(data, meta, name, path)
        node = tree
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return tree, meta.get("extra")
