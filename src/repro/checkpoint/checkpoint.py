"""Sharded pytree checkpointing without external deps (orbax-free).

Format: one directory per step —
    ckpt_dir/step_000123/
        manifest.json        (tree structure, shapes, dtypes, mesh info)
        arrays.npz           (flat leaf name -> host array)
        _COMMITTED           (sentinel written last: atomicity marker)

Writes go to ``step_X.tmp`` and are atomically renamed after the sentinel
is in place, so a crash mid-write can never yield a checkpoint that
``latest_step`` would pick up. Restore is *elastic*: arrays are loaded on
host and re-placed under whatever sharding the caller provides — restoring
a 16x16-mesh checkpoint onto an 8x16 (or single-device) mesh is the same
code path (tests/test_checkpoint.py exercises it).
"""

from __future__ import annotations

import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "restore", "latest_step", "available_steps"]

_SENTINEL = "_COMMITTED"


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(ckpt_dir: str, step: int, tree, extra_meta: dict | None = None) -> str:
    """Atomically write ``tree`` as checkpoint ``step``."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    names, leaves, _ = _flatten_with_names(tree)
    host = {}
    for name, leaf in zip(names, leaves):
        arr = np.asarray(jax.device_get(leaf))
        host[name] = arr
    # bf16 isn't portable through np.savez: view as uint16 and record dtype
    meta = {"step": step, "leaves": {}}
    packed = {}
    for name, arr in host.items():
        if arr.dtype == jnp.bfloat16:
            packed[name] = arr.view(np.uint16)
            meta["leaves"][name] = {"dtype": "bfloat16", "shape": list(arr.shape)}
        else:
            packed[name] = arr
            meta["leaves"][name] = {"dtype": str(arr.dtype), "shape": list(arr.shape)}
    if extra_meta:
        meta["extra"] = extra_meta
    np.savez(os.path.join(tmp, "arrays.npz"), **packed)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, _SENTINEL), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, _SENTINEL)):
                steps.append(int(name.split("_")[1]))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore checkpoint ``step`` into the structure of ``like``.

    ``like`` supplies the pytree structure + expected shapes/dtypes (e.g.
    ``jax.eval_shape`` output). ``shardings`` (same structure or a single
    sharding) controls placement — pass the *current* mesh's shardings for
    elastic restore onto a different topology.
    Returns (tree, extra_meta).
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(path, _SENTINEL)):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "manifest.json")) as f:
        meta = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    names, leaves, treedef = _flatten_with_names(like)
    shard_list = None
    if shardings is not None:
        if isinstance(shardings, (list, tuple)):
            shard_list = list(shardings)
        else:
            try:
                shard_list = jax.tree.leaves(
                    shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
                if len(shard_list) != len(leaves):
                    shard_list = [shardings] * len(leaves)
            except Exception:
                shard_list = [shardings] * len(leaves)

    out = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        if name not in meta["leaves"]:
            raise KeyError(f"checkpoint missing leaf {name!r}")
        info = meta["leaves"][name]
        arr = data[name]
        if info["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        # np.shape, not leaf.shape: ``like`` may carry Python int/float/bool
        # leaves (config scalars inside a model NamedTuple) that have no
        # .shape attribute — they save as 0-d arrays and round-trip back to
        # Python scalars of the template's type.
        want_shape = tuple(np.shape(leaf))
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs model {want_shape}")
        if isinstance(leaf, (bool, int, float)) and not isinstance(leaf, np.ndarray):
            out.append(type(leaf)(arr[()]))
        elif shard_list is not None:
            out.append(jax.device_put(arr, shard_list[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), meta.get("extra")
