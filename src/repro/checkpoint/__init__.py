from . import checkpoint
from .checkpoint import (
    CheckpointCorruptError,
    available_steps,
    latest_step,
    read_manifest,
    restore,
    restore_tree,
    save,
)

__all__ = ["checkpoint", "save", "restore", "restore_tree", "read_manifest",
           "latest_step", "available_steps", "CheckpointCorruptError"]
