from . import checkpoint
from .checkpoint import available_steps, latest_step, restore, save

__all__ = ["checkpoint", "save", "restore", "latest_step", "available_steps"]
