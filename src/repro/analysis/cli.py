"""``python -m repro.analysis`` — run the analyzer and report findings.

Layers are selectable so CI can split them into fast/slow steps:

* ``--ast-only``   — Layer 1 AST lint over the source tree (no JAX import)
* ``--audit-only`` — Layer 2 jaxpr audit (A1/A2 over the entry-point
  registry, A4 over the kernel BlockSpec registry); needs JAX
* default          — both layers

``--strict`` exits 1 on any active (non-suppressed) finding; ``--json``
emits the machine-readable report for pre-commit/tooling consumers.
"""

from __future__ import annotations

import argparse
import os
import sys

from . import ast_lint
from .findings import Finding, render_json, render_text

_DEFAULT_PATHS = ("src",)


def _repo_root() -> str:
    # src/repro/analysis/cli.py -> repo root is three levels above src/
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(os.path.dirname(os.path.dirname(here)))


def _run_audits(entries: list[str] | None) -> tuple[list[Finding],
                                                    list[Finding]]:
    # deferred: the AST layer must work without importing JAX (fast path,
    # and usable from tooling that cannot initialize a backend)
    from . import entry_points, vmem
    findings = entry_points.audit_entry_points(entries)
    findings += vmem.audit_vmem(platform="tpu")
    return findings, []


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST lint + jaxpr trace audit for the repro codebase")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/dirs to lint (default: src/ at repo root)")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 if any active finding remains")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="machine-readable findings report")
    layer = parser.add_mutually_exclusive_group()
    layer.add_argument("--ast-only", action="store_true",
                       help="run only the Layer 1 AST lint")
    layer.add_argument("--audit-only", action="store_true",
                       help="run only the Layer 2 jaxpr/VMEM audits")
    parser.add_argument("--entry", action="append", dest="entries",
                        help="audit only this entry point (repeatable)")
    args = parser.parse_args(argv)

    findings: list[Finding] = []
    suppressed: list[Finding] = []

    if not args.audit_only:
        paths = args.paths or [os.path.join(_repo_root(), p)
                               for p in _DEFAULT_PATHS]
        active, supp = ast_lint.run_ast_lint(paths)
        findings += active
        suppressed += supp

    if not args.ast_only:
        active, supp = _run_audits(args.entries)
        findings += active
        suppressed += supp

    if args.as_json:
        print(render_json(findings, suppressed))
    else:
        print(render_text(findings, suppressed, args.strict))
    return 1 if (args.strict and findings) else 0


if __name__ == "__main__":
    sys.exit(main())
