"""Traceable registry of the repo's real jit entry points (DESIGN.md §13).

Each entry builds ``(fn, example_args)`` at *representative small shapes*
— large enough to exercise every branch the production shapes take
(multi-block scan, sparse operator route, tiled SpMM grid), small enough
that tracing is sub-second. The jaxpr audit does not execute these
functions; it only stages them with ``jax.make_jaxpr``, so entries are
cheap even where a real call would not be.

Adding an entry point here is the whole integration story for a new
subsystem: the A1/A2 audits and the CI lane pick it up by name. A3
(recompile guard) executes for real, so it has its own smaller registry
(:func:`recompile_targets`) of public drivers worth running twice.
"""

from __future__ import annotations

from typing import Callable

import jax
import numpy as np

from . import jaxpr_audit
from .findings import Finding

__all__ = ["ENTRY_POINTS", "trace_entry", "audit_entry_points",
           "recompile_targets"]


def _rng(seed: int) -> np.random.Generator:
    return np.random.default_rng(seed)


def _dense(seed: int, *shape: int) -> jax.Array:
    import jax.numpy as jnp
    return jnp.asarray(_rng(seed).standard_normal(shape), dtype=jnp.float32)


def _bcoo(seed: int, m: int, n: int, density: float = 0.1):
    from jax.experimental import sparse as jsparse
    import jax.numpy as jnp
    g = _rng(seed)
    mask = g.random((m, n)) < density
    mask[0, 0] = True  # never empty
    dense = np.where(mask, g.standard_normal((m, n)), 0.0)
    return jsparse.BCOO.fromdense(jnp.asarray(dense, dtype=jnp.float32))


def _small_cfg(**overrides):
    from repro.core.lamc import LAMCConfig
    base = dict(n_row_clusters=2, n_col_clusters=2, svd_iters=2,
                kmeans_iters=2, merge_kmeans_iters=2, merge_restarts=1,
                signature_dim=8, seed=0)
    base.update(overrides)
    return LAMCConfig(**base)


def _small_plan(**overrides):
    from repro.core.partition import PartitionPlan
    base = dict(n_rows=32, n_cols=32, m=2, n=2, phi=16, psi=16, t_p=2,
                seed=0)
    base.update(overrides)
    return PartitionPlan(**base)


# -- builders ---------------------------------------------------------------

def _lamc_dense():
    from repro.core import lamc
    cfg, plan = _small_cfg(), _small_plan()
    return (lambda a: lamc._lamc_jit(a, cfg, plan),
            (_dense(0, 32, 32),))


def _lamc_sparse():
    from repro.core import lamc, sparse as _sparse
    cfg = _small_cfg(input_format="bcoo", spmm_impl="dual_ell")
    plan = _small_plan(m=1, n=1, phi=32, psi=32, spmm_route="dual_ell")
    a = _bcoo(1, 32, 32, density=0.2)
    operator = _sparse.prepare_operator(a, "dual_ell")
    return (lambda mat: lamc._lamc_jit(mat, cfg, plan, operator), (a,))


def _distributed_step():
    from jax.sharding import Mesh
    from repro.core import distributed
    cfg, plan = _small_cfg(), _small_plan()
    devices = np.array(jax.devices()[:1]).reshape(1, 1)
    mesh = Mesh(devices, ("data", "model"))
    step, _, _ = distributed.lamc_step_fn(cfg, plan, mesh, ("data", "model"))

    def fn(a):
        with mesh:
            return step(a)
    return fn, (_dense(2, 32, 32),)


def _streaming_chunk():
    import importlib

    import jax.numpy as jnp

    # the package re-exports a `fit` *function*, shadowing the module
    fit = importlib.import_module("repro.streaming.fit")
    cfg = fit.StreamConfig(n_row_clusters=2, n_col_clusters=2, col_blocks=2,
                           signature_dim=8, anchor_rows=8, svd_iters=2,
                           kmeans_iters=2)
    blocks = _dense(3, cfg.blocks_per_chunk, 16, 16)
    feats = _dense(4, 16, 8)
    return (lambda b, f, t: fit._chunk_atoms(cfg, b, f, t),
            (blocks, feats, jnp.int32(0)))


def _cosine_assign():
    from repro.kernels import ops
    return ops.cosine_assign, (_dense(5, 256, 64), _dense(6, 4, 64))


def _cosine_topk():
    from repro.kernels import ops
    return (lambda x, s: ops.cosine_topk(x, s, 2),
            (_dense(7, 256, 64), _dense(8, 4, 64)))


def _spmm():
    from repro.kernels import ops
    a = _bcoo(9, 64, 64)
    return (lambda mat, b: ops.spmm(mat, b), (a, _dense(10, 64, 16)))


def _tiled_operand():
    from repro.kernels import spmm as kspmm
    return kspmm.bcoo_to_block_sparse(_bcoo(11, 256, 256), bm=128, bk=128)


def _spmm_tiled():
    from repro.kernels import ops
    a = _tiled_operand()
    return (lambda mat, b: ops.spmm_tiled(mat, b), (a, _dense(12, 256, 128)))


def _spmm_ata():
    from repro.kernels import ops
    a = _tiled_operand()
    return (lambda mat, x: ops.spmm_ata(mat, x), (a, _dense(13, 256, 128)))


def _scaled_operand():
    import jax.numpy as jnp
    from repro.kernels import spmm as kspmm
    a = _tiled_operand()
    n_tr, n_tc = a.n_tiles
    bm, bk = a.tile_shape
    rs = jnp.abs(_dense(14, n_tr, bm)) + 0.5
    cs = jnp.abs(_dense(15, n_tc, bk)) + 0.5
    return kspmm.BlockSparseMatrix(
        blocks=a.blocks, block_rows=a.block_rows, block_cols=a.block_cols,
        t_order=a.t_order, shape=a.shape, row_scale=rs, col_scale=cs)


def _spmm_tiled_scaled():
    from repro.kernels import ops
    a = _scaled_operand()
    return (lambda mat, b: ops.spmm_tiled(mat, b), (a, _dense(16, 256, 128)))


def _spmm_ata_gram():
    from repro.kernels import ops
    a = _scaled_operand()
    return (lambda mat, x: ops.spmm_ata(mat, x, with_gram=True),
            (a, _dense(17, 256, 16)))


def _tiled_convert():
    # stage 2 of the device conversion (the static-G build); stage 1's
    # occupancy pass and popcount sync run at build time here, so the
    # traced program is exactly what executes per conversion on device
    import jax.numpy as jnp
    from repro.kernels import spmm as kspmm
    a = _bcoo(18, 256, 256)
    rows, cols = a.indices[:, 0], a.indices[:, 1]
    occ, count = kspmm.block_sparse_pattern_device(rows, cols, 2, 2, 128, 128)
    g = int(count)
    return (lambda r, c, v, o: kspmm.block_sparse_build_device(
        r, c, v, o, g, 2, 128, 128), (rows, cols, a.data, occ))


def _with_obs(builder: Callable[[], tuple[Callable, tuple]]
              ) -> Callable[[], tuple[Callable, tuple]]:
    """Obs-enabled variant of an entry builder.

    The wrapped fn flips ``obs.configure(enabled=True)`` for the duration
    of the call and runs inside an active span, so staging it proves the
    telemetry hooks add nothing to the lowered program: the audit rules
    (R2 host-sync, A1 RNG-gather, op census) see the *same* jaxpr as the
    plain entry — ``tests/test_obs.py`` pins jaxpr equality directly.
    """
    def build():
        from repro import obs

        fn, example_args = builder()

        def wrapped(*args):
            was = obs.enabled()
            obs.configure(enabled=True)
            try:
                with obs.span("audit_entry"):
                    return fn(*args)
            finally:
                obs.configure(enabled=was)
        return wrapped, example_args
    return build


#: name -> () -> (fn, example_args); every jit surface the audits gate.
ENTRY_POINTS: dict[str, Callable[[], tuple[Callable, tuple]]] = {
    "lamc_dense": _lamc_dense,
    "lamc_sparse": _lamc_sparse,
    "distributed_step": _distributed_step,
    "streaming_chunk": _streaming_chunk,
    "cosine_assign": _cosine_assign,
    "cosine_topk": _cosine_topk,
    "spmm": _spmm,
    "spmm_tiled": _spmm_tiled,
    "spmm_ata": _spmm_ata,
    "spmm_tiled_scaled": _spmm_tiled_scaled,
    "spmm_ata_gram": _spmm_ata_gram,
    "tiled_convert": _tiled_convert,
    # obs-enabled twins: same functions staged with telemetry switched on
    # (spans active, kernel_dispatch events firing). Auditing these keeps
    # the obs layer honest — if a hook ever leaked a primitive or a host
    # sync into traced code, these entries would diverge from their plain
    # twins and the A1/R2 rules would fire here first.
    "lamc_dense_obs": _with_obs(_lamc_dense),
    "streaming_chunk_obs": _with_obs(_streaming_chunk),
    "cosine_assign_obs": _with_obs(_cosine_assign),
    "spmm_ata_obs": _with_obs(_spmm_ata),
}


def trace_entry(name: str, x64: bool = False):
    """Stage one entry point to a ClosedJaxpr (no execution).

    ``x64=True`` re-traces under ``jax_enable_x64`` so A2 can see f64
    avals that default tracing silently truncates; the flag is always
    restored. Inputs are built before the flag flips so their dtypes stay
    the production f32/int32 — any f64 in the trace is then the
    function's own promotion, not an artifact of the harness.
    """
    fn, example_args = ENTRY_POINTS[name]()
    if not x64:
        return jax.make_jaxpr(fn)(*example_args)
    prev = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        return jax.make_jaxpr(fn)(*example_args)
    finally:
        jax.config.update("jax_enable_x64", prev)


def audit_entry_points(names: list[str] | None = None,
                       x64: bool = True) -> list[Finding]:
    """A1 (+A2 under x64) over the registry; trace failures are findings
    too — an entry point that stops tracing is itself a regression."""
    findings: list[Finding] = []
    for name in names or sorted(ENTRY_POINTS):
        try:
            closed = trace_entry(name, x64=x64)
        except Exception as exc:  # noqa: BLE001 — report, don't crash the lane
            findings.append(Finding(
                rule="A1", path=f"entry:{name}", line=0,
                message="entry point failed to trace",
                evidence=f"{type(exc).__name__}: {exc}"))
            continue
        findings.extend(
            jaxpr_audit.audit_entry_jaxpr(name, closed, x64_traced=x64))
    return findings


def recompile_targets() -> dict[str, tuple[Callable, Callable[[], tuple]]]:
    """A3 targets: public drivers called for real, twice, at fixed shape.

    ``make_args`` builds fresh buffers per call so a cache miss cannot
    hide behind buffer identity.
    """
    from repro.core import lamc
    from repro.streaming import assign, model as smodel

    cfg, plan = _small_cfg(), _small_plan()
    counter = {"n": 0}

    def lamc_args():
        counter["n"] += 1
        return (_dense(100 + counter["n"], 32, 32), cfg, plan)

    k, q, n_cols = 2, 8, 32
    model = smodel.CoclusterModel(
        row_labels=np.zeros(32, np.int32), col_labels=np.zeros(32, np.int32),
        row_votes=np.zeros((32, k), np.float32),
        col_votes=np.zeros((32, k), np.float32),
        row_sigs=np.asarray(_dense(200, k, q)),
        col_sigs=np.asarray(_dense(201, k, q)),
        row_mean=np.zeros(q, np.float32), col_mean=np.zeros(q, np.float32),
        anchor_rows=np.arange(q, dtype=np.int32),
        anchor_cols=np.arange(q, dtype=np.int32),
    )

    def assign_args():
        counter["n"] += 1
        return (model, _dense(300 + counter["n"], 16, n_cols))

    return {
        "lamc_cocluster": (lamc.lamc_cocluster, lamc_args),
        "assign_rows": (assign.assign_rows, assign_args),
    }
