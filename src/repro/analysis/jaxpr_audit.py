"""Layer 2 — jaxpr trace auditor (rules A1-A3, DESIGN.md §13).

The AST layer sees source; this layer sees what JAX will actually stage.
Entry points (``entry_points.ENTRY_POINTS``) are traced with abstract
inputs via ``jax.make_jaxpr`` and their jaxprs walked recursively through
every nested closed jaxpr (``pjit``, ``scan``, ``while``, ``cond``,
custom-call wrappers).

**A1 — RNG fused into gather-heavy equations.** The permanent regression
gate for the PR 4 defect: a ``threefry2x32``/``random_bits`` output that
reaches a ``gather`` operand *without passing a materialization barrier*
(``dot_general``, QR/Cholesky/solve/SVD — ops XLA cannot fuse a
generator through) lets XLA inline the generator into the gather and
recompute it per gathered element (measured ~7x per product). Implemented
as boolean taint propagation over the equation graph: RNG primitives
source taint, barrier primitives absorb it, a tainted ``gather`` operand
is a finding. RNG inside a ``while`` body is flagged unconditionally
(trip count is data-dependent — the draw count is not replayable);
RNG inside ``scan`` bodies is fine *by design* here (counter-derived
per-resample keys) as long as it stays barriered from gathers.

**A2 — unintended dtype promotion.** The same entry points are re-traced
under ``jax_enable_x64`` and every equation output checked for non-weak
``float64``/``complex128`` avals. With x64 off, a stray promotion (an
implicit-dtype ``random.normal``, a numpy f64 constant) is silently
truncated and invisible; under x64 it surfaces exactly where it would
change kernel numerics. Weak-typed scalars (Python literals) are exempt.

**A3 — recompile guard.** ``count_recompiles`` calls an entry point
twice with same-shape/dtype (fresh) arguments and counts XLA compile
events via the ``jax_log_compiles`` hook; any compile after warmup is a
cache miss — a non-hashable static, an accidental weak-type flip, or a
Python-object config leaking into trace identity.
"""

from __future__ import annotations

import contextlib
import logging
from typing import Callable, Iterator

import jax

from .findings import Finding

try:  # jax.core is the semi-public home through 0.4.x
    from jax import core as _jcore
except ImportError:  # pragma: no cover
    from jax._src import core as _jcore

__all__ = ["audit_rng_gather", "audit_dtypes", "count_recompiles",
           "audit_entry_jaxpr", "RNG_SOURCES", "BARRIERS"]

#: primitives that *generate* pseudo-random bits
RNG_SOURCES = frozenset({"threefry2x32", "random_bits", "random_gamma"})
#: primitives that stop taint. Two families: linear-algebra custom calls
#: whose results XLA materializes (a generator cannot be fused through
#: them), and reductions/sorts/contractions — the PR 4 hazard is a *pure
#: elementwise* chain from generator to gather operand (each gathered
#: element recomputes its own generator lane); once the dependence
#: collapses through a reduction or reordering, per-element regeneration
#: is no longer what a fused gather would do. This is also what keeps
#: legitimate sampling (inverse-CDF via cumsum/searchsorted, permutation
#: via sort, argmin-based selection) out of the findings.
BARRIERS = frozenset({
    # materializing linear algebra
    "dot_general", "qr", "householder_product", "cholesky",
    "triangular_solve", "svd", "eigh", "lu", "custom_linear_solve",
    "conv_general_dilated",
    # reductions / reorderings that end the elementwise chain
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "reduce_and",
    "reduce_or", "reduce_xor", "argmax", "argmin", "reduce_window_sum",
    "reduce_window_max", "reduce_window_min", "cumsum", "cumprod", "cummax",
    "cummin", "cumlogsumexp", "sort", "top_k",
})
#: per-element consumers that replay their operand per gathered index when
#: the producer fuses in
_SINKS = frozenset({"gather"})

_OPAQUE = frozenset({"pallas_call"})  # operands are materialized pre-launch


def _is_closed(x) -> bool:
    return isinstance(x, _jcore.ClosedJaxpr)


def _sub_named(eqn):
    """Sub-jaxpr for call-like eqns whose invars map 1:1 (pjit, remat,
    custom_jvp/vjp wrappers)."""
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        sub = eqn.params.get(key)
        if _is_closed(sub) and len(sub.jaxpr.invars) == len(eqn.invars):
            return sub
        if isinstance(sub, _jcore.Jaxpr) and len(sub.invars) == len(eqn.invars):
            return _jcore.ClosedJaxpr(sub, ())
    return None


def _iter_all_subjaxprs(params: dict) -> Iterator[_jcore.ClosedJaxpr]:
    for v in params.values():
        vals = v if isinstance(v, (list, tuple)) else [v]
        for u in vals:
            if _is_closed(u):
                yield u
            elif isinstance(u, _jcore.Jaxpr):
                yield _jcore.ClosedJaxpr(u, ())


# --------------------------------------------------------------------------
# A1 — taint propagation
# --------------------------------------------------------------------------

class _TaintWalker:
    def __init__(self, entry: str):
        self.entry = entry
        self.findings: list[Finding] = []
        self._seen: set[tuple] = set()

    def _finding(self, message: str, evidence: str) -> None:
        key = (message, evidence)
        if key not in self._seen:
            self._seen.add(key)
            self.findings.append(Finding(
                rule="A1", path=f"entry:{self.entry}", line=0,
                message=message, evidence=evidence))

    def propagate(self, closed: _jcore.ClosedJaxpr, taint_in: list[bool],
                  path: tuple[str, ...] = (), in_while: bool = False,
                  ) -> list[bool]:
        jaxpr = closed.jaxpr
        taint: dict = {}
        for var, t in zip(jaxpr.invars, taint_in):
            taint[var] = t

        def is_t(atom) -> bool:
            return (not isinstance(atom, _jcore.Literal)
                    and taint.get(atom, False))

        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            in_t = [is_t(v) for v in eqn.invars]
            sub = _sub_named(eqn)
            if name in RNG_SOURCES:
                if in_while:
                    self._finding(
                        "RNG primitive inside a while-loop body — draw "
                        "count is data-dependent, not replayable",
                        f"{' > '.join(path) or '<top>'} > {name}")
                out_t = [True] * len(eqn.outvars)
            elif name in BARRIERS:
                out_t = [False] * len(eqn.outvars)
            elif name in _SINKS:
                if in_t and in_t[0]:
                    self._finding(
                        "RNG output reaches a gather operand with no "
                        "materialization barrier — XLA can fuse the "
                        "generator into the gather (the PR 4 ~7x SpMM "
                        "regression)",
                        f"{' > '.join(path) or '<top>'} > {name}; insert an "
                        "orthonormalization / dot_general between the "
                        "sample and the sparse product")
                out_t = [any(in_t)] * len(eqn.outvars)
            elif name in _OPAQUE:
                out_t = [False] * len(eqn.outvars)
            elif name == "scan":
                nc = eqn.params["num_consts"]
                ncar = eqn.params["num_carry"]
                body = eqn.params["jaxpr"]
                cur = list(in_t)
                out = [False] * len(eqn.outvars)
                for _ in range(max(2, ncar + 1)):  # monotone fixpoint
                    out = self.propagate(body, cur, path + ("scan",),
                                         in_while)
                    changed = False
                    for i in range(ncar):
                        if out[i] and not cur[nc + i]:
                            cur[nc + i] = True
                            changed = True
                    if not changed:
                        break
                out_t = out
            elif name == "while":
                cn = eqn.params["cond_nconsts"]
                bn = eqn.params["body_nconsts"]
                cond = eqn.params["cond_jaxpr"]
                body = eqn.params["body_jaxpr"]
                carry_t = list(in_t[cn + bn:])
                for _ in range(max(2, len(carry_t) + 1)):
                    out = self.propagate(body, in_t[cn:cn + bn] + carry_t,
                                         path + ("while",), True)
                    changed = False
                    for i, t in enumerate(out):
                        if t and not carry_t[i]:
                            carry_t[i] = True
                            changed = True
                    if not changed:
                        break
                self.propagate(cond, in_t[:cn] + carry_t,
                               path + ("while_cond",), True)
                out_t = carry_t
            elif name == "cond":
                branches = eqn.params["branches"]
                outs = [self.propagate(b, in_t[1:], path + ("cond",),
                                       in_while) for b in branches]
                out_t = [any(o[i] for o in outs)
                         for i in range(len(eqn.outvars))]
            elif sub is not None:
                label = eqn.params.get("name", name)
                out_t = self.propagate(sub, in_t, path + (str(label),),
                                       in_while)
            else:
                out_t = [any(in_t)] * len(eqn.outvars)
            for v, t in zip(eqn.outvars, out_t):
                if not isinstance(v, _jcore.DropVar):
                    taint[v] = t
        return [is_t(v) for v in jaxpr.outvars]


def audit_rng_gather(entry: str, closed: _jcore.ClosedJaxpr) -> list[Finding]:
    """A1 over one traced entry point (inputs start untainted)."""
    walker = _TaintWalker(entry)
    walker.propagate(closed, [False] * len(closed.jaxpr.invars))
    return walker.findings


# --------------------------------------------------------------------------
# A2 — dtype promotion audit
# --------------------------------------------------------------------------

_BAD_DTYPES = ("float64", "complex128")


def audit_dtypes(entry: str, closed: _jcore.ClosedJaxpr) -> list[Finding]:
    """Flag non-weak f64/c128 equation outputs anywhere in the trace.

    Meaningful only when the trace ran under ``jax_enable_x64`` (see
    ``entry_points.trace_entry(x64=True)``) — with x64 off these dtypes
    cannot appear and the audit trivially passes.
    """
    findings: list[Finding] = []
    seen: set[tuple] = set()

    def walk(jaxpr: _jcore.Jaxpr, path: tuple[str, ...]) -> None:
        for eqn in jaxpr.eqns:
            name = eqn.primitive.name
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                dt = getattr(aval, "dtype", None)
                if (dt is not None and str(dt) in _BAD_DTYPES
                        and not getattr(aval, "weak_type", False)):
                    key = (name, str(dt), path)
                    if key in seen:
                        continue
                    seen.add(key)
                    findings.append(Finding(
                        rule="A2", path=f"entry:{entry}", line=0,
                        message=f"non-weak {dt} produced by {name!r} — an "
                                "implicit promotion the f32 kernel contract "
                                "silently truncates when x64 is off",
                        evidence=f"{' > '.join(path) or '<top>'} > {name}; "
                                 "pass an explicit dtype=jnp.float32"))
            for sub in _iter_all_subjaxprs(eqn.params):
                walk(sub.jaxpr, path + (str(eqn.params.get(
                    "name", name)),))

    walk(closed.jaxpr, ())
    return findings


def audit_entry_jaxpr(entry: str, closed: _jcore.ClosedJaxpr,
                      x64_traced: bool = False) -> list[Finding]:
    """A1 (+A2 when the trace ran under x64) over one entry point."""
    findings = audit_rng_gather(entry, closed)
    if x64_traced:
        findings += audit_dtypes(entry, closed)
    return findings


# --------------------------------------------------------------------------
# A3 — recompile guard
# --------------------------------------------------------------------------

@contextlib.contextmanager
def _capture_compiles() -> Iterator[list[str]]:
    """Capture XLA 'Compiling <fn> ...' events via jax_log_compiles."""
    records: list[str] = []

    class _Handler(logging.Handler):
        def emit(self, record: logging.LogRecord) -> None:
            msg = record.getMessage()
            if msg.startswith("Compiling "):
                records.append(msg)

    handler = _Handler(level=logging.DEBUG)
    logger = logging.getLogger("jax._src.interpreters.pxla")
    prev_level = logger.level
    prev_flag = jax.config.jax_log_compiles
    jax.config.update("jax_log_compiles", True)
    logger.addHandler(handler)
    if logger.level > logging.DEBUG or logger.level == logging.NOTSET:
        logger.setLevel(logging.DEBUG)
    try:
        yield records
    finally:
        logger.removeHandler(handler)
        logger.setLevel(prev_level)
        jax.config.update("jax_log_compiles", prev_flag)


def count_recompiles(entry: str, fn: Callable, make_args: Callable[[], tuple],
                     repeats: int = 2) -> tuple[int, list[Finding]]:
    """Call ``fn`` on fresh same-shape args ``1 + repeats`` times; any XLA
    compile event after the warmup call is a jit cache miss.

    ``make_args`` must build *new* arrays each call (same shapes/dtypes,
    different buffers) so donated/cached-buffer effects can't mask a miss.
    Returns ``(n_misses, findings)`` — findings non-empty iff misses > 0.
    """
    fn(*make_args())  # warmup: tracing + first compile are expected
    with _capture_compiles() as records:
        for _ in range(repeats):
            out = fn(*make_args())
        jax.block_until_ready(out)
    findings = []
    if records:
        findings.append(Finding(
            rule="A3", path=f"entry:{entry}", line=0,
            message=f"{len(records)} XLA compile(s) on same-shape repeat "
                    "calls — the jit cache is missing",
            evidence="; ".join(sorted(set(records))[:4])))
    return len(records), findings
