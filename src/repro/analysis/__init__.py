"""Static-analysis layer for the repro codebase (DESIGN.md §13).

Two layers guard the invariants the paper's numbers depend on:

* **AST lint** (:mod:`.ast_lint`) — rules R1-R4 over source: PRNG key
  reuse, host sync in jitted scope, non-static captured state, and
  wall-clock/legacy-RNG use where counter-derived keys are the contract.
* **jaxpr audit** (:mod:`.jaxpr_audit`, :mod:`.entry_points`,
  :mod:`.vmem`) — rules A1-A4 over the staged computation: RNG-into-
  gather fusion (the PR 4 regression gate), dtype promotion, recompile
  misses, and Pallas VMEM budgets.

CLI: ``python -m repro.analysis [--strict] [--json]``. Suppress a
finding in source with ``# repro: allow[RULE] reason``.
"""

from .findings import RULES, Finding, parse_pragmas  # noqa: F401

__all__ = ["Finding", "RULES", "parse_pragmas"]
