"""A4 — static VMEM estimator for Pallas kernel BlockSpecs (DESIGN.md §13).

A TPU core has ~16 MiB of VMEM feeding the MXU/VPU; a ``pallas_call``
whose resident working set — one block per input/output BlockSpec plus
every scratch buffer — exceeds it fails at compile time on hardware (and
silently *passes* under ``interpret=True``, which is exactly how an
oversized tile config survives CPU CI). This module prices a kernel's
working set from its BlockSpecs alone, so the check runs anywhere.

The estimator is the single source of truth for runtime fallback
decisions too: ``kernels.ops.spmm_ata`` asks :func:`ata_resident_bytes`
whether the fused normal-equations kernel's Y-stripe + output-stripe fit
the budget before choosing one launch over two (previously an ad-hoc
inline byte count with its own private budget constant).

``KERNEL_SPECS`` declares every kernel's blocks for representative tile
configs; the jaxpr-audit lane walks it and fails CI when a kernel's
default tiling stops fitting. The per-platform budget deliberately uses
a safety fraction: XLA needs VMEM headroom for semaphores, DMA staging
and double buffering, so committing all 16 MiB to declared blocks is
already an overflow in practice.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from .findings import Finding

__all__ = ["BlockUse", "KernelEstimate", "estimate_kernel",
           "vmem_budget_bytes", "ata_resident_bytes", "KERNEL_SPECS",
           "audit_vmem", "VMEM_BYTES_PER_CORE", "VMEM_SAFETY_FRACTION"]

#: physical VMEM per TPU core (v4/v5 class); see /opt guide "~16 MB/core".
VMEM_BYTES_PER_CORE = 16 * 2**20
#: fraction of physical VMEM the declared working set may claim — the rest
#: is headroom for double buffering and DMA staging.
VMEM_SAFETY_FRACTION = 0.75

# (sublane, lane) tiling granule for f32 — blocks not aligned to it are
# padded up by Mosaic, so the estimator prices the padded footprint.
_SUBLANE = 8
_LANE = 128


def vmem_budget_bytes(platform: str = "tpu") -> int:
    """Usable VMEM budget for one kernel's declared working set."""
    if platform != "tpu":  # interpret/jnp tiers have no VMEM ceiling
        return 2**62
    return int(VMEM_BYTES_PER_CORE * VMEM_SAFETY_FRACTION)


@dataclasses.dataclass(frozen=True)
class BlockUse:
    """One BlockSpec (or scratch shape) of a kernel invocation."""

    name: str                       # operand label, for the report
    block_shape: tuple[int, ...]    # per-grid-step resident block
    dtype: str = "float32"
    array_shape: tuple[int, ...] | None = None  # full (padded) operand

    def padded_block(self) -> tuple[int, ...]:
        """Block shape padded to the (8, 128) f32 tiling granule."""
        shape = tuple(int(s) for s in self.block_shape)
        if len(shape) == 0:
            return shape
        out = list(shape)
        out[-1] = max(1, math.ceil(out[-1] / _LANE)) * _LANE
        if len(out) >= 2:
            out[-2] = max(1, math.ceil(out[-2] / _SUBLANE)) * _SUBLANE
        return tuple(out)

    def nbytes(self) -> int:
        return int(np.prod(self.padded_block(), dtype=np.int64)
                   * np.dtype(self.dtype).itemsize)

    def divisibility_issues(self) -> list[str]:
        """Block dims must tile the (padded) array dims exactly — a ragged
        final block reads out of bounds on the DMA path."""
        if self.array_shape is None:
            return []
        issues = []
        for axis, (b, a) in enumerate(zip(self.block_shape,
                                          self.array_shape)):
            if b <= 0:
                issues.append(f"{self.name}: axis {axis} block dim {b} <= 0")
            elif a % b != 0:
                issues.append(
                    f"{self.name}: array dim {a} not divisible by block "
                    f"dim {b} on axis {axis}")
        return issues


@dataclasses.dataclass(frozen=True)
class KernelEstimate:
    name: str
    blocks: tuple[BlockUse, ...]
    total_bytes: int
    budget_bytes: int
    issues: tuple[str, ...]

    @property
    def fits(self) -> bool:
        return self.total_bytes <= self.budget_bytes and not self.issues

    def describe(self) -> str:
        parts = [f"{b.name}={b.block_shape}:{b.nbytes()}B"
                 for b in self.blocks]
        return (f"{self.name}: total {self.total_bytes}B of "
                f"{self.budget_bytes}B budget ({', '.join(parts)})")


def estimate_kernel(name: str, blocks: list[BlockUse],
                    platform: str = "tpu") -> KernelEstimate:
    total = sum(b.nbytes() for b in blocks)
    issues: list[str] = []
    for b in blocks:
        issues.extend(b.divisibility_issues())
    return KernelEstimate(name=name, blocks=tuple(blocks),
                          total_bytes=total,
                          budget_bytes=vmem_budget_bytes(platform),
                          issues=tuple(issues))


def ata_resident_bytes(n_tile_rows: int, n_tile_cols: int, bm: int, bk: int,
                       bn: int, itemsize: int = 4, *,
                       with_gram: bool = False,
                       scaled: bool = False) -> int:
    """Resident bytes of the fused ``A.T @ (A @ x)`` kernel per column
    stripe: the whole-height VMEM Y scratch ``(n_tr * bm, bn)`` plus the
    whole-height output stripe ``(n_tc * bk, bn)`` (both live across the
    full payload sweep — see ``kernels.spmm.spmm_ata_pallas``). The
    payload/x blocks stream through and are amortized against double-
    buffering headroom, not this figure.

    ``with_gram`` adds the ``(bn, bn)`` Gram output of the fused
    subspace-iteration step; ``scaled`` adds the per-payload row/col
    scale slivers (``(1, bm)`` + ``(1, bk)``, priced at their padded
    sublane granule)."""
    total = (n_tile_rows * bm + n_tile_cols * bk) * bn * itemsize
    if with_gram:
        total += bn * bn * itemsize
    if scaled:
        total += (_SUBLANE * max(bm, _LANE) + _SUBLANE * max(bk, _LANE)) \
            * itemsize
    return total


def _scale_blocks(bm: int, bk: int) -> list[BlockUse]:
    return [
        BlockUse("row_scale", (1, bm)),
        BlockUse("col_scale", (1, bk)),
    ]


def _spmm_tiled_blocks(g: int, bm: int, bk: int, bn: int, n_pad: int,
                       m_out: int, scaled: bool = False) -> list[BlockUse]:
    blocks = [
        BlockUse("payload", (1, bm, bk), array_shape=(g, bm, bk)),
        BlockUse("rhs", (bk, bn), array_shape=(bk * 4, n_pad)),
        BlockUse("out", (bm, bn), array_shape=(m_out, n_pad)),
    ]
    if scaled:
        blocks += _scale_blocks(bm, bk)
    return blocks


def _spmm_ata_blocks(n_tr: int, n_tc: int, bm: int, bk: int, bn: int,
                     scaled: bool = False,
                     with_gram: bool = False) -> list[BlockUse]:
    blocks = [
        BlockUse("payload", (1, bm, bk)),
        BlockUse("x", (bk, bn)),
        BlockUse("out_stripe", (n_tc * bk, bn)),
        BlockUse("y_scratch", (n_tr * bm, bn)),
    ]
    if scaled:
        blocks += _scale_blocks(bm, bk)
    if with_gram:
        blocks.append(BlockUse("gram", (bn, bn)))
    return blocks


#: kernel name -> () -> KernelEstimate at its shipped default tile config.
#: These are the shapes the ops wrappers actually launch; the audit fails
#: when an edit makes any default config stop fitting VMEM.
KERNEL_SPECS: dict[str, Callable[[], KernelEstimate]] = {
    # ops.kmeans_assign: tile_p=512 points, d<=1024 feature cols, k<=512
    "kmeans_assign": lambda: estimate_kernel("kmeans_assign", [
        BlockUse("x", (512, 1024), array_shape=(4096, 1024)),
        BlockUse("centroids", (512, 1024), array_shape=(512, 1024)),
        BlockUse("labels", (512,), dtype="int32", array_shape=(4096,)),
        BlockUse("d2", (512,), array_shape=(4096,)),
    ]),
    # ops.kmeans_update adds the (K, D) sums and (1, K) counts accumulators
    "kmeans_update": lambda: estimate_kernel("kmeans_update", [
        BlockUse("x", (512, 1024), array_shape=(4096, 1024)),
        BlockUse("centroids", (512, 1024), array_shape=(512, 1024)),
        BlockUse("weights", (512,), array_shape=(4096,)),
        BlockUse("labels", (512,), dtype="int32", array_shape=(4096,)),
        BlockUse("d2", (512,), array_shape=(4096,)),
        BlockUse("sums", (512, 1024), array_shape=(512, 1024)),
        BlockUse("counts", (1, 512), array_shape=(1, 512)),
    ]),
    # ops.cosine_assign: serving scorer, q<=1024 anchor dims, K<=1024 sigs
    "cosine_assign": lambda: estimate_kernel("cosine_assign", [
        BlockUse("x", (512, 1024), array_shape=(4096, 1024)),
        BlockUse("signatures", (1024, 1024), array_shape=(1024, 1024)),
        BlockUse("labels", (512,), dtype="int32", array_shape=(4096,)),
        BlockUse("score", (512,), array_shape=(4096,)),
    ]),
    "cosine_topk": lambda: estimate_kernel("cosine_topk", [
        BlockUse("x", (512, 1024), array_shape=(4096, 1024)),
        BlockUse("signatures", (1024, 1024), array_shape=(1024, 1024)),
        BlockUse("labels", (512, 8), dtype="int32", array_shape=(4096, 8)),
        BlockUse("scores", (512, 8), array_shape=(4096, 8)),
    ]),
    # kernels.bipartite_normalize at its default 256x256 tiles
    "scale_apply": lambda: estimate_kernel("scale_apply", [
        BlockUse("a", (256, 256), array_shape=(4096, 4096)),
        BlockUse("d1", (256,), array_shape=(4096,)),
        BlockUse("d2", (256,), array_shape=(4096,)),
        BlockUse("out", (256, 256), array_shape=(4096, 4096)),
    ]),
    # flash attention: tile_q=512, tile_k=512, head dim 128 + m/l/acc scratch
    "flash_attention": lambda: estimate_kernel("flash_attention", [
        BlockUse("q", (1, 512, 128), array_shape=(8, 4096, 128)),
        BlockUse("k", (1, 512, 128), array_shape=(8, 4096, 128)),
        BlockUse("v", (1, 512, 128), array_shape=(8, 4096, 128)),
        BlockUse("out", (1, 512, 128), array_shape=(8, 4096, 128)),
        BlockUse("acc_scratch", (512, 128)),
        BlockUse("m_scratch", (512, _LANE)),
        BlockUse("l_scratch", (512, _LANE)),
    ]),
    # tiled SpMM family at the shipped bm=bk=bn=128 tiles
    "spmm_tiled": lambda: estimate_kernel(
        "spmm_tiled", _spmm_tiled_blocks(g=64, bm=128, bk=128, bn=128,
                                         n_pad=512, m_out=1024)),
    # fused normal equations at the largest tile grid the runtime fallback
    # admits under the shared budget (see ops.spmm_ata)
    "spmm_ata": lambda: estimate_kernel(
        "spmm_ata", _spmm_ata_blocks(n_tr=16, n_tc=16, bm=128, bk=128,
                                     bn=128)),
    # scale-fused variants (normalize_bipartite applied in VMEM): the two
    # per-payload scale slivers ride along with every payload block
    "spmm_tiled_scaled": lambda: estimate_kernel(
        "spmm_tiled_scaled", _spmm_tiled_blocks(g=64, bm=128, bk=128,
                                                bn=128, n_pad=512,
                                                m_out=1024, scaled=True)),
    # fused subspace-iteration step: scaled SpMM -> Gram of the resident
    # output stripe, all in one launch (see ops.spmm_ata with_gram=True)
    "spmm_ata_fused_step": lambda: estimate_kernel(
        "spmm_ata_fused_step", _spmm_ata_blocks(n_tr=16, n_tc=16, bm=128,
                                                bk=128, bn=128, scaled=True,
                                                with_gram=True)),
}


def audit_vmem(platform: str = "tpu") -> list[Finding]:
    """A4 pass: every registered kernel's default config must fit."""
    findings = []
    for name, build in sorted(KERNEL_SPECS.items()):
        est = build()
        if est.total_bytes > est.budget_bytes:
            findings.append(Finding(
                rule="A4", path=f"kernel:{name}", line=0,
                message=f"VMEM working set {est.total_bytes} B exceeds "
                        f"budget {est.budget_bytes} B",
                evidence=est.describe()))
        for issue in est.issues:
            findings.append(Finding(
                rule="A4", path=f"kernel:{name}", line=0,
                message=f"block/array divisibility violation: {issue}",
                evidence=est.describe()))
    return findings
