"""Layer 1 — AST lint passes over ``src/`` (rules R1-R4, DESIGN.md §13).

Repo-specific correctness rules that a generic linter cannot express:

* **R1 — PRNG key reuse.** A ``jax.random`` key is a counter, not a
  stream: consuming the same key twice yields *identical* samples. The
  rule tracks key-typed names through one function body (linear walk,
  branches merged, loop bodies walked twice to catch cross-iteration
  reuse) and fires when a key is sampled/escaped twice without an
  intervening rebind, or sampled after it was already ``split``/
  ``fold_in``-derived from (the parent must die once children exist).

* **R2 — host sync inside jitted scope.** ``float()``/``.item()``/
  ``np.*`` on a traced value forces a device sync and graph break. The
  rule builds the module call graph from every jit root (``@jax.jit``
  decorations, ``jax.jit(f)`` calls, ``pallas_call``/``shard_map``
  bodies) and flags host conversions applied to values tainted by
  ``jnp.``/``jax.`` computation or function parameters.

* **R3 — non-static Python state captured by jitted code.** Mutable
  default arguments (shared across calls — silently baked into a trace),
  ``global`` mutation inside jit-reachable functions, and writes to
  module-level mutable containers from jit-reachable scope.

* **R4 — wall-clock / legacy numpy RNG in ``src/repro``.** The repo's
  reproducibility contract is counter-derived keys ``(seed, chunk,
  block)``; the legacy ``np.random.*`` module samplers (hidden global
  stream), unseeded ``default_rng()``, and ``time.*`` flowing into seeds
  all break bit-replayability (the recovery-equivalence invariant of
  DESIGN.md §12).

False positives are suppressed in place with ``# repro: allow[RULE]
reason`` (``findings.parse_pragmas``).
"""

from __future__ import annotations

import ast
import os
from typing import Iterable

from .findings import Finding, filter_suppressed, parse_pragmas

__all__ = ["lint_source", "run_ast_lint", "iter_python_files"]

# jax.random functions that *produce* keys rather than consume entropy.
_KEY_PRODUCERS = {"key", "PRNGKey", "split", "fold_in", "wrap_key_data",
                  "clone"}
# jax.random functions that derive children but leave the parent logically
# dead (sampling the parent afterwards correlates with every child).
_KEY_DERIVERS = {"split", "fold_in", "clone"}
# module-level legacy numpy samplers (the hidden global MT19937 stream);
# everything else under np.random (default_rng, Generator, SeedSequence,
# bit generators) is the counter-friendly API and allowed.
_NP_LEGACY_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
                 "PCG64", "Philox", "SFC64", "MT19937"}
_TIME_FNS = {"time", "time_ns", "perf_counter", "perf_counter_ns",
             "monotonic", "monotonic_ns"}


def _dotted(node: ast.AST) -> str | None:
    """'a.b.c' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Aliases:
    """Resolve import aliases to canonical dotted module paths."""

    def __init__(self, tree: ast.Module):
        self.map: dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.map[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    if a.name != "*":
                        self.map[a.asname or a.name] = (
                            f"{node.module}.{a.name}")

    def resolve(self, name: str | None) -> str | None:
        if name is None:
            return None
        head, _, rest = name.partition(".")
        base = self.map.get(head, head)
        return f"{base}.{rest}" if rest else base


def _call_target(call: ast.Call, aliases: _Aliases) -> str | None:
    return aliases.resolve(_dotted(call.func))


def _is_jax_random(target: str | None) -> bool:
    return bool(target) and (target.startswith("jax.random.")
                             or target.startswith("jax._src.random."))


def _names_in(node: ast.AST) -> Iterable[str]:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name):
            yield sub.id


# --------------------------------------------------------------------------
# R1 — PRNG key reuse
# --------------------------------------------------------------------------

_FRESH, _DERIVED, _CONSUMED = "fresh", "derived", "consumed"


class _R1Scope:
    """Linear symbolic walk of one function body tracking key states."""

    def __init__(self, aliases: _Aliases, findings: list[Finding]):
        self.aliases = aliases
        self.findings = findings
        self.state: dict[str, str] = {}
        self.first_use: dict[str, int] = {}

    # -- helpers -----------------------------------------------------------
    def _producer_call(self, node: ast.AST) -> str | None:
        """'key'/'split'/... if node is a key-producing jax.random call."""
        if isinstance(node, ast.Call):
            tgt = _call_target(node, self.aliases)
            if _is_jax_random(tgt) and tgt.rsplit(".", 1)[-1] in _KEY_PRODUCERS:
                return tgt.rsplit(".", 1)[-1]
        return None

    def _bind(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.state[target.id] = _FRESH
            self.first_use.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._bind(elt)

    def _fire(self, name: str, node: ast.AST, how: str) -> None:
        self.findings.append(Finding(
            rule="R1", path="", line=node.lineno,
            message=f"key {name!r} {how}",
            evidence=f"previous use at line "
                     f"{self.first_use.get(name, node.lineno)}; rebind or "
                     f"derive a child via split/fold_in"))

    def _consume(self, name: str, node: ast.AST, via: str) -> None:
        st = self.state.get(name)
        if st == _CONSUMED:
            self._fire(name, node, f"consumed again by {via} after it was "
                                   "already consumed")
        elif st == _DERIVED:
            self._fire(name, node, f"consumed by {via} after split/fold_in "
                                   "derived children from it")
        else:
            self.state[name] = _CONSUMED
            self.first_use.setdefault(name, node.lineno)

    def _derive(self, name: str, node: ast.AST) -> None:
        # deriving (split/fold_in/clone) is always safe, even from an
        # already-consumed key: the child stream is distinct from the
        # sample drawn earlier. Only *sampling* twice collides.
        self.state[name] = _DERIVED
        self.first_use.setdefault(name, node.lineno)

    # -- statement walk ----------------------------------------------------
    def run(self, body: list[ast.stmt]) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
            value = stmt.value
            if value is not None:
                self._expr(value)
            targets = (stmt.targets if isinstance(stmt, ast.Assign)
                       else [stmt.target])
            if value is not None and self._producer_call(value):
                for t in targets:
                    self._bind(t)
            else:
                for t in targets:
                    if isinstance(t, ast.Name):
                        self.state.pop(t.id, None)
        elif isinstance(stmt, ast.If):
            before = dict(self.state)
            self.run(stmt.body)
            after_if = self.state
            self.state = dict(before)
            self.run(stmt.orelse)
            merged = dict(self.state)
            for k, v in after_if.items():  # most-consumed state wins
                order = {_FRESH: 0, _DERIVED: 1, _CONSUMED: 2}
                if order.get(v, 0) > order.get(merged.get(k, _FRESH), 0):
                    merged[k] = v
            self.state = merged
        elif isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
            # two passes over the body: the second catches keys consumed
            # once per iteration without a per-iteration rebind/fold_in
            iter_is_keys = False
            if isinstance(stmt, (ast.For, ast.AsyncFor)):
                self._expr(stmt.iter)
                # the loop target is key-typed only when iterating keys
                # (``for k in jax.random.split(...)`` or over a tracked
                # key batch); any other loop variable shadows key state
                iter_is_keys = (
                    self._producer_call(stmt.iter) is not None
                    or (isinstance(stmt.iter, ast.Name)
                        and stmt.iter.id in self.state))
                if not iter_is_keys:
                    for name in _names_in(stmt.target):
                        self.state.pop(name, None)
            else:
                self._expr(stmt.test)
            for _pass in range(2):
                if iter_is_keys:
                    # each iteration rebinds the target to a fresh batch
                    # element, so consumption never carries across passes
                    self._bind(stmt.target)
                self.run(stmt.body)
            self.run(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._expr(item.context_expr)
            self.run(stmt.body)
        elif isinstance(stmt, ast.Try):
            self.run(stmt.body)
            for h in stmt.handlers:
                self.run(h.body)
            self.run(stmt.orelse)
            self.run(stmt.finalbody)
        elif isinstance(stmt, (ast.Return, ast.Expr)):
            if stmt.value is not None:
                self._expr(stmt.value, returning=isinstance(stmt, ast.Return))
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            pass  # nested defs get their own scope via the module walk
        # other statements don't move keys

    # -- expression walk ---------------------------------------------------
    def _expr(self, node: ast.AST, returning: bool = False) -> None:
        if isinstance(node, ast.Call):
            self._call(node)
            return
        if isinstance(node, ast.Lambda):
            self._expr(node.body)
            return
        for child in ast.iter_child_nodes(node):
            self._expr(child, returning=returning)

    def _call(self, call: ast.Call) -> None:
        for a in call.args:
            self._expr(a)
        for kw in call.keywords:
            self._expr(kw.value)
        tgt = _call_target(call, self.aliases)
        if _is_jax_random(tgt):
            fn = tgt.rsplit(".", 1)[-1]
            if fn in {"key", "PRNGKey", "wrap_key_data", "key_data"}:
                return  # constructors consume ints, not keys
            first = call.args[0] if call.args else None
            if isinstance(first, ast.Name) and first.id in self.state:
                if fn in _KEY_DERIVERS:
                    self._derive(first.id, call)
                else:
                    self._consume(first.id, call, f"jax.random.{fn}")
            return
        # any other call: a key passed *whole* escapes (the callee will
        # consume it — a second escape of the same key is reuse). Only
        # bare names count: ``fn(keys[i])`` hands over one element of a
        # key batch, which is the standard fan-out idiom, not reuse.
        for arg in list(call.args) + [kw.value for kw in call.keywords]:
            if isinstance(arg, ast.Name) and arg.id in self.state:
                self._consume(arg.id, call, f"call to {tgt or '<expr>'}")


def _r1_function(fn: ast.AST, aliases: _Aliases,
                 findings: list[Finding]) -> None:
    scope = _R1Scope(aliases, findings)
    body = fn.body if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef,
                                      ast.Module)) else []
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        # params used as the key argument of any jax.random call are
        # key-typed and start fresh
        key_params = set()
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                tgt = _call_target(sub, aliases)
                if (_is_jax_random(tgt)
                        and tgt.rsplit(".", 1)[-1] not in
                        {"key", "PRNGKey", "wrap_key_data"}
                        and sub.args and isinstance(sub.args[0], ast.Name)):
                    key_params.add(sub.args[0].id)
        args = fn.args
        all_params = [a.arg for a in (args.posonlyargs + args.args
                                      + args.kwonlyargs)]
        for p in all_params:
            if p in key_params:
                scope.state[p] = _FRESH
    scope.run(body)


# --------------------------------------------------------------------------
# R2/R3 — jit reachability + host sync + captured state
# --------------------------------------------------------------------------

def _decorator_is_jit(dec: ast.AST, aliases: _Aliases) -> bool:
    tgt = aliases.resolve(_dotted(dec))
    if tgt in ("jax.jit", "jit"):
        return True
    if isinstance(dec, ast.Call):
        # functools.partial(jax.jit, ...) / jax.jit(...) as decorator factory
        head = aliases.resolve(_dotted(dec.func))
        if head in ("jax.jit", "jit"):
            return True
        if head in ("functools.partial", "partial") and dec.args:
            return aliases.resolve(_dotted(dec.args[0])) in ("jax.jit", "jit")
    return False


def _jit_roots(tree: ast.Module, aliases: _Aliases,
               functions: dict[str, ast.AST]) -> set[str]:
    roots: set[str] = set()
    for name, fn in functions.items():
        for dec in getattr(fn, "decorator_list", []):
            if _decorator_is_jit(dec, aliases):
                roots.add(name)
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        tgt = _call_target(node, aliases)
        args = node.args
        if tgt in ("jax.jit", "jit") and args:
            n = _dotted(args[0])
            if n in functions:
                roots.add(n)
        # pallas kernel bodies and shard_map bodies trace under jit
        if tgt and (tgt.endswith("pallas_call") or tgt.endswith("shard_map")):
            if args:
                n = _dotted(args[0])
                if n in functions:
                    roots.add(n)
    return roots


def _reachable(functions: dict[str, ast.AST], roots: set[str]) -> set[str]:
    calls: dict[str, set[str]] = {}
    for name, fn in functions.items():
        refs = {n for n in _names_in(fn) if n in functions and n != name}
        calls[name] = refs
    seen = set(roots)
    frontier = list(roots)
    while frontier:
        cur = frontier.pop()
        for nxt in calls.get(cur, ()):
            if nxt not in seen:
                seen.add(nxt)
                frontier.append(nxt)
    return seen


_TRACED_ROOTS = ("jnp.", "jax.", "lax.")
_STATIC_PARAM_NAMES = {"self", "cls", "cfg", "config", "plan", "mesh",
                       "interpret"}


def _r2_r3_function(fn: ast.AST, aliases: _Aliases,
                    module_mutables: set[str],
                    findings: list[Finding]) -> None:
    # shallow taint: params + names assigned from jax/jnp expressions
    traced: set[str] = set()
    args = fn.args
    for a in args.posonlyargs + args.args + args.kwonlyargs:
        if a.arg not in _STATIC_PARAM_NAMES:
            traced.add(a.arg)

    def expr_traced(node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and sub.id in traced:
                return True
            if isinstance(sub, ast.Call):
                tgt = aliases.resolve(_dotted(sub.func)) or ""
                if tgt.startswith(("jax.", "jnp.", "jax.numpy.")):
                    return True
        return False

    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and expr_traced(node.value):
            for t in node.targets:
                for n in _names_in(t):
                    traced.add(n)

    for node in ast.walk(fn):
        if isinstance(node, ast.Global):
            findings.append(Finding(
                rule="R3", path="", line=node.lineno,
                message=f"'global {', '.join(node.names)}' inside "
                        "jit-reachable code — module state mutated after "
                        "trace is silently stale",
                evidence="thread state through function arguments instead"))
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                base = t
                while isinstance(base, (ast.Subscript, ast.Attribute)):
                    base = base.value
                if (isinstance(base, ast.Name) and base.id in module_mutables
                        and not isinstance(t, ast.Name)):
                    findings.append(Finding(
                        rule="R3", path="", line=node.lineno,
                        message=f"write into module-level mutable "
                                f"{base.id!r} from jit-reachable code",
                        evidence="jit captures the object at trace time; "
                                 "later writes don't retrace"))
        if not isinstance(node, ast.Call):
            continue
        tgt = aliases.resolve(_dotted(node.func)) or ""
        # .item() on anything traced-ish
        if (isinstance(node.func, ast.Attribute) and node.func.attr == "item"
                and expr_traced(node.func.value)):
            findings.append(Finding(
                rule="R2", path="", line=node.lineno,
                message=".item() inside jit-reachable code blocks on device "
                        "sync (or fails under trace)",
                evidence="keep the value on device, or hoist the readback "
                         "out of the jitted scope"))
        elif tgt in ("float", "int", "bool") and node.args and expr_traced(
                node.args[0]) and not isinstance(node.args[0], ast.Constant):
            findings.append(Finding(
                rule="R2", path="", line=node.lineno,
                message=f"{tgt}() applied to a traced value inside "
                        "jit-reachable code forces a host sync",
                evidence="use jnp casts / keep the value abstract"))
        elif (tgt.startswith(("np.", "numpy."))
              and not tgt.startswith(("np.random.", "numpy.random."))
              and any(expr_traced(a) for a in node.args)):
            findings.append(Finding(
                rule="R2", path="", line=node.lineno,
                message=f"{tgt}(...) on a traced value inside jit-reachable "
                        "code materializes on host",
                evidence="use the jnp equivalent"))


def _mutable_defaults(tree: ast.Module, findings: list[Finding]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            continue
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            bad = isinstance(d, (ast.List, ast.Dict, ast.Set))
            if isinstance(d, ast.Call):
                callee = _dotted(d.func)
                bad = callee in ("list", "dict", "set")
            if bad:
                findings.append(Finding(
                    rule="R3", path="", line=d.lineno,
                    message="mutable default argument is shared across "
                            "calls and baked into any jit trace",
                    evidence="default to None and construct inside the body"))


def _module_mutables(tree: ast.Module) -> set[str]:
    out = set()
    for stmt in tree.body:
        if isinstance(stmt, ast.Assign) and isinstance(
                stmt.value, (ast.List, ast.Dict, ast.Set)):
            for t in stmt.targets:
                if isinstance(t, ast.Name):
                    out.add(t.id)
    return out


# --------------------------------------------------------------------------
# R4 — wall clock / legacy numpy RNG
# --------------------------------------------------------------------------

def _strip_annotations(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a tree skipping annotation subtrees (np.random.Generator type
    hints are not calls into the legacy stream)."""
    skip: set[int] = set()
    for node in ast.walk(fn):
        ann = getattr(node, "annotation", None)
        if ann is not None:
            for sub in ast.walk(ann):
                skip.add(id(sub))
        if isinstance(node, ast.AnnAssign) and node.annotation is not None:
            for sub in ast.walk(node.annotation):
                skip.add(id(sub))
    for node in ast.walk(fn):
        if id(node) not in skip:
            yield node


def _r4_module(tree: ast.Module, aliases: _Aliases,
               findings: list[Finding]) -> None:
    for node in _strip_annotations(tree):
        if not isinstance(node, ast.Call):
            continue
        tgt = aliases.resolve(_dotted(node.func)) or ""
        norm = tgt.replace("numpy.", "np.", 1)
        if norm.startswith("np.random."):
            fn = norm.split(".", 2)[2] if norm.count(".") >= 2 else ""
            leaf = fn.split(".")[0]
            if leaf == "default_rng" and not node.args and not node.keywords:
                findings.append(Finding(
                    rule="R4", path="", line=node.lineno,
                    message="np.random.default_rng() without a seed draws "
                            "from OS entropy — not replayable",
                    evidence="derive the seed from the (seed, step) "
                             "counters the repo keys everything on"))
            elif leaf and leaf not in _NP_LEGACY_OK:
                findings.append(Finding(
                    rule="R4", path="", line=node.lineno,
                    message=f"legacy np.random.{leaf} uses the hidden "
                            "global stream — not counter-derived",
                    evidence="use np.random.default_rng([seed, step]) or "
                             "jax.random with fold_in"))
        if norm.startswith("time.") and norm.split(".")[1] in _TIME_FNS:
            # only a problem when the clock flows into randomness/seeds —
            # detected one level up (call-arg / seed-assign contexts)
            continue
    # clock-into-seed contexts
    for node in _strip_annotations(tree):
        time_call = None
        ctx = None
        if isinstance(node, ast.Call):
            tgt = aliases.resolve(_dotted(node.func)) or ""
            norm = tgt.replace("numpy.", "np.", 1)
            if (norm.startswith(("np.random.", "jax.random."))
                    or norm.endswith((".default_rng", ".key", ".PRNGKey"))):
                for a in list(node.args) + [k.value for k in node.keywords]:
                    for sub in ast.walk(a):
                        if isinstance(sub, ast.Call):
                            t2 = aliases.resolve(_dotted(sub.func)) or ""
                            if (t2.startswith("time.")
                                    and t2.split(".")[1] in _TIME_FNS):
                                time_call, ctx = sub, norm
        elif isinstance(node, ast.Assign):
            names = [t.id for t in node.targets if isinstance(t, ast.Name)]
            if any("seed" in n.lower() for n in names):
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Call):
                        t2 = aliases.resolve(_dotted(sub.func)) or ""
                        if (t2.startswith("time.")
                                and t2.split(".")[1] in _TIME_FNS):
                            time_call, ctx = sub, f"seed name {names!r}"
        if time_call is not None:
            findings.append(Finding(
                rule="R4", path="", line=time_call.lineno,
                message="wall clock flows into a seed/RNG — every run "
                        "draws a different stream",
                evidence=f"context: {ctx}; pass an explicit counter-derived "
                         "seed instead"))


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def lint_source(path: str, source: str) -> list[Finding]:
    """All R-rule findings for one file (pragmas NOT yet applied)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:  # surfaced as its own finding, not a crash
        return [Finding(rule="R0", path=path, line=e.lineno or 0,
                        message=f"syntax error: {e.msg}")]
    aliases = _Aliases(tree)
    raw: list[Finding] = []

    functions: dict[str, ast.AST] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            functions.setdefault(node.name, node)

    # R1 over every function and the module body
    for fn in functions.values():
        _r1_function(fn, aliases, raw)
    _r1_function(tree, aliases, raw)

    # R2/R3 over jit-reachable functions
    roots = _jit_roots(tree, aliases, functions)
    reach = _reachable(functions, roots)
    mutables = _module_mutables(tree)
    for name in reach:
        _r2_r3_function(functions[name], aliases, mutables, raw)
    _mutable_defaults(tree, raw)

    # R4 only where counter keys are the contract
    if "src/repro" in path.replace(os.sep, "/") or path.startswith("repro/"):
        _r4_module(tree, aliases, raw)

    seen = set()
    out = []
    for f in raw:
        f = Finding(rule=f.rule, path=path, line=f.line, message=f.message,
                    evidence=f.evidence)
        if f.key() not in seen:
            seen.add(f.key())
            out.append(f)
    return out


def iter_python_files(paths: list[str]) -> Iterable[str]:
    for p in paths:
        if os.path.isfile(p) and p.endswith(".py"):
            yield p
        elif os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def run_ast_lint(paths: list[str]) -> tuple[list[Finding], list[Finding]]:
    """Lint every .py under ``paths``; returns (active, suppressed)."""
    findings: list[Finding] = []
    pragmas: dict[str, dict[int, set[str]]] = {}
    for path in iter_python_files(paths):
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        pragmas[path] = parse_pragmas(source)
        findings.extend(lint_source(path, source))
    return filter_suppressed(findings, pragmas)
