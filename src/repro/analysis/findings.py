"""Finding records, ``# repro: allow[RULE]`` pragmas, and report rendering.

Every analyzer layer (AST lint, jaxpr audit, VMEM estimator) emits
:class:`Finding` records. A finding names its rule, where it anchors
(``path:line`` for lint findings, an entry-point name for trace-audit
findings), and the evidence that makes it actionable.

Suppression is source-anchored: a ``# repro: allow[R1]`` comment on the
offending line (or on a comment-only line directly above it) silences
that rule there. Pragmas carry a free-text justification after the
bracket — the lint layer does not parse it, but CI review should:
an allow pragma without a reason is a smell.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Iterable

__all__ = ["Finding", "parse_pragmas", "filter_suppressed",
           "render_text", "render_json", "RULES"]

#: rule id -> one-line description (the catalog DESIGN.md §13 documents)
RULES = {
    "R1": "PRNG key reuse: a key consumed twice without split/fold_in",
    "R2": "host sync inside jitted scope (float()/.item()/np.* on traced values)",
    "R3": "non-static Python state captured by jitted code (mutable defaults, "
          "mutated module globals)",
    "R4": "wall-clock or legacy numpy RNG where counter-derived keys are the "
          "contract",
    "A1": "RNG generation feeding a gather-heavy op without a materialization "
          "barrier (the PR 4 threefry-into-SpMM fusion), or RNG inside a "
          "while body",
    "A2": "unintended dtype promotion (non-weak f64/c128 in a traced entry "
          "point)",
    "A3": "jit cache miss on a same-shape/dtype repeat call (hidden recompile)",
    "A4": "Pallas kernel VMEM-resident blocks + scratch exceed the per-"
          "platform budget, or block shape does not tile the array",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str       # "R1".."R4" (lint) / "A1".."A4" (trace audit)
    path: str       # repo-relative file path, or "entry:<name>" for audits
    line: int       # 1-based source line; 0 when not source-anchored
    message: str    # what is wrong, in one sentence
    evidence: str = ""  # the snippet / primitive path / byte math backing it

    def key(self) -> tuple:
        return (self.rule, self.path, self.line, self.message)

    def to_dict(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "message": self.message, "evidence": self.evidence}


_PRAGMA_RE = re.compile(r"#\s*repro:\s*allow\[([A-Za-z0-9*,\s]+)\]")


def parse_pragmas(source: str) -> dict[int, set[str]]:
    """Map line number -> set of allowed rule ids (``{"*"}`` allows all).

    A pragma on a code line covers that line. A pragma on a line whose
    code content is only the comment covers the *next* line as well, so
    long statements can carry the pragma above them.
    """
    allowed: dict[int, set[str]] = {}
    lines = source.splitlines()
    for i, text in enumerate(lines, start=1):
        m = _PRAGMA_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        allowed.setdefault(i, set()).update(rules)
        if text[: m.start()].strip() == "":  # comment-only line
            allowed.setdefault(i + 1, set()).update(rules)
    return allowed


def _covers(rules: set[str], rule: str) -> bool:
    return "*" in rules or rule in rules


def filter_suppressed(findings: Iterable[Finding],
                      pragmas_by_path: dict[str, dict[int, set[str]]],
                      ) -> tuple[list[Finding], list[Finding]]:
    """Split findings into (active, suppressed) using per-file pragmas.

    Multi-line statements anchor their finding at the statement's first
    line, which is where the pragma must sit (or the comment line above).
    """
    active, suppressed = [], []
    for f in findings:
        rules = pragmas_by_path.get(f.path, {}).get(f.line, set())
        (suppressed if _covers(rules, f.rule) else active).append(f)
    return active, suppressed


def render_text(findings: list[Finding], suppressed: list[Finding],
                strict: bool) -> str:
    out = []
    for f in sorted(findings, key=Finding.key):
        loc = f.path if f.line == 0 else f"{f.path}:{f.line}"
        out.append(f"{loc}: [{f.rule}] {f.message}")
        if f.evidence:
            for ln in f.evidence.splitlines():
                out.append(f"    {ln}")
    n, s = len(findings), len(suppressed)
    tail = f"{n} finding{'s' if n != 1 else ''}"
    if s:
        tail += f" ({s} suppressed by pragma)"
    if strict and n:
        tail += " — failing (--strict)"
    out.append(tail)
    return "\n".join(out)


def render_json(findings: list[Finding], suppressed: list[Finding]) -> str:
    return json.dumps(
        {"findings": [f.to_dict() for f in sorted(findings, key=Finding.key)],
         "suppressed": [f.to_dict() for f in sorted(suppressed,
                                                    key=Finding.key)],
         "rules": RULES},
        indent=2)
