"""Deterministic synthetic LM token pipeline.

Provides the training/serving data path for the assigned-architecture stack:
an infinite, restart-reproducible stream of (tokens, targets) batches. Data
is generated with a counter-based PRNG keyed on (seed, step) so that:

  * resuming from a checkpoint at step S regenerates the exact same batch
    sequence (fault-tolerance requirement — no data-loader state to persist);
  * every data-parallel shard derives its own slice locally — the pipeline
    performs zero host-to-host communication.

The token distribution is a Zipfian unigram mix with injected n-gram
structure so cross-entropy actually decreases during the example training
runs (pure-uniform tokens would make loss curves flat and tests vacuous).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["TokenBatchSpec", "synthetic_lm_batches", "make_batch"]


@dataclasses.dataclass(frozen=True)
class TokenBatchSpec:
    batch_size: int
    seq_len: int
    vocab_size: int
    seed: int = 0


def _zipf_probs(vocab_size: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


def make_batch(spec: TokenBatchSpec, step: int) -> dict[str, np.ndarray]:
    """One (tokens, targets) batch, deterministic in (spec.seed, step)."""
    rng = np.random.default_rng(np.random.SeedSequence([spec.seed, step]))
    probs = _zipf_probs(min(spec.vocab_size, 8192))
    base = rng.choice(len(probs), size=(spec.batch_size, spec.seq_len + 1), p=probs)
    # inject learnable bigram structure: with p=0.5, t[i+1] = f(t[i])
    succ = (np.arange(len(probs)) * 31 + 7) % len(probs)
    copy_mask = rng.random((spec.batch_size, spec.seq_len)) < 0.5
    for t in range(spec.seq_len):
        nxt = succ[base[:, t]]
        base[:, t + 1] = np.where(copy_mask[:, t], nxt, base[:, t + 1])
    tokens = base[:, :-1].astype(np.int32)
    targets = base[:, 1:].astype(np.int32)
    return {"tokens": tokens, "targets": targets}


def synthetic_lm_batches(spec: TokenBatchSpec, start_step: int = 0) -> Iterator[dict]:
    """Infinite restartable batch stream (see module docstring)."""
    step = start_step
    while True:
        yield make_batch(spec, step)
        step += 1
