"""Synthetic data with planted co-cluster ground truth.

The paper evaluates on Amazon-1000 (1000x1000 dense review vectors),
CLASSIC4 (18000x1000 doc-term) and RCV1-Large (sparse, very large). Those
corpora are not redistributable inside this container, so the benchmark
harness uses *planted-structure proxies* with matching shapes, density and
block-diagonal-plus-noise statistics — which is exactly the structure the
co-clustering metrics (NMI/ARI vs ground truth) need.

Generator model: pick k row clusters x d col clusters; each (r, c) pair is a
potential co-cluster with mean ``mu[r, c]``; entries are
``mu[u_i, v_j] + noise``; for sparse variants a Bernoulli mask keeps the
target density and background blocks have zero mean (classic checkerboard /
block-diagonal planting).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "PlantedCoClusters",
    "PlantedOverlapCoClusters",
    "planted_cocluster_matrix",
    "planted_overlapping_cocluster_matrix",
    "to_bcoo",
    "amazon1000_proxy",
    "classic4_proxy",
    "rcv1_proxy",
]


def to_bcoo(matrix: np.ndarray):
    """Dense (planted) matrix -> canonical 2-D jax BCOO.

    Built from ``np.nonzero`` triplets (row-major sorted, unique indices)
    rather than ``BCOO.fromdense`` so ``nse`` is exact and no jax scan
    runs over the dense array. The proxies are generated dense (the
    planting model needs the full checkerboard), but downstream the
    sparse pipeline only ever sees this BCOO.
    """
    import jax.numpy as jnp
    from jax.experimental import sparse as jsparse

    mat = np.asarray(matrix)
    r, c = np.nonzero(mat)
    indices = jnp.asarray(np.stack([r, c], axis=1).astype(np.int32))
    return jsparse.BCOO((jnp.asarray(mat[r, c]), indices), shape=mat.shape,
                        indices_sorted=True, unique_indices=True)


@dataclasses.dataclass
class PlantedCoClusters:
    matrix: np.ndarray          # (M, N) float32
    row_labels: np.ndarray      # (M,) int32 ground truth
    col_labels: np.ndarray      # (N,) int32
    k: int
    d: int
    density: float              # fraction of nonzeros

    @property
    def shape(self):
        return self.matrix.shape

    def bcoo(self):
        """The planted matrix as a jax BCOO (see ``to_bcoo``)."""
        return to_bcoo(self.matrix)


def planted_cocluster_matrix(
    rng: np.random.Generator,
    n_rows: int,
    n_cols: int,
    k: int,
    d: int | None = None,
    *,
    signal: float = 3.0,
    noise: float = 1.0,
    density: float = 1.0,
    diagonal_only: bool = False,
    balanced: bool = True,
    dtype=np.float32,
) -> PlantedCoClusters:
    """Checkerboard (or block-diagonal if ``diagonal_only``) planted matrix.

    ``signal/noise`` controls difficulty; ``density < 1`` produces sparse
    data (zeros off the support). Labels are shuffled so no algorithm can
    exploit index order.
    """
    if d is None:
        d = k
    if balanced:
        row_labels = np.arange(n_rows) % k
        col_labels = np.arange(n_cols) % d
    else:
        row_labels = rng.integers(0, k, n_rows)
        col_labels = rng.integers(0, d, n_cols)
    rng.shuffle(row_labels)
    rng.shuffle(col_labels)

    if diagonal_only:
        mu = np.zeros((k, d), dtype)
        for i in range(min(k, d)):
            mu[i, i] = signal
    else:
        # checkerboard: distinct mean per (r,c) cell, spread in [0, signal]
        mu = rng.uniform(0.0, signal, (k, d)).astype(dtype)

    mat = mu[row_labels][:, col_labels].astype(dtype)
    mat += rng.normal(0.0, noise, mat.shape).astype(dtype)
    if density < 1.0:
        mask = rng.random(mat.shape) < density
        mat = np.where(mask, mat, 0.0).astype(dtype)
    return PlantedCoClusters(
        matrix=mat,
        row_labels=row_labels.astype(np.int32),
        col_labels=col_labels.astype(np.int32),
        k=k,
        d=d,
        density=float((mat != 0).mean()),
    )


@dataclasses.dataclass
class PlantedOverlapCoClusters:
    """Overlapping, non-exhaustive planted ground truth (DESIGN.md §11).

    Membership matrices replace label vectors: a row (column) may belong
    to several co-clusters or to none. ``row_labels``/``col_labels`` are
    the hard projections (argmax membership, -1 for outliers) so the
    classic NMI/ARI metrics still apply to the covered points.
    """

    matrix: np.ndarray           # (M, N) float32
    row_membership: np.ndarray   # (M, k) bool
    col_membership: np.ndarray   # (N, d) bool
    k: int
    d: int
    density: float

    @property
    def shape(self):
        return self.matrix.shape

    @property
    def row_labels(self) -> np.ndarray:
        m = self.row_membership
        return np.where(m.any(1), m.argmax(1), -1).astype(np.int32)

    @property
    def col_labels(self) -> np.ndarray:
        m = self.col_membership
        return np.where(m.any(1), m.argmax(1), -1).astype(np.int32)

    def bcoo(self):
        return to_bcoo(self.matrix)


def _overlap_membership(rng, n: int, k: int, overlap_frac: float,
                        outlier_frac: float) -> np.ndarray:
    """(n, k) bool membership: balanced primaries, ``overlap_frac`` of the
    covered points add a second distinct cluster, ``outlier_frac`` belong
    to none."""
    member = np.zeros((n, k), bool)
    n_out = int(round(outlier_frac * n))
    covered = n - n_out
    primary = np.arange(covered) % k
    member[np.arange(covered), primary] = True
    n_ov = int(round(overlap_frac * covered))
    second = (primary[:n_ov] + 1 + rng.integers(0, k - 1, n_ov)) % k
    member[np.arange(n_ov), second] = True
    member = member[rng.permutation(n)]
    return member


def planted_overlapping_cocluster_matrix(
    rng: np.random.Generator,
    n_rows: int,
    n_cols: int,
    k: int,
    d: int | None = None,
    *,
    row_overlap: float = 0.2,
    row_outliers: float = 0.05,
    col_overlap: float = 0.0,
    col_outliers: float = 0.0,
    signal: float = 4.0,
    noise: float = 1.0,
    density: float = 1.0,
    dtype=np.float32,
) -> PlantedOverlapCoClusters:
    """Planted co-clusters with overlapping and unassigned rows/columns.

    The NEO-CC regime (Whang & Dhillon): a point in several co-clusters
    has the *mean* of its clusters' checkerboard profiles (it sits midway
    between the cluster centroids — genuinely ambiguous, so consensus
    votes split across its clusters), and an outlier point is an
    *anomalous* row/column — an unstructured random profile at signal
    scale, so its restriction to different column blocks matches
    different clusters and its votes scatter instead of concentrating.
    ``row_overlap``/``col_overlap`` are the fraction of covered points
    with a second cluster; ``row_outliers``/``col_outliers`` the
    fraction belonging to none.

    Cell means are a circulant shift pattern (every cluster profile is a
    rotation of the same ramp, plus a seeded perturbation): equal norms,
    guaranteed pairwise separation — iid-uniform checkerboards
    occasionally draw two near-identical cluster profiles, which
    destroys the single-membership base clustering and with it any
    overlap measurement (the failure is in the planting, not the
    algorithm).
    """
    if d is None:
        d = k
    row_m = _overlap_membership(rng, n_rows, k, row_overlap, row_outliers)
    col_m = _overlap_membership(rng, n_cols, d, col_overlap, col_outliers)
    base = np.linspace(0.2, 1.0, max(k, d))
    mu = signal * base[(np.arange(k)[:, None] + np.arange(d)[None, :]) % max(k, d)]
    mu = (mu + rng.uniform(0.0, 0.1 * signal, (k, d))).astype(dtype)
    rw = row_m.astype(dtype) / np.maximum(row_m.sum(1, keepdims=True), 1)
    cw = col_m.astype(dtype) / np.maximum(col_m.sum(1, keepdims=True), 1)
    mat = rw @ mu @ cw.T
    row_out = ~row_m.any(1)
    col_out = ~col_m.any(1)
    mat[row_out] = rng.uniform(0.0, signal, (int(row_out.sum()), n_cols))
    mat[:, col_out] = rng.uniform(0.0, signal, (n_rows, int(col_out.sum())))
    mat += rng.normal(0.0, noise, mat.shape).astype(dtype)
    if density < 1.0:
        mask = rng.random(mat.shape) < density
        mat = np.where(mask, mat, 0.0).astype(dtype)
    return PlantedOverlapCoClusters(
        matrix=mat.astype(dtype),
        row_membership=row_m,
        col_membership=col_m,
        k=k,
        d=d,
        density=float((mat != 0).mean()),
    )


def amazon1000_proxy(seed: int = 0) -> PlantedCoClusters:
    """1000 x 1000 dense review-vector proxy (5 topics x 5 aspect groups)."""
    rng = np.random.default_rng(seed)
    return planted_cocluster_matrix(rng, 1000, 1000, k=5, d=5,
                                    signal=3.0, noise=1.0, density=1.0)


def classic4_proxy(seed: int = 0, n_docs: int = 18000) -> PlantedCoClusters:
    """18000 x 1000 doc-term proxy (4 collections), mildly sparse."""
    rng = np.random.default_rng(seed)
    return planted_cocluster_matrix(rng, n_docs, 1000, k=4, d=4,
                                    signal=4.0, noise=1.0, density=0.15)


def rcv1_proxy(seed: int = 0, n_docs: int = 100_000, n_terms: int = 5000) -> PlantedCoClusters:
    """RCV1-scale sparse proxy. Default trimmed to container memory; the
    benchmark harness scales it with ``--scale``."""
    rng = np.random.default_rng(seed)
    return planted_cocluster_matrix(rng, n_docs, n_terms, k=10, d=10,
                                    signal=5.0, noise=0.4, density=0.05)
