"""Synthetic data with planted co-cluster ground truth.

The paper evaluates on Amazon-1000 (1000x1000 dense review vectors),
CLASSIC4 (18000x1000 doc-term) and RCV1-Large (sparse, very large). Those
corpora are not redistributable inside this container, so the benchmark
harness uses *planted-structure proxies* with matching shapes, density and
block-diagonal-plus-noise statistics — which is exactly the structure the
co-clustering metrics (NMI/ARI vs ground truth) need.

Generator model: pick k row clusters x d col clusters; each (r, c) pair is a
potential co-cluster with mean ``mu[r, c]``; entries are
``mu[u_i, v_j] + noise``; for sparse variants a Bernoulli mask keeps the
target density and background blocks have zero mean (classic checkerboard /
block-diagonal planting).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = [
    "PlantedCoClusters",
    "planted_cocluster_matrix",
    "to_bcoo",
    "amazon1000_proxy",
    "classic4_proxy",
    "rcv1_proxy",
]


def to_bcoo(matrix: np.ndarray):
    """Dense (planted) matrix -> canonical 2-D jax BCOO.

    Built from ``np.nonzero`` triplets (row-major sorted, unique indices)
    rather than ``BCOO.fromdense`` so ``nse`` is exact and no jax scan
    runs over the dense array. The proxies are generated dense (the
    planting model needs the full checkerboard), but downstream the
    sparse pipeline only ever sees this BCOO.
    """
    import jax.numpy as jnp
    from jax.experimental import sparse as jsparse

    mat = np.asarray(matrix)
    r, c = np.nonzero(mat)
    indices = jnp.asarray(np.stack([r, c], axis=1).astype(np.int32))
    return jsparse.BCOO((jnp.asarray(mat[r, c]), indices), shape=mat.shape,
                        indices_sorted=True, unique_indices=True)


@dataclasses.dataclass
class PlantedCoClusters:
    matrix: np.ndarray          # (M, N) float32
    row_labels: np.ndarray      # (M,) int32 ground truth
    col_labels: np.ndarray      # (N,) int32
    k: int
    d: int
    density: float              # fraction of nonzeros

    @property
    def shape(self):
        return self.matrix.shape

    def bcoo(self):
        """The planted matrix as a jax BCOO (see ``to_bcoo``)."""
        return to_bcoo(self.matrix)


def planted_cocluster_matrix(
    rng: np.random.Generator,
    n_rows: int,
    n_cols: int,
    k: int,
    d: int | None = None,
    *,
    signal: float = 3.0,
    noise: float = 1.0,
    density: float = 1.0,
    diagonal_only: bool = False,
    balanced: bool = True,
    dtype=np.float32,
) -> PlantedCoClusters:
    """Checkerboard (or block-diagonal if ``diagonal_only``) planted matrix.

    ``signal/noise`` controls difficulty; ``density < 1`` produces sparse
    data (zeros off the support). Labels are shuffled so no algorithm can
    exploit index order.
    """
    if d is None:
        d = k
    if balanced:
        row_labels = np.arange(n_rows) % k
        col_labels = np.arange(n_cols) % d
    else:
        row_labels = rng.integers(0, k, n_rows)
        col_labels = rng.integers(0, d, n_cols)
    rng.shuffle(row_labels)
    rng.shuffle(col_labels)

    if diagonal_only:
        mu = np.zeros((k, d), dtype)
        for i in range(min(k, d)):
            mu[i, i] = signal
    else:
        # checkerboard: distinct mean per (r,c) cell, spread in [0, signal]
        mu = rng.uniform(0.0, signal, (k, d)).astype(dtype)

    mat = mu[row_labels][:, col_labels].astype(dtype)
    mat += rng.normal(0.0, noise, mat.shape).astype(dtype)
    if density < 1.0:
        mask = rng.random(mat.shape) < density
        mat = np.where(mask, mat, 0.0).astype(dtype)
    return PlantedCoClusters(
        matrix=mat,
        row_labels=row_labels.astype(np.int32),
        col_labels=col_labels.astype(np.int32),
        k=k,
        d=d,
        density=float((mat != 0).mean()),
    )


def amazon1000_proxy(seed: int = 0) -> PlantedCoClusters:
    """1000 x 1000 dense review-vector proxy (5 topics x 5 aspect groups)."""
    rng = np.random.default_rng(seed)
    return planted_cocluster_matrix(rng, 1000, 1000, k=5, d=5,
                                    signal=3.0, noise=1.0, density=1.0)


def classic4_proxy(seed: int = 0, n_docs: int = 18000) -> PlantedCoClusters:
    """18000 x 1000 doc-term proxy (4 collections), mildly sparse."""
    rng = np.random.default_rng(seed)
    return planted_cocluster_matrix(rng, n_docs, 1000, k=4, d=4,
                                    signal=4.0, noise=1.0, density=0.15)


def rcv1_proxy(seed: int = 0, n_docs: int = 100_000, n_terms: int = 5000) -> PlantedCoClusters:
    """RCV1-scale sparse proxy. Default trimmed to container memory; the
    benchmark harness scales it with ``--scale``."""
    rng = np.random.default_rng(seed)
    return planted_cocluster_matrix(rng, n_docs, n_terms, k=10, d=10,
                                    signal=5.0, noise=0.4, density=0.05)
