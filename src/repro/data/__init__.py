from .synthetic import (
    PlantedCoClusters,
    amazon1000_proxy,
    classic4_proxy,
    planted_cocluster_matrix,
    rcv1_proxy,
    to_bcoo,
)
from .tokens import TokenBatchSpec, synthetic_lm_batches

__all__ = [
    "PlantedCoClusters",
    "planted_cocluster_matrix",
    "to_bcoo",
    "amazon1000_proxy",
    "classic4_proxy",
    "rcv1_proxy",
    "TokenBatchSpec",
    "synthetic_lm_batches",
]
