"""Gradient compression for cross-pod (DCN) data parallelism.

At 1000+ nodes the pod-level gradient all-reduce crosses the slow DCN
fabric; compressing it is the standard distributed-optimization trick.
Two schemes, both with error feedback (the residual of what compression
dropped is added back next step, preserving convergence — Karimireddy et
al. 2019):

  * ``topk``: keep the largest-|g| fraction per tensor (magnitude sparsify).
  * ``int8``: per-tensor affine quantization to int8.

Usage in the train step: compress(g + residual) -> communicate the compact
form across the ``pod`` axis -> decompress; residual' = (g + residual) -
decompressed. ``compressed_allreduce`` packages the whole pattern around
``jax.lax.pmean``. The compression is simulated losslessly in the dry-run
(the collective carries the already-decompressed tensor; bytes accounting
for §Roofline uses the compact payload size).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["topk_compress", "topk_decompress", "int8_compress",
           "int8_decompress", "compressed_allreduce", "payload_bytes"]


def topk_compress(g: jax.Array, fraction: float = 0.05):
    """Keep the top-``fraction`` entries by magnitude. Returns
    (values, flat_indices, shape)."""
    flat = g.reshape(-1).astype(jnp.float32)
    k = max(1, int(flat.size * fraction))
    vals, idx = jax.lax.top_k(jnp.abs(flat), k)
    vals = flat[idx]
    return vals, idx, g.shape


def topk_decompress(vals, idx, shape):
    flat = jnp.zeros(int(jnp.prod(jnp.asarray(shape))), jnp.float32)
    flat = flat.at[idx].set(vals)
    return flat.reshape(shape)


def int8_compress(g: jax.Array):
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q, scale):
    return q.astype(jnp.float32) * scale


def payload_bytes(g: jax.Array, scheme: str, fraction: float = 0.05) -> int:
    n = g.size
    if scheme == "topk":
        k = max(1, int(n * fraction))
        return k * (4 + 4)  # f32 value + i32 index
    if scheme == "int8":
        return n * 1 + 4
    return n * 4


def compressed_allreduce(grads, residuals, axis_name: str,
                         scheme: str = "int8", fraction: float = 0.05):
    """Error-feedback compressed mean-all-reduce over ``axis_name``.

    Works per-leaf; returns (reduced_grads, new_residuals). Inside jit/
    shard_map only — ``axis_name`` must be bound.
    """
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        if scheme == "int8":
            q, scale = int8_compress(gf)
            approx = int8_decompress(q, scale)
        elif scheme == "topk":
            vals, idx, shape = topk_compress(gf, fraction)
            approx = topk_decompress(vals, idx, shape)
        else:
            approx = gf
        new_r = gf - approx
        reduced = jax.lax.pmean(approx, axis_name)
        return reduced, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))
