"""LR schedules: WSD (Warmup-Stable-Decay, the MiniCPM schedule) and cosine.

Schedules return a multiplicative factor on the peak LR, as a jittable
function of the (traced) step — usable inside a compiled train step.
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["wsd_schedule", "cosine_schedule", "linear_warmup"]


def linear_warmup(step, warmup_steps: int):
    return jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))


def wsd_schedule(step, *, warmup_steps: int, stable_steps: int,
                 decay_steps: int, final_scale: float = 0.1):
    """Warmup-Stable-Decay (arXiv:2404.06395 §4): linear warmup, long flat
    stable phase at peak LR, then a fast exponential-style decay tail."""
    step = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup_steps)
    in_decay = step > (warmup_steps + stable_steps)
    decay_t = jnp.clip((step - warmup_steps - stable_steps)
                       / max(decay_steps, 1), 0.0, 1.0)
    decay = final_scale ** decay_t  # exponential interpolation 1 -> final
    return jnp.where(in_decay, decay, warm)


def cosine_schedule(step, *, warmup_steps: int, total_steps: int,
                    final_scale: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = linear_warmup(step, warmup_steps)
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                 0.0, 1.0)
    cos = final_scale + (1 - final_scale) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, cos)
