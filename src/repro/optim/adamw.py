"""AdamW in pure JAX, pytree-native, sharding-transparent.

Optimizer state is a pytree of the same structure as params, so any param
sharding policy (FSDP over ``data``, TP over ``model``) applies verbatim to
``m``/``v`` — the states co-locate with their weights and the update is
fully local (no optimizer collectives).

Supports: bias-corrected moments, decoupled weight decay, global-norm
clipping, and optional gradient compression hooks (see grad_compress.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "global_norm", "clip_by_global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4                 # peak LR; scaled by schedule(step)
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0           # 0 disables


class AdamWState(NamedTuple):
    step: jax.Array                  # () int32
    m: Any                           # pytree like params (f32)
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, params, grads, state: AdamWState,
                 lr_scale: jax.Array | float = 1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    if cfg.clip_norm > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = global_norm(grads)
    step = state.step + 1
    b1t = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        m_hat = m_new / b1t
        v_hat = v_new / b2t
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
