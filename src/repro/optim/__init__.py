from . import grad_compress
from .adamw import AdamWConfig, AdamWState, adamw_init, adamw_update, global_norm
from .schedule import cosine_schedule, wsd_schedule

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "global_norm", "cosine_schedule", "wsd_schedule", "grad_compress"]
