"""Shared BENCH_*.json writer.

One read-merge-write helper for every producer of benchmark trajectory
files (``benchmarks/run.py`` sections and ``launch/serve_lamc.py``), so
partial runs refresh their own rows without clobbering the rest and the
on-disk format cannot drift between writers.
"""

from __future__ import annotations

import json

__all__ = ["merge_rows"]


def merge_rows(path: str, new_rows: dict) -> int:
    """Merge ``new_rows`` into the JSON dict at ``path``; returns total size."""
    merged = {}
    try:
        with open(path) as f:
            merged = json.load(f)
    except (OSError, ValueError):
        pass
    merged.update(new_rows)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    return len(merged)
