"""Shared BENCH_*.json writer.

One read-merge-write helper for every producer of benchmark trajectory
files (``benchmarks/run.py`` sections and ``launch/serve_lamc.py``), so
partial runs refresh their own rows without clobbering the rest and the
on-disk format cannot drift between writers.

Each trajectory file owns a key namespace (``BENCH_sparse.json`` owns
``sparse_*``, ``BENCH_stream.json`` owns ``stream_*``/``serve_*``,
``BENCH_atoms.json`` everything else). Writers declare their namespace
via ``own_prefixes`` / ``foreign_prefixes`` and stale foreign keys —
rows a previous, differently-routed writer left behind — are scrubbed on
rewrite instead of accreting forever.
"""

from __future__ import annotations

import json

__all__ = ["merge_rows"]


def merge_rows(path: str, new_rows: dict,
               own_prefixes: tuple[str, ...] | None = None,
               foreign_prefixes: tuple[str, ...] = ()) -> int:
    """Merge ``new_rows`` into the JSON dict at ``path``; returns total size.

    ``own_prefixes``: if given, pre-existing keys *not* matching any of
    these prefixes are dropped (the file owns exactly that namespace).
    ``foreign_prefixes``: pre-existing keys matching any of these are
    dropped (keys owned by *another* trajectory file). Both scrubs apply
    only to what is already on disk — ``new_rows`` always lands as given.
    """
    merged = {}
    try:
        with open(path) as f:
            merged = json.load(f)
    except (OSError, ValueError):
        pass
    if own_prefixes is not None:
        merged = {k: v for k, v in merged.items()
                  if k.startswith(tuple(own_prefixes))}
    if foreign_prefixes:
        merged = {k: v for k, v in merged.items()
                  if not k.startswith(tuple(foreign_prefixes))}
    merged.update(new_rows)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    return len(merged)
