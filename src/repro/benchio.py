"""Shared BENCH_*.json writer.

One read-merge-write helper for every producer of benchmark trajectory
files (``benchmarks/run.py`` sections and ``launch/serve_lamc.py``), so
partial runs refresh their own rows without clobbering the rest and the
on-disk format cannot drift between writers.

Each trajectory file owns a key namespace (``BENCH_sparse.json`` owns
``sparse_*``, ``BENCH_stream.json`` owns ``stream_*``/``serve_*``,
``BENCH_atoms.json`` everything else). Writers declare their namespace
via ``own_prefixes`` / ``foreign_prefixes`` and stale foreign keys —
rows a previous, differently-routed writer left behind — are scrubbed on
rewrite instead of accreting forever.

Every write also refreshes a **provenance sidecar**, ``BENCH_meta.json``
in the same directory: per trajectory file, the git SHA, JAX version,
backend/device kind, and UTC timestamp of its last writer. The bare
numbers in the trajectory files are only a trend if each point is
attributable to a commit and a machine; the sidecar makes the BENCH
history carry that attribution instead of relying on git archaeology.
"""

from __future__ import annotations

import datetime
import json
import os
import subprocess

__all__ = ["merge_rows", "provenance", "META_BASENAME"]

#: sidecar filename, written next to each trajectory file.
META_BASENAME = "BENCH_meta.json"


def provenance() -> dict:
    """Environment fingerprint for one benchmark write.

    Never raises: outside a git checkout (or before JAX is importable)
    the fields degrade to ``"unavailable"`` — a bench row with partial
    provenance still beats one with none.
    """
    sha = "unavailable"
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        if proc.returncode == 0 and proc.stdout.strip():
            sha = proc.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    info = {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
    try:
        import jax
        info["jax_version"] = jax.__version__
        info["backend"] = jax.default_backend()
        info["device_kind"] = jax.devices()[0].device_kind
    except Exception:  # noqa: BLE001 — provenance must never fail a bench
        info.setdefault("jax_version", "unavailable")
        info.setdefault("backend", "unavailable")
        info.setdefault("device_kind", "unavailable")
    return info


def _write_meta_sidecar(path: str, n_rows: int) -> None:
    meta_path = os.path.join(
        os.path.dirname(os.path.abspath(path)), META_BASENAME)
    merged = {}
    try:
        with open(meta_path) as f:
            merged = json.load(f)
        if not isinstance(merged, dict):
            merged = {}
    except (OSError, ValueError):
        pass
    entry = provenance()
    entry["rows"] = n_rows
    merged[os.path.basename(path)] = entry
    with open(meta_path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)


def merge_rows(path: str, new_rows: dict,
               own_prefixes: tuple[str, ...] | None = None,
               foreign_prefixes: tuple[str, ...] = (),
               replace_prefixes: tuple[str, ...] = ()) -> int:
    """Merge ``new_rows`` into the JSON dict at ``path``; returns total size.

    ``own_prefixes``: if given, pre-existing keys *not* matching any of
    these prefixes are dropped (the file owns exactly that namespace).
    ``foreign_prefixes``: pre-existing keys matching any of these are
    dropped (keys owned by *another* trajectory file).
    ``replace_prefixes``: pre-existing keys matching any of these are
    dropped even when they belong to this file's own namespace — for
    writers that regenerate a whole row family per run, so renamed or
    retired rows cannot accrete alongside their successors. All three
    scrubs apply only to what is already on disk — ``new_rows`` always
    lands as given.

    Side effect: the ``BENCH_meta.json`` sidecar next to ``path`` gains
    (or refreshes) this file's provenance entry.
    """
    merged = {}
    try:
        with open(path) as f:
            merged = json.load(f)
    except (OSError, ValueError):
        pass
    if own_prefixes is not None:
        merged = {k: v for k, v in merged.items()
                  if k.startswith(tuple(own_prefixes))}
    if foreign_prefixes:
        merged = {k: v for k, v in merged.items()
                  if not k.startswith(tuple(foreign_prefixes))}
    if replace_prefixes:
        merged = {k: v for k, v in merged.items()
                  if not k.startswith(tuple(replace_prefixes))}
    merged.update(new_rows)
    with open(path, "w") as f:
        json.dump(merged, f, indent=2, sort_keys=True)
    _write_meta_sidecar(path, len(merged))
    return len(merged)
