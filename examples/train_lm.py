"""End-to-end LM training driver example: a smollm-family model trained for
a few hundred steps on the synthetic restartable pipeline, with periodic
checkpointing, an injected mid-run failure, and automatic recovery.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

On a pod the identical driver takes --full-config and the production mesh
(the multi-pod dry-run proves those configs lower + compile).
"""

import argparse
import shutil
import tempfile

from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--fail-at", type=int, default=120,
                    help="inject a simulated failure at this step (0=off)")
    args = ap.parse_args()

    ckpt_dir = tempfile.mkdtemp(prefix="repro_train_lm_")
    try:
        run = train_loop(
            arch="smollm-360m",          # reduced config of the same family
            steps=args.steps,
            batch_size=args.batch_size,
            seq_len=args.seq_len,
            ckpt_dir=ckpt_dir,
            save_every=50,
            fail_at=(args.fail_at,) if args.fail_at else (),
            lr=3e-3,
        )
        first = run.losses[0][1]
        last = run.losses[-1][1]
        print(f"\nloss {first:.3f} -> {last:.3f} over {run.final_step} steps "
              f"({run.failures} failure(s) recovered, {run.wall_s:.0f}s)")
        assert last < first, "training did not reduce loss"
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
