"""LAMC x MoE integration: co-cluster the token-type x expert affinity
matrix of a trained MoE router to discover expert specialization groups
(DESIGN.md — the paper's technique applied to the LM stack).

    PYTHONPATH=src python examples/moe_expert_analysis.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced
from repro.core import LAMCConfig, lamc_cocluster
from repro.data.tokens import TokenBatchSpec, make_batch
from repro.models import build_model
from repro.models.moe import moe_apply


def main():
    cfg = reduced("deepseek-moe-16b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # run a few batches through layer-1's router, accumulating
    # token-id x expert affinities
    spec = TokenBatchSpec(batch_size=8, seq_len=64, vocab_size=cfg.vocab_size,
                          seed=0)
    n_types = cfg.vocab_size
    affinity = np.zeros((n_types, cfg.n_experts), np.float32)
    # router weights of the first scanned unit's MoE
    router = np.asarray(params["units"]["0"]["moe"]["router"]["w"][0])
    embed = np.asarray(params["embed"]["table"], np.float32)
    for step in range(4):
        batch = make_batch(spec, step)
        toks = batch["tokens"].ravel()
        logits = embed[toks] @ router                    # (T, E)
        probs = jax.nn.softmax(jnp.asarray(logits), -1)
        np.add.at(affinity, toks, np.asarray(probs))

    # co-cluster token-types x experts
    seen = affinity.sum(1) > 0
    mat = jnp.asarray(affinity[seen])
    print(f"affinity matrix: {mat.shape} (token types x {cfg.n_experts} experts)")
    cfg_l = LAMCConfig(n_row_clusters=4, n_col_clusters=2,
                       atom_row_clusters=4, atom_col_clusters=2,
                       min_cocluster_rows=mat.shape[0] // 8,
                       min_cocluster_cols=2)
    out = lamc_cocluster(mat, cfg_l)
    groups = np.asarray(out.col_labels)
    print("expert groups:", {g: list(np.where(groups == g)[0]) for g in set(groups)})
    rl = np.asarray(out.row_labels)
    print("token-type cluster sizes:", np.bincount(rl).tolist())


if __name__ == "__main__":
    main()
