"""Quickstart: co-cluster a planted matrix with LAMC and score it.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import LAMCConfig, lamc_cocluster, cocluster_scores
from repro.core.baselines import scc_full
from repro.data import planted_cocluster_matrix
import jax


def main():
    rng = np.random.default_rng(0)
    data = planted_cocluster_matrix(rng, 1200, 900, k=5, d=5,
                                    signal=4.0, noise=0.7)
    a = jnp.asarray(data.matrix)

    # the probabilistic model picks (m, n, T_p) for a 95% detection floor
    cfg = LAMCConfig(
        n_row_clusters=5, n_col_clusters=5,
        min_cocluster_rows=240,   # the smallest co-cluster we care about
        min_cocluster_cols=180,
        p_thresh=0.95,
        workers=4,                # pretend 4 parallel units; plan adapts
    )
    out = lamc_cocluster(a, cfg)
    plan = out.plan
    print(f"plan: {plan.m}x{plan.n} blocks of {plan.phi}x{plan.psi}, "
          f"T_p={plan.t_p} resamples, detection>= {plan.detection_p:.3f}")

    s = cocluster_scores(np.asarray(out.row_labels), np.asarray(out.col_labels),
                         data.row_labels, data.col_labels)
    print(f"LAMC     : NMI={s['nmi']:.3f} ARI={s['ari']:.3f}")

    base = scc_full(jax.random.key(0), a, 5)
    sb = cocluster_scores(np.asarray(base.row_labels), np.asarray(base.col_labels),
                          data.row_labels, data.col_labels)
    print(f"full SCC : NMI={sb['nmi']:.3f} ARI={sb['ari']:.3f}")


if __name__ == "__main__":
    main()
