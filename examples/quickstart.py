"""Quickstart: co-cluster a planted matrix with LAMC, persist the fitted
model, and assign new rows against the restored artifact.

    PYTHONPATH=src python examples/quickstart.py

Walks the full production loop: batch fit -> score -> save the
CoclusterModel checkpoint -> load it back -> out-of-sample assign_rows —
then prints the fit's phase-span trace (repro.obs, DESIGN.md §14) so the
wall-clock breakdown of what just ran is part of the demo.
"""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs, streaming
from repro.core import LAMCConfig, lamc_cocluster, cocluster_scores
from repro.core.baselines import scc_full
from repro.core.metrics import nmi
from repro.data import planted_cocluster_matrix


def main():
    obs.configure(enabled=True)  # span-trace the whole loop (DESIGN.md §14)
    obs.reset_trace()
    rng = np.random.default_rng(0)
    # 1400 rows planted; fit on the first 1200, hold out 200 for serving
    data = planted_cocluster_matrix(rng, 1400, 900, k=5, d=5,
                                    signal=4.0, noise=0.7)
    a = jnp.asarray(data.matrix[:1200])
    heldout = jnp.asarray(data.matrix[1200:])

    # the probabilistic model picks (m, n, T_p) for a 95% detection floor
    cfg = LAMCConfig(
        n_row_clusters=5, n_col_clusters=5,
        min_cocluster_rows=240,   # the smallest co-cluster we care about
        min_cocluster_cols=180,
        p_thresh=0.95,
        workers=4,                # pretend 4 parallel units; plan adapts
    )
    out = lamc_cocluster(a, cfg)
    plan = out.plan
    print(f"plan: {plan.m}x{plan.n} blocks of {plan.phi}x{plan.psi}, "
          f"T_p={plan.t_p} resamples, detection>= {plan.detection_p:.3f}")

    s = cocluster_scores(np.asarray(out.row_labels), np.asarray(out.col_labels),
                         data.row_labels[:1200], data.col_labels)
    print(f"LAMC     : NMI={s['nmi']:.3f} ARI={s['ari']:.3f}")

    base = scc_full(jax.random.key(0), a, 5)
    sb = cocluster_scores(np.asarray(base.row_labels), np.asarray(base.col_labels),
                          data.row_labels[:1200], data.col_labels)
    print(f"full SCC : NMI={sb['nmi']:.3f} ARI={sb['ari']:.3f}")

    # fit -> save -> load -> assign: the serving loop (DESIGN.md §10)
    with tempfile.TemporaryDirectory() as ckpt_dir:
        model = streaming.model_from_result(out)
        streaming.save_model(ckpt_dir, model, cfg=cfg, plan=plan)
        restored, meta = streaming.load_model(ckpt_dir)
        print(f"saved + restored model ({meta['kind']}, "
              f"{restored.n_rows}x{restored.n_cols})")
        res = streaming.assign_rows(restored, heldout)
        agree = nmi(np.asarray(res.labels), data.row_labels[1200:])
        print(f"held-out assign_rows: NMI vs planted truth = {agree:.3f}, "
              f"mean score {float(np.mean(np.asarray(res.score))):.3f}")

    # where the time went: the fenced span tree of everything above
    print("\nfit trace (python -m repro.obs renders saved traces):")
    print(obs.render_trace())


if __name__ == "__main__":
    main()
