"""Doc x term co-clustering on the CLASSIC4-shaped proxy (paper §V workload):
discovers document collections and their vocabularies simultaneously, then
serves topic assignment for unseen documents from the fitted model.

    PYTHONPATH=src python examples/text_coclustering.py
    PYTHONPATH=src python examples/text_coclustering.py --overlap
    PYTHONPATH=src python examples/text_coclustering.py --ckpt /path/to/model

With ``--ckpt`` pointing at a saved CoclusterModel the fit is skipped and
the checkpoint is served directly; an unfitted or stale checkpoint fails
loudly (``streaming.ModelLoadError``) instead of producing garbage labels.
``--overlap`` fits in the non-exhaustive assignment mode (DESIGN.md §11):
terms that serve several collections keep *multiple* memberships (a real
vocabulary effect — "model" belongs to both the CACM and MEDLINE
vocabularies) and terms whose votes never concentrate are flagged as
outliers instead of being forced into a topic.
"""

import argparse
import sys
import tempfile

import jax.numpy as jnp
import numpy as np

from repro import streaming
from repro.core import LAMCConfig, lamc_cocluster, cocluster_scores
from repro.core.metrics import nmi
from repro.data import classic4_proxy


def fit_model(data, ckpt_dir: str, overlap: bool = False):
    a = jnp.asarray(data.matrix)
    print(f"doc-term matrix: {data.shape}, density {data.density:.3f}")
    cfg = LAMCConfig(
        n_row_clusters=4, n_col_clusters=4,
        min_cocluster_rows=700, min_cocluster_cols=120,
        p_thresh=0.95, workers=8,
        # sparse doc-term data: a single doc hits only ~density * q anchor
        # terms, so out-of-sample scoring needs a wider anchor set than the
        # dense default (64) to see enough of each request
        signature_dim=256,
        assignment="overlap" if overlap else "hard",
    )
    out = lamc_cocluster(a, cfg)
    s = cocluster_scores(np.asarray(out.row_labels), np.asarray(out.col_labels),
                         data.row_labels, data.col_labels)
    print(f"plan {out.plan.m}x{out.plan.n} T_p={out.plan.t_p} -> "
          f"NMI={s['nmi']:.3f} ARI={s['ari']:.3f}")
    if overlap:
        show_overlap(out)
    model = streaming.model_from_result(out)
    streaming.save_model(ckpt_dir, model, cfg=cfg, plan=out.plan)
    return model


def show_overlap(out):
    """Multi-membership demo: which terms straddle topic vocabularies."""
    doc_m = np.asarray(out.row_membership)
    term_m = np.asarray(out.col_membership)
    for name, m in (("docs", doc_m), ("terms", term_m)):
        card = m.sum(1)
        multi, none = int((card >= 2).sum()), int((card == 0).sum())
        print(f"{name}: {int((card == 1).sum())} single-topic, "
              f"{multi} multi-topic, {none} outliers")
    multi_terms = np.nonzero(term_m.sum(1) >= 2)[0]
    for t in multi_terms[:8]:
        topics = np.nonzero(term_m[t])[0].tolist()
        votes = np.asarray(out.col_votes)[t]
        share = votes / max(votes.sum(), 1)
        print(f"  term {t}: topics {topics} "
              f"(vote shares {[f'{share[c]:.2f}' for c in topics]})")


def serve_from(model: streaming.CoclusterModel, data):
    # vote margins = per-document confidence (consensus strength)
    votes = np.asarray(model.row_votes)
    margin = np.sort(votes, 1)[:, -1] / np.maximum(votes.sum(1), 1)
    print(f"mean consensus confidence: {margin.mean():.2f} "
          f"(1.0 = all resamples agree)")

    # out-of-sample: assign "new" documents (here: the training docs,
    # scored only through the q anchor terms) against the topic signatures
    n = min(512, data.shape[0], model.n_rows)
    docs = jnp.asarray(data.matrix[:n])
    res = streaming.assign_rows(model, docs)
    agree = nmi(np.asarray(res.labels), np.asarray(model.row_labels[:n]))
    print(f"assign_rows on {n} docs: NMI vs fitted labels = {agree:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default=None,
                    help="serve this saved CoclusterModel instead of fitting")
    ap.add_argument("--n-docs", type=int, default=6000)
    ap.add_argument("--overlap", action="store_true",
                    help="fit in non-exhaustive overlap mode and demo "
                         "multi-membership terms (DESIGN.md §11)")
    args = ap.parse_args()

    data = classic4_proxy(seed=0, n_docs=args.n_docs)
    if args.ckpt is not None:
        try:
            model, meta = streaming.load_model(args.ckpt)
        except streaming.ModelLoadError as e:
            sys.exit(f"cannot serve from {args.ckpt!r}: {e}")
        if model.n_cols != data.shape[1]:
            sys.exit(
                f"cannot serve from {args.ckpt!r}: model was fitted on "
                f"{model.n_rows}x{model.n_cols} data but this corpus has "
                f"{data.shape[1]} terms (stale checkpoint?)")
        print(f"restored {meta['kind']} ({model.n_rows}x{model.n_cols})")
        serve_from(model, data)
        return

    with tempfile.TemporaryDirectory() as ckpt_dir:
        fit_model(data, ckpt_dir, overlap=args.overlap)
        # serve from the *restored* artifact — the same path a separate
        # serving process would take
        model, _ = streaming.load_model(ckpt_dir)
        serve_from(model, data)


if __name__ == "__main__":
    main()
