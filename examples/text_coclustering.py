"""Doc x term co-clustering on the CLASSIC4-shaped proxy (paper §V workload):
discovers document collections and their vocabularies simultaneously.

    PYTHONPATH=src python examples/text_coclustering.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LAMCConfig, lamc_cocluster, cocluster_scores
from repro.data import classic4_proxy


def main():
    data = classic4_proxy(seed=0, n_docs=6000)  # 6000 docs x 1000 terms
    a = jnp.asarray(data.matrix)
    print(f"doc-term matrix: {data.shape}, density {data.density:.3f}")

    cfg = LAMCConfig(
        n_row_clusters=4, n_col_clusters=4,
        min_cocluster_rows=700, min_cocluster_cols=120,
        p_thresh=0.95, workers=8,
    )
    out = lamc_cocluster(a, cfg)
    s = cocluster_scores(np.asarray(out.row_labels), np.asarray(out.col_labels),
                         data.row_labels, data.col_labels)
    print(f"plan {out.plan.m}x{out.plan.n} T_p={out.plan.t_p} -> "
          f"NMI={s['nmi']:.3f} ARI={s['ari']:.3f}")

    # vote margins = per-document confidence (consensus strength)
    votes = np.asarray(out.row_votes)
    margin = np.sort(votes, 1)[:, -1] / np.maximum(votes.sum(1), 1)
    print(f"mean consensus confidence: {margin.mean():.2f} "
          f"(1.0 = all resamples agree)")


if __name__ == "__main__":
    main()
