"""Table II reproduction: running time of full-matrix co-clustering vs LAMC.

Paper's claim: ~83% wall-time reduction for dense matrices, up to ~30% for
sparse. Mapping to this container (DESIGN.md §2):

  * "dense" row  — exact-SVD spectral atom (the paper's SCC cost profile,
    superlinear O(MN min(M,N))): partitioning pays off even on one worker.
  * "sparse" row — randomized-SVD atom (linear cost, the profile of
    sparse-aware methods): serial partitioning gains are smaller, mirroring
    the paper's dense/sparse asymmetry. True parallel speedup on a pod is
    additionally ~workers-fold (the dry-run's LAMC cells carry that term).

Matrices are planted-co-cluster proxies shaped like the paper's datasets.
All timings are wall-clock with a compile warm-up excluded.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LAMCConfig, lamc_cocluster
from repro.core.baselines import nmtf_full, scc_full
from repro.core.partition import PartitionPlan
from repro.data import planted_cocluster_matrix

ROWS = []


def _timed(fn, *args, repeats=1, **kw):
    out = fn(*args, **kw)           # warm-up / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats, out


def run(report=print):
    rng = np.random.default_rng(0)
    k = 5
    # dense cell: large enough that the superlinear exact SVD dominates the
    # fixed pipeline overhead (the paper's regime); 8x8 grid, T_p=1 —
    # serial block work = full/8, so the single-core ceiling is 87.5%.
    data_dense = planted_cocluster_matrix(rng, 3200, 2560, k=k, d=k,
                                          signal=4.0, noise=0.7)
    a = jnp.asarray(data_dense.matrix)
    t_full, _ = _timed(lambda: scc_full(jax.random.key(0), a, k,
                                        svd_method="exact").row_labels)
    plan_d = PartitionPlan(3200, 2560, m=8, n=8, phi=400, psi=320, t_p=1, seed=0)
    cfg = LAMCConfig(n_row_clusters=k, n_col_clusters=k, svd_method="exact")
    t_lamc, _ = _timed(lambda: lamc_cocluster(a, cfg, plan=plan_d).row_labels)
    red_dense = 100.0 * (1 - t_lamc / t_full)
    report(f"table2_dense_scc_full,{t_full*1e6:.0f},baseline_s={t_full:.2f}")
    report(f"table2_dense_lamc_scc,{t_lamc*1e6:.0f},reduction_pct={red_dense:.1f}")

    # sparse cell: low-density data needs larger blocks (enough nonzeros
    # per block) and consensus resamples — 4x4 grid with T_p=3 leaves a
    # serial ceiling of 1 - 3/4 = 25-30%, mirroring the paper's smaller
    # sparse gain ("up to 30%").
    data_sp = planted_cocluster_matrix(rng, 2400, 2000, k=k, d=k,
                                       signal=4.0, noise=0.5, density=0.05)
    asp = jnp.asarray(data_sp.matrix)
    t_full_s, _ = _timed(lambda: scc_full(jax.random.key(0), asp, k,
                                          svd_method="exact").row_labels)
    plan_s = PartitionPlan(2400, 2000, m=4, n=4, phi=600, psi=500, t_p=3, seed=0)
    t_lamc_s, _ = _timed(lambda: lamc_cocluster(asp, cfg, plan=plan_s).row_labels)
    red_sp = 100.0 * (1 - t_lamc_s / t_full_s)
    report(f"table2_sparse_scc_full,{t_full_s*1e6:.0f},baseline_s={t_full_s:.2f}")
    report(f"table2_sparse_lamc_scc,{t_lamc_s*1e6:.0f},reduction_pct={red_sp:.1f}")

    # NMTF rows (PNMTF baseline): multiplicative updates are LINEAR per
    # iteration, so serial partitioning cannot reduce FLOPs — single-core
    # reduction ~0 or negative by design; the gain is the workers-fold
    # parallel term carried by the dry-run cells (benchmarks/README.md).
    data_n = planted_cocluster_matrix(rng, 2000, 1600, k=k, d=k,
                                      signal=4.0, noise=0.7)
    an = jnp.asarray(data_n.matrix)
    plan_n = PartitionPlan(2000, 1600, m=4, n=4, phi=500, psi=400, t_p=1, seed=0)
    t_nmtf, _ = _timed(lambda: nmtf_full(jax.random.key(0), an, k,
                                         n_iter=100).row_labels)
    cfg_n = LAMCConfig(n_row_clusters=k, n_col_clusters=k, atom="nmtf",
                       nmtf_iters=100)
    t_lamc_n, _ = _timed(lambda: lamc_cocluster(an, cfg_n, plan=plan_n).row_labels)
    red_n = 100.0 * (1 - t_lamc_n / t_nmtf)
    report(f"table2_nmtf_full,{t_nmtf*1e6:.0f},baseline_s={t_nmtf:.2f}")
    report(f"table2_lamc_nmtf,{t_lamc_n*1e6:.0f},"
           f"reduction_pct={red_n:.1f}_serial_1core_see_notes")
    return {"dense_reduction_pct": red_dense, "sparse_reduction_pct": red_sp,
            "nmtf_reduction_pct": red_n}


if __name__ == "__main__":
    run()
