"""Theorem-1 validation: analytic detection bound vs Monte-Carlo truth.

Not a table in the paper, but the partitioning algorithm's correctness
rests on Eq. (3); this bench quantifies the bound's tightness across
co-cluster sizes and grids (consumed by benchmarks/README.md §Dry-run notes).
"""

from __future__ import annotations

import numpy as np

from repro.core import probability as P


def run(report=print):
    rng = np.random.default_rng(0)
    rows = []
    # adversarially small co-clusters / tight thresholds: the regime where
    # the bound is non-trivial and T_p > 1 actually gets exercised
    for (mk, nk, m, n, tm, tn) in [
        (40, 40, 4, 4, 8, 8),
        (30, 30, 8, 8, 4, 4),
        (60, 40, 8, 8, 6, 5),
        (25, 25, 4, 4, 6, 6),
    ]:
        mc = P.mc_failure_estimate(rng, mk, nk, 1000, 1000, m, n, tm, tn,
                                   trials=1000)
        bound = P.failure_bound(mk, nk, 1000, 1000, m, n, tm, tn)
        tp = P.min_resamples(0.95, mk, nk, 1000, 1000, m, n, tm, tn)
        report(f"prob_bound_Mk{mk}x{nk}_g{m}x{n}_T{tm}{tn},{bound*1e6:.0f},"
               f"mc={mc:.4f} bound={bound:.4f} tp95={tp}")
        rows.append((mk, nk, m, n, mc, bound, tp))
    return rows


if __name__ == "__main__":
    run()
