"""Table III reproduction: NMI / ARI of SCC, PNMTF, LAMC-SCC, LAMC-PNMTF on
the three dataset proxies (Amazon-1000, CLASSIC4, RCV1 — planted-structure
stand-ins with the paper's shapes/densities; DESIGN.md §7).

Expected qualitative result (paper Table III): LAMC variants match or beat
their unpartitioned atoms; everything processes every dataset (no '*'
failures) because partitioning bounds the per-task working set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LAMCConfig, lamc_cocluster
from repro.core.baselines import nmtf_full, scc_full
from repro.core.metrics import cocluster_scores
from repro.data import amazon1000_proxy, classic4_proxy, rcv1_proxy


def _eval(name, pred_rows, pred_cols, data, report):
    s = cocluster_scores(np.asarray(pred_rows), np.asarray(pred_cols),
                         data.row_labels, data.col_labels)
    report(f"table3_{name}_nmi,{s['nmi']*1e6:.0f},nmi={s['nmi']:.4f}")
    report(f"table3_{name}_ari,{s['ari']*1e6:.0f},ari={s['ari']:.4f}")
    return s


def run(report=print, rcv1_scale: float = 0.2):
    out = {}
    datasets = {
        "amazon1000": (amazon1000_proxy(0), 5),
        "classic4": (classic4_proxy(0, n_docs=6000), 4),
        # RCV1 proxy trimmed to container memory; --scale grows it
        "rcv1": (rcv1_proxy(0, n_docs=int(100_000 * rcv1_scale),
                            n_terms=2000), 10),
    }
    for dname, (data, k) in datasets.items():
        a = jnp.asarray(data.matrix)
        key = jax.random.key(0)

        scc = scc_full(key, a, k)
        out[f"{dname}/scc"] = _eval(f"{dname}_scc", scc.row_labels,
                                    scc.col_labels, data, report)

        nm = nmtf_full(key, a, k, n_iter=80)
        out[f"{dname}/pnmtf"] = _eval(f"{dname}_pnmtf", nm.row_labels,
                                      nm.col_labels, data, report)

        cfg = LAMCConfig(
            n_row_clusters=k, n_col_clusters=k,
            min_cocluster_rows=max(data.shape[0] // (2 * k), 8),
            min_cocluster_cols=max(data.shape[1] // (2 * k), 8),
            p_thresh=0.95, workers=4)
        lam = lamc_cocluster(a, cfg)
        out[f"{dname}/lamc_scc"] = _eval(f"{dname}_lamc_scc", lam.row_labels,
                                         lam.col_labels, data, report)

        cfg_n = LAMCConfig(
            n_row_clusters=k, n_col_clusters=k, atom="nmtf", nmtf_iters=80,
            min_cocluster_rows=max(data.shape[0] // (2 * k), 8),
            min_cocluster_cols=max(data.shape[1] // (2 * k), 8),
            p_thresh=0.95, workers=4)
        lamn = lamc_cocluster(a, cfg_n)
        out[f"{dname}/lamc_pnmtf"] = _eval(f"{dname}_lamc_pnmtf",
                                           lamn.row_labels, lamn.col_labels,
                                           data, report)
    return out


if __name__ == "__main__":
    run()
